"""Train a (reduced) assigned-architecture LM with the full substrate:
sharded-ready train step, AdamW, deterministic data pipeline, checkpointing,
and a simulated-failure restart demonstrating fault tolerance.

  PYTHONPATH=src python examples/train_lm.py [--arch rwkv6_3b] [--steps 30]
"""
import argparse
import os
import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).parent.parent
sys.path.insert(0, str(ROOT / "src"))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="rwkv6_3b")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    env = dict(os.environ, PYTHONPATH=str(ROOT / "src"))
    base = [
        sys.executable, "-m", "repro.launch.train", "--arch", args.arch, "--smoke",
        "--steps", str(args.steps), "--batch", "8", "--seq", "64",
        "--ckpt-dir", args.ckpt, "--ckpt-every", "10", "--log-every", "5",
    ]

    print("=== phase 1: train, then crash at step", args.steps // 2 + 1, "===")
    r = subprocess.run(base + ["--simulate-failure", str(args.steps // 2 + 1)], env=env)
    print("exit code:", r.returncode, "(simulated failure)")

    print("=== phase 2: restart --resume from the last checkpoint ===")
    r = subprocess.run(base + ["--resume"], env=env)
    assert r.returncode == 0
    print("=== done: training survived a mid-run failure ===")


if __name__ == "__main__":
    main()
