"""End-to-end driver for the paper's kind: SERVE repeated k-NN query batches.

30 ticks (as in the paper's evaluation) of 50K moving objects, one k-NN query
per object per tick, timeslice semantics, index reuse + drift-triggered
rebuild.  This is the deployable TickEngine service loop.

  PYTHONPATH=src python examples/moving_objects_service.py [--objects N] [--ticks T]
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent.parent / "src"))

import numpy as np

from repro.core import EngineConfig, TickEngine, available_backends
from repro.data import make_workload


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--objects", type=int, default=50_000)
    ap.add_argument("--ticks", type=int, default=30)
    ap.add_argument("--k", type=int, default=32)
    ap.add_argument("--distribution", default="gaussian",
                    choices=["uniform", "gaussian", "network"])
    ap.add_argument("--backend", default="dense_topk",
                    choices=list(available_backends()),
                    help="SCAN-step selection backend (executor registry)")
    args = ap.parse_args()

    engine = TickEngine(EngineConfig(k=args.k, th_quad=384, l_max=8, window=256,
                                     chunk=8192, backend=args.backend))
    workload = make_workload(args.objects, args.distribution, seed=0)

    print(f"serving {args.objects} objects x {args.ticks} ticks "
          f"({args.distribution}, k={args.k}, backend={args.backend})")

    def on_tick(res):
        print(f"tick {res.tick:2d}: {res.wall_s * 1e3:7.1f} ms "
              f"({args.objects / res.wall_s / 1e3:6.1f}K q/s) "
              f"iters={res.iterations:3d} cand/q={res.candidates / args.objects:6.0f} "
              f"{'REBUILT' if res.rebuilt else ''}")

    results = engine.run(workload, ticks=args.ticks, query_rate=1.0, on_tick=on_tick)
    steady = [r.wall_s for r in results[1:]]
    print(f"\nsteady state: {np.median(steady) * 1e3:.1f} ms/tick = "
          f"{args.objects / np.median(steady):,.0f} queries/s on one CPU core")
    print("(the paper's GPU pipeline is the TPU dry-run target; CPU numbers "
          "exercise the identical program)")


if __name__ == "__main__":
    main()
