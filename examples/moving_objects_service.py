"""End-to-end driver for the paper's kind: SERVE repeated k-NN query batches.

30 ticks (as in the paper's evaluation) of 50K moving objects, one k-NN query
per object per tick, timeslice semantics, index reuse + drift-triggered
rebuild — through the **session API** (``repro.api``, DESIGN.md §11): a
``KnnSession`` built from a declarative ``ServiceSpec`` owns device-resident
object and query state; queries are registered ONCE and moved in place,
object motion streams in as delta scatters (``--ingest delta``) or full
snapshots (``--ingest snapshot``), and ``--overlap`` submits tick τ+1 while
τ's results are still in flight (the paper's CPU/GPU pipeline overlap).
Runs on any execution plan: ``single`` (one device), ``sharded`` (the 1-D
``("query",)`` mesh, DESIGN.md §10), ``object_sharded`` (the 1-D
``("object",)`` mesh: Morton-sliced objects, per-device quadtrees,
merge-reduced lists, DESIGN.md §12) or ``hybrid`` (the 2-D
``("query", "object")`` mesh; pick the factorization with ``--mesh QxO``).
``--partitioner cost_balanced`` swaps the equal-count shard splits for
skew-adaptive cost-balanced boundaries (count-pyramid seed + measured-work
EMA, DESIGN.md §13) — same bits, tighter straggler gap under skew.

``--collect stats`` swaps the per-tick ``(Q, k)`` host transfer for the
on-device ResultSink aggregates (k-th-distance drift, neighbour churn,
shard-hit histogram — DESIGN.md §14); ``--precision mixed`` runs the sweep
as a bf16 prune + exact fp32 refine with bitwise-identical results.

``--maintenance incremental`` turns on the delta index-maintenance path
(DESIGN.md §15): each tick's reindex splices only the moved rows into the
device-resident sorted order instead of re-sorting all N — pair it with
``--churn F`` to move only a random fraction ``F`` of the objects per tick
(the default 1.0 moves everything, where the churn budget correctly defers
to a full rebuild).

  PYTHONPATH=src python examples/moving_objects_service.py \
      [--objects N] [--ticks T] \
      [--plan single|sharded|object_sharded|hybrid] [--devices D] \
      [--mesh QxO] [--partitioner equal|cost_balanced] \
      [--ingest snapshot|delta] [--overlap] [--churn F] \
      [--maintenance rebuild|incremental] \
      [--precision fp32|mixed] [--merge dense_merge|fused_multi] \
      [--collect full|stats|none] [--tenants N]

``--tenants N`` (N > 1) serves the same workload through the multi-tenant
``repro.serve.KnnServer`` instead of a solo session: the query batch splits
round-robin across N tenants sharing ONE tick program, and each tick's
object delta arrives via the next tenant in turn (DESIGN.md §16).

``--devices D`` (CPU) forces D host devices via XLA_FLAGS *before* jax
initializes, so the mesh plans run on a real D-device mesh without
accelerators.
"""
import argparse
import os
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent.parent / "src"))


def _parse_args():
    ap = argparse.ArgumentParser()
    ap.add_argument("--objects", type=int, default=50_000)
    ap.add_argument("--ticks", type=int, default=30)
    ap.add_argument("--k", type=int, default=32)
    ap.add_argument("--distribution", default="gaussian",
                    choices=["uniform", "gaussian", "network", "zipf",
                             "hotspot_cluster"])
    ap.add_argument("--backend", default="dense_topk",
                    help="SCAN-step selection backend (validated eagerly by "
                         "ServiceSpec against the executor registry)")
    ap.add_argument("--plan", default="single",
                    choices=["single", "sharded", "object_sharded", "hybrid"],
                    help="execution plan (plan registry)")
    ap.add_argument("--devices", type=int, default=None,
                    help="devices on the plan's 1-D mesh; on CPU also forces "
                         "that many host devices (set before jax init)")
    ap.add_argument("--mesh", default=None, metavar="QxO",
                    help="hybrid mesh shape, e.g. 2x4 (query x object "
                         "devices); default: most balanced factorization")
    ap.add_argument("--partitioner", default="equal",
                    choices=["equal", "cost_balanced"],
                    help="work partitioner for the plan's split axes: equal "
                         "count, or skew-adaptive cost-balanced boundaries "
                         "(DESIGN.md §13)")
    ap.add_argument("--chunk", type=int, default=8192,
                    help="query chunk rows; batches pad to devices*chunk, so "
                         "use a small chunk for small smoke runs")
    ap.add_argument("--ingest", default="snapshot",
                    choices=["snapshot", "delta"],
                    help="object motion path: full-snapshot upload per tick, "
                         "or device-side delta scatter (update_objects)")
    ap.add_argument("--overlap", action="store_true",
                    help="submit tick t+1 while tick t's results are in "
                         "flight (double-buffer staging vs compute)")
    ap.add_argument("--maintenance", default="rebuild",
                    choices=["rebuild", "incremental"],
                    help="per-tick index refresh: full re-sort, or the "
                         "delta splice that pays for churn, not for N "
                         "(DESIGN.md §15; bitwise-identical results)")
    ap.add_argument("--churn", type=float, default=1.0, metavar="F",
                    help="fraction of objects that actually move per tick "
                         "(default 1.0 = all); with --ingest delta only the "
                         "churned rows cross the host, which is what lets "
                         "--maintenance incremental engage")
    ap.add_argument("--precision", default="fp32",
                    choices=["fp32", "mixed"],
                    help="sweep precision: fp32, or the bf16 prune + exact "
                         "fp32 refine pass (bitwise-identical results, "
                         "DESIGN.md §14)")
    ap.add_argument("--merge", default="dense_merge",
                    help="MERGE backend for the merge-axis plans "
                         "(object_sharded/hybrid); fused_multi collapses "
                         "the reduction into one multi-way kernel pass")
    ap.add_argument("--collect", default="full",
                    choices=["full", "stats", "none"],
                    help="result delivery: full (Q,k) lists, on-device "
                         "ResultSink aggregates only (stats), or nothing "
                         "(none) — DESIGN.md §14")
    ap.add_argument("--tenants", type=int, default=1,
                    help="serve N tenants through ONE shared KnnServer tick "
                         "program (repro.serve, DESIGN.md §16): the query "
                         "batch splits round-robin across tenants and each "
                         "tick's object delta is fed by the next tenant in "
                         "turn; 1 (default) = the solo KnnSession path")
    ap.add_argument("--invalidation", default="epoch",
                    choices=["epoch", "spatial"],
                    help="result-cache invalidation mode of the --tenants "
                         "server: epoch clears the store on every delta; "
                         "spatial evicts only entries whose k-th-distance "
                         "ball a moved row stabs (DESIGN.md §16)")
    return ap.parse_args()


def main():
    args = _parse_args()

    mesh_shape = args.devices
    if args.mesh:
        try:
            q, o = (int(x) for x in args.mesh.lower().split("x"))
        except ValueError:
            raise SystemExit(f"--mesh must look like 2x4, got {args.mesh!r}")
        mesh_shape = (q, o)
        if args.devices is None:
            args.devices = q * o

    # the device count must be pinned before the first jax import
    if args.devices and args.devices > 1:
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={args.devices}"
        ).strip()

    import jax
    import numpy as np

    from repro.api import KnnSession, ServiceSpec
    from repro.data import make_workload

    try:
        spec = ServiceSpec(k=args.k, th_quad=384, l_max=8,
                           window=min(256, args.chunk), chunk=args.chunk,
                           backend=args.backend, plan=args.plan,
                           mesh_shape=mesh_shape,
                           partitioner=args.partitioner,
                           maintenance=args.maintenance,
                           precision=args.precision, merge=args.merge,
                           collect=args.collect)
    except ValueError as e:  # eager validation lists the registries
        raise SystemExit(str(e))

    if args.tenants > 1:
        return _serve_tenants(args, spec)

    session = KnnSession(spec)
    workload = make_workload(args.objects, args.distribution, seed=0)
    all_ids = np.arange(args.objects, dtype=np.int32)

    print(f"serving {args.objects} objects x {args.ticks} ticks "
          f"({args.distribution}, k={args.k}, backend={args.backend}, "
          f"ingest={args.ingest}, overlap={args.overlap}, "
          f"maintenance={args.maintenance}, churn={args.churn:g}, "
          f"precision={args.precision}, collect={args.collect})")
    print(f"{session.plan.describe()}  (jax sees {jax.device_count()} "
          f"{jax.default_backend()} device(s))")

    def on_tick(res, tick_s):
        # under --overlap, res.wall_s spans submit..collection (one round
        # late); tick_s is the true per-round serve time measured here
        extra = f" compile={res.compile_s:.2f}s" if res.compile_s else ""
        if args.maintenance != "rebuild":
            extra += f" maint={res.maintenance}"
        if res.aggregates is not None:  # --collect stats: the sink's O(Q)
            a = res.aggregates
            extra += (f" drift={float(a.kth_drift_mean):.1f}"
                      f" churn={float(a.churn_mean):.3f}")
        print(f"tick {res.tick:2d}: {tick_s * 1e3:7.1f} ms "
              f"({args.objects / max(tick_s, 1e-9) / 1e3:6.1f}K q/s) "
              f"iters={res.iterations:3d} cand/q={res.candidates / args.objects:6.0f} "
              f"{'REBUILT' if res.rebuilt else ''}{extra}")

    # seed device-resident state once; thereafter only motion crosses the host
    session.ingest_objects(workload.positions())
    cur = np.asarray(workload.positions(), np.float32).copy()
    churn_rng = np.random.default_rng(1)
    qpos, qid = workload.query_batch(1.0)
    hq = session.register_queries(qpos, qid)

    results, rounds, pending = [], [], None
    last = time.perf_counter()

    def collect(handle):
        results.append(handle.result())
        nonlocal last
        now = time.perf_counter()
        rounds.append(now - last)
        last = now
        on_tick(results[-1], rounds[-1])

    for t in range(args.ticks):
        if t > 0:
            workload.advance()
            new = np.asarray(workload.positions(), np.float32)
            if args.churn < 1.0:
                # only a random F-fraction of the fleet actually moves —
                # the regime the incremental maintenance path is built for
                d = max(1, int(round(args.objects * args.churn)))
                ids = churn_rng.choice(args.objects, d,
                                       replace=False).astype(np.int32)
                cur[ids] = new[ids]
            else:
                ids, cur = all_ids, new.copy()
            if args.ingest == "delta":
                session.update_objects(ids, cur[ids])
            else:
                session.ingest_objects(cur)
            session.update_queries(hq, workload.query_batch(1.0)[0])
        handle = session.submit()
        if pending is not None:
            collect(pending)
        if args.overlap:
            pending = handle  # collect after the NEXT submit is staged
        else:
            collect(handle)
            pending = None
    if pending is not None:
        collect(pending)  # drain round: compute already overlapped earlier

    # exclude the compile round, and (when overlapped) the near-zero drain
    # round, from the steady-state figure
    steady = rounds[1:-1] if (args.overlap and len(rounds) > 2) else rounds[1:]
    print(f"\nsteady state: {np.median(steady) * 1e3:.1f} ms/tick = "
          f"{args.objects / np.median(steady):,.0f} queries/s "
          f"[{session.plan.describe()}]")
    print("(the paper's GPU pipeline is the TPU dry-run target; CPU numbers "
          "exercise the identical program)")


def _serve_tenants(args, spec):
    """The --tenants N path: one shared KnnServer tick for every tenant.

    The query batch splits round-robin across tenants (tenant *i* owns rows
    ``i::N``), every tenant observes the SAME moving-object world, and each
    tick's object delta is fed by the next tenant in round-robin turn — the
    serving-layer shape of DESIGN.md §16.  Per-tick hit rate shows how much
    device work the dedup + result cache saved (under the default epoch
    invalidation it is 0 while every tick moves objects — motion clears the
    store; --invalidation spatial keeps entries whose k-th ball no moved
    row stabbed, so localized --churn motion leaves hot entries serving).
    """
    import numpy as np

    from repro.data import make_workload
    from repro.serve import KnnServer

    server = KnnServer(spec, invalidation=args.invalidation)
    workload = make_workload(args.objects, args.distribution, seed=0)
    T = args.tenants

    print(f"serving {args.objects} objects x {args.ticks} ticks "
          f"across {T} tenants ({args.distribution}, k={args.k}, "
          f"ingest={args.ingest}, overlap={args.overlap}, "
          f"collect={args.collect})")

    server.ingest_objects(workload.positions())
    cur = np.asarray(workload.positions(), np.float32).copy()
    churn_rng = np.random.default_rng(1)
    qpos, qid = workload.query_batch(1.0)
    tenants, groups = [], []
    for i in range(T):
        t = server.admit(f"tenant-{i}")
        tenants.append(t)
        groups.append(t.register_queries(qpos[i::T], qid[i::T]))
    print(server.describe())

    rounds, pending = [], None
    last = time.perf_counter()

    def collect(st):
        res = st.result()
        nonlocal last
        now = time.perf_counter()
        rounds.append(now - last)
        last = now
        extra = f" compile={res.compile_s:.2f}s" if res.compile_s else ""
        print(f"tick {res.tick:2d}: {rounds[-1] * 1e3:7.1f} ms "
              f"rows={res.rows_total} computed={res.rows_computed} "
              f"hit={res.hit_rate:.2f} epoch={res.epoch}"
              f"{' REBUILT' if res.rebuilt else ''}{extra}")
        # each tenant's rows stay addressable (and bit-identical to a solo
        # session's — the §16 contract); touch one to keep the path honest
        server_rows = st.result_for(groups[res.tick % T])
        assert server_rows[0].shape[0] == groups[res.tick % T].count

    for t in range(args.ticks):
        if t > 0:
            workload.advance()
            new = np.asarray(workload.positions(), np.float32)
            if args.churn < 1.0:
                d = max(1, int(round(args.objects * args.churn)))
                ids = churn_rng.choice(args.objects, d,
                                       replace=False).astype(np.int32)
                cur[ids] = new[ids]
            else:
                ids, cur = np.arange(args.objects, dtype=np.int32), new.copy()
            if args.ingest == "delta":
                # round-robin: THIS tick's observations arrive via tenant t%T
                tenants[t % T].update_objects(ids, cur[ids])
            else:
                server.ingest_objects(cur)
            newq = workload.query_batch(1.0)[0]
            for i in range(T):
                tenants[i].update_queries(groups[i], newq[i::T])
        handle = server.submit()
        if pending is not None:
            collect(pending)
        if args.overlap:
            pending = handle
        else:
            collect(handle)
            pending = None
    if pending is not None:
        collect(pending)

    steady = rounds[1:-1] if (args.overlap and len(rounds) > 2) else rounds[1:]
    served = server.rows_served
    print(f"\nsteady state: {np.median(steady) * 1e3:.1f} ms/tick, "
          f"{T} tenants, {served} rows served, "
          f"{server.rows_computed} computed "
          f"(lifetime hit rate "
          f"{1 - server.rows_computed / max(served, 1):.2f}) "
          f"[{server.session.plan.describe()}]")


if __name__ == "__main__":
    main()
