"""End-to-end driver for the paper's kind: SERVE repeated k-NN query batches.

30 ticks (as in the paper's evaluation) of 50K moving objects, one k-NN query
per object per tick, timeslice semantics, index reuse + drift-triggered
rebuild.  This is the deployable TickEngine service loop, on either execution
plan: ``single`` (one device) or ``sharded`` (the 1-D ``("query",)`` mesh,
DESIGN.md §10).

  PYTHONPATH=src python examples/moving_objects_service.py \
      [--objects N] [--ticks T] [--plan single|sharded] [--devices D]

``--devices D`` (CPU) forces D host devices via XLA_FLAGS *before* jax
initializes, so the sharded plan runs on a real D-device mesh without
accelerators.
"""
import argparse
import os
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent.parent / "src"))


def _parse_args():
    ap = argparse.ArgumentParser()
    ap.add_argument("--objects", type=int, default=50_000)
    ap.add_argument("--ticks", type=int, default=30)
    ap.add_argument("--k", type=int, default=32)
    ap.add_argument("--distribution", default="gaussian",
                    choices=["uniform", "gaussian", "network"])
    ap.add_argument("--backend", default="dense_topk",
                    help="SCAN-step selection backend (executor registry)")
    ap.add_argument("--plan", default="single", choices=["single", "sharded"],
                    help="execution plan (plan registry)")
    ap.add_argument("--devices", type=int, default=None,
                    help="mesh size on the ('query',) axis; on CPU also "
                         "forces that many host devices (set before jax init)")
    return ap.parse_args()


def main():
    args = _parse_args()

    # the device count must be pinned before the first jax import
    if args.devices and args.devices > 1:
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={args.devices}"
        ).strip()

    import jax
    import numpy as np

    from repro.core import EngineConfig, TickEngine, available_backends
    from repro.data import make_workload

    if args.backend not in available_backends():
        raise SystemExit(f"--backend must be one of {available_backends()}")

    engine = TickEngine(EngineConfig(k=args.k, th_quad=384, l_max=8, window=256,
                                     chunk=8192, backend=args.backend,
                                     plan=args.plan, mesh_shape=args.devices))
    workload = make_workload(args.objects, args.distribution, seed=0)

    print(f"serving {args.objects} objects x {args.ticks} ticks "
          f"({args.distribution}, k={args.k}, backend={args.backend})")
    print(f"{engine.plan.describe()}  (jax sees {jax.device_count()} "
          f"{jax.default_backend()} device(s))")

    def on_tick(res):
        print(f"tick {res.tick:2d}: {res.wall_s * 1e3:7.1f} ms "
              f"({args.objects / res.wall_s / 1e3:6.1f}K q/s) "
              f"iters={res.iterations:3d} cand/q={res.candidates / args.objects:6.0f} "
              f"{'REBUILT' if res.rebuilt else ''}")

    results = engine.run(workload, ticks=args.ticks, query_rate=1.0, on_tick=on_tick)
    steady = [r.wall_s for r in results[1:]]
    print(f"\nsteady state: {np.median(steady) * 1e3:.1f} ms/tick = "
          f"{args.objects / np.median(steady):,.0f} queries/s "
          f"[{engine.plan.describe()}]")
    print("(the paper's GPU pipeline is the TPU dry-run target; CPU numbers "
          "exercise the identical program)")


if __name__ == "__main__":
    main()
