"""Batched LM serving example: prefill a prompt batch, decode with KV cache /
recurrent state — the serve-side counterpart of the dry-run decode cells.

  PYTHONPATH=src python examples/serve_lm.py [--arch zamba2_7b]
"""
import argparse
import sys
from pathlib import Path

ROOT = Path(__file__).parent.parent
sys.path.insert(0, str(ROOT / "src"))

from repro.launch import serve  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="zamba2_7b")
    ap.add_argument("--tokens", type=int, default=12)
    args = ap.parse_args()
    serve.main([
        "lm", "--arch", args.arch, "--smoke", "--batch", "4",
        "--prompt-len", "16", "--tokens", str(args.tokens),
    ])


if __name__ == "__main__":
    main()
