"""Quickstart: one batch of k-NN queries through the paper's pipeline,
then the same workload served statefully through the session API
(``repro.api`` — persistent queries, delta object updates; DESIGN.md §11).

  PYTHONPATH=src python examples/quickstart.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent.parent / "src"))

import jax.numpy as jnp
import numpy as np

from repro.api import KnnSession, ServiceSpec
from repro.core import build_index, knn_bruteforce, knn_query_batch


def main():
    rng = np.random.default_rng(0)
    n, k = 20_000, 8

    # moving-object positions at the end of a tick (synthetic, uniform)
    points = rng.uniform(0, 22_500, size=(n, 2)).astype(np.float32)

    # stage (i)+(ii): build the PR-quadtree index (Morton sort + count pyramid)
    index = build_index(jnp.asarray(points), jnp.zeros(2), 22_500.0,
                        l_max=8, th_quad=192)

    # stage (iii): every object queries its k nearest neighbours (excl. itself)
    qid = jnp.arange(n, dtype=jnp.int32)
    nn_idx, nn_dist, stats = knn_query_batch(index, jnp.asarray(points), qid, k=k)

    print(f"processed {n} queries in {int(stats.iterations)} masked iterations")
    print(f"scanned {float(stats.candidates):.0f} candidate slots "
          f"({float(stats.candidates) / n:.0f} per query vs {n} brute-force)")
    print("first query's neighbours:", np.asarray(nn_idx[0]))
    print("distances:", np.round(np.asarray(nn_dist[0]), 2))

    # verify against the brute-force oracle
    bi, bd = knn_bruteforce(jnp.asarray(points[:1000]), jnp.asarray(points[:256]),
                            qid[:256], k)
    np.testing.assert_allclose(
        np.asarray(knn_query_batch(
            build_index(jnp.asarray(points[:1000]), jnp.zeros(2), 22_500.0,
                        l_max=6, th_quad=32),
            jnp.asarray(points[:256]), qid[:256], k=k)[1]),
        np.asarray(bd), rtol=1e-5, atol=1e-3)
    print("matches brute force ✓")

    # ---- the serving view of the same problem: a session over ticks -------
    # queries persist across ticks; only object MOTION crosses the host.
    session = KnnSession(ServiceSpec(k=k, th_quad=192, l_max=7, window=128,
                                     chunk=2048, side=22_500.0))
    session.ingest_objects(points)                     # snapshot seed
    hq = session.register_queries(points[:512], np.arange(512, dtype=np.int32))
    r0 = session.submit().result()                     # tick 0 (compiles)
    moved = rng.choice(n, 1_000, replace=False).astype(np.int32)
    session.update_objects(moved, points[moved] + 25.0)  # delta scatter
    r1 = session.submit().result()                     # tick 1, steady state
    print(f"session: tick0 {r0.wall_s * 1e3:.1f} ms (compile "
          f"{r0.compile_s:.2f} s), tick1 {r1.wall_s * 1e3:.1f} ms for "
          f"{session.query_count} persistent queries "
          f"(registered via {hq})")


if __name__ == "__main__":
    main()
