import os
import sys

# tests run on the single real CPU device (the dry-run, and only the dry-run,
# forces 512 placeholder devices — keep that flag OUT of here)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
