import gc
import os
import sys

import pytest

# tests run on the single real CPU device (the dry-run, and only the dry-run,
# forces 512 placeholder devices — keep that flag OUT of here)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


@pytest.fixture(autouse=True, scope="module")
def _drop_jax_executable_caches():
    # Every cached jitted executable pins its captured constants as live
    # device buffers, each a separate anonymous mmap; across the full suite
    # the process accumulates tens of thousands of maps and crosses
    # vm.max_map_count (default 65530), at which point XLA's next compile
    # segfaults instead of raising.  Clearing between modules bounds the
    # accumulation to one module's worth — every module passes standalone,
    # so nothing else changes.
    yield
    import jax

    jax.clear_caches()
    gc.collect()
