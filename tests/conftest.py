import gc
import os
import sys

import pytest

# tests run on the single real CPU device (the dry-run, and only the dry-run,
# forces 512 placeholder devices — keep that flag OUT of here)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Every cached jitted executable pins its captured constants as live device
# buffers, each a separate anonymous mmap; across the full suite the process
# can accumulate tens of thousands of maps and cross vm.max_map_count
# (default 65530), at which point XLA's next compile segfaults instead of
# raising.  Dropping the executable caches between modules bounds the
# accumulation — but it also recompiles everything the next module shares,
# which is pure waste on machines nowhere near the limit.  So the drop is
# GATED on actual proximity to the limit (see _near_map_count_limit;
# DESIGN.md §16 documents the mechanism), overridable for debugging:
#
#   REPRO_JAX_CACHE_DROP=always  drop after every module (the old behavior)
#   REPRO_JAX_CACHE_DROP=never   never drop (reproduce the segfault)
#   REPRO_JAX_CACHE_DROP=auto    drop only when near the map-count limit
#                                (default)
_DROP_FRACTION = 0.5  # drop once the process holds > 50% of max_map_count


def _read_int(path):
    try:
        with open(path) as f:
            return int(f.read().strip())
    except (OSError, ValueError):
        return None


def _count_maps():
    try:
        with open("/proc/self/maps", "rb") as f:
            return sum(1 for _ in f)
    except OSError:
        return None


def _near_map_count_limit() -> bool:
    limit = _read_int("/proc/sys/vm/max_map_count")
    maps = _count_maps()
    if limit is None or maps is None:
        # no /proc (non-Linux): mmap exhaustion manifests differently and
        # the workaround has nothing to measure — keep the caches
        return False
    return maps > _DROP_FRACTION * limit


@pytest.fixture(autouse=True, scope="module")
def _drop_jax_executable_caches():
    yield
    mode = os.environ.get("REPRO_JAX_CACHE_DROP", "auto")
    if mode == "never":
        return
    if mode != "always" and not _near_map_count_limit():
        return
    import jax

    jax.clear_caches()
    gc.collect()
