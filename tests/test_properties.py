"""Property-based parity harness: the full backend × plan matrix is ONE answer.

The tentpole-guard of the object-sharded execution plans (DESIGN.md §12).
Selection is everywhere the canonical lexicographic ``(d2, id)`` order and
navigation keeps equal-distance blocks, so a query's k-NN list is a pure
function of the candidate *set* — which makes "bit-identical across every
SCAN backend, every ExecutionPlan and every object partition" a *property*
we can fuzz rather than a handful of pinned examples.  Strategies generate
object/query clouds with duplicates, coincident points, extreme Zipf skew
and ``n < k``; every drawn cloud is swept through the whole
backend × plan matrix and must produce the same bits as the ``single``
plan's ``dense_topk`` reference, which itself must match the kd-tree oracle
(distances exactly per rank; ids as sets strictly below the k-th distance,
where the oracle's own tie order is not canonical).

Since the Partitioner seam (DESIGN.md §13) the matrix has a third axis: the
mesh plans run under BOTH registered partitioners — ``equal`` (the static
equal-count splits) and ``cost_balanced`` (skew-adaptive boundaries from the
count-pyramid cost seed) — and must stay bit-identical either way: the
partitioner only moves chunk/slice boundaries, and results are a pure
function of the candidate set.  DESIGN.md §14 added a fourth axis, sweep
*precision*: ``mixed`` (bf16 widened-radius prefilter + exact fp32 refine)
must reproduce the fp32 bits across the entire matrix, fuzzed below.
DESIGN.md §15 added the *maintenance* axis (incremental == rebuild at every
tick) and §16 the *serving* axis: N tenants coalesced through one
``repro.serve.KnnServer`` — dedup, fair-share weighting and cache replay on
the path — must reproduce N solo sessions bitwise.

Runs on however many devices exist: the tier-1 job exercises the matrix on
1 device, the tier1-multidevice job on a forced 8-device grid where
``sharded``/``object_sharded`` lay real 8-way meshes and ``hybrid`` the 2x4
mesh.  Hypothesis draws through the deterministic fallback
(``repro.testing``) when the real wheel is absent, so failures reproduce.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:
    from repro.testing import given, settings, strategies as st

from repro.core import (
    KDTree,
    available_backends,
    build_index,
    knn_query_batch_chunked,
    object_shard_capacity,
)
from repro.data import make_workload
from repro.kernels import tree_merge_lists
from repro.launch.mesh import default_hybrid_shape

NDEV = jax.device_count()
SIDE = 22_500.0

# (plan, mesh_shape, partitioner): every registered plan across every visible
# device, the mesh plans under both registered partitioners
PLAN_GRID = (
    ("single", None, "equal"),
    ("sharded", NDEV, "equal"),
    ("sharded", NDEV, "cost_balanced"),
    ("object_sharded", NDEV, "equal"),
    ("object_sharded", NDEV, "cost_balanced"),
    ("hybrid", default_hybrid_shape(NDEV), "equal"),
    ("hybrid", default_hybrid_shape(NDEV), "cost_balanced"),
)


def _cloud(seed: int, n: int, family: int, dup_every: int, zipf_a: float):
    """One object cloud: 0=uniform, 1=gaussian hotspots, 2=Zipf-skewed
    clusters (the ``zipf`` generator preset — most mass in one tiny region:
    deep tree + long scan intervals + uneven shards); ``dup_every > 1``
    overlays exact coincident duplicates."""
    rng = np.random.default_rng(seed)
    if family == 0:
        pts = rng.uniform(0, SIDE, (n, 2))
    elif family == 1:
        c = rng.uniform(0, SIDE, (4, 2))
        pts = c[rng.integers(0, 4, n)] + rng.normal(0, SIDE * 0.01, (n, 2))
    else:
        pts = make_workload(
            n, "zipf", seed=seed, zipf_a=zipf_a, clusters=12,
            hotspot_sigma_frac=0.002, side=SIDE,
        ).positions()
    if dup_every > 1:
        base = pts[: max(1, n // dup_every)]
        pts = np.tile(base, (dup_every + 1, 1))[:n]
        pts = pts[rng.permutation(n)]
    return np.clip(pts, 0, SIDE).astype(np.float32)


def _queries(pts: np.ndarray, nq: int, seed: int):
    """Half coincident with objects (self-excluding qids), half external."""
    rng = np.random.default_rng(seed + 1)
    m = nq // 2
    own = rng.choice(pts.shape[0], size=m, replace=False)
    qpos = np.concatenate(
        [pts[own], rng.uniform(0, SIDE, (nq - m, 2)).astype(np.float32)]
    ).astype(np.float32)
    qid = np.concatenate(
        [own.astype(np.int32), np.full((nq - m,), -2, np.int32)]
    )
    return qpos, qid


def _check_oracle(pts, qpos, qid, ii, dd, k):
    """Reference vs the kd-tree: exact distances per rank, id sets off ties."""
    tree = KDTree(pts)
    ri, rd = tree.query_batch(qpos, k, qid=qid)
    np.testing.assert_allclose(dd, rd, rtol=1e-5, atol=1e-3)
    for r in range(len(qpos)):
        kth = rd[r, k - 1]
        want = set(ri[r][rd[r] < kth * (1 - 1e-6)]) - {-1}
        got = set(ii[r][dd[r] < kth * (1 - 1e-6)]) - {-1}
        assert want == got, (r, want, got)


def _object_axis(plan: str, mesh) -> int:
    """Object-mesh axis size of a PLAN_GRID cell (1 = no object sharding)."""
    if plan == "object_sharded":
        return int(mesh)
    if plan == "hybrid":
        return int(mesh[1])
    return 1


def _sweep(idx, qpos, qid, *, k, backend, plan, mesh, partitioner="equal",
           precision=None, merge=None):
    ii, dd, _ = knn_query_batch_chunked(
        idx, qpos, qid, k=k, window=16, chunk=16, backend=backend,
        precision=precision, plan=plan, num_devices=mesh,
        partitioner=partitioner, merge=merge,
    )
    return ii, dd


@settings(max_examples=6, deadline=None)
@given(
    st.integers(min_value=0, max_value=10_000),  # seed
    st.integers(min_value=0, max_value=2),       # family
    st.integers(min_value=1, max_value=6),       # dup_every
    st.floats(min_value=1.2, max_value=3.5),     # zipf_a
)
def test_full_matrix_bit_identical(seed, family, dup_every, zipf_a):
    """Every plan × partitioner == that backend's single-plan reference,
    bitwise, for every backend; backends cross-agree up to distance
    rounding; the dense reference matches the kd-tree oracle.

    Bit-identity is asserted *per backend across the whole plan ×
    partitioner grid* — the canonical-selection guarantee (DESIGN.md
    §12/§13): partitioners only move chunk/slice boundaries, and results
    are a pure function of the candidate set.  Across backends only the
    distance VALUES are compared (1-ulp tolerance): XLA fuses the f32
    ``dx*dx + dy*dy`` with FMA differently per backend's surrounding graph,
    so cross-backend bits differ in the last place on tied inputs while each
    backend is internally exact.  Shapes are held fixed (96 objects, 24
    queries) so the jit cache is hit across examples and the matrix stays
    cheap to fuzz.
    """
    pts = _cloud(seed, 96, family, dup_every, zipf_a)
    qpos, qid = _queries(pts, 24, seed)
    k = 6
    idx = build_index(jnp.asarray(pts), jnp.zeros(2), SIDE, l_max=5, th_quad=8)
    ref_i, ref_d = _sweep(idx, qpos, qid, k=k, backend="dense_topk",
                          plan="single", mesh=None)
    _check_oracle(pts, qpos, qid, ref_i, ref_d, k)
    for backend in available_backends():
        base_i, base_d = _sweep(idx, qpos, qid, k=k, backend=backend,
                                plan="single", mesh=None)
        # cross-backend: same candidates up to last-place distance rounding
        np.testing.assert_allclose(
            base_d, ref_d, rtol=1e-6, err_msg=f"dists {backend} vs dense")
        for plan, mesh, part in PLAN_GRID[1:]:
            ii, dd = _sweep(idx, qpos, qid, k=k, backend=backend,
                            plan=plan, mesh=mesh, partitioner=part)
            np.testing.assert_array_equal(
                ii, base_i, err_msg=f"ids {backend}/{plan}/{part}")
            np.testing.assert_array_equal(
                dd, base_d, err_msg=f"dists {backend}/{plan}/{part}")


@settings(max_examples=4, deadline=None)
@given(
    st.integers(min_value=0, max_value=10_000),  # seed
    st.integers(min_value=0, max_value=2),       # family
    st.integers(min_value=1, max_value=6),       # dup_every
    st.floats(min_value=1.2, max_value=3.5),     # zipf_a
)
def test_mixed_precision_bit_identical(seed, family, dup_every, zipf_a):
    """``precision="mixed"`` == ``fp32``, bitwise, for every backend across
    the whole plan × partitioner grid — including the fused-multi merge on
    the object-axis plans.

    The mixed sweep prepends a bf16 distance pass that prunes candidates
    outside a conservatively *widened* k-th-distance radius and re-ranks
    only the survivors in exact fp32 (DESIGN.md §14).  The widening bound
    (``MIXED_WIDEN`` > the accumulated bf16 relative error) guarantees no
    candidate at or inside the true k-th boundary is ever pruned, so the
    exact pass sees the same effective candidate set and the canonical
    ``(d2, id)`` selection produces the same bits — duplicates, Zipf skew
    and ``kth = inf`` under-full rows included.
    """
    pts = _cloud(seed, 96, family, dup_every, zipf_a)
    qpos, qid = _queries(pts, 24, seed)
    k = 6
    idx = build_index(jnp.asarray(pts), jnp.zeros(2), SIDE, l_max=5, th_quad=8)
    for backend in available_backends():
        base_i, base_d = _sweep(idx, qpos, qid, k=k, backend=backend,
                                plan="single", mesh=None)
        for plan, mesh, part in PLAN_GRID:
            merge = "fused_multi" if plan in ("object_sharded",
                                              "hybrid") else None
            ii, dd = _sweep(idx, qpos, qid, k=k, backend=backend,
                            plan=plan, mesh=mesh, partitioner=part,
                            precision="mixed", merge=merge)
            np.testing.assert_array_equal(
                ii, base_i, err_msg=f"ids mixed {backend}/{plan}/{part}")
            np.testing.assert_array_equal(
                dd, base_d, err_msg=f"dists mixed {backend}/{plan}/{part}")


@settings(max_examples=5, deadline=None)
@given(
    st.integers(min_value=0, max_value=10_000),
    st.integers(min_value=1, max_value=7),       # n < k = 8
    st.integers(min_value=1, max_value=3),       # dup_every
)
def test_fewer_objects_than_k_all_plans(seed, n, dup_every):
    """n < k: (-1, inf) padding rows must be identical across the plan grid,
    including object shards that hold ONLY sentinel padding rows."""
    rng = np.random.default_rng(seed)
    pts = rng.uniform(0, SIDE, (n, 2)).astype(np.float32)
    if dup_every > 1:
        pts = np.tile(pts, (1 + n // dup_every, 1))[:n]
    qid = np.arange(n, dtype=np.int32)
    k = 8
    idx = build_index(jnp.asarray(pts), jnp.zeros(2), SIDE, l_max=4, th_quad=4)
    ref = _sweep(idx, pts, qid, k=k, backend="dense_topk", plan="single",
                 mesh=None)
    # each query sees the other n-1 objects, then padding
    assert np.isinf(ref[1][:, n - 1:]).all()
    assert (ref[0][:, n - 1:] == -1).all()
    for plan, mesh, part in PLAN_GRID[1:]:
        ii, dd = _sweep(idx, pts, qid, k=k, backend="dense_topk", plan=plan,
                        mesh=mesh, partitioner=part)
        np.testing.assert_array_equal(ii, ref[0], err_msg=f"{plan}/{part}")
        np.testing.assert_array_equal(dd, ref[1], err_msg=f"{plan}/{part}")


@settings(max_examples=3, deadline=None)
@given(
    st.integers(min_value=0, max_value=10_000),  # seed
    st.integers(min_value=0, max_value=2),       # family
    st.integers(min_value=1, max_value=4),       # dup_every
    st.floats(min_value=1.2, max_value=3.5),     # zipf_a
)
def test_maintenance_axis_bit_identical(seed, family, dup_every, zipf_a):
    """maintenance="incremental" == "rebuild", bitwise, at EVERY tick across
    the plan × partitioner grid — the fifth harness axis (DESIGN.md §15).

    Two sessions consume one motion script in lockstep.  The script is built
    to hit the seam's interesting transitions: tick 0 serves straight off
    the fresh build (both sessions "skip"); small-delta ticks splice
    incrementally, with the moved rows TELEPORTED across the region so their
    Morton ranks — and under ``object_sharded``/``hybrid`` their owning
    shards — change (boundary-crossing migration rides the ordinary splice,
    no special casing); a clean tick exercises the dirty-flag skip; and one
    over-budget tick (60% of N > churn_budget=0.25) forces the mid-run
    deferred FULL refresh.  ``rebuild_factor`` is set high so the drift
    trigger never fires and the mode schedule is deterministic; drift ×
    maintenance interplay is pinned separately in tests/test_maintenance.py.

    Asserted bitwise per tick: the (Q, k) neighbour lists AND every index
    array — order (pos/ids/codes), intervals (starts), pyramid, z_map
    (leaf_level).  Shapes are held fixed so the jit cache is shared across
    examples and grid cells.
    """
    from repro.api import KnnSession, ServiceSpec

    n, nq, k = 128, 16, 4
    pts0 = _cloud(seed, n, family, dup_every, zipf_a)
    qpos, qid = _queries(pts0, nq, seed)
    rng = np.random.default_rng(seed + 2)
    # motion script: rows moved before each tick (None = clean tick)
    script = [None, 12, None, int(n * 0.6), 12]
    want_modes = ["skip", "incremental", "skip", "rebuild", "incremental"]
    for plan, mesh, part in PLAN_GRID:
        sessions = {}
        for maint in ("rebuild", "incremental"):
            spec = ServiceSpec(
                k=k, window=16, chunk=32, l_max=5, th_quad=8, side=SIDE,
                plan=plan, mesh_shape=mesh, partitioner=part,
                maintenance=maint, churn_budget=0.25, delta_pad=16,
                rebuild_factor=1e9,
            )
            s = KnnSession(spec)
            s.ingest_objects(pts0)
            s.register_queries(qpos, qid)
            sessions[maint] = s
        pts = pts0.copy()
        move_rng = np.random.default_rng(seed + 3)
        for t, mv in enumerate(script):
            if mv:
                ids = move_rng.choice(n, mv, replace=False)
                # teleport: uniform over the whole region ⇒ Morton ranks and
                # (for the object-axis plans) shard ownership change
                new = move_rng.uniform(0, SIDE, (mv, 2)).astype(np.float32)
                pts[ids] = new
                for s in sessions.values():
                    s.update_objects(ids, new)
            ra = sessions["rebuild"].submit().result()
            rb = sessions["incremental"].submit().result()
            if want_modes[t] == "incremental" and _object_axis(plan, mesh) > 1:
                # the PER-SHARD churn budget (DESIGN.md §15) may defer an
                # in-global-budget tick when the drawn movers concentrate in
                # one object shard — a legitimate policy outcome, and the
                # bits below must agree either way
                assert rb.maintenance in ("incremental", "rebuild"), \
                    (plan, part, t)
            else:
                assert rb.maintenance == want_modes[t], (plan, part, t)
            tag = f"{plan}/{part}/tick{t}"
            np.testing.assert_array_equal(ra.nn_idx, rb.nn_idx, err_msg=tag)
            np.testing.assert_array_equal(ra.nn_dist, rb.nn_dist, err_msg=tag)
            ia = sessions["rebuild"].index
            ib = sessions["incremental"].index
            for f in ("pos", "ids", "codes", "starts", "pyramid",
                      "leaf_level"):
                np.testing.assert_array_equal(
                    np.asarray(getattr(ia, f)), np.asarray(getattr(ib, f)),
                    err_msg=f"{tag}/{f}",
                )


def test_mover_crosses_moving_cost_balanced_boundary():
    """A mover crosses a cost_balanced object-shard boundary on the SAME
    tick the boundary moves — and the incremental splice still reproduces
    the rebuild bits.

    The adversarial alignment for per-shard maintenance: the mover's old
    rank is owned by the source shard *under last tick's boundaries* (which
    is where the per-shard churn budget charges it), its new rank lands in a
    different shard, and the tick's refresh moves the boundary itself.
    Object boundaries are count-balanced rank intervals by design
    (``core.plan._object_row_costs``: uniform row costs — the boundary RANK
    values are a static function of (n, R), asserted here to really come
    from the cost seed, not the capacity rule: n = 125 is indivisible by R),
    so what moves each tick is the partition those ranks induce over the
    re-spliced Morton order: the boundary OBJECT — the row a shard's
    interval starts at — changes while the mover crosses it, which is
    exactly the coordinate shift the per-shard splice has to survive.

    The mover set is built per shard at exactly ``floor(0.25 × owned)``
    rows, so the tick stays on the incremental path by construction
    (strict-``>`` deferral rule), and every mover teleports into one tight
    far-corner hotspot so ranks shift across every shard.  On one device
    the case still runs (and pins bit-identity); the crossing/boundary
    assertions need R > 1.
    """
    from repro.api import KnnSession, ServiceSpec

    n, nq, k = 125, 16, 4
    rng = np.random.default_rng(71)
    pts0 = rng.uniform(0, SIDE, (n, 2)).astype(np.float32)
    qpos, qid = _queries(pts0, nq, 71)
    sessions = {}
    for maint in ("rebuild", "incremental"):
        spec = ServiceSpec(
            k=k, window=16, chunk=32, l_max=5, th_quad=8, side=SIDE,
            plan="object_sharded", mesh_shape=NDEV,
            partitioner="cost_balanced", maintenance=maint,
            churn_budget=0.25, delta_pad=16, rebuild_factor=1e9,
        )
        s = KnnSession(spec)
        s.ingest_objects(pts0)
        s.register_queries(qpos, qid)
        sessions[maint] = s
    a, b = sessions["rebuild"], sessions["incremental"]

    def lockstep(tag):
        ra, rb = a.submit().result(), b.submit().result()
        np.testing.assert_array_equal(ra.nn_idx, rb.nn_idx, err_msg=tag)
        np.testing.assert_array_equal(ra.nn_dist, rb.nn_dist, err_msg=tag)
        for f in ("pos", "ids", "codes", "starts", "pyramid", "leaf_level"):
            np.testing.assert_array_equal(
                np.asarray(getattr(a.index, f)),
                np.asarray(getattr(b.index, f)), err_msg=f"{tag}/{f}",
            )
        return ra, rb

    lockstep("tick0")
    by_rank0 = np.asarray(b.index.ids).copy()
    mover = int(by_rank0[0])  # lowest Morton rank
    if NDEV > 1:
        bounds0 = np.asarray(b._obj_bounds).copy()
        src_shard = int(b.object_shards([mover])[0])
        # the boundaries really are the cost seed's, not the capacity rule's
        from repro.core.balance import equal_boundaries

        assert not np.array_equal(
            bounds0, np.asarray(equal_boundaries(n, NDEV))
        ), "cost_balanced bounds degenerate to the capacity rule"
    else:
        bounds0 = np.array([0, n])
    # per source shard, exactly floor(0.25 * owned) movers from its lowest
    # ranks — in budget by construction; the rank-0 mover rides in shard 0's
    # quota (uniform cloud: every shard owns >= 4 rows)
    picks = []
    for r in range(len(bounds0) - 1):
        lo, hi = int(bounds0[r]), int(bounds0[r + 1])
        picks.extend(range(lo, lo + (hi - lo) // 4))
    ids = by_rank0[np.asarray(picks, np.int64)]
    assert mover in ids
    # one tight hotspot at the far (max-Morton) corner: every shard's ranks
    # shift, so the object each boundary starts at moves this tick
    hot = np.array([SIDE * 0.993, SIDE * 0.987], np.float32)
    new = (hot + rng.normal(0, SIDE * 1e-4, (len(ids), 2))).astype(np.float32)
    for s in sessions.values():
        s.update_objects(ids, new)
    _, rb1 = lockstep("tick1-crossing")
    assert rb1.maintenance == "incremental"
    if NDEV > 1:
        assert int(b.object_shards([mover])[0]) != src_shard, \
            "mover did not cross a shard boundary"
        # the boundary moved: the source shard's successor boundary starts
        # at a different object than it did last tick
        by_rank1 = np.asarray(b.index.ids)
        cut = int(bounds0[src_shard + 1])
        assert by_rank1[cut] != by_rank0[cut], "boundary object did not move"
    # settle: a clean tick replays the same bits off the spliced order
    _, rb2 = lockstep("tick2-clean")
    assert rb2.maintenance == "skip"


@settings(max_examples=3, deadline=None)
@given(
    st.integers(min_value=0, max_value=10_000),  # seed
    st.integers(min_value=0, max_value=2),       # family
    st.integers(min_value=1, max_value=6),       # dup_every
    st.floats(min_value=1.2, max_value=3.5),     # zipf_a
)
def test_server_axis_bit_identical(seed, family, dup_every, zipf_a):
    """An N-tenant KnnServer == N solo KnnSessions, bitwise, at EVERY tick
    across the plan × partitioner grid — the sixth harness axis
    (DESIGN.md §16).

    Three tenants share one server: their query groups overlap on an exact
    shared prefix (bit-duplicate rows exercise intra-tick dedup; the
    object clouds carry coincident duplicates and Zipf skew from the same
    strategies as the rest of the harness).  The motion script hits the
    serving layer's interesting transitions: tick 0 computes fresh and
    populates the cache; tick 1 has NO motion, so the whole tick must
    replay from the cache (asserted: zero computed rows); tick 2's delta —
    fed through ONE tenant's ingest into the shared world — invalidates
    (everything under ``invalidation="epoch"``; exactly the stabbed balls
    under ``"spatial"``, where surviving entries keep serving).  Both
    invalidation modes run the same script, and each tenant's rows are
    compared bitwise against a solo session replaying the same world
    script, for every grid cell — so under churn every cache-surviving
    entry is pinned bitwise equal to a cold recomputation.  Shapes are
    held fixed so the jit cache is shared across examples and cells.
    """
    from repro.api import KnnSession, ServiceSpec
    from repro.serve import KnnServer

    n, rows, k = 128, 8, 4
    pts = _cloud(seed, n, family, dup_every, zipf_a)
    rng = np.random.default_rng(seed + 5)
    shared, _ = _queries(pts, rows // 2, seed)  # exact-duplicate prefix
    tq = []
    for g in range(3):
        own = rng.uniform(0, SIDE, (rows - shared.shape[0], 2)).astype(
            np.float32)
        qid = np.full((rows,), -2, np.int32)
        qid[-1] = g
        tq.append((np.concatenate([shared, own]), qid))
    ids = rng.choice(n, 16, replace=False).astype(np.int32)
    new = rng.uniform(0, SIDE, (16, 2)).astype(np.float32)
    for plan, mesh, part in PLAN_GRID:
        spec = ServiceSpec(k=k, window=16, chunk=32, l_max=5, th_quad=8,
                           side=SIDE, plan=plan, mesh_shape=mesh,
                           partitioner=part)
        got = {}
        for invalidation in ("epoch", "spatial"):
            srv = KnnServer(spec, invalidation=invalidation)
            srv.ingest_objects(pts)
            tenants = [srv.admit(f"t{g}") for g in range(3)]
            handles = [t.register_queries(*tq[g])
                       for g, t in enumerate(tenants)]
            ticks = []
            for t in range(3):
                if t == 2:
                    tenants[1].update_objects(ids, new)
                st = srv.submit()
                res = st.result()
                if t == 1:  # unchanged world: full cache replay
                    assert res.rows_computed == 0, (
                        plan, part, invalidation, res)
                ticks.append([st.result_for(h) for h in handles])
            got[invalidation] = ticks
        for g, (qpos, qid) in enumerate(tq):
            sess = KnnSession(spec)
            sess.ingest_objects(pts)
            sess.register_queries(qpos, qid)
            want = [sess.submit().result()]
            sess.update_objects(ids, new)
            want.append(sess.submit().result())
            for inval, ticks in got.items():
                for srv_t, solo_t in ((0, 0), (1, 0), (2, 1)):
                    tag = f"{plan}/{part}/{inval}/t{g}/tick{srv_t}"
                    np.testing.assert_array_equal(
                        ticks[srv_t][g][0], want[solo_t].nn_idx, err_msg=tag)
                    np.testing.assert_array_equal(
                        ticks[srv_t][g][1], want[solo_t].nn_dist, err_msg=tag)


@pytest.mark.parametrize("r", [2, 3, 8])
def test_pipeline_r_way_partition_composes(r):
    """The plan-level composition law WITHOUT a mesh: R independent local
    quadtrees over Morton-contiguous slices, swept with the full pipeline,
    tree-merge-reduced to the single-plan bits — including the uneven final
    shard (89 objects: R=8 pads the tail slice with sentinels) and distance
    ties (duplicated positions).

    This is the object_sharded dataflow run shard-by-shard on one device
    (the same ``_pad_object_slices`` / ``_local_index`` / ``_chunked_sweep``
    helpers the plan wires into shard_map), so it pins the decomposition
    itself separately from mesh machinery — which tests/test_plan.py pins on
    forced 8-device grids.
    """
    from repro.core import plan as plan_mod
    from repro.core.executor import resolve_executor
    from repro.core.pipeline import _resolve_max_nav

    rng = np.random.default_rng(40 + r)
    base = rng.uniform(0, SIDE, (45, 2)).astype(np.float32)
    pts = np.tile(base, (2, 1))[:89]  # 89: uneven final slice for r=2,3,8
    pts = pts[rng.permutation(len(pts))]
    qpos, qid = _queries(pts, 24, seed=7)
    k, window, chunk = 6, 16, 16
    idx = build_index(jnp.asarray(pts), jnp.zeros(2), SIDE, l_max=5, th_quad=8)
    want_i, want_d, _ = knn_query_batch_chunked(
        idx, qpos, qid, k=k, window=window, chunk=chunk, plan="single")

    nq = qpos.shape[0]
    qpos_p, qid_p = plan_mod.pad_queries(qpos, qid, chunk)
    order, inv = plan_mod._sort_unsort(idx, jnp.asarray(qpos_p))
    qs = jnp.asarray(qpos_p, jnp.float32)[order]
    qi = jnp.asarray(qid_p, jnp.int32)[order]
    opos, oids = plan_mod._pad_object_slices(idx, r)
    cap = opos.shape[0] // r
    assert cap == object_shard_capacity(len(pts), r)
    parts_d, parts_i = [], []
    for s in range(r):
        local = plan_mod._local_index(
            opos[s * cap:(s + 1) * cap], oids[s * cap:(s + 1) * cap],
            idx.origin, idx.side, l_max=idx.l_max, th_quad=idx.th_quad)
        ii, d2, _, _ = plan_mod._chunked_sweep(
            local, qs, qi, k=k, window=window, chunk=chunk,
            max_nav=_resolve_max_nav(idx, None), max_iters=100_000,
            executor=resolve_executor(None))
        parts_d.append(d2)
        parts_i.append(ii)
    got_d2, got_i = tree_merge_lists(
        jnp.stack(parts_d), jnp.stack(parts_i), k=k)
    got_i = np.asarray(got_i[inv])[:nq]
    got_d = np.asarray(jnp.sqrt(got_d2)[inv])[:nq]
    np.testing.assert_array_equal(got_i, want_i)
    np.testing.assert_array_equal(got_d, want_d)
