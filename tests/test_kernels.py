"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret=True)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import (
    bucket_kselect_op,
    bucket_kselect_ref,
    pairwise_dist_op,
    pairwise_dist_ref,
    topk_select_op,
    topk_select_ref,
)


def _data(q, c, seed=0, dtype=np.float32):
    rng = np.random.default_rng(seed)
    qpos = jnp.asarray(rng.uniform(0, 1000, (q, 2)).astype(dtype))
    ppos = jnp.asarray(rng.uniform(0, 1000, (c, 2)).astype(dtype))
    valid = jnp.asarray(rng.random(c) < 0.9)
    return qpos, ppos, valid


@pytest.mark.parametrize("q,c", [(1, 1), (8, 128), (20, 300), (64, 1024), (7, 130)])
def test_pairwise_dist_shapes(q, c):
    qpos, ppos, valid = _data(q, c, seed=q * 1000 + c)
    got = pairwise_dist_op(qpos, ppos, valid)
    want = pairwise_dist_ref(qpos[:, 0], qpos[:, 1], ppos[:, 0], ppos[:, 1], valid)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


@pytest.mark.parametrize("k", [1, 4, 16, 64])
@pytest.mark.parametrize("q,c", [(8, 128), (17, 333)])
def test_bucket_kselect_guarantee(q, c, k):
    qpos, ppos, valid = _data(q, c, seed=k)
    r = np.asarray(bucket_kselect_op(qpos, ppos, valid, k=k))
    ref = np.asarray(
        bucket_kselect_ref(qpos[:, 0], qpos[:, 1], ppos[:, 0], ppos[:, 1], valid,
                           k=k, num_bins=32, iters=4)
    )
    np.testing.assert_allclose(r, ref, rtol=1e-5)
    d2 = np.asarray(pairwise_dist_ref(qpos[:, 0], qpos[:, 1], ppos[:, 0], ppos[:, 1], valid))
    nv = int(np.asarray(valid).sum())
    cnt = (d2 < r[:, None]).sum(1)
    assert (cnt >= min(k, nv)).all()
    if nv >= k:
        # selection is tight: at most a thin shell above k after 4 refinements
        assert cnt.mean() <= k * 1.5 + 2


@pytest.mark.parametrize("k", [1, 8, 32])
@pytest.mark.parametrize("q,c", [(8, 64), (30, 257)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_topk_select_sweep(q, c, k, dtype):
    rng = np.random.default_rng(q + c + k)
    d2 = jnp.asarray(rng.uniform(0, 100, (q, c))).astype(dtype).astype(jnp.float32)
    ids = jnp.tile(jnp.arange(c, dtype=jnp.int32)[None], (q, 1))
    got_d, got_i = topk_select_op(d2, ids, k=min(k, c))
    want_d, want_i = topk_select_ref(d2, ids, k=min(k, c))
    np.testing.assert_allclose(np.asarray(got_d), np.asarray(want_d), rtol=1e-6)
    # ids may differ on exact ties; distances must match exactly per rank
    got_vals = np.take_along_axis(np.asarray(d2), np.asarray(got_i), 1)
    want_vals = np.take_along_axis(np.asarray(d2), np.asarray(want_i), 1)
    np.testing.assert_allclose(got_vals, want_vals, rtol=1e-6)


def test_topk_select_with_infs():
    d2 = jnp.asarray([[1.0, jnp.inf, 0.5, jnp.inf]])
    ids = jnp.asarray([[10, 11, 12, 13]], jnp.int32)
    out_d, out_i = topk_select_op(d2, ids, k=3)
    assert list(np.asarray(out_i)[0][:2]) == [12, 10]
    assert int(np.asarray(out_i)[0][2]) == -1  # inf slot -> padded id
