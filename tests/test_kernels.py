"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret=True)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import (
    MIXED_WIDEN,
    bucket_kselect_op,
    bucket_kselect_ref,
    merge_backend_names,
    get_merge_backend,
    merge_topk_lists_ref,
    mixed_prune_keep,
    pairwise_dist_op,
    pairwise_dist_ref,
    topk_select_op,
    topk_select_ref,
    tree_merge_lists,
)


def _data(q, c, seed=0, dtype=np.float32):
    rng = np.random.default_rng(seed)
    qpos = jnp.asarray(rng.uniform(0, 1000, (q, 2)).astype(dtype))
    ppos = jnp.asarray(rng.uniform(0, 1000, (c, 2)).astype(dtype))
    valid = jnp.asarray(rng.random(c) < 0.9)
    return qpos, ppos, valid


@pytest.mark.parametrize("q,c", [(1, 1), (8, 128), (20, 300), (64, 1024), (7, 130)])
def test_pairwise_dist_shapes(q, c):
    qpos, ppos, valid = _data(q, c, seed=q * 1000 + c)
    got = pairwise_dist_op(qpos, ppos, valid)
    want = pairwise_dist_ref(qpos[:, 0], qpos[:, 1], ppos[:, 0], ppos[:, 1], valid)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


@pytest.mark.parametrize("k", [1, 4, 16, 64])
@pytest.mark.parametrize("q,c", [(8, 128), (17, 333)])
def test_bucket_kselect_guarantee(q, c, k):
    qpos, ppos, valid = _data(q, c, seed=k)
    r = np.asarray(bucket_kselect_op(qpos, ppos, valid, k=k))
    ref = np.asarray(
        bucket_kselect_ref(qpos[:, 0], qpos[:, 1], ppos[:, 0], ppos[:, 1], valid,
                           k=k, num_bins=32, iters=4)
    )
    np.testing.assert_allclose(r, ref, rtol=1e-5)
    d2 = np.asarray(pairwise_dist_ref(qpos[:, 0], qpos[:, 1], ppos[:, 0], ppos[:, 1], valid))
    nv = int(np.asarray(valid).sum())
    cnt = (d2 < r[:, None]).sum(1)
    assert (cnt >= min(k, nv)).all()
    if nv >= k:
        # selection is tight: at most a thin shell above k after 4 refinements
        assert cnt.mean() <= k * 1.5 + 2


@pytest.mark.parametrize("k", [1, 8, 32])
@pytest.mark.parametrize("q,c", [(8, 64), (30, 257)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_topk_select_sweep(q, c, k, dtype):
    rng = np.random.default_rng(q + c + k)
    d2 = jnp.asarray(rng.uniform(0, 100, (q, c))).astype(dtype).astype(jnp.float32)
    ids = jnp.tile(jnp.arange(c, dtype=jnp.int32)[None], (q, 1))
    got_d, got_i = topk_select_op(d2, ids, k=min(k, c))
    want_d, want_i = topk_select_ref(d2, ids, k=min(k, c))
    np.testing.assert_allclose(np.asarray(got_d), np.asarray(want_d), rtol=1e-6)
    # ids may differ on exact ties; distances must match exactly per rank
    got_vals = np.take_along_axis(np.asarray(d2), np.asarray(got_i), 1)
    want_vals = np.take_along_axis(np.asarray(d2), np.asarray(want_i), 1)
    np.testing.assert_allclose(got_vals, want_vals, rtol=1e-6)


def test_topk_select_with_infs():
    d2 = jnp.asarray([[1.0, jnp.inf, 0.5, jnp.inf]])
    ids = jnp.asarray([[10, 11, 12, 13]], jnp.int32)
    out_d, out_i = topk_select_op(d2, ids, k=3)
    assert list(np.asarray(out_i)[0][:2]) == [12, 10]
    assert int(np.asarray(out_i)[0][2]) == -1  # inf slot -> padded id


@pytest.mark.parametrize("scale", [1.0, 1e3, 22_500.0])
@pytest.mark.parametrize("seed", [0, 7, 91])
def test_mixed_prune_keep_is_conservative(seed, scale):
    """The bf16 widened-radius prefilter NEVER drops a candidate at or
    inside the exact k-th boundary (the bitwise-identity precondition of
    the mixed sweep, DESIGN.md §14) — coincident points, near-boundary
    candidates and kth = inf under-full rows included; and the widening
    really is wider than the accumulated bf16 relative error."""
    assert MIXED_WIDEN > (1 + 2.0 ** -8) ** 5  # margin over 5 roundings
    rng = np.random.default_rng(seed)
    t, w = 16, 256
    qpos = rng.uniform(0, scale, (t, 2)).astype(np.float32)
    cpos = rng.uniform(0, scale, (t, w, 2)).astype(np.float32)
    cpos[:, :7] = qpos[:, None, :]  # coincident candidates (d2 = 0)
    dx = jnp.asarray(cpos[:, :, 0] - qpos[:, None, 0])
    dy = jnp.asarray(cpos[:, :, 1] - qpos[:, None, 1])
    d2 = np.asarray(dx * dx + dy * dy)
    k = 8
    kth = np.sort(d2, axis=1)[:, k - 1].astype(np.float32)
    kth[0] = np.inf  # under-full row: everything must be kept
    keep = np.asarray(mixed_prune_keep(dx, dy, jnp.asarray(kth)))
    inside = d2 <= kth[:, None]
    assert (keep | ~inside).all(), "prefilter dropped an in-boundary candidate"
    assert keep[0].all()  # kth = inf keeps the whole window
    # and it really prunes: far-away candidates don't survive
    assert (~keep[1:] & (d2[1:] > 2.0 * kth[1:, None])).sum() > 0 or (
        np.isinf(kth[1:]).all()
    )


def _ascending_lists(q, width, k, seed, lo=0.0, hi=100.0, id_base=0):
    """Random ascending +inf/-1-padded (dist, id) lists, ragged fill per row."""
    rng = np.random.default_rng(seed)
    n_real = rng.integers(0, width + 1, size=q)
    d = np.full((q, width), np.inf, np.float32)
    i = np.full((q, width), -1, np.int32)
    for r in range(q):
        vals = np.sort(rng.uniform(lo, hi, n_real[r])).astype(np.float32)
        d[r, : n_real[r]] = vals
        i[r, : n_real[r]] = id_base + rng.choice(10_000, n_real[r], replace=False)
    return jnp.asarray(d), jnp.asarray(i)


@pytest.mark.parametrize("backend", merge_backend_names())
@pytest.mark.parametrize("q,ka,kb,k", [(1, 4, 4, 4), (9, 8, 3, 8), (32, 16, 16, 8)])
def test_merge_topk_lists_backends(backend, q, ka, kb, k):
    """Every merge backend == the jnp oracle: distances exact per rank, ids
    equal off ties, +inf rows padded with -1 (DESIGN.md §10 contract)."""
    d_a, i_a = _ascending_lists(q, ka, k, seed=q + ka)
    d_b, i_b = _ascending_lists(q, kb, k, seed=q + kb + 1, id_base=20_000)
    got_d, got_i = get_merge_backend(backend)(d_a, i_a, d_b, i_b, k)
    want_d, want_i = merge_topk_lists_ref(d_a, i_a, d_b, i_b, k=k)
    np.testing.assert_allclose(np.asarray(got_d), np.asarray(want_d), rtol=1e-6)
    got_i, want_i = np.asarray(got_i), np.asarray(want_i)
    ties = np.asarray(want_d)[:, :, None] == np.asarray(want_d)[:, None, :]
    unique = ties.sum(axis=2)[np.isfinite(np.asarray(want_d))] == 1
    np.testing.assert_array_equal(
        got_i[np.isfinite(np.asarray(got_d))][unique],
        want_i[np.isfinite(np.asarray(want_d))][unique],
    )
    assert (got_i[np.isinf(np.asarray(got_d))] == -1).all()


@pytest.mark.parametrize("backend", merge_backend_names())
def test_merge_composes_partitioned_knn(backend):
    """The object-sharding composition law the primitive exists for:
    ``knn(P_a ∪ P_b) = merge(knn(P_a), knn(P_b))`` per query row."""
    rng = np.random.default_rng(23)
    pts = rng.uniform(0, 1000, (200, 2)).astype(np.float32)
    qpos = rng.uniform(0, 1000, (24, 2)).astype(np.float32)
    k = 6
    d2 = np.square(qpos[:, None, :] - pts[None, :, :]).sum(-1)
    ids = np.tile(np.arange(200, dtype=np.int32), (24, 1))
    half = 100
    da, ia = topk_select_ref(jnp.asarray(d2[:, :half]), jnp.asarray(ids[:, :half]), k=k)
    db, ib = topk_select_ref(jnp.asarray(d2[:, half:]), jnp.asarray(ids[:, half:]), k=k)
    full_d, full_i = topk_select_ref(jnp.asarray(d2), jnp.asarray(ids), k=k)
    got_d, got_i = get_merge_backend(backend)(da, ia, db, ib, k)
    np.testing.assert_allclose(np.asarray(got_d), np.asarray(full_d), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(got_i), np.asarray(full_i))


@pytest.mark.parametrize("backend", merge_backend_names())
@pytest.mark.parametrize("r", [2, 3, 8])
def test_tree_merge_composes_r_way_partition(backend, r):
    """The sharded generalization: knn over an R-way object partition equals
    an R-way ``tree_merge_lists`` reduction of the per-partition lists —
    including the uneven final shard (its list padded with (inf, -1) rows
    when the slice holds fewer than k candidates) and massed distance ties
    (duplicated columns), bit-for-bit under the canonical lexicographic
    ``(d2, id)`` contract of DESIGN.md §12."""
    rng = np.random.default_rng(100 + r)
    n, q, k = 89, 24, 6  # 89: uneven tail slice for every r; tail < cap
    qpos = rng.uniform(0, 1000, (q, 2)).astype(np.float32)
    pts = rng.uniform(0, 1000, (45, 2)).astype(np.float32)
    pts = np.tile(pts, (2, 1))[:n]  # duplicated positions -> distance ties
    d2 = np.square(qpos[:, None, :] - pts[None, :, :]).sum(-1).astype(np.float32)
    ids = np.tile(rng.permutation(n).astype(np.int32), (q, 1))
    full_d, full_i = topk_select_ref(jnp.asarray(d2), jnp.asarray(ids), k=k)
    cap = -(-n // r)
    parts_d, parts_i = [], []
    for s in range(r):
        sl = slice(s * cap, min((s + 1) * cap, n))
        pd, pi = topk_select_ref(
            jnp.asarray(d2[:, sl]), jnp.asarray(ids[:, sl]), k=k)
        pad = k - pd.shape[1]
        if pad > 0:  # final shard narrower than k: inf/-1 padded list
            pd = jnp.concatenate(
                [pd, jnp.full((q, pad), jnp.inf, jnp.float32)], axis=1)
            pi = jnp.concatenate(
                [pi, jnp.full((q, pad), -1, jnp.int32)], axis=1)
        parts_d.append(pd)
        parts_i.append(pi)
    got_d, got_i = tree_merge_lists(
        jnp.stack(parts_d), jnp.stack(parts_i), k=k, merge=backend)
    np.testing.assert_array_equal(np.asarray(got_d), np.asarray(full_d))
    np.testing.assert_array_equal(np.asarray(got_i), np.asarray(full_i))
