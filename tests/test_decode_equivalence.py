"""Train-mode (full-sequence, chunked) vs decode-mode (stepwise) equivalence.

The strongest correctness checks in the model stack: the chunked SSD / RWKV6 /
attention-with-cache decode paths must reproduce the full-sequence forward
token by token.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.models import attention as attn_mod
from repro.models import decode_step, forward, init_decode_state, init_params
from repro.models import ssm as ssm_mod

TOL = dict(rtol=2e-3, atol=2e-3)


def _rollout(cfg, params, tokens, extra=None, max_len=None):
    """Teacher-forced decode over `tokens`, returning stacked logits."""
    b, s = tokens.shape
    st = init_decode_state(cfg, b, max_len or s, mem_len=s)
    if extra:
        st.update(extra)
    outs = []
    for t in range(s):
        logits, st = decode_step(params, cfg, st, tokens[:, t : t + 1], jnp.int32(t))
        outs.append(logits[:, 0])
    return jnp.stack(outs, axis=1)


def _compare(arch, seq=16, extra_fn=None):
    cfg = get_smoke_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (2, seq)), jnp.int32)
    batch = {"tokens": tokens}
    extra = None
    if extra_fn:
        batch_extra, extra = extra_fn(cfg)
        batch.update(batch_extra)
    full, _ = forward(params, cfg, batch)
    step = _rollout(cfg, params, tokens, extra=extra)
    np.testing.assert_allclose(np.asarray(step), np.asarray(full), **TOL)


def test_dense_gqa_decode_matches_forward():
    _compare("yi_34b")


def test_swa_decode_matches_forward():
    # seq shorter than the smoke window (32) -> ring buffer not yet wrapping
    _compare("h2o_danube_3_4b", seq=16)


def test_moe_decode_matches_forward():
    # NOTE: capacity at S=16 vs S=1 differs; use a config where nothing drops
    import dataclasses

    cfg = get_smoke_config("granite_moe_3b_a800m")
    cfg = dataclasses.replace(cfg, capacity_factor=8.0)  # no drops either mode
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (2, 8)), jnp.int32)
    full, _ = forward(params, cfg, {"tokens": tokens})
    step = _rollout(cfg, params, tokens)
    np.testing.assert_allclose(np.asarray(step), np.asarray(full), **TOL)


def test_rwkv6_decode_matches_forward():
    _compare("rwkv6_3b", seq=16)  # ssm_chunk=8 -> 2 chunks exercised


def test_zamba2_hybrid_decode_matches_forward():
    _compare("zamba2_7b", seq=16)


def test_encdec_decode_matches_forward():
    from repro.models import encode_memory, seed_decode_state

    cfg = get_smoke_config("seamless_m4t_large_v2")
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (2, 16)), jnp.int32)
    frames = jnp.asarray(rng.normal(0, 0.1, (2, 16, cfg.d_model)), jnp.float32)
    full, _ = forward(params, cfg, {"tokens": tokens, "frames": frames})
    mem = encode_memory(params, cfg, frames)
    st = init_decode_state(cfg, 2, 16, mem_len=16)
    st = seed_decode_state(params, cfg, st, mem)
    outs = []
    for t in range(16):
        logits, st = decode_step(params, cfg, st, tokens[:, t : t + 1], jnp.int32(t))
        outs.append(logits[:, 0])
    step = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.asarray(step), np.asarray(full), **TOL)


def test_vlm_decode_matches_forward():
    from repro.models import seed_decode_state

    cfg = get_smoke_config("llama_3_2_vision_11b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(2)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (2, 16)), jnp.int32)
    img = jnp.asarray(rng.normal(0, 0.1, (2, cfg.n_img_tokens, cfg.d_model)), jnp.float32)
    full, _ = forward(params, cfg, {"tokens": tokens, "img": img})
    st = init_decode_state(cfg, 2, 16)
    st = seed_decode_state(params, cfg, st, img)
    outs = []
    for t in range(16):
        logits, st = decode_step(params, cfg, st, tokens[:, t : t + 1], jnp.int32(t))
        outs.append(logits[:, 0])
    step = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.asarray(step), np.asarray(full), **TOL)


# ---------------------------------------------------------------- unit level
def test_mamba2_block_chunked_vs_step():
    key = jax.random.PRNGKey(3)
    d, expand, heads, state, conv = 32, 2, 4, 8, 4
    p = ssm_mod.init_mamba2(key, d, expand, heads, state, conv, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 16, d)) * 0.5
    full = ssm_mod.mamba2(p, x, expand=expand, n_heads=heads, state=state, chunk=8)
    st = ssm_mod.init_mamba2_state(2, d, expand, heads, state, conv, jnp.float32)
    outs = []
    for t in range(16):
        y, st = ssm_mod.mamba2_decode(
            p, x[:, t : t + 1], st, expand=expand, n_heads=heads, state=state
        )
        outs.append(y[:, 0])
    step = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.asarray(step), np.asarray(full), rtol=2e-3, atol=2e-3)


def test_rwkv6_timemix_chunked_vs_step():
    key = jax.random.PRNGKey(5)
    d, heads, ff = 32, 4, 64
    p = ssm_mod.init_rwkv6(key, d, ff, heads, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(6), (2, 16, d)) * 0.5
    full = ssm_mod.rwkv6_timemix(p, x, n_heads=heads, chunk=8)
    shift = jnp.zeros((2, d))
    S = jnp.zeros((2, heads, d // heads, d // heads))
    outs = []
    for t in range(16):
        y, (shift, S, _) = ssm_mod.rwkv6_timemix_decode(
            p, x[:, t : t + 1], (shift, S, jnp.zeros((2, d))), n_heads=heads
        )
        outs.append(y[:, 0])
    step = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.asarray(step), np.asarray(full), rtol=2e-3, atol=2e-3)


def test_attention_decode_ring_buffer_swa():
    """SWA ring-buffer decode == full forward with sliding-window mask."""
    key = jax.random.PRNGKey(7)
    d, h, kv, dh, win = 32, 4, 2, 8, 8
    p = attn_mod.init_attn(key, d, h, kv, dh, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(8), (2, 24, d)) * 0.5
    full, _ = attn_mod.attention(
        p, x, n_heads=h, n_kv=kv, d_head=dh, rope_theta=1e4, window=win
    )
    cache = attn_mod.init_cache(2, kv, win, dh, jnp.float32)
    outs = []
    for t in range(24):
        y, cache = attn_mod.attention_decode(
            p, x[:, t : t + 1], cache, jnp.int32(t),
            n_heads=h, n_kv=kv, d_head=dh, rope_theta=1e4, window=win,
        )
        outs.append(y[:, 0])
    step = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.asarray(step), np.asarray(full), rtol=2e-3, atol=2e-3)
