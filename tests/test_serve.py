"""Multi-tenant serving layer: admission, quotas, dedup, cache, bit-identity.

The acceptance contract of the ``repro.serve`` subsystem (DESIGN.md §16):

  * an N-tenant :class:`~repro.serve.KnnServer` returns, per tenant, the
    bitwise-same rows N solo :class:`~repro.api.KnnSession` instances would
    have produced — across every plan × partitioner, through drift rebuilds
    and concurrent per-tenant delta ingest, with dedup and cache replay on
    the serving path (the property harness fuzzes the same contract);
  * the epoch-keyed result cache hits on identical re-registration, is
    invalidated by ANY world movement (delta ingest, snapshot ingest, drift
    rebuild), and can never leak a mutable array across tenants;
  * quotas bound registration (raise by default, ``clip=True`` degrades to
    the remaining rows) and quota-clipped rows are served exactly.

Runs on however many devices exist; the subprocess test forces an 8-device
host grid regardless of the outer environment.
"""
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.api import KnnSession, ServiceSpec
from repro.launch.mesh import default_hybrid_shape
from repro.serve import (
    AdmissionError,
    KnnServer,
    QuotaExceededError,
    ResultCache,
    TenantRegistry,
)

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
NDEV = jax.device_count()
SIDE = 22_500.0

PLAN_GRID = (
    ("single", None, "equal"),
    ("sharded", NDEV, "equal"),
    ("sharded", NDEV, "cost_balanced"),
    ("object_sharded", NDEV, "equal"),
    ("object_sharded", NDEV, "cost_balanced"),
    ("hybrid", default_hybrid_shape(NDEV), "equal"),
    ("hybrid", default_hybrid_shape(NDEV), "cost_balanced"),
)


def _spec(**kw):
    kw.setdefault("k", 4)
    kw.setdefault("th_quad", 8)
    kw.setdefault("l_max", 5)
    kw.setdefault("window", 16)
    kw.setdefault("chunk", 32)
    kw.setdefault("side", SIDE)
    return ServiceSpec(**kw)


def _world(n=128, seed=0):
    rng = np.random.default_rng(seed)
    return rng.uniform(0, SIDE, (n, 2)).astype(np.float32)


def _tenant_queries(pts, seed, groups=3, rows=8, overlap=True):
    """Per-tenant query groups; consecutive tenants share their first rows
    (exact bit duplicates) so dedup and the cache have something to fold."""
    rng = np.random.default_rng(seed)
    out = []
    shared = rng.uniform(0, SIDE, (rows // 2, 2)).astype(np.float32)
    for g in range(groups):
        own = rng.uniform(0, SIDE, (rows - len(shared), 2)).astype(np.float32)
        qpos = np.concatenate([shared, own]) if overlap else np.concatenate(
            [rng.uniform(0, SIDE, (len(shared), 2)).astype(np.float32), own])
        qid = np.full((rows,), -2, np.int32)
        qid[-1] = g  # one self-excluding row per tenant
        out.append((qpos, qid))
    return out


# ------------------------------------------------------------------ registry

def test_registry_dedup_and_bit_pattern_keys():
    """compute_view folds exact duplicates across tenants; keys are raw bit
    patterns, so -0.0 and 0.0 (different bits) never alias."""
    reg = TenantRegistry()
    a = np.array([[1.0, 2.0], [3.0, 4.0]], np.float32)
    reg.register(0, a)
    reg.register(1, a)  # tenant 1 asks the bitwise-same questions
    reg.register(1, np.array([[5.0, 6.0]], np.float32))
    v = reg.compute_view()
    assert reg.nrows == 5 and v.n_unique == 3
    # every logical row maps back to its own bits
    np.testing.assert_array_equal(v.qpos[v.row_to_unique], reg.qpos)
    np.testing.assert_array_equal(v.qid[v.row_to_unique], reg.qid)
    assert len(v.keys) == 3 and len(set(v.keys)) == 3
    # same geometry, different qid -> different key (qid defines the result)
    reg.register(0, a[:1], np.array([7], np.int32))
    assert reg.compute_view().n_unique == 4
    # signed zero: bitwise-distinct, must not alias
    reg.register(0, np.array([[0.0, 0.0], [-0.0, 0.0]], np.float32))
    assert reg.compute_view().n_unique == 6


def test_registry_group_lifecycle():
    reg = TenantRegistry()
    h0 = reg.register(0, _world(4, 1))
    h1 = reg.register(1, _world(3, 2))
    assert reg.tenant_count(0) == 4 and reg.tenant_count(1) == 3
    reg.update(h1, _world(3, 5))
    with pytest.raises(ValueError, match="owns 3 rows"):
        reg.update(h1, _world(2, 5))
    reg.drop(h0)
    assert reg.tenant_count(0) == 0 and reg.nrows == 3
    with pytest.raises(KeyError, match="not live"):
        reg.drop(h0)
    reg.drop_tenant(1)
    assert reg.nrows == 0
    with pytest.raises(ValueError, match="empty query group"):
        reg.register(0, np.zeros((0, 2), np.float32))


# -------------------------------------------------------------------- cache

def test_result_cache_lru_and_epoch_semantics():
    c = ResultCache(capacity=2)
    ii = np.arange(4, dtype=np.int32)
    dd = np.arange(4, dtype=np.float32)
    assert c.lookup(b"a") is None
    c.insert(b"a", ii, dd)
    got_i, got_d = c.lookup(b"a")
    np.testing.assert_array_equal(got_i, ii)
    assert not got_i.flags.writeable and not got_d.flags.writeable
    # values are copies: mutating the source never reaches the store
    ii[0] = -99
    assert c.lookup(b"a")[0][0] == 0
    # LRU: touching "a" makes "b" the eviction victim at capacity 2
    c.insert(b"b", ii, dd)
    c.lookup(b"a")
    c.insert(b"c", ii, dd)
    assert c.lookup(b"b") is None and c.lookup(b"a") is not None
    assert c.stats.evictions == 1
    # epoch bump atomically clears the store
    e0 = c.epoch
    c.bump_epoch("test-ingest")
    assert c.epoch == e0 + 1 and len(c) == 0
    assert c.last_invalidation == "test-ingest"
    assert c.stats.invalidations == 2  # "a" and "c" were live
    assert c.lookup(b"a") is None
    # disabled cache: inserts drop, lookups miss
    off = ResultCache(capacity=0)
    assert not off.enabled
    off.insert(b"a", ii, dd)
    assert off.lookup(b"a") is None
    with pytest.raises(ValueError, match="capacity"):
        ResultCache(capacity=-1)


# ------------------------------------------------------- admission + quotas

def test_admission_and_eviction():
    srv = KnnServer(_spec(), max_tenants=2)
    a = srv.admit("alice")
    with pytest.raises(AdmissionError, match="already admitted"):
        srv.admit("alice")
    srv.admit("bob")
    with pytest.raises(AdmissionError, match="max_tenants"):
        srv.admit("carol")
    srv.ingest_objects(_world())
    ha = a.register_queries(_world(4, 3))
    assert a.query_count == 4 and srv.query_count == 4
    srv.evict(a)
    assert not a.live and srv.query_count == 0
    with pytest.raises(AdmissionError, match="evicted"):
        a.register_queries(_world(2, 4))
    with pytest.raises(AdmissionError, match="not admitted"):
        srv.evict(a)
    del ha
    # the freed slot readmits
    srv.admit("carol")


def test_quota_raise_and_clip():
    srv = KnnServer(_spec(), default_quota=6)
    t = srv.admit("alice")
    assert t.quota == 6
    t.register_queries(_world(4, 1))
    assert t.quota_remaining == 2
    with pytest.raises(QuotaExceededError, match="exceed quota 6"):
        t.register_queries(_world(4, 2))
    # clip=True registers exactly the first quota_remaining rows
    q = _world(4, 2)
    h = t.register_queries(q, clip=True)
    assert h.count == 2 and t.quota_remaining == 0
    np.testing.assert_array_equal(
        srv._registry.qpos[srv._registry.group_rows(h.hid)], q[:2])
    # at zero remaining even clip raises
    with pytest.raises(QuotaExceededError):
        t.register_queries(_world(1, 3), clip=True)
    # dropping frees quota
    t.drop_queries(h)
    assert t.quota_remaining == 2
    with pytest.raises(ValueError, match="quota must be >= 1"):
        srv.admit("bob", quota=0)


# ----------------------------------------------- server ≡ solo, full grid

def _solo_results(spec, pts_script, qpos, qid):
    """Replay one tenant's view through a solo session; returns per-tick rows."""
    sess = KnnSession(spec)
    out = []
    for op, payload in pts_script:
        if op == "snapshot":
            sess.ingest_objects(payload)
        elif op == "delta":
            sess.update_objects(*payload)
        else:
            if op == "register":
                sess.register_queries(qpos, qid)
            r = sess.submit().result()
            out.append((np.asarray(r.nn_idx), np.asarray(r.nn_dist)))
    return out


@pytest.mark.parametrize("plan,mesh,part", PLAN_GRID)
def test_server_bitwise_equals_solo_sessions(plan, mesh, part):
    """3 overlapping tenants through one server == 3 solo sessions, bitwise,
    per tick — including a no-motion tick served from the cache and a delta
    tick that invalidates it (the tentpole acceptance criterion)."""
    spec = _spec(plan=plan, mesh_shape=mesh, partitioner=part)
    pts = _world(128, seed=10)
    tq = _tenant_queries(pts, seed=11, groups=3, rows=8)
    rng = np.random.default_rng(12)

    srv = KnnServer(spec)
    srv.ingest_objects(pts)
    tenants = [srv.admit(f"t{i}") for i in range(3)]
    handles = [t.register_queries(*tq[i]) for i, t in enumerate(tenants)]

    deltas = []
    for _ in range(2):
        ids = rng.choice(128, 16, replace=False).astype(np.int32)
        deltas.append((ids, rng.uniform(0, SIDE, (16, 2)).astype(np.float32)))

    server_rows = []
    # tick 0: fresh; tick 1: NO motion (pure cache replay); ticks 2-3: deltas
    # fed by rotating tenants (concurrent per-tenant ingest)
    for t in range(4):
        if t >= 2:
            tenants[t % 3].update_objects(*deltas[t - 2])
        st = srv.submit()
        res = st.result()
        server_rows.append([st.result_for(h) for h in handles])
        if t == 1:  # world unchanged -> whole tick replays from the cache
            assert res.rows_computed == 0 and res.inner is None
            assert res.hit_rate == 1.0
    for i, (qpos, qid) in enumerate(tq):
        script = [("snapshot", pts), ("register", None),
                  ("delta", deltas[0]), ("tick", None),
                  ("delta", deltas[1]), ("tick", None)]
        solo = _solo_results(spec, script, qpos, qid)
        # server ticks 0 and 1 both correspond to solo tick 0 (no motion)
        for srv_t, solo_t in ((0, 0), (1, 0), (2, 1), (3, 2)):
            ii, dd, qids = server_rows[srv_t][i]
            np.testing.assert_array_equal(
                ii, solo[solo_t][0], err_msg=f"t{i} tick{srv_t}")
            np.testing.assert_array_equal(
                dd, solo[solo_t][1], err_msg=f"t{i} tick{srv_t}")
            np.testing.assert_array_equal(qids, qid)


def test_quota_clipped_rows_served_exactly():
    """A clip-registered group's surviving rows are served with the same bits
    a solo session gives those rows."""
    spec = _spec()
    pts = _world(96, seed=20)
    q = _world(8, seed=21)
    srv = KnnServer(spec)
    srv.ingest_objects(pts)
    t = srv.admit("alice", quota=5)
    h = t.register_queries(q, clip=True)
    assert h.count == 5
    ii, dd, _ = srv.submit().result_for(h)
    sess = KnnSession(spec)
    sess.ingest_objects(pts)
    sess.register_queries(q[:5])
    r = sess.submit().result()
    np.testing.assert_array_equal(ii, r.nn_idx)
    np.testing.assert_array_equal(dd, r.nn_dist)


# ------------------------------------------------- drift rebuild + epochs

def test_drift_rebuild_mid_flight_with_concurrent_delta():
    """One tenant's teleport delta triggers a drift rebuild; while that tick
    is still in flight another tenant ingests a further delta and submits.
    Epoch hygiene: the rebuild bumps when observed, the racing tick never
    inserts stale entries, and every tick stays solo-exact."""
    n = 2000
    rng = np.random.default_rng(30)
    uniform = rng.uniform(0, SIDE, (n, 2)).astype(np.float32)
    clustered = (rng.normal(0, 60, (n, 2)) + 11_250).astype(
        np.float32).clip(0, SIDE - 1)
    spec = _spec(k=8, th_quad=32, l_max=6, window=64, chunk=512,
                 rebuild_factor=1.5)
    small_ids = np.arange(32, dtype=np.int32)
    small_new = rng.uniform(0, SIDE, (32, 2)).astype(np.float32)

    srv = KnnServer(spec)
    srv.ingest_objects(uniform)
    a, b = srv.admit("alice"), srv.admit("bob")
    qa = a.register_queries(uniform[:64], np.arange(64, dtype=np.int32))
    qb = b.register_queries(uniform[64:128])
    srv.submit().result()
    srv.submit().result()  # baseline tick (work-at-build anchor)
    e0 = srv.cache.epoch
    b.update_objects(np.arange(n, dtype=np.int32), clustered)
    assert srv.cache.epoch == e0 + 1  # delta ingest bumps immediately
    st_drift = srv.submit()  # drift decision pending
    # concurrent ingest + submit while the drift tick is in flight
    a.update_objects(small_ids, small_new)
    st_next = srv.submit()
    r_drift = st_drift.result()
    assert r_drift.rebuilt
    assert srv.cache.last_invalidation == "drift-rebuild"
    assert srv.cache.epoch > e0 + 1
    r_next = st_next.result()
    assert r_next.rows_computed == r_next.rows_unique  # nothing stale served
    assert r_next.epoch != r_drift.epoch

    # solo replay, per tenant, same op order
    world2 = clustered.copy()
    world2[small_ids] = small_new
    for qpos, qid, handle, ticks in (
        (uniform[:64], np.arange(64, dtype=np.int32), qa, None),
        (uniform[64:128], None, qb, None),
    ):
        sess = KnnSession(spec)
        sess.ingest_objects(uniform)
        sess.register_queries(qpos, qid)
        sess.submit().result()
        sess.submit().result()
        sess.update_objects(np.arange(n, dtype=np.int32), clustered)
        h1 = sess.submit()
        sess.update_objects(small_ids, small_new)
        h2 = sess.submit()
        s1, s2 = h1.result(), h2.result()
        assert s1.rebuilt
        for st, sr in ((st_drift, s1), (st_next, s2)):
            ii, dd, _ = st.result_for(handle)
            np.testing.assert_array_equal(ii, sr.nn_idx)
            np.testing.assert_array_equal(dd, sr.nn_dist)


def test_epoch_bumps_on_every_world_movement():
    srv = KnnServer(_spec())
    pts = _world(64, seed=40)
    srv.ingest_objects(pts)
    assert srv.cache.epoch == 1  # snapshot ingest counts
    t = srv.admit("alice")
    t.register_queries(_world(4, 41))
    r0 = srv.submit().result()
    assert r0.rows_computed == 4 and srv.cache.stats.insertions == 4
    # identical re-registration by ANOTHER tenant hits the cache
    u = srv.admit("bob")
    hu = u.register_queries(srv._registry.qpos[:4].copy(),
                            srv._registry.qid[:4].copy())
    r1 = srv.submit().result()
    assert r1.rows_computed == 0 and r1.cache_hit_rows == 8
    # delta ingest invalidates: next tick recomputes everything
    t.update_objects(np.array([0], np.int32), pts[:1] + 1.0)
    r2 = srv.submit().result()
    assert r2.rows_computed == r2.rows_unique and r2.cache_hit_rows == 0
    # snapshot ingest invalidates too
    e = srv.cache.epoch
    srv.ingest_objects(pts)
    assert srv.cache.epoch == e + 1 and len(srv.cache) == 0
    assert srv.submit().result_for(hu)  # still serveable after the bumps


def test_cache_no_cross_tenant_mutation():
    """A tenant mutating its returned arrays cannot corrupt what another
    tenant is later served from the cache."""
    spec = _spec()
    srv = KnnServer(spec)
    pts = _world(96, seed=50)
    srv.ingest_objects(pts)
    q = _world(6, seed=51)
    a, b = srv.admit("alice"), srv.admit("bob")
    ha = a.register_queries(q)
    st0 = srv.submit()
    ii_a, dd_a, _ = st0.result_for(ha)
    want_i, want_d = ii_a.copy(), dd_a.copy()
    ii_a[:] = -7
    dd_a[:] = -7.0
    hb = b.register_queries(q)  # bitwise-same questions
    st1 = srv.submit()
    r1 = st1.result()
    assert r1.rows_computed == 0  # served purely from the cache
    ii_b, dd_b, _ = st1.result_for(hb)
    np.testing.assert_array_equal(ii_b, want_i)
    np.testing.assert_array_equal(dd_b, want_d)
    ii_b[:] = 9  # callers own their copies; the store stays read-only
    ii_b2, _, _ = st1.result_for(ha)
    np.testing.assert_array_equal(ii_b2, want_i)


# ------------------------------------------------------- collect="stats"

def test_collect_stats_dedup_without_cache():
    """Under collect="stats" the cache is disabled (lists never reach the
    host) but intra-tick dedup still shares device work, and result_for
    returns device rows matching the full-collect bits."""
    pts = _world(96, seed=60)
    q = _world(6, seed=61)
    srv = KnnServer(_spec(collect="stats"))
    assert not srv.cache.enabled
    srv.ingest_objects(pts)
    a, b = srv.admit("alice"), srv.admit("bob")
    ha, hb = a.register_queries(q), b.register_queries(q)
    st = srv.submit()
    res = st.result()
    assert res.rows_total == 12 and res.rows_computed == 6
    assert res.dedup_hit_rows == 6 and res.cache_hit_rows == 0
    ii, dd, _ = st.result_for(hb)  # device arrays (jnp gather path)
    full = KnnServer(_spec(collect="full"))
    full.ingest_objects(pts)
    hf = full.admit("x").register_queries(q)
    fi, fd, _ = full.submit().result_for(hf)
    np.testing.assert_array_equal(np.asarray(ii), fi)
    np.testing.assert_array_equal(np.asarray(dd), fd)
    # next tick recomputes (no cache under stats) but stays deduped
    r2 = srv.submit().result()
    assert r2.rows_computed == 6 and r2.cache_hit_rows == 0


def test_result_for_errors():
    srv = KnnServer(_spec())
    srv.ingest_objects(_world(64, seed=70))
    with pytest.raises(RuntimeError, match="no registered tenant queries"):
        srv.submit()
    a = srv.admit("alice")
    h = a.register_queries(_world(3, 71))
    a.drop_queries(h)
    b = srv.admit("bob")
    hb = b.register_queries(_world(3, 72))
    st = srv.submit()
    with pytest.raises(KeyError, match="owned no rows"):
        st.result_for(h)  # dropped before submit
    with pytest.raises(KeyError, match="belongs to tenant"):
        a.drop_queries(hb)
    st.result_for(hb)


# --------------------------------------- forced 8-device mesh (real XLA)

def test_server_solo_parity_on_8_devices():
    """3 tenants through one server on a real 8-device grid == solo sessions,
    bitwise, for the mesh plans under cost_balanced — with a delta tick and a
    cache-replay tick in the script.  Subprocess because the device count
    must be set before jax init."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax
assert jax.device_count() == 8, jax.device_count()
from repro.api import KnnSession, ServiceSpec
from repro.serve import KnnServer

SIDE = 22_500.0
rng = np.random.default_rng(0)
pts = rng.uniform(0, SIDE, (512, 2)).astype(np.float32)
shared = rng.uniform(0, SIDE, (8, 2)).astype(np.float32)
tq = [np.concatenate([shared, rng.uniform(0, SIDE, (8, 2)).astype(np.float32)])
      for _ in range(3)]
ids = rng.choice(512, 32, replace=False).astype(np.int32)
new = rng.uniform(0, SIDE, (32, 2)).astype(np.float32)

for plan, mesh in (("sharded", 8), ("hybrid", (2, 4))):
    spec = ServiceSpec(k=4, th_quad=8, l_max=5, window=16, chunk=32,
                       side=SIDE, plan=plan, mesh_shape=mesh,
                       partitioner="cost_balanced")
    srv = KnnServer(spec)
    srv.ingest_objects(pts)
    tenants = [srv.admit(f"t{i}") for i in range(3)]
    handles = [t.register_queries(tq[i]) for i, t in enumerate(tenants)]
    got = []
    for t in range(3):
        if t == 2:
            tenants[1].update_objects(ids, new)
        st = srv.submit()
        res = st.result()
        if t == 1:
            assert res.rows_computed == 0, (plan, res)  # cache replay
        got.append([st.result_for(h) for h in handles])
    for i in range(3):
        sess = KnnSession(spec)
        sess.ingest_objects(pts)
        sess.register_queries(tq[i])
        want = [sess.submit().result()]
        sess.update_objects(ids, new)
        want.append(sess.submit().result())
        for srv_t, solo_t in ((0, 0), (1, 0), (2, 1)):
            np.testing.assert_array_equal(
                got[srv_t][i][0], want[solo_t].nn_idx, err_msg=f"{plan}/t{i}")
            np.testing.assert_array_equal(
                got[srv_t][i][1], want[solo_t].nn_dist, err_msg=f"{plan}/t{i}")
print("SERVE_8DEV_OK")
"""
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)  # the child pins its own device count
    r = subprocess.run(
        [sys.executable, "-c", code], env=env, capture_output=True, text=True
    )
    assert r.returncode == 0, (r.stdout[-1000:], r.stderr[-3000:])
    assert "SERVE_8DEV_OK" in r.stdout
