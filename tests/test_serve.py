"""Multi-tenant serving layer: admission, quotas, dedup, cache, bit-identity.

The acceptance contract of the ``repro.serve`` subsystem (DESIGN.md §16):

  * an N-tenant :class:`~repro.serve.KnnServer` returns, per tenant, the
    bitwise-same rows N solo :class:`~repro.api.KnnSession` instances would
    have produced — across every plan × partitioner, through drift rebuilds
    and concurrent per-tenant delta ingest, with dedup and cache replay on
    the serving path (the property harness fuzzes the same contract);
  * the epoch-keyed result cache hits on identical re-registration, is
    invalidated by ANY world movement (delta ingest, snapshot ingest, drift
    rebuild), and can never leak a mutable array across tenants;
  * quotas bound registration (raise by default, ``clip=True`` degrades to
    the remaining rows) and quota-clipped rows are served exactly.

Runs on however many devices exist; the subprocess test forces an 8-device
host grid regardless of the outer environment.
"""
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.api import KnnSession, ServiceSpec
from repro.launch.mesh import default_hybrid_shape
from repro.serve import (
    AdmissionError,
    KnnServer,
    QuotaExceededError,
    ResultCache,
    TenantRegistry,
)

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
NDEV = jax.device_count()
SIDE = 22_500.0

PLAN_GRID = (
    ("single", None, "equal"),
    ("sharded", NDEV, "equal"),
    ("sharded", NDEV, "cost_balanced"),
    ("object_sharded", NDEV, "equal"),
    ("object_sharded", NDEV, "cost_balanced"),
    ("hybrid", default_hybrid_shape(NDEV), "equal"),
    ("hybrid", default_hybrid_shape(NDEV), "cost_balanced"),
)


def _spec(**kw):
    kw.setdefault("k", 4)
    kw.setdefault("th_quad", 8)
    kw.setdefault("l_max", 5)
    kw.setdefault("window", 16)
    kw.setdefault("chunk", 32)
    kw.setdefault("side", SIDE)
    return ServiceSpec(**kw)


def _world(n=128, seed=0):
    rng = np.random.default_rng(seed)
    return rng.uniform(0, SIDE, (n, 2)).astype(np.float32)


def _tenant_queries(pts, seed, groups=3, rows=8, overlap=True):
    """Per-tenant query groups; consecutive tenants share their first rows
    (exact bit duplicates) so dedup and the cache have something to fold."""
    rng = np.random.default_rng(seed)
    out = []
    shared = rng.uniform(0, SIDE, (rows // 2, 2)).astype(np.float32)
    for g in range(groups):
        own = rng.uniform(0, SIDE, (rows - len(shared), 2)).astype(np.float32)
        qpos = np.concatenate([shared, own]) if overlap else np.concatenate(
            [rng.uniform(0, SIDE, (len(shared), 2)).astype(np.float32), own])
        qid = np.full((rows,), -2, np.int32)
        qid[-1] = g  # one self-excluding row per tenant
        out.append((qpos, qid))
    return out


# ------------------------------------------------------------------ registry

def test_registry_dedup_and_bit_pattern_keys():
    """compute_view folds exact duplicates across tenants; keys are raw bit
    patterns, so -0.0 and 0.0 (different bits) never alias."""
    reg = TenantRegistry()
    a = np.array([[1.0, 2.0], [3.0, 4.0]], np.float32)
    reg.register(0, a)
    reg.register(1, a)  # tenant 1 asks the bitwise-same questions
    reg.register(1, np.array([[5.0, 6.0]], np.float32))
    v = reg.compute_view()
    assert reg.nrows == 5 and v.n_unique == 3
    # every logical row maps back to its own bits
    np.testing.assert_array_equal(v.qpos[v.row_to_unique], reg.qpos)
    np.testing.assert_array_equal(v.qid[v.row_to_unique], reg.qid)
    assert len(v.keys) == 3 and len(set(v.keys)) == 3
    # same geometry, different qid -> different key (qid defines the result)
    reg.register(0, a[:1], np.array([7], np.int32))
    assert reg.compute_view().n_unique == 4
    # signed zero: bitwise-distinct, must not alias
    reg.register(0, np.array([[0.0, 0.0], [-0.0, 0.0]], np.float32))
    assert reg.compute_view().n_unique == 6


def test_registry_group_lifecycle():
    reg = TenantRegistry()
    h0 = reg.register(0, _world(4, 1))
    h1 = reg.register(1, _world(3, 2))
    assert reg.tenant_count(0) == 4 and reg.tenant_count(1) == 3
    reg.update(h1, _world(3, 5))
    with pytest.raises(ValueError, match="owns 3 rows"):
        reg.update(h1, _world(2, 5))
    reg.drop(h0)
    assert reg.tenant_count(0) == 0 and reg.nrows == 3
    with pytest.raises(KeyError, match="not live"):
        reg.drop(h0)
    reg.drop_tenant(1)
    assert reg.nrows == 0
    with pytest.raises(ValueError, match="empty query group"):
        reg.register(0, np.zeros((0, 2), np.float32))


# -------------------------------------------------------------------- cache

def test_result_cache_lru_and_epoch_semantics():
    c = ResultCache(capacity=2)
    ii = np.arange(4, dtype=np.int32)
    dd = np.arange(4, dtype=np.float32)
    assert c.lookup(b"a") is None
    c.insert(b"a", ii, dd)
    got_i, got_d = c.lookup(b"a")
    np.testing.assert_array_equal(got_i, ii)
    assert not got_i.flags.writeable and not got_d.flags.writeable
    # values are copies: mutating the source never reaches the store
    ii[0] = -99
    assert c.lookup(b"a")[0][0] == 0
    # LRU: touching "a" makes "b" the eviction victim at capacity 2
    c.insert(b"b", ii, dd)
    c.lookup(b"a")
    c.insert(b"c", ii, dd)
    assert c.lookup(b"b") is None and c.lookup(b"a") is not None
    assert c.stats.evictions == 1
    # epoch bump atomically clears the store
    e0 = c.epoch
    c.bump_epoch("test-ingest")
    assert c.epoch == e0 + 1 and len(c) == 0
    assert c.last_invalidation == "test-ingest"
    assert c.stats.invalidations == 2  # "a" and "c" were live
    assert c.lookup(b"a") is None
    # disabled cache: inserts drop, lookups miss
    off = ResultCache(capacity=0)
    assert not off.enabled
    off.insert(b"a", ii, dd)
    assert off.lookup(b"a") is None
    with pytest.raises(ValueError, match="capacity"):
        ResultCache(capacity=-1)


# ------------------------------------------------------- admission + quotas

def test_admission_and_eviction():
    srv = KnnServer(_spec(), max_tenants=2)
    a = srv.admit("alice")
    with pytest.raises(AdmissionError, match="already admitted"):
        srv.admit("alice")
    srv.admit("bob")
    with pytest.raises(AdmissionError, match="max_tenants"):
        srv.admit("carol")
    srv.ingest_objects(_world())
    ha = a.register_queries(_world(4, 3))
    assert a.query_count == 4 and srv.query_count == 4
    srv.evict(a)
    assert not a.live and srv.query_count == 0
    with pytest.raises(AdmissionError, match="evicted"):
        a.register_queries(_world(2, 4))
    with pytest.raises(AdmissionError, match="not admitted"):
        srv.evict(a)
    del ha
    # the freed slot readmits
    srv.admit("carol")


def test_quota_raise_and_clip():
    srv = KnnServer(_spec(), default_quota=6)
    t = srv.admit("alice")
    assert t.quota == 6
    t.register_queries(_world(4, 1))
    assert t.quota_remaining == 2
    with pytest.raises(QuotaExceededError, match="exceed quota 6"):
        t.register_queries(_world(4, 2))
    # clip=True registers exactly the first quota_remaining rows
    q = _world(4, 2)
    h = t.register_queries(q, clip=True)
    assert h.count == 2 and t.quota_remaining == 0
    np.testing.assert_array_equal(
        srv._registry.qpos[srv._registry.group_rows(h.hid)], q[:2])
    # at zero remaining even clip raises
    with pytest.raises(QuotaExceededError):
        t.register_queries(_world(1, 3), clip=True)
    # dropping frees quota
    t.drop_queries(h)
    assert t.quota_remaining == 2
    with pytest.raises(ValueError, match="quota must be >= 1"):
        srv.admit("bob", quota=0)


# ----------------------------------------------- server ≡ solo, full grid

def _solo_results(spec, pts_script, qpos, qid):
    """Replay one tenant's view through a solo session; returns per-tick rows."""
    sess = KnnSession(spec)
    out = []
    for op, payload in pts_script:
        if op == "snapshot":
            sess.ingest_objects(payload)
        elif op == "delta":
            sess.update_objects(*payload)
        else:
            if op == "register":
                sess.register_queries(qpos, qid)
            r = sess.submit().result()
            out.append((np.asarray(r.nn_idx), np.asarray(r.nn_dist)))
    return out


@pytest.mark.parametrize("plan,mesh,part", PLAN_GRID)
def test_server_bitwise_equals_solo_sessions(plan, mesh, part):
    """3 overlapping tenants through one server == 3 solo sessions, bitwise,
    per tick — including a no-motion tick served from the cache and a delta
    tick that invalidates it (the tentpole acceptance criterion)."""
    spec = _spec(plan=plan, mesh_shape=mesh, partitioner=part)
    pts = _world(128, seed=10)
    tq = _tenant_queries(pts, seed=11, groups=3, rows=8)
    rng = np.random.default_rng(12)

    srv = KnnServer(spec)
    srv.ingest_objects(pts)
    tenants = [srv.admit(f"t{i}") for i in range(3)]
    handles = [t.register_queries(*tq[i]) for i, t in enumerate(tenants)]

    deltas = []
    for _ in range(2):
        ids = rng.choice(128, 16, replace=False).astype(np.int32)
        deltas.append((ids, rng.uniform(0, SIDE, (16, 2)).astype(np.float32)))

    server_rows = []
    # tick 0: fresh; tick 1: NO motion (pure cache replay); ticks 2-3: deltas
    # fed by rotating tenants (concurrent per-tenant ingest)
    for t in range(4):
        if t >= 2:
            tenants[t % 3].update_objects(*deltas[t - 2])
        st = srv.submit()
        res = st.result()
        server_rows.append([st.result_for(h) for h in handles])
        if t == 1:  # world unchanged -> whole tick replays from the cache
            assert res.rows_computed == 0 and res.inner is None
            assert res.hit_rate == 1.0
    for i, (qpos, qid) in enumerate(tq):
        script = [("snapshot", pts), ("register", None),
                  ("delta", deltas[0]), ("tick", None),
                  ("delta", deltas[1]), ("tick", None)]
        solo = _solo_results(spec, script, qpos, qid)
        # server ticks 0 and 1 both correspond to solo tick 0 (no motion)
        for srv_t, solo_t in ((0, 0), (1, 0), (2, 1), (3, 2)):
            ii, dd, qids = server_rows[srv_t][i]
            np.testing.assert_array_equal(
                ii, solo[solo_t][0], err_msg=f"t{i} tick{srv_t}")
            np.testing.assert_array_equal(
                dd, solo[solo_t][1], err_msg=f"t{i} tick{srv_t}")
            np.testing.assert_array_equal(qids, qid)


def test_quota_clipped_rows_served_exactly():
    """A clip-registered group's surviving rows are served with the same bits
    a solo session gives those rows."""
    spec = _spec()
    pts = _world(96, seed=20)
    q = _world(8, seed=21)
    srv = KnnServer(spec)
    srv.ingest_objects(pts)
    t = srv.admit("alice", quota=5)
    h = t.register_queries(q, clip=True)
    assert h.count == 5
    ii, dd, _ = srv.submit().result_for(h)
    sess = KnnSession(spec)
    sess.ingest_objects(pts)
    sess.register_queries(q[:5])
    r = sess.submit().result()
    np.testing.assert_array_equal(ii, r.nn_idx)
    np.testing.assert_array_equal(dd, r.nn_dist)


# ------------------------------------------------- drift rebuild + epochs

def test_drift_rebuild_mid_flight_with_concurrent_delta():
    """One tenant's teleport delta triggers a drift rebuild; while that tick
    is still in flight another tenant ingests a further delta and submits.
    Epoch hygiene: the rebuild bumps when observed, the racing tick never
    inserts stale entries, and every tick stays solo-exact."""
    n = 2000
    rng = np.random.default_rng(30)
    uniform = rng.uniform(0, SIDE, (n, 2)).astype(np.float32)
    clustered = (rng.normal(0, 60, (n, 2)) + 11_250).astype(
        np.float32).clip(0, SIDE - 1)
    spec = _spec(k=8, th_quad=32, l_max=6, window=64, chunk=512,
                 rebuild_factor=1.5)
    small_ids = np.arange(32, dtype=np.int32)
    small_new = rng.uniform(0, SIDE, (32, 2)).astype(np.float32)

    srv = KnnServer(spec)
    srv.ingest_objects(uniform)
    a, b = srv.admit("alice"), srv.admit("bob")
    qa = a.register_queries(uniform[:64], np.arange(64, dtype=np.int32))
    qb = b.register_queries(uniform[64:128])
    srv.submit().result()
    srv.submit().result()  # baseline tick (work-at-build anchor)
    e0 = srv.cache.epoch
    b.update_objects(np.arange(n, dtype=np.int32), clustered)
    assert srv.cache.epoch == e0 + 1  # delta ingest bumps immediately
    st_drift = srv.submit()  # drift decision pending
    # concurrent ingest + submit while the drift tick is in flight
    a.update_objects(small_ids, small_new)
    st_next = srv.submit()
    r_drift = st_drift.result()
    assert r_drift.rebuilt
    assert srv.cache.last_invalidation == "drift-rebuild"
    assert srv.cache.epoch > e0 + 1
    r_next = st_next.result()
    assert r_next.rows_computed == r_next.rows_unique  # nothing stale served
    assert r_next.epoch != r_drift.epoch

    # solo replay, per tenant, same op order
    world2 = clustered.copy()
    world2[small_ids] = small_new
    for qpos, qid, handle, ticks in (
        (uniform[:64], np.arange(64, dtype=np.int32), qa, None),
        (uniform[64:128], None, qb, None),
    ):
        sess = KnnSession(spec)
        sess.ingest_objects(uniform)
        sess.register_queries(qpos, qid)
        sess.submit().result()
        sess.submit().result()
        sess.update_objects(np.arange(n, dtype=np.int32), clustered)
        h1 = sess.submit()
        sess.update_objects(small_ids, small_new)
        h2 = sess.submit()
        s1, s2 = h1.result(), h2.result()
        assert s1.rebuilt
        for st, sr in ((st_drift, s1), (st_next, s2)):
            ii, dd, _ = st.result_for(handle)
            np.testing.assert_array_equal(ii, sr.nn_idx)
            np.testing.assert_array_equal(dd, sr.nn_dist)


def test_epoch_bumps_on_every_world_movement():
    srv = KnnServer(_spec())
    pts = _world(64, seed=40)
    srv.ingest_objects(pts)
    assert srv.cache.epoch == 1  # snapshot ingest counts
    t = srv.admit("alice")
    t.register_queries(_world(4, 41))
    r0 = srv.submit().result()
    assert r0.rows_computed == 4 and srv.cache.stats.insertions == 4
    # identical re-registration by ANOTHER tenant hits the cache
    u = srv.admit("bob")
    hu = u.register_queries(srv._registry.qpos[:4].copy(),
                            srv._registry.qid[:4].copy())
    r1 = srv.submit().result()
    assert r1.rows_computed == 0 and r1.cache_hit_rows == 8
    # delta ingest invalidates: next tick recomputes everything
    t.update_objects(np.array([0], np.int32), pts[:1] + 1.0)
    r2 = srv.submit().result()
    assert r2.rows_computed == r2.rows_unique and r2.cache_hit_rows == 0
    # snapshot ingest invalidates too
    e = srv.cache.epoch
    srv.ingest_objects(pts)
    assert srv.cache.epoch == e + 1 and len(srv.cache) == 0
    assert srv.submit().result_for(hu)  # still serveable after the bumps


def test_cache_no_cross_tenant_mutation():
    """A tenant mutating its returned arrays cannot corrupt what another
    tenant is later served from the cache."""
    spec = _spec()
    srv = KnnServer(spec)
    pts = _world(96, seed=50)
    srv.ingest_objects(pts)
    q = _world(6, seed=51)
    a, b = srv.admit("alice"), srv.admit("bob")
    ha = a.register_queries(q)
    st0 = srv.submit()
    ii_a, dd_a, _ = st0.result_for(ha)
    want_i, want_d = ii_a.copy(), dd_a.copy()
    ii_a[:] = -7
    dd_a[:] = -7.0
    hb = b.register_queries(q)  # bitwise-same questions
    st1 = srv.submit()
    r1 = st1.result()
    assert r1.rows_computed == 0  # served purely from the cache
    ii_b, dd_b, _ = st1.result_for(hb)
    np.testing.assert_array_equal(ii_b, want_i)
    np.testing.assert_array_equal(dd_b, want_d)
    ii_b[:] = 9  # callers own their copies; the store stays read-only
    ii_b2, _, _ = st1.result_for(ha)
    np.testing.assert_array_equal(ii_b2, want_i)


# --------------------------------------------- spatial invalidation mode


def _ball_world():
    """A controlled world: 4 axis-aligned neighbours around a hotspot query
    at (1000, 1000) — exact integer distances 1..4, so the cached k=4 ball
    has squared radius EXACTLY 16.0 in f32 — plus far-corner filler."""
    pts = np.array(
        [[1001.0, 1000.0],   # id 0, d2 = 1
         [1002.0, 1000.0],   # id 1, d2 = 4
         [1003.0, 1000.0],   # id 2, d2 = 9
         [1000.0, 1004.0],   # id 3, d2 = 16  (the k-th neighbour)
         [20000.0, 20000.0],  # id 4: the mover, starts far away
         [21000.0, 20000.0],
         [20000.0, 21000.0],
         [21000.0, 21000.0]], np.float32)
    q = np.array([[1000.0, 1000.0]], np.float32)
    return pts, q


def _one_delta_solo(spec, pts, q, qid, ids, new):
    sess = KnnSession(spec)
    sess.ingest_objects(pts)
    sess.register_queries(q, qid)
    r0 = sess.submit().result()
    sess.update_objects(ids, new)
    r1 = sess.submit().result()
    return r0, r1


def test_spatial_survives_unrelated_motion():
    """The tentpole acceptance scenario on the local device count: hotspot
    queries disjoint from the delta region keep serving from the cache
    across delta-ingesting ticks under spatial invalidation (epoch mode
    drops to zero), every served row bitwise equal to cold recomputation."""
    rng = np.random.default_rng(80)
    pts = rng.uniform(0, SIDE, (256, 2)).astype(np.float32)
    ids = np.arange(200, 232, dtype=np.int32)
    pts[ids] = rng.uniform(20000, 22000, (32, 2)).astype(np.float32)
    q = rng.uniform(0, 800, (8, 2)).astype(np.float32)  # far-corner hotspot
    deltas = [rng.uniform(20000, 22000, (32, 2)).astype(np.float32)
              for _ in range(2)]
    spec = _spec()

    # solo reference across the same world script
    sess = KnnSession(spec)
    sess.ingest_objects(pts)
    sess.register_queries(q)
    want = [sess.submit().result()]
    for new in deltas:
        sess.update_objects(ids, new)
        want.append(sess.submit().result())

    for mode, expect_cached in (("epoch", False), ("spatial", True)):
        srv = KnnServer(spec, invalidation=mode)
        srv.ingest_objects(pts)
        t = srv.admit("a")
        h = t.register_queries(q)
        for tick in range(3):
            if tick:
                t.update_objects(ids, deltas[tick - 1])
            st = srv.submit()
            res = st.result()
            ii, dd, _ = st.result_for(h)
            np.testing.assert_array_equal(ii, want[tick].nn_idx,
                                          err_msg=f"{mode}/tick{tick}")
            np.testing.assert_array_equal(dd, want[tick].nn_dist,
                                          err_msg=f"{mode}/tick{tick}")
            if tick:  # the delta-ingesting ticks
                if expect_cached:
                    assert res.rows_computed == 0 and res.hit_rate > 0, (
                        mode, tick, res)
                    assert srv.cache.last_invalidation == "delta-stab:a"
                else:
                    assert res.cache_hit_rows == 0, (mode, tick, res)


def test_spatial_ball_enter_leave_and_unrelated():
    """Per-entry eviction edges: a mover entering the cached k-th ball
    evicts, a mover leaving it evicts (its OLD position stabs), and far
    motion leaves the entry serving — with solo-exact bits throughout."""
    pts, q = _ball_world()
    spec = _spec()
    srv = KnnServer(spec, invalidation="spatial")
    srv.ingest_objects(pts)
    t = srv.admit("a")
    h = t.register_queries(q)
    st = srv.submit()
    st.result()
    mover = np.array([4], np.int32)
    script = [
        # (new position, must_evict)
        (np.array([[20001.0, 20000.0]], np.float32), False),  # far -> far
        (np.array([[1000.0, 1002.0]], np.float32), True),     # ENTERS ball
        (np.array([[18000.0, 18000.0]], np.float32), True),   # LEAVES ball
        (np.array([[18000.0, 17000.0]], np.float32), False),  # far again
    ]
    world = pts.copy()
    for new, must_evict in script:
        sess = KnnSession(spec)
        sess.ingest_objects(world)
        sess.register_queries(q)
        sess.submit().result()
        sess.update_objects(mover, new)
        want = sess.submit().result()
        world[mover] = new
        t.update_objects(mover, new)
        st = srv.submit()
        res = st.result()
        ii, dd, _ = st.result_for(h)
        np.testing.assert_array_equal(ii, want.nn_idx, err_msg=str(new))
        np.testing.assert_array_equal(dd, want.nn_dist, err_msg=str(new))
        assert res.rows_computed == (1 if must_evict else 0), (new, res)


def test_spatial_exact_kth_distance_tie_evicts():
    """Motion to EXACTLY the k-th distance flips the canonical selection:
    ties break to the lowest id, so a low-id mover landing at d2 == kth2
    displaces the high-id incumbent.  The inclusive <= ball boundary is
    what catches it — an exclusive stab would serve a stale row."""
    pts = np.array(
        [[1001.0, 1000.0],    # id 0, d2 = 1
         [20000.0, 20000.0],  # id 1: the mover, starts far away
         [1002.0, 1000.0],    # id 2, d2 = 4
         [1003.0, 1000.0],    # id 3, d2 = 9
         [1000.0, 1004.0],    # id 4, d2 = 16 — the incumbent k-th
         [21000.0, 21000.0],
         [20000.0, 21000.0],
         [21000.0, 20500.0]], np.float32)
    q = np.array([[1000.0, 1000.0]], np.float32)
    spec = _spec()
    srv = KnnServer(spec, invalidation="spatial")
    srv.ingest_objects(pts)
    t = srv.admit("a")
    h = t.register_queries(q)
    st0 = srv.submit()
    st0.result()
    i0, _, _ = st0.result_for(h)
    assert 4 in i0[0] and 1 not in i0[0]
    # id 1 moves to d2 EXACTLY 16 (= the cached kth2): tie with id 4,
    # lowest id wins -> membership flips even though no distance shrank
    new = np.array([[996.0, 1000.0]], np.float32)
    t.update_objects(np.array([1], np.int32), new)
    st1 = srv.submit()
    res = st1.result()
    assert res.rows_computed == 1, res  # the boundary stab evicted
    i1, d1, _ = st1.result_for(h)
    world = pts.copy()
    world[1] = new
    sess = KnnSession(spec)
    sess.ingest_objects(world)
    sess.register_queries(q)
    want = sess.submit().result()
    np.testing.assert_array_equal(i1, want.nn_idx)
    np.testing.assert_array_equal(d1, want.nn_dist)
    assert 1 in i1[0] and 4 not in i1[0]


def test_spatial_mover_is_excluded_qid():
    """A mover that is some query's excluded qid: its motion cannot change
    that query's rows (it is excluded by definition), the conservative stab
    may still evict — either way the served bits must equal recomputation."""
    pts, q = _ball_world()
    spec = _spec()
    qid = np.array([4], np.int32)  # the mover IS this query's exclusion
    srv = KnnServer(spec, invalidation="spatial")
    srv.ingest_objects(pts)
    t = srv.admit("a")
    h = t.register_queries(q, qid)
    st0 = srv.submit()
    st0.result()
    i0, d0, _ = st0.result_for(h)
    # id 4 jumps INTO the ball: the stab evicts (conservative), but the
    # recomputed rows are identical — id 4 is excluded from its own list
    new = np.array([[1000.0, 1001.0]], np.float32)
    r0, r1 = _one_delta_solo(spec, pts, q, qid, np.array([4], np.int32), new)
    t.update_objects(np.array([4], np.int32), new)
    st1 = srv.submit()
    st1.result()
    i1, d1, _ = st1.result_for(h)
    np.testing.assert_array_equal(i1, r1.nn_idx)
    np.testing.assert_array_equal(d1, r1.nn_dist)
    np.testing.assert_array_equal(i1, i0)  # exclusion: rows truly unchanged
    np.testing.assert_array_equal(d1, d0)


def test_spatial_negative_zero_geometry_keys():
    """-0.0 and 0.0 are distinct cache keys (bit-pattern keying) with the
    same geometry: both survive unrelated motion as separate entries and
    both serve bitwise-correct rows."""
    rng = np.random.default_rng(81)
    pts = rng.uniform(10000, SIDE, (64, 2)).astype(np.float32)
    q = np.array([[0.0, 5.0], [-0.0, 5.0]], np.float32)
    assert q[0].tobytes() != q[1].tobytes()
    spec = _spec()
    srv = KnnServer(spec, invalidation="spatial")
    srv.ingest_objects(pts)
    t = srv.admit("a")
    h = t.register_queries(q)
    r0 = srv.submit().result()
    assert r0.rows_unique == 2 and len(srv.cache) == 2
    ids = np.array([0], np.int32)
    new = rng.uniform(10000, SIDE, (1, 2)).astype(np.float32)
    t.update_objects(ids, new)
    st = srv.submit()
    res = st.result()
    assert res.rows_computed == 0 and len(srv.cache) == 2, res
    ii, dd, _ = st.result_for(h)
    world = pts.copy()
    world[0] = new
    sess = KnnSession(spec)
    sess.ingest_objects(world)
    sess.register_queries(q)
    want = sess.submit().result()
    np.testing.assert_array_equal(ii, want.nn_idx)
    np.testing.assert_array_equal(dd, want.nn_dist)


def test_spatial_stab_budget_falls_back_to_epoch_clear():
    """Deltas over stab_budget rows give up on stabbing: full epoch clear
    (reason tagged stab-budget), then normal recompute with correct bits."""
    rng = np.random.default_rng(82)
    pts = rng.uniform(0, SIDE, (64, 2)).astype(np.float32)
    q = rng.uniform(0, SIDE, (4, 2)).astype(np.float32)
    spec = _spec()
    srv = KnnServer(spec, invalidation="spatial", stab_budget=4)
    srv.ingest_objects(pts)
    t = srv.admit("a")
    h = t.register_queries(q)
    srv.submit().result()
    assert len(srv.cache) == 4
    e0 = srv.cache.epoch
    ids = rng.choice(64, 8, replace=False).astype(np.int32)  # 8 > budget 4
    new = rng.uniform(0, SIDE, (8, 2)).astype(np.float32)
    t.update_objects(ids, new)
    assert srv.cache.last_invalidation == "stab-budget:a"
    assert srv.cache.epoch == e0 + 1 and len(srv.cache) == 0
    st = srv.submit()
    res = st.result()
    assert res.rows_computed == res.rows_unique and res.cache_hit_rows == 0
    ii, dd, _ = st.result_for(h)
    world = pts.copy()
    world[ids] = new
    sess = KnnSession(spec)
    sess.ingest_objects(world)
    sess.register_queries(q)
    want = sess.submit().result()
    np.testing.assert_array_equal(ii, want.nn_idx)
    np.testing.assert_array_equal(dd, want.nn_dist)


def test_rebuilt_tick_inserts_survive_in_both_modes():
    """The rebuild-cliff fix: a drift-rebuilt tick's own fresh results are
    inserted (the insert guard keys on the world-mutation counter, which
    rebuilds don't touch), so the next no-motion tick replays fully from
    the cache — in BOTH invalidation modes.  Before the fix the epoch guard
    silently dropped those inserts every rebuild."""
    n = 2000
    rng = np.random.default_rng(83)
    uniform = rng.uniform(0, SIDE, (n, 2)).astype(np.float32)
    clustered = (rng.normal(0, 60, (n, 2)) + 11_250).astype(
        np.float32).clip(0, SIDE - 1)
    spec = _spec(k=8, th_quad=32, l_max=6, window=64, chunk=512,
                 rebuild_factor=1.5)
    for mode in ("epoch", "spatial"):
        srv = KnnServer(spec, invalidation=mode)
        srv.ingest_objects(uniform)
        a = srv.admit("alice")
        ha = a.register_queries(uniform[:64], np.arange(64, dtype=np.int32))
        srv.submit().result()
        srv.submit().result()  # work-at-build anchor
        a.update_objects(np.arange(n, dtype=np.int32), clustered)
        r_drift = srv.submit().result()
        assert r_drift.rebuilt, mode
        assert len(srv.cache) > 0, mode  # the rebuilt tick's own inserts
        r_next = srv.submit().result()  # no motion since
        assert r_next.rows_computed == 0 and r_next.cache_hit_rows > 0, (
            mode, r_next)
        st = srv.submit()
        ii, dd, _ = st.result_for(ha)
        sess = KnnSession(spec)
        sess.ingest_objects(uniform)
        sess.register_queries(uniform[:64], np.arange(64, dtype=np.int32))
        sess.submit().result()
        sess.submit().result()
        sess.update_objects(np.arange(n, dtype=np.int32), clustered)
        want = sess.submit().result()
        assert want.rebuilt
        np.testing.assert_array_equal(ii, want.nn_idx, err_msg=mode)
        np.testing.assert_array_equal(dd, want.nn_dist, err_msg=mode)


# ------------------------------------------- latency accounting + handles


def test_server_tick_wall_s_excludes_host_idle():
    """wall_s = submit_s + drain_s + assemble_s, all >= 0 — host idle
    between submit() and a lazy result() must not inflate the tick's
    latency (it used to: wall_s was measured submit-to-materialize)."""
    import time as _time

    srv = KnnServer(_spec())
    srv.ingest_objects(_world(128, seed=90))
    t = srv.admit("a")
    t.register_queries(_world(8, 91))
    srv.submit().result()  # warm the compile cache
    st = srv.submit()
    _time.sleep(0.3)  # host idle the old accounting charged to the tick
    res = st.result()
    assert res.compile_s == 0.0
    assert res.wall_s < 0.25, res.wall_s
    assert res.submit_s >= 0 and res.drain_s >= 0 and res.assemble_s >= 0
    assert res.wall_s == res.submit_s + res.drain_s + res.assemble_s


def test_tick_handle_public_finalized_rebuilt_post():
    """The server's drift observation runs on TickHandle's public
    read-only properties, not session privates."""
    sess = KnnSession(_spec())
    sess.ingest_objects(_world(64, seed=92))
    sess.register_queries(_world(4, 93))
    h = sess.submit()
    assert h.finalized is False  # not finalized until result/next submit
    assert h.rebuilt_post is False
    h.result()
    assert h.finalized is True
    assert h.rebuilt_post is False  # no drift in a static world
    with pytest.raises(AttributeError):
        h.finalized = True
    with pytest.raises(AttributeError):
        h.rebuilt_post = True


# ------------------------------------------------------- collect="stats"

def test_collect_stats_dedup_without_cache():
    """Under collect="stats" the cache is disabled (lists never reach the
    host) but intra-tick dedup still shares device work, and result_for
    returns device rows matching the full-collect bits."""
    pts = _world(96, seed=60)
    q = _world(6, seed=61)
    srv = KnnServer(_spec(collect="stats"))
    assert not srv.cache.enabled
    srv.ingest_objects(pts)
    a, b = srv.admit("alice"), srv.admit("bob")
    ha, hb = a.register_queries(q), b.register_queries(q)
    st = srv.submit()
    res = st.result()
    assert res.rows_total == 12 and res.rows_computed == 6
    assert res.dedup_hit_rows == 6 and res.cache_hit_rows == 0
    ii, dd, _ = st.result_for(hb)  # device arrays (jnp gather path)
    full = KnnServer(_spec(collect="full"))
    full.ingest_objects(pts)
    hf = full.admit("x").register_queries(q)
    fi, fd, _ = full.submit().result_for(hf)
    np.testing.assert_array_equal(np.asarray(ii), fi)
    np.testing.assert_array_equal(np.asarray(dd), fd)
    # next tick recomputes (no cache under stats) but stays deduped
    r2 = srv.submit().result()
    assert r2.rows_computed == 6 and r2.cache_hit_rows == 0


def test_result_for_errors():
    srv = KnnServer(_spec())
    srv.ingest_objects(_world(64, seed=70))
    with pytest.raises(RuntimeError, match="no registered tenant queries"):
        srv.submit()
    a = srv.admit("alice")
    h = a.register_queries(_world(3, 71))
    a.drop_queries(h)
    b = srv.admit("bob")
    hb = b.register_queries(_world(3, 72))
    st = srv.submit()
    with pytest.raises(KeyError, match="owned no rows"):
        st.result_for(h)  # dropped before submit
    with pytest.raises(KeyError, match="belongs to tenant"):
        a.drop_queries(hb)
    st.result_for(hb)


# --------------------------------------- forced 8-device mesh (real XLA)

def test_server_solo_parity_on_8_devices():
    """3 tenants through one server on a real 8-device grid == solo sessions,
    bitwise, for the mesh plans under cost_balanced — with a delta tick and a
    cache-replay tick in the script, in BOTH invalidation modes — plus the
    spatial acceptance pin: localized churn keeps a disjoint hotspot served
    entirely from cache on the delta tick.  Subprocess because the device
    count must be set before jax init."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax
assert jax.device_count() == 8, jax.device_count()
from repro.api import KnnSession, ServiceSpec
from repro.serve import KnnServer

SIDE = 22_500.0
rng = np.random.default_rng(0)
pts = rng.uniform(0, SIDE, (512, 2)).astype(np.float32)
shared = rng.uniform(0, SIDE, (8, 2)).astype(np.float32)
tq = [np.concatenate([shared, rng.uniform(0, SIDE, (8, 2)).astype(np.float32)])
      for _ in range(3)]
ids = rng.choice(512, 32, replace=False).astype(np.int32)
new = rng.uniform(0, SIDE, (32, 2)).astype(np.float32)

# localized-churn world: hotspot queries in one corner, all movers far away
pts2 = rng.uniform(0, SIDE, (512, 2)).astype(np.float32)
far_ids = np.arange(400, 432, dtype=np.int32)
pts2[far_ids] = rng.uniform(20000, 22000, (32, 2)).astype(np.float32)
far_new = rng.uniform(20000, 22000, (32, 2)).astype(np.float32)
hotq = rng.uniform(0, 800, (8, 2)).astype(np.float32)

for plan, mesh in (("sharded", 8), ("hybrid", (2, 4))):
    spec = ServiceSpec(k=4, th_quad=8, l_max=5, window=16, chunk=32,
                       side=SIDE, plan=plan, mesh_shape=mesh,
                       partitioner="cost_balanced")
    want_all = []
    for i in range(3):
        sess = KnnSession(spec)
        sess.ingest_objects(pts)
        sess.register_queries(tq[i])
        want = [sess.submit().result()]
        sess.update_objects(ids, new)
        want.append(sess.submit().result())
        want_all.append(want)
    for mode in ("epoch", "spatial"):
        srv = KnnServer(spec, invalidation=mode)
        srv.ingest_objects(pts)
        tenants = [srv.admit(f"t{i}") for i in range(3)]
        handles = [t.register_queries(tq[i]) for i, t in enumerate(tenants)]
        got = []
        for t in range(3):
            if t == 2:
                tenants[1].update_objects(ids, new)
            st = srv.submit()
            res = st.result()
            if t == 1:
                assert res.rows_computed == 0, (plan, mode, res)  # replay
            got.append([st.result_for(h) for h in handles])
        for i in range(3):
            want = want_all[i]
            for srv_t, solo_t in ((0, 0), (1, 0), (2, 1)):
                np.testing.assert_array_equal(
                    got[srv_t][i][0], want[solo_t].nn_idx,
                    err_msg=f"{plan}/{mode}/t{i}")
                np.testing.assert_array_equal(
                    got[srv_t][i][1], want[solo_t].nn_dist,
                    err_msg=f"{plan}/{mode}/t{i}")

    # spatial acceptance: the delta tick serves the hotspot 100% from cache
    # (epoch mode would recompute every row), bits equal to recomputation
    srv = KnnServer(spec, invalidation="spatial")
    srv.ingest_objects(pts2)
    hot = srv.admit("hot")
    hh = hot.register_queries(hotq)
    srv.submit().result()
    hot.update_objects(far_ids, far_new)
    st = srv.submit()
    res = st.result()
    assert res.rows_computed == 0 and res.cache_hit_rows > 0, (plan, res)
    assert srv.cache.last_invalidation == "delta-stab:hot", plan
    ii, dd, _ = st.result_for(hh)
    world2 = pts2.copy()
    world2[far_ids] = far_new
    sess = KnnSession(spec)
    sess.ingest_objects(world2)
    sess.register_queries(hotq)
    cold = sess.submit().result()
    np.testing.assert_array_equal(ii, cold.nn_idx, err_msg=plan)
    np.testing.assert_array_equal(dd, cold.nn_dist, err_msg=plan)
print("SERVE_8DEV_OK")
"""
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)  # the child pins its own device count
    r = subprocess.run(
        [sys.executable, "-c", code], env=env, capture_output=True, text=True
    )
    assert r.returncode == 0, (r.stdout[-1000:], r.stderr[-3000:])
    assert "SERVE_8DEV_OK" in r.stdout
