"""PR-quadtree invariants (paper Sec. 4.1)."""
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # offline container: deterministic fallback shim
    from repro.testing import given, settings, strategies as st

from repro.core import build_index, leaf_of_points, reindex_objects
from repro.core.quadtree import ball_stab_mask, pyramid_offset


def _index(pts, l_max=5, th=8):
    return build_index(jnp.asarray(pts, jnp.float32), jnp.zeros(2), 1000.0, l_max=l_max, th_quad=th)


def _leaves(idx):
    """Enumerate leaves as (key, level, span) by walking fine cells."""
    ll = np.asarray(idx.leaf_level)
    n_fine = len(ll)
    leaves = []
    c = 0
    while c < n_fine:
        lvl = ll[c]
        span = 4 ** (idx.l_max - lvl)
        leaves.append((c, int(lvl), int(span)))
        c += span
    return leaves


pointsets = st.lists(
    st.tuples(st.floats(0, 999.9), st.floats(0, 999.9)), min_size=1, max_size=300
)


@settings(max_examples=25, deadline=None)
@given(pointsets, st.integers(2, 6), st.integers(2, 32))
def test_leaves_partition_domain_and_objects(points, l_max, th):
    idx = _index(points, l_max, th)
    leaves = _leaves(idx)
    # leaves tile [0, 4^l_max) exactly
    assert sum(s for _, _, s in leaves) == 4**idx.l_max
    starts = np.asarray(idx.starts)
    # leaf object intervals partition the sorted object array
    total = 0
    for key, lvl, span in leaves:
        cnt = starts[key + span] - starts[key]
        total += cnt
        # PR-quadtree split invariant: leaf count <= th unless at l_max
        if lvl < idx.l_max:
            assert cnt <= th, (key, lvl, cnt)
    assert total == len(points)


@settings(max_examples=25, deadline=None)
@given(pointsets)
def test_leaf_alignment_and_zmap(points):
    idx = _index(points)
    for key, lvl, span in _leaves(idx):
        assert key % span == 0  # aligned (Morton total order, paper Fig. 2)
    # z_map lookup: every point's leaf contains its fine cell
    key, lvl = leaf_of_points(idx, jnp.asarray(points, jnp.float32))
    ll = np.asarray(idx.leaf_level)
    from repro.core import morton

    fine = np.asarray(
        morton.morton_encode_points(jnp.asarray(points, jnp.float32), idx.origin, idx.side, idx.l_max)
    )
    for i in range(len(points)):
        span = 4 ** (idx.l_max - int(lvl[i]))
        assert int(key[i]) <= fine[i] < int(key[i]) + span
        assert ll[fine[i]] == int(lvl[i])


def test_pyramid_consistency():
    rng = np.random.default_rng(0)
    pts = rng.uniform(0, 1000, (500, 2)).astype(np.float32)
    idx = _index(pts, l_max=4, th=16)
    pyr = np.asarray(idx.pyramid)
    for l in range(idx.l_max):
        cur = pyr[pyramid_offset(l) : pyramid_offset(l) + 4**l]
        nxt = pyr[pyramid_offset(l + 1) : pyramid_offset(l + 1) + 4 ** (l + 1)]
        np.testing.assert_array_equal(cur, nxt.reshape(-1, 4).sum(1))
    assert pyr[0] == 500  # root holds everything


def test_reindex_keeps_partition_updates_objects():
    rng = np.random.default_rng(1)
    pts = rng.uniform(0, 1000, (400, 2)).astype(np.float32)
    idx = _index(pts, l_max=4, th=16)
    ll_before = np.asarray(idx.leaf_level).copy()
    pts2 = pts + rng.normal(0, 5, pts.shape).astype(np.float32)
    pts2 = np.clip(pts2, 0, 999.9)
    idx2 = reindex_objects(idx, jnp.asarray(pts2))
    # stage (i) partition unchanged; stage (ii) object store refreshed
    np.testing.assert_array_equal(ll_before, np.asarray(idx2.leaf_level))
    assert np.asarray(idx2.pyramid)[0] == 400
    # sorted by fine code
    codes = np.asarray(idx2.codes)
    assert (np.diff(codes) >= 0).all()


# ---------------------------------------------- ball_stab_mask (serve cache)


def _exact_stab(centers, kth2, moved):
    """Reference O(E*M) closed-ball stab in f64 (margin-free)."""
    c = np.asarray(centers, np.float64)
    m = np.asarray(moved, np.float64)
    d2 = ((c[:, None, :] - m[None, :, :]) ** 2).sum(axis=2)
    return (d2 <= np.asarray(kth2, np.float64)[:, None]).any(axis=1)


def test_ball_stab_exact_path_inclusive_boundary():
    centers = np.array([[100.0, 100.0], [900.0, 900.0]], np.float32)
    kth2 = np.array([25.0, 4.0], np.float64)
    moved = np.array([[105.0, 100.0],  # distance EXACTLY 5 from entry 0
                      [500.0, 500.0]], np.float32)
    got = ball_stab_mask(centers, kth2, moved,
                         origin=(0.0, 0.0), side=1000.0, l_max=5)
    np.testing.assert_array_equal(got, [True, False])
    # a hair outside the (margin-widened) boundary does not stab
    moved2 = np.array([[105.1, 100.0]], np.float32)
    got2 = ball_stab_mask(centers, kth2, moved2,
                          origin=(0.0, 0.0), side=1000.0, l_max=5)
    np.testing.assert_array_equal(got2, [False, False])


def test_ball_stab_zero_radius_needs_bitwise_equal_position():
    centers = np.array([[100.0, 100.0]], np.float32)
    kth2 = np.array([0.0], np.float64)
    same = np.array([[100.0, 100.0]], np.float32)
    near = np.array([[100.0 + 2.0**-10, 100.0]], np.float32)
    assert ball_stab_mask(centers, kth2, same,
                          origin=(0.0, 0.0), side=1000.0, l_max=5)[0]
    assert not ball_stab_mask(centers, kth2, near,
                              origin=(0.0, 0.0), side=1000.0, l_max=5)[0]


def test_ball_stab_nonfinite_geometry_always_stabs():
    centers = np.array([[np.nan, 5.0], [5.0, 5.0], [5.0, 5.0], [5.0, 5.0]],
                       np.float32)
    kth2 = np.array([1.0, np.nan, np.inf, 1.0], np.float64)
    far = np.array([[900.0, 900.0]], np.float32)
    got = ball_stab_mask(centers, kth2, far,
                         origin=(0.0, 0.0), side=1000.0, l_max=5)
    # NaN center, NaN radius, inf radius (under-full query) all evict;
    # the one well-formed ball survives far motion
    np.testing.assert_array_equal(got, [True, True, True, False])
    # ...and non-finite geometry stabs even with NO movement to blame
    got0 = ball_stab_mask(centers, kth2, np.empty((0, 2), np.float32),
                          origin=(0.0, 0.0), side=1000.0, l_max=5)
    np.testing.assert_array_equal(got0, [True, True, True, False])


def test_ball_stab_empty_entries():
    got = ball_stab_mask(np.empty((0, 2), np.float32), np.empty((0,)),
                         np.array([[1.0, 1.0]], np.float32),
                         origin=(0.0, 0.0), side=1000.0, l_max=5)
    assert got.shape == (0,)


def test_ball_stab_pyramid_path_covers_exact():
    """The coarse Morton-pyramid regime (moved > exact_rows) must be a
    superset of the exact stab — cell granularity and boundary clipping
    may add evictions, never drop one — including out-of-region movers."""
    rng = np.random.default_rng(7)
    for trial in range(25):
        E = int(rng.integers(1, 40))
        M = int(rng.integers(9, 120))  # > exact_rows=8 forces the pyramid
        centers = rng.uniform(0, 1000, (E, 2)).astype(np.float32)
        r = rng.uniform(0, 200, (E,))
        kth2 = (r ** 2).astype(np.float64)
        moved = rng.uniform(-100, 1100, (M, 2)).astype(np.float32)
        coarse = ball_stab_mask(centers, kth2, moved, origin=(0.0, 0.0),
                                side=1000.0, l_max=5, exact_rows=8)
        exact = _exact_stab(centers, kth2, moved)
        assert not (exact & ~coarse).any(), (trial, "coarse dropped a stab")


def test_ball_stab_pyramid_path_keeps_disjoint_entries():
    """Coarseness is bounded: movers confined to one corner leave a
    far-corner ball alone even on the pyramid path."""
    centers = np.array([[900.0, 900.0]], np.float32)
    kth2 = np.array([100.0], np.float64)  # radius 10 ball at (900, 900)
    rng = np.random.default_rng(8)
    moved = rng.uniform(0, 100, (64, 2)).astype(np.float32)
    got = ball_stab_mask(centers, kth2, moved, origin=(0.0, 0.0),
                         side=1000.0, l_max=5, exact_rows=8)
    assert not got[0]
