"""PR-quadtree invariants (paper Sec. 4.1)."""
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # offline container: deterministic fallback shim
    from repro.testing import given, settings, strategies as st

from repro.core import build_index, leaf_of_points, reindex_objects
from repro.core.quadtree import pyramid_offset


def _index(pts, l_max=5, th=8):
    return build_index(jnp.asarray(pts, jnp.float32), jnp.zeros(2), 1000.0, l_max=l_max, th_quad=th)


def _leaves(idx):
    """Enumerate leaves as (key, level, span) by walking fine cells."""
    ll = np.asarray(idx.leaf_level)
    n_fine = len(ll)
    leaves = []
    c = 0
    while c < n_fine:
        lvl = ll[c]
        span = 4 ** (idx.l_max - lvl)
        leaves.append((c, int(lvl), int(span)))
        c += span
    return leaves


pointsets = st.lists(
    st.tuples(st.floats(0, 999.9), st.floats(0, 999.9)), min_size=1, max_size=300
)


@settings(max_examples=25, deadline=None)
@given(pointsets, st.integers(2, 6), st.integers(2, 32))
def test_leaves_partition_domain_and_objects(points, l_max, th):
    idx = _index(points, l_max, th)
    leaves = _leaves(idx)
    # leaves tile [0, 4^l_max) exactly
    assert sum(s for _, _, s in leaves) == 4**idx.l_max
    starts = np.asarray(idx.starts)
    # leaf object intervals partition the sorted object array
    total = 0
    for key, lvl, span in leaves:
        cnt = starts[key + span] - starts[key]
        total += cnt
        # PR-quadtree split invariant: leaf count <= th unless at l_max
        if lvl < idx.l_max:
            assert cnt <= th, (key, lvl, cnt)
    assert total == len(points)


@settings(max_examples=25, deadline=None)
@given(pointsets)
def test_leaf_alignment_and_zmap(points):
    idx = _index(points)
    for key, lvl, span in _leaves(idx):
        assert key % span == 0  # aligned (Morton total order, paper Fig. 2)
    # z_map lookup: every point's leaf contains its fine cell
    key, lvl = leaf_of_points(idx, jnp.asarray(points, jnp.float32))
    ll = np.asarray(idx.leaf_level)
    from repro.core import morton

    fine = np.asarray(
        morton.morton_encode_points(jnp.asarray(points, jnp.float32), idx.origin, idx.side, idx.l_max)
    )
    for i in range(len(points)):
        span = 4 ** (idx.l_max - int(lvl[i]))
        assert int(key[i]) <= fine[i] < int(key[i]) + span
        assert ll[fine[i]] == int(lvl[i])


def test_pyramid_consistency():
    rng = np.random.default_rng(0)
    pts = rng.uniform(0, 1000, (500, 2)).astype(np.float32)
    idx = _index(pts, l_max=4, th=16)
    pyr = np.asarray(idx.pyramid)
    for l in range(idx.l_max):
        cur = pyr[pyramid_offset(l) : pyramid_offset(l) + 4**l]
        nxt = pyr[pyramid_offset(l + 1) : pyramid_offset(l + 1) + 4 ** (l + 1)]
        np.testing.assert_array_equal(cur, nxt.reshape(-1, 4).sum(1))
    assert pyr[0] == 500  # root holds everything


def test_reindex_keeps_partition_updates_objects():
    rng = np.random.default_rng(1)
    pts = rng.uniform(0, 1000, (400, 2)).astype(np.float32)
    idx = _index(pts, l_max=4, th=16)
    ll_before = np.asarray(idx.leaf_level).copy()
    pts2 = pts + rng.normal(0, 5, pts.shape).astype(np.float32)
    pts2 = np.clip(pts2, 0, 999.9)
    idx2 = reindex_objects(idx, jnp.asarray(pts2))
    # stage (i) partition unchanged; stage (ii) object store refreshed
    np.testing.assert_array_equal(ll_before, np.asarray(idx2.leaf_level))
    assert np.asarray(idx2.pyramid)[0] == 400
    # sorted by fine code
    codes = np.asarray(idx2.codes)
    assert (np.diff(codes) >= 0).all()
