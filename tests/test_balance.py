"""Partitioner seam: boundaries, per-shard counters, cost_balanced ≡ equal.

The acceptance contract of the cost-balanced partitioning refactor
(DESIGN.md §13): partitioners only move chunk/slice boundaries, so

  * ``cost_balanced`` results are bit-identical to ``equal`` (and hence to
    the ``single`` plan) across the full plan × backend matrix;
  * the new per-shard candidate counters sum to the existing global
    ``stats.candidates`` bitwise — the global IS defined as their sum;
  * on a skewed (Zipf) workload over a real 8-device mesh, ``cost_balanced``
    reduces the straggler gap (max/mean per-shard candidates) vs ``equal``
    on the query-sharded plan.

Runs on however many devices exist; the subprocess tests force an 8-device
host grid regardless of the outer environment.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    CostBalancedPartitioner,
    EqualPartitioner,
    ShardedPlan,
    available_backends,
    available_partitioners,
    build_index,
    knn_query_batch_chunked,
    partitioner_names,
    resolve_partitioner,
    resolve_plan,
    straggler_gap,
)
from repro.core.balance import balanced_boundaries, equal_boundaries
from repro.data import make_workload

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
NDEV = jax.device_count()


# ---------------------------------------------------------------- registry

def test_partitioner_registry_names():
    assert set(partitioner_names()) == {"equal", "cost_balanced"}
    assert available_partitioners() == partitioner_names()


def test_resolve_partitioner():
    assert resolve_partitioner(None) == EqualPartitioner()
    assert resolve_partitioner("equal") == EqualPartitioner()
    cb = resolve_partitioner("cost_balanced")
    assert isinstance(cb, CostBalancedPartitioner)
    assert resolve_partitioner(cb) is cb
    with pytest.raises(ValueError, match="unknown partitioner"):
        resolve_partitioner("nope")


def test_cost_balanced_knob_validation():
    with pytest.raises(ValueError, match="slack"):
        CostBalancedPartitioner(slack=0.5)
    with pytest.raises(ValueError, match="ema_alpha"):
        CostBalancedPartitioner(ema_alpha=0.0)
    with pytest.raises(ValueError, match="ema_alpha"):
        CostBalancedPartitioner(ema_alpha=1.5)


def test_plans_carry_partitioner():
    """resolve_plan threads the partitioner into every mesh plan; the
    EngineConfig/ServiceSpec name knob rejects unknown partitioners."""
    from repro.api import ServiceSpec
    from repro.core import EngineConfig

    for name in ("sharded", "object_sharded", "hybrid"):
        p = resolve_plan(name, num_devices=(1, 1) if name == "hybrid" else 1,
                         partitioner="cost_balanced")
        assert isinstance(p.partitioner, CostBalancedPartitioner), name
        assert "cost_balanced" in p.describe()
        q = resolve_plan(name, num_devices=(1, 1) if name == "hybrid" else 1)
        assert q.partitioner == EqualPartitioner()
    with pytest.raises(ValueError, match="unknown partitioner"):
        EngineConfig(partitioner="nope")
    with pytest.raises(ValueError, match="unknown partitioner"):
        ServiceSpec(partitioner="nope")
    assert ServiceSpec(partitioner="cost_balanced").engine_config().partitioner \
        == "cost_balanced"


# -------------------------------------------------------------- boundaries

def test_equal_boundaries_match_capacity_rule():
    b = np.asarray(equal_boundaries(32, 8))
    np.testing.assert_array_equal(b, np.arange(9) * 4)
    # uneven: last shard short, coverage exact
    b = np.asarray(equal_boundaries(10, 4))
    assert b[0] == 0 and b[-1] == 10
    assert (np.diff(b) <= 3).all() and (np.diff(b) >= 0).all()


@pytest.mark.parametrize("n,r,skew", [(32, 8, 8.0), (100, 4, 3.0),
                                      (7, 8, 5.0), (64, 3, 1.0)])
def test_balanced_boundaries_invariants(n, r, skew):
    """Monotone, full coverage, capacity respected, feasible for n < R."""
    rng = np.random.default_rng(n * 31 + r)
    costs = jnp.asarray(rng.pareto(1.5, n).astype(np.float32) * skew + 1.0)
    cap = CostBalancedPartitioner().query_capacity(n, r)
    b = np.asarray(balanced_boundaries(costs, r, cap))
    assert b.shape == (r + 1,)
    assert b[0] == 0 and b[-1] == n
    assert (np.diff(b) >= 0).all()
    assert (np.diff(b) <= cap).all()


def test_balanced_boundaries_reduce_max_shard_cost():
    """On a hotspot cost vector the balanced split's max shard cost is
    strictly below the equal split's (the whole point of the seam)."""
    costs = np.ones(32, np.float32)
    costs[:4] = 100.0  # hotspot in the first equal shard
    r = 8
    cap = CostBalancedPartitioner().query_capacity(32, r)
    bb = np.asarray(balanced_boundaries(jnp.asarray(costs), r, cap))
    be = np.asarray(equal_boundaries(32, r))

    def max_shard(b):
        return max(costs[b[i]:b[i + 1]].sum() for i in range(r))

    assert max_shard(bb) < max_shard(be)
    # infeasible capacity is rejected eagerly
    with pytest.raises(ValueError, match="infeasible"):
        balanced_boundaries(jnp.asarray(costs), 8, 3)


def test_balanced_boundaries_uniform_costs_are_equalish():
    b = np.asarray(balanced_boundaries(jnp.ones(40, jnp.float32), 4,
                                       CostBalancedPartitioner()
                                       .query_capacity(40, 4)))
    np.testing.assert_array_equal(b, [0, 10, 20, 30, 40])


# ------------------------------------------- per-shard counters + parity

def _zipf_case(n=512, nq=128, seed=3):
    pts = make_workload(n, "zipf", seed=seed, zipf_a=1.8,
                        hotspot_sigma_frac=0.003).positions()
    rng = np.random.default_rng(seed)
    qsel = rng.choice(n, nq, replace=False)
    idx = build_index(jnp.asarray(pts), jnp.zeros(2), 22_500.0,
                      l_max=6, th_quad=16)
    return idx, pts[qsel], qsel.astype(np.int32)


@pytest.mark.parametrize("plan,mesh", [
    ("single", None), ("sharded", NDEV), ("object_sharded", NDEV),
    ("hybrid", None),
])
@pytest.mark.parametrize("partitioner", ["equal", "cost_balanced"])
def test_shard_counters_sum_to_global(plan, mesh, partitioner):
    """aux.shard_candidates sums to stats.candidates bitwise, and the
    counter vector has one entry per mesh device."""
    idx, qpos, qid = _zipf_case()
    _, _, stats, aux = knn_query_batch_chunked(
        idx, qpos, qid, k=6, window=32, chunk=32, plan=plan,
        num_devices=mesh, partitioner=partitioner, with_aux=True)
    p = resolve_plan(plan, num_devices=mesh)
    if plan == "single":
        expect_r = 1
    elif plan == "hybrid":
        expect_r = p.query_devices * p.object_devices
    else:
        expect_r = p.num_devices
    assert aux.shard_candidates.shape == (expect_r,)
    assert aux.shard_iterations.shape == (expect_r,)
    assert np.float32(aux.shard_candidates.sum()) == np.float32(
        stats.candidates)
    assert int(aux.shard_iterations.sum()) == int(stats.iterations)
    # object boundaries cover the object array exactly
    assert aux.object_bounds[0] == 0
    assert aux.object_bounds[-1] == idx.n_objects
    assert (np.diff(aux.object_bounds) >= 0).all()


def test_cost_balanced_bitwise_equal_full_matrix():
    """cost_balanced ≡ equal, bitwise, for every backend × mesh plan (the
    satellite pin; the property harness fuzzes the same contract)."""
    idx, qpos, qid = _zipf_case()
    for backend in available_backends():
        for plan, mesh in (("sharded", NDEV), ("object_sharded", NDEV),
                           ("hybrid", None)):
            a_i, a_d, _ = knn_query_batch_chunked(
                idx, qpos, qid, k=6, window=32, chunk=32, backend=backend,
                plan=plan, num_devices=mesh, partitioner="equal")
            b_i, b_d, _ = knn_query_batch_chunked(
                idx, qpos, qid, k=6, window=32, chunk=32, backend=backend,
                plan=plan, num_devices=mesh, partitioner="cost_balanced")
            np.testing.assert_array_equal(a_i, b_i,
                                          err_msg=f"{backend}/{plan}")
            np.testing.assert_array_equal(a_d, b_d,
                                          err_msg=f"{backend}/{plan}")


def test_equal_partitioner_plan_equality():
    """The default-constructed plan IS the equal-partitioner plan (jit cache
    keys and registry defaults agree)."""
    assert ShardedPlan(num_devices=2) == ShardedPlan(
        num_devices=2, partitioner=EqualPartitioner())
    assert ShardedPlan(num_devices=2) != ShardedPlan(
        num_devices=2, partitioner=CostBalancedPartitioner())


# ------------------------------------------- tenant-fair boundary weights

def test_tenant_fair_weights_sum_to_one_per_tenant():
    """Each tenant's rows carry 1/count, so every tenant's total influence
    on the boundary seed is exactly 1.0 regardless of its query volume."""
    from repro.core.balance import tenant_fair_weights

    tid = np.array([0, 0, 0, 0, 1, 2, 2], np.int64)
    w = np.asarray(tenant_fair_weights(tid))
    assert w.dtype == np.float32 and w.shape == (7,)
    np.testing.assert_allclose(w, [0.25] * 4 + [1.0] + [0.5] * 2)
    for t in (0, 1, 2):
        np.testing.assert_allclose(w[tid == t].sum(), 1.0, rtol=1e-6)
    # non-contiguous / unordered ids work; empty input is empty
    w2 = np.asarray(tenant_fair_weights([7, -3, 7]))
    np.testing.assert_allclose(w2, [0.5, 1.0, 0.5])
    assert tenant_fair_weights([]).shape == (0,)


def test_query_cost_weights_validation_and_bit_identity():
    """set_query_cost_weights validates eagerly (length, positivity) and —
    because weights scale the boundary seed only — cannot change bits on
    the cost-balanced plans even under wildly skewed weights."""
    from repro.api import KnnSession, ServiceSpec

    def run(weights_fn, plan, mesh):
        spec = ServiceSpec(k=4, th_quad=16, l_max=5, window=32, chunk=32,
                           plan=plan, mesh_shape=mesh,
                           partitioner="cost_balanced")
        sess = KnnSession(spec)
        w = make_workload(300, "zipf", seed=13, zipf_a=1.6)
        sess.ingest_objects(w.positions())
        h = sess.register_queries(w.positions(),
                                  np.arange(300, dtype=np.int32))
        rng = np.random.default_rng(5)
        out = []
        for _ in range(3):
            if weights_fn is not None:
                sess.set_query_cost_weights(weights_fn(rng))
            out.append(sess.submit().result())
            w.advance()
            sess.update_objects(np.arange(300), w.positions())
            sess.update_queries(h, w.positions())
        return out

    skewed = lambda rng: rng.pareto(1.2, 300).astype(np.float32) + 1e-3
    for plan, mesh in (("sharded", NDEV), ("object_sharded", NDEV),
                       ("hybrid", None)):
        a, b = run(None, plan, mesh), run(skewed, plan, mesh)
        for ra, rb in zip(a, b):
            np.testing.assert_array_equal(ra.nn_idx, rb.nn_idx, err_msg=plan)
            np.testing.assert_array_equal(ra.nn_dist, rb.nn_dist,
                                          err_msg=plan)

    from repro.api import ServiceSpec as SS
    sess = KnnSession(SS(k=4, th_quad=16, l_max=5, window=32, chunk=32))
    sess.ingest_objects(make_workload(64, "uniform", seed=0).positions())
    sess.register_queries(np.zeros((4, 2), np.float32))
    with pytest.raises(ValueError, match="4-row registry"):
        sess.set_query_cost_weights(np.ones(3, np.float32))
    with pytest.raises(ValueError, match="finite and > 0"):
        sess.set_query_cost_weights(np.array([1, 0, 1, 1], np.float32))
    with pytest.raises(ValueError, match="finite and > 0"):
        sess.set_query_cost_weights(np.array([1, np.inf, 1, 1], np.float32))
    sess.set_query_cost_weights(np.ones(4, np.float32))
    sess.submit().result()
    # weights must be re-set after a row-set change (validated at submit)
    sess.register_queries(np.ones((2, 2), np.float32))
    with pytest.raises(RuntimeError, match="row set changed"):
        sess.submit()
    sess.set_query_cost_weights(None)
    sess.submit().result()


# ------------------------------------------------------- session EMA loop

def test_session_qcost_ema_persists_and_resets():
    """The per-query cost EMA warms after one tick, persists across ticks
    and drift rebuilds, and resets when the registry's row set changes."""
    from repro.api import KnnSession, ServiceSpec

    spec = ServiceSpec(k=4, th_quad=16, l_max=5, window=32, chunk=32,
                       plan="sharded", mesh_shape=NDEV,
                       partitioner="cost_balanced", rebuild_factor=1.2)
    sess = KnnSession(spec)
    w = make_workload(400, "zipf", seed=7, zipf_a=1.6)
    sess.ingest_objects(w.positions())
    h = sess.register_queries(w.positions(), np.arange(400, dtype=np.int32))
    assert sess._qcost is None
    sess.submit().result()
    warm = np.asarray(sess._qcost)
    assert warm.shape[0] >= 400 and (warm[:400] > 0).all()
    # persists across ticks (and any drift rebuild triggered by motion)
    for _ in range(3):
        w.advance()
        sess.update_objects(np.arange(400), w.positions())
        sess.update_queries(h, w.positions())
        sess.submit().result()
    assert (np.asarray(sess._qcost)[:400] > 0).all()
    # row-set change invalidates the row alignment -> reset
    sess.register_queries(w.positions()[:8])
    sess.submit().result()
    assert sess._qcost is not None  # re-seeded by the tick just run
    sess.drop_queries(h)
    assert sess._registry.rows_changed


def test_session_results_identical_across_partitioners_over_ticks():
    """A moving zipf workload served tick-for-tick: cost_balanced sessions
    return the same bits as equal ones while re-cutting boundaries from the
    measured-work EMA every tick."""
    from repro.api import KnnSession, ServiceSpec

    def run(partitioner, plan, mesh):
        spec = ServiceSpec(k=4, th_quad=16, l_max=5, window=32, chunk=32,
                           plan=plan, mesh_shape=mesh,
                           partitioner=partitioner)
        sess = KnnSession(spec)
        w = make_workload(300, "hotspot_cluster", seed=11, clusters=3)
        sess.ingest_objects(w.positions())
        h = sess.register_queries(w.positions(),
                                  np.arange(300, dtype=np.int32))
        out = []
        for _ in range(3):
            out.append(sess.submit().result())
            w.advance()
            sess.update_objects(np.arange(300), w.positions())
            sess.update_queries(h, w.positions())
        return out

    for plan, mesh in (("sharded", NDEV), ("object_sharded", NDEV)):
        a, b = run("equal", plan, mesh), run("cost_balanced", plan, mesh)
        for ra, rb in zip(a, b):
            np.testing.assert_array_equal(ra.nn_idx, rb.nn_idx,
                                          err_msg=plan)
            np.testing.assert_array_equal(ra.nn_dist, rb.nn_dist,
                                          err_msg=plan)
            assert ra.rebuilt == rb.rebuilt


# ------------------------------------- forced 8-device mesh (real XLA)

def test_partitioner_parity_and_straggler_gap_on_8_devices():
    """On a real 8-device grid with a Zipf hotspot: every plan × partitioner
    matches the single plan bitwise, per-shard counters sum to the global,
    and cost_balanced tightens the straggler gap on the query-sharded plan
    (the acceptance criterion of DESIGN.md §13).

    Runs in a subprocess because the device count must be set before jax
    init.
    """
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax, jax.numpy as jnp
assert jax.device_count() == 8, jax.device_count()
from repro.core import build_index, knn_query_batch_chunked, straggler_gap
from repro.data import make_workload

pts = make_workload(2048, "zipf", seed=0, zipf_a=1.6,
                    hotspot_sigma_frac=0.002).positions()
qid = np.arange(2048, dtype=np.int32)
idx = build_index(jnp.asarray(pts), jnp.zeros(2), 22500.0, l_max=6, th_quad=24)
a_i, a_d, _ = knn_query_batch_chunked(idx, pts, qid, k=8, window=32, chunk=32,
                                      plan="single")
gaps = {}
for plan, mesh in (("sharded", 8), ("object_sharded", 8), ("hybrid", (2, 4))):
    for part in ("equal", "cost_balanced"):
        b_i, b_d, st, aux = knn_query_batch_chunked(
            idx, pts, qid, k=8, window=32, chunk=32, plan=plan,
            num_devices=mesh, partitioner=part, with_aux=True)
        np.testing.assert_array_equal(a_i, b_i, err_msg=f"{plan}/{part}")
        np.testing.assert_array_equal(a_d, b_d, err_msg=f"{plan}/{part}")
        assert np.float32(aux.shard_candidates.sum()) == np.float32(
            st.candidates), (plan, part)
        assert aux.shard_candidates.shape == (8,)
        gaps[(plan, part)] = straggler_gap(aux.shard_candidates)
assert gaps[("sharded", "cost_balanced")] < gaps[("sharded", "equal")], gaps
print("BALANCE_8DEV_OK", gaps)
"""
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)  # the child pins its own device count
    r = subprocess.run(
        [sys.executable, "-c", code], env=env, capture_output=True, text=True
    )
    assert r.returncode == 0, (r.stdout[-1000:], r.stderr[-3000:])
    assert "BALANCE_8DEV_OK" in r.stdout
