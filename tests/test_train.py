"""Training substrate: optimizer, checkpoint/restart fault tolerance, compression."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.train import (
    OptConfig,
    adamw_update,
    clip_by_global_norm,
    crosspod_mean_int8,
    init_error_feedback,
    init_opt,
    latest_step,
    restore_checkpoint,
    restore_latest,
    save_checkpoint,
    shard_map_compat,
)

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_adamw_moves_toward_minimum():
    params = {"w": jnp.asarray([5.0, -3.0])}
    opt = init_opt(params)
    cfg = OptConfig(lr=0.1, weight_decay=0.0, warmup_steps=1)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}  # d/dw w^2
        grads, _ = clip_by_global_norm(grads, cfg.clip_norm)
        params, opt = adamw_update(params, grads, opt, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.1


def test_clip_by_global_norm():
    grads = {"a": jnp.full((10,), 100.0)}
    clipped, gn = clip_by_global_norm(grads, 1.0)
    np.testing.assert_allclose(float(jnp.linalg.norm(clipped["a"])), 1.0, rtol=1e-5)
    assert float(gn) > 100


def test_checkpoint_roundtrip_and_atomicity(tmp_path):
    tree = {
        "params": {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3)},
        "opt": {"m": jnp.ones((2, 3)), "step": jnp.int32(7)},
    }
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, 5, tree)
    save_checkpoint(d, 10, tree)
    assert latest_step(d) == 10
    # a torn write (tmp dir) must not be picked up
    os.makedirs(os.path.join(d, "step_00000099.tmp"))
    assert latest_step(d) == 10
    restored, step = restore_latest(d, tree)
    assert step == 10
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]), np.asarray(tree["params"]["w"]))
    assert int(restored["opt"]["step"]) == 7


def test_restart_determinism_via_launcher(tmp_path):
    """Crash at step 6, resume from ckpt 5, final params == uninterrupted run.

    Exercises the real launcher path (repro.launch.train) end to end.
    """
    env = dict(os.environ, PYTHONPATH=SRC)
    common = [
        sys.executable, "-m", "repro.launch.train", "--arch", "yi_34b", "--smoke",
        "--steps", "10", "--batch", "4", "--seq", "16", "--ckpt-every", "5",
        "--log-every", "100",
    ]
    d1 = str(tmp_path / "a")
    r = subprocess.run(common + ["--ckpt-dir", d1], env=env, capture_output=True, text=True)
    assert r.returncode == 0, r.stderr[-2000:]

    d2 = str(tmp_path / "b")
    r = subprocess.run(
        common + ["--ckpt-dir", d2, "--simulate-failure", "6"],
        env=env, capture_output=True, text=True,
    )
    assert r.returncode == 42  # the simulated crash
    assert latest_step(d2) == 5
    r = subprocess.run(
        common + ["--ckpt-dir", d2, "--resume"], env=env, capture_output=True, text=True
    )
    assert r.returncode == 0, r.stderr[-2000:]
    assert "resumed from step 5" in r.stdout

    a = np.load(os.path.join(d1, "step_00000010", "arrays.npz"))
    b = np.load(os.path.join(d2, "step_00000010", "arrays.npz"))
    assert set(a.files) == set(b.files)
    for k in a.files:
        np.testing.assert_allclose(a[k], b[k], rtol=1e-5, atol=1e-6, err_msg=k)


def test_int8_crosspod_compression_accuracy():
    """int8 all-gather mean over a 1-pod axis == identity within quant error,
    and error feedback carries the residual."""
    mesh = jax.make_mesh((1,), ("pod",))
    grads = {"w": jnp.asarray(np.random.default_rng(0).normal(0, 1, (64,)), jnp.float32)}
    err = init_error_feedback(grads)

    from jax.sharding import PartitionSpec as P

    f = shard_map_compat(
        lambda g, e: crosspod_mean_int8(g, e, "pod"),
        mesh=mesh,
        in_specs=(jax.tree.map(lambda _: P(), grads), jax.tree.map(lambda _: P(), err)),
        out_specs=(jax.tree.map(lambda _: P(), grads), jax.tree.map(lambda _: P(), err)),
        axis_names={"pod"},
        check_vma=False,
    )
    mean, new_err = f(grads, err)
    # quantization error bounded by one step of the scale
    scale = float(jnp.abs(grads["w"]).max()) / 127.0
    np.testing.assert_allclose(np.asarray(mean["w"]), np.asarray(grads["w"]), atol=scale)
    # error feedback holds exactly the residual
    np.testing.assert_allclose(
        np.asarray(new_err["w"]),
        np.asarray(grads["w"] - mean["w"]),
        atol=1e-6,
    )


def test_grad_accumulation_matches_full_batch():
    from repro.configs import get_smoke_config
    from repro.models import init_params
    from repro.train.step import grads_and_loss

    cfg = get_smoke_config("yi_34b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (8, 16)), jnp.int32)}
    l1, g1 = grads_and_loss(params, cfg, batch, accum=1)
    l2, g2 = grads_and_loss(params, cfg, batch, accum=4)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-4)
    flat1 = jax.tree_util.tree_leaves(g1)
    flat2 = jax.tree_util.tree_leaves(g2)
    for a, b in zip(flat1, flat2):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), rtol=2e-3, atol=2e-4
        )
