"""Per-arch smoke tests (reduced configs): one forward/train step on CPU,
asserting output shapes + no NaNs — plus decode-step shape checks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config, list_archs
from repro.models import (
    decode_step,
    encode_memory,
    forward,
    init_decode_state,
    init_params,
    loss_fn,
    seed_decode_state,
)
from repro.train import OptConfig, init_opt, make_train_step

B, S = 2, 16


def _batch(cfg):
    batch = {"tokens": jnp.ones((B, S), jnp.int32)}
    if cfg.family == "encdec":
        batch["frames"] = jnp.full((B, S, cfg.d_model), 0.01, jnp.float32)
    if cfg.family == "vlm":
        batch["img"] = jnp.full((B, cfg.n_img_tokens, cfg.d_model), 0.01, jnp.float32)
    return batch


@pytest.mark.parametrize("arch", list_archs())
def test_smoke_forward(arch):
    cfg = get_smoke_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    logits, aux = forward(params, cfg, _batch(cfg))
    assert logits.shape == (B, S, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", list_archs())
def test_smoke_decode_step(arch):
    cfg = get_smoke_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    st = init_decode_state(cfg, B, 32, mem_len=S)
    if cfg.family == "encdec":
        frames = jnp.full((B, S, cfg.d_model), 0.01, jnp.float32)
        st = seed_decode_state(params, cfg, st, encode_memory(params, cfg, frames))
    if cfg.family == "vlm":
        img = jnp.full((B, cfg.n_img_tokens, cfg.d_model), 0.01, jnp.float32)
        st = seed_decode_state(params, cfg, st, img)
    logits, st2 = decode_step(params, cfg, st, jnp.ones((B, 1), jnp.int32), jnp.int32(0))
    assert logits.shape == (B, 1, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()
    assert jax.tree_util.tree_structure(st) == jax.tree_util.tree_structure(st2)


@pytest.mark.parametrize("arch", ["rwkv6_3b", "granite_moe_3b_a800m", "yi_34b"])
def test_smoke_train_step(arch):
    """One optimizer step runs and produces finite loss + updated params."""
    cfg = get_smoke_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = init_opt(params)
    step = jax.jit(make_train_step(cfg, OptConfig(lr=1e-3, warmup_steps=1)))
    p2, o2, m = step(params, opt, _batch(cfg))
    assert np.isfinite(float(m["loss"]))
    assert int(o2["step"]) == 1
    # params actually moved
    delta = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda a, b: float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).sum()), params, p2),
    )
    assert delta > 0


def test_loss_decreases_tiny_model():
    """A few steps on the synthetic LM task should reduce the loss."""
    from repro.data.lm import LMDataConfig, SyntheticLMData

    cfg = get_smoke_config("yi_34b")
    params = init_params(cfg, jax.random.PRNGKey(1))
    opt = init_opt(params)
    data = SyntheticLMData(LMDataConfig(vocab=cfg.vocab, batch=8, seq_len=32, seed=3))
    step = jax.jit(make_train_step(cfg, OptConfig(lr=3e-3, warmup_steps=2)))
    losses = []
    for i in range(12):
        batch = {k: jnp.asarray(v) for k, v in data.batch_for_step(i).items()}
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.1, losses
