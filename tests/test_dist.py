"""Logical-axis sharding rules + a reduced end-to-end dry-run on fake devices."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.dist import logical_to_spec, use_rules
from repro.launch.mesh import make_local_mesh

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_rules_divisibility_fallback():
    mesh = make_local_mesh(data=1, model=1)
    with use_rules(mesh):
        # dim 24 on a 1-wide axis always divides; use a fake 16 via rules math
        spec = logical_to_spec(("batch", "heads"), (8, 24))
        assert isinstance(spec, P)


def test_rules_dedup_first_binding_wins():
    mesh = make_local_mesh(data=1, model=1)
    with use_rules(mesh, {"expert": "model", "expert_cap": "model", "ff": "model"}):
        spec = logical_to_spec(("expert", "expert_cap", "ff"), (4, 4, 4))
        # only the first gets 'model'; later duplicates are dropped
        assert spec[0] == "model"
        assert spec[1] is None and spec[2] is None


def test_rules_missing_axis_filtered():
    mesh = make_local_mesh(data=1, model=1)  # no 'pod' axis
    with use_rules(mesh):
        spec = logical_to_spec(("batch",), (8,))
        # ('pod','data') filtered to ('data',)
        assert spec[0] == ("data",) or spec[0] == "data"


def test_constrain_noop_outside_mesh():
    from repro.dist import constrain

    x = jnp.ones((4, 4))
    y = constrain(x, ("batch", None))
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_reduced_dryrun_on_fake_devices():
    """8 fake devices, 2x4 mesh, smoke config: lower+compile a sharded train
    step + a decode step, assert collectives appear and memory is sane.

    Runs in a subprocess because the device count must be set before jax init.
    """
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, dataclasses
from repro.configs import get_smoke_config
from repro.dist import use_rules
from repro.launch.specs import abstract_train_state, input_specs, abstract_decode_state, shard_struct
from repro.configs.base import ShapeCell
from repro.train import make_train_step, OptConfig
from repro.models import decode_step
from repro.launch.hlo_stats import collective_stats

mesh = jax.make_mesh((2, 4), ("data", "model"))
cfg = dataclasses.replace(get_smoke_config("qwen3_moe_235b_a22b"), n_experts=8, top_k=2)
shape = ShapeCell("t", 32, 8, "train")
with use_rules(mesh):
    params, opt = abstract_train_state(cfg)
    batch = input_specs(cfg, shape)
    comp = jax.jit(make_train_step(cfg, OptConfig())).lower(params, opt, batch).compile()
    cs = collective_stats(comp.as_text())
    assert cs["total_count"] > 0, "expected collectives in sharded train step"
    dshape = ShapeCell("d", 64, 8, "decode")
    state = abstract_decode_state(cfg, dshape)
    tok = input_specs(cfg, dshape)["tokens"]
    pos = shard_struct((), jnp.int32, ())
    fn = lambda p, st, t, q: decode_step(p, cfg, st, t, q)
    comp2 = jax.jit(fn).lower(params, state, tok, pos).compile()
print("REDUCED_DRYRUN_OK")
"""
    env = dict(os.environ, PYTHONPATH=SRC)
    r = subprocess.run([sys.executable, "-c", code], env=env, capture_output=True, text=True)
    assert r.returncode == 0, (r.stdout[-1000:], r.stderr[-3000:])
    assert "REDUCED_DRYRUN_OK" in r.stdout


def test_crosspod_trainstep_on_fake_devices():
    """shard_map cross-pod step (int8 compression) compiles on a (2,2,2) mesh."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from repro.configs import get_smoke_config
from repro.dist import use_rules
from repro.models import init_params
from repro.train import OptConfig, init_opt, init_error_feedback, make_train_step_crosspod
mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
cfg = get_smoke_config("yi_34b")
with use_rules(mesh):
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = init_opt(params)
    err = init_error_feedback(params)
    step = make_train_step_crosspod(cfg, OptConfig(), mesh, compress=True)
    batch = {"tokens": jnp.ones((8, 16), jnp.int32)}
    p2, o2, e2, m = jax.jit(step)(params, opt, err, batch)
    assert jnp.isfinite(m["loss"]).all()
print("CROSSPOD_OK", float(m["loss"]))
"""
    env = dict(os.environ, PYTHONPATH=SRC)
    r = subprocess.run([sys.executable, "-c", code], env=env, capture_output=True, text=True)
    assert r.returncode == 0, (r.stdout[-1000:], r.stderr[-3000:])
    assert "CROSSPOD_OK" in r.stdout
