"""Property tests for Morton coding (the structural backbone of the index)."""
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # offline container: deterministic fallback shim
    from repro.testing import given, settings, strategies as st

from repro.core import morton

coords = st.integers(min_value=0, max_value=(1 << 15) - 1)


@settings(max_examples=50, deadline=None)
@given(st.lists(st.tuples(coords, coords), min_size=1, max_size=64))
def test_encode_decode_roundtrip(cells):
    cx = jnp.asarray([c[0] for c in cells], jnp.int32)
    cy = jnp.asarray([c[1] for c in cells], jnp.int32)
    z = morton.encode_cells(cx, cy)
    dx, dy = morton.decode_code(z)
    assert (np.asarray(dx) == np.asarray(cx)).all()
    assert (np.asarray(dy) == np.asarray(cy)).all()


@settings(max_examples=30, deadline=None)
@given(st.tuples(coords, coords), st.integers(0, 7))
def test_ancestor_prefix_property(cell, up):
    """z' = z >> 2u is the Morton code of the ancestor u levels up (paper 4.1.1)."""
    cx, cy = cell
    z = morton.encode_cells(jnp.asarray([cx]), jnp.asarray([cy]))
    zu = z >> (2 * up)
    ax, ay = morton.decode_code(zu)
    assert int(ax[0]) == cx >> up
    assert int(ay[0]) == cy >> up


@settings(max_examples=30, deadline=None)
@given(
    st.lists(
        st.tuples(st.floats(0, 999.99), st.floats(0, 999.99)),
        min_size=2,
        max_size=64,
    ),
    st.integers(2, 8),
)
def test_same_cell_same_code(points, level):
    """Points in the same grid cell share a code; codes respect cell identity."""
    pts = jnp.asarray(points, jnp.float32)
    origin = jnp.zeros(2)
    z = morton.morton_encode_points(pts, origin, 1000.0, level)
    n = 1 << level
    cell = np.floor(np.asarray(pts) / 1000.0 * n).clip(0, n - 1).astype(int)
    for i in range(len(points)):
        for j in range(len(points)):
            same_cell = (cell[i] == cell[j]).all()
            assert (int(z[i]) == int(z[j])) == bool(same_cell)


def test_block_box_and_distance():
    origin = jnp.zeros(2)
    side = 1024.0
    l_max = 5  # 32x32 fine cells of 32u
    # block (code 0, a=1) covers fine cells 0..3 = 2x2 cells = [0,64)^2
    x0, y0, x1, y1 = morton.block_box(jnp.asarray([0]), jnp.asarray([1]), origin, side, l_max)
    assert float(x0[0]) == 0 and float(y0[0]) == 0
    assert float(x1[0]) == 64.0 and float(y1[0]) == 64.0
    # distance from inside is 0; from (100, 32) it's 36 in x
    d2 = morton.point_to_block_dist2(
        jnp.asarray([100.0]), jnp.asarray([32.0]), jnp.asarray([0]), jnp.asarray([1]),
        origin, side, l_max,
    )
    np.testing.assert_allclose(float(d2[0]), 36.0**2, rtol=1e-6)
