"""Backend parity: every registered SCAN backend == the sequential CPU oracle.

The executor contract (DESIGN.md §6): all backends return identical neighbor
sets up to k-th-distance ties, on easy *and* adversarial inputs — skewed
(Gaussian-cluster) distributions, duplicate positions (distance ties), and
``n_objects < k`` padding rows.  The oracle is ``core/cpu_ref.py``'s kd-tree
(the paper's K-NN_CPU competitor), deliberately a different algorithm family
from both the pipeline and the brute-force jnp baseline.

Also pins the serving-layer contract introduced by the device-resident tick
refactor: ``TickEngine.process_tick`` never routes through the host-side
chunk loop.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    EngineConfig,
    KDTree,
    QueryExecutor,
    TickEngine,
    available_backends,
    build_index,
    knn_query_batch,
    knn_query_batch_chunked,
)
from repro.data import make_workload

BACKENDS = available_backends()


def _assert_matches_kdtree(pts, qpos, qid, k, *, backend, l_max=6, th=24,
                           window=32, side=22_500.0, chunk=None):
    idx = build_index(jnp.asarray(pts), jnp.zeros(2), side, l_max=l_max, th_quad=th)
    if chunk is None:
        ii, dd, _ = knn_query_batch(
            idx, jnp.asarray(qpos), jnp.asarray(qid), k=k, window=window,
            backend=backend,
        )
        ii, dd = np.asarray(ii), np.asarray(dd)
    else:
        ii, dd, _ = knn_query_batch_chunked(
            idx, qpos, qid, k=k, window=window, chunk=chunk, backend=backend
        )
    tree = KDTree(pts)
    ri, rd = tree.query_batch(qpos, k, qid=qid)
    # distances must agree exactly as multisets per row (ties make ids ambiguous)
    np.testing.assert_allclose(dd, rd, rtol=1e-5, atol=1e-3)
    # where the distance is strictly below the k-th, the id sets must agree
    for r in range(len(qpos)):
        kth = rd[r, k - 1]
        want = set(ri[r][rd[r] < kth * (1 - 1e-6)]) - {-1}
        got = set(ii[r][dd[r] < kth * (1 - 1e-6)]) - {-1}
        assert want == got, (r, want, got)
    return ii, dd


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("hotspots", [2, 25])
def test_backend_parity_gaussian_skew(backend, hotspots):
    """Skewed hotspot clusters: deep tree regions + long scan intervals."""
    w = make_workload(1200, "gaussian", seed=5, hotspots=hotspots)
    pts = w.positions()
    qpos, qid = w.query_batch()
    _assert_matches_kdtree(pts, qpos, qid, 8, backend=backend)


@pytest.mark.parametrize("backend", BACKENDS)
def test_backend_parity_chunked_driver(backend):
    """The lax.map chunked driver agrees with the oracle across chunks."""
    w = make_workload(900, "gaussian", seed=9, hotspots=3)
    pts = w.positions()
    qpos, qid = w.query_batch()
    _assert_matches_kdtree(pts, qpos, qid, 8, backend=backend, chunk=256)


@pytest.mark.parametrize("backend", BACKENDS)
def test_backend_parity_duplicate_positions(backend):
    """Stacked duplicates => massive distance ties (the bucket kernel's worst
    case: k-th element on a histogram bucket edge)."""
    rng = np.random.default_rng(17)
    base = rng.uniform(0, 22_500, (80, 2)).astype(np.float32)
    pts = np.repeat(base, 6, axis=0)  # every position 6 times
    rng.shuffle(pts)
    qid = np.arange(len(pts), dtype=np.int32)
    _assert_matches_kdtree(pts, pts, qid, 10, backend=backend, th=8)


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("n", [1, 3, 7])
def test_backend_parity_fewer_objects_than_k(backend, n):
    """n_objects < k: rows must pad with (-1, inf) identically everywhere."""
    rng = np.random.default_rng(n)
    pts = rng.uniform(0, 22_500, (n, 2)).astype(np.float32)
    qid = np.arange(n, dtype=np.int32)
    k = 8
    idx = build_index(jnp.asarray(pts), jnp.zeros(2), 22_500.0, l_max=4, th_quad=4)
    ii, dd, _ = knn_query_batch(
        idx, jnp.asarray(pts), jnp.asarray(qid), k=k, window=16, backend=backend
    )
    ii, dd = np.asarray(ii), np.asarray(dd)
    # each query sees the other n-1 objects, then padding
    assert np.isfinite(dd[:, : n - 1]).all()
    assert np.isinf(dd[:, n - 1 :]).all()
    assert (ii[:, n - 1 :] == -1).all()


def test_unknown_backend_rejected():
    with pytest.raises(ValueError, match="unknown scan backend"):
        QueryExecutor(backend="nope")


def test_engine_never_uses_host_chunk_loop(monkeypatch):
    """The acceptance contract of the device-resident tick refactor: one fused
    jitted call per tick — the host-side chunk loop must be unreachable from
    ``process_tick``."""
    import repro.core.pipeline as pipeline
    import repro.core.ticks as ticks

    def boom(*a, **k):  # pragma: no cover - would fail the test if reached
        raise AssertionError("host chunk loop used inside process_tick")

    monkeypatch.setattr(pipeline, "knn_query_batch_chunked", boom)
    monkeypatch.setattr(pipeline, "knn_query_batch", boom)

    eng = TickEngine(EngineConfig(k=4, th_quad=16, l_max=5, window=32, chunk=256))
    w = make_workload(600, "uniform", seed=1)
    results = eng.run(w, ticks=2)
    assert len(results) == 2
    assert results[0].nn_dist.shape == (600, 4)
    assert np.isfinite(results[1].nn_dist).all()


@pytest.mark.parametrize("backend", BACKENDS)
def test_engine_backend_config_parity(backend):
    """EngineConfig.backend threads through to identical tick results."""
    w = make_workload(700, "gaussian", seed=2, hotspots=4)
    pts = w.positions()
    qpos, qid = w.query_batch()
    eng = TickEngine(
        EngineConfig(k=6, th_quad=16, l_max=5, window=32, chunk=256, backend=backend)
    )
    res = eng.process_tick(pts, qpos, qid)
    tree = KDTree(pts)
    _, rd = tree.query_batch(qpos, 6, qid=qid)
    np.testing.assert_allclose(res.nn_dist, rd, rtol=1e-5, atol=1e-3)
