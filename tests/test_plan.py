"""ExecutionPlan seam: sharded == single bit-for-bit, padding, registry.

The acceptance contract of the mesh-sharded refactor (DESIGN.md §10): the
``sharded`` plan — replicated index, ``shard_map`` query shards, concatenating
gather — must produce **bit-identical** ids and distances to the ``single``
plan on the same inputs, because every shard boundary coincides with a chunk
boundary of the single plan's sweep.  Runs on however many devices exist
(CI runs the suite twice: 1 real CPU device and 8 forced host devices); the
subprocess test additionally pins an 8-device mesh regardless of the outer
environment.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    EngineConfig,
    HybridPlan,
    ObjectShardedPlan,
    ShardedPlan,
    SinglePlan,
    TickEngine,
    available_plans,
    build_index,
    knn_bruteforce_chunked,
    knn_query_batch_chunked,
    resolve_plan,
)
from repro.data import make_workload

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
NDEV = jax.device_count()


# ------------------------------------------------------------------ registry

def test_plan_registry_names():
    assert set(available_plans()) == {
        "single", "sharded", "object_sharded", "hybrid"
    }


def test_unknown_plan_rejected():
    with pytest.raises(ValueError, match="unknown execution plan"):
        resolve_plan("nope")


def test_executor_resolve_plan_is_plan_resolve_plan():
    """core.executor re-exports the canonical plan resolver as the SAME
    function object (a documented alias, not a divergent wrapper)."""
    from repro.core import executor as executor_mod
    from repro.core import plan as plan_mod

    assert executor_mod.resolve_plan is plan_mod.resolve_plan
    for name in ("single", "sharded"):
        a = executor_mod.resolve_plan(name, num_devices=1)
        b = plan_mod.resolve_plan(name, num_devices=1)
        assert a == b
    assert executor_mod.resolve_plan(None) == plan_mod.resolve_plan(None)


def test_resolve_plan_defaults():
    assert resolve_plan(None) == SinglePlan()
    assert resolve_plan("single") == SinglePlan()
    p = resolve_plan("sharded")
    assert isinstance(p, ShardedPlan) and p.num_devices == NDEV
    assert resolve_plan("sharded", num_devices=1) == ShardedPlan(num_devices=1)
    assert resolve_plan(p) is p
    o = resolve_plan("object_sharded")
    assert isinstance(o, ObjectShardedPlan) and o.num_devices == NDEV
    assert o.object_axis_size == NDEV and o.merge == "dense_merge"
    h = resolve_plan("hybrid")
    assert isinstance(h, HybridPlan)
    assert h.query_devices * h.object_devices == NDEV
    assert h.query_devices <= h.object_devices  # balanced factorization
    assert resolve_plan("hybrid", num_devices=(1, 1)) == HybridPlan(1, 1)
    # 1-D plans reject 2-D mesh shapes, hybrid rejects malformed tuples
    with pytest.raises(ValueError, match="1-D mesh"):
        resolve_plan("sharded", num_devices=(2, 2))
    with pytest.raises(ValueError, match="1-D mesh"):
        resolve_plan("object_sharded", num_devices=(2, 2))
    with pytest.raises(ValueError, match="query, object"):
        resolve_plan("hybrid", num_devices=(2, 2, 2))


def test_resolve_plan_merge_axis():
    """The MERGE backend rides the same resolution seam as the partitioner:
    named object-axis plans pick it up; plans without an object axis ignore
    it; unknown names fail eagerly at the registry."""
    o = resolve_plan("object_sharded", merge="fused_multi")
    assert isinstance(o, ObjectShardedPlan) and o.merge == "fused_multi"
    h = resolve_plan("hybrid", merge="fused_multi")
    assert isinstance(h, HybridPlan) and h.merge == "fused_multi"
    assert resolve_plan("object_sharded").merge == "dense_merge"
    # query-axis-only plans have no merge reduce: the knob is ignored
    assert resolve_plan("single", merge="fused_multi") == SinglePlan()
    assert resolve_plan("sharded", merge="fused_multi") == ShardedPlan(
        num_devices=NDEV)
    with pytest.raises(ValueError, match="unknown merge backend"):
        knn_query_batch_chunked(
            _tiny_index(), np.zeros((4, 2), np.float32), None,
            k=2, chunk=4, plan="object_sharded", num_devices=1, merge="nope",
        )


@pytest.mark.parametrize("plan,mesh", [
    ("object_sharded", None),
    ("hybrid", None),
])
def test_fused_multi_merge_plan_parity(plan, mesh):
    """merge="fused_multi" (one multi-way kernel over the concatenated
    per-shard lists — no HBM round-trip between binary-tree rounds) must
    reproduce the dense_merge bits on the object-axis plans: the canonical
    ``(d2, id)`` selection is associative, so a multi-way selection over
    R·k entries equals the binary reduction tree (DESIGN.md §14)."""
    w = make_workload(600, "gaussian", seed=6, hotspots=4)
    pts = w.positions()
    qpos, qid = w.query_batch()
    idx = build_index(jnp.asarray(pts), jnp.zeros(2), 22_500.0, l_max=6,
                      th_quad=24)
    ref_i, ref_d, _ = knn_query_batch_chunked(
        idx, qpos, qid, k=6, window=32, chunk=32, plan="single")
    for merge in ("dense_merge", "fused_multi"):
        ii, dd, _ = knn_query_batch_chunked(
            idx, qpos, qid, k=6, window=32, chunk=32, plan=plan,
            num_devices=mesh, merge=merge)
        np.testing.assert_array_equal(ii, ref_i, err_msg=f"{plan}/{merge}")
        np.testing.assert_array_equal(dd, ref_d, err_msg=f"{plan}/{merge}")


def test_plan_pad_multiples_and_object_axis():
    """Query padding granularity: chunk per query-axis device; the object
    axis never pads queries (the batch is replicated across it)."""
    chunk = 64
    assert SinglePlan().pad_multiple(chunk) == chunk
    assert ShardedPlan(num_devices=4).pad_multiple(chunk) == 4 * chunk
    assert ObjectShardedPlan(num_devices=4).pad_multiple(chunk) == chunk
    assert HybridPlan(2, 4).pad_multiple(chunk) == 2 * chunk
    assert SinglePlan().object_axis_size == 1
    assert ShardedPlan(num_devices=4).object_axis_size == 1
    assert ObjectShardedPlan(num_devices=4).object_axis_size == 4
    assert HybridPlan(2, 4).object_axis_size == 4


def test_sharded_plan_rejects_bad_device_counts():
    with pytest.raises(ValueError):
        ShardedPlan(num_devices=0)
    with pytest.raises(ValueError):
        ObjectShardedPlan(num_devices=0)
    with pytest.raises(ValueError):
        HybridPlan(0, 1)
    with pytest.raises(ValueError, match="devices"):
        # plan constructs, the mesh (built at trace time) rejects the overask
        knn_query_batch_chunked(
            _tiny_index(), np.zeros((4, 2), np.float32), None,
            k=2, chunk=4, plan="sharded", num_devices=NDEV + 1,
        )
    with pytest.raises(ValueError, match="devices"):
        knn_query_batch_chunked(
            _tiny_index(), np.zeros((4, 2), np.float32), None,
            k=2, chunk=4, plan="object_sharded", num_devices=NDEV + 1,
        )


def _tiny_index():
    rng = np.random.default_rng(0)
    pts = rng.uniform(0, 1000, (32, 2)).astype(np.float32)
    return build_index(jnp.asarray(pts), jnp.zeros(2), 1000.0, l_max=4, th_quad=8)


# ------------------------------------------------- determinism across plans

def _both_plans(pts, qpos, qid, *, k, chunk, num_devices):
    idx = build_index(jnp.asarray(pts), jnp.zeros(2), 22_500.0, l_max=6, th_quad=24)
    a_i, a_d, _ = knn_query_batch_chunked(
        idx, qpos, qid, k=k, window=32, chunk=chunk, plan="single"
    )
    b_i, b_d, _ = knn_query_batch_chunked(
        idx, qpos, qid, k=k, window=32, chunk=chunk,
        plan="sharded", num_devices=num_devices,
    )
    return (a_i, a_d), (b_i, b_d)


@pytest.mark.parametrize("dist", ["uniform", "gaussian", "network"])
def test_sharded_bit_identical_to_single(dist):
    """All three workload families: ids AND distances bit-for-bit equal."""
    w = make_workload(700, dist, seed=5)
    pts = w.positions()
    qpos, qid = w.query_batch()
    (a_i, a_d), (b_i, b_d) = _both_plans(
        pts, qpos, qid, k=8, chunk=64, num_devices=NDEV
    )
    np.testing.assert_array_equal(a_i, b_i)
    np.testing.assert_array_equal(a_d, b_d)


def test_sharded_bit_identical_duplicate_ties_and_padding():
    """Duplicate positions (massed distance ties) and n < k inf/-1 padding
    must resolve identically across plans — same per-query op sequence."""
    rng = np.random.default_rng(8)
    base = rng.uniform(0, 22_500, (40, 2)).astype(np.float32)
    pts = np.repeat(base, 4, axis=0)  # every position 4 times -> ties
    rng.shuffle(pts)
    qid = np.arange(len(pts), dtype=np.int32)
    (a_i, a_d), (b_i, b_d) = _both_plans(
        pts, pts, qid, k=6, chunk=32, num_devices=NDEV
    )
    np.testing.assert_array_equal(a_i, b_i)
    np.testing.assert_array_equal(a_d, b_d)
    # n < k: padding rows identical too
    small = rng.uniform(0, 22_500, (3, 2)).astype(np.float32)
    (a_i, a_d), (b_i, b_d) = _both_plans(
        small, small, np.arange(3, dtype=np.int32), k=8, chunk=16,
        num_devices=NDEV,
    )
    np.testing.assert_array_equal(a_i, b_i)
    np.testing.assert_array_equal(a_d, b_d)
    assert (a_i[:, 2:] == -1).all() and np.isinf(a_d[:, 2:]).all()


# ------------------------------------------------------- padding regression

@pytest.mark.parametrize("nq", [1, None])  # None -> num_devices * chunk - 1
def test_sharded_pad_strip_regression(nq):
    """A batch not divisible by num_devices * chunk pads once host-side and
    strips after the gather: Q=1 and Q=num_devices*chunk-1 (the two worst
    cases: maximal padding, and one-row-short of no padding)."""
    chunk = 32
    nq = NDEV * chunk - 1 if nq is None else nq
    rng = np.random.default_rng(nq)
    pts = rng.uniform(0, 22_500, (500, 2)).astype(np.float32)
    qpos = rng.uniform(0, 22_500, (nq, 2)).astype(np.float32)
    idx = build_index(jnp.asarray(pts), jnp.zeros(2), 22_500.0, l_max=5, th_quad=16)
    ii, dd, _ = knn_query_batch_chunked(
        idx, qpos, None, k=4, window=32, chunk=chunk,
        plan="sharded", num_devices=NDEV,
    )
    assert ii.shape == (nq, 4) and dd.shape == (nq, 4)
    bi, bd = knn_bruteforce_chunked(
        pts, qpos, np.full((nq,), -2, np.int32), k=4, chunk=max(nq, 1)
    )
    np.testing.assert_allclose(dd, bd, rtol=1e-5, atol=1e-3)


def test_engine_sharded_pad_strip_q1():
    """The engine path: a single query through the sharded tick step."""
    w = make_workload(400, "uniform", seed=9)
    eng = TickEngine(
        EngineConfig(k=4, th_quad=16, l_max=5, window=32, chunk=32,
                     plan="sharded", mesh_shape=NDEV)
    )
    qpos = w.positions()[:1]
    res = eng.process_tick(w.positions(), qpos, np.array([0], np.int32))
    assert res.nn_idx.shape == (1, 4)
    bi, bd = knn_bruteforce_chunked(
        w.positions(), qpos, np.array([0], np.int32), k=4, chunk=32
    )
    np.testing.assert_allclose(res.nn_dist, bd, rtol=1e-5, atol=1e-3)


# ------------------------------------------------------ engine plan parity

def test_engine_plan_parity_over_ticks():
    """TickEngine under plan=sharded == plan=single, tick for tick, bitwise."""
    def run(plan):
        eng = TickEngine(
            EngineConfig(k=6, th_quad=16, l_max=5, window=32, chunk=64,
                         plan=plan, mesh_shape=NDEV if plan == "sharded" else None)
        )
        w = make_workload(600, "gaussian", seed=2, hotspots=4)
        return eng.run(w, ticks=3)

    single, sharded = run("single"), run("sharded")
    for rs, rh in zip(single, sharded):
        np.testing.assert_array_equal(rs.nn_idx, rh.nn_idx)
        np.testing.assert_array_equal(rs.nn_dist, rh.nn_dist)
        assert rs.rebuilt == rh.rebuilt


@pytest.mark.parametrize("plan,mesh", [
    ("object_sharded", None),   # None -> every visible device
    ("hybrid", None),           # None -> balanced factorization
])
def test_engine_object_plan_parity_over_ticks(plan, mesh):
    """TickEngine under the object-axis plans == plan=single, tick for tick,
    bitwise on results.  (Stats — iterations/candidates — legitimately differ:
    local trees prune differently; the canonical-selection contract makes
    results partition-invariant anyway, see DESIGN.md §12.)"""
    def run(p, m):
        eng = TickEngine(
            EngineConfig(k=6, th_quad=16, l_max=5, window=32, chunk=64,
                         plan=p, mesh_shape=m)
        )
        w = make_workload(600, "gaussian", seed=2, hotspots=4)
        return eng.run(w, ticks=3)

    for rs, rh in zip(run("single", None), run(plan, mesh)):
        np.testing.assert_array_equal(rs.nn_idx, rh.nn_idx)
        np.testing.assert_array_equal(rs.nn_dist, rh.nn_dist)


# -------------------------------------------- forced 8-device mesh (real XLA)

def test_sharded_determinism_on_forced_8_device_mesh():
    """The acceptance criterion on real multi-device XLA: an 8-device CPU grid
    (forced host devices) produces bit-identical results to the single plan on
    all three workload families for EVERY mesh plan — sharded (8-way query),
    object_sharded (8-way object) and hybrid (the 2x4 grid) — engine path
    included.

    Runs in a subprocess because the device count must be set before jax init.
    """
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import warnings
warnings.filterwarnings("ignore", category=DeprecationWarning)
import numpy as np, jax, jax.numpy as jnp
assert jax.device_count() == 8, jax.device_count()
from repro.core import EngineConfig, TickEngine, build_index, knn_query_batch_chunked
from repro.data import make_workload

MESHES = [("sharded", 8), ("object_sharded", 8), ("hybrid", (2, 4))]
for dist in ("uniform", "gaussian", "network"):
    w = make_workload(500, dist, seed=5)
    pts = w.positions(); qpos, qid = w.query_batch()
    idx = build_index(jnp.asarray(pts), jnp.zeros(2), 22500.0, l_max=5, th_quad=24)
    a_i, a_d, _ = knn_query_batch_chunked(idx, qpos, qid, k=6, window=32, chunk=32, plan="single")
    for plan, mesh in MESHES:
        b_i, b_d, _ = knn_query_batch_chunked(idx, qpos, qid, k=6, window=32, chunk=32, plan=plan, num_devices=mesh)
        np.testing.assert_array_equal(a_i, b_i, err_msg=f"{dist}/{plan}")
        np.testing.assert_array_equal(a_d, b_d, err_msg=f"{dist}/{plan}")

w = make_workload(400, "gaussian", seed=3, hotspots=3)
ref = TickEngine(EngineConfig(k=4, th_quad=16, l_max=5, window=32, chunk=32)).run(
    make_workload(400, "gaussian", seed=3, hotspots=3), ticks=2)
for plan, mesh in MESHES:
    eng = TickEngine(EngineConfig(k=4, th_quad=16, l_max=5, window=32, chunk=32, plan=plan, mesh_shape=mesh))
    res = eng.run(make_workload(400, "gaussian", seed=3, hotspots=3), ticks=2)
    assert res[0].nn_dist.shape == (400, 4)
    for r, s in zip(ref, res):
        np.testing.assert_array_equal(r.nn_idx, s.nn_idx, err_msg=plan)
        np.testing.assert_array_equal(r.nn_dist, s.nn_dist, err_msg=plan)
print("SHARDED_8DEV_OK")
"""
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)  # the child pins its own device count
    r = subprocess.run(
        [sys.executable, "-c", code], env=env, capture_output=True, text=True
    )
    assert r.returncode == 0, (r.stdout[-1000:], r.stderr[-3000:])
    assert "SHARDED_8DEV_OK" in r.stdout
