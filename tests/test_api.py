"""Session-oriented serving API (repro.api, DESIGN.md §11).

The acceptance contract of the api_redesign PR: the ``KnnSession`` delta-
update and overlapped-submit paths are **bit-identical** to the snapshot
``TickEngine`` path — same padded batches, same jitted step, same drift
bookkeeping sequence — on all three workload families and under both
execution plans.  Plus: eager ServiceSpec/EngineConfig validation, the
persistent query registry (add/update/drop with stable handles), two-in-
flight TickHandle ordering, the compile_s/wall_s split, and the deprecation-
shim equivalence (TickEngine.run ≡ a blocking KnnSession loop).
"""
import os
import subprocess
import sys
import warnings

import jax
import numpy as np
import pytest

from repro.api import KnnSession, QueryHandle, ServiceSpec
from repro.core import EngineConfig, TickEngine, knn_bruteforce_chunked
from repro.data import make_workload

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
NDEV = jax.device_count()


def _spec(plan="single", **kw):
    base = dict(k=6, th_quad=24, l_max=6, window=32, chunk=64, side=22_500.0,
                plan=plan, mesh_shape=NDEV if plan == "sharded" else None,
                delta_pad=64)
    base.update(kw)
    return ServiceSpec(**base)


def _engine(spec):
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        return TickEngine(spec.engine_config(), origin=spec.origin,
                          side=spec.side)


# ----------------------------------------------------------------- validation

@pytest.mark.parametrize("bad, match", [
    (dict(backend="nope"), r"unknown backend 'nope'.*registered SCAN backends.*dense_topk"),
    (dict(plan="nope"), r"unknown execution plan 'nope'.*registered plans.*single"),
    (dict(chunk=100, window=64), r"chunk \(100\).*multiple of window \(64\)"),
    (dict(k=3000, chunk=2048, window=256), r"k \(3000\).*<= chunk \(2048\)"),
    (dict(mesh_shape=0), r"mesh_shape"),
    (dict(mesh_shape=(2, 4, 8)), r"query, object"),
    (dict(plan="sharded", mesh_shape=(2, 4)), r"1-D mesh"),
    (dict(plan="object_sharded", mesh_shape=(2, 4)), r"1-D mesh"),
    (dict(side=-1.0), r"side"),
    (dict(delta_pad=0), r"delta_pad"),
    (dict(partitioner="nope"), r"unknown partitioner 'nope'.*cost_balanced"),
    (dict(precision="nope"), r"unknown precision 'nope'.*mixed"),
    (dict(merge="nope"), r"unknown merge backend 'nope'.*fused_multi"),
    (dict(collect="nope"), r"unknown collect mode 'nope'.*stats"),
])
def test_service_spec_validates_eagerly(bad, match):
    with pytest.raises(ValueError, match=match):
        ServiceSpec(**bad)


@pytest.mark.parametrize("bad, match", [
    (dict(backend="nope"), r"unknown backend.*registered SCAN backends"),
    (dict(plan="nope"), r"unknown execution plan.*registered plans"),
    (dict(chunk=100, window=64), r"chunk.*multiple of window"),
    (dict(k=3000, chunk=2048, window=256), r"k.*<= chunk"),
])
def test_engine_config_validates_eagerly(bad, match):
    """Bad names used to surface only as a deep registry KeyError on first use."""
    with pytest.raises(ValueError, match=match):
        EngineConfig(**bad)


def test_spec_subsumes_engine_config_roundtrip():
    cfg = EngineConfig(k=8, th_quad=48, l_max=6, window=64, chunk=1024,
                       backend="brute", plan="sharded", mesh_shape=1,
                       precision="mixed", merge="fused_multi")
    spec = ServiceSpec.from_engine(cfg, origin=(1.0, 2.0), side=9_000.0)
    assert spec.engine_config() == cfg
    assert spec.origin == (1.0, 2.0) and spec.side == 9_000.0
    assert spec.precision == "mixed" and spec.merge == "fused_multi"


# ------------------------------------------------- delta-update parity (tent)

def _moved_subset(rng, pts, frac, side=22_500.0):
    m = max(1, int(len(pts) * frac))
    ids = rng.choice(len(pts), m, replace=False).astype(np.int32)
    new = pts.copy()
    new[ids] = np.clip(
        new[ids] + rng.uniform(-180, 180, (m, 2)).astype(np.float32),
        0, side - 1e-3,
    ).astype(np.float32)
    return ids, new


@pytest.mark.parametrize("dist", ["uniform", "gaussian", "network"])
def test_delta_updates_bit_identical_to_snapshot(dist):
    """N scattered updates (applied in several chunks) ≡ the equivalent full
    snapshot through the TickEngine path — ids AND distances bitwise."""
    w = make_workload(700, dist, seed=5)
    pts = w.positions().copy()
    qid = np.arange(len(pts), dtype=np.int32)
    rng = np.random.default_rng(17)

    spec = _spec()
    sess = KnnSession(spec)
    sess.ingest_objects(pts)
    hq = sess.register_queries(pts, qid)
    eng = _engine(spec)

    cur = pts
    for t in range(3):
        if t > 0:
            ids, cur = _moved_subset(rng, cur, frac=0.3)
            # deltas land in three separate scatter calls (accumulation path)
            for part in np.array_split(np.arange(len(ids)), 3):
                sess.update_objects(ids[part], cur[ids[part]])
            sess.update_queries(hq, cur)
        r_s = sess.submit().result()
        r_e = eng.process_tick(cur, cur, qid)
        np.testing.assert_array_equal(r_s.nn_idx, r_e.nn_idx)
        np.testing.assert_array_equal(r_s.nn_dist, r_e.nn_dist)
        assert r_s.rebuilt == r_e.rebuilt
        assert r_s.candidates == r_e.candidates


def test_delta_updates_bit_identical_sharded_plan():
    w = make_workload(500, "gaussian", seed=3, hotspots=4)
    pts = w.positions().copy()
    qid = np.arange(len(pts), dtype=np.int32)
    rng = np.random.default_rng(7)
    spec = _spec(plan="sharded", chunk=32)
    sess = KnnSession(spec)
    sess.ingest_objects(pts)
    hq = sess.register_queries(pts, qid)
    eng = _engine(spec)
    cur = pts
    for t in range(2):
        if t > 0:
            ids, cur = _moved_subset(rng, cur, frac=0.5)
            sess.update_objects(ids, cur[ids])
            sess.update_queries(hq, cur)
        r_s = sess.submit().result()
        r_e = eng.process_tick(cur, cur, qid)
        np.testing.assert_array_equal(r_s.nn_idx, r_e.nn_idx)
        np.testing.assert_array_equal(r_s.nn_dist, r_e.nn_dist)


# ------------------------------------------- delta routing, object-axis plans

def _object_plan_spec(plan):
    mesh = NDEV if plan == "object_sharded" else None  # hybrid: balanced
    return _spec(plan=plan, chunk=32, mesh_shape=mesh)


@pytest.mark.parametrize("plan", ["object_sharded", "hybrid"])
def test_delta_routing_single_shard_batch(plan):
    """Routing edge 1: an update batch whose every moved row is owned by ONE
    object shard — the grouped scatter must stay bit-identical to the
    snapshot engine path (DESIGN.md §12 ownership rule)."""
    w = make_workload(400, "gaussian", seed=11, hotspots=3)
    pts = w.positions().copy()
    qid = np.arange(len(pts), dtype=np.int32)
    spec = _object_plan_spec(plan)
    sess = KnnSession(spec)
    sess.ingest_objects(pts)
    hq = sess.register_queries(pts, qid)
    eng = _engine(spec)
    r_s = sess.submit().result()
    r_e = eng.process_tick(pts, pts, qid)
    np.testing.assert_array_equal(r_s.nn_idx, r_e.nn_idx)

    owners = sess.object_shards(np.arange(len(pts)))
    target = int(owners[0])
    ids = np.nonzero(owners == target)[0].astype(np.int32)
    assert ids.size > 0 and (sess.object_shards(ids) == target).all()
    rng = np.random.default_rng(5)
    cur = pts.copy()
    cur[ids] = np.clip(
        cur[ids] + rng.uniform(-50, 50, (ids.size, 2)).astype(np.float32),
        0, spec.side - 1e-3)
    sess.update_objects(ids, cur[ids])
    sess.update_queries(hq, cur)
    r_s = sess.submit().result()
    r_e = eng.process_tick(cur, cur, qid)
    np.testing.assert_array_equal(r_s.nn_idx, r_e.nn_idx)
    np.testing.assert_array_equal(r_s.nn_dist, r_e.nn_dist)


@pytest.mark.parametrize("plan", ["object_sharded", "hybrid"])
def test_delta_routing_row_crosses_shard_ownership(plan):
    """Routing edge 2: a row whose move changes its owning shard between
    ticks (Morton rank jump across slice boundaries) — ownership is
    re-derived from the live index, results stay bit-identical."""
    w = make_workload(300, "uniform", seed=13)
    pts = w.positions().copy()
    qid = np.arange(len(pts), dtype=np.int32)
    spec = _object_plan_spec(plan)
    sess = KnnSession(spec)
    sess.ingest_objects(pts)
    hq = sess.register_queries(pts, qid)
    eng = _engine(spec)
    sess.submit().result()
    eng.process_tick(pts, pts, qid)

    # the Morton-first object, teleported to the far corner: rank 0 -> n-1
    mover = int(np.asarray(sess.index.ids)[0])
    before = int(sess.object_shards([mover])[0])
    cur = pts.copy()
    cur[mover] = [spec.side - 1.0, spec.side - 1.0]
    sess.update_objects([mover], cur[mover][None])
    sess.update_queries(hq, cur)
    r_s = sess.submit().result()
    r_e = eng.process_tick(cur, cur, qid)
    np.testing.assert_array_equal(r_s.nn_idx, r_e.nn_idx)
    np.testing.assert_array_equal(r_s.nn_dist, r_e.nn_dist)
    after = int(sess.object_shards([mover])[0])
    shards = sess.plan.object_axis_size
    if shards > 1:
        assert before == 0 and after == shards - 1  # ownership crossed


@pytest.mark.parametrize("plan", ["object_sharded", "hybrid"])
def test_delta_routing_empty_delta_tick(plan):
    """Routing edge 3: an empty update batch is a no-op tick — identical
    results to resubmitting unchanged state, and to the snapshot engine."""
    w = make_workload(250, "network", seed=19)
    pts = w.positions().copy()
    qid = np.arange(len(pts), dtype=np.int32)
    spec = _object_plan_spec(plan)
    sess = KnnSession(spec)
    sess.ingest_objects(pts)
    sess.register_queries(pts, qid)
    eng = _engine(spec)
    r0 = sess.submit().result()
    e0 = eng.process_tick(pts, pts, qid)
    sess.update_objects(np.zeros((0,), np.int32), np.zeros((0, 2), np.float32))
    r1 = sess.submit().result()
    e1 = eng.process_tick(pts, pts, qid)
    np.testing.assert_array_equal(r0.nn_idx, r1.nn_idx)
    np.testing.assert_array_equal(r1.nn_idx, e1.nn_idx)
    np.testing.assert_array_equal(r1.nn_dist, e1.nn_dist)
    np.testing.assert_array_equal(r0.nn_idx, e0.nn_idx)


def test_object_shards_ownership_rule():
    """`object_shards` IS the documented rule: Morton rank // ceil(N/R)."""
    w = make_workload(200, "gaussian", seed=23, hotspots=2)
    pts = w.positions()
    spec = _object_plan_spec("object_sharded")
    sess = KnnSession(spec)
    sess.ingest_objects(pts)
    sess.register_queries(pts[:32], np.arange(32, dtype=np.int32))
    if sess.plan.object_axis_size > 1:
        # ownership is defined by the index's Morton order: not built yet
        with pytest.raises(RuntimeError, match="before the first submit"):
            sess.object_shards([0])
    sess.submit().result()
    shards = sess.object_shards(np.arange(len(pts)))
    r = sess.plan.object_axis_size
    assert shards.min() >= 0 and shards.max() < r
    # independent spelling of the rule from the index's Morton order
    order = np.asarray(sess.index.ids)
    rank = np.empty(len(pts), np.int64)
    rank[order] = np.arange(len(pts))
    cap = -(-len(pts) // r)
    np.testing.assert_array_equal(shards, rank // cap)
    # stale/unknown ids raise instead of returning clamped garbage owners
    if r > 1:
        with pytest.raises(ValueError, match="outside the live index"):
            sess.object_shards([len(pts)])
        with pytest.raises(ValueError, match="outside the live index"):
            sess.object_shards([-1])
    # plans without an object axis own everything on shard 0
    s2 = KnnSession(_spec())
    s2.ingest_objects(pts)
    s2.register_queries(pts[:32], np.arange(32, dtype=np.int32))
    s2.submit().result()
    assert (s2.object_shards(np.arange(len(pts))) == 0).all()


# ------------------------------------------------------ query registry (tent)

@pytest.mark.parametrize("plan", ["single", "sharded"])
def test_query_registry_add_drop_across_ticks(plan):
    """Handles persist across ticks; drops compact the registry; the served
    batch always equals the equivalent snapshot batch, bitwise."""
    rng = np.random.default_rng(4)
    pts = rng.uniform(0, 22_500, (600, 2)).astype(np.float32)
    qa = rng.uniform(0, 22_500, (90, 2)).astype(np.float32)
    qb = rng.uniform(0, 22_500, (40, 2)).astype(np.float32)
    qc = rng.uniform(0, 22_500, (25, 2)).astype(np.float32)

    spec = _spec(plan=plan, chunk=32)
    sess = KnnSession(spec)
    sess.ingest_objects(pts)
    ha = sess.register_queries(qa)
    hb = sess.register_queries(qb, np.arange(40, dtype=np.int32))
    assert isinstance(ha, QueryHandle) and ha.count == 90

    def reference(qpos, qid):
        eng = _engine(spec)
        return eng.process_tick(pts, qpos, qid)

    # tick 0: A + B
    r0 = sess.submit().result()
    ref = reference(np.concatenate([qa, qb]),
                    np.concatenate([np.full(90, -2, np.int32),
                                    np.arange(40, dtype=np.int32)]))
    np.testing.assert_array_equal(r0.nn_idx, ref.nn_idx)
    np.testing.assert_array_equal(r0.nn_dist, ref.nn_dist)

    # tick 1: drop A -> only B remains (compacted to the front)
    sess.drop_queries(ha)
    r1 = sess.submit().result()
    ref1 = reference(qb, np.arange(40, dtype=np.int32))
    np.testing.assert_array_equal(r1.nn_idx, ref1.nn_idx)
    np.testing.assert_array_equal(r1.nn_dist, ref1.nn_dist)
    assert r1.nn_idx.shape == (40, spec.k)

    # tick 2: register C -> B + C
    hc = sess.register_queries(qc)
    h2 = sess.submit()
    r2 = h2.result()
    ref2 = reference(np.concatenate([qb, qc]),
                     np.concatenate([np.arange(40, dtype=np.int32),
                                     np.full(25, -2, np.int32)]))
    np.testing.assert_array_equal(r2.nn_idx, ref2.nn_idx)
    np.testing.assert_array_equal(r2.nn_dist, ref2.nn_dist)
    # per-handle result slicing via the ownership snapshot
    ci, cd, cq = h2.result_for(hc)
    np.testing.assert_array_equal(ci, r2.nn_idx[40:])
    np.testing.assert_array_equal(cd, r2.nn_dist[40:])
    assert (cq == -2).all()
    bi, bd, bq = h2.result_for(hb)
    np.testing.assert_array_equal(bi, r2.nn_idx[:40])
    np.testing.assert_array_equal(bq, np.arange(40, dtype=np.int32))

    # dropped handle is dead
    with pytest.raises(KeyError, match="not live"):
        sess.update_queries(ha, qa)


def test_update_queries_moves_only_that_group():
    rng = np.random.default_rng(9)
    pts = rng.uniform(0, 22_500, (400, 2)).astype(np.float32)
    qa = rng.uniform(0, 22_500, (30, 2)).astype(np.float32)
    qb = rng.uniform(0, 22_500, (20, 2)).astype(np.float32)
    spec = _spec()
    sess = KnnSession(spec)
    sess.ingest_objects(pts)
    ha = sess.register_queries(qa)
    hb = sess.register_queries(qb)
    sess.submit().result()
    qa2 = np.clip(qa + 50.0, 0, 22_499).astype(np.float32)
    sess.update_queries(ha, qa2)
    r = sess.submit().result()
    ref = _engine(spec).process_tick(pts, np.concatenate([qa2, qb]), None)
    np.testing.assert_array_equal(r.nn_idx, ref.nn_idx)
    np.testing.assert_array_equal(r.nn_dist, ref.nn_dist)


# --------------------------------------------------- overlapped submit (tent)

def test_two_in_flight_handles_any_collection_order():
    """Submit τ+1 while τ's results are in flight; collect out of order;
    every tick bitwise-equal to the blocking reference loop."""
    w = make_workload(500, "gaussian", seed=2, hotspots=4)
    qid = np.arange(500, dtype=np.int32)
    frames = []
    for _ in range(4):
        frames.append(w.positions().copy())
        w.advance()

    spec = _spec()
    eng = _engine(spec)
    blocking = [eng.process_tick(p, p, qid) for p in frames]

    sess = KnnSession(spec)
    sess.ingest_objects(frames[0])
    hq = sess.register_queries(frames[0], qid)
    handles = [sess.submit()]
    for p in frames[1:]:
        sess.ingest_objects(p)
        sess.update_queries(hq, p)
        handles.append(sess.submit())  # up to 2 unmaterialized in flight
        if len(handles) > 2:
            handles[-3].result()
    # collect the tail out of order
    res = {h.tick: h.result() for h in reversed(handles)}
    assert sorted(res) == [0, 1, 2, 3]
    assert [h.tick for h in handles] == [0, 1, 2, 3]
    for t, ref in enumerate(blocking):
        np.testing.assert_array_equal(res[t].nn_idx, ref.nn_idx)
        np.testing.assert_array_equal(res[t].nn_dist, ref.nn_dist)
        assert res[t].rebuilt == ref.rebuilt
    # result() is idempotent
    assert handles[1].result() is res[1]
    assert handles[0].done()


def test_result_of_finalized_tick_leaves_successor_pending():
    """result(τ) after submit(τ+1) — τ was finalized by the submit — must not
    finalize (and block on) τ+1; τ+1 stays in flight."""
    rng = np.random.default_rng(3)
    pts = rng.uniform(0, 22_500, (200, 2)).astype(np.float32)
    sess = KnnSession(_spec())
    sess.ingest_objects(pts)
    sess.register_queries(pts[:50])
    ha = sess.submit()
    hb = sess.submit()  # finalizes ha's bookkeeping
    ra = ha.result()
    assert len(sess._pending) == 1 and sess._pending[0] is hb
    rb = hb.result()
    assert not sess._pending
    np.testing.assert_array_equal(ra.nn_idx, rb.nn_idx)  # static state


# ------------------------------------------------------- shim equivalence

def test_tick_engine_shim_equivalent_to_session_loop():
    """TickEngine.run ≡ the manual KnnSession loop, tick for tick, bitwise
    (results, rebuilt flags, candidate counters)."""
    cfg = EngineConfig(k=6, th_quad=16, l_max=5, window=32, chunk=64)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        eng = TickEngine(cfg)
    w1 = make_workload(600, "gaussian", seed=2, hotspots=4)
    engine_res = eng.run(w1, ticks=3)

    sess = KnnSession(ServiceSpec.from_engine(cfg))
    w2 = make_workload(600, "gaussian", seed=2, hotspots=4)
    hq = None
    session_res = []
    for _ in range(3):
        qpos, qid = w2.query_batch(1.0)
        sess.ingest_objects(w2.positions())
        if hq is None:
            hq = sess.register_queries(qpos, qid)
        else:
            sess.update_queries(hq, qpos)
        session_res.append(sess.submit().result())
        w2.advance()

    for re_, rs in zip(engine_res, session_res):
        np.testing.assert_array_equal(re_.nn_idx, rs.nn_idx)
        np.testing.assert_array_equal(re_.nn_dist, rs.nn_dist)
        assert re_.rebuilt == rs.rebuilt
        assert re_.candidates == rs.candidates
        assert re_.iterations == rs.iterations


def test_tick_engine_warns_deprecation():
    with pytest.warns(DeprecationWarning, match="KnnSession"):
        TickEngine(EngineConfig(k=4, th_quad=16, l_max=5, window=32, chunk=64))


# ------------------------------------------------------- compile_s split

def test_compile_time_split_from_wall_time():
    """First submit of a new shape records compile_s; steady ticks report 0
    and wall_s excludes the compile entirely."""
    rng = np.random.default_rng(1)
    pts = rng.uniform(0, 22_500, (300, 2)).astype(np.float32)
    # odd geometry -> guaranteed fresh jit cache entry in this process
    sess = KnnSession(_spec(k=5, window=32, chunk=96))
    sess.ingest_objects(pts)
    sess.register_queries(pts[:33])
    r0 = sess.submit().result()
    r1 = sess.submit().result()
    assert r0.compile_s > 0.0
    assert r1.compile_s == 0.0
    assert r0.wall_s >= 0.0 and r1.wall_s >= 0.0
    # the shim surfaces the same split; its tick 1 is the FIRST snapshot
    # re-ingest of this shape, which runs the "rebuild" maintenance mode —
    # a distinct static, hence its own one-time compile (DESIGN.md §15) —
    # so steady state (compile_s == 0) starts at tick 2
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        eng = TickEngine(EngineConfig(k=5, th_quad=24, l_max=6, window=32,
                                      chunk=96))
    e0 = eng.process_tick(pts, pts[:33], None)
    e1 = eng.process_tick(pts, pts[:33], None)
    e2 = eng.process_tick(pts, pts[:33], None)
    assert e0.compile_s >= 0.0 and e1.compile_s >= 0.0
    assert e2.compile_s == 0.0


# ------------------------------------------------------- drift rebuild

def test_drift_rebuild_through_delta_path():
    """Teleporting all objects into one hotspot via update_objects must
    trigger the partition rebuild and stay exact (paper Sec. 4.1.1)."""
    n, k = 3000, 16
    rng = np.random.default_rng(12)
    uniform = rng.uniform(0, 22_500, (n, 2)).astype(np.float32)
    clustered = (rng.normal(0, 60, (n, 2)) + 11_250).astype(np.float32).clip(0, 22_499)
    qid = np.arange(n, dtype=np.int32)

    sess = KnnSession(_spec(k=k, th_quad=32, l_max=6, window=64, chunk=1024,
                            rebuild_factor=1.5))
    sess.ingest_objects(uniform)
    hq = sess.register_queries(uniform, qid)
    r0 = sess.submit().result()
    assert r0.rebuilt  # initial build
    r1 = sess.submit().result()
    assert not r1.rebuilt
    sess.update_objects(np.arange(n, dtype=np.int32), clustered)
    sess.update_queries(hq, clustered)
    r2 = sess.submit().result()
    assert r2.rebuilt, (r2.candidates, r1.candidates)
    bi, bd = knn_bruteforce_chunked(clustered, clustered, qid, k=k, chunk=1024)
    np.testing.assert_allclose(r2.nn_dist, bd, rtol=1e-5, atol=1e-3)


@pytest.mark.parametrize("partitioner", ["equal", "cost_balanced"])
def test_object_shards_fresh_after_drift_rebuild(partitioner):
    """Rebuild-then-route regression (object_sharded): ownership answered
    while a drift-rebuild decision is still pending must reflect the POST-
    rebuild Morton order, not the submitted tick's stale one.

    ``object_shards`` finalizes pending ticks first; the answer must agree
    with an independent spelling of the ownership rule evaluated on
    whatever index is live AFTER the call — which the next tick serves from.
    """
    n = 2000
    rng = np.random.default_rng(21)
    uniform = rng.uniform(0, 22_500, (n, 2)).astype(np.float32)
    clustered = (rng.normal(0, 60, (n, 2)) + 11_250).astype(
        np.float32).clip(0, 22_499)
    qid = np.arange(n, dtype=np.int32)
    sess = KnnSession(_spec(plan="object_sharded", mesh_shape=NDEV,
                            th_quad=32, chunk=512, rebuild_factor=1.5,
                            partitioner=partitioner))
    sess.ingest_objects(uniform)
    hq = sess.register_queries(uniform, qid)
    sess.submit().result()
    sess.submit().result()  # baseline tick (sets the work-at-build anchor)
    sess.update_objects(qid, clustered)
    sess.update_queries(hq, clustered)
    h = sess.submit()  # drift tick: rebuild decision PENDING until finalize
    owners = sess.object_shards(qid)  # must finalize + answer post-rebuild
    if sess.plan.object_axis_size > 1:  # trivial-ownership fast path skips it
        assert h._finalized
    res = h.result()
    assert res.rebuilt  # the teleport really did trigger the rebuild
    # independent spelling of the rule from the live (post-rebuild) index
    order = np.asarray(sess.index.ids)
    rank = np.empty(n, np.int64)
    rank[order] = np.arange(n)
    if sess._obj_bounds is not None:
        bounds = np.asarray(sess._obj_bounds)
        expect = np.searchsorted(bounds, rank, side="right") - 1
    else:
        expect = rank // -(-n // sess.plan.object_axis_size)
    np.testing.assert_array_equal(owners, expect)


def test_result_materialize_false_returns_device_arrays():
    """result(materialize=False) hands back device arrays (no host sync);
    a later result() still materializes numpy, bit-identically."""
    rng = np.random.default_rng(9)
    pts = rng.uniform(0, 22_500, (300, 2)).astype(np.float32)
    sess = KnnSession(_spec())
    sess.ingest_objects(pts)
    sess.register_queries(pts, np.arange(300, dtype=np.int32))
    h = sess.submit()
    dev = h.result(materialize=False)
    assert isinstance(dev.nn_idx, jax.Array) and isinstance(
        dev.nn_dist, jax.Array)
    assert dev.nn_idx.shape == (300, sess.spec.k)
    assert isinstance(dev.shard_candidates, jax.Array)
    # idempotent: same device-result object, no re-slice
    assert h.result(materialize=False) is dev
    host = h.result()
    assert isinstance(host.nn_idx, np.ndarray)
    np.testing.assert_array_equal(np.asarray(dev.nn_idx), host.nn_idx)
    np.testing.assert_array_equal(np.asarray(dev.nn_dist), host.nn_dist)
    assert np.float32(host.shard_candidates.sum()) == np.float32(
        host.candidates)
    assert h.result() is host  # materialized result is cached


def test_update_objects_duplicate_ids_last_wins():
    """Several observations for one object in one delta batch resolve
    deterministically to the LAST one (≡ applying them in order)."""
    rng = np.random.default_rng(6)
    pts = rng.uniform(0, 22_500, (300, 2)).astype(np.float32)
    spec = _spec()
    sess = KnnSession(spec)
    sess.ingest_objects(pts)
    sess.register_queries(pts, np.arange(300, dtype=np.int32))
    sess.submit().result()
    ids = np.array([7, 7, 12, 7, 12], np.int32)
    upd = rng.uniform(0, 22_500, (5, 2)).astype(np.float32)
    sess.update_objects(ids, upd)
    expect = pts.copy()
    expect[7], expect[12] = upd[3], upd[4]  # last observation per id
    r = sess.submit().result()
    ref = _engine(spec)
    ref.process_tick(pts, pts, np.arange(300, dtype=np.int32))
    ref_r = ref.process_tick(expect, pts, np.arange(300, dtype=np.int32))
    np.testing.assert_array_equal(r.nn_idx, ref_r.nn_idx)
    np.testing.assert_array_equal(r.nn_dist, ref_r.nn_dist)


# --------------------------------- on-device result consumers (DESIGN.md §14)

def test_collect_stats_aggregates_match_full_results():
    """collect="stats": nn lists never cross the host boundary; the sink's
    aggregates agree with what the full lists imply — k-th distances
    bitwise, zero drift/churn on a static workload, shard hit total = Q*k,
    first tick churn = 1 (no previous observation)."""
    rng = np.random.default_rng(31)
    pts = rng.uniform(0, 22_500, (500, 2)).astype(np.float32)
    q = rng.uniform(0, 22_500, (64, 2)).astype(np.float32)

    full = KnnSession(_spec())
    full.ingest_objects(pts)
    full.register_queries(q)
    f0 = full.submit().result()
    f1 = full.submit().result()

    sess = KnnSession(_spec(collect="stats"))
    sess.ingest_objects(pts)
    sess.register_queries(q)
    r0 = sess.submit().result()
    r1 = sess.submit().result()
    assert r0.nn_idx is None and r0.nn_dist is None
    a0, a1 = r0.aggregates, r1.aggregates
    assert float(a0.churn_mean) == 1.0 and float(a0.churn_max) == 1.0
    assert float(a1.churn_mean) == 0.0 and float(a1.kth_drift_mean) == 0.0
    np.testing.assert_array_equal(
        np.asarray(a1.kth_dist)[:64], f1.nn_dist[:, -1])
    assert int(a1.n_live) == 64
    assert float(np.asarray(a1.shard_hits).sum()) == 64 * sess.spec.k
    # bookkeeping unaffected by the collect mode
    assert r1.candidates == f1.candidates
    assert r1.iterations == f1.iterations
    np.testing.assert_array_equal(r1.shard_candidates, f1.shard_candidates)


@pytest.mark.parametrize("plan", ["object_sharded", "hybrid"])
def test_collect_stats_shard_hits_follow_object_partition(plan):
    """Under the object-axis plans the hit histogram spans the mesh's object
    shards and matches a host-side recount from the full lists + the
    session's own ownership answer."""
    w = make_workload(400, "gaussian", seed=11, hotspots=3)
    pts = w.positions()
    qid = np.arange(64, dtype=np.int32)
    spec = _spec(plan=plan, chunk=32,
                 mesh_shape=NDEV if plan == "object_sharded" else None,
                 collect="stats")
    sess = KnnSession(spec)
    sess.ingest_objects(pts)
    sess.register_queries(pts[:64], qid)
    r = sess.submit().result()
    hits = np.asarray(r.aggregates.shard_hits)
    assert hits.shape == (sess.plan.object_axis_size,)
    assert hits.sum() == 64 * spec.k
    full = KnnSession(_spec(plan=plan, chunk=32, mesh_shape=spec.mesh_shape))
    full.ingest_objects(pts)
    full.register_queries(pts[:64], qid)
    rf = full.submit().result()
    owners = sess.object_shards(rf.nn_idx.reshape(-1))
    np.testing.assert_array_equal(
        hits, np.bincount(owners, minlength=hits.shape[0]))


def test_collect_none_ships_nothing():
    """collect="none": the result record carries only the bookkeeping the
    finalize scalars already paid for — no lists, no counters, no transfer
    time — while the drift-rebuild sequence stays identical to full."""
    n = 3000
    rng = np.random.default_rng(12)
    uniform = rng.uniform(0, 22_500, (n, 2)).astype(np.float32)
    clustered = (rng.normal(0, 60, (n, 2)) + 11_250).astype(
        np.float32).clip(0, 22_499)
    qid = np.arange(n, dtype=np.int32)

    def drive(collect):
        sess = KnnSession(_spec(k=16, th_quad=32, l_max=6, window=64,
                                chunk=1024, rebuild_factor=1.5,
                                collect=collect))
        sess.ingest_objects(uniform)
        hq = sess.register_queries(uniform, qid)
        out = [sess.submit().result(), sess.submit().result()]
        sess.update_objects(np.arange(n, dtype=np.int32), clustered)
        sess.update_queries(hq, clustered)
        out.append(sess.submit().result())
        return out

    none_res = drive("none")
    full_res = drive("full")
    for rn, rf in zip(none_res, full_res):
        assert rn.nn_idx is None and rn.nn_dist is None
        assert rn.shard_candidates is None and rn.aggregates is None
        assert rn.collect_s == 0.0
        assert rn.rebuilt == rf.rebuilt
        assert rn.candidates == rf.candidates
        assert rn.iterations == rf.iterations
    assert none_res[2].rebuilt  # the teleport's drift trigger still fired


def test_collect_stats_churn_resets_on_registry_change():
    """The sink's cross-tick memory is row-aligned with the padded registry
    batch: a row-set change resets it (churn reports 1 again) instead of
    comparing against another query's stale neighbour list."""
    rng = np.random.default_rng(44)
    pts = rng.uniform(0, 22_500, (400, 2)).astype(np.float32)
    sess = KnnSession(_spec(collect="stats"))
    sess.ingest_objects(pts)
    sess.register_queries(pts[:40])
    sess.submit().result()
    r1 = sess.submit().result()
    assert float(r1.aggregates.churn_mean) == 0.0
    sess.register_queries(pts[40:50])  # row set changed -> sink state reset
    r2 = sess.submit().result()
    assert float(r2.aggregates.churn_mean) == 1.0
    r3 = sess.submit().result()
    assert float(r3.aggregates.churn_mean) == 0.0


def test_result_for_device_rows_under_stats_mode():
    """result_for under collect="stats" serves device-array rows (no host
    transfer of the lists) and refuses after the buffers are released."""
    rng = np.random.default_rng(3)
    pts = rng.uniform(0, 22_500, (300, 2)).astype(np.float32)
    sess = KnnSession(_spec(collect="stats"))
    sess.ingest_objects(pts)
    hq = sess.register_queries(pts[:32])
    h = sess.submit()
    di, dd, dq = h.result_for(hq)
    assert isinstance(di, jax.Array) and di.shape == (32, sess.spec.k)
    full = KnnSession(_spec())
    full.ingest_objects(pts)
    full.register_queries(pts[:32])
    rf = full.submit().result()
    np.testing.assert_array_equal(np.asarray(di), rf.nn_idx)
    np.testing.assert_array_equal(np.asarray(dd), rf.nn_dist)
    h.result()  # materializes the aggregates, releases the list buffers
    with pytest.raises(RuntimeError, match="never transferred"):
        h.result_for(hq)


def test_mixed_precision_session_bitwise_over_ticks():
    """precision="mixed" through the session (delta ingest, drift rebuild)
    == fp32, tick for tick, bitwise (DESIGN.md §14)."""
    w = make_workload(500, "gaussian", seed=2, hotspots=4)
    qid = np.arange(500, dtype=np.int32)
    frames = []
    for _ in range(3):
        frames.append(w.positions().copy())
        w.advance()

    def drive(precision):
        sess = KnnSession(_spec(precision=precision))
        sess.ingest_objects(frames[0])
        hq = sess.register_queries(frames[0], qid)
        out = []
        for t, p in enumerate(frames):
            if t > 0:
                moved = np.nonzero((p != frames[t - 1]).any(1))[0].astype(
                    np.int32)
                sess.update_objects(moved, p[moved])
                sess.update_queries(hq, p)
            out.append(sess.submit().result())
        return out

    for rm, rf in zip(drive("mixed"), drive("fp32")):
        np.testing.assert_array_equal(rm.nn_idx, rf.nn_idx)
        np.testing.assert_array_equal(rm.nn_dist, rf.nn_dist)
        assert rm.rebuilt == rf.rebuilt


# ---------------------------- in-flight device handles (satellite, §14)

def test_device_handles_stay_valid_across_submits_and_rebuild():
    """Two-in-flight materialize=False contract: tick τ's device arrays stay
    valid (and correct) after τ+1 submits, and after a drift rebuild is
    applied between τ's submit and τ's result — nothing donates or
    overwrites the result buffers."""
    n, k = 2000, 8
    rng = np.random.default_rng(27)
    uniform = rng.uniform(0, 22_500, (n, 2)).astype(np.float32)
    clustered = (rng.normal(0, 60, (n, 2)) + 11_250).astype(
        np.float32).clip(0, 22_499)
    qid = np.arange(n, dtype=np.int32)

    spec = _spec(k=k, th_quad=32, l_max=6, window=64, chunk=512,
                 rebuild_factor=1.5)
    eng = _engine(spec)
    ref = [eng.process_tick(uniform, uniform, qid),
           eng.process_tick(uniform, uniform, qid),
           eng.process_tick(clustered, clustered, qid),
           eng.process_tick(clustered, clustered, qid)]

    sess = KnnSession(spec)
    sess.ingest_objects(uniform)
    hq = sess.register_queries(uniform, qid)
    h0 = sess.submit()
    h1 = sess.submit()  # two in flight; h0 finalized here
    dev0 = h0.result(materialize=False)
    sess.update_objects(qid, clustered)
    sess.update_queries(hq, clustered)
    h2 = sess.submit()  # the drift tick; h1 finalized here
    dev1 = h1.result(materialize=False)
    h3 = sess.submit()  # finalizing h2 applies the REBUILD before dispatch
    # h2's device arrays were produced pre-rebuild; the rebuild between its
    # submit and this read must not invalidate or corrupt them
    dev2 = h2.result(materialize=False)
    assert h2._finalized and h2.result().rebuilt
    r3 = h3.result()
    assert not r3.rebuilt
    for dev, r in zip((dev0, dev1, dev2), ref):
        assert isinstance(dev.nn_idx, jax.Array)
        np.testing.assert_array_equal(np.asarray(dev.nn_idx), r.nn_idx)
        np.testing.assert_array_equal(np.asarray(dev.nn_dist), r.nn_dist)
    np.testing.assert_array_equal(r3.nn_idx, ref[3].nn_idx)


def test_device_aggregates_stay_valid_with_two_in_flight():
    """Same contract for the stats sink's device aggregates: τ's aggregate
    arrays survive τ+1's submit (the sink state advances functionally)."""
    rng = np.random.default_rng(5)
    pts = rng.uniform(0, 22_500, (400, 2)).astype(np.float32)
    sess = KnnSession(_spec(collect="stats"))
    sess.ingest_objects(pts)
    hq = sess.register_queries(pts[:48])
    h0 = sess.submit()
    moved = pts[:48] + 25.0
    sess.update_queries(hq, np.clip(moved, 0, 22_499).astype(np.float32))
    h1 = sess.submit()
    d0 = h0.result(materialize=False)
    d1 = h1.result(materialize=False)
    assert isinstance(d0.aggregates.kth_dist, jax.Array)
    assert float(d0.aggregates.churn_mean) == 1.0  # first tick
    assert 0.0 <= float(d1.aggregates.churn_mean) <= 1.0
    r0 = h0.result()
    assert isinstance(r0.aggregates.kth_dist, np.ndarray)
    assert float(r0.aggregates.churn_mean) == 1.0


# ------------------------------------------------------- error surface

def test_session_error_surface():
    sess = KnnSession(_spec())
    with pytest.raises(RuntimeError, match="ingest_objects"):
        sess.update_objects([0], [[1.0, 1.0]])
    with pytest.raises(RuntimeError, match="no object state"):
        sess.submit()
    pts = np.random.default_rng(0).uniform(0, 22_500, (100, 2)).astype(np.float32)
    sess.ingest_objects(pts)
    with pytest.raises(RuntimeError, match="empty query registry"):
        sess.submit()
    with pytest.raises(ValueError, match="empty query group"):
        sess.register_queries(np.zeros((0, 2), np.float32))
    h = sess.register_queries(pts[:10])
    with pytest.raises(ValueError, match="10 rows"):
        sess.update_queries(h, pts[:5])
    with pytest.raises(ValueError, match="out of range"):
        sess.update_objects([100], [[1.0, 1.0]])
    with pytest.raises(ValueError, match="ids vs"):
        sess.update_objects([1, 2], [[1.0, 1.0]])
    with pytest.raises(ValueError, match="qid has"):
        sess.register_queries(pts[:4], np.arange(3, dtype=np.int32))
    sess.drop_queries(h)
    with pytest.raises(KeyError):
        sess.drop_queries(h)
    sess.set_queries(pts[:8])
    assert sess.query_count == 8


# -------------------------------------------- forced 8-device mesh (real XLA)

def test_session_parity_on_forced_8_device_mesh():
    """The acceptance criterion on real multi-device XLA: delta ingest +
    overlapped submit through KnnSession is bit-identical to the snapshot
    TickEngine path under BOTH plans on an 8-device host mesh, all three
    workload families.  Subprocess: device count must precede jax init."""
    code = r"""
import os, warnings
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax
assert jax.device_count() == 8, jax.device_count()
from repro.api import KnnSession, ServiceSpec
from repro.core import EngineConfig, TickEngine
from repro.data import make_workload

for plan in ("single", "sharded"):
    for dist in ("uniform", "gaussian", "network"):
        spec = ServiceSpec(k=4, th_quad=16, l_max=5, window=32, chunk=32,
                           plan=plan, mesh_shape=8 if plan == "sharded" else None,
                           delta_pad=64)
        w = make_workload(400, dist, seed=5)
        frames = []
        for _ in range(3):
            frames.append(w.positions().copy()); w.advance()
        qid = np.arange(400, dtype=np.int32)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            eng = TickEngine(spec.engine_config())
        ref = [eng.process_tick(p, p, qid) for p in frames]

        sess = KnnSession(spec)
        sess.ingest_objects(frames[0])
        hq = sess.register_queries(frames[0], qid)
        handles, prev = [], None
        for t, p in enumerate(frames):
            if t > 0:
                moved = np.nonzero((p != frames[t-1]).any(1))[0].astype(np.int32)
                sess.update_objects(moved, p[moved])
                sess.update_queries(hq, p)
            handles.append(sess.submit())  # overlapped: result lags one tick
        for h, r in zip(handles, ref):
            got = h.result()
            np.testing.assert_array_equal(got.nn_idx, r.nn_idx)
            np.testing.assert_array_equal(got.nn_dist, r.nn_dist)
            assert got.rebuilt == r.rebuilt
print("SESSION_8DEV_OK")
"""
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)  # the child pins its own device count
    r = subprocess.run(
        [sys.executable, "-c", code], env=env, capture_output=True, text=True
    )
    assert r.returncode == 0, (r.stdout[-1000:], r.stderr[-3000:])
    assert "SESSION_8DEV_OK" in r.stdout
