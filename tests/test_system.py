"""End-to-end behaviour of the paper's system: the tick engine (Sec. 2.2/2.3).

Iterated batch processing over moving objects: timeslice semantics per tick,
index reuse across ticks, rebuild policy on distribution drift.
"""
import numpy as np

from repro.core import EngineConfig, TickEngine, knn_bruteforce_chunked
from repro.data import WorkloadConfig, MovingObjectWorkload, make_workload


def test_engine_matches_bruteforce_over_ticks():
    n, k, ticks = 2000, 8, 4
    eng = TickEngine(EngineConfig(k=k, th_quad=32, l_max=6, window=64, chunk=1024))
    w = make_workload(n, "gaussian", seed=11)
    results = eng.run(w, ticks=ticks)
    assert len(results) == ticks
    # replay the workload and verify every tick against brute force
    w2 = make_workload(n, "gaussian", seed=11)
    for t in range(ticks):
        qpos, qid = w2.query_batch()
        bi, bd = knn_bruteforce_chunked(w2.positions(), qpos, qid, k=k, chunk=1024)
        np.testing.assert_allclose(results[t].nn_dist, bd, rtol=1e-5, atol=1e-3)
        w2.advance()
    # index built once, reused after
    assert results[0].rebuilt
    assert not results[1].rebuilt


def test_rebuild_policy_triggers_on_drift():
    """Teleporting all objects into one hotspot must blow up the work counter
    and trigger a partition rebuild (paper Sec. 4.1.1 trigger)."""
    n, k = 3000, 16
    eng = TickEngine(
        EngineConfig(k=k, th_quad=32, l_max=6, window=64, chunk=1024, rebuild_factor=1.5)
    )
    rng = np.random.default_rng(12)
    uniform = rng.uniform(0, 22500, (n, 2)).astype(np.float32)
    clustered = (rng.normal(0, 60, (n, 2)) + 11250).astype(np.float32).clip(0, 22499)
    qid = np.arange(n, dtype=np.int32)

    r0 = eng.process_tick(uniform, uniform, qid)
    assert r0.rebuilt  # initial build
    r1 = eng.process_tick(uniform, uniform, qid)
    assert not r1.rebuilt
    # drift: everything collapses into one cluster -> old partition is bad
    r2 = eng.process_tick(clustered, clustered, qid)
    assert r2.rebuilt, (r2.candidates, r1.candidates)
    # and the result is still exact under the stale partition
    bi, bd = knn_bruteforce_chunked(clustered, clustered, qid, k=k, chunk=1024)
    np.testing.assert_allclose(r2.nn_dist, bd, rtol=1e-5, atol=1e-3)


def test_query_rate_below_one():
    w = MovingObjectWorkload(WorkloadConfig(n_objects=500, distribution="uniform", seed=3))
    qpos, qid = w.query_batch(rate=0.25)
    assert len(qid) == 125
    eng = TickEngine(EngineConfig(k=4, th_quad=16, l_max=5, window=32, chunk=256))
    res = eng.process_tick(w.positions(), qpos, qid)
    bi, bd = knn_bruteforce_chunked(w.positions(), qpos, qid, k=4, chunk=256)
    np.testing.assert_allclose(res.nn_dist, bd, rtol=1e-5, atol=1e-3)


def test_workload_speed_bound():
    """Table 1: per-tick displacement <= max_speed (all three generators)."""
    for dist in ("uniform", "gaussian", "network"):
        w = make_workload(300, dist, seed=7)
        p0 = w.positions().copy()
        w.advance()
        p1 = w.positions()
        disp = np.linalg.norm(p1 - p0, axis=1)
        assert disp.max() <= w.cfg.max_speed * 1.5 + 1e-3, (dist, disp.max())


def test_cpu_kdtree_reference():
    import jax.numpy as jnp

    from repro.core import KDTree, knn_bruteforce

    rng = np.random.default_rng(13)
    pts = rng.uniform(0, 1000, (400, 2)).astype(np.float32)
    tree = KDTree(pts, leaf_size=16)
    ids, dist = tree.query_batch(pts[:50], k=5, qid=np.arange(50))
    bi, bd = knn_bruteforce(jnp.asarray(pts), jnp.asarray(pts[:50]), jnp.arange(50, dtype=jnp.int32), 5)
    np.testing.assert_allclose(dist, np.asarray(bd), rtol=1e-5, atol=1e-4)
