"""The iterative k-NN pipeline vs the brute-force oracle (Def. 1 semantics)."""
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # offline container: deterministic fallback shim
    from repro.testing import given, settings, strategies as st

from repro.core import build_index, knn_bruteforce, knn_query_batch, knn_query_batch_chunked
from repro.data import make_workload


def _check(pts, qpos, qid, k, l_max=5, th=16, window=32, side=1000.0):
    idx = build_index(jnp.asarray(pts), jnp.zeros(2), side, l_max=l_max, th_quad=th)
    ii, dd, stats = knn_query_batch(
        idx, jnp.asarray(qpos), None if qid is None else jnp.asarray(qid), k=k, window=window
    )
    bi, bd = knn_bruteforce(
        jnp.asarray(pts),
        jnp.asarray(qpos),
        jnp.full((len(qpos),), -2, jnp.int32) if qid is None else jnp.asarray(qid),
        k,
    )
    np.testing.assert_allclose(np.asarray(dd), np.asarray(bd), rtol=1e-5, atol=1e-3)
    return ii, dd, stats


@pytest.mark.parametrize("dist", ["uniform", "gaussian", "network"])
@pytest.mark.parametrize("k", [1, 8, 33])
def test_vs_bruteforce_distributions(dist, k):
    w = make_workload(1500, dist, seed=2)
    pts = w.positions()
    qpos, qid = w.query_batch()
    idx = build_index(jnp.asarray(pts), jnp.zeros(2), 22500.0, l_max=6, th_quad=24)
    ii, dd, _ = knn_query_batch(idx, jnp.asarray(qpos), jnp.asarray(qid), k=k, window=32)
    bi, bd = knn_bruteforce(jnp.asarray(pts), jnp.asarray(qpos), jnp.asarray(qid), k)
    np.testing.assert_allclose(np.asarray(dd), np.asarray(bd), rtol=1e-5, atol=1e-3)


@pytest.mark.parametrize("th_quad", [4, 64, 4096])
def test_tree_height_extremes(th_quad):
    """th_quad sweep: deep tree (many leaf visits) and flat tree (one big leaf)."""
    rng = np.random.default_rng(3)
    pts = rng.uniform(0, 1000, (800, 2)).astype(np.float32)
    _check(pts, pts[:200], np.arange(200, dtype=np.int32), 16, th=th_quad)


def test_k_exceeds_population():
    """k > |P|-1: lists padded with (-1, inf), all real objects present."""
    rng = np.random.default_rng(4)
    pts = rng.uniform(0, 1000, (10, 2)).astype(np.float32)
    idx = build_index(jnp.asarray(pts), jnp.zeros(2), 1000.0, l_max=4, th_quad=4)
    ii, dd, _ = knn_query_batch(idx, jnp.asarray(pts), jnp.arange(10, dtype=jnp.int32), k=16)
    ii = np.asarray(ii)
    dd = np.asarray(dd)
    for row in range(10):
        real = ii[row][ii[row] >= 0]
        assert len(real) == 9  # everything except self
        assert np.isinf(dd[row][len(real):]).all()


def test_external_queries_and_self_exclusion():
    rng = np.random.default_rng(5)
    pts = rng.uniform(0, 1000, (300, 2)).astype(np.float32)
    # external queries (no issuing object): nearest can be distance 0
    ii, dd, _ = _check(pts, pts[:50], None, 4)
    assert (np.asarray(dd)[:, 0] == 0).all()
    # object queries: self excluded -> nearest distance > 0 (points distinct whp)
    ii2, dd2, _ = _check(pts, pts[:50], np.arange(50, dtype=np.int32), 4)
    assert (np.asarray(dd2)[:, 0] > 0).all()


def test_duplicate_points():
    pts = np.ones((50, 2), np.float32) * 500.0
    _check(pts, pts[:10], np.arange(10, dtype=np.int32), 8)


def test_skewed_cluster_in_corner():
    rng = np.random.default_rng(6)
    a = rng.uniform(0, 10, (400, 2))
    b = rng.uniform(900, 1000, (20, 2))
    pts = np.concatenate([a, b]).astype(np.float32)
    q = np.concatenate([a[:30], b[:10]]).astype(np.float32)
    _check(pts, q, None, 12, th=8)


@settings(max_examples=15, deadline=None)
@given(
    st.lists(st.tuples(st.floats(0, 999.9), st.floats(0, 999.9)), min_size=3, max_size=200),
    st.integers(1, 12),
    st.integers(2, 5),
    st.integers(2, 24),
)
def test_property_random_sets(points, k, l_max, th):
    """Any point set, any k/tree shape: pipeline == brute force (dist multiset)."""
    pts = np.asarray(points, np.float32)
    _check(pts, pts, np.arange(len(pts), dtype=np.int32), k, l_max=l_max, th=th, window=16)


def test_chunked_driver_matches():
    rng = np.random.default_rng(7)
    pts = rng.uniform(0, 1000, (700, 2)).astype(np.float32)
    idx = build_index(jnp.asarray(pts), jnp.zeros(2), 1000.0, l_max=5, th_quad=16)
    qid = np.arange(700, dtype=np.int32)
    ii_a, dd_a, _ = knn_query_batch(idx, jnp.asarray(pts), jnp.asarray(qid), k=8)
    ii_b, dd_b, _ = knn_query_batch_chunked(idx, pts, qid, k=8, chunk=256)
    np.testing.assert_allclose(np.asarray(dd_a), dd_b, rtol=1e-6)
