"""Incremental index maintenance (DESIGN.md §15): splice, pyramid, session.

Three layers of pinning, bottom-up:

* kernel — the delta-splice rank merge (``repro.kernels.delta_splice``)
  against a host-side reference merge: stability on cross-run code ties,
  sentinel discipline, permutation property; and the sparse gather plan
  (the production path — Δ-sized scatters only) bitwise against the dense
  scatter formulation;
* core — ``reindex_objects_delta`` bitwise against ``reindex_objects`` for
  delta sizes from 1 row to 100% churn (coincident points, same-cell moves,
  no-op moves, sentinel padding included), and ``pyramid_delta`` bitwise
  against a from-scratch recount;
* session — the scheduling policy: dirty-flag "skip" on clean ticks, the
  churn-budget deferral to a full refresh, snapshot ingest forcing a full
  refresh, and ``TickResult.maintenance`` recording what actually ran.

The cross-plan lockstep property (incremental ≡ rebuild, every tick, across
the plan × partitioner grid on however many devices exist) lives in
tests/test_properties.py.
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import KnnSession, ServiceSpec
from repro.core import (
    EngineConfig,
    MAINTENANCE_MODES,
    build_index,
    pyramid_delta,
    rebuild_zmap,
    reindex_objects,
    reindex_objects_delta,
    starts_from_pyramid,
)
from repro.core.quadtree import _count_pyramid
from repro.kernels import (
    gather_splice,
    merge_ranks,
    searchsorted_pairs,
    sparse_splice_plan,
    splice_payload,
)

SIDE = 1000.0


def _index(pts, l_max=5, th=8):
    return build_index(jnp.asarray(pts), jnp.zeros(2), SIDE, l_max=l_max, th_quad=th)


def _assert_index_equal(a, b, fields=("pos", "ids", "codes", "starts",
                                      "pyramid", "leaf_level")):
    for f in fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(a, f)), np.asarray(getattr(b, f)), err_msg=f
        )


# --------------------------------------------------------------------- kernel
def _ref_merge_positions(ca, ia, cb, ib):
    """Host reference: positions of each run element in the stable merge."""
    tagged = [(c, i, 0, j) for j, (c, i) in enumerate(zip(ca, ia))] + [
        (c, i, 1, j) for j, (c, i) in enumerate(zip(cb, ib))
    ]
    tagged.sort(key=lambda t: (t[0], t[1], t[2]))  # A before B on full ties
    pa = np.empty(len(ca), np.int32)
    pb = np.empty(len(cb), np.int32)
    for pos, (_, _, run, j) in enumerate(tagged):
        (pa if run == 0 else pb)[j] = pos
    return pa, pb


@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("na,nb", [(17, 5), (64, 64), (1, 33), (40, 1)])
def test_merge_ranks_matches_reference(seed, na, nb):
    """Rank merge == the host-side stable merge, ties and all.

    Codes are drawn from a tiny alphabet so cross-run code collisions are
    the common case, and ids are globally unique (the quadtree's contract) —
    the (code, id) pairs decide every tie.
    """
    rng = np.random.default_rng(seed)
    ids = rng.permutation(na + nb).astype(np.int32)
    ca = np.sort(rng.integers(0, 6, na).astype(np.int32))
    cb = np.sort(rng.integers(0, 6, nb).astype(np.int32))
    # sort ids within equal-code segments to honor the sorted-run contract
    ia = ids[:na][np.lexsort((ids[:na], ca))]
    ca = ca[np.argsort(ca, kind="stable")]
    ib = ids[na:][np.lexsort((ids[na:], cb))]
    cb = cb[np.argsort(cb, kind="stable")]
    pa, pb = merge_ranks(
        jnp.asarray(ca), jnp.asarray(ia), jnp.asarray(cb), jnp.asarray(ib)
    )
    ref_a, ref_b = _ref_merge_positions(ca, ia, cb, ib)
    np.testing.assert_array_equal(np.asarray(pa), ref_a)
    np.testing.assert_array_equal(np.asarray(pb), ref_b)
    # real positions are a permutation of [0, na+nb)
    assert sorted(np.concatenate([pa, pb]).tolist()) == list(range(na + nb))


def test_merge_ranks_sentinel_rows_land_past_n():
    """Equal sentinel keys across BOTH runs land at positions >= n_real and
    are dropped by the payload scatter — the no-mask sentinel discipline."""
    sent_c, sent_i = np.int32(1 << 10), np.int32(100)
    ca = np.array([1, 3, sent_c, sent_c], np.int32)
    ia = np.array([7, 2, sent_i, sent_i], np.int32)
    cb = np.array([3, sent_c, sent_c], np.int32)
    ib = np.array([0, sent_i, sent_i], np.int32)
    pa, pb = merge_ranks(
        jnp.asarray(ca), jnp.asarray(ia), jnp.asarray(cb), jnp.asarray(ib)
    )
    n_real = 3
    real = sorted([int(pa[0]), int(pa[1]), int(pb[0])])
    assert real == [0, 1, 2]
    assert int(pb[0]) == 1  # (3, 0) precedes (3, 2): id breaks the code tie
    assert all(int(p) >= n_real for p in [pa[2], pa[3], pb[1], pb[2]])
    out = splice_payload(pa, pb, jnp.asarray(ia), jnp.asarray(ib), n_real, fill=-1)
    np.testing.assert_array_equal(np.asarray(out), [7, 0, 2])


@pytest.mark.parametrize("side", ["left", "right"])
def test_searchsorted_pairs_matches_numpy_on_packed_keys(side):
    """Pair binary search == np.searchsorted over the packed 64-bit key."""
    rng = np.random.default_rng(3)
    kc = np.sort(rng.integers(0, 50, 200).astype(np.int32))
    ki = rng.integers(0, 1000, 200).astype(np.int32)
    ki = ki[np.lexsort((ki, kc))]
    qc = rng.integers(0, 50, 77).astype(np.int32)
    qi = rng.integers(0, 1000, 77).astype(np.int32)
    packed = kc.astype(np.int64) * 1_000_000 + ki
    q_packed = qc.astype(np.int64) * 1_000_000 + qi
    got = searchsorted_pairs(
        jnp.asarray(kc), jnp.asarray(ki), jnp.asarray(qc), jnp.asarray(qi),
        side=side,
    )
    np.testing.assert_array_equal(
        np.asarray(got), np.searchsorted(packed, q_packed, side=side)
    )


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_sparse_splice_plan_matches_dense_merge(seed):
    """The gather plan (Δ-sized scatters only) reproduces the dense
    merge_ranks/splice_payload output bitwise — including heavy code ties,
    sentinel padding on both event arrays, and a 2-D payload."""
    rng = np.random.default_rng(seed)
    n, d, npad = 120, 30, 9
    sent_c, sent_i = np.int32(1 << 12), np.int32(n)
    codes = np.sort(rng.integers(0, 12, n).astype(np.int32))  # heavy ties
    ids = rng.permutation(n).astype(np.int32)
    ids = ids[np.lexsort((ids, codes))]
    pay2d = rng.uniform(0, 1, (n, 2)).astype(np.float32)
    slots_real = np.sort(rng.choice(n, d, replace=False)).astype(np.int32)
    new_codes = rng.integers(0, 12, d).astype(np.int32)
    ord_b = np.lexsort((ids[slots_real], new_codes))
    cb = np.concatenate([new_codes[ord_b], np.full(npad, sent_c)])
    ib = np.concatenate([ids[slots_real][ord_b], np.full(npad, sent_i)])
    pb2d = np.concatenate(
        [rng.uniform(0, 1, (d, 2)), rng.uniform(0, 1, (npad, 2))]
    ).astype(np.float32)
    # dense reference: compacted survivors + sentinel tail, rank-merged
    keep = np.ones(n, bool)
    keep[slots_real] = False
    ca = np.concatenate([codes[keep], np.full(d, sent_c)])
    ia = np.concatenate([ids[keep], np.full(d, sent_i)])
    pa2d = np.concatenate([pay2d[keep], np.zeros((d, 2), np.float32)])
    pos_a, pos_b = merge_ranks(
        jnp.asarray(ca), jnp.asarray(ia), jnp.asarray(cb), jnp.asarray(ib)
    )
    want_ids = splice_payload(pos_a, pos_b, jnp.asarray(ia), jnp.asarray(ib), n)
    want_2d = splice_payload(
        pos_a, pos_b, jnp.asarray(pa2d), jnp.asarray(pb2d), n
    )
    # sparse plan: event arrays padded with sentinels, searched vs ORIGINAL keys
    packed = codes.astype(np.int64) * (1 << 13) + ids
    ins_full = np.searchsorted(
        packed, cb.astype(np.int64) * (1 << 13) + ib, side="right"
    ).astype(np.int32)
    slots = np.concatenate([slots_real, np.full(npad, n, np.int32)])
    src_a, b_src = sparse_splice_plan(
        jnp.asarray(slots), jnp.asarray(ins_full), n
    )
    got_ids = gather_splice(src_a, b_src, jnp.asarray(ids), jnp.asarray(ib))
    got_2d = gather_splice(src_a, b_src, jnp.asarray(pay2d), jnp.asarray(pb2d))
    np.testing.assert_array_equal(np.asarray(got_ids), np.asarray(want_ids))
    np.testing.assert_array_equal(np.asarray(got_2d), np.asarray(want_2d))


# ----------------------------------------------------------------------- core
def test_pyramid_delta_equals_recount():
    """Scatter-add of per-level ±1 deltas == a from-scratch recount, bitwise
    (int32 adds commute exactly); zero-weight (padding) rows are inert."""
    rng = np.random.default_rng(4)
    l_max = 5
    codes = rng.integers(0, 4**l_max, 500).astype(np.int32)
    pyr = _count_pyramid(jnp.asarray(codes), l_max)
    moved = rng.choice(500, 60, replace=False)
    new_codes_rows = rng.integers(0, 4**l_max, 60).astype(np.int32)
    codes2 = codes.copy()
    codes2[moved] = new_codes_rows
    # 60 real rows + 4 padding rows with garbage (but in-range) codes
    old = np.concatenate([codes[moved], np.array([0, 1, 2, 3], np.int32)])
    new = np.concatenate([new_codes_rows, np.array([3, 2, 1, 0], np.int32)])
    w = np.concatenate([np.ones(60, np.int32), np.zeros(4, np.int32)])
    got = pyramid_delta(
        pyr, jnp.asarray(old), jnp.asarray(new), jnp.asarray(w), l_max
    )
    want = _count_pyramid(jnp.asarray(codes2), l_max)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    np.testing.assert_array_equal(
        np.asarray(starts_from_pyramid(got, l_max)),
        np.asarray(starts_from_pyramid(want, l_max)),
    )


@pytest.mark.parametrize("delta_frac", [0.002, 0.05, 0.5, 1.0])
def test_reindex_delta_bitwise_equals_full(delta_frac):
    """reindex_objects_delta == reindex_objects, bitwise, for every churn
    level — duplicates (coincident points, code ties) and no-op moves mixed
    in, delta padded with sentinel-N rows like the session pads it."""
    rng = np.random.default_rng(5)
    n = 800
    pts = rng.uniform(0, SIDE, (n, 2)).astype(np.float32)
    pts[::7] = pts[3]  # coincident points: heavy code ties
    idx = _index(pts)
    d = max(1, int(n * delta_frac))
    ids = rng.choice(n, d, replace=False).astype(np.int32)
    pts2 = pts.copy()
    pts2[ids] = rng.uniform(0, SIDE, (d, 2)).astype(np.float32)
    pts2[ids[: d // 4]] = pts[ids[: d // 4]] + 0.01  # same-cell nudge
    pts2[ids[d // 4: d // 2]] = pts[ids[d // 4: d // 2]]  # no-op move
    padded = np.concatenate([ids, np.full(7, n, np.int32)])
    # old positions as of the index build; padding rows deliberately garbage
    old_pos = np.concatenate(
        [pts[ids], rng.uniform(0, SIDE, (7, 2)).astype(np.float32)]
    )
    got = reindex_objects_delta(
        idx, jnp.asarray(pts2), jnp.asarray(padded), jnp.asarray(old_pos)
    )
    want = reindex_objects(idx, jnp.asarray(pts2))
    _assert_index_equal(got, want)


def test_reindex_delta_pair_fallback_bitwise():
    """The pair-key search/sort fallback (taken when (code, id) cannot pack
    into an int32: 4**l_max * (n+1) + n >= 2**31) stays bitwise-equal to the
    full reindex.  l_max=8 with n >= 32767 crosses the threshold."""
    rng = np.random.default_rng(11)
    n = 33_000
    assert 4**8 * (n + 1) + n >= 2**31  # really exercises the fallback
    pts = rng.uniform(0, SIDE, (n, 2)).astype(np.float32)
    idx = _index(pts, l_max=8, th=96)
    d = 64
    ids = rng.choice(n, d, replace=False).astype(np.int32)
    pts2 = pts.copy()
    pts2[ids] = rng.uniform(0, SIDE, (d, 2)).astype(np.float32)
    padded = np.concatenate([ids, np.full(5, n, np.int32)])
    old_pos = np.concatenate([pts[ids], np.zeros((5, 2), np.float32)])
    got = reindex_objects_delta(
        idx, jnp.asarray(pts2), jnp.asarray(padded), jnp.asarray(old_pos)
    )
    want = reindex_objects(idx, jnp.asarray(pts2))
    _assert_index_equal(got, want)


def test_reindex_delta_chained_ticks():
    """Feeding each tick's *incremental* output into the next stays bitwise
    on the full-reindex trajectory — errors cannot accumulate because there
    are none."""
    rng = np.random.default_rng(6)
    n = 600
    pts = rng.uniform(0, SIDE, (n, 2)).astype(np.float32)
    inc = full = _index(pts)
    for _ in range(5):
        ids = rng.choice(n, 31, replace=False).astype(np.int32)
        old = pts[ids].copy()
        pts[ids] = np.clip(
            pts[ids] + rng.normal(0, SIDE / 10, (31, 2)), 0, SIDE - 0.01
        ).astype(np.float32)
        inc = reindex_objects_delta(
            inc, jnp.asarray(pts), jnp.asarray(ids), jnp.asarray(old)
        )
        full = reindex_objects(full, jnp.asarray(pts))
        _assert_index_equal(inc, full)


# -------------------------------------------------------------------- session
def _session(maintenance, pts, qpos, **kw):
    spec = ServiceSpec(
        k=4, chunk=256, window=32, l_max=5, th_quad=32, side=SIDE,
        delta_pad=64, maintenance=maintenance, **kw,
    )
    s = KnnSession(spec)
    s.ingest_objects(pts)
    s.register_queries(qpos)
    return s


def test_session_modes_and_bit_identity():
    """One motion script, two sessions: the scheduling decisions differ
    exactly as specified, the bits never do."""
    rng = np.random.default_rng(7)
    n = 500
    pts = rng.uniform(0, SIDE, (n, 2)).astype(np.float32)
    qpos = rng.uniform(0, SIDE, (32, 2)).astype(np.float32)
    a = _session("rebuild", pts, qpos)
    b = _session("incremental", pts, qpos, churn_budget=0.25)
    script = [None, 20, None, 20, 400, 20]  # rows moved before each tick
    want_a = ["skip", "rebuild", "skip", "rebuild", "rebuild", "rebuild"]
    want_b = ["skip", "incremental", "skip", "incremental", "rebuild",
              "incremental"]
    for t, mv in enumerate(script):
        if mv:
            ids = rng.choice(n, mv, replace=False)
            new = rng.uniform(0, SIDE, (mv, 2)).astype(np.float32)
            a.update_objects(ids, new)
            b.update_objects(ids, new)
        ra, rb = a.submit().result(), b.submit().result()
        assert ra.maintenance == want_a[t], t
        assert rb.maintenance == want_b[t], t
        np.testing.assert_array_equal(ra.nn_idx, rb.nn_idx, err_msg=str(t))
        np.testing.assert_array_equal(ra.nn_dist, rb.nn_dist, err_msg=str(t))
        _assert_index_equal(a.index, b.index)


def test_session_snapshot_ingest_forces_full_refresh():
    """A snapshot replaces the buffer with an unknown delta: the next tick
    must run the full refresh even under an incremental spec."""
    rng = np.random.default_rng(8)
    pts = rng.uniform(0, SIDE, (300, 2)).astype(np.float32)
    qpos = rng.uniform(0, SIDE, (16, 2)).astype(np.float32)
    s = _session("incremental", pts, qpos)
    assert s.submit().result().maintenance == "skip"  # fresh build
    s.update_objects([5], [[1.0, 2.0]])
    assert s.submit().result().maintenance == "incremental"
    s.ingest_objects(rng.uniform(0, SIDE, (300, 2)).astype(np.float32))
    assert s.submit().result().maintenance == "rebuild"


def test_session_duplicate_delta_ids_count_once_against_budget():
    """The same object moving many times between submits is ONE moved row
    for the churn budget (the pending set is a union)."""
    rng = np.random.default_rng(9)
    n = 200
    pts = rng.uniform(0, SIDE, (n, 2)).astype(np.float32)
    qpos = rng.uniform(0, SIDE, (8, 2)).astype(np.float32)
    s = _session("incremental", pts, qpos, churn_budget=0.05)  # budget = 10 rows
    s.submit().result()
    for _ in range(30):  # 30 batches, all hitting the same 6 objects
        s.update_objects([0, 1, 2, 3, 4, 5],
                         rng.uniform(0, SIDE, (6, 2)).astype(np.float32))
    assert s.submit().result().maintenance == "incremental"
    ref = reindex_objects(s.index, s._positions)
    _assert_index_equal(s.index, ref, fields=("pos", "ids", "codes", "starts",
                                              "pyramid"))


# ------------------------------------------- sharded maintenance (DESIGN §15)
def test_rebuild_zmap_equals_fresh_build():
    """Stage-(i) reuse: ``rebuild_zmap`` over a spliced (current) index ==
    ``build_index`` from scratch, every field bitwise — the drift policy's
    z_map re-decision needs no fresh argsort when the order is current."""
    rng = np.random.default_rng(12)
    n = 700
    pts = rng.uniform(0, SIDE, (n, 2)).astype(np.float32)
    pts[::9] = pts[4]  # coincident rows: code ties in the kept order
    idx = _index(pts)
    ids = rng.choice(n, 90, replace=False)
    pts2 = pts.copy()
    pts2[ids] = rng.uniform(0, SIDE, (90, 2)).astype(np.float32)
    got = rebuild_zmap(reindex_objects(idx, jnp.asarray(pts2)))
    want = _index(pts2)
    _assert_index_equal(got, want)
    # idempotent on an already-current index too
    _assert_index_equal(rebuild_zmap(want), want)


@pytest.mark.parametrize("r", [2, 3, 8])
def test_derived_local_index_bitwise_equals_local_rebuild(r):
    """The derived local tree (masked slice + interval pyramid from the
    GLOBAL starts — ``_local_index_derived``) == the per-shard
    ``build_index`` over the same slice (``_local_index``), every field
    bitwise, over equal-capacity boundaries — including the uneven final
    shard and coincident duplicates.  This is the shard_map body's
    maintenance branch run host-side, shard by shard."""
    from repro.core import plan as plan_mod

    rng = np.random.default_rng(20 + r)
    n = 89  # uneven final slice for r = 2, 3, 8
    pts = rng.uniform(0, SIDE, (n, 2)).astype(np.float32)
    pts[::7] = pts[3]
    idx = _index(pts)
    cap = plan_mod.object_shard_capacity(n, r)
    bo = np.minimum(np.arange(r + 1) * cap, n)
    for s in range(r):
        rebuilt, derived = _shard_local_pair(idx, bo, s, cap)
        _assert_index_equal(rebuilt, derived)


def test_derived_local_index_uneven_and_empty_shards():
    """Cost-balanced-style boundaries as data: uneven owned counts, an EMPTY
    shard (own = 0 collapses the whole capacity window onto one clone row)
    and a full-capacity shard all stay bitwise-equal to the rebuild."""
    rng = np.random.default_rng(24)
    n = 200
    pts = rng.uniform(0, SIDE, (n, 2)).astype(np.float32)
    idx = _index(pts)
    bo = np.array([0, 10, 10, 120, 200])  # shard 1 owns nothing
    capo = 110  # >= max owned count, as the partitioner guarantees
    for s in range(4):
        rebuilt, derived = _shard_local_pair(idx, bo, s, capo)
        _assert_index_equal(rebuilt, derived)


def _shard_local_pair(idx, bo, r, capo):
    """Host-side emulation of ``_object_merge_local``'s two local-tree
    branches for shard ``r``: returns (rebuilt, derived) local indexes."""
    from repro.core import plan as plan_mod

    opos, oids, ocodes = plan_mod._pad_object_tail(idx, capo)
    start, own = int(bo[r]), int(bo[r + 1] - bo[r])
    opos_raw = opos[start:start + capo]
    oids_raw = oids[start:start + capo]
    mask = jnp.arange(capo) < own
    clone = opos_raw[int(np.clip(own - 1, 0, capo - 1))]
    opos_l = jnp.where(mask[:, None], opos_raw, clone[None, :])
    oids_l = jnp.where(mask, oids_raw, -1)
    rebuilt = plan_mod._local_index(
        opos_l, oids_l, idx.origin, idx.side, l_max=idx.l_max,
        th_quad=idx.th_quad,
    )
    codes_raw = ocodes[start:start + capo]
    clone_code = codes_raw[int(np.clip(own - 1, 0, capo - 1))]
    codes_l = jnp.where(mask, codes_raw, clone_code)
    derived = plan_mod._local_index_derived(
        idx.origin, idx.side, opos_l, oids_l, codes_l, clone_code,
        idx.starts, jnp.int32(start), jnp.int32(own), capo,
        l_max=idx.l_max, th_quad=idx.th_quad,
    )
    return rebuilt, derived


def test_delta_shard_counts_matches_host_recount():
    """Per-source-shard pending counts == a host bincount over the ownership
    rule, under both the capacity rule and explicit boundaries; sentinel-N
    padding rows are charged to no shard."""
    from repro.core.ticks import delta_shard_counts, object_shard_of

    rng = np.random.default_rng(13)
    n = 257
    pts = rng.uniform(0, SIDE, (n, 2)).astype(np.float32)
    idx = _index(pts)
    real = rng.choice(n, 40, replace=False).astype(np.int32)
    padded = jnp.asarray(np.concatenate([real, np.full(9, n, np.int32)]))
    for r, bounds in ((8, None), (4, jnp.asarray([0, 30, 101, 101, 257],
                                                 jnp.int32))):
        got = delta_shard_counts(idx, padded, r, bounds)
        shards = np.asarray(object_shard_of(idx, jnp.asarray(real), r, bounds))
        np.testing.assert_array_equal(
            np.asarray(got), np.bincount(shards, minlength=r)
        )


def test_shard_churn_over_budget_exact_boundary():
    """The per-shard deferral rule is STRICT: exactly churn_budget × owned
    movers in one shard stays incremental (mirroring the global ``<=`` rule);
    one more defers.  Spreading the same total across shards stays under;
    sentinel padding rows are inert."""
    from repro.core.ticks import shard_churn_over_budget

    rng = np.random.default_rng(14)
    n, r = 64, 4  # equal rule: 16 owned per shard, budget = 4 rows each
    pts = rng.uniform(0, SIDE, (n, 2)).astype(np.float32)
    idx = _index(pts)
    by_rank = np.asarray(idx.ids).astype(np.int32)

    def over(ranks):
        ids = jnp.asarray(by_rank[np.asarray(ranks)])
        return bool(shard_churn_over_budget(idx, ids, r, 0.25))

    assert not over(range(4))          # shard 0 at exactly its budget
    assert over(range(5))              # one past: defer
    assert not over([0, 1, 2, 3, 16])  # same 5 movers spread over 2 shards
    padded = jnp.asarray(np.concatenate(
        [by_rank[:4], np.full(6, n, np.int32)]
    ))
    assert not bool(shard_churn_over_budget(idx, padded, r, 0.25))


def test_session_churn_budget_exact_quarter_boundary():
    """The session's global deferral boundary is inclusive: exactly 25% of N
    pending splices incrementally, one row more defers to the full refresh —
    and both land on the full-reindex bits."""
    rng = np.random.default_rng(15)
    n = 64
    pts = rng.uniform(0, SIDE, (n, 2)).astype(np.float32)
    qpos = rng.uniform(0, SIDE, (8, 2)).astype(np.float32)
    for m, want in ((16, "incremental"), (17, "rebuild")):
        s = _session("incremental", pts, qpos, churn_budget=0.25)
        s.submit().result()
        ids = rng.choice(n, m, replace=False)
        s.update_objects(ids, rng.uniform(0, SIDE, (m, 2)).astype(np.float32))
        assert s.submit().result().maintenance == want, m
        ref = reindex_objects(s.index, s._positions)
        _assert_index_equal(s.index, ref, fields=("pos", "ids", "codes",
                                                  "starts", "pyramid"))


@pytest.mark.parametrize("plan", ["single", "sharded", "object_sharded",
                                  "hybrid"])
def test_no_motion_tick_skips_on_all_plans(plan):
    """A clean tick statically skips the reindex on EVERY plan — the mesh
    plans' derived local trees included — and replays the same bits."""
    import jax

    from repro.launch.mesh import default_hybrid_shape

    ndev = jax.device_count()
    mesh = (None if plan == "single"
            else default_hybrid_shape(ndev) if plan == "hybrid" else ndev)
    rng = np.random.default_rng(16)
    n = 96
    pts = rng.uniform(0, SIDE, (n, 2)).astype(np.float32)
    qpos = rng.uniform(0, SIDE, (16, 2)).astype(np.float32)
    for maint in ("rebuild", "incremental"):
        spec = ServiceSpec(
            k=4, window=16, chunk=32, l_max=5, th_quad=8, side=SIDE,
            plan=plan, mesh_shape=mesh, maintenance=maint,
            churn_budget=0.25, delta_pad=16,
        )
        s = KnnSession(spec)
        s.ingest_objects(pts)
        s.register_queries(qpos)
        assert s.submit().result().maintenance == "skip"  # fresh build
        ids = rng.choice(n, 8, replace=False)
        s.update_objects(ids, rng.uniform(0, SIDE, (8, 2)).astype(np.float32))
        moved = s.submit().result()
        assert moved.maintenance != "skip"
        still = s.submit().result()  # no motion since
        assert still.maintenance == "skip", (plan, maint)
        np.testing.assert_array_equal(moved.nn_idx, still.nn_idx)
        np.testing.assert_array_equal(moved.nn_dist, still.nn_dist)


def test_session_per_shard_budget_defers_concentrated_churn():
    """Movers concentrating in ONE object shard defer the whole tick to the
    full refresh even when the global fraction is comfortably in budget; the
    same total spread across shards splices — and either way the session
    lands on the full-reindex bits.  Needs a real object mesh (skipped on
    one device, where the per-shard rule degenerates to the global one)."""
    import jax

    if jax.device_count() < 2:
        pytest.skip("per-shard budget needs an object mesh (R > 1)")
    r = jax.device_count()
    n = 64 * r  # equal capacity 64 per shard, per-shard budget = 16 rows
    rng = np.random.default_rng(17)
    pts = rng.uniform(0, SIDE, (n, 2)).astype(np.float32)
    qpos = rng.uniform(0, SIDE, (8, 2)).astype(np.float32)
    cases = (
        (np.arange(17), "rebuild"),                       # all in shard 0
        (np.concatenate([np.arange(16), [64]]), "incremental"),  # spread
    )
    for ranks, want in cases:
        s = _session("incremental", pts, qpos, plan="object_sharded",
                     mesh_shape=r, churn_budget=0.25)
        s.submit().result()
        ids = np.asarray(s.index.ids)[ranks]
        s.update_objects(
            ids, rng.uniform(0, SIDE, (len(ids), 2)).astype(np.float32)
        )
        assert s.submit().result().maintenance == want, ranks
        ref = reindex_objects(s.index, s._positions)
        _assert_index_equal(s.index, ref, fields=("pos", "ids", "codes",
                                                  "starts", "pyramid"))


def test_session_drift_rebuild_reuses_spliced_order():
    """Drift × maintenance: a low ``rebuild_factor`` fires the stage-(i)
    z_map rebuild between ticks; under the incremental spec it reuses the
    spliced order (``rebuild_zmap``, no fresh argsort) and must stay bitwise
    on the rebuild session's trajectory."""
    rng = np.random.default_rng(18)
    n = 400
    pts = rng.uniform(0, SIDE, (n, 2)).astype(np.float32)
    qpos = rng.uniform(0, SIDE, (16, 2)).astype(np.float32)
    a = _session("rebuild", pts, qpos, rebuild_factor=0.5)
    b = _session("incremental", pts, qpos, rebuild_factor=0.5,
                 churn_budget=0.25)
    rebuilds = 0
    for t in range(5):
        ids = rng.choice(n, 20, replace=False)
        new = rng.uniform(0, SIDE, (20, 2)).astype(np.float32)
        a.update_objects(ids, new)
        b.update_objects(ids, new)
        ra, rb = a.submit().result(), b.submit().result()
        rebuilds += bool(rb.rebuilt)
        assert ra.maintenance == ("rebuild" if t else "skip")
        np.testing.assert_array_equal(ra.nn_idx, rb.nn_idx, err_msg=str(t))
        np.testing.assert_array_equal(ra.nn_dist, rb.nn_dist, err_msg=str(t))
        _assert_index_equal(a.index, b.index)
    assert rebuilds >= 1  # the drift trigger actually fired mid-run


def test_validation_rejects_bad_maintenance_knobs():
    with pytest.raises(ValueError, match="maintenance"):
        ServiceSpec(maintenance="lazy")
    with pytest.raises(ValueError, match="churn_budget"):
        ServiceSpec(maintenance="incremental", churn_budget=0.0)
    with pytest.raises(ValueError, match="churn_budget"):
        EngineConfig(churn_budget=1.5)
    with pytest.raises(ValueError, match="maintenance"):
        EngineConfig(maintenance="never")
    assert "rebuild" in MAINTENANCE_MODES and "incremental" in MAINTENANCE_MODES


def test_spec_round_trips_maintenance_knobs():
    cfg = EngineConfig(maintenance="incremental", churn_budget=0.1)
    spec = ServiceSpec.from_engine(cfg)
    assert spec.maintenance == "incremental" and spec.churn_budget == 0.1
    cfg2 = spec.engine_config()
    assert cfg2.maintenance == "incremental" and cfg2.churn_budget == 0.1
    assert dataclasses.asdict(cfg) == dataclasses.asdict(cfg2)
