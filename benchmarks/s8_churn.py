"""S8: churn rate x maintenance mode x plan — pay for churn, not for N.

The acceptance probe of the incremental index-maintenance seam (DESIGN.md
§15): a Zipf-skewed moving-object workload where a controlled fraction of
the objects TELEPORT each tick (uniform re-draw over the region — the
worst case for the splice: Morton ranks scatter across the whole order and,
under the mesh plans, rows cross shard boundaries), served on a forced
8-device host grid under ``maintenance="rebuild" | "incremental"`` across
the plan sweep.  Per row we record:

* ``reindex_stage_s`` — the reindex-stage time of the maintenance mode the
  session actually ran (per-stage counter: the stage is timed as its own
  jitted device program at the session's exact N / delta-pad shapes,
  ``block_until_ready``-bracketed, min of ``reps``).  Both variants are
  always reported (``reindex_rebuild_s`` / ``reindex_incremental_s``) so
  the artifact carries the full rebuild-vs-delta curve.  For the plans with
  an object mesh axis (object_sharded / hybrid) the stage is PLAN-AWARE:
  it adds the per-device local-tree refresh the shard_map body runs each
  tick — ``build_index`` over one ceil(N/R)-row slice under ``rebuild``
  vs the derived local tree (masked slice + interval pyramid off the global
  starts, ``core.plan._local_index_derived``) under ``incremental`` — split
  out as ``local_rebuild_s`` / ``local_derived_s`` next to the global
  ``global_rebuild_s`` / ``global_incremental_s`` components;
* ``mode_used`` — what the session's scheduler chose in steady state: at
  100% churn the budget (``churn_budget=0.25``) correctly defers the
  incremental spec to the full refresh, and the row shows it;
* ``tick_s_median`` — whole-tick wall through the session API (on a CPU
  host the query sweep shares cores with the forced devices, so the stage
  column is the honest churn-scaling signal);
* ``bit_identical`` — every tick's results compared bitwise against a
  lockstep single-plan REBUILD session (the §15 contract, asserted), plus a
  bitwise index comparison of the standalone stage programs at benchmark
  size.

Each row runs in a subprocess because
``--xla_force_host_platform_device_count`` must be set before jax init.

  PYTHONPATH=src python benchmarks/s8_churn.py [--objects N] [--ticks T]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

DEFAULT_CHURNS = (0.001, 0.01, 0.1, 1.0)
DEFAULT_PLANS = (("single", ""), ("sharded", "8"), ("object_sharded", "8"),
                 ("hybrid", "2x4"))
DEFAULT_DEVICES = 8
DELTA_PAD = 256
CHURN_BUDGET = 0.25
SIDE = 22_500.0


def _parse_mesh(mesh: str):
    if not mesh:
        return None
    if "x" in mesh:
        q, o = mesh.split("x")
        return (int(q), int(o))
    return int(mesh)


def _child(args) -> None:
    """One (churn, maintenance, plan) row; prints a tagged JSON line."""
    import numpy as np

    import jax
    import jax.numpy as jnp

    from repro.api import KnnSession, ServiceSpec
    from repro.core import (
        build_index,
        object_shard_capacity,
        pad_capacity,
        reindex_objects,
        reindex_objects_delta,
    )
    from repro.data import make_workload

    n = args.objects
    d = max(1, int(round(n * args.churn)))
    rng = np.random.default_rng(0)
    w = make_workload(n, "zipf", seed=0, zipf_a=args.zipf_a,
                      hotspot_sigma_frac=0.003)
    pts = np.asarray(w.positions(), np.float32)
    nq = min(args.queries, n)
    qpos = pts[:nq].copy()
    qid = np.arange(nq, dtype=np.int32)

    def session(plan, mesh, maintenance):
        return KnnSession(ServiceSpec(
            k=args.k, th_quad=96, l_max=7, window=128, chunk=args.chunk,
            plan=plan, mesh_shape=mesh, maintenance=maintenance,
            churn_budget=CHURN_BUDGET, delta_pad=DELTA_PAD,
        ))

    sess = session(args.plan, _parse_mesh(args.mesh), args.maintenance)
    ref = session("single", None, "rebuild")
    for s in (sess, ref):
        s.ingest_objects(pts)
    sess.register_queries(qpos, qid)
    ref.register_queries(qpos, qid)

    cur = pts.copy()
    walls, modes, bit_identical = [], [], True
    for t in range(args.ticks):
        r = sess.submit().result()
        r_ref = ref.submit().result()
        bit_identical &= bool(
            np.array_equal(r.nn_idx, r_ref.nn_idx)
            and np.array_equal(r.nn_dist, r_ref.nn_dist)
        )
        assert bit_identical, f"tick {t}: diverged from single/rebuild"
        if t >= 1:  # skip the build+compile tick
            walls.append(r.wall_s)
            modes.append(r.maintenance)
        ids = rng.choice(n, d, replace=False).astype(np.int32)
        new = rng.uniform(0, SIDE, (d, 2)).astype(np.float32)
        cur[ids] = new
        sess.update_objects(ids, new)
        ref.update_objects(ids, new)
    mode_used = max(set(modes), key=modes.count)

    # reindex stage as its own device program, at the session's shapes: the
    # tick program is fused, so stage attribution needs standalone timing —
    # the same ops _tick_step inlines, same N, same padded delta length.
    idx = build_index(jnp.asarray(cur), jnp.zeros(2, jnp.float32), SIDE,
                      l_max=7, th_quad=96)
    ids = np.sort(rng.choice(n, d, replace=False).astype(np.int32))
    nxt = cur.copy()
    nxt[ids] = rng.uniform(0, SIDE, (d, 2)).astype(np.float32)
    pad = pad_capacity(d, DELTA_PAD) - d
    padded = np.concatenate([ids, np.full(pad, n, np.int32)])
    old_pos = np.concatenate([cur[ids], np.zeros((pad, 2), np.float32)])
    nxt_dev, padded_dev = jnp.asarray(nxt), jnp.asarray(padded)
    old_dev = jnp.asarray(old_pos)
    full = jax.block_until_ready(reindex_objects(idx, nxt_dev))
    inc = jax.block_until_ready(
        reindex_objects_delta(idx, nxt_dev, padded_dev, old_dev))
    for f in ("pos", "ids", "codes", "starts", "pyramid"):
        assert np.array_equal(np.asarray(getattr(full, f)),
                              np.asarray(getattr(inc, f))), f
    bit_identical &= True

    def stage_time(fn, *fa):
        # min over reps: the 8 forced host devices contend for cores, and
        # scheduler noise only ever ADDS time — the floor is the honest
        # per-device stage cost
        ts = []
        for _ in range(args.reps):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(*fa))
            ts.append(time.perf_counter() - t0)
        return float(np.min(ts))

    t_rebuild = stage_time(reindex_objects, idx, nxt_dev)
    t_incremental = stage_time(reindex_objects_delta, idx, nxt_dev,
                               padded_dev, old_dev)

    # plan-aware local-tree component: the object-mesh plans refresh R
    # device-local quadtrees per tick inside shard_map — under "rebuild"
    # each device re-sorts its ceil(N/R)-row slice (build_index); under
    # "incremental" it derives the local tree from the already-spliced
    # global order (masked slice + interval pyramid, no per-device sort).
    # Timed standalone at one full shard's exact capacity — devices run
    # concurrently, so one shard's cost IS the per-tick stage cost.
    mesh = _parse_mesh(args.mesh)
    r_o = 1
    if args.plan == "object_sharded":
        r_o = int(mesh)
    elif args.plan == "hybrid":
        r_o = int(mesh[1])
    t_local_rebuild = t_local_derived = 0.0
    if r_o > 1:
        from repro.core import plan as plan_mod

        capo = object_shard_capacity(n, r_o)
        opos, oids, ocodes = plan_mod._pad_object_tail(full, capo)
        own = min(capo, n)  # shard 0 is always full
        opos_l, oids_l, codes_l = opos[:capo], oids[:capo], ocodes[:capo]
        clone_code = codes_l[own - 1]

        @jax.jit
        def _loc_rebuild(p, i):
            return plan_mod._local_index(
                p, i, full.origin, full.side, l_max=7, th_quad=96)

        @jax.jit
        def _loc_derived(p, i, c, cc, gs):
            return plan_mod._local_index_derived(
                full.origin, full.side, p, i, c, cc, gs, jnp.int32(0),
                jnp.int32(own), capo, l_max=7, th_quad=96)

        loc_reb = jax.block_until_ready(_loc_rebuild(opos_l, oids_l))
        loc_der = jax.block_until_ready(_loc_derived(
            opos_l, oids_l, codes_l, clone_code, full.starts))
        for f in ("pos", "ids", "codes", "starts", "pyramid", "leaf_level"):
            assert np.array_equal(np.asarray(getattr(loc_reb, f)),
                                  np.asarray(getattr(loc_der, f))), f
        t_local_rebuild = stage_time(_loc_rebuild, opos_l, oids_l)
        t_local_derived = stage_time(_loc_derived, opos_l, oids_l, codes_l,
                                     clone_code, full.starts)

    reb_total = t_rebuild + t_local_rebuild
    inc_total = t_incremental + t_local_derived
    row = {
        "churn": args.churn,
        "delta_rows": d,
        "maintenance": args.maintenance,
        "mode_used": mode_used,
        "plan": args.plan,
        "mesh": args.mesh,
        "devices": int(jax.device_count()),
        "objects": n,
        "ticks": args.ticks,
        "k": args.k,
        "chunk": args.chunk,
        "object_axis": r_o,
        "reindex_stage_s": (inc_total if mode_used == "incremental"
                            else reb_total),
        "reindex_rebuild_s": reb_total,
        "reindex_incremental_s": inc_total,
        "global_rebuild_s": t_rebuild,
        "global_incremental_s": t_incremental,
        "local_rebuild_s": t_local_rebuild,
        "local_derived_s": t_local_derived,
        "tick_s_median": float(np.median(walls)),
        "bit_identical": bit_identical,
    }
    print("S8ROW " + json.dumps(row), flush=True)


def run(
    objects: int = 50_000,
    ticks: int = 5,
    k: int = 8,
    chunk: int = 256,
    queries: int = 512,
    reps: int = 15,
    churns=DEFAULT_CHURNS,
    plans=DEFAULT_PLANS,
    devices: int = DEFAULT_DEVICES,
    check: bool = True,
    out: str | None = "BENCH_churn.json",
):
    """Sweep churn x maintenance x plan on forced host devices.

    Returns the row list; the JSON artifact additionally carries a
    per-(churn, plan) summary with the rebuild -> incremental reindex-stage
    ratio — the headline number (>1 = the delta path is cheaper).  With
    ``check`` (full runs), asserts the §15 acceptance criterion: >= 3x
    stage reduction at every churn level <= 10%.
    """
    here = os.path.dirname(os.path.abspath(__file__))
    src = os.path.join(here, "..", "src")
    rows = []
    for churn in churns:
        for plan, mesh in plans:
            for maintenance in ("rebuild", "incremental"):
                env = dict(os.environ)
                env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
                env["XLA_FLAGS"] = (
                    env.get("XLA_FLAGS", "")
                    + f" --xla_force_host_platform_device_count={devices}"
                ).strip()
                cmd = [
                    sys.executable, os.path.abspath(__file__), "--child",
                    "--plan", plan, "--mesh", mesh,
                    "--maintenance", maintenance,
                    "--churn", str(churn),
                    "--objects", str(objects), "--ticks", str(ticks),
                    "--k", str(k), "--chunk", str(chunk),
                    "--queries", str(queries), "--reps", str(reps),
                ]
                r = subprocess.run(cmd, env=env, capture_output=True,
                                   text=True)
                if r.returncode != 0:
                    raise RuntimeError(
                        f"s8 child (churn={churn}, plan={plan}, "
                        f"maintenance={maintenance}) failed:\n"
                        + r.stderr[-2000:]
                    )
                row = json.loads(next(
                    l for l in r.stdout.splitlines() if l.startswith("S8ROW ")
                )[6:])
                rows.append(row)
                print(f"s8_churn/c{churn}_{plan}_{maintenance},"
                      f"{row['reindex_stage_s'] * 1e6:.1f},"
                      f"mode={row['mode_used']}", flush=True)

    summary = []
    for churn in churns:
        for plan, _ in plans:
            pair = {
                row["maintenance"]: row for row in rows
                if row["churn"] == churn and row["plan"] == plan
            }
            reb = pair["rebuild"]["reindex_stage_s"]
            inc = pair["incremental"]["reindex_stage_s"]
            summary.append({
                "churn": churn,
                "plan": plan,
                "object_axis": pair["incremental"]["object_axis"],
                "delta_rows": pair["incremental"]["delta_rows"],
                "mode_used_incremental": pair["incremental"]["mode_used"],
                "reindex_rebuild_s": reb,
                "reindex_incremental_s": inc,
                "local_rebuild_s": pair["rebuild"]["local_rebuild_s"],
                "local_derived_s": pair["incremental"]["local_derived_s"],
                "stage_ratio": reb / inc if inc > 0 else float("inf"),
            })
    if check:
        # §15 acceptance: the stage pays for churn, not for N — at every
        # churn level <= 10% the incremental stage must be >= 3x cheaper
        # (at 100% churn the budget defers to rebuild and the ratio ~ 1).
        # For the object-mesh plans the stage includes the per-device
        # local-tree refresh, whose derived path saves a capo-row sort but
        # keeps an O(4**l_max) floor — the sharded acceptance bar is >= 2x
        # (ISSUE 10), still on the plan-aware total.
        for s in summary:
            if s["churn"] <= 0.1:
                bar = 2.0 if s["object_axis"] > 1 else 3.0
                assert s["mode_used_incremental"] == "incremental", s
                assert s["stage_ratio"] >= bar, (
                    f"incremental reindex not >= {bar}x cheaper at churn "
                    f"{s['churn']} on plan {s['plan']}: {s}"
                )
    if out:
        rec = {
            "schema": 2,
            "unit": "seconds",
            "devices": devices,
            "churn_budget": CHURN_BUDGET,
            "delta_pad": DELTA_PAD,
            "rows": rows,
            "summary": summary,
            "timestamp": time.time(),
        }
        with open(out, "w") as f:
            json.dump(rec, f, indent=2)
        print(f"# wrote {out}", flush=True)
    return rows


def main() -> None:
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    ap = argparse.ArgumentParser()
    ap.add_argument("--child", action="store_true", help=argparse.SUPPRESS)
    ap.add_argument("--plan", default="single")
    ap.add_argument("--mesh", default="",
                    help="mesh shape: '' (single), '8' (1-D) or '2x4'")
    ap.add_argument("--maintenance", default="incremental")
    ap.add_argument("--churn", type=float, default=0.01)
    ap.add_argument("--zipf-a", type=float, default=1.6)
    ap.add_argument("--objects", type=int, default=50_000)
    ap.add_argument("--ticks", type=int, default=5)
    ap.add_argument("--k", type=int, default=8)
    ap.add_argument("--chunk", type=int, default=256)
    ap.add_argument("--queries", type=int, default=512)
    ap.add_argument("--reps", type=int, default=15)
    ap.add_argument("--no-check", action="store_true",
                    help="skip the >= 3x stage-reduction assertion "
                         "(small smoke sizes)")
    ap.add_argument("--churns", default=None,
                    help="comma list of churn fractions for the sweep "
                         "(default: %s)" % (DEFAULT_CHURNS,))
    ap.add_argument("--plans", default=None,
                    help="comma list of plan[:mesh] entries, e.g. "
                         "'sharded:8,hybrid:2x4' (default: full matrix)")
    ap.add_argument("--out", default="BENCH_churn.json")
    args = ap.parse_args()
    if args.child:
        _child(args)
        return
    churns = (tuple(float(c) for c in args.churns.split(","))
              if args.churns else DEFAULT_CHURNS)
    plans = (tuple((p.split(":") + [""])[:2] for p in args.plans.split(","))
             if args.plans else DEFAULT_PLANS)
    run(objects=args.objects, ticks=args.ticks, k=args.k, chunk=args.chunk,
        queries=args.queries, reps=args.reps, churns=churns, plans=plans,
        check=not args.no_check, out=args.out)


if __name__ == "__main__":
    main()
