"""Shared benchmark helpers: timing, workload setup, CSV emission."""
from __future__ import annotations

import time

import jax
import numpy as np


def time_call(fn, *args, warmup: int = 1, iters: int = 3, **kw):
    """Median wall time of fn(*args) with block_until_ready, in seconds."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args, **kw))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kw))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def emit(name: str, seconds: float, derived: str = ""):
    """CSV row: name,us_per_call,derived."""
    print(f"{name},{seconds * 1e6:.1f},{derived}", flush=True)
