"""S4: SCAN-backend sweep + host-loop vs device-chunked driver comparison.

New axis introduced by the executor refactor: the same indexed pipeline is run
with every registered SCAN backend (``dense_topk`` | ``fused_bucket`` |
``brute``) on uniform and skewed workloads, plus a *legacy host-loop* driver
row (one ``knn_query_batch`` dispatch + device->host copy per chunk — the
seed's ``knn_query_batch_chunked``) against the fused single-call driver, so
the device-residency win is a measured number, not a claim.

Emits CSV rows like every other study and (via ``--out`` / ``run(out=...)``)
a machine-readable ``BENCH_backends.json`` for the perf trajectory.
"""
from __future__ import annotations

import json

import jax.numpy as jnp
import numpy as np

from repro.core import (
    available_backends,
    build_index,
    knn_query_batch,
    knn_query_batch_chunked,
)
from repro.data import make_workload

from .common import emit, time_call


def _host_loop_chunked(index, qpos, qid, *, k, window, chunk, backend):
    """The seed's driver: Python chunk loop, one dispatch + copy per chunk."""
    nq = qpos.shape[0]
    out = []
    for lo in range(0, nq, chunk):
        hi = min(lo + chunk, nq)
        qp = jnp.asarray(qpos[lo:hi])
        qi = jnp.asarray(qid[lo:hi])
        if hi - lo < chunk:
            pad = chunk - (hi - lo)
            qp = jnp.concatenate([qp, jnp.tile(qp[-1:], (pad, 1))])
            qi = jnp.concatenate([qi, jnp.full((pad,), -2, jnp.int32)])
        ii, _, _ = knn_query_batch(index, qp, qi, k=k, window=window, backend=backend)
        out.append(np.asarray(ii[: hi - lo]))
    return np.concatenate(out)


def run(
    n_objects: int = 20_000,
    k: int = 32,
    dists=("uniform", "gaussian"),
    window: int = 128,
    chunk: int = 4096,
    out: str | None = None,
):
    records = []
    for dist in dists:
        w = make_workload(n_objects, dist, seed=0)
        pts = w.positions()
        qpos, qid = w.query_batch()
        idx = build_index(
            jnp.asarray(pts), jnp.zeros(2), 22500.0, l_max=8, th_quad=384
        )
        # driver comparison (fixed default backend): host loop vs device map
        t_host = time_call(
            lambda: _host_loop_chunked(
                idx, qpos, qid, k=k, window=window, chunk=chunk, backend="dense_topk"
            ),
            iters=2,
        )
        for backend in available_backends():
            t_dev = time_call(
                lambda b=backend: knn_query_batch_chunked(
                    idx, qpos, qid, k=k, window=window, chunk=chunk, backend=b
                )[0],
                iters=2,
            )
            _, _, stats = knn_query_batch_chunked(
                idx, qpos, qid, k=k, window=window, chunk=chunk, backend=backend
            )
            cand_s = stats.candidates / t_dev
            emit(
                f"s4_backends/{dist}/{backend}",
                t_dev,
                f"cand/s={cand_s:.3e} vs_host_loop={t_host / t_dev:.2f}x",
            )
            records.append(
                {
                    "dist": dist,
                    "backend": backend,
                    "n_objects": n_objects,
                    "k": k,
                    "window": window,
                    "chunk": chunk,
                    "seconds": t_dev,
                    "host_loop_seconds": t_host,
                    "candidates": stats.candidates,
                    "candidates_per_s": cand_s,
                    "queries_per_s": n_objects / t_dev,
                }
            )
    if out:
        with open(out, "w") as f:
            json.dump(records, f, indent=2)
        print(f"# wrote {out}", flush=True)
    return records


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--objects", type=int, default=20_000)
    ap.add_argument("--k", type=int, default=32)
    ap.add_argument("--out", default="BENCH_backends.json")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(n_objects=args.objects, k=args.k, out=args.out)
