"""S1 / Fig. 3 left: tree height (th_quad) x neighbours-list size k.

Reproduces the paper's finding: each k has a wide optimal th_quad range; too
deep a tree (small th_quad) pays per-leaf overhead, too flat a tree loses
pruning power; execution time grows with k.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import build_index, knn_query_batch
from repro.data import make_workload

from .common import emit, time_call


def run(n_objects=50_000, ks=(8, 32, 128), th_quads=(48, 192, 768, 3072), seed=0):
    w = make_workload(n_objects, "uniform", seed=seed)
    pts = jnp.asarray(w.positions())
    qpos, qid = w.query_batch()
    qpos, qid = jnp.asarray(qpos), jnp.asarray(qid)
    rows = []
    for k in ks:
        for th in th_quads:
            idx = build_index(pts, jnp.zeros(2), 22500.0, l_max=8, th_quad=th)
            fn = lambda: knn_query_batch(idx, qpos, qid, k=k)[0]
            sec = time_call(fn, warmup=1, iters=3)
            emit(f"s1_treeheight/k={k}/th={th}", sec, f"{n_objects / sec:.0f} q/s")
            rows.append((k, th, sec))
    # sanity: for each k, the best th is interior or the sweep is monotone-ish
    return rows


if __name__ == "__main__":
    run()
