"""Benchmark runner — one function per paper table/figure + kernels + roofline.

Prints ``name,us_per_call,derived`` CSV rows.  Each sub-bench is importable and
has a __main__ for full-size runs; this runner uses CPU-feasible defaults.

``--smoke`` runs a minutes-scale subset and writes ``BENCH_smoke.json``
(queries/s + candidates/s per backend, engine tick latency, serving-mode
rows) plus ``BENCH_serving_smoke.json`` (ingest x submit x collect mode,
s6), ``ROOFLINE_stages_smoke.json`` (per-stage roofline: reindex/sweep/
merge/collect bytes + FLOPs over measured counters) and
``BENCH_skew_smoke.json`` (straggler gap: equal vs cost_balanced partitioner
on a forced 8-device grid, s7) — the per-PR perf trajectory artifacts
consumed by CI.  The plain
``BENCH_serving.json``/``BENCH_skew.json`` are committed full-size
artifacts, regenerated only by full (non-smoke) runs.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time


def _smoke(out_path: str) -> None:
    import jax
    import numpy as np

    from benchmarks import s4_backends
    from repro.core import EngineConfig, TickEngine, available_backends
    from repro.data import make_workload

    rec: dict = {"schema": 2, "unit": "seconds"}
    rec["device_count"] = int(jax.device_count())
    rec["backends"] = s4_backends.run(
        n_objects=8_000, k=16, dists=("uniform",), chunk=2048, out=None
    )

    # engine steady-state: per-tick wall time after warmup, default backend;
    # every SCAN backend on the single plan, plus the sharded plan over
    # whatever mesh this process sees (1 locally, 8 in the CI multi-device job)
    def engine_row(backend, plan):
        eng = TickEngine(
            EngineConfig(k=16, th_quad=192, l_max=7, window=128, chunk=2048,
                         backend=backend, plan=plan)
        )
        w = make_workload(8_000, "gaussian", seed=0)
        results = eng.run(w, ticks=4)
        steady = [r.wall_s for r in results[1:]]
        return {
            "plan": eng.plan.name,
            "devices": int(jax.device_count()),
            "tick_s_median": float(np.median(steady)),
            "queries_per_s": float(8_000 / np.median(steady)),
            "candidates_per_tick": float(np.mean([r.candidates for r in results[1:]])),
        }

    ticks = {b: engine_row(b, "single") for b in available_backends()}
    rec["engine"] = ticks
    rec["engine_sharded"] = engine_row("dense_topk", "sharded")

    # serving-mode sweep (session API): ingest x submit x collect mode,
    # reduced size.  Written under a _smoke name: the plain
    # BENCH_serving.json is the committed full-size (50K x 30) artifact and
    # must not be clobbered by smoke runs.
    from benchmarks import s6_serving

    rec["serving"] = s6_serving.run(
        objects=4_000, ticks=4, k=16, chunk=1024, window=128,
        out="BENCH_serving_smoke.json",
    )

    # per-stage roofline (reindex/sweep/merge/collect) at smoke size — the
    # stage volume model over measured counters; full-size table comes from
    # a plain `python benchmarks/roofline.py` run (ROOFLINE_stages.json)
    from benchmarks import roofline

    rec["roofline_stages"] = roofline.run(
        objects=4_000, queries=1_024, ticks=3, chunk=1024,
        out="ROOFLINE_stages_smoke.json",
    )

    # skew row: the partitioner seam's straggler-gap probe on a forced
    # 8-device grid (equal vs cost_balanced, bit-identity asserted in-run);
    # one exponent x the query-sharded plans keeps smoke minutes-scale.
    # Written under a _smoke name: the plain BENCH_skew.json is the
    # committed full-matrix artifact (s7 at full size) and must not be
    # clobbered by smoke runs — same discipline as BENCH_serving.json above
    from benchmarks import s7_skew

    rec["skew"] = s7_skew.run(
        objects=2_048, ticks=3, k=8, chunk=128, exponents=(1.6,),
        plans=(("sharded", "8"), ("hybrid", "2x4")),
        out="BENCH_skew_smoke.json",
    )
    rec["timestamp"] = time.time()
    with open(out_path, "w") as f:
        json.dump(rec, f, indent=2)
    print(f"# wrote {out_path}", flush=True)


def main() -> None:
    root = os.path.join(os.path.dirname(__file__), "..")
    sys.path.insert(0, root)  # `benchmarks` namespace package
    sys.path.insert(0, os.path.join(root, "src"))
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced sweep; writes the JSON perf artifact")
    ap.add_argument("--out", default="BENCH_smoke.json",
                    help="smoke-mode JSON output path")
    args = ap.parse_args()

    print("name,us_per_call,derived")
    if args.smoke:
        _smoke(args.out)
        return

    from benchmarks import (
        kernels,
        s1_skew,
        s1_treeheight,
        s2_vs_baseline,
        s3_vary_k,
        s3_vs_cpu,
        s4_backends,
        s5_scaling,
        s6_serving,
        s7_skew,
    )

    s1_treeheight.run(n_objects=30_000, ks=(8, 32), th_quads=(48, 384, 1536))
    s1_skew.run(n_objects=30_000, hotspots=(4, 25), th_quads=(96, 384))
    s2_vs_baseline.run_vary_n(ns=(5_000, 20_000))
    s2_vs_baseline.run_vary_k(n=20_000, ks=(8, 64))
    s3_vs_cpu.run(ns=(20_000,), dists=("uniform", "gaussian"))
    s3_vary_k.run(n=20_000, ks=(8, 64), dists=("uniform",))
    s3_vary_k.run_update_strategies(q=64, c=512, ks=(32,))
    s4_backends.run(n_objects=20_000, k=32, out="BENCH_backends.json")
    s5_scaling.run(objects=8_000, ticks=4, out="BENCH_scaling.json")
    s7_skew.run(objects=4_096, ticks=4, out="BENCH_skew.json")
    # full scale matches the committed artifact (50K objects x 4096 queries
    # x 30 ticks) so a full run regenerates BENCH_serving.json at its
    # documented size
    s6_serving.run(objects=50_000, queries=4_096, ticks=30, passes=6,
                   out="BENCH_serving.json")
    kernels.run(q=64, c=512, k=16)

    # per-stage roofline at the committed serving config
    from benchmarks import roofline

    roofline.run(out="ROOFLINE_stages.json")


if __name__ == "__main__":
    main()
