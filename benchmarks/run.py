"""Benchmark runner — one function per paper table/figure + kernels + roofline.

Prints ``name,us_per_call,derived`` CSV rows.  Each sub-bench is importable and
has a __main__ for full-size runs; this runner uses CPU-feasible defaults.
"""
from __future__ import annotations

import os
import sys


def main() -> None:
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    from benchmarks import kernels, s1_skew, s1_treeheight, s2_vs_baseline, s3_vary_k, s3_vs_cpu

    print("name,us_per_call,derived")
    s1_treeheight.run(n_objects=30_000, ks=(8, 32), th_quads=(48, 384, 1536))
    s1_skew.run(n_objects=30_000, hotspots=(4, 25), th_quads=(96, 384))
    s2_vs_baseline.run_vary_n(ns=(5_000, 20_000))
    s2_vs_baseline.run_vary_k(n=20_000, ks=(8, 64))
    s3_vs_cpu.run(ns=(20_000,), dists=("uniform", "gaussian"))
    s3_vary_k.run(n=20_000, ks=(8, 64), dists=("uniform",))
    s3_vary_k.run_update_strategies(q=64, c=512, ks=(32,))
    kernels.run(q=64, c=512, k=16)

    # roofline summary (optimized defaults if recorded, else baseline)
    res = os.path.join(os.path.dirname(__file__), "..", "results")
    path = os.path.join(res, "dryrun_opt.jsonl")
    if not os.path.exists(path):
        path = os.path.join(res, "dryrun_baseline.jsonl")
    if os.path.exists(path):
        from benchmarks import roofline

        recs = roofline.load(path)
        print()
        print(roofline.fmt_table(recs, "16x16"))


if __name__ == "__main__":
    main()
