"""S1 / Fig. 3 right: spatial skewness (hotspot count) x th_quad optimality.

Paper finding: skew raises execution time but barely moves the optimal
th_quad range (k fixed at 32, 500K objects in the paper; scaled down here).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import build_index, knn_query_batch
from repro.data import WorkloadConfig, MovingObjectWorkload

from .common import emit, time_call


def run(n_objects=50_000, k=32, hotspots=(4, 25, 100), th_quads=(96, 384, 1536), seed=0):
    rows = []
    for h in hotspots:
        w = MovingObjectWorkload(
            WorkloadConfig(n_objects=n_objects, distribution="gaussian", hotspots=h, seed=seed)
        )
        pts = jnp.asarray(w.positions())
        qpos, qid = w.query_batch()
        qpos, qid = jnp.asarray(qpos), jnp.asarray(qid)
        for th in th_quads:
            idx = build_index(pts, jnp.zeros(2), 22500.0, l_max=8, th_quad=th)
            sec = time_call(lambda: knn_query_batch(idx, qpos, qid, k=k)[0], iters=3)
            emit(f"s1_skew/hotspots={h}/th={th}", sec, f"{n_objects / sec:.0f} q/s")
            rows.append((h, th, sec))
    return rows


if __name__ == "__main__":
    run()
