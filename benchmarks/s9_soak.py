"""S9: multi-tenant serving soak — tail latency + cache hits under Zipf arrival.

The acceptance probe of the serving layer (DESIGN.md §16): an OPEN-LOOP soak
of a :class:`repro.serve.KnnServer` on a forced 8-device host grid.  Per
tick, a Poisson number of tenant *requests* arrive — each request retargets
one tenant (round-robin) onto a query group drawn Zipf-style from a shared
hotspot pool, so tenants overlap heavily on the popular groups — and a
controlled fraction of the objects teleports every ``motion_every``-th tick
(fed as a per-tenant delta, round-robin).  The arrival schedule is fixed
up front and never waits on service (open loop): a slow tick eats the next
arrivals late, which is exactly what makes the TAIL of the latency
distribution honest.  Per row we record:

* ``p50_ms / p95_ms / p99_ms`` — post-warmup attributable serve latency
  (``ServerTickResult.wall_s`` = staging + device drain + assembly; host
  idle and compile excluded by construction);
* ``dedup_rate`` / ``cache_rate`` — post-warmup fractions of logical tenant
  rows served without fresh device work, reported SEPARATELY: intra-tick
  dedup (overlapping pool groups fold into one computed row) vs. cross-tick
  cache replay (rows served from a still-valid entry).  ``hit_rate`` keeps
  the combined number; a nonzero combined rate under Zipf overlap is the
  acceptance bar, and under ``--invalidations epoch,spatial`` the cache
  column is what shows spatial invalidation surviving unrelated motion;
* ``cache`` — the ResultCache lifetime counters (lookups/hits/insertions/
  evictions/invalidations) and the epoch count actually consumed.

Each row runs in a subprocess because
``--xla_force_host_platform_device_count`` must be set before jax init.

  PYTHONPATH=src python benchmarks/s9_soak.py [--objects N] [--ticks T]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

DEFAULT_PLANS = (
    ("single", "", "equal"),
    ("sharded", "8", "cost_balanced"),
    ("hybrid", "2x4", "cost_balanced"),
)
DEFAULT_DEVICES = 8
SIDE = 22_500.0


def _parse_mesh(mesh: str):
    if not mesh:
        return None
    if "x" in mesh:
        q, o = mesh.split("x")
        return (int(q), int(o))
    return int(mesh)


def _child(args) -> None:
    """One (plan, partitioner) soak row; prints a tagged JSON line."""
    import numpy as np

    import jax

    from repro.api import ServiceSpec
    from repro.data import make_workload
    from repro.serve import KnnServer

    n = args.objects
    T = args.tenants
    H = args.pool
    g = args.group
    rng = np.random.default_rng(0)
    w = make_workload(n, "zipf", seed=0, zipf_a=args.zipf_a,
                      hotspot_sigma_frac=0.003)
    pts = np.asarray(w.positions(), np.float32)

    server = KnnServer(ServiceSpec(
        k=args.k, th_quad=96, l_max=7, window=128, chunk=args.chunk,
        plan=args.plan, mesh_shape=_parse_mesh(args.mesh),
        partitioner=args.partitioner,
    ), invalidation=args.invalidation)
    server.ingest_objects(pts)
    tenants = [server.admit(f"t{i}", quota=g) for i in range(T)]

    # the shared hotspot pool: H query groups of g rows, each a tight cloud
    # around a (Zipf-placed) object — what tenants overlap ON
    pool = []
    for _ in range(H):
        c = pts[int(rng.integers(n))]
        pool.append(np.asarray(
            c + rng.normal(0.0, SIDE * 0.002, (g, 2)), np.float32
        ))

    def zipf_group() -> int:
        return int((rng.zipf(args.zipf_a) - 1) % H)

    current = {}
    for i, t in enumerate(tenants):
        j = zipf_group()
        current[i] = (t.register_queries(pool[j]), j)

    # the OPEN-LOOP schedule: arrivals + motion per tick, fixed up front —
    # a slow tick never thins the load behind it
    arrivals = rng.poisson(args.lam, args.ticks)
    d = max(1, int(round(n * args.churn)))
    motion = [
        args.motion_every and t > 0 and t % args.motion_every == 0
        for t in range(args.ticks)
    ]

    event_i = 0
    cur = pts.copy()
    walls, served_at, computed_at = [], 0, 0
    dedup_at, cache_at = 0, 0
    rebuilds = 0
    for tick in range(args.ticks):
        for _ in range(int(arrivals[tick])):
            i = event_i % T
            event_i += 1
            old_handle, _ = current[i]
            tenants[i].drop_queries(old_handle)
            j = zipf_group()
            current[i] = (tenants[i].register_queries(pool[j]), j)
        if motion[tick]:
            ids = rng.choice(n, d, replace=False).astype(np.int32)
            new = rng.uniform(0, SIDE, (d, 2)).astype(np.float32)
            cur[ids] = new
            tenants[tick % T].update_objects(ids, new)
        res = server.submit().result()
        rebuilds += bool(res.rebuilt)
        if tick >= args.warmup:
            # attributable latency, not the host loop's wall: staging +
            # drain + assembly, idle and compile excluded by construction
            walls.append(res.wall_s)
            served_at += res.rows_total
            computed_at += res.rows_computed
            dedup_at += res.dedup_hit_rows
            cache_at += res.cache_hit_rows
    walls = np.asarray(walls)
    p50, p95, p99 = (float(x) for x in np.percentile(walls, [50, 95, 99]))
    row = {
        "plan": args.plan,
        "mesh": args.mesh,
        "partitioner": args.partitioner,
        "invalidation": args.invalidation,
        "devices": int(jax.device_count()),
        "objects": n,
        "tenants": T,
        "pool": H,
        "group_rows": g,
        "lam": args.lam,
        "zipf_a": args.zipf_a,
        "ticks": args.ticks,
        "warmup": args.warmup,
        "churn": args.churn,
        "motion_every": args.motion_every,
        "k": args.k,
        "chunk": args.chunk,
        "arrivals": int(arrivals.sum()),
        "rebuilds": rebuilds,
        "p50_ms": p50 * 1e3,
        "p95_ms": p95 * 1e3,
        "p99_ms": p99 * 1e3,
        "rows_served": served_at,
        "rows_computed": computed_at,
        "dedup_rate": dedup_at / max(served_at, 1),
        "cache_rate": cache_at / max(served_at, 1),
        "hit_rate": (dedup_at + cache_at) / max(served_at, 1),
        "epochs": int(server.cache.epoch),
        "cache": server.cache.stats.as_dict(),
    }
    print("S9ROW " + json.dumps(row), flush=True)


def run(
    objects: int = 20_000,
    tenants: int = 16,
    pool: int = 8,
    group: int = 64,
    lam: float = 4.0,
    zipf_a: float = 1.2,
    ticks: int = 40,
    warmup: int = 4,
    churn: float = 0.02,
    motion_every: int = 2,
    k: int = 16,
    chunk: int = 256,
    plans=DEFAULT_PLANS,
    invalidations=("epoch",),
    churns=None,
    devices: int = DEFAULT_DEVICES,
    check: bool = True,
    out: str | None = "BENCH_soak.json",
):
    """Soak each (plan, partitioner) × invalidation × churn row on forced
    host devices.

    ``invalidations`` selects the server's cache-invalidation modes to
    sweep; ``churns`` (None = just ``churn``) the per-motion-tick moved
    fraction — the epoch-vs-spatial comparison at 1% and 10% churn is the
    invalidation axis the CI soak uploads.  Returns the row list; with
    ``check`` (full runs) asserts the §16 acceptance criterion — a NONZERO
    hit rate under the Zipf-overlapping tenant workload on every row.
    """
    here = os.path.dirname(os.path.abspath(__file__))
    src = os.path.join(here, "..", "src")
    if churns is None:
        churns = (churn,)
    rows = []
    for plan, mesh, partitioner in plans:
        for invalidation in invalidations:
            for c in churns:
                env = dict(os.environ)
                env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
                env["XLA_FLAGS"] = (
                    env.get("XLA_FLAGS", "")
                    + f" --xla_force_host_platform_device_count={devices}"
                ).strip()
                cmd = [
                    sys.executable, os.path.abspath(__file__), "--child",
                    "--plan", plan, "--mesh", mesh,
                    "--partitioner", partitioner,
                    "--invalidation", invalidation,
                    "--objects", str(objects), "--tenants", str(tenants),
                    "--pool", str(pool), "--group", str(group),
                    "--lam", str(lam), "--zipf-a", str(zipf_a),
                    "--ticks", str(ticks), "--warmup", str(warmup),
                    "--churn", str(c), "--motion-every", str(motion_every),
                    "--k", str(k), "--chunk", str(chunk),
                ]
                r = subprocess.run(cmd, env=env, capture_output=True,
                                   text=True)
                if r.returncode != 0:
                    raise RuntimeError(
                        f"s9 child (plan={plan}, partitioner={partitioner}, "
                        f"invalidation={invalidation}, churn={c}) failed:\n"
                        + r.stderr[-2000:]
                    )
                row = json.loads(next(
                    l for l in r.stdout.splitlines()
                    if l.startswith("S9ROW ")
                )[6:])
                rows.append(row)
                print(
                    f"s9_soak/{plan}_{partitioner}_{invalidation}_c{c:g},"
                    f"p50={row['p50_ms']:.1f}ms,p95={row['p95_ms']:.1f}ms,"
                    f"p99={row['p99_ms']:.1f}ms,dedup={row['dedup_rate']:.2f},"
                    f"cache={row['cache_rate']:.2f}", flush=True)
    if check:
        for row in rows:
            assert row["hit_rate"] > 0.0, (
                "no dedup/cache hits under the Zipf-overlapping tenant "
                f"workload: {row}"
            )
    if out:
        rec = {
            "schema": 1,
            "unit": "milliseconds",
            "devices": devices,
            "rows": rows,
            "timestamp": time.time(),
        }
        with open(out, "w") as f:
            json.dump(rec, f, indent=2)
        print(f"# wrote {out}", flush=True)
    return rows


def main() -> None:
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    ap = argparse.ArgumentParser()
    ap.add_argument("--child", action="store_true", help=argparse.SUPPRESS)
    ap.add_argument("--plan", default="sharded")
    ap.add_argument("--mesh", default="8",
                    help="mesh shape: '' (single), '8' (1-D) or '2x4'")
    ap.add_argument("--partitioner", default="cost_balanced")
    ap.add_argument("--invalidation", default="epoch",
                    choices=("epoch", "spatial"),
                    help="cache invalidation mode for the child row")
    ap.add_argument("--invalidations", default=None,
                    help="comma list of invalidation modes to sweep "
                         "(e.g. 'epoch,spatial'; default: --invalidation)")
    ap.add_argument("--churns", default=None,
                    help="comma list of churn fractions to sweep "
                         "(e.g. '0.01,0.10'; default: --churn)")
    ap.add_argument("--objects", type=int, default=20_000)
    ap.add_argument("--tenants", type=int, default=16)
    ap.add_argument("--pool", type=int, default=8,
                    help="shared hotspot query-group pool size")
    ap.add_argument("--group", type=int, default=64,
                    help="query rows per pool group")
    ap.add_argument("--lam", type=float, default=4.0,
                    help="Poisson arrival rate (tenant requests per tick)")
    ap.add_argument("--zipf-a", type=float, default=1.2)
    ap.add_argument("--ticks", type=int, default=40)
    ap.add_argument("--warmup", type=int, default=4,
                    help="ticks excluded from the latency/hit accounting")
    ap.add_argument("--churn", type=float, default=0.02)
    ap.add_argument("--motion-every", type=int, default=2,
                    help="teleport a churn-fraction every Nth tick (0 = "
                         "never); non-motion ticks serve from the cache")
    ap.add_argument("--k", type=int, default=16)
    ap.add_argument("--chunk", type=int, default=256)
    ap.add_argument("--devices", type=int, default=DEFAULT_DEVICES)
    ap.add_argument("--no-check", action="store_true",
                    help="skip the nonzero-hit-rate assertion")
    ap.add_argument("--plans", default=None,
                    help="comma list of plan[:mesh[:partitioner]] entries, "
                         "e.g. 'sharded:8:cost_balanced' (default: full "
                         "matrix)")
    ap.add_argument("--out", default="BENCH_soak.json")
    args = ap.parse_args()
    if args.child:
        _child(args)
        return
    plans = (tuple((p.split(":") + ["", "equal"])[:3]
                   for p in args.plans.split(","))
             if args.plans else DEFAULT_PLANS)
    invalidations = (tuple(args.invalidations.split(","))
                     if args.invalidations else (args.invalidation,))
    churns = (tuple(float(c) for c in args.churns.split(","))
              if args.churns else None)
    run(objects=args.objects, tenants=args.tenants, pool=args.pool,
        group=args.group, lam=args.lam, zipf_a=args.zipf_a, ticks=args.ticks,
        warmup=args.warmup, churn=args.churn, motion_every=args.motion_every,
        k=args.k, chunk=args.chunk, plans=plans, invalidations=invalidations,
        churns=churns, devices=args.devices,
        check=not args.no_check, out=args.out)


if __name__ == "__main__":
    main()
