"""S6: serving-path sweep — ingest × submit × collect mode.

The axes introduced by the session API (repro.api, DESIGN.md §11/§14).  The
scenario is the paper's motivating one: a persistent set of monitoring
queries served every tick, while only a *fraction* of the object population
reports a position update per tick.

  snapshot_blocking       — the PR-1/PR-2 contract: TickEngine.process_tick
                            re-uploads the full position snapshot AND re-
                            stages the full query batch every tick, blocking.
  snapshot_overlapped     — KnnSession with full-snapshot ingest but
                            persistent registered queries and one tick of
                            submit-ahead.
  delta_blocking          — KnnSession: device-side scatter of the moved
                            fraction, persistent queries, blocking collect.
  delta_overlapped        — delta ingest + submit τ+1 while τ is in flight:
                            the paper's pipeline (host staging and result
                            readback double-buffered against device compute).
  delta_overlapped_stats  — same pipeline, ``collect="stats"``: the on-device
                            ResultSink aggregates (drift/churn/shard-hit
                            histogram) are all that reaches the host — O(Q)
                            scalars instead of the (Q, k) lists.
  delta_overlapped_none   — ``collect="none"``: nothing beyond the session's
                            two drift-policy scalars crosses the boundary.

Measurement design: each mode serves the identical pre-generated update
stream with the device queue to itself (modes must NOT interleave tick-by-
tick: an overlapped session's in-flight compute would drain inside the next
blocking mode's clock, crediting async modes with the other modes' work —
measured, x=900 nonsense).  Machine-load drift — large on shared CPU hosts
— is cancelled by running the whole mode sequence twice in mirrored (ABBA)
order and pooling, so every mode samples early and late load equally.
Overlapped runs drop the pipeline-fill round (submit-only) and fold the
drained last result into the final round.

Per tick we record the *structural* serving costs (deterministic: bytes
staged host→device, bytes collected device→host) and the decomposed host
times: staging, device-compute drain (``TickHandle.block_until_ready``), and
host collection (``TickResult.collect_s`` — the materialization transfer
ONLY, attributed to the tick that materializes; DESIGN.md §14).  The old
``host_collect`` column conflated the two — on a CPU host, where device
compute shares the cores, it read ~the whole sweep.  On a CPU host the drain
column therefore stays large in every mode and wall-clock gains are bounded
by the staging+collection fraction; on an accelerator the overlapped modes
additionally hide the whole staging pipeline behind compute, and the collect
column is the per-tick PCIe/ICI cost the stats/none modes delete.

  PYTHONPATH=src python benchmarks/s6_serving.py [--objects N] [--ticks T]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time

DEFAULT_UPDATE_FRACTION = 0.05

MODES = ("snapshot_blocking", "snapshot_overlapped",
         "delta_blocking", "delta_overlapped",
         "delta_overlapped_stats", "delta_overlapped_none")


def _mode_axes(mode):
    """mode string -> (ingest, submit, collect)."""
    parts = mode.split("_")
    return parts[0], parts[1], (parts[2] if len(parts) > 2 else "full")


def _frames(n, ticks, fraction, seed, side=22_500.0, max_speed=200.0):
    """Pre-generate (p0, per-tick (moved_ids, moved_pos, full_snapshot)):
    every mode consumes the identical update stream."""
    import numpy as np

    rng = np.random.default_rng(seed)
    p0 = rng.uniform(0, side, (n, 2)).astype(np.float32)
    pos = p0
    m = max(1, int(n * fraction))
    out = []
    for _ in range(ticks - 1):
        ids = rng.choice(n, m, replace=False).astype(np.int32)
        step = rng.uniform(-max_speed, max_speed, (m, 2)).astype(np.float32)
        pos = pos.copy()
        pos[ids] = np.clip(pos[ids] + step, 0, side - 1e-3)
        out.append((ids, pos[ids].copy(), pos))
    return p0, out


class _ModeRunner:
    """One serving mode advanced tick-by-tick (so modes can interleave)."""

    def __init__(self, mode, spec, p0, qpos, qid):
        import warnings

        from repro.api import KnnSession
        from repro.core import TickEngine

        self.mode = mode
        self.ingest, self.submit_mode, self.collect_mode = _mode_axes(mode)
        spec = dataclasses.replace(spec, collect=self.collect_mode)
        self.qpos, self.qid = qpos, qid
        self.pending = None
        self.stage_s = []   # host time staging object/query state
        self.wait_s = []    # host time blocked draining device compute
        self.collect_s = [] # host time materializing results (transfer only)
        self.tick_s = []    # host wall for the whole tick turn
        if mode == "snapshot_blocking":
            with warnings.catch_warnings():
                warnings.simplefilter("ignore", DeprecationWarning)
                self.eng = TickEngine(spec.engine_config())
            self.first = self.eng.process_tick(p0, qpos, qid)
            self.sess = None
        else:
            self.sess = KnnSession(spec)
            self.sess.ingest_objects(p0)
            self.sess.register_queries(qpos, qid)
            self.first = self.sess.submit().result()
        self.compile_s = self.first.compile_s

    def _settle(self, handle):
        """Drain compute (timed as wait), then materialize (collect_s)."""
        tw = time.perf_counter()
        handle.block_until_ready()
        self.wait_s.append(time.perf_counter() - tw)
        res = handle.result()
        self.collect_s.append(res.collect_s)

    def run_tick(self, ids, mpos, snap):
        t0 = time.perf_counter()
        if self.sess is None:  # TickEngine snapshot path: host-blocked throughout
            res = self.eng.process_tick(snap, self.qpos, self.qid)
            self.stage_s.append(0.0)  # not separable from the blocking call
            self.collect_s.append(res.collect_s)
            # the rest of the blocking call is staging+drain, reported as wait
            self.wait_s.append(
                max(0.0, time.perf_counter() - t0 - res.collect_s))
        else:
            if self.ingest == "delta":
                self.sess.update_objects(ids, mpos)
            else:
                self.sess.ingest_objects(snap)
            t1 = time.perf_counter()
            h = self.sess.submit()
            if self.submit_mode == "overlapped":
                if self.pending is not None:
                    self._settle(self.pending)
                self.pending = h
            else:
                self._settle(h)
            self.stage_s.append(t1 - t0)
        self.tick_s.append(time.perf_counter() - t0)

    def drain(self):
        if self.pending is not None:
            t0 = time.perf_counter()
            self._settle(self.pending)
            self.pending = None
            self.tick_s[-1] += time.perf_counter() - t0


def _staged_bytes(mode, n, q_padded, m_padded):
    """Host->device bytes per steady tick (deterministic, not measured)."""
    if mode.startswith("delta"):
        return m_padded * 12  # ids i32 + (x, y) f32
    objects = n * 8
    queries = (q_padded * 12) if mode == "snapshot_blocking" else 0
    return objects + queries  # persistent registry: queries stay on device


def _collected_bytes(collect, nq, q_padded, k, r_total=1, r_obj=1):
    """Device->host bytes per steady tick (deterministic, not measured).

    ``full`` ships the (Q, k) lists (i32 idx + f32 dist) plus the per-shard
    counters; ``stats`` ships the ResultSink aggregates — kth_dist (Qp,) f32,
    four scalar reductions, the (R_o,) shard-hit histogram, n_live — plus the
    same counters; ``none`` ships nothing (the two drift-policy scalars the
    session reads at finalize are mode-independent and excluded throughout).
    """
    counters = r_total * 8  # shard_candidates f32 + shard_iterations i32
    if collect == "none":
        return 0
    if collect == "stats":
        return q_padded * 4 + 4 * 4 + r_obj * 4 + 4 + counters
    return nq * k * 8 + counters


def _check_first_tick_parity(first_results, queries):
    """Every mode served the identical tick-0 batch.

    Full-collect modes compare the (Q, k) lists bitwise.  ``stats`` modes
    never ship the lists; their on-device kth_dist column must still equal
    the full result's k-th distance bitwise (the sink consumes the same
    device arrays).  ``none`` modes ship nothing — structurally nothing to
    compare, but the fields must really be absent.
    """
    import numpy as np

    base = first_results[MODES[0]]
    for mode in MODES[1:]:
        r = first_results[mode]
        collect = _mode_axes(mode)[2]
        if collect == "full":
            np.testing.assert_array_equal(r.nn_idx, base.nn_idx)
            np.testing.assert_array_equal(r.nn_dist, base.nn_dist)
        elif collect == "stats":
            assert r.nn_idx is None and r.nn_dist is None
            np.testing.assert_array_equal(
                np.asarray(r.aggregates.kth_dist)[:queries],
                base.nn_dist[:, -1],
            )
        else:
            assert r.nn_idx is None and r.aggregates is None


def run(
    objects: int = 50_000,
    queries: int | None = None,
    ticks: int = 30,
    k: int = 16,
    chunk: int = 4096,
    window: int = 128,
    update_fraction: float = DEFAULT_UPDATE_FRACTION,
    passes: int = 2,
    precision: str = "fp32",
    merge: str = "dense_merge",
    out: str | None = "BENCH_serving.json",
):
    """Interleaved sweep of the serving modes; returns the row list."""
    import numpy as np

    from repro.api import ServiceSpec
    from repro.core import pad_capacity

    queries = objects if queries is None else queries
    if ticks < 3:
        raise ValueError("need ticks >= 3: one warmup round plus at least "
                         "two measured rounds (overlapped modes drop the "
                         "pipeline-fill round)")
    spec = ServiceSpec(k=k, th_quad=192, l_max=7, window=window, chunk=chunk,
                       precision=precision, merge=merge)
    p0, frames = _frames(objects, ticks, update_fraction, seed=0)
    rng = np.random.default_rng(1)
    qpos = rng.uniform(0, 22_500, (queries, 2)).astype(np.float32)
    qid = np.full((queries,), -2, np.int32)

    # each mode gets the device queue to itself; mirrored (ABBA) passes
    # cancel machine-load drift — every mode samples early and late equally
    order = []
    for p in range(max(1, passes)):
        order += list(MODES) if p % 2 == 0 else list(reversed(MODES))
    pooled = {m: {"tick": [], "stage": [], "wait": [], "collect": [],
                  "compile": None}
              for m in MODES}
    first_results = {}
    for mode in order:
        r = _ModeRunner(mode, spec, p0, qpos, qid)
        if mode not in first_results:
            first_results[mode] = r.first
        for ids, mpos, snap in frames:
            r.run_tick(ids, mpos, snap)
        r.drain()
        # drop the pipeline-fill round of overlapped runs (submit-only,
        # near-zero — it has no collection); drain() folded the deferred
        # final result into the last round, so totals stay honest
        s = slice(1, None) if r.submit_mode == "overlapped" else slice(None)
        pooled[mode]["tick"].extend(r.tick_s[s])
        pooled[mode]["stage"].extend(r.stage_s[s])
        pooled[mode]["wait"].extend(r.wait_s)
        pooled[mode]["collect"].extend(r.collect_s)
        if pooled[mode]["compile"] is None:
            pooled[mode]["compile"] = float(r.compile_s)

    _check_first_tick_parity(first_results, queries)

    q_padded = pad_capacity(queries, chunk)
    m_padded = pad_capacity(max(1, int(objects * update_fraction)),
                            spec.delta_pad)
    base_med = float(np.median(pooled[MODES[0]]["tick"]))
    rows = []
    for mode in MODES:
        ingest, submit_mode, collect = _mode_axes(mode)
        med = float(np.median(pooled[mode]["tick"]))
        rows.append({
            "mode": mode,
            "ingest": ingest,
            "submit": submit_mode,
            "collect": collect,
            "precision": precision,
            "steady_tick_s": med,
            "queries_per_s": queries / med,
            "compile_s_first_tick": pooled[mode]["compile"],
            "host_staging_ms_per_tick": float(
                np.median(pooled[mode]["stage"])) * 1e3,
            "device_drain_ms_per_tick": float(
                np.median(pooled[mode]["wait"])) * 1e3,
            "host_collect_ms_per_tick": float(
                np.median(pooled[mode]["collect"])) * 1e3,
            "staged_bytes_per_tick": _staged_bytes(
                mode, objects, q_padded, m_padded),
            "collected_bytes_per_tick": _collected_bytes(
                collect, queries, q_padded, k),
            "speedup_vs_snapshot_blocking": base_med / med,
        })
        print(f"s6_serving/{mode},{med * 1e6:.1f},"
              f"qps={rows[-1]['queries_per_s']:.0f},"
              f"collect_ms={rows[-1]['host_collect_ms_per_tick']:.2f},"
              f"x={rows[-1]['speedup_vs_snapshot_blocking']:.3f}", flush=True)

    if out:
        rec = {
            "schema": 4,
            "unit": "seconds",
            "objects": objects,
            "queries": queries,
            "ticks": ticks,
            "k": k,
            "update_fraction": update_fraction,
            "passes": passes,
            "precision": precision,
            "merge": merge,
            "schedule": "mirrored passes (each mode isolated per run)",
            "rows": rows,
            "timestamp": time.time(),
        }
        with open(out, "w") as f:
            json.dump(rec, f, indent=2)
        print(f"# wrote {out}", flush=True)
    return rows


def main() -> None:
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    ap = argparse.ArgumentParser()
    ap.add_argument("--objects", type=int, default=50_000)
    ap.add_argument("--queries", type=int, default=None)
    ap.add_argument("--ticks", type=int, default=30)
    ap.add_argument("--k", type=int, default=16)
    ap.add_argument("--chunk", type=int, default=4096)
    ap.add_argument("--window", type=int, default=128)
    ap.add_argument("--update-fraction", type=float,
                    default=DEFAULT_UPDATE_FRACTION)
    ap.add_argument("--passes", type=int, default=2,
                    help="mirrored mode-sequence repetitions (drift cancel)")
    ap.add_argument("--precision", default="fp32",
                    choices=["fp32", "mixed"],
                    help="sweep precision (mixed: bf16 prune + fp32 refine; "
                         "bitwise-identical results, DESIGN.md §14)")
    ap.add_argument("--merge", default="dense_merge",
                    help="MERGE backend for the merge-axis plans")
    ap.add_argument("--out", default="BENCH_serving.json")
    args = ap.parse_args()
    run(objects=args.objects, queries=args.queries, ticks=args.ticks,
        k=args.k, chunk=args.chunk, window=args.window,
        update_fraction=args.update_fraction, passes=args.passes,
        precision=args.precision, merge=args.merge, out=args.out)


if __name__ == "__main__":
    main()
