"""S3 / Fig. 6: varying k x distribution, vs the CPU kd-tree.

Also benches the two result-update strategies: the paper's cached vs coalesced
write duality collapses on TPU (DESIGN.md §3), so the TPU-meaningful contrast
reported here is the lax.top_k merge (XLA path) vs the bucket-kselect kernel
radius pass (Pallas path, interpret-timed on CPU — indicative only).
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core import KDTree, build_index, knn_query_batch_chunked
from repro.data import make_workload
from repro.kernels import bucket_kselect_op

from .common import emit, time_call

CPU_SAMPLE = 500


def run(n=20_000, ks=(4, 32, 128), dists=("uniform", "gaussian")):
    rows = []
    for dist in dists:
        w = make_workload(n, dist, seed=2)
        pts = w.positions()
        qpos, qid = w.query_batch()
        idx = build_index(jnp.asarray(pts), jnp.zeros(2), 22500.0, l_max=8, th_quad=384)
        tree = KDTree(pts, leaf_size=32)
        for k in ks:
            t_pipe = time_call(
                lambda: knn_query_batch_chunked(idx, qpos, qid, k=k, chunk=8192)[0],
                iters=2,
            )
            t0 = time.perf_counter()
            tree.query_batch(qpos[:CPU_SAMPLE], k, qid[:CPU_SAMPLE])
            t_cpu = (time.perf_counter() - t0) / CPU_SAMPLE * n
            emit(f"s3_vary_k/{dist}/k={k}/pipeline", t_pipe, f"speedup={t_cpu / t_pipe:.1f}x")
            rows.append((dist, k, t_pipe, t_cpu))
    return rows


def run_update_strategies(q=256, c=2048, ks=(32, 256)):
    """top_k merge vs fused bucket-kselect radius (the Alabi et al. pillar)."""
    rng = np.random.default_rng(0)
    qpos = jnp.asarray(rng.uniform(0, 1000, (q, 2)), jnp.float32)
    ppos = jnp.asarray(rng.uniform(0, 1000, (c, 2)), jnp.float32)
    import jax

    for k in ks:
        d2 = jnp.sum((qpos[:, None] - ppos[None, :]) ** 2, -1)
        t_topk = time_call(jax.jit(lambda d: jax.lax.top_k(-d, k)), d2, iters=5)
        t_bucket = time_call(
            lambda: bucket_kselect_op(qpos, ppos, k=k), iters=2
        )
        emit(f"s3_update/k={k}/lax_topk", t_topk, "")
        emit(f"s3_update/k={k}/bucket_kselect_interpret", t_bucket, "")


if __name__ == "__main__":
    run()
    run_update_strategies()
