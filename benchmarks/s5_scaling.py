"""S5: plan x mesh-shape x device-count scaling sweep on forced host devices.

The sweep axis of the ExecutionPlan seam (DESIGN.md §10/§12): the same tick
engine runs under every registered plan at FIXED total query load — ``single``
(the one-device reference row), ``sharded`` at 1/2/4/8 devices on the
("query",) mesh, ``object_sharded`` at 1/2/4/8 on the ("object",) mesh (per-
device object state shrinks with the device count — THE object-axis scaling
row the paper's massive datasets need), and ``hybrid`` on 2-D
(query, object) grids (2x2, 2x4, 4x2) — and per-tick latency + candidates/s
are recorded per (plan, mesh_shape, devices) row into ``BENCH_scaling.json``.

Each row runs in a subprocess because ``--xla_force_host_platform_device_count``
must be set before jax initializes.  On a CPU host the forced devices share
the same cores, so this measures the *overhead* of each mesh decomposition
(shard_map fan-out, per-shard index builds, merge tree, psum, gather) rather
than real speedup — the point is that the decompositions are load-bearing
and cheap; accelerator meshes supply the parallelism.

  PYTHONPATH=src python benchmarks/s5_scaling.py [--objects N] [--ticks T]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

DEFAULT_DEVICE_COUNTS = (1, 2, 4, 8)
DEFAULT_HYBRID_SHAPES = ((2, 2), (2, 4), (4, 2))


def _parse_mesh(mesh: str):
    """CLI mesh spec -> EngineConfig.mesh_shape: '' None, '4' int, '2x4' pair."""
    if not mesh:
        return None
    if "x" in mesh:
        q, o = mesh.split("x")
        return (int(q), int(o))
    return int(mesh)


def _child(args) -> None:
    """One (plan, mesh) row; prints a tagged JSON line for the parent."""
    import numpy as np

    from repro.core import EngineConfig, TickEngine
    from repro.data import make_workload

    import jax

    mesh_shape = _parse_mesh(args.mesh) if args.plan != "single" else None
    eng = TickEngine(
        EngineConfig(k=args.k, th_quad=192, l_max=7, window=128,
                     chunk=args.chunk, plan=args.plan, mesh_shape=mesh_shape)
    )
    w = make_workload(args.objects, "gaussian", seed=0)
    results = eng.run(w, ticks=args.ticks)
    steady = [r.wall_s for r in results[1:]]
    cand = float(np.mean([r.candidates for r in results[1:]]))
    tick_s = float(np.median(steady))
    row = {
        "plan": args.plan,
        "mesh_shape": mesh_shape if isinstance(mesh_shape, int) or mesh_shape
        is None else list(mesh_shape),
        "devices": int(jax.device_count()),
        "objects": args.objects,
        "k": args.k,
        "chunk": args.chunk,
        "ticks": args.ticks,
        "tick_s_median": tick_s,
        "queries_per_s": args.objects / tick_s,
        "candidates_per_s": cand / tick_s,
        "candidates_per_tick": cand,
    }
    print("S5ROW " + json.dumps(row), flush=True)


def run(
    objects: int = 8_000,
    ticks: int = 4,
    k: int = 16,
    chunk: int = 1024,
    device_counts=DEFAULT_DEVICE_COUNTS,
    hybrid_shapes=DEFAULT_HYBRID_SHAPES,
    out: str | None = "BENCH_scaling.json",
):
    """Sweep plan x mesh shape at fixed total Q; returns the row list."""
    here = os.path.dirname(os.path.abspath(__file__))
    src = os.path.join(here, "..", "src")
    rows = []
    sweep = (
        [("single", "", 1)]
        + [("sharded", str(d), d) for d in device_counts]
        + [("object_sharded", str(d), d) for d in device_counts]
        + [("hybrid", f"{q}x{o}", q * o) for q, o in hybrid_shapes]
    )
    for plan, mesh, devices in sweep:
        env = dict(os.environ)
        env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
        env["XLA_FLAGS"] = (
            env.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={devices}"
        ).strip()
        cmd = [
            sys.executable, os.path.abspath(__file__), "--child",
            "--plan", plan, "--mesh", mesh,
            "--objects", str(objects), "--ticks", str(ticks),
            "--k", str(k), "--chunk", str(chunk),
        ]
        r = subprocess.run(cmd, env=env, capture_output=True, text=True)
        if r.returncode != 0:
            raise RuntimeError(
                f"s5 child (plan={plan}, mesh={mesh or devices}) failed:\n"
                + r.stderr[-2000:]
            )
        row = json.loads(
            next(l for l in r.stdout.splitlines() if l.startswith("S5ROW "))[6:]
        )
        rows.append(row)
        tag = f"{plan}_{mesh}" if mesh else f"{plan}_d{devices}"
        print(f"s5_scaling/{tag},"
              f"{row['tick_s_median'] * 1e6:.1f},"
              f"qps={row['queries_per_s']:.0f}", flush=True)
    if out:
        rec = {
            "schema": 2,
            "unit": "seconds",
            "fixed_total_queries": objects,
            "rows": rows,
            "timestamp": time.time(),
        }
        with open(out, "w") as f:
            json.dump(rec, f, indent=2)
        print(f"# wrote {out}", flush=True)
    return rows


def main() -> None:
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    ap = argparse.ArgumentParser()
    ap.add_argument("--child", action="store_true", help=argparse.SUPPRESS)
    ap.add_argument("--plan", default="single")
    ap.add_argument("--mesh", default="",
                    help="mesh shape: '4' (1-D plans) or '2x4' (hybrid)")
    ap.add_argument("--objects", type=int, default=8_000)
    ap.add_argument("--ticks", type=int, default=4)
    ap.add_argument("--k", type=int, default=16)
    ap.add_argument("--chunk", type=int, default=1024)
    ap.add_argument("--out", default="BENCH_scaling.json")
    args = ap.parse_args()
    if args.child:
        _child(args)
        return
    run(objects=args.objects, ticks=args.ticks, k=args.k, chunk=args.chunk,
        out=args.out)


if __name__ == "__main__":
    main()
