"""S5: plan x device-count scaling sweep on a forced-host-device CPU mesh.

New axis introduced by the ExecutionPlan refactor (DESIGN.md §10): the same
tick engine is run under the ``single`` plan (the one-device reference row)
and the ``sharded`` plan at 1/2/4/8 forced host devices, at FIXED total query
load, and per-tick latency + candidates/s are recorded per (plan, devices)
row into ``BENCH_scaling.json``.

Each row runs in a subprocess because ``--xla_force_host_platform_device_count``
must be set before jax initializes.  On a CPU host the forced devices share
the same cores, so this measures the *overhead* of the mesh decomposition
(shard_map fan-out, psum, gather) rather than real speedup — the point is
that the decomposition is load-bearing and cheap; accelerator meshes supply
the parallelism.

  PYTHONPATH=src python benchmarks/s5_scaling.py [--objects N] [--ticks T]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

DEFAULT_DEVICE_COUNTS = (1, 2, 4, 8)


def _child(args) -> None:
    """One (plan, devices) row; prints a tagged JSON line for the parent."""
    import numpy as np

    from repro.core import EngineConfig, TickEngine
    from repro.data import make_workload

    import jax

    eng = TickEngine(
        EngineConfig(k=args.k, th_quad=192, l_max=7, window=128,
                     chunk=args.chunk, plan=args.plan,
                     mesh_shape=args.devices if args.plan == "sharded" else None)
    )
    w = make_workload(args.objects, "gaussian", seed=0)
    results = eng.run(w, ticks=args.ticks)
    steady = [r.wall_s for r in results[1:]]
    cand = float(np.mean([r.candidates for r in results[1:]]))
    tick_s = float(np.median(steady))
    row = {
        "plan": args.plan,
        "devices": int(jax.device_count()),
        "objects": args.objects,
        "k": args.k,
        "chunk": args.chunk,
        "ticks": args.ticks,
        "tick_s_median": tick_s,
        "queries_per_s": args.objects / tick_s,
        "candidates_per_s": cand / tick_s,
        "candidates_per_tick": cand,
    }
    print("S5ROW " + json.dumps(row), flush=True)


def run(
    objects: int = 8_000,
    ticks: int = 4,
    k: int = 16,
    chunk: int = 1024,
    device_counts=DEFAULT_DEVICE_COUNTS,
    out: str | None = "BENCH_scaling.json",
):
    """Sweep plan x device count at fixed total Q; returns the row list."""
    here = os.path.dirname(os.path.abspath(__file__))
    src = os.path.join(here, "..", "src")
    rows = []
    sweep = [("single", 1)] + [("sharded", d) for d in device_counts]
    for plan, devices in sweep:
        env = dict(os.environ)
        env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
        env["XLA_FLAGS"] = (
            env.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={devices}"
        ).strip()
        cmd = [
            sys.executable, os.path.abspath(__file__), "--child",
            "--plan", plan, "--devices", str(devices),
            "--objects", str(objects), "--ticks", str(ticks),
            "--k", str(k), "--chunk", str(chunk),
        ]
        r = subprocess.run(cmd, env=env, capture_output=True, text=True)
        if r.returncode != 0:
            raise RuntimeError(
                f"s5 child (plan={plan}, devices={devices}) failed:\n"
                + r.stderr[-2000:]
            )
        row = json.loads(
            next(l for l in r.stdout.splitlines() if l.startswith("S5ROW "))[6:]
        )
        rows.append(row)
        print(f"s5_scaling/{plan}_d{devices},"
              f"{row['tick_s_median'] * 1e6:.1f},"
              f"qps={row['queries_per_s']:.0f}", flush=True)
    if out:
        rec = {
            "schema": 1,
            "unit": "seconds",
            "fixed_total_queries": objects,
            "rows": rows,
            "timestamp": time.time(),
        }
        with open(out, "w") as f:
            json.dump(rec, f, indent=2)
        print(f"# wrote {out}", flush=True)
    return rows


def main() -> None:
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    ap = argparse.ArgumentParser()
    ap.add_argument("--child", action="store_true", help=argparse.SUPPRESS)
    ap.add_argument("--plan", default="single")
    ap.add_argument("--devices", type=int, default=1)
    ap.add_argument("--objects", type=int, default=8_000)
    ap.add_argument("--ticks", type=int, default=4)
    ap.add_argument("--k", type=int, default=16)
    ap.add_argument("--chunk", type=int, default=1024)
    ap.add_argument("--out", default="BENCH_scaling.json")
    args = ap.parse_args()
    if args.child:
        _child(args)
        return
    run(objects=args.objects, ticks=args.ticks, k=args.k, chunk=args.chunk,
        out=args.out)


if __name__ == "__main__":
    main()
