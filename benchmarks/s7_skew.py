"""S7: Zipf exponent x plan x partitioner — the straggler-gap sweep.

The acceptance probe of the cost-balanced partitioning seam (DESIGN.md §13):
a Zipf-skewed moving-object workload is served on a forced 8-device host
grid under every mesh plan (``sharded`` 8-way, ``object_sharded`` 8-way,
``hybrid`` 2x4) x partitioner (``equal`` | ``cost_balanced``), through the
session API (persistent queries + delta object updates, so the measured-work
EMA feedback loop is live).  Per row we record:

* ``gap_mean`` / ``gap_max`` — the straggler gap, max/mean per-shard
  candidate volume (``TickResult.shard_candidates``; 1.0 = perfectly
  balanced, 8.0 = one device does everything) over the steady ticks;
* ``tick_s_median`` — wall per tick (on a CPU host the forced devices share
  cores, so this shows the *overhead* of boundary computation + masked
  capacity slack, not real speedup — the gap column is what an accelerator
  mesh converts to wall-clock);
* ``bit_identical`` — every tick's results compared bitwise against a
  lockstep ``single``-plan session (the §12/§13 contract, asserted).

Each row runs in a subprocess because
``--xla_force_host_platform_device_count`` must be set before jax init.

  PYTHONPATH=src python benchmarks/s7_skew.py [--objects N] [--ticks T]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

DEFAULT_EXPONENTS = (1.2, 1.6)
DEFAULT_PLANS = (("sharded", "8"), ("object_sharded", "8"), ("hybrid", "2x4"))
DEFAULT_DEVICES = 8


def _parse_mesh(mesh: str):
    if not mesh:
        return None
    if "x" in mesh:
        q, o = mesh.split("x")
        return (int(q), int(o))
    return int(mesh)


def _child(args) -> None:
    """One (zipf_a, plan, partitioner) row; prints a tagged JSON line."""
    import numpy as np

    import jax

    from repro.api import KnnSession, ServiceSpec
    from repro.core import straggler_gap
    from repro.data import make_workload

    def session(plan, mesh, partitioner):
        return KnnSession(ServiceSpec(
            k=args.k, th_quad=96, l_max=7, window=128, chunk=args.chunk,
            plan=plan, mesh_shape=mesh, partitioner=partitioner,
        ))

    w = make_workload(args.objects, "zipf", seed=0, zipf_a=args.zipf_a,
                      hotspot_sigma_frac=0.003)
    qid = np.arange(args.objects, dtype=np.int32)
    sess = session(args.plan, _parse_mesh(args.mesh), args.partitioner)
    ref = session("single", None, "equal")
    pts = w.positions()
    for s in (sess, ref):
        s.ingest_objects(pts)
    hq = sess.register_queries(pts, qid)
    hr = ref.register_queries(pts, qid)

    gaps, walls, cands, bit_identical = [], [], [], True
    for t in range(args.ticks):
        r = sess.submit().result()
        r_ref = ref.submit().result()
        bit_identical &= bool(
            np.array_equal(r.nn_idx, r_ref.nn_idx)
            and np.array_equal(r.nn_dist, r_ref.nn_dist)
        )
        assert bit_identical, f"tick {t}: results diverged from single"
        if t >= 1:  # skip the build+compile tick
            gaps.append(straggler_gap(r.shard_candidates))
            walls.append(r.wall_s)
            cands.append(r.candidates)
        w.advance()
        pts = w.positions()
        sess.update_objects(qid, pts)
        sess.update_queries(hq, pts)
        ref.update_objects(qid, pts)
        ref.update_queries(hr, pts)

    row = {
        "zipf_a": args.zipf_a,
        "plan": args.plan,
        "mesh": args.mesh,
        "partitioner": args.partitioner,
        "devices": int(jax.device_count()),
        "objects": args.objects,
        "ticks": args.ticks,
        "k": args.k,
        "chunk": args.chunk,
        "gap_mean": float(np.mean(gaps)),
        "gap_max": float(np.max(gaps)),
        "tick_s_median": float(np.median(walls)),
        "candidates_per_tick": float(np.mean(cands)),
        "bit_identical": bit_identical,
    }
    print("S7ROW " + json.dumps(row), flush=True)


def run(
    objects: int = 4_096,
    ticks: int = 4,
    k: int = 8,
    chunk: int = 128,
    exponents=DEFAULT_EXPONENTS,
    plans=DEFAULT_PLANS,
    devices: int = DEFAULT_DEVICES,
    out: str | None = "BENCH_skew.json",
):
    """Sweep zipf_a x plan x partitioner on forced host devices.

    Returns the row list; the JSON artifact additionally carries a
    per-(zipf_a, plan) summary with the equal -> cost_balanced gap ratio —
    the headline number (>1 = cost_balanced is better balanced).
    """
    here = os.path.dirname(os.path.abspath(__file__))
    src = os.path.join(here, "..", "src")
    rows = []
    for zipf_a in exponents:
        for plan, mesh in plans:
            for partitioner in ("equal", "cost_balanced"):
                env = dict(os.environ)
                env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
                env["XLA_FLAGS"] = (
                    env.get("XLA_FLAGS", "")
                    + f" --xla_force_host_platform_device_count={devices}"
                ).strip()
                cmd = [
                    sys.executable, os.path.abspath(__file__), "--child",
                    "--plan", plan, "--mesh", mesh,
                    "--partitioner", partitioner,
                    "--zipf-a", str(zipf_a),
                    "--objects", str(objects), "--ticks", str(ticks),
                    "--k", str(k), "--chunk", str(chunk),
                ]
                r = subprocess.run(cmd, env=env, capture_output=True,
                                   text=True)
                if r.returncode != 0:
                    raise RuntimeError(
                        f"s7 child (zipf_a={zipf_a}, plan={plan}, "
                        f"partitioner={partitioner}) failed:\n"
                        + r.stderr[-2000:]
                    )
                row = json.loads(next(
                    l for l in r.stdout.splitlines() if l.startswith("S7ROW ")
                )[6:])
                rows.append(row)
                print(f"s7_skew/a{zipf_a}_{plan}_{partitioner},"
                      f"{row['tick_s_median'] * 1e6:.1f},"
                      f"gap={row['gap_mean']:.3f}", flush=True)

    summary = []
    for zipf_a in exponents:
        for plan, _ in plans:
            pair = {
                row["partitioner"]: row for row in rows
                if row["zipf_a"] == zipf_a and row["plan"] == plan
            }
            summary.append({
                "zipf_a": zipf_a,
                "plan": plan,
                "gap_equal": pair["equal"]["gap_mean"],
                "gap_cost_balanced": pair["cost_balanced"]["gap_mean"],
                "gap_ratio": pair["equal"]["gap_mean"]
                / pair["cost_balanced"]["gap_mean"],
            })
    # the acceptance criterion: cost_balanced tightens the gap on at least
    # one sharded plan at every exponent.  Needs balancing freedom: with
    # fewer than ~2 chunks per device (objects/chunk <= devices) contiguous
    # chunk-granular boundaries cannot move and the ratio degenerates to 1.
    for zipf_a in exponents:
        assert any(s["gap_ratio"] > 1.0 for s in summary
                   if s["zipf_a"] == zipf_a), (
            f"no plan improved at zipf_a={zipf_a} — if objects/chunk "
            f"({objects}/{chunk}) is close to the device count "
            f"({devices}), boundaries have no freedom to move; "
            f"{summary}")
    if out:
        rec = {
            "schema": 1,
            "unit": "seconds",
            "devices": devices,
            "rows": rows,
            "summary": summary,
            "timestamp": time.time(),
        }
        with open(out, "w") as f:
            json.dump(rec, f, indent=2)
        print(f"# wrote {out}", flush=True)
    return rows


def main() -> None:
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    ap = argparse.ArgumentParser()
    ap.add_argument("--child", action="store_true", help=argparse.SUPPRESS)
    ap.add_argument("--plan", default="sharded")
    ap.add_argument("--mesh", default="8",
                    help="mesh shape: '8' (1-D plans) or '2x4' (hybrid)")
    ap.add_argument("--partitioner", default="equal")
    ap.add_argument("--zipf-a", type=float, default=1.6)
    ap.add_argument("--objects", type=int, default=4_096)
    ap.add_argument("--ticks", type=int, default=4)
    ap.add_argument("--k", type=int, default=8)
    ap.add_argument("--chunk", type=int, default=128)
    ap.add_argument("--out", default="BENCH_skew.json")
    args = ap.parse_args()
    if args.child:
        _child(args)
        return
    run(objects=args.objects, ticks=args.ticks, k=args.k, chunk=args.chunk,
        out=args.out)


if __name__ == "__main__":
    main()
