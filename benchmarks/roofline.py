"""Per-stage roofline for the serving tick (DESIGN.md §14).

Decomposes one steady tick into the four pipeline stages and puts each on a
roofline: bytes moved, FLOPs, arithmetic intensity, and the memory-bound /
compute-bound time under a configurable machine model.  Volumes come from
the workload parameters plus MEASURED per-tick counters (candidate volume,
iteration counts, ``TickResult.collect_s``) from a short live session — not
from guessed densities — so the table justifies each optimisation against
hardware limits rather than vibes:

  reindex — split into recode / sort / pyramid sub-bars, each modeled for
            BOTH maintenance modes (DESIGN.md §15): the rebuild column pays
            O(N) in every sub-bar (encode all N, full comparison sort,
            full bincount); the incremental column pays the recode and
            pyramid bars in Δ (the measured ``update_fraction`` of N) and
            the sort bar in Δ log Δ + Δ log N search traffic plus the two
            O(N) cumsums and output gathers of the sparse splice plan.
            All sub-bars are bandwidth-bound — the delta path's win is
            staging bytes, not arithmetic, and the table shows exactly
            which bytes stop scaling with N.  The object-mesh plans
            (object_sharded / hybrid) add a PER-PLAN pair of bars for the
            device-local tree refresh the shard_map body runs each tick:
            ``local-rebuild`` re-sorts each ceil(N/R)-row slice
            (``build_index`` per device), ``local-derived`` reuses the
            spliced global order — masked slice + interval pyramid off the
            global starts (``core.plan._local_index_derived``), a fixed
            O(4**l_max) cost with NO sort bytes, which is the sharded
            maintenance win.
  sweep   — the distance/prune pass over the measured candidate volume.
            fp32 reads 12 B/candidate; ``precision="mixed"`` reads bf16
            positions (8 B/candidate with the id) and re-ranks only the
            widened-boundary survivors in fp32 — the table carries both
            variants so the bf16 pass is justified by its bytes column.
  merge   — the R-way per-shard top-k list reduction.  Modeled both as the
            binary merge tree (intermediate lists round-trip HBM between
            MERGE calls) and as the fused single-pass multi-way kernel
            (``merge="fused_multi"``: partial lists read once) — the bytes
            ratio ≈ 3(R−1)/(R+1) is the fusion's justification.
  collect — device→host result delivery per ``collect`` mode (structural
            bytes, same model as s6_serving) with the measured per-tick
            ``collect_s`` alongside, so achieved transfer cost is visible
            next to the modeled one.

  PYTHONPATH=src python benchmarks/roofline.py [--objects N] [--queries Q]
      [--peak-gflops F] [--peak-gbs B] [--obj-shards R]
      [--out ROOFLINE_stages.json]

The machine peaks default to generic CPU-host numbers; pass the target
accelerator's to move the ridge point.  The stage *volumes* are machine-
independent.
"""
from __future__ import annotations

import argparse
import json
import math
import os
import sys
import time

COLLECT_MODES = ("full", "stats", "none")


def _measure(objects, queries, ticks, k, chunk, window, update_fraction):
    """Short live session per collect mode: measured counters, not guesses.

    Returns (candidates_per_tick, iterations_per_tick,
    collect_ms_per_tick[mode], steady_tick_s) — candidate volume is identical
    across collect modes (same sweep), so it is taken from the "full" run.
    """
    import numpy as np

    from repro.api import KnnSession, ServiceSpec

    rng = np.random.default_rng(0)
    p0 = rng.uniform(0, 22_500, (objects, 2)).astype(np.float32)
    qpos = rng.uniform(0, 22_500, (queries, 2)).astype(np.float32)
    qid = np.full((queries,), -2, np.int32)
    m = max(1, int(objects * update_fraction))

    collect_ms = {}
    cand = iters = steady = None
    for mode in COLLECT_MODES:
        spec = ServiceSpec(k=k, th_quad=192, l_max=7, window=window,
                           chunk=chunk, collect=mode)
        sess = KnnSession(spec)
        sess.ingest_objects(p0)
        sess.register_queries(qpos, qid)
        sess.submit().result()  # compile + warmup tick
        cs, ts, cands, its = [], [], [], []
        for _ in range(ticks):
            ids = rng.choice(objects, m, replace=False).astype(np.int32)
            step = rng.uniform(-200, 200, (m, 2)).astype(np.float32)
            t0 = time.perf_counter()
            sess.update_objects(ids, np.clip(p0[ids] + step, 0, 22_499.0))
            h = sess.submit()
            h.block_until_ready()
            res = h.result()
            ts.append(time.perf_counter() - t0)
            cs.append(res.collect_s)
            cands.append(res.candidates)
            its.append(res.iterations)
        collect_ms[mode] = float(np.median(cs)) * 1e3
        if mode == "full":
            cand = float(np.median(cands))
            iters = float(np.median(its))
            steady = float(np.median(ts))
    return cand, iters, collect_ms, steady


def _collected_bytes(collect, nq, q_padded, k, r_total=1, r_obj=1):
    """Structural device->host bytes (kept in sync with s6_serving)."""
    counters = r_total * 8
    if collect == "none":
        return 0
    if collect == "stats":
        return q_padded * 4 + 4 * 4 + r_obj * 4 + 4 + counters
    return nq * k * 8 + counters


def _reindex_stages(n, delta_rows, l_max):
    """The reindex stage split into recode/sort/pyramid sub-bars, modeled
    for both maintenance modes.  Rebuild pays O(N) everywhere; incremental
    (the sparse splice plan, DESIGN.md §15) pays Δ in the recode and
    pyramid bars and Δ·log + two O(N) cumsums + O(N) output gathers in the
    sort bar — the residual O(N) terms are gather/cumsum streams, not sort
    passes, which is the whole win."""
    d = delta_rows
    pyr = (4 ** (l_max + 1) - 1) // 3  # flattened count-pyramid cells
    log_n = max(1, math.ceil(math.log2(max(n, 2))))
    sort_passes = max(1, math.ceil(log_n / 8))
    sort_passes_d = max(1, math.ceil(math.log2(max(d, 2)) / 8))
    return [
        # recode: read (x,y) f32, write code i32, ~30 bit-ops/pt.  The
        # incremental path encodes each moved row twice (old + new key).
        {
            "stage": "reindex[rebuild:recode]",
            "bytes": n * 12,
            "flops": n * 30,
            "model": f"morton encode all N={n}",
        },
        {
            "stage": "reindex[incremental:recode]",
            "bytes": 2 * d * 12,
            "flops": 2 * d * 30,
            "model": f"old+new codes for the D={d} moved rows only",
        },
        # sort: radix-style byte digits, read+write 8 B/pt/pass over
        # (code, id) pairs, then gather-reorder pos+ids (12 B/pt r+w).
        {
            "stage": "reindex[rebuild:sort]",
            "bytes": sort_passes * 2 * n * 8 + 2 * n * 12,
            "flops": n * log_n,
            "model": f"{sort_passes}-pass sort of N pairs + gather reorder",
        },
        # incremental: sort just the Δ run, binary-search 2Δ keys against
        # the N-row order (log N gathers of 8 B each), then the sparse
        # splice plan's two O(N) cumsums (i32 r+w) and the O(N) output
        # gathers of pos+ids+codes (16 B read + write per row).
        {
            "stage": "reindex[incremental:sort]",
            "bytes": (sort_passes_d * 2 * d * 8 + 2 * d * log_n * 8
                      + 2 * 2 * n * 8 + 2 * n * 16),
            "flops": 4 * d * log_n + 2 * n,
            "model": (f"D-run sort + 2D searches (log2 N = {log_n}) + "
                      "2 cumsums + output gathers, all O(N) terms "
                      "streaming"),
        },
        # pyramid: counts at the fine level + l_max reshape-sum rollups +
        # the starts cumsum.  Rebuild bincounts all N codes; incremental
        # scatter-adds ±1 at 2Δ fine cells — the rollup cost is fixed.
        {
            "stage": "reindex[rebuild:pyramid]",
            "bytes": n * 4 + 3 * pyr * 4,
            "flops": n + 2 * pyr,
            "model": f"bincount over N + {l_max}-level rollup + starts",
        },
        {
            "stage": "reindex[incremental:pyramid]",
            "bytes": 2 * d * 4 + 3 * pyr * 4,
            "flops": 2 * d + 2 * pyr,
            "model": f"±1 scatters at 2D fine cells + fixed rollup ({pyr} "
                     "pyramid cells)",
        },
    ]


def _local_tree_stages(n, r_obj, l_max):
    """The object-mesh plans' per-device local-tree refresh, both paths.

    Under ``maintenance="rebuild"`` every device re-derives its quadtree
    from its ceil(N/R)-row Morton slice each tick: encode + stable sort +
    bincount over the slice (``core.plan._local_index``).  Under
    ``"incremental"``/``"skip"`` the globally spliced order is already
    current, so the local tree is DERIVED: both paths stream the slice
    (dynamic_slice + mask), but the derived one replaces the per-device
    sort with an interval pyramid off the global prefix offsets
    (``_local_index_derived``) — a fixed O(4**l_max) gather/rollup whose
    bytes do not scale with N/R.  Devices run concurrently, so one shard's
    volume is the per-tick stage volume."""
    if r_obj <= 1:
        return []
    nr = -(-n // r_obj)
    pyr = (4 ** (l_max + 1) - 1) // 3
    fine = 4 ** l_max
    log_nr = max(1, math.ceil(math.log2(max(nr, 2))))
    passes = max(1, math.ceil(log_nr / 8))
    # both paths carve + mask the (pos, id, code) slice: read + write
    slice_bytes = 2 * nr * (12 + 4 + 4)
    return [
        {
            "stage": f"reindex[local-rebuild,R={r_obj}]",
            "bytes": (slice_bytes + nr * 12 + passes * 2 * nr * 8
                      + 2 * nr * 12 + nr * 4 + 3 * pyr * 4),
            "flops": nr * 30 + nr * log_nr + nr + 2 * pyr,
            "model": (f"per device: encode + {passes}-pass sort + bincount "
                      f"over its ceil(N/R)={nr}-row slice"),
        },
        {
            "stage": f"reindex[local-derived,R={r_obj}]",
            "bytes": slice_bytes + (fine + 1) * 8 + 3 * pyr * 4,
            "flops": 2 * fine + 2 * pyr,
            "model": (f"per device: masked slice + interval pyramid off the "
                      f"global starts ({fine} fine cells), no sort"),
        },
    ]


def build_stages(objects, queries, q_padded, k, candidates, r_obj,
                 collect_ms, delta_rows, l_max):
    """The per-stage (bytes, flops) volumes.  Every count is a documented
    first-order model over workload parameters + measured counters."""
    n, c = objects, candidates
    stages = []
    stages.extend(_reindex_stages(n, delta_rows, l_max))
    stages.extend(_local_tree_stages(n, r_obj, l_max))

    # sweep: per candidate read the (x,y) position + id, ~8 flops
    # (2 sub, 2 mul, 1 add, compare + amortized selection update)
    stages.append({
        "stage": "sweep[fp32]",
        "bytes": int(c * 12),
        "flops": int(c * 8),
        "model": f"measured candidates/tick C={c:.0f}, 12 B + 8 flop each",
    })
    # mixed: the bf16 prune reads half the position bytes; the exact refine
    # re-reads fp32 rows only for in-boundary survivors — structurally
    # bounded by ~2 boundary shells of k per query (DESIGN.md §14)
    refine = min(c, 2.0 * queries * k)
    stages.append({
        "stage": "sweep[mixed]",
        "bytes": int(c * 8 + refine * 12),
        "flops": int(c * 8 + refine * 8),
        "model": f"bf16 prune over C + fp32 refine over <= 2Qk={refine:.0f}",
    })

    # merge: R-way reduction of per-shard (Q, k) lists, 8 B/entry; both
    # variants do the same ~2k compare/select work per query per reduction
    # step — the fusion's win is list bytes not round-tripping HBM
    lists = q_padded * k * 8
    merge_flops = int(q_padded * 2 * k * max(r_obj - 1, 0))
    stages.append({
        "stage": f"merge[tree,R={r_obj}]",
        "bytes": int(lists * 3 * max(r_obj - 1, 0)),
        "flops": merge_flops,
        "model": "binary tree: each of R-1 merges reads 2 + writes 1 list",
    })
    stages.append({
        "stage": f"merge[fused,R={r_obj}]",
        "bytes": int(lists * (r_obj + 1)) if r_obj > 1 else 0,
        "flops": merge_flops,
        "model": "fused multi-way: R lists read once, 1 written "
                 "(merge='fused_multi')",
    })

    # collect: structural transfer bytes per mode + the measured cost
    for mode in COLLECT_MODES:
        stages.append({
            "stage": f"collect[{mode}]",
            "bytes": _collected_bytes(mode, queries, q_padded, k,
                                      r_obj=r_obj),
            "flops": 0,
            "measured_ms": collect_ms.get(mode),
            "model": "structural device->host bytes (s6_serving model)",
        })
    return stages


def annotate(stages, peak_gflops, peak_gbs):
    """Roofline arithmetic: bound times + dominant limit per stage."""
    for s in stages:
        t_mem = s["bytes"] / (peak_gbs * 1e9)
        t_flop = s["flops"] / (peak_gflops * 1e9)
        s["intensity_flops_per_byte"] = (
            s["flops"] / s["bytes"] if s["bytes"] else 0.0)
        s["memory_s"] = t_mem
        s["compute_s"] = t_flop
        s["bound_s"] = max(t_mem, t_flop)
        s["dominant"] = "memory" if t_mem >= t_flop else "compute"
    return stages


def fmt_table(stages):
    hdr = (f"{'stage':28s} {'MB':>9s} {'MFLOP':>9s} {'F/B':>7s} "
           f"{'mem_ms':>8s} {'cmp_ms':>8s} {'bound':>7s} {'meas_ms':>8s}")
    rows = [hdr, "-" * len(hdr)]
    for s in stages:
        meas = s.get("measured_ms")
        meas_str = f"{meas:8.3f}" if meas is not None else f"{'—':>8s}"
        rows.append(
            f"{s['stage']:28s} {s['bytes'] / 1e6:9.3f} "
            f"{s['flops'] / 1e6:9.2f} {s['intensity_flops_per_byte']:7.2f} "
            f"{s['memory_s'] * 1e3:8.3f} {s['compute_s'] * 1e3:8.3f} "
            f"{s['dominant']:>7s} {meas_str}"
        )
    return "\n".join(rows)


def run(
    objects: int = 50_000,
    queries: int = 4_096,
    ticks: int = 5,
    k: int = 16,
    chunk: int = 4_096,
    window: int = 128,
    update_fraction: float = 0.05,
    obj_shards: int = 8,
    peak_gflops: float = 100.0,
    peak_gbs: float = 25.0,
    out: str | None = "ROOFLINE_stages.json",
):
    from repro.core import pad_capacity

    cand, iters, collect_ms, steady = _measure(
        objects, queries, ticks, k, chunk, window, update_fraction)
    q_padded = pad_capacity(queries, chunk)
    delta_rows = max(1, int(objects * update_fraction))  # same Δ _measure moves
    stages = annotate(
        build_stages(objects, queries, q_padded, k, cand, obj_shards,
                     collect_ms, delta_rows, l_max=7),
        peak_gflops, peak_gbs,
    )
    print(f"per-stage roofline: N={objects} Q={queries} k={k} "
          f"C/tick={cand:.0f} iters={iters:.0f} "
          f"steady={steady * 1e3:.1f} ms (measured, collect=full) "
          f"@ {peak_gflops:.0f} GFLOP/s, {peak_gbs:.0f} GB/s")
    print(fmt_table(stages))
    if out:
        rec = {
            "schema": 3,  # schema 3: + per-plan local-tree refresh bars
            # (local-rebuild vs local-derived for the object-mesh plans);
            # schema 2 split reindex into recode/sort/pyramid sub-bars
            # x rebuild/incremental (delta-aware volumes)
            "objects": objects, "queries": queries, "k": k, "chunk": chunk,
            "window": window, "ticks": ticks,
            "update_fraction": update_fraction,
            "delta_rows_modeled": delta_rows,
            "obj_shards_modeled": obj_shards,
            "peak_gflops": peak_gflops, "peak_gbs": peak_gbs,
            "measured": {
                "candidates_per_tick": cand,
                "iterations_per_tick": iters,
                "steady_tick_s_full": steady,
                "collect_ms_per_tick": collect_ms,
            },
            "stages": stages,
            "timestamp": time.time(),
        }
        with open(out, "w") as f:
            json.dump(rec, f, indent=2)
        print(f"# wrote {out}", flush=True)
    return stages


def main(argv=None) -> int:
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    ap = argparse.ArgumentParser()
    ap.add_argument("--objects", type=int, default=50_000)
    ap.add_argument("--queries", type=int, default=4_096)
    ap.add_argument("--ticks", type=int, default=5)
    ap.add_argument("--k", type=int, default=16)
    ap.add_argument("--chunk", type=int, default=4_096)
    ap.add_argument("--window", type=int, default=128)
    ap.add_argument("--update-fraction", type=float, default=0.05)
    ap.add_argument("--obj-shards", type=int, default=8,
                    help="R for the merge-stage model (the object-axis "
                         "shard count the tree/fused comparison assumes)")
    ap.add_argument("--peak-gflops", type=float, default=100.0)
    ap.add_argument("--peak-gbs", type=float, default=25.0)
    ap.add_argument("--out", default="ROOFLINE_stages.json")
    args = ap.parse_args(argv)
    run(objects=args.objects, queries=args.queries, ticks=args.ticks,
        k=args.k, chunk=args.chunk, window=args.window,
        update_fraction=args.update_fraction, obj_shards=args.obj_shards,
        peak_gflops=args.peak_gflops, peak_gbs=args.peak_gbs, out=args.out)
    return 0


if __name__ == "__main__":
    sys.exit(main())
