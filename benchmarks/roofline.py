"""Roofline table from dry-run JSONL records (EXPERIMENTS.md §Roofline source).

Usage:
  PYTHONPATH=src python -m benchmarks.roofline results/dryrun_baseline.jsonl
"""
from __future__ import annotations

import json
import sys


def load(path: str):
    recs = []
    with open(path) as f:
        for line in f:
            recs.append(json.loads(line))
    return recs


def fmt_table(recs, mesh: str | None = "16x16"):
    rows = []
    hdr = (
        f"{'arch':26s} {'shape':12s} {'mesh':8s} {'compute_s':>10s} {'memory_s':>10s} "
        f"{'coll_s':>10s} {'dom':>10s} {'GB/dev':>8s} {'useful':>7s}"
    )
    rows.append(hdr)
    rows.append("-" * len(hdr))
    for r in recs:
        if mesh and r.get("mesh") != mesh:
            continue
        if r["status"] == "skip":
            rows.append(f"{r['arch']:26s} {r['shape']:12s} {r['mesh']:8s} {'— skipped: ' + r['reason']}")
            continue
        if r["status"] != "ok":
            rows.append(f"{r['arch']:26s} {r['shape']:12s} {r['mesh']:8s} ERROR {r.get('error','')[:60]}")
            continue
        t = r["roofline"]
        mem = r.get("memory", {}).get("bytes_per_device", 0) / 1e9
        rows.append(
            f"{r['arch']:26s} {r['shape']:12s} {r['mesh']:8s} {t['compute_s']:10.4f} "
            f"{t['memory_s']:10.4f} {t['collective_s']:10.4f} {t['dominant']:>10s} "
            f"{mem:8.1f} {t.get('useful_flops_ratio', 0):7.3f}"
        )
    return "\n".join(rows)


def main(argv=None):
    args = argv or sys.argv[1:]
    path = args[0] if args else "results/dryrun_baseline.jsonl"
    recs = load(path)
    for mesh in ("16x16", "2x16x16"):
        print(f"\n=== mesh {mesh} ===")
        print(fmt_table(recs, mesh))
    return 0


if __name__ == "__main__":
    sys.exit(main())
