"""Per-kernel microbenches (interpret mode on CPU — correctness-path timing;
the TPU numbers come from the dry-run roofline, not from these)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.kernels import bucket_kselect_op, pairwise_dist_op, topk_select_op

from .common import emit, time_call


def run(q=256, c=1024, k=32):
    rng = np.random.default_rng(0)
    qpos = jnp.asarray(rng.uniform(0, 1000, (q, 2)), jnp.float32)
    ppos = jnp.asarray(rng.uniform(0, 1000, (c, 2)), jnp.float32)
    d2 = jnp.sum((qpos[:, None] - ppos[None, :]) ** 2, -1)
    ids = jnp.tile(jnp.arange(c, dtype=jnp.int32)[None], (q, 1))
    emit("kernels/pairwise_dist", time_call(lambda: pairwise_dist_op(qpos, ppos), iters=2),
         f"{q}x{c}")
    emit("kernels/bucket_kselect", time_call(lambda: bucket_kselect_op(qpos, ppos, k=k), iters=2),
         f"{q}x{c},k={k}")
    emit("kernels/topk_select", time_call(lambda: topk_select_op(d2, ids, k=k), iters=2),
         f"{q}x{c},k={k}")


if __name__ == "__main__":
    run()
