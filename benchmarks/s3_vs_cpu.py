"""S3 / Fig. 5: K-NN_GPU vs K-NN_CPU (sequential kd-tree), varying N x skew.

The CPU competitor answers a 1000-query subsample (sequential best-first
kd-tree, leaf 32 as in the paper) and is extrapolated to the full batch —
the paper runs FLANN on everything; our python kd-tree is the same algorithmic
class but interpreter-bound, so the derived column reports per-query costs.
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core import KDTree, build_index, knn_query_batch_chunked
from repro.data import make_workload

from .common import emit, time_call

CPU_SAMPLE = 1000


def run(ns=(20_000, 60_000), dists=("uniform", "gaussian"), k=32):
    rows = []
    for dist in dists:
        for n in ns:
            w = make_workload(n, dist, seed=1)
            pts = w.positions()
            qpos, qid = w.query_batch()
            idx = build_index(jnp.asarray(pts), jnp.zeros(2), 22500.0, l_max=8, th_quad=384)
            t_pipe = time_call(
                lambda: knn_query_batch_chunked(idx, qpos, qid, k=k, chunk=8192)[0],
                iters=2,
            )
            tree = KDTree(pts, leaf_size=32)
            t0 = time.perf_counter()
            tree.query_batch(qpos[:CPU_SAMPLE], k, qid[:CPU_SAMPLE])
            t_cpu = (time.perf_counter() - t0) / CPU_SAMPLE * n
            emit(
                f"s3_vs_cpu/{dist}/N={n}/pipeline",
                t_pipe,
                f"speedup={t_cpu / t_pipe:.1f}x",
            )
            emit(f"s3_vs_cpu/{dist}/N={n}/kdtree_cpu", t_cpu, f"{t_cpu / n * 1e6:.0f} us/q")
            rows.append((dist, n, t_pipe, t_cpu))
    return rows


if __name__ == "__main__":
    run()
