"""S2 / Fig. 4: K-NN_GPU (indexed pipeline) vs K-NN_BASELINE (Garcia brute force).

Left plot: vary object count at k=32 — the pipeline pulls ahead as N grows.
Right plot: vary k at fixed N — the brute-force cost is k-independent while the
pipeline's grows, shrinking (but per the paper, not closing) the gap.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import build_index, knn_bruteforce_chunked, knn_query_batch_chunked
from repro.data import make_workload

from .common import emit, time_call


def _setup(n, seed=0):
    w = make_workload(n, "uniform", seed=seed)
    pts = w.positions()
    qpos, qid = w.query_batch()
    idx = build_index(jnp.asarray(pts), jnp.zeros(2), 22500.0, l_max=8, th_quad=384)
    return pts, qpos, qid, idx


def run_vary_n(ns=(5_000, 20_000, 60_000), k=32, backend="dense_topk"):
    rows = []
    tag = "" if backend == "dense_topk" else f"/{backend}"
    for n in ns:
        pts, qpos, qid, idx = _setup(n)
        t_pipe = time_call(
            lambda: knn_query_batch_chunked(
                idx, qpos, qid, k=k, chunk=8192, backend=backend
            )[0],
            iters=2,
        )
        t_bf = time_call(
            lambda: knn_bruteforce_chunked(pts, qpos, qid, k=k, chunk=2048)[0], iters=2
        )
        emit(
            f"s2_vs_baseline/N={n}/pipeline{tag}", t_pipe,
            f"speedup={t_bf / t_pipe:.1f}x",
        )
        emit(f"s2_vs_baseline/N={n}/bruteforce", t_bf, "")
        rows.append((n, t_pipe, t_bf))
    return rows


def run_vary_k(n=20_000, ks=(4, 32, 128, 256), backend="dense_topk"):
    rows = []
    tag = "" if backend == "dense_topk" else f"/{backend}"
    pts, qpos, qid, idx = _setup(n)
    for k in ks:
        t_pipe = time_call(
            lambda: knn_query_batch_chunked(
                idx, qpos, qid, k=k, chunk=8192, backend=backend
            )[0],
            iters=2,
        )
        t_bf = time_call(
            lambda: knn_bruteforce_chunked(pts, qpos, qid, k=k, chunk=2048)[0], iters=2
        )
        emit(
            f"s2_vs_baseline/k={k}/pipeline{tag}", t_pipe,
            f"speedup={t_bf / t_pipe:.1f}x",
        )
        emit(f"s2_vs_baseline/k={k}/bruteforce", t_bf, "")
        rows.append((k, t_pipe, t_bf))
    return rows


def run():
    return run_vary_n(), run_vary_k()


if __name__ == "__main__":
    run()
