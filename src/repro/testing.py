"""Deterministic fallback for the tiny slice of `hypothesis` the tests use.

The CI container has no ``hypothesis`` wheel and the tier-1 suite must not
depend on network installs, so property tests import it through a guard::

    try:
        from hypothesis import given, settings, strategies as st
    except ModuleNotFoundError:
        from repro.testing import given, settings, strategies as st

Semantics here are a strict subset: ``@given`` draws ``max_examples`` examples
from the strategies with a seed derived from the test name (stable across
runs — failures reproduce), with no shrinking and no example database.  When
real hypothesis is available it wins, shrinking and all.
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Any, Callable

import numpy as np

__all__ = ["given", "settings", "strategies"]

DEFAULT_MAX_EXAMPLES = 20


@dataclasses.dataclass(frozen=True)
class _Strategy:
    draw: Callable[[np.random.Generator], Any]

    def sample(self, rng: np.random.Generator):
        return self.draw(rng)


class strategies:
    """Namespace mirroring ``hypothesis.strategies`` (the used subset)."""

    @staticmethod
    def floats(min_value: float, max_value: float) -> _Strategy:
        return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)))

    @staticmethod
    def integers(min_value: int, max_value: int) -> _Strategy:
        # hypothesis bounds are inclusive
        return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))

    @staticmethod
    def tuples(*elems: _Strategy) -> _Strategy:
        return _Strategy(lambda rng: tuple(e.sample(rng) for e in elems))

    @staticmethod
    def lists(elem: _Strategy, *, min_size: int = 0, max_size: int = 10) -> _Strategy:
        def draw(rng):
            n = int(rng.integers(min_size, max_size + 1))
            return [elem.sample(rng) for _ in range(n)]

        return _Strategy(draw)


def settings(max_examples: int = DEFAULT_MAX_EXAMPLES, deadline=None):
    """Decorator setting the example count on a ``@given``-wrapped test."""

    def deco(fn):
        fn._max_examples = max_examples
        return fn

    return deco


def given(*strats: _Strategy):
    """Run the test once per drawn example (deterministic per-test seed)."""

    def deco(fn):
        def run(*args, **kw):
            # @settings may sit above @given (stamps `run`) or below (stamps `fn`)
            n = getattr(
                run, "_max_examples", getattr(fn, "_max_examples", DEFAULT_MAX_EXAMPLES)
            )
            rng = np.random.default_rng(zlib.crc32(fn.__name__.encode()))
            for _ in range(n):
                fn(*args, *(s.sample(rng) for s in strats), **kw)

        # NOT functools.wraps: copying __wrapped__ would make pytest inspect
        # the original signature and treat the drawn arguments as fixtures.
        run.__name__ = fn.__name__
        run.__doc__ = fn.__doc__
        run.__module__ = fn.__module__
        return run

    return deco
