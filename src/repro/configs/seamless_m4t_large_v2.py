"""seamless-m4t-large-v2 [audio] — enc-dec, 24L+24L d=1024 16H d_ff=8192,
vocab 256206.  [arXiv:2308.11596; hf]
Modality frontend is a STUB per the assignment: ``input_specs`` feeds
precomputed speech-frame embeddings (B, S_enc, d) to the encoder.
"""
import dataclasses

from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="seamless_m4t_large_v2",
    family="encdec",
    n_layers=48,          # 24 enc + 24 dec
    n_enc_layers=24,
    n_dec_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab=256206,
    activation="gelu",
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=4, n_enc_layers=2, n_dec_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, d_ff=128, vocab=128, param_dtype="float32",
        compute_dtype="float32", remat=False,
    )
