"""yi-34b [dense] — llama-arch GQA, 60L d=7168 56H (kv=8) d_ff=20480
vocab=64000.  [arXiv:2403.04652; hf]
Pure full attention -> long_500k cell is SKIPPED (DESIGN.md §5).
"""
import dataclasses

from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="yi_34b",
    family="dense",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=20480,
    vocab=64000,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab=128, param_dtype="float32", compute_dtype="float32", remat=False,
    )
