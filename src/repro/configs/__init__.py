"""Architecture config registry: ``get_config(arch_id)`` / ``list_archs()``.

One module per assigned architecture; each exposes ``CONFIG`` (exact public
dims) and ``smoke()`` (reduced same-family config for CPU tests).
"""
from __future__ import annotations

import importlib

from .base import SHAPES, ModelConfig, ShapeCell

ARCH_IDS = (
    "granite_moe_3b_a800m",
    "qwen3_moe_235b_a22b",
    "seamless_m4t_large_v2",
    "deepseek_coder_33b",
    "h2o_danube_3_4b",
    "nemotron_4_340b",
    "yi_34b",
    "zamba2_7b",
    "rwkv6_3b",
    "llama_3_2_vision_11b",
)

_ALIAS = {a.replace("_", "-"): a for a in ARCH_IDS}


def _mod(arch_id: str):
    arch_id = _ALIAS.get(arch_id, arch_id)
    return importlib.import_module(f"repro.configs.{arch_id}")


def get_config(arch_id: str) -> ModelConfig:
    return _mod(arch_id).CONFIG


def get_smoke_config(arch_id: str) -> ModelConfig:
    return _mod(arch_id).smoke()


def list_archs():
    return list(ARCH_IDS)


__all__ = [
    "ARCH_IDS",
    "SHAPES",
    "ModelConfig",
    "ShapeCell",
    "get_config",
    "get_smoke_config",
    "list_archs",
]
