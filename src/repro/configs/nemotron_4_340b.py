"""nemotron-4-340b [dense] — 96L d=18432 96H (GQA kv=8) d_ff=73728
vocab=256000, squared-ReLU MLP.  [arXiv:2402.16819]
Pure full attention -> long_500k cell is SKIPPED (DESIGN.md §5).
"""
import dataclasses

from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="nemotron_4_340b",
    family="dense",
    n_layers=96,
    d_model=18432,
    n_heads=96,
    n_kv_heads=8,
    d_ff=73728,
    vocab=256000,
    activation="relu2",
    rope_theta=10_000.0,
    sp_residual=True,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=256,
        vocab=128, param_dtype="float32", compute_dtype="float32", remat=False,
    )
