"""zamba2-7b [hybrid] — 81 blocks: Mamba2 backbone + ONE shared attention
block applied every 6 mamba blocks (Zamba design), d=3584 32H (kv=32=MHA)
d_ff=14336 vocab=32000 ssm_state=64.  [arXiv:2411.15242]
SSM state decode -> long_500k cell RUNS.
"""
import dataclasses

from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="zamba2_7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_ff=14336,
    vocab=32000,
    ssm_state=64,
    ssm_expand=2,
    ssm_conv=4,
    ssm_chunk=128,
    shared_attn_every=6,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=7, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab=128, ssm_state=16, ssm_chunk=8, shared_attn_every=2,
        param_dtype="float32", compute_dtype="float32", remat=False,
    )
