"""h2o-danube-3-4b [dense] — llama+mistral mix with sliding-window attention,
24L d=3840 32H (GQA kv=8) d_ff=10240 vocab=32000.  [arXiv:2401.16818]
SWA (window 4096) makes decode O(W): long_500k cell RUNS (ring-buffer cache).
"""
import dataclasses

from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="h2o_danube_3_4b",
    family="dense",
    n_layers=24,
    d_model=3840,
    n_heads=32,
    n_kv_heads=8,
    d_ff=10240,
    vocab=32000,
    sliding_window=4096,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab=128, sliding_window=32, param_dtype="float32",
        compute_dtype="float32", remat=False,
    )
