"""qwen3-moe-235b-a22b [moe] — 94L d=4096 64H (GQA kv=4) d_ff=1536/expert,
vocab 151936, 128 experts top-8.  [hf:Qwen/Qwen3-235B-A22B; hf]
"""
import dataclasses

from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen3_moe_235b_a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    d_ff=1536,
    vocab=151936,
    d_head=128,
    n_experts=128,
    top_k=8,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=48,
        d_head=16, vocab=128, n_experts=8, top_k=2, param_dtype="float32",
        compute_dtype="float32", remat=False,
    )
