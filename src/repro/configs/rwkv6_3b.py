"""rwkv6-3b "Finch" [ssm] — attention-free, data-dependent decay, 32L d=2560
(40 heads x 64) d_ff=8960 vocab=65536.  [arXiv:2404.05892; hf]
Linear recurrence -> long_500k cell RUNS (O(1) state decode).
"""
import dataclasses

from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="rwkv6_3b",
    family="ssm",
    n_layers=32,
    d_model=2560,
    n_heads=40,          # head size 64
    n_kv_heads=40,
    d_ff=8960,
    vocab=65536,
    ssm_chunk=128,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab=128, ssm_chunk=8, param_dtype="float32", compute_dtype="float32",
        remat=False,
    )
