"""granite-moe-3b-a800m [moe] — 32L d=1536 24H (GQA kv=8) d_ff=512/expert,
vocab 49155, 40 experts top-8.  [hf:ibm-granite/granite-3.0-3b-a800m-base; hf]
(The assignment sheet lists "MoE 40e top-8" — we use 40 experts; see DESIGN.md.)
"""
import dataclasses

from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="granite_moe_3b_a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    d_ff=512,
    vocab=49155,
    n_experts=40,
    top_k=8,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=32,
        vocab=128, n_experts=8, top_k=2, param_dtype="float32",
        compute_dtype="float32", remat=False,
    )
