"""Model + workload configuration schema.

One :class:`ModelConfig` per assigned architecture lives in
``repro/configs/<arch_id>.py`` with the exact public-literature dimensions; each
also exposes a ``smoke()`` reduction (same family, tiny dims) for CPU tests.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

__all__ = ["ModelConfig", "ShapeCell", "SHAPES", "Family"]

Family = str  # 'dense' | 'moe' | 'ssm' | 'hybrid' | 'encdec' | 'vlm'


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 0  # 0 -> d_model // n_heads
    # activations / norms
    activation: str = "swiglu"  # swiglu | relu2 | gelu
    norm_eps: float = 1e-5
    rope_theta: float = 10_000.0
    # attention variants
    sliding_window: Optional[int] = None  # SWA (h2o-danube)
    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # SSM (mamba2 / rwkv6)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_heads: int = 0  # 0 -> d_inner // 64
    ssm_conv: int = 4
    ssm_chunk: int = 128
    # hybrid (zamba2): one shared attention block applied every N ssm blocks
    shared_attn_every: int = 0
    # encoder-decoder (seamless)
    n_enc_layers: int = 0
    n_dec_layers: int = 0
    # vlm (llama-3.2-vision): one cross-attn block every N layers
    cross_attn_every: int = 0
    n_img_tokens: int = 1601
    # numerics
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    # training-side knobs
    remat: bool = True
    # Megatron-style residual sequence parallelism (seq -> 'model'): the
    # memory-bound win for very wide dense stacks (EXPERIMENTS.md §Perf A2)
    sp_residual: bool = False
    # dry-run cost probes: unroll layer scans so XLA cost analysis counts every
    # layer (while-loop bodies are otherwise counted once)
    scan_unroll: bool = False
    tie_embeddings: bool = False

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.ssm_heads or self.d_inner // 64

    def n_params(self) -> int:
        """Approximate parameter count (embeddings + blocks), for 6ND."""
        d, ff, v = self.d_model, self.d_ff, self.vocab
        dh = self.head_dim
        attn = d * self.n_heads * dh + 2 * d * self.n_kv_heads * dh + self.n_heads * dh * d
        if self.family == "moe":
            mlp = d * self.n_experts + self.n_experts * (3 * d * ff)
        elif self.activation == "swiglu":
            mlp = 3 * d * ff
        else:
            mlp = 2 * d * ff
        if self.family == "ssm":  # rwkv6
            blk = 6 * d * d + 2 * d * ff + d * ff  # time-mix + channel-mix approx
            n = self.n_layers * blk
        elif self.family == "hybrid":
            h = self.n_ssm_heads
            din = self.d_inner
            mamba = d * (2 * din + 2 * self.ssm_state + h) + din * d
            n_attn = max(1, self.n_layers // (self.shared_attn_every + 1))
            n = self.n_layers * mamba + n_attn * 0 + (attn + mlp)  # shared block once
        elif self.family == "encdec":
            n = self.n_enc_layers * (attn + mlp) + self.n_dec_layers * (2 * attn + mlp)
        else:
            n = self.n_layers * (attn + mlp)
        emb = v * d * (1 if self.tie_embeddings else 2)
        return int(n + emb)

    def n_active_params(self) -> int:
        """Active params per token (MoE: only top_k experts count), for 6·N_active·D."""
        if self.family != "moe":
            return self.n_params()
        d, ff = self.d_model, self.d_ff
        dh = self.head_dim
        attn = d * self.n_heads * dh + 2 * d * self.n_kv_heads * dh + self.n_heads * dh * d
        mlp = d * self.n_experts + self.top_k * (3 * d * ff)
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        return int(self.n_layers * (attn + mlp) + emb)


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    """One assigned (input-shape) cell for the dry-run grid."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


SHAPES: tuple[ShapeCell, ...] = (
    ShapeCell("train_4k", 4_096, 256, "train"),
    ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    ShapeCell("decode_32k", 32_768, 128, "decode"),
    ShapeCell("long_500k", 524_288, 1, "decode"),
)
