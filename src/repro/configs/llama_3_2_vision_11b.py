"""llama-3.2-vision-11b [vlm] — 40L d=4096 32H (GQA kv=8) d_ff=14336
vocab=128256, cross-attention image layers every 5th layer.
[hf:meta-llama/Llama-3.2-11B-Vision]
Vision frontend is a STUB per the assignment: ``input_specs`` feeds
precomputed patch embeddings (B, 1601, d).  Full attention -> long_500k SKIPPED.
"""
import dataclasses

from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="llama_3_2_vision_11b",
    family="vlm",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=128256,
    cross_attn_every=5,
    n_img_tokens=1601,
    rope_theta=500_000.0,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab=128, cross_attn_every=2, n_img_tokens=16, param_dtype="float32",
        compute_dtype="float32", remat=False,
    )
