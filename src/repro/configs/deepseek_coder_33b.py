"""deepseek-coder-33b [dense] — llama-arch, 62L d=7168 56H (GQA kv=8)
d_ff=19200 vocab=32256.  [arXiv:2401.14196; hf]
Pure full attention -> long_500k cell is SKIPPED (DESIGN.md §5).
"""
import dataclasses

from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="deepseek_coder_33b",
    family="dense",
    n_layers=62,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=19200,
    vocab=32256,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab=128, param_dtype="float32", compute_dtype="float32", remat=False,
    )
