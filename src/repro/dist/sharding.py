"""Logical-axis sharding rules (flax/T5X-style, trimmed to what we use).

Model code annotates activations/params with *logical* axis names
(``("batch", "act_seq", "ff")``); a thread-local rule table bound to a mesh by
:func:`use_rules` maps every logical name to zero or more *mesh* axes.  The
indirection keeps model code mesh-agnostic: the dry-run hillclimbs alternative
bindings purely via ``--override`` (see launch/dryrun.py) without touching a
single model file.

Spec construction applies three fixups, in order (tests in test_dist.py):
  1. **missing-axis filter** — mesh axes absent from the bound mesh are dropped
     (so the single-pod 16x16 mesh silently ignores the ``pod`` member of
     ``("pod", "data")`` bindings);
  2. **dedup** — a mesh axis may shard at most one dim of a value; the first
     binding wins, later duplicates are dropped;
  3. **divisibility fallback** — a mesh axis whose size does not divide the dim
     is dropped (XLA would reject the constraint otherwise).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Mapping

from jax.sharding import Mesh, PartitionSpec as P

__all__ = [
    "DEFAULT_RULES",
    "SPATIAL_RULES",
    "LogicalRules",
    "current_rules",
    "logical_to_spec",
    "shard_map_compat",
    "use_rules",
]

# logical name -> mesh axis | tuple of mesh axes | None (replicate).
# 'batch' spans pod+data (DP across pods, FSDP/DP inside); 'embed' carries the
# FSDP param sharding; head/ff/vocab/expert dims are Megatron-TP on 'model'.
DEFAULT_RULES: dict[str, str | tuple[str, ...] | None] = {
    "batch": ("pod", "data"),
    "cache_batch": ("pod", "data"),
    "seq": None,
    "act_seq": None,
    "kv_seq": None,
    "act_kv_seq": None,
    "img": None,
    "embed": "data",
    "heads": "model",
    "kv": "model",
    "ff": "model",
    "vocab": "model",
    "expert": "model",
    "expert_cap": "model",
    "conv": None,
}

# Spatial logical axes for the k-NN serving path (DESIGN.md §10/§12).  Tick
# meshes name up to two axes: ``("query",)`` (the sharded plan: Morton-sorted
# query batch split across devices, quadtree replicated), ``("object",)``
# (the object-sharded plan: Morton-contiguous object slices, one local
# quadtree per device, per-query lists merge-reduced across the axis via
# kernels/merge_topk.py) and the 2-D ``("query", "object")`` hybrid mesh.
# The missing-axis fixup below makes one rule table serve all three: on a
# query-only mesh the "object" binding drops away (values replicate), and
# vice versa.  "cell" stays reserved (a future cell-granular layout).
SPATIAL_RULES: dict[str, str | tuple[str, ...] | None] = {
    "query": "query",
    "object": "object",
    "cell": None,
}


class LogicalRules:
    """A rule table bound to a mesh (the object ``current_rules()`` returns)."""

    def __init__(self, mesh: Mesh, rules: Mapping[str, str | tuple | None]):
        self.mesh = mesh
        self.rules = dict(rules)

    def spec(self, logical_axes, shape=None) -> P:
        axis_sizes = dict(zip(self.mesh.axis_names, self.mesh.devices.shape))
        used: set[str] = set()
        entries = []
        for d, name in enumerate(logical_axes):
            binding = self.rules.get(name) if name is not None else None
            if binding is None:
                entries.append(None)
                continue
            if isinstance(binding, str):
                binding = (binding,)
            kept = []
            prod = 1
            for ax in binding:
                if ax not in axis_sizes or ax in used:  # filter + dedup
                    continue
                if shape is not None and shape[d] % (prod * axis_sizes[ax]) != 0:
                    continue  # divisibility fallback: replicate instead
                kept.append(ax)
                used.add(ax)
                prod *= axis_sizes[ax]
            entries.append(None if not kept else kept[0] if len(kept) == 1 else tuple(kept))
        return P(*entries)


_local = threading.local()


def current_rules() -> LogicalRules | None:
    """The active rule table, or None outside any ``use_rules`` scope."""
    return getattr(_local, "rules", None)


@contextlib.contextmanager
def use_rules(mesh: Mesh, overrides: Mapping[str, str | tuple | None] | None = None):
    """Bind ``DEFAULT_RULES`` (+ per-experiment overrides) to ``mesh``."""
    merged = dict(DEFAULT_RULES)
    if overrides:
        merged.update(overrides)
    prev = current_rules()
    _local.rules = LogicalRules(mesh, merged)
    try:
        yield _local.rules
    finally:
        _local.rules = prev


def logical_to_spec(logical_axes, shape=None) -> P:
    """Logical axes (+ optional concrete shape for divisibility) -> PartitionSpec."""
    lr = current_rules()
    assert lr is not None, "logical_to_spec requires an active use_rules(mesh) scope"
    return lr.spec(logical_axes, shape)


def shard_map_compat(f, *, mesh, in_specs, out_specs, axis_names, check_vma):
    """``jax.shard_map`` across jax versions (shared by train and serving).

    Newer jax exposes ``jax.shard_map(..., axis_names=, check_vma=)``.  0.4.x
    only has ``jax.experimental.shard_map.shard_map`` whose partial-auto mode
    (``auto=``) hard-crashes the bundled XLA on collectives over the manual
    axis (``Check failed: IsManualSubgroup``), so there we fall back to a
    FULLY manual map: same semantics — values are only ever split on the
    manual axes, everything else enters replicated — minus the intra-region
    GSPMD resharding, which is a performance hint, not a correctness
    requirement.
    """
    import jax

    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            axis_names=axis_names, check_vma=check_vma,
        )
    from jax.experimental.shard_map import shard_map as _sm

    return _sm(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=False
    )
