"""Distribution utilities: logical-axis sharding rules + activation constraints.

``use_rules(mesh, overrides)`` binds the logical->mesh axis table; model code
then calls ``constrain(x, logical_axes)`` at layer boundaries, which lowers to
``with_sharding_constraint`` under an active rule scope and is a no-op outside
one (so the k-NN pipeline, tests and single-host runs never pay for it).
"""
from __future__ import annotations

import jax

from .sharding import (
    DEFAULT_RULES,
    SPATIAL_RULES,
    LogicalRules,
    current_rules,
    logical_to_spec,
    shard_map_compat,
    use_rules,
)

__all__ = [
    "DEFAULT_RULES",
    "SPATIAL_RULES",
    "LogicalRules",
    "constrain",
    "current_rules",
    "logical_to_spec",
    "shard_map_compat",
    "use_rules",
]


def _manual_axes_active() -> bool:
    """True while tracing inside a shard_map/pmap manual-axis region.

    jax 0.4.x XLA rejects ``with_sharding_constraint`` under a partially-manual
    shard_map (``Check failed: sharding.IsManualSubgroup()``), so ``constrain``
    degrades to identity there — the constraint is an optimization hint, and
    GSPMD still propagates shardings through the auto axes.  On jax versions
    without this probe the check returns False and the constraint applies.
    """
    try:
        from jax._src import core as _core

        return bool(_core.get_axis_env().axis_sizes)
    except Exception:
        return False


def constrain(x, logical_axes):
    """Sharding-constrain ``x`` by logical axis names; identity outside rules."""
    lr = current_rules()
    if lr is None or _manual_axes_active():
        return x
    spec = lr.spec(logical_axes, x.shape)
    sharding = jax.sharding.NamedSharding(lr.mesh, spec)
    return jax.lax.with_sharding_constraint(x, sharding)
