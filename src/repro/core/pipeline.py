"""Iterative k-NN query computation (paper Sec. 4.2) — TPU/JAX adaptation.

Paper recap: after indexing, every query is joined with its own quadtree leaf
(first iteration); queries whose result list may still be improved by objects in
other leaves remain *active* and advance along the Morton total order of leaves in
two alternating directions (left/right), pruning every leaf/subtree whose box is
farther than the query's current k-th distance, until no query is active.

TPU adaptation (see DESIGN.md §3): the paper materializes per-cell thread-block
tasks on the fly and sorts them by weight to balance GPU SMs.  Under XLA we run a
**masked dense iteration**: all queries advance in lockstep inside one
``lax.while_loop``; per iteration each query either
  * SCANs one fixed-width window of ``W`` candidate objects from its current leaf
    (gather -> masked distance tile -> top-k merge), or
  * NAVigates the *virtual full quadtree* (arithmetic-only, paper Sec. 4.2.2):
    up to ``max_nav`` aligned-block jumps that skip empty (count-pyramid) or
    pruned (box farther than kth) regions in O(4^a)-sized strides.
Queries are pre-sorted by Morton code, so active lanes stay spatially coherent —
the same locality argument as the paper's SM-task packing, expressed as vector-lane
coherence instead of warp coherence.

The SCAN step's distance+selection is NOT inlined here: it dispatches through a
:class:`repro.core.executor.QueryExecutor` to a registered kernel-layer backend
(``dense_topk`` | ``fused_bucket`` | ``brute`` — DESIGN.md §6), carried through
``jax.jit`` as a static argument.

Batching: ``knn_query_batch`` runs one device program over the whole batch.
Memory-bounded chunking and device layout live one layer up, behind the
ExecutionPlan seam (``core/plan.py``, DESIGN.md §10): the ``single`` plan maps
this module's sorted-query program over fixed-shape chunks with ``lax.map``
inside one jitted call, the ``sharded`` plan additionally splits the sorted
batch across a device mesh with ``shard_map``.  (``knn_query_batch_chunked``
remains importable here as a thin delegate — see its docstring.)

Invariants that make block-skipping sound (proved in tests):
  * cursors ``cl``/``cr`` always sit on leaf boundaries;
  * an aligned block that starts (ends) on a leaf boundary is a union of whole
    leaves, hence skippable as a unit;
  * the k-th distance is non-increasing, so a once-far block stays prunable;
  * pruning keeps equal-distance blocks (``<=``/``>`` comparisons) and every
    selection step is lexicographic by ``(d2, id)``, so the final list is the
    unique canonical k-NN answer — independent of scan order, chunk
    boundaries, query sharding AND object partition (DESIGN.md §12; this is
    what lets the object-sharded plans merge per-shard lists bit-exactly).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import morton
from .executor import QueryExecutor, resolve_executor
from .quadtree import QuadtreeIndex

__all__ = [
    "knn_query_batch",
    "knn_query_batch_chunked",
    "default_max_nav",
    "KnnStats",
]

INF = jnp.inf


class KnnStats(NamedTuple):
    iterations: jnp.ndarray  # () i32 — outer while-loop trips
    candidates: jnp.ndarray  # () i64-ish f32 — total candidate object slots scanned
    leaves_visited: jnp.ndarray  # () i32 — scheduled leaf scans (incl. own leaf)


def zero_stats() -> KnnStats:
    """All-zero stats — the masked-out chunk's contribution (core/plan.py)."""
    return KnnStats(
        iterations=jnp.int32(0),
        candidates=jnp.float32(0.0),
        leaves_visited=jnp.int32(0),
    )


class _State(NamedTuple):
    best_d: jnp.ndarray  # (Q, k) ascending squared dists, inf-padded
    best_i: jnp.ndarray  # (Q, k) object ids, -1 padded
    scanning: jnp.ndarray  # (Q,) bool
    s_cur: jnp.ndarray  # (Q,) i32 scan interval start (object array)
    e_cur: jnp.ndarray  # (Q,) i32 scan interval end
    off: jnp.ndarray  # (Q,) i32 window offset within interval
    cl: jnp.ndarray  # (Q,) i32 left frontier (fine code, leaf boundary)
    cr: jnp.ndarray  # (Q,) i32 right frontier
    act_l: jnp.ndarray  # (Q,) bool
    act_r: jnp.ndarray  # (Q,) bool
    next_right: jnp.ndarray  # (Q,) bool — alternation bit (paper Sec. 4.2.2)
    it: jnp.ndarray  # () i32
    cand_q: jnp.ndarray  # (Q,) f32 — candidate slots scanned PER QUERY (cost model)
    leaves: jnp.ndarray  # () i32


def _nav_step(index: QuadtreeIndex, qx, qy, kth2, cursor, run, dir_r):
    """One navigation step; ``dir_r`` is a per-query bool (True = rightwards).

    Returns (found, s, e, new_cursor, exhausted):
      found     — a near, non-empty leaf was located (schedule its scan)
      s, e      — object interval of that leaf
      new_cursor— cursor after the step (past the found leaf, or past the skipped
                  aligned block)
      exhausted — cursor left the domain; direction goes inactive

    All loops are rolled (lax.fori_loop) to keep the compiled program small; the
    pyramid is indexed at a *dynamic* level via its flat layout.
    """
    l_max = index.l_max
    n_fine = 4**l_max
    one = jnp.int32(1)

    exhausted = jnp.where(dir_r, cursor >= n_fine, cursor <= 0)
    cprobe = jnp.clip(jnp.where(dir_r, cursor, cursor - 1), 0, n_fine - 1)

    lvl = index.leaf_level[cprobe]
    a0 = (l_max - lvl).astype(jnp.int32)
    span0 = jnp.left_shift(one, 2 * a0)
    # leaf start (right: == cursor; left: aligned block ending at cursor)
    leaf_key = jnp.where(dir_r, cprobe, (cprobe >> (2 * a0)) << (2 * a0))
    s = index.starts[jnp.clip(leaf_key, 0, n_fine - 1)]
    e = index.starts[jnp.clip(leaf_key + span0, 0, n_fine)]
    cnt = e - s
    leaf_d2 = morton.point_to_block_dist2(
        qx, qy, leaf_key, a0, index.origin, index.side, l_max
    )
    # `<=`, not `<`: leaves whose box sits EXACTLY at the k-th distance are
    # scanned, so every candidate tied at the k-th distance enters selection.
    # Together with the lexicographic (d2, id) selection contract (DESIGN.md
    # §12) this makes the result a pure function of the candidate set —
    # identical bits under any chunking, query sharding or object partition.
    found = run & ~exhausted & (cnt > 0) & (leaf_d2 <= kth2)

    # --- far/empty aligned-block skip: pick the largest admissible jump.
    pyr_n = index.pyramid.shape[0]

    def try_level(a, best_a):
        ai = jnp.int32(a)
        blk = jnp.left_shift(one, 2 * ai)
        code = jnp.where(dir_r, cursor, cursor - blk)
        in_dom = jnp.where(dir_r, cursor + blk <= n_fine, cursor - blk >= 0)
        pidx = jnp.where(dir_r, cursor >> (2 * ai), (cursor >> (2 * ai)) - 1)
        lvl_off = (jnp.left_shift(one, 2 * (l_max - ai)) - 1) // 3
        empty = index.pyramid[jnp.clip(lvl_off + pidx, 0, pyr_n - 1)] == 0
        far = (
            morton.point_to_block_dist2(
                qx, qy, code, ai, index.origin, index.side, l_max
            )
            > kth2  # strict: blocks AT the k-th distance still get scanned
        )
        aligned = (cursor & (blk - 1)) == 0
        ok = aligned & in_dom & (ai >= a0) & (empty | far)
        return jnp.where(ok & (ai > best_a), ai, best_a)

    best_a = jax.lax.fori_loop(1, l_max + 1, try_level, a0)
    jump = jnp.left_shift(one, 2 * best_a)

    step = jnp.where(found, span0, jump)
    new_cursor = jnp.where(
        run & ~exhausted, jnp.where(dir_r, cursor + step, cursor - step), cursor
    )
    return found, s, e, new_cursor, run & exhausted


def _knn_sorted_impl(
    index: QuadtreeIndex,
    qpos: jnp.ndarray,
    qid: jnp.ndarray,
    k: int,
    window: int,
    max_nav: int,
    max_iters: int,
    executor: QueryExecutor,
):
    """k-NN for queries already sorted by Morton code (trace-level body)."""
    nq = qpos.shape[0]
    n_obj = index.n_objects
    n_fine = index.n_fine
    l_max = index.l_max
    qx, qy = qpos[:, 0], qpos[:, 1]

    # --- first-iteration setup: query indexing (z_map lookup), own-leaf task.
    fine = morton.morton_encode_points(qpos, index.origin, index.side, l_max)
    lvl = index.leaf_level[fine]
    shift = 2 * (l_max - lvl)
    key = (fine >> shift) << shift
    span = jnp.left_shift(jnp.int32(1), shift)
    s0 = index.starts[key]
    e0 = index.starts[jnp.clip(key + span, 0, n_fine)]

    state = _State(
        best_d=jnp.full((nq, k), INF, jnp.float32),
        best_i=jnp.full((nq, k), -1, jnp.int32),
        scanning=e0 > s0,
        s_cur=s0,
        e_cur=e0,
        off=jnp.zeros((nq,), jnp.int32),
        cl=key,
        cr=key + span,
        act_l=jnp.ones((nq,), bool),
        act_r=jnp.ones((nq,), bool),
        next_right=jnp.ones((nq,), bool),
        it=jnp.int32(0),
        cand_q=jnp.zeros((nq,), jnp.float32),
        leaves=(e0 > s0).sum().astype(jnp.int32),
    )

    warange = jnp.arange(window, dtype=jnp.int32)

    def live(st: _State):
        return st.scanning | st.act_l | st.act_r

    def cond(st: _State):
        return jnp.any(live(st)) & (st.it < max_iters)

    def body(st: _State) -> _State:
        # ---------------- SCAN: one window of W candidates per scanning query.
        idx = st.s_cur[:, None] + st.off[:, None] + warange[None, :]
        in_window = st.scanning[:, None] & (idx < st.e_cur[:, None])
        idxc = jnp.clip(idx, 0, n_obj - 1)
        # NOTE: a fused (x,y,id) packed gather was tried and REFUTED — two
        # narrow gathers beat one wide one here (EXPERIMENTS.md §Perf, P4)
        cpos = index.pos[idxc]  # (Q, W, 2)
        cids = index.ids[idxc]
        # negative ids are sentinels: -2 external queries, -1 the padding rows
        # the object-sharded plans append to even out shard slices
        valid = in_window & (cids != qid[:, None]) & (cids >= 0)
        # distance + k-selection merge: dispatched to the registered backend
        # (result lists stay ascending; linear layout of Fig. 1)
        best_d, best_i = executor.scan_merge(
            qpos, cpos, cids, valid, st.best_d, st.best_i, k=k
        )
        kth2 = best_d[:, k - 1]

        off2 = st.off + window
        leaf_done = st.s_cur + off2 >= st.e_cur
        scanning = st.scanning & ~leaf_done
        off = jnp.where(st.scanning & ~leaf_done, off2, st.off)
        # candidates stat counts scanned slots incl. the issuer (seed
        # semantics), kept PER QUERY: the per-query totals are the measured
        # work the cost-balanced partitioner's EMA feeds on (core/balance.py),
        # and their sum is the global drift statistic as before
        cand_q = st.cand_q + in_window.sum(axis=1).astype(jnp.float32)

        # ---------------- NAV: bounded frontier advance for idle active queries.
        nav = ~scanning & (st.act_l | st.act_r)

        def nav_body(_, nst):
            cl, cr, act_l, act_r, next_right, s_cur, e_cur, found_any = nst
            pending = nav & ~found_any & (act_l | act_r)
            go_right = act_r & (next_right | ~act_l)
            run = pending & (go_right | act_l)
            cursor = jnp.where(go_right, cr, cl)
            f, s_f, e_f, cur2, ex = _nav_step(
                index, qx, qy, kth2, cursor, run, go_right
            )
            cr = jnp.where(run & go_right, cur2, cr)
            cl = jnp.where(run & ~go_right, cur2, cl)
            act_r = act_r & ~(ex & go_right)
            act_l = act_l & ~(ex & ~go_right)
            s_cur = jnp.where(f, s_f, s_cur)
            e_cur = jnp.where(f, e_f, e_cur)
            # alternate directions while both remain active (paper Sec. 4.2.2)
            next_right = jnp.where(f, ~go_right, next_right)
            found_any = found_any | f
            return cl, cr, act_l, act_r, next_right, s_cur, e_cur, found_any

        nst = (
            st.cl,
            st.cr,
            st.act_l,
            st.act_r,
            st.next_right,
            st.s_cur,
            st.e_cur,
            jnp.zeros((nq,), bool),
        )
        cl, cr, act_l, act_r, next_right, s_cur, e_cur, found_any = jax.lax.fori_loop(
            0, max_nav, nav_body, nst
        )

        scanning = scanning | found_any
        off = jnp.where(found_any, 0, off)
        leaves = st.leaves + found_any.sum().astype(jnp.int32)

        return _State(
            best_d=best_d,
            best_i=best_i,
            scanning=scanning,
            s_cur=s_cur,
            e_cur=e_cur,
            off=off,
            cl=cl,
            cr=cr,
            act_l=act_l,
            act_r=act_r,
            next_right=next_right,
            it=st.it + 1,
            cand_q=cand_q,
            leaves=leaves,
        )

    st = jax.lax.while_loop(cond, body, state)
    stats = KnnStats(
        iterations=st.it, candidates=st.cand_q.sum(), leaves_visited=st.leaves
    )
    return st.best_i, st.best_d, stats, st.cand_q


_knn_sorted = jax.jit(
    _knn_sorted_impl,
    static_argnames=("k", "window", "max_nav", "max_iters", "executor"),
)


def _sort_unsort(index: QuadtreeIndex, qpos: jnp.ndarray):
    """Morton sort permutation of the queries (locality; see module docstring)."""
    qcodes = morton.morton_encode_points(qpos, index.origin, index.side, index.l_max)
    order = jnp.argsort(qcodes)
    return order, jnp.argsort(order)


def default_max_nav(l_max: int) -> int:
    """Navigation steps bundled per iteration: enough aligned jumps to cross
    the whole domain (the single source of this formula — serving reuses it)."""
    return 2 * l_max + 4


def _resolve_max_nav(index: QuadtreeIndex, max_nav):
    return default_max_nav(index.l_max) if max_nav is None else max_nav


def knn_query_batch(
    index: QuadtreeIndex,
    qpos: jnp.ndarray,
    qid: jnp.ndarray | None = None,
    *,
    k: int = 32,
    window: int = 128,
    max_nav: int | None = None,
    max_iters: int = 100_000,
    backend: str | QueryExecutor | None = None,
):
    """Compute a batch of k-NN queries against the index (one tick's ``Q``).

    Parameters
    ----------
    index: built/refreshed :class:`QuadtreeIndex` over the tick's positions ``P``.
    qpos: (Q, 2) query centers.
    qid:  (Q,) issuing-object id, excluded from its own result (Def. 1's ``i != j``);
          pass None for external (non-object) queries.
    k: result-list size.
    window: candidate window width W (the per-iteration tile).
    max_nav: navigation steps bundled per iteration (default ``2*l_max + 4``,
        enough to cross the whole domain by aligned jumps).
    backend: SCAN backend name or :class:`QueryExecutor` (default ``dense_topk``;
        see ``repro.core.executor.available_backends``).

    Returns
    -------
    (nn_idx (Q, k) i32, nn_dist (Q, k) f32 *euclidean*, stats) — rows ascending by
    distance, padded with (-1, inf) when fewer than k objects exist.  Ties at the
    k-th distance are resolved arbitrarily (paper Sec. 2.1).
    """
    qpos = jnp.asarray(qpos, jnp.float32)
    nq = qpos.shape[0]
    if qid is None:
        qid = jnp.full((nq,), -2, jnp.int32)  # never matches a real id
    else:
        qid = jnp.asarray(qid, jnp.int32)
    executor = resolve_executor(backend)
    max_nav = _resolve_max_nav(index, max_nav)
    # spatial sort of queries (locality for z_map lookups & frontier coherence)
    order, inv = _sort_unsort(index, qpos)
    idx_s, d2_s, stats, _ = _knn_sorted(
        index, qpos[order], qid[order], k, window, max_nav, max_iters, executor
    )
    return idx_s[inv], jnp.sqrt(d2_s[inv]), stats


def knn_query_batch_chunked(index, qpos, qid=None, **kw):
    """Delegates to :func:`repro.core.plan.knn_query_batch_chunked` — chunking
    and device layout are rehomed behind the ExecutionPlan seam.  Kept here so
    the serving-layer contract test (tests/test_backends.py) can pin that the
    tick engine never routes through a host-side chunk driver.  The lazy
    import avoids a module cycle (plan.py imports this module's trace-level
    internals)."""
    from .plan import knn_query_batch_chunked as impl

    return impl(index, qpos, qid, **kw)
