"""ExecutionPlan — how a tick's query batch is laid onto devices (DESIGN.md §10).

The pipeline (``core/pipeline.py``) knows how to answer *sorted* queries
against an index; the serving layer (``core/ticks.py``) knows *when* to run a
tick.  The plan is the seam between them: it owns device layout — how the
Morton-sorted batch is chunked, split across a mesh, and gathered back.  Four
plans ship:

``single``
    Today's path: global Morton sort, ``lax.map`` over fixed-shape chunks on
    one device (the chunked sweep formerly inlined in
    ``pipeline.knn_chunked_device``, rehomed here behind the seam).

``sharded``
    A 1-D ``("query",)`` mesh (``launch.mesh.make_query_mesh``) laid out by
    the spatial logical-axis rules (``repro.dist.SPATIAL_RULES``): the
    quadtree index — positions, ids, starts, count pyramid — is *replicated*
    across devices, the Morton-sorted query batch is split into per-device
    contiguous shards with ``shard_map``, each device runs the identical
    masked dense iteration locally over its shard, and the per-shard
    ``(k, dist, id)`` lists are gathered by concatenation (query shards are
    disjoint, so the gather needs no merge).

``object_sharded``
    A 1-D ``("object",)`` mesh (``launch.mesh.make_object_mesh``, DESIGN.md
    §12): the **object set** is split into Morton-contiguous slices, each
    device builds its own quadtree over its slice and runs the full query
    batch against it locally, and the per-device *partial* result lists are
    ``all_gather``-ed along the object axis and reduced with a binary tree
    of the MERGE backends (``kernels.ops.tree_merge_lists`` over
    ``dense_merge`` | ``fused_merge``).  This is the partition-then-merge
    route to object sets larger than one device's memory (Gowanlock's
    hybrid KNN-join, PAPERS.md).

``hybrid``
    The 2-D ``("query", "object")`` mesh composing both decompositions
    (``launch.mesh.make_spatial_mesh``): the Morton-sorted query batch
    splits along the query axis, the Morton-sorted object array along the
    object axis; each device sweeps its query shard over its object slice,
    partial lists merge-reduce along the object axis and gather by
    concatenation along the query axis.  ``mesh_shape=(qd, od)`` picks the
    factorization; the default is the most balanced one
    (``launch.mesh.default_hybrid_shape``).

**Partitioner seam (DESIGN.md §13).**  Plans no longer hard-code equal
splits: where to cut the Morton-sorted query batch (in whole-chunk units)
and the Morton-sorted object array (in row units) is delegated to a
:class:`repro.core.balance.Partitioner` carried inside the plan.  ``equal``
reproduces the pre-seam equal-count splits; ``cost_balanced`` bins the same
contiguous ranges so each shard's *estimated cost* balances — seeded from
the count pyramid (:func:`_query_cost_estimate` — each query's leaf
population) and refined by the per-query EMA of measured candidate volume
the session threads through ``qcost`` (the repeated-query feedback loop).
The object axis stays count-balanced (:func:`_object_row_costs` — see its
docstring for the measured rationale), boundaries still flowing through the
same seam.
Because shard shapes must stay static under ``jit``/``shard_map``, balanced
shards are **uneven-but-static**: every shard compiles at a fixed capacity
(``Partitioner.*_capacity``) and masks the unused tail — dead query chunks
are skipped with a ``lax.cond`` inside the chunk map, surplus object rows
carry sentinel id -1 exactly like the equal plan's tail padding.

ALL plans are **bit-identical** to ``single`` for EVERY partitioner (pinned
by tests/test_plan.py and the property harness tests/test_properties.py
across the full backend × plan × partitioner matrix).  Two disciplines make
that hold:

  * every query-shard boundary coincides with a chunk boundary — the host
    pads the batch to ``(query devices) * chunk`` (:func:`pad_queries`) and
    partitioners cut in whole-chunk units, so per-chunk programs are
    identical to the single plan's regardless of which device owns a chunk;
  * selection is everywhere the canonical lexicographic ``(d2, id)`` order
    and navigation keeps equal-distance blocks (DESIGN.md §12), so a
    query's result is a pure function of the candidate *set* — any object
    partition yields the same bits after the merge reduction (the
    composition law ``knn(∪ P_r) = tree_merge(knn(P_r))``, contract-tested
    R-way in tests/test_kernels.py).

Every ``run`` returns a :class:`PlanAux` alongside the result lists: global
:class:`~repro.core.pipeline.KnnStats` scalars (the drift trigger), the
per-shard candidate/iteration counters (the straggler-gap metric — no
longer only the psum-reduced global), the next per-query cost EMA, and the
object-axis boundaries actually used (the serving layer routes delta
updates by them).

Plans are frozen (hence hashable) dataclasses, carried through ``jax.jit`` as
*static* arguments exactly like :class:`repro.core.executor.QueryExecutor`:
the jitted tick step specializes per (plan, backend, partitioner) triple —
boundaries are data, so per-tick re-balancing never recompiles.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import ClassVar, NamedTuple

import jax
import jax.numpy as jnp

from repro.dist import SPATIAL_RULES, shard_map_compat, use_rules
from repro.kernels.ops import get_merge_backend, tree_merge_lists
from repro.launch.mesh import (
    default_hybrid_shape,
    make_object_mesh,
    make_query_mesh,
    make_spatial_mesh,
)

from . import morton
from .balance import EqualPartitioner, Partitioner, resolve_partitioner
from .pipeline import (
    KnnStats,
    _knn_sorted_impl,
    _resolve_max_nav,
    _sort_unsort,
    zero_stats,
)
from .quadtree import (
    QuadtreeIndex,
    _leaf_levels,
    build_index,
    local_pyramid_from_starts,
    starts_from_pyramid,
)

__all__ = [
    "ExecutionPlan",
    "PlanAux",
    "SinglePlan",
    "ShardedPlan",
    "ObjectShardedPlan",
    "HybridPlan",
    "register_plan",
    "resolve_plan",
    "plan_names",
    "pad_capacity",
    "pad_queries",
    "object_shard_capacity",
    "knn_chunked_device",
    "knn_sharded_device",
    "knn_query_batch_chunked",
    "run_plan_device",
]

# EMA weight applied to the measured per-query candidate volume when the
# plan's partitioner does not define one (EqualPartitioner has no cost
# model; the EMA is still maintained so a later cost_balanced session —
# or introspection — sees warm per-query costs).
_EMA_ALPHA_DEFAULT = 0.25


class PlanAux(NamedTuple):
    """Per-tick auxiliary outputs every plan returns beside the result lists.

    ``stats``
        Global :class:`KnnStats` scalars — computed as the SUM of the
        per-shard counters, so ``stats.candidates`` equals
        ``shard_candidates.sum()`` by construction (pinned by tests).
    ``shard_candidates`` / ``shard_iterations``
        (R_total,) per-shard measured counters, one entry per mesh device
        (R_total = 1 for ``single``); ``max/mean`` of the candidates row is
        the straggler gap benchmarks report (``balance.straggler_gap``).
    ``qcost_next``
        (Q_padded,) f32 per-query cost EMA in the CALLER's row order — the
        session persists it across ticks and feeds it back as ``qcost``.
    ``object_bounds``
        (R_o + 1,) i32 Morton-row boundaries of the object partition this
        tick actually used (R_o = ``object_axis_size``; ``[0, N]`` when the
        object axis is unsharded).  The serving layer routes delta updates
        and answers ``object_shards`` introspection with them.
    """

    stats: KnnStats
    shard_candidates: jnp.ndarray
    shard_iterations: jnp.ndarray
    qcost_next: jnp.ndarray
    object_bounds: jnp.ndarray


def pad_capacity(nq: int, multiple: int) -> int:
    """Padded row count for ``nq`` queries at the plan's granularity.

    This is the capacity of the persistent padded query registry
    (``repro.api``): the registry restages its device batch only when the
    live set changes, and the compiled tick step is keyed by this capacity
    (chunk count per shard), never by the raw query count.
    """
    return max(1, -(-nq // multiple)) * multiple


def pad_queries(qpos, qid, multiple: int):
    """Host-side pad of (Q,2)/(Q,) to :func:`pad_capacity` rows.

    ``multiple`` is the plan's padding granularity (:meth:`ExecutionPlan.
    pad_multiple`): ``chunk`` for the single plan, ``num_devices * chunk`` for
    the sharded plan — one pad, host-side, so every device shard is a whole
    number of identical fixed-shape chunks.  Padding rows clone the last
    query with qid=-2; callers strip them after the gather via ``[:Q]`` (the
    global unsort returns them to the tail).  Both the snapshot path
    (``TickEngine``/``knn_query_batch_chunked``) and the session registry pad
    through HERE, which is what makes their padded batches — and hence their
    results and stats — bit-identical.
    """
    import numpy as np

    nq = qpos.shape[0]
    padded = pad_capacity(nq, multiple)
    if padded == nq:
        return qpos, qid
    pad = padded - nq
    qpos = np.concatenate([qpos, np.tile(np.asarray(qpos[-1:]), (pad, 1))])
    qid = np.concatenate([np.asarray(qid), np.full((pad,), -2, np.int32)])
    return qpos, qid


def object_shard_capacity(n_objects: int, num_shards: int) -> int:
    """Rows per object shard under the EQUAL partition: ``ceil(N / R)``.

    The equal-split object plans slice the Morton-sorted object array into
    ``num_shards`` consecutive slices of this capacity (the last one padded
    with sentinel id -1 rows) — an object's owning shard is its Morton
    *rank* divided by this capacity.  Under ``cost_balanced`` the slices
    are uneven and ownership is defined by the boundaries the tick returns
    (``PlanAux.object_bounds``); ``repro.core.ticks.object_shard_of``
    evaluates either rule device-side for delta-ingest routing.
    """
    if num_shards < 1:
        raise ValueError(f"num_shards must be >= 1, got {num_shards}")
    return -(-max(1, n_objects) // num_shards)


# --------------------------------------------------------------------------
# cost estimates (the partitioner's seed — count-pyramid statistics)
# --------------------------------------------------------------------------


def _query_cost_estimate(index: QuadtreeIndex, qpos_s, window: int):
    """(Q,) f32 estimated candidate volume per (Morton-sorted) query.

    The z_map lookup the first SCAN iteration performs anyway: each query's
    own-leaf population, plus one ``window`` as the floor every query pays
    (at least one scheduled scan + navigation).  Pure count-pyramid reads —
    no extra state; refined by the measured EMA from the second tick on.
    """
    fine = morton.morton_encode_points(
        qpos_s, index.origin, index.side, index.l_max
    )
    lvl = index.leaf_level[fine]
    shift = 2 * (index.l_max - lvl)
    key = (fine >> shift) << shift
    span = jnp.left_shift(jnp.int32(1), shift)
    s0 = index.starts[key]
    e0 = index.starts[jnp.clip(key + span, 0, index.n_fine)]
    return (e0 - s0).astype(jnp.float32) + jnp.float32(window)


def _object_row_costs(index: QuadtreeIndex):
    """(N,) f32 per-object cost on the object axis: uniform — count-balanced.

    The object axis stays "objects per slice" on purpose.  Unlike the query
    axis, per-shard sweep cost is NOT additive in rows: every (replicated)
    query runs a full local k-NN against each slice, and a slice's cost
    grows with its spatial extent — measured on Zipf workloads, balancing
    slices by query-interaction density instead of count inflated total
    candidate volume by >2x (sparse slices grew, and every query paid to
    search them).  Equal-count Morton slices are also the memory constraint
    the object axis exists for (ceil(N/R) rows per device).  The uniform
    cost still flows through the Partitioner seam, so a future object-axis
    cost model (ROADMAP: dynamic re-sharding without rebuild) plugs in
    without touching the plans.
    """
    return jnp.ones((index.n_objects,), jnp.float32)


def _ema_next(prev_rows, measured_rows, alpha: float):
    """Per-query cost EMA step; rows with no history adopt the measurement."""
    a = jnp.float32(alpha)
    return jnp.where(
        prev_rows > 0, (1 - a) * prev_rows + a * measured_rows, measured_rows
    )


# --------------------------------------------------------------------------
# static-capacity padding + uneven-shard addressing helpers
# --------------------------------------------------------------------------


def _pad_tail_rows(qpos_s, qid_s, extra: int):
    """Sorted query arrays padded by ``extra`` clone rows (qid -2) so a
    shard's ``dynamic_slice`` of one capacity never clamps at the tail.

    Built by static-slice scatter (``.at[:n].set``), NOT ``jnp.concatenate``
    — see :func:`_pad_object_slices` for the jax-0.4.x GSPMD rationale.
    """
    n = qpos_s.shape[0]
    qp = (
        jnp.zeros((n + extra, 2), qpos_s.dtype)
        .at[:n].set(qpos_s)
        .at[n:].set(qpos_s[-1])
    )
    qi = jnp.full((n + extra,), -2, jnp.int32).at[:n].set(qid_s)
    return qp, qi


def _pad_object_tail(index: QuadtreeIndex, extra: int):
    """Morton-sorted (pos, gids, codes) padded by ``extra`` sentinel rows.

    Same construction as :func:`_pad_object_slices` (clone-position, id -1
    rows the scan's validity mask drops), but sized for the boundary-sliced
    path: a shard reads ``capacity`` rows starting at its boundary, so the
    tail needs ``capacity`` spare rows for the last shard's mask region.
    The padded codes clone the last real code — consistent with the cloned
    positions (``encode`` of the clone position IS the clone code), which is
    what keeps the derived local-index path (:func:`_local_index_derived`)
    bitwise-equal to re-encoding the cloned slice.
    """
    n = index.n_objects
    opos = (
        jnp.zeros((n + extra, 2), index.pos.dtype)
        .at[:n].set(index.pos)
        .at[n:].set(index.pos[-1])
    )
    oids = jnp.full((n + extra,), -1, jnp.int32).at[:n].set(index.ids)
    ocodes = (
        jnp.zeros((n + extra,), jnp.int32)
        .at[:n].set(index.codes)
        .at[n:].set(index.codes[-1])
    )
    return opos, oids, ocodes


def _pad_object_slices(index: QuadtreeIndex, num_shards: int):
    """Morton-sorted (pos, gids) padded so every EQUAL shard slice is equal.

    Padding rows clone the last object's position (staying at the tail of the
    Morton order, so slices remain Morton-contiguous) with sentinel id -1 —
    the scan's validity mask drops them, so they can never enter a result
    list (they only inflate the padded shard's candidate statistic).

    Built by static-slice scatter (``.at[:n].set``), NOT ``jnp.concatenate``:
    on jax 0.4.x, a concatenate produced inside the enclosing jit and fed to
    the fully-manual shard_map fallback over a 2-D mesh is mis-partitioned by
    GSPMD — devices receive garbage slices (bit-parity caught it on the
    forced 8-device grid; eager mode and 1-D meshes are unaffected).

    Kept for the mesh-free R-way composition harness
    (tests/test_properties.py); the plans themselves now slice by
    partitioner boundaries via :func:`_pad_object_tail`.
    """
    n = index.n_objects
    cap = object_shard_capacity(n, num_shards)
    pad = num_shards * cap - n
    if not pad:
        return index.pos, index.ids
    opos = (
        jnp.zeros((n + pad, 2), index.pos.dtype)
        .at[:n].set(index.pos)
        .at[n:].set(index.pos[-1])
    )
    oids = jnp.full((n + pad,), -1, jnp.int32).at[:n].set(index.ids)
    return opos, oids


def _owner_positions(bounds, nq: int, chunk: int, shard_stride: int):
    """Row positions of the global sorted batch inside the tiled gather.

    The uneven-shard paths emit shard ``r``'s rows starting at
    ``r * shard_stride`` of the concatenated ``shard_map`` output (each
    shard a fixed ``capacity`` block, real rows first).  Global sorted row
    ``j`` lives in chunk ``c = j // chunk``, owned by the shard whose
    boundary interval contains ``c`` (``searchsorted`` over the chunk-unit
    boundaries), at chunk offset ``c - bounds[r]`` within that shard.
    """
    rows = jnp.arange(nq, dtype=jnp.int32)
    c = rows // chunk
    r = (jnp.searchsorted(bounds, c, side="right") - 1).astype(jnp.int32)
    return r * shard_stride + (c - bounds[r]) * chunk + rows % chunk


def _local_index(opos, oids, origin, side, *, l_max, th_quad):
    """A shard-local quadtree over one Morton-contiguous object slice.

    Built with the *global* region geometry (origin/side/l_max), so Morton
    codes — and hence query sort order and navigation arithmetic — agree
    with every other shard and with the single plan.  ``build_index``
    assigns ids by sort position within its input; they are remapped through
    ``oids`` back to global object ids so result lists and the qid
    self-exclusion are partition-invariant.
    """
    local = build_index(opos, origin, side, l_max=l_max, th_quad=th_quad)
    return dataclasses.replace(local, ids=oids[local.ids])


def _local_index_derived(origin, side, opos_l, oids_l, codes_l, clone_code,
                         gstarts, start, own, capo: int, *, l_max, th_quad):
    """The shard-local quadtree DERIVED from the globally maintained order.

    The incremental maintenance path (DESIGN.md §15) keeps the global index's
    ``(code, id)``-sorted order current by splicing only the moved rows — and
    a device's Morton-contiguous boundary slice of that order is *already*
    sorted, so :func:`_local_index`'s ``build_index`` (encode + stable argsort
    + bincount over the slice) is the identity permutation re-deriving what
    the global arrays already hold:

    * ``pos``/``ids``/``codes`` are the masked slice itself (surplus capacity
      rows collapse onto the last owned row / its code, exactly as the build
      path's clone rows encode);
    * the local count pyramid is interval arithmetic over the GLOBAL
      ``starts`` (:func:`~repro.core.quadtree.local_pyramid_from_starts`) —
      integer-exact equal to the build path's ``bincount``;
    * ``leaf_level`` and local ``starts`` are the same ``_leaf_levels`` /
      ``starts_from_pyramid`` ops over that (bitwise-equal) pyramid.

    Net: per-shard index maintenance costs O(4**l_max) gathers + adds instead
    of the build path's O(capo log capo) sort — the local trees pay for churn
    (already paid globally, Δ-sized) instead of N/R, which is the tentpole of
    the sharded incremental maintenance PR.  Bitwise-equal to
    :func:`_local_index` whenever the global index is current for the sliced
    arrays (pinned by tests/test_maintenance.py and the property harness).
    """
    pyramid = local_pyramid_from_starts(
        gstarts, start, own, clone_code, capo, l_max
    )
    leaf_level = _leaf_levels(pyramid, l_max, th_quad)
    starts = starts_from_pyramid(pyramid, l_max)
    return QuadtreeIndex(
        origin=origin,
        side=side,
        pos=opos_l,
        ids=oids_l,
        codes=codes_l,
        starts=starts,
        leaf_level=leaf_level,
        pyramid=pyramid,
        l_max=l_max,
        th_quad=th_quad,
    )


def _take_replica0(x, n_replicas: int):
    """(n_replicas * Q, ...) tiled output -> one replica's (Q, ...) rows."""
    if n_replicas == 1:
        return x
    return x.reshape((n_replicas, x.shape[0] // n_replicas) + x.shape[1:])[0]


def _stats1(st: KnnStats) -> KnnStats:
    """Scalar stats -> (1,) arrays, the tiled per-shard out_spec unit."""
    return KnnStats(
        iterations=st.iterations.reshape(1),
        candidates=st.candidates.reshape(1),
        leaves_visited=st.leaves_visited.reshape(1),
    )


def _stats_total(st_t: KnnStats) -> KnnStats:
    """Gathered (R,) per-shard stats -> global scalars (their sum).

    The global candidate counter is DEFINED as the sum of the per-shard
    counters, so ``aux.stats.candidates == aux.shard_candidates.sum()``
    holds bitwise by construction.
    """
    return KnnStats(
        iterations=st_t.iterations.sum(),
        candidates=st_t.candidates.sum(),
        leaves_visited=st_t.leaves_visited.sum(),
    )


# --------------------------------------------------------------------------
# chunked sweeps (trace-level bodies shared by the plans)
# --------------------------------------------------------------------------


def _chunked_sweep(index, qpos_s, qid_s, *, k, window, chunk, max_nav,
                   max_iters, executor):
    """``lax.map`` of the sorted-query program over fixed-shape chunks.

    Trace-level body shared by the plans: on the single plan it covers the
    whole batch, on the mesh plans it is the device-local program inside
    ``shard_map``.  Inputs must already be Morton-sorted and a whole number
    of chunks.  Returns ``(idx, d2, stats, cand_q)`` — the per-query
    measured candidate volume rides along for the cost-EMA feedback loop.
    """
    nq = qpos_s.shape[0]
    n_chunks = nq // chunk

    def one_chunk(args):
        qp, qi = args
        return _knn_sorted_impl(
            index, qp, qi, k, window, max_nav, max_iters, executor
        )

    idx_c, d2_c, stats_c, cq_c = jax.lax.map(
        one_chunk,
        (qpos_s.reshape(n_chunks, chunk, 2), qid_s.reshape(n_chunks, chunk)),
    )
    stats = KnnStats(
        iterations=stats_c.iterations.sum(),
        candidates=stats_c.candidates.sum(),
        leaves_visited=stats_c.leaves_visited.sum(),
    )
    return idx_c.reshape(nq, k), d2_c.reshape(nq, k), stats, cq_c.reshape(nq)


def _chunked_sweep_masked(index, qpos_s, qid_s, n_live_chunks, *, k, window,
                          chunk, max_nav, max_iters, executor):
    """:func:`_chunked_sweep` with a dynamic live-chunk count.

    The uneven-shard paths compile every shard at a fixed chunk *capacity*;
    a shard that owns fewer chunks skips the dead tail with a ``lax.cond``
    per chunk (``lax.map`` lowers to ``scan``, so the dead branch really is
    skipped, not select-executed).  Dead chunks contribute (-1, inf) rows —
    never gathered — and zero stats, so per-shard counters only count owned
    work.
    """
    nq = qpos_s.shape[0]
    n_chunks = nq // chunk

    def one_chunk(args):
        qp, qi, live = args

        def real(_):
            return _knn_sorted_impl(
                index, qp, qi, k, window, max_nav, max_iters, executor
            )

        def dead(_):
            return (
                jnp.full((chunk, k), -1, jnp.int32),
                jnp.full((chunk, k), jnp.inf, jnp.float32),
                zero_stats(),
                jnp.zeros((chunk,), jnp.float32),
            )

        return jax.lax.cond(live, real, dead, None)

    live = jnp.arange(n_chunks, dtype=jnp.int32) < n_live_chunks
    idx_c, d2_c, stats_c, cq_c = jax.lax.map(
        one_chunk,
        (qpos_s.reshape(n_chunks, chunk, 2), qid_s.reshape(n_chunks, chunk),
         live),
    )
    stats = KnnStats(
        iterations=stats_c.iterations.sum(),
        candidates=stats_c.candidates.sum(),
        leaves_visited=stats_c.leaves_visited.sum(),
    )
    return idx_c.reshape(nq, k), d2_c.reshape(nq, k), stats, cq_c.reshape(nq)


def _object_merge_local(origin, side, opos_r, oids_r, ocodes_r, gstarts,
                        qp_l, qi_l, ownq_chunks, bo, capo, *, l_max, th_quad,
                        k, window, chunk, max_nav, max_iters, executor, merge,
                        maintenance="rebuild"):
    """Device-local body shared by object_sharded and hybrid (inside shard_map).

    Carves the device's own Morton-contiguous object slice out of the padded
    (replicated) object arrays by its ``"object"``-axis boundary interval
    (``dynamic_slice`` of one static ``capo``-row capacity; rows past the
    owned count take sentinel id -1 — identical semantics to the equal
    plan's tail padding, so the valid candidate set per shard is exactly the
    boundary interval), builds the local quadtree over the slice, sweeps the
    (replicated or query-sharded) batch over it, then reduces the per-shard
    partial lists across the ``object`` mesh axis: ``all_gather`` of the
    (Q_local, k) lists — O(R·Q·k), list-sized, never candidate-sized —
    followed by a local binary ``tree_merge_lists`` with the selected MERGE
    backend.  Every device along the object axis computes the identical
    merged list (the reduction is deterministic), so the output is
    replicated on that axis.  ``ownq_chunks`` is the query-axis live-chunk
    count (None = whole batch, the object_sharded case); the per-query
    measured candidate volume is psum-reduced over the object axis so the
    cost EMA sees each query's whole-tick volume.

    ``origin``/``side`` arrive as explicit (replicated) operands, not a
    closure — shard_map bodies must not capture traced values.

    ``maintenance`` (a STATIC python string, safe to close over) selects how
    the device-local quadtree is obtained: ``"rebuild"`` re-derives it from
    the sliced positions with :func:`_local_index` (encode + argsort +
    bincount over ``capo`` rows — the pre-seam behaviour and the bench
    baseline); any other mode (``"incremental"`` / ``"skip"``) means the
    global index's sorted order and pyramid are current for the sliced
    arrays, so the local tree is *derived* from them
    (:func:`_local_index_derived`: masked slice + interval pyramid from the
    replicated global ``starts``) — no per-device sort, O(4**l_max) instead
    of O(capo log capo).  ``ocodes_r``/``gstarts`` carry the padded global
    codes and global prefix offsets for that path (replicated operands, dead
    code under ``"rebuild"``).

    Two jax-0.4.x fallback-shard_map miscompiles shape this body (both
    caught by the bit-parity harness on the forced 8-device grid; newer jax
    and eager mode are unaffected, and the workarounds are semantically
    neutral there):

    * object arrays enter REPLICATED and each device slices locally
      (``axis_index`` + ``dynamic_slice``) — an in_spec that splits a value
      computed inside the enclosing jit along the object axis hands some
      devices garbage slices;
    * outputs leave TILED over every mesh axis, never spec'd as replicated —
      an out_spec that omits a mesh axis of a 2-D mesh assembles garbage
      from the "replicated" dim.  The caller keeps replica 0
      (:func:`_take_replica0` / :func:`_owner_positions`).
    """
    r = jax.lax.axis_index("object")
    start = bo[r]
    own = bo[r + 1] - bo[r]
    opos_raw = jax.lax.dynamic_slice_in_dim(opos_r, start, capo, 0)
    oids_raw = jax.lax.dynamic_slice_in_dim(oids_r, start, capo, 0)
    mask = jnp.arange(capo, dtype=jnp.int32) < own
    # rows past the owned count are the NEXT shard's objects (the capacity
    # window overlaps it): besides dropping their ids, pile their positions
    # onto the slice's last owned row — left in place they would occupy real
    # cells of the local tree and attract scans (capacity slack would turn
    # into measured work); collapsed they cost at most one leaf, exactly
    # like the equal plan's tail padding
    clone = opos_raw[jnp.clip(own - 1, 0, capo - 1)]
    opos_l = jnp.where(mask[:, None], opos_raw, clone[None, :])
    oids_l = jnp.where(mask, oids_raw, -1)
    if maintenance == "rebuild":
        local = _local_index(opos_l, oids_l, origin, side,
                             l_max=l_max, th_quad=th_quad)
    else:
        codes_raw = jax.lax.dynamic_slice_in_dim(ocodes_r, start, capo, 0)
        clone_code = codes_raw[jnp.clip(own - 1, 0, capo - 1)]
        codes_l = jnp.where(mask, codes_raw, clone_code)
        local = _local_index_derived(
            origin, side, opos_l, oids_l, codes_l, clone_code, gstarts,
            start, own, capo, l_max=l_max, th_quad=th_quad,
        )
    if ownq_chunks is None:
        idx_l, d2_l, st, cq_l = _chunked_sweep(
            local, qp_l, qi_l, k=k, window=window, chunk=chunk,
            max_nav=max_nav, max_iters=max_iters, executor=executor,
        )
    else:
        idx_l, d2_l, st, cq_l = _chunked_sweep_masked(
            local, qp_l, qi_l, ownq_chunks, k=k, window=window, chunk=chunk,
            max_nav=max_nav, max_iters=max_iters, executor=executor,
        )
    d2_all = jax.lax.all_gather(d2_l, "object")  # (R, Q_local, k)
    idx_all = jax.lax.all_gather(idx_l, "object")
    d2_m, idx_m = tree_merge_lists(d2_all, idx_all, k=k, merge=merge)
    cq_m = jax.lax.psum(cq_l, "object")
    return idx_m, d2_m, _stats1(st), cq_m


class ExecutionPlan:
    """Interface: device layout of one tick's query sweep (see module doc)."""

    name: ClassVar[str]

    @property
    def object_axis_size(self) -> int:
        """Shards on the object axis (1 = objects unsharded).

        The serving layer reads this to route delta updates to the owning
        shard (``repro.core.ticks.object_shard_of``; DESIGN.md §12).
        """
        return 1

    def pad_multiple(self, chunk: int) -> int:
        """Host-side padding granularity for :func:`pad_queries`."""
        raise NotImplementedError

    def run(self, index: QuadtreeIndex, qpos, qid, qcost, *, k, window,
            chunk, max_nav, max_iters, executor, qweight=None,
            maintenance="rebuild"):
        """Trace-level tick sweep: (index, padded Q) -> (idx, dist, aux).

        ``qpos.shape[0]`` must be a whole multiple of ``pad_multiple(chunk)``;
        ``qcost`` is the (Q,) per-query cost EMA in the caller's row order
        (zeros = no history; the count-pyramid estimate seeds instead).
        ``qweight`` is an optional (Q,) f32 multiplier on the boundary-seeding
        cost (the serving layer's tenant-fairness weights,
        ``core.balance.tenant_fair_weights``); it scales *influence on shard
        boundaries only* — plans that never split the query axis ignore it,
        and because boundaries only move shard ownership (DESIGN.md §13) it
        can never change results.  ``maintenance`` is the STATIC mode the
        tick step refreshed the index under (DESIGN.md §15): plans without
        per-device local trees ignore it; the object-axis plans use it to
        pick the local-index path — ``"rebuild"`` re-builds each local tree
        from its slice, ``"incremental"``/``"skip"`` derive it from the
        (current) global sorted order with no per-device sort.  Results come
        back in the caller's query order, distances euclidean; ``aux`` is the
        :class:`PlanAux` record.
        """
        raise NotImplementedError

    def describe(self) -> str:
        """Human-readable mesh/layout summary (the example service prints it)."""
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class SinglePlan(ExecutionPlan):
    """One device, the refactor-invariant path: sort -> chunked sweep -> unsort.

    Has no split axes, so the partitioner seam is moot here — but the
    per-query cost EMA is still maintained (measured candidate volume per
    query), so a session that later runs a cost-balanced mesh plan starts
    from warm costs.
    """

    name: ClassVar[str] = "single"

    def pad_multiple(self, chunk: int) -> int:
        return chunk

    def run(self, index, qpos, qid, qcost, *, k, window, chunk, max_nav,
            max_iters, executor, qweight=None, maintenance="rebuild"):
        del qweight  # no query-axis split: fairness weights have no seam here
        del maintenance  # no local trees: the global index is swept directly
        order, inv = _sort_unsort(index, qpos)
        idx_s, d2_s, stats, cq_s = _chunked_sweep(
            index, qpos[order], qid[order], k=k, window=window, chunk=chunk,
            max_nav=max_nav, max_iters=max_iters, executor=executor,
        )
        qcost_next = _ema_next(qcost[order], cq_s, _EMA_ALPHA_DEFAULT)[inv]
        aux = PlanAux(
            stats=stats,
            shard_candidates=stats.candidates.reshape(1),
            shard_iterations=stats.iterations.reshape(1),
            qcost_next=qcost_next,
            object_bounds=jnp.asarray([0, index.n_objects], jnp.int32),
        )
        return idx_s[inv], jnp.sqrt(d2_s[inv]), aux

    def describe(self) -> str:
        return "plan=single mesh=() devices=1"


@dataclasses.dataclass(frozen=True)
class ShardedPlan(ExecutionPlan):
    """Replicated index, query-sharded sweep over a 1-D ``("query",)`` mesh.

    ONE boundary-driven body for both partitioners (the last split-``in_specs``
    path was retired with DESIGN.md §14): the sorted batch enters ``shard_map``
    REPLICATED, boundaries ride in as data, and each device ``dynamic_slice``s
    its owned chunk range out of one static capacity — chunks past its
    boundary interval are skipped by the masked sweep.  Under ``equal`` the
    boundaries are the static equal-count cuts (every device owns exactly
    ``n_chunks / R`` chunks, so no chunk is ever masked); under
    ``cost_balanced`` they re-balance every tick.  Replicating the query
    batch is bounded by the index this plan already replicates.
    """

    num_devices: int
    partitioner: Partitioner = EqualPartitioner()
    name: ClassVar[str] = "sharded"

    def __post_init__(self):
        if self.num_devices < 1:
            raise ValueError(f"num_devices must be >= 1, got {self.num_devices}")

    def pad_multiple(self, chunk: int) -> int:
        # every device shard must be a whole number of chunks
        return self.num_devices * chunk

    def run(self, index, qpos, qid, qcost, *, k, window, chunk, max_nav,
            max_iters, executor, qweight=None, maintenance="rebuild"):
        del maintenance  # index replicated, no local trees to maintain
        from jax.sharding import PartitionSpec as P

        mesh = make_query_mesh(self.num_devices)
        with use_rules(mesh, SPATIAL_RULES) as rules:
            qpos_spec = rules.spec(("query", None))   # (Q, 2) split on axis 0
            qvec_spec = rules.spec(("query",))        # (Q,) split
        repl_spec = P()

        # global Morton sort: shards stay spatially coherent AND chunk
        # boundaries coincide with the single plan's (bit-identity argument)
        order, inv = _sort_unsort(index, qpos)
        qpos_s, qid_s = qpos[order], qid[order]
        obj_bounds = jnp.asarray([0, index.n_objects], jnp.int32)
        alpha = getattr(self.partitioner, "ema_alpha", _EMA_ALPHA_DEFAULT)

        nq = qpos.shape[0]
        n_chunks = nq // chunk
        cap_c = self.partitioner.query_capacity(n_chunks, self.num_devices)
        est_s = _query_cost_estimate(index, qpos_s, window)
        prev_s = qcost[order]
        cost_s = jnp.where(prev_s > 0, prev_s, est_s)
        if qweight is not None:
            # tenant-fair boundary seeding: weights scale each query's
            # influence on the split, never its results (DESIGN.md §16)
            cost_s = cost_s * qweight[order]
        bounds = self.partitioner.query_boundaries(
            cost_s.reshape(n_chunks, chunk).sum(axis=1), self.num_devices
        )
        qs_pad, qi_pad = _pad_tail_rows(qpos_s, qid_s, cap_c * chunk)

        def device_local(index, qp, qi, b):
            r = jax.lax.axis_index("query")
            start = b[r] * chunk
            ownq = b[r + 1] - b[r]
            qp_l = jax.lax.dynamic_slice_in_dim(qp, start, cap_c * chunk, 0)
            qi_l = jax.lax.dynamic_slice_in_dim(qi, start, cap_c * chunk, 0)
            idx_l, d2_l, st, cq_l = _chunked_sweep_masked(
                index, qp_l, qi_l, ownq, k=k, window=window, chunk=chunk,
                max_nav=max_nav, max_iters=max_iters, executor=executor,
            )
            # local (1,)-shaped stats leave TILED along the mesh — the
            # gathered (R,) rows ARE the per-shard counters; the global
            # drift statistic is their sum, taken outside the mesh
            return idx_l, d2_l, _stats1(st), cq_l

        # batch + boundaries enter REPLICATED (devices self-slice by
        # boundary), outputs leave tiled — the jax-0.4.x discipline of
        # _object_merge_local applied to the query axis
        sharded = shard_map_compat(
            device_local,
            mesh=mesh,
            in_specs=(repl_spec, repl_spec, repl_spec, repl_spec),
            out_specs=(qpos_spec, qpos_spec,
                       KnnStats(qvec_spec, qvec_spec, qvec_spec),
                       qvec_spec),
            axis_names={"query"},
            check_vma=False,
        )
        idx_t, d2_t, st_t, cq_t = sharded(index, qs_pad, qi_pad, bounds)
        pos = _owner_positions(bounds, nq, chunk, cap_c * chunk)
        idx_s, d2_s, cq_s = idx_t[pos], d2_t[pos], cq_t[pos]

        qcost_next = _ema_next(qcost[order], cq_s, alpha)[inv]
        aux = PlanAux(
            stats=_stats_total(st_t),
            shard_candidates=st_t.candidates,
            shard_iterations=st_t.iterations,
            qcost_next=qcost_next,
            object_bounds=obj_bounds,
        )
        return idx_s[inv], jnp.sqrt(d2_s[inv]), aux

    def describe(self) -> str:
        return (
            f"plan=sharded mesh=({self.num_devices},) axes=('query',) "
            f"devices={self.num_devices} partitioner={self.partitioner.name}"
        )


@dataclasses.dataclass(frozen=True)
class ObjectShardedPlan(ExecutionPlan):
    """Morton-sliced objects, one local quadtree per device, merge-reduced.

    The inverse decomposition of :class:`ShardedPlan`: the query batch is
    *replicated* across the 1-D ``("object",)`` mesh while each device owns
    a Morton-contiguous boundary interval of the object array — equal-count
    (``ceil(N / R)``) under the ``equal`` partitioner, interaction-density
    balanced under ``cost_balanced`` — and a quadtree over just its slice;
    per-device object state shrinks by R, which is what scales the *object*
    axis past one device's memory (the paper's massive datasets).  The
    per-query partial lists reduce across the mesh with a binary tree of
    ``merge`` (a MERGE backend name; DESIGN.md §12).
    """

    num_devices: int
    merge: str = "dense_merge"
    partitioner: Partitioner = EqualPartitioner()
    name: ClassVar[str] = "object_sharded"

    def __post_init__(self):
        if self.num_devices < 1:
            raise ValueError(f"num_devices must be >= 1, got {self.num_devices}")
        get_merge_backend(self.merge)  # fail fast on unknown names

    @property
    def object_axis_size(self) -> int:
        return self.num_devices

    def pad_multiple(self, chunk: int) -> int:
        # queries are replicated, not split: single-plan granularity
        return chunk

    def run(self, index, qpos, qid, qcost, *, k, window, chunk, max_nav,
            max_iters, executor, qweight=None, maintenance="rebuild"):
        del qweight  # queries replicated, not split: no boundary to seed
        from jax.sharding import PartitionSpec as P

        mesh = make_object_mesh(self.num_devices)
        with use_rules(mesh, SPATIAL_RULES) as rules:
            out2_spec = rules.spec(("object", None))  # tiled outputs
            out1_spec = rules.spec(("object",))
        repl_spec = P()

        order, inv = _sort_unsort(index, qpos)
        qpos_s, qid_s = qpos[order], qid[order]
        capo = self.partitioner.object_capacity(
            index.n_objects, self.num_devices
        )
        bo = self.partitioner.object_boundaries(
            _object_row_costs(index), self.num_devices
        )
        opos, oids, ocodes = _pad_object_tail(index, capo)

        def device_local(origin, side, opos_r, oids_r, ocodes_r, gstarts,
                         qp, qi, bo_r):
            return _object_merge_local(
                origin, side, opos_r, oids_r, ocodes_r, gstarts, qp, qi,
                None, bo_r, capo,
                l_max=index.l_max, th_quad=index.th_quad, k=k, window=window,
                chunk=chunk, max_nav=max_nav, max_iters=max_iters,
                executor=executor, merge=self.merge, maintenance=maintenance,
            )

        # object arrays + boundaries enter replicated (devices self-slice by
        # axis index), outputs leave tiled over the object axis
        # (replica-major); see _object_merge_local for why nothing else is
        # spec'd
        sharded = shard_map_compat(
            device_local,
            mesh=mesh,
            in_specs=(repl_spec,) * 9,
            out_specs=(out2_spec, out2_spec,
                       KnnStats(out1_spec, out1_spec, out1_spec), out1_spec),
            axis_names={"object"},
            check_vma=False,
        )
        idx_t, d2_t, st_t, cq_t = sharded(
            index.origin, index.side, opos, oids, ocodes, index.starts,
            qpos_s, qid_s, bo
        )
        idx_s = _take_replica0(idx_t, self.num_devices)
        d2_s = _take_replica0(d2_t, self.num_devices)
        cq_s = _take_replica0(cq_t, self.num_devices)
        alpha = getattr(self.partitioner, "ema_alpha", _EMA_ALPHA_DEFAULT)
        qcost_next = _ema_next(qcost[order], cq_s, alpha)[inv]
        aux = PlanAux(
            stats=_stats_total(st_t),
            shard_candidates=st_t.candidates,
            shard_iterations=st_t.iterations,
            qcost_next=qcost_next,
            object_bounds=bo,
        )
        return idx_s[inv], jnp.sqrt(d2_s[inv]), aux

    def describe(self) -> str:
        return (
            f"plan=object_sharded mesh=({self.num_devices},) axes=('object',) "
            f"devices={self.num_devices} merge={self.merge} "
            f"partitioner={self.partitioner.name}"
        )


@dataclasses.dataclass(frozen=True)
class HybridPlan(ExecutionPlan):
    """2-D ``("query", "object")`` mesh: both decompositions composed.

    Device ``(i, j)`` sweeps query-boundary interval ``i`` over object
    slice ``j``; results merge-reduce along the object axis (identical on
    every device of a query row) and gather by concatenation along the
    query axis.  The query padding granularity is ``query_devices * chunk``
    — object slicing needs no query-side padding (DESIGN.md §12).  Both
    axes take their boundaries from the partitioner (equal-count under
    ``equal``, cost-balanced under ``cost_balanced``); like
    :class:`ShardedPlan` there is ONE boundary-driven body for both
    partitioners — the query batch enters replicated either way, which is
    bounded by the object arrays this plan already replicates, and equal
    boundaries never mask a chunk.
    """

    query_devices: int
    object_devices: int
    merge: str = "dense_merge"
    partitioner: Partitioner = EqualPartitioner()
    name: ClassVar[str] = "hybrid"

    def __post_init__(self):
        if self.query_devices < 1 or self.object_devices < 1:
            raise ValueError(
                "mesh_shape axes must be >= 1, got "
                f"({self.query_devices}, {self.object_devices})"
            )
        get_merge_backend(self.merge)  # fail fast on unknown names

    @property
    def object_axis_size(self) -> int:
        return self.object_devices

    def pad_multiple(self, chunk: int) -> int:
        # every query shard must be a whole number of chunks
        return self.query_devices * chunk

    def run(self, index, qpos, qid, qcost, *, k, window, chunk, max_nav,
            max_iters, executor, qweight=None, maintenance="rebuild"):
        from jax.sharding import PartitionSpec as P

        qd, od = self.query_devices, self.object_devices
        mesh = make_spatial_mesh(qd, od)
        repl_spec = P()
        # outputs tiled over BOTH axes — query-major, object as the inner
        # (replica) block; see _object_merge_local for why
        out2_spec = P(("query", "object"), None)
        out1_spec = P(("query", "object"))

        order, inv = _sort_unsort(index, qpos)
        qpos_s, qid_s = qpos[order], qid[order]
        nq = qpos.shape[0]
        n_chunks = nq // chunk
        capq = self.partitioner.query_capacity(n_chunks, qd)
        capo = self.partitioner.object_capacity(index.n_objects, od)
        est_s = _query_cost_estimate(index, qpos_s, window)
        prev_s = qcost[order]
        cost_s = jnp.where(prev_s > 0, prev_s, est_s)
        if qweight is not None:
            cost_s = cost_s * qweight[order]
        bq = self.partitioner.query_boundaries(
            cost_s.reshape(n_chunks, chunk).sum(axis=1), qd
        )
        bo = self.partitioner.object_boundaries(_object_row_costs(index), od)
        qs_pad, qi_pad = _pad_tail_rows(qpos_s, qid_s, capq * chunk)
        opos, oids, ocodes = _pad_object_tail(index, capo)

        def device_local(origin, side, opos_r, oids_r, ocodes_r, gstarts,
                         qp, qi, bq_r, bo_r):
            i = jax.lax.axis_index("query")
            qstart = bq_r[i] * chunk
            ownq = bq_r[i + 1] - bq_r[i]
            qp_l = jax.lax.dynamic_slice_in_dim(qp, qstart, capq * chunk, 0)
            qi_l = jax.lax.dynamic_slice_in_dim(qi, qstart, capq * chunk, 0)
            return _object_merge_local(
                origin, side, opos_r, oids_r, ocodes_r, gstarts, qp_l, qi_l,
                ownq, bo_r, capo,
                l_max=index.l_max, th_quad=index.th_quad, k=k, window=window,
                chunk=chunk, max_nav=max_nav, max_iters=max_iters,
                executor=executor, merge=self.merge, maintenance=maintenance,
            )

        sharded = shard_map_compat(
            device_local,
            mesh=mesh,
            in_specs=(repl_spec,) * 10,
            out_specs=(out2_spec, out2_spec,
                       KnnStats(out1_spec, out1_spec, out1_spec), out1_spec),
            axis_names={"query", "object"},
            check_vma=False,
        )
        idx_t, d2_t, st_t, cq_t = sharded(
            index.origin, index.side, opos, oids, ocodes, index.starts,
            qs_pad, qi_pad, bq, bo
        )
        # shard (i, j) emits at block i*od + j of the tiled output; taking
        # object-replica j=0 makes the query-shard stride od * capq * chunk
        pos = _owner_positions(bq, nq, chunk, od * capq * chunk)
        idx_s, d2_s, cq_s = idx_t[pos], d2_t[pos], cq_t[pos]
        alpha = getattr(self.partitioner, "ema_alpha", _EMA_ALPHA_DEFAULT)
        qcost_next = _ema_next(qcost[order], cq_s, alpha)[inv]
        aux = PlanAux(
            stats=_stats_total(st_t),
            shard_candidates=st_t.candidates,
            shard_iterations=st_t.iterations,
            qcost_next=qcost_next,
            object_bounds=bo,
        )
        return idx_s[inv], jnp.sqrt(d2_s[inv]), aux

    def describe(self) -> str:
        return (
            f"plan=hybrid mesh=({self.query_devices}, {self.object_devices}) "
            f"axes=('query', 'object') "
            f"devices={self.query_devices * self.object_devices} "
            f"merge={self.merge} partitioner={self.partitioner.name}"
        )


# --------------------------------------------------------------------------
# plan registry — serving/benchmarks/examples select a plan by name
# --------------------------------------------------------------------------

# name -> factory(num_devices | None, Partitioner, merge | None) -> ExecutionPlan
_PLANS: dict = {}


def register_plan(name: str):
    """Decorator: register an ExecutionPlan factory under ``name``."""

    def deco(factory):
        _PLANS[name] = factory
        return factory

    return deco


def plan_names() -> tuple[str, ...]:
    """Names accepted by ``resolve_plan`` / ``EngineConfig.plan``."""
    return tuple(sorted(_PLANS))


@register_plan("single")
def _make_single(num_devices=None, partitioner=None, merge=None) -> SinglePlan:
    # the single plan has no split axes; the partitioner/merge knobs are
    # accepted (specs default them globally) and ignored
    return SinglePlan()


def _as_1d(name: str, num_devices) -> int:
    if num_devices is None:
        return jax.device_count()
    if isinstance(num_devices, (tuple, list)):
        raise ValueError(
            f"plan {name!r} lays a 1-D mesh; mesh_shape must be an int, "
            f"got {tuple(num_devices)!r} (use plan='hybrid' for 2-D shapes)"
        )
    return int(num_devices)


@register_plan("sharded")
def _make_sharded(num_devices=None, partitioner=None, merge=None) -> ShardedPlan:
    # no object axis, hence no merge reduction; the knob is accepted and
    # ignored like the single plan's partitioner
    return ShardedPlan(
        num_devices=_as_1d("sharded", num_devices),
        partitioner=resolve_partitioner(partitioner),
    )


@register_plan("object_sharded")
def _make_object_sharded(
    num_devices=None, partitioner=None, merge=None
) -> ObjectShardedPlan:
    return ObjectShardedPlan(
        num_devices=_as_1d("object_sharded", num_devices),
        partitioner=resolve_partitioner(partitioner),
        **({} if merge is None else {"merge": str(merge)}),
    )


@register_plan("hybrid")
def _make_hybrid(num_devices=None, partitioner=None, merge=None) -> HybridPlan:
    if isinstance(num_devices, (tuple, list)):
        if len(num_devices) != 2:
            raise ValueError(
                f"hybrid mesh_shape must be (query, object), got {num_devices!r}"
            )
        q, o = (int(x) for x in num_devices)
    else:
        q, o = default_hybrid_shape(num_devices)
    return HybridPlan(
        query_devices=q, object_devices=o,
        partitioner=resolve_partitioner(partitioner),
        **({} if merge is None else {"merge": str(merge)}),
    )


def resolve_plan(plan, *, num_devices=None, partitioner=None,
                 merge=None) -> ExecutionPlan:
    """Name | ExecutionPlan | None -> ExecutionPlan (default: single).

    ``num_devices`` parameterizes named plans (``EngineConfig.mesh_shape``):
    an int for the 1-D plans (``sharded`` / ``object_sharded``, default every
    visible device) or a ``(query, object)`` pair for ``hybrid`` (default the
    most balanced factorization of the device count).  ``partitioner`` is a
    :mod:`repro.core.balance` name or instance (default ``equal``); ``merge``
    a MERGE backend name for the object-axis reduction (default
    ``dense_merge``; ``fused_multi`` collapses the tree into one Pallas
    program — DESIGN.md §14).  Both are ignored when ``plan`` is already an
    ExecutionPlan instance (the instance carries its own).
    """
    if plan is None:
        return SinglePlan()
    if isinstance(plan, ExecutionPlan):
        return plan
    try:
        factory = _PLANS[str(plan)]
    except KeyError:
        raise ValueError(
            f"unknown execution plan {plan!r}; registered: {plan_names()}"
        ) from None
    return factory(num_devices, partitioner, merge)


# --------------------------------------------------------------------------
# jitted drivers
# --------------------------------------------------------------------------


@partial(
    jax.jit,
    static_argnames=("k", "window", "chunk", "max_nav", "max_iters",
                     "executor", "plan", "maintenance"),
)
def run_plan_device(
    index: QuadtreeIndex,
    qpos: jnp.ndarray,
    qid: jnp.ndarray,
    qcost: jnp.ndarray | None = None,
    qweight: jnp.ndarray | None = None,
    *,
    k: int,
    window: int,
    chunk: int,
    max_nav: int,
    max_iters: int,
    executor,
    plan: ExecutionPlan,
    maintenance: str = "rebuild",
):
    """Memory-bounded batch k-NN as ONE device program, laid out by ``plan``.

    ``Q`` must already be a whole number of ``plan.pad_multiple(chunk)`` rows:
    callers pad on the host (:func:`pad_queries`) so the compiled program is
    keyed by chunk count per shard, not by the raw query count — variable
    per-tick batch sizes reuse the same executable.  ``qcost`` is the (Q,)
    per-query cost EMA (None/zeros = no history; the serving session threads
    ``aux.qcost_next`` back in).  ``qweight`` is the optional (Q,) fairness
    multiplier on the boundary seed (None = unweighted; see
    :meth:`ExecutionPlan.run`) — None is a valid pytree leaf-set, so sessions
    that never set weights compile the exact same program as before.

    ``maintenance`` forwards the tick step's STATIC refresh mode to the plan
    (see :meth:`ExecutionPlan.run`): the object-axis plans derive their local
    trees from the global sorted order instead of re-building them whenever
    the mode guarantees that order is current (``"incremental"``/``"skip"``).
    The default ``"rebuild"`` is always valid.

    Returns (nn_idx (Q,k) i32, nn_dist (Q,k) f32 euclidean, aux
    :class:`PlanAux`) in the caller's query order (padding rows come back in
    their input positions).
    """
    nq = qpos.shape[0]
    assert nq % plan.pad_multiple(chunk) == 0, (nq, chunk, plan)
    if qcost is None:
        qcost = jnp.zeros((nq,), jnp.float32)
    return plan.run(
        index,
        qpos.astype(jnp.float32),
        qid.astype(jnp.int32),
        qcost.astype(jnp.float32),
        k=k,
        window=window,
        chunk=chunk,
        max_nav=max_nav,
        max_iters=max_iters,
        executor=executor,
        qweight=None if qweight is None else qweight.astype(jnp.float32),
        maintenance=maintenance,
    )


def knn_chunked_device(index, qpos, qid, *, k, window, chunk, max_nav,
                       max_iters, executor):
    """The single plan's sweep (kept as the PR-1 name and 3-tuple return;
    serving now goes through :func:`run_plan_device` with an explicit plan)."""
    ii, dd, aux = run_plan_device(
        index, qpos, qid, k=k, window=window, chunk=chunk, max_nav=max_nav,
        max_iters=max_iters, executor=executor, plan=SinglePlan(),
    )
    return ii, dd, aux.stats


def knn_sharded_device(index, qpos, qid, *, k, window, chunk, max_nav,
                       max_iters, executor, num_devices):
    """The sharded plan's sweep over ``num_devices`` mesh devices."""
    ii, dd, aux = run_plan_device(
        index, qpos, qid, k=k, window=window, chunk=chunk, max_nav=max_nav,
        max_iters=max_iters, executor=executor,
        plan=ShardedPlan(num_devices=num_devices),
    )
    return ii, dd, aux.stats


def knn_query_batch_chunked(
    index: QuadtreeIndex,
    qpos,
    qid=None,
    *,
    k: int = 32,
    window: int = 128,
    chunk: int = 8192,
    max_nav: int | None = None,
    max_iters: int = 100_000,
    backend=None,
    precision=None,
    plan=None,
    num_devices: int | None = None,
    partitioner=None,
    merge=None,
    maintenance: str = "rebuild",
    with_aux: bool = False,
):
    """Host-friendly wrapper over :func:`run_plan_device` (numpy in/out).

    ``plan``/``num_devices``/``partitioner``/``merge`` select the execution
    plan by name (default ``single`` / ``equal`` / ``dense_merge``);
    ``backend``/``precision`` the executor (default ``dense_topk`` /
    ``fp32``).  Padding and stripping are handled here, once, host-side.
    ``maintenance`` forwards the local-tree path to the object-axis plans
    (``"rebuild"`` builds per-device trees; ``"incremental"`` derives them
    from the index's sorted order — valid because a hand-built index IS
    current for itself).  ``with_aux=True`` appends the host-materialized
    :class:`PlanAux` (per-shard counters, cost EMA, object boundaries) to
    the return tuple — the benchmarks' straggler-gap probe.
    """
    import numpy as np

    from .executor import resolve_executor

    nq = qpos.shape[0]
    if qid is None:
        qid = np.full((nq,), -2, np.int32)
    plan = resolve_plan(plan, num_devices=num_devices, partitioner=partitioner,
                        merge=merge)
    qpos_p, qid_p = pad_queries(
        np.asarray(qpos), np.asarray(qid), plan.pad_multiple(chunk)
    )
    ii, dd, aux = run_plan_device(
        index,
        jnp.asarray(qpos_p, jnp.float32),
        jnp.asarray(qid_p, jnp.int32),
        k=k,
        window=window,
        chunk=chunk,
        max_nav=_resolve_max_nav(index, max_nav),
        max_iters=max_iters,
        executor=resolve_executor(backend, precision),
        plan=plan,
        maintenance=maintenance,
    )
    stats = KnnStats(
        iterations=int(aux.stats.iterations),
        candidates=float(aux.stats.candidates),
        leaves_visited=int(aux.stats.leaves_visited),
    )
    out = (np.asarray(ii[:nq]), np.asarray(dd[:nq]), stats)
    if with_aux:
        out += (PlanAux(
            stats=stats,
            shard_candidates=np.asarray(aux.shard_candidates),
            shard_iterations=np.asarray(aux.shard_iterations),
            qcost_next=np.asarray(aux.qcost_next[:nq]),
            object_bounds=np.asarray(aux.object_bounds),
        ),)
    return out
