"""ExecutionPlan — how a tick's query batch is laid onto devices (DESIGN.md §10).

The pipeline (``core/pipeline.py``) knows how to answer *sorted* queries
against an index; the serving layer (``core/ticks.py``) knows *when* to run a
tick.  The plan is the seam between them: it owns device layout — how the
Morton-sorted batch is chunked, split across a mesh, and gathered back.  Two
plans ship:

``single``
    Today's path: global Morton sort, ``lax.map`` over fixed-shape chunks on
    one device (the chunked sweep formerly inlined in
    ``pipeline.knn_chunked_device``, rehomed here behind the seam).

``sharded``
    A 1-D ``("query",)`` mesh (``launch.mesh.make_query_mesh``) laid out by
    the spatial logical-axis rules (``repro.dist.SPATIAL_RULES``): the
    quadtree index — positions, ids, starts, count pyramid — is *replicated*
    across devices, the Morton-sorted query batch is split into per-device
    contiguous shards with ``shard_map``, each device runs the identical
    masked dense iteration locally over its shard, and the per-shard
    ``(k, dist, id)`` lists are gathered by concatenation (query shards are
    disjoint, so the gather needs no merge).  The drift statistic is
    ``psum``-reduced over the mesh so the serving layer's rebuild trigger
    sees the whole tick's volume.

``object_sharded``
    A 1-D ``("object",)`` mesh (``launch.mesh.make_object_mesh``, DESIGN.md
    §12): the **object set** is split into Morton-contiguous equal-count
    slices (the Morton-sorted object array of the global index, reshaped;
    the tail slice padded with sentinel id -1 rows that the scan masks out),
    each device builds its own quadtree over its slice and runs the full
    query batch against it locally, and the per-device *partial* result
    lists are ``all_gather``-ed along the object axis and reduced with a
    binary tree of the MERGE backends (``kernels.ops.tree_merge_lists`` over
    ``dense_merge`` | ``fused_merge``).  This is the partition-then-merge
    route to object sets larger than one device's memory (Gowanlock's
    hybrid KNN-join, PAPERS.md).

``hybrid``
    The 2-D ``("query", "object")`` mesh composing both decompositions
    (``launch.mesh.make_spatial_mesh``): the Morton-sorted query batch
    splits along the query axis, the Morton-sorted object array along the
    object axis; each device sweeps its query shard over its object slice,
    partial lists merge-reduce along the object axis and gather by
    concatenation along the query axis.  ``mesh_shape=(qd, od)`` picks the
    factorization; the default is the most balanced one
    (``launch.mesh.default_hybrid_shape``).

ALL plans are **bit-identical** to ``single`` (pinned by tests/test_plan.py
and the property harness tests/test_properties.py across the full
backend × plan matrix).  Two disciplines make that hold:

  * every query-shard boundary coincides with a chunk boundary — the host
    pads the batch to ``(query devices) * chunk`` (:func:`pad_queries`), so
    per-chunk programs are identical to the single plan's;
  * selection is everywhere the canonical lexicographic ``(d2, id)`` order
    and navigation keeps equal-distance blocks (DESIGN.md §12), so a
    query's result is a pure function of the candidate *set* — any object
    partition yields the same bits after the merge reduction (the
    composition law ``knn(∪ P_r) = tree_merge(knn(P_r))``, contract-tested
    R-way in tests/test_kernels.py).

Plans are frozen (hence hashable) dataclasses, carried through ``jax.jit`` as
*static* arguments exactly like :class:`repro.core.executor.QueryExecutor`:
the jitted tick step specializes per (plan, backend) pair.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import ClassVar

import jax
import jax.numpy as jnp

from repro.dist import SPATIAL_RULES, shard_map_compat, use_rules
from repro.kernels.ops import tree_merge_lists
from repro.launch.mesh import (
    default_hybrid_shape,
    make_object_mesh,
    make_query_mesh,
    make_spatial_mesh,
)

from .pipeline import (
    KnnStats,
    _knn_sorted_impl,
    _resolve_max_nav,
    _sort_unsort,
)
from .quadtree import QuadtreeIndex, build_index

__all__ = [
    "ExecutionPlan",
    "SinglePlan",
    "ShardedPlan",
    "ObjectShardedPlan",
    "HybridPlan",
    "register_plan",
    "resolve_plan",
    "plan_names",
    "pad_capacity",
    "pad_queries",
    "object_shard_capacity",
    "knn_chunked_device",
    "knn_sharded_device",
    "knn_query_batch_chunked",
    "run_plan_device",
]


def pad_capacity(nq: int, multiple: int) -> int:
    """Padded row count for ``nq`` queries at the plan's granularity.

    This is the capacity of the persistent padded query registry
    (``repro.api``): the registry restages its device batch only when the
    live set changes, and the compiled tick step is keyed by this capacity
    (chunk count per shard), never by the raw query count.
    """
    return max(1, -(-nq // multiple)) * multiple


def pad_queries(qpos, qid, multiple: int):
    """Host-side pad of (Q,2)/(Q,) to :func:`pad_capacity` rows.

    ``multiple`` is the plan's padding granularity (:meth:`ExecutionPlan.
    pad_multiple`): ``chunk`` for the single plan, ``num_devices * chunk`` for
    the sharded plan — one pad, host-side, so every device shard is a whole
    number of identical fixed-shape chunks.  Padding rows clone the last
    query with qid=-2; callers strip them after the gather via ``[:Q]`` (the
    global unsort returns them to the tail).  Both the snapshot path
    (``TickEngine``/``knn_query_batch_chunked``) and the session registry pad
    through HERE, which is what makes their padded batches — and hence their
    results and stats — bit-identical.
    """
    import numpy as np

    nq = qpos.shape[0]
    padded = pad_capacity(nq, multiple)
    if padded == nq:
        return qpos, qid
    pad = padded - nq
    qpos = np.concatenate([qpos, np.tile(np.asarray(qpos[-1:]), (pad, 1))])
    qid = np.concatenate([np.asarray(qid), np.full((pad,), -2, np.int32)])
    return qpos, qid


def object_shard_capacity(n_objects: int, num_shards: int) -> int:
    """Rows per object shard: ``ceil(N / R)`` — THE shard-ownership rule.

    The object-sharded plans slice the Morton-sorted object array into
    ``num_shards`` consecutive slices of this capacity (the last one padded
    with sentinel id -1 rows).  An object's owning shard is therefore its
    Morton *rank* divided by this capacity — equal object counts per shard
    regardless of skew, Morton-contiguous so each local quadtree covers a
    compact region.  ``repro.core.ticks.object_shard_of`` evaluates the rule
    device-side for delta-ingest routing.
    """
    if num_shards < 1:
        raise ValueError(f"num_shards must be >= 1, got {num_shards}")
    return -(-max(1, n_objects) // num_shards)


def _pad_object_slices(index: QuadtreeIndex, num_shards: int):
    """Morton-sorted (pos, gids) padded so every shard slice is equal-size.

    Padding rows clone the last object's position (staying at the tail of the
    Morton order, so slices remain Morton-contiguous) with sentinel id -1 —
    the scan's validity mask drops them, so they can never enter a result
    list (they only inflate the padded shard's candidate statistic).

    Built by static-slice scatter (``.at[:n].set``), NOT ``jnp.concatenate``:
    on jax 0.4.x, a concatenate produced inside the enclosing jit and fed to
    the fully-manual shard_map fallback over a 2-D mesh is mis-partitioned by
    GSPMD — devices receive garbage slices (bit-parity caught it on the
    forced 8-device grid; eager mode and 1-D meshes are unaffected).
    """
    n = index.n_objects
    cap = object_shard_capacity(n, num_shards)
    pad = num_shards * cap - n
    if not pad:
        return index.pos, index.ids
    opos = (
        jnp.zeros((n + pad, 2), index.pos.dtype)
        .at[:n].set(index.pos)
        .at[n:].set(index.pos[-1])
    )
    oids = jnp.full((n + pad,), -1, jnp.int32).at[:n].set(index.ids)
    return opos, oids


def _local_index(opos, oids, origin, side, *, l_max, th_quad):
    """A shard-local quadtree over one Morton-contiguous object slice.

    Built with the *global* region geometry (origin/side/l_max), so Morton
    codes — and hence query sort order and navigation arithmetic — agree
    with every other shard and with the single plan.  ``build_index``
    assigns ids by sort position within its input; they are remapped through
    ``oids`` back to global object ids so result lists and the qid
    self-exclusion are partition-invariant.
    """
    local = build_index(opos, origin, side, l_max=l_max, th_quad=th_quad)
    return dataclasses.replace(local, ids=oids[local.ids])


def _object_local_merge(origin, side, opos, oids, qp, qi, *, num_shards,
                        l_max, th_quad, k, window, chunk, max_nav, max_iters,
                        executor, merge, axis_names):
    """Device-local body shared by object_sharded and hybrid (inside shard_map).

    Carves the device's own Morton-contiguous object slice out of the padded
    (replicated) object arrays by its ``"object"`` axis index, builds the
    local quadtree over just that slice, sweeps the (replicated or
    query-sharded) batch over it, then reduces the per-shard partial lists
    across the ``object`` mesh axis: ``all_gather`` of the (Q_local, k)
    lists — O(R·Q·k), list-sized, never candidate-sized — followed by a
    local binary ``tree_merge_lists`` with the selected MERGE backend.
    Every device along the object axis computes the identical merged list
    (the reduction is deterministic), so the output is replicated on that
    axis.  Stats are ``psum``-reduced over all mesh axes so the drift
    trigger sees whole-tick volume.

    ``origin``/``side`` arrive as explicit (replicated) operands, not a
    closure — shard_map bodies must not capture traced values.

    Two jax-0.4.x fallback-shard_map miscompiles shape this body (both
    caught by the bit-parity harness on the forced 8-device grid; newer jax
    and eager mode are unaffected, and the workarounds are semantically
    neutral there):

    * object arrays enter REPLICATED and each device slices locally
      (``axis_index`` + ``dynamic_slice``) — an in_spec that splits a value
      computed inside the enclosing jit along the object axis hands some
      devices garbage slices;
    * outputs leave TILED over every mesh axis, never spec'd as replicated —
      an out_spec that omits a mesh axis of a 2-D mesh assembles garbage
      from the "replicated" dim.  The caller keeps replica 0
      (:func:`_take_replica0`).
    """
    r = jax.lax.axis_index("object")
    size = opos.shape[0] // num_shards  # static rows per shard (padded)
    opos_l = jax.lax.dynamic_slice_in_dim(opos, r * size, size, 0)
    oids_l = jax.lax.dynamic_slice_in_dim(oids, r * size, size, 0)
    local = _local_index(opos_l, oids_l, origin, side,
                         l_max=l_max, th_quad=th_quad)
    idx_l, d2_l, st = _chunked_sweep(
        local, qp, qi, k=k, window=window, chunk=chunk,
        max_nav=max_nav, max_iters=max_iters, executor=executor,
    )
    d2_all = jax.lax.all_gather(d2_l, "object")  # (R, Q_local, k)
    idx_all = jax.lax.all_gather(idx_l, "object")
    d2_m, idx_m = tree_merge_lists(d2_all, idx_all, k=k, merge=merge)
    st = KnnStats(*(jax.lax.psum(x, axis_names).reshape(1) for x in st))
    return idx_m, d2_m, st


def _take_replica0(x, n_replicas: int):
    """(n_replicas * Q, ...) tiled output -> one replica's (Q, ...) rows."""
    if n_replicas == 1:
        return x
    return x.reshape((n_replicas, x.shape[0] // n_replicas) + x.shape[1:])[0]


def _chunked_sweep(index, qpos_s, qid_s, *, k, window, chunk, max_nav,
                   max_iters, executor):
    """``lax.map`` of the sorted-query program over fixed-shape chunks.

    Trace-level body shared by both plans: on the single plan it covers the
    whole batch, on the sharded plan it is the device-local program inside
    ``shard_map``.  Inputs must already be Morton-sorted and a whole number of
    chunks.
    """
    nq = qpos_s.shape[0]
    n_chunks = nq // chunk

    def one_chunk(args):
        qp, qi = args
        return _knn_sorted_impl(
            index, qp, qi, k, window, max_nav, max_iters, executor
        )

    idx_c, d2_c, stats_c = jax.lax.map(
        one_chunk,
        (qpos_s.reshape(n_chunks, chunk, 2), qid_s.reshape(n_chunks, chunk)),
    )
    stats = KnnStats(
        iterations=stats_c.iterations.sum(),
        candidates=stats_c.candidates.sum(),
        leaves_visited=stats_c.leaves_visited.sum(),
    )
    return idx_c.reshape(nq, k), d2_c.reshape(nq, k), stats


class ExecutionPlan:
    """Interface: device layout of one tick's query sweep (see module doc)."""

    name: ClassVar[str]

    @property
    def object_axis_size(self) -> int:
        """Shards on the object axis (1 = objects unsharded).

        The serving layer reads this to route delta updates to the owning
        shard (``repro.core.ticks.object_shard_of``; DESIGN.md §12).
        """
        return 1

    def pad_multiple(self, chunk: int) -> int:
        """Host-side padding granularity for :func:`pad_queries`."""
        raise NotImplementedError

    def run(self, index: QuadtreeIndex, qpos, qid, *, k, window, chunk,
            max_nav, max_iters, executor):
        """Trace-level tick sweep: (index, padded Q) -> (idx, dist, stats).

        ``qpos.shape[0]`` must be a whole multiple of ``pad_multiple(chunk)``;
        results come back in the caller's query order, distances euclidean.
        """
        raise NotImplementedError

    def describe(self) -> str:
        """Human-readable mesh/layout summary (the example service prints it)."""
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class SinglePlan(ExecutionPlan):
    """One device, the refactor-invariant path: sort -> chunked sweep -> unsort."""

    name: ClassVar[str] = "single"

    def pad_multiple(self, chunk: int) -> int:
        return chunk

    def run(self, index, qpos, qid, *, k, window, chunk, max_nav, max_iters,
            executor):
        order, inv = _sort_unsort(index, qpos)
        idx_s, d2_s, stats = _chunked_sweep(
            index, qpos[order], qid[order], k=k, window=window, chunk=chunk,
            max_nav=max_nav, max_iters=max_iters, executor=executor,
        )
        return idx_s[inv], jnp.sqrt(d2_s[inv]), stats

    def describe(self) -> str:
        return "plan=single mesh=() devices=1"


@dataclasses.dataclass(frozen=True)
class ShardedPlan(ExecutionPlan):
    """Replicated index, query-sharded sweep over a 1-D ``("query",)`` mesh."""

    num_devices: int
    name: ClassVar[str] = "sharded"

    def __post_init__(self):
        if self.num_devices < 1:
            raise ValueError(f"num_devices must be >= 1, got {self.num_devices}")

    def pad_multiple(self, chunk: int) -> int:
        # every device shard must be a whole number of chunks
        return self.num_devices * chunk

    def run(self, index, qpos, qid, *, k, window, chunk, max_nav, max_iters,
            executor):
        from jax.sharding import PartitionSpec as P

        mesh = make_query_mesh(self.num_devices)
        with use_rules(mesh, SPATIAL_RULES) as rules:
            qpos_spec = rules.spec(("query", None))   # (Q, 2) split on axis 0
            qvec_spec = rules.spec(("query",))        # (Q,) split
        repl_spec = P()  # index pytree + psum'd stats: replicated

        # global Morton sort: shards stay spatially coherent AND chunk
        # boundaries coincide with the single plan's (bit-identity argument)
        order, inv = _sort_unsort(index, qpos)
        qpos_s, qid_s = qpos[order], qid[order]

        def device_local(index, qp, qi):
            idx_l, d2_l, st = _chunked_sweep(
                index, qp, qi, k=k, window=window, chunk=chunk,
                max_nav=max_nav, max_iters=max_iters, executor=executor,
            )
            # rebuild trigger must see the WHOLE tick's computation volume
            st = KnnStats(*(jax.lax.psum(x, "query") for x in st))
            return idx_l, d2_l, st

        sharded = shard_map_compat(
            device_local,
            mesh=mesh,
            in_specs=(repl_spec, qpos_spec, qvec_spec),
            out_specs=(qpos_spec, qpos_spec, repl_spec),
            axis_names={"query"},
            check_vma=False,
        )
        idx_s, d2_s, stats = sharded(index, qpos_s, qid_s)
        return idx_s[inv], jnp.sqrt(d2_s[inv]), stats

    def describe(self) -> str:
        return (
            f"plan=sharded mesh=({self.num_devices},) axes=('query',) "
            f"devices={self.num_devices}"
        )


@dataclasses.dataclass(frozen=True)
class ObjectShardedPlan(ExecutionPlan):
    """Morton-sliced objects, one local quadtree per device, merge-reduced.

    The inverse decomposition of :class:`ShardedPlan`: the query batch is
    *replicated* across the 1-D ``("object",)`` mesh while each device owns
    ``ceil(N / R)`` Morton-contiguous objects and a quadtree over just its
    slice — per-device object state shrinks by R, which is what scales the
    *object* axis past one device's memory (the paper's massive datasets).
    The per-query partial lists reduce across the mesh with a binary tree of
    ``merge`` (a MERGE backend name; DESIGN.md §12).
    """

    num_devices: int
    merge: str = "dense_merge"
    name: ClassVar[str] = "object_sharded"

    def __post_init__(self):
        if self.num_devices < 1:
            raise ValueError(f"num_devices must be >= 1, got {self.num_devices}")

    @property
    def object_axis_size(self) -> int:
        return self.num_devices

    def pad_multiple(self, chunk: int) -> int:
        # queries are replicated, not split: single-plan granularity
        return chunk

    def run(self, index, qpos, qid, *, k, window, chunk, max_nav, max_iters,
            executor):
        from jax.sharding import PartitionSpec as P

        mesh = make_object_mesh(self.num_devices)
        with use_rules(mesh, SPATIAL_RULES) as rules:
            out2_spec = rules.spec(("object", None))  # tiled outputs
            out1_spec = rules.spec(("object",))
        repl_spec = P()

        order, inv = _sort_unsort(index, qpos)
        qpos_s, qid_s = qpos[order], qid[order]
        opos, oids = _pad_object_slices(index, self.num_devices)

        def device_local(origin, side, opos_r, oids_r, qp, qi):
            return _object_local_merge(
                origin, side, opos_r, oids_r, qp, qi,
                num_shards=self.num_devices,
                l_max=index.l_max, th_quad=index.th_quad, k=k, window=window,
                chunk=chunk, max_nav=max_nav, max_iters=max_iters,
                executor=executor, merge=self.merge, axis_names="object",
            )

        # object arrays enter replicated (devices self-slice by axis index),
        # outputs leave tiled over the object axis (replica-major); see
        # _object_local_merge for why nothing else is spec'd
        sharded = shard_map_compat(
            device_local,
            mesh=mesh,
            in_specs=(repl_spec, repl_spec, repl_spec, repl_spec, repl_spec,
                      repl_spec),
            out_specs=(out2_spec, out2_spec,
                       KnnStats(out1_spec, out1_spec, out1_spec)),
            axis_names={"object"},
            check_vma=False,
        )
        idx_t, d2_t, st_t = sharded(
            index.origin, index.side, opos, oids, qpos_s, qid_s
        )
        idx_s = _take_replica0(idx_t, self.num_devices)
        d2_s = _take_replica0(d2_t, self.num_devices)
        stats = KnnStats(*(x[0] for x in st_t))
        return idx_s[inv], jnp.sqrt(d2_s[inv]), stats

    def describe(self) -> str:
        return (
            f"plan=object_sharded mesh=({self.num_devices},) axes=('object',) "
            f"devices={self.num_devices} merge={self.merge}"
        )


@dataclasses.dataclass(frozen=True)
class HybridPlan(ExecutionPlan):
    """2-D ``("query", "object")`` mesh: both decompositions composed.

    Device ``(i, j)`` sweeps query shard ``i`` over object slice ``j``;
    results merge-reduce along the object axis (identical on every device of
    a query row) and gather by concatenation along the query axis.  The
    query padding granularity is ``query_devices * chunk`` — object slicing
    needs no query-side padding (DESIGN.md §12).
    """

    query_devices: int
    object_devices: int
    merge: str = "dense_merge"
    name: ClassVar[str] = "hybrid"

    def __post_init__(self):
        if self.query_devices < 1 or self.object_devices < 1:
            raise ValueError(
                "mesh_shape axes must be >= 1, got "
                f"({self.query_devices}, {self.object_devices})"
            )

    @property
    def object_axis_size(self) -> int:
        return self.object_devices

    def pad_multiple(self, chunk: int) -> int:
        # every query shard must be a whole number of chunks
        return self.query_devices * chunk

    def run(self, index, qpos, qid, *, k, window, chunk, max_nav, max_iters,
            executor):
        from jax.sharding import PartitionSpec as P

        mesh = make_spatial_mesh(self.query_devices, self.object_devices)
        with use_rules(mesh, SPATIAL_RULES) as rules:
            qpos_spec = rules.spec(("query", None))
            qvec_spec = rules.spec(("query",))
        repl_spec = P()
        # outputs tiled over BOTH axes — query-major, object as the inner
        # (replica) block; see _object_local_merge for why
        out2_spec = P(("query", "object"), None)
        out1_spec = P(("query", "object"))

        order, inv = _sort_unsort(index, qpos)
        qpos_s, qid_s = qpos[order], qid[order]
        opos, oids = _pad_object_slices(index, self.object_devices)

        def device_local(origin, side, opos_r, oids_r, qp, qi):
            return _object_local_merge(
                origin, side, opos_r, oids_r, qp, qi,
                num_shards=self.object_devices,
                l_max=index.l_max, th_quad=index.th_quad, k=k, window=window,
                chunk=chunk, max_nav=max_nav, max_iters=max_iters,
                executor=executor, merge=self.merge,
                axis_names=("query", "object"),
            )

        sharded = shard_map_compat(
            device_local,
            mesh=mesh,
            in_specs=(repl_spec, repl_spec, repl_spec, repl_spec, qpos_spec,
                      qvec_spec),
            out_specs=(out2_spec, out2_spec,
                       KnnStats(out1_spec, out1_spec, out1_spec)),
            axis_names={"query", "object"},
            check_vma=False,
        )
        idx_t, d2_t, st_t = sharded(
            index.origin, index.side, opos, oids, qpos_s, qid_s
        )
        nq, od = qpos.shape[0], self.object_devices
        qq = nq // self.query_devices  # rows per query shard

        def dereplicate(x):
            # (qdev * od * qq, k) -> drop the inner object-replica block
            return x.reshape((self.query_devices, od, qq) + x.shape[1:])[
                :, 0
            ].reshape((nq,) + x.shape[1:])

        idx_s, d2_s = dereplicate(idx_t), dereplicate(d2_t)
        stats = KnnStats(*(x[0] for x in st_t))
        return idx_s[inv], jnp.sqrt(d2_s[inv]), stats

    def describe(self) -> str:
        return (
            f"plan=hybrid mesh=({self.query_devices}, {self.object_devices}) "
            f"axes=('query', 'object') "
            f"devices={self.query_devices * self.object_devices} "
            f"merge={self.merge}"
        )


# --------------------------------------------------------------------------
# plan registry — serving/benchmarks/examples select a plan by name
# --------------------------------------------------------------------------

# name -> factory(num_devices | None) -> ExecutionPlan
_PLANS: dict = {}


def register_plan(name: str):
    """Decorator: register an ExecutionPlan factory under ``name``."""

    def deco(factory):
        _PLANS[name] = factory
        return factory

    return deco


def plan_names() -> tuple[str, ...]:
    """Names accepted by ``resolve_plan`` / ``EngineConfig.plan``."""
    return tuple(sorted(_PLANS))


@register_plan("single")
def _make_single(num_devices=None) -> SinglePlan:
    return SinglePlan()


def _as_1d(name: str, num_devices) -> int:
    if num_devices is None:
        return jax.device_count()
    if isinstance(num_devices, (tuple, list)):
        raise ValueError(
            f"plan {name!r} lays a 1-D mesh; mesh_shape must be an int, "
            f"got {tuple(num_devices)!r} (use plan='hybrid' for 2-D shapes)"
        )
    return int(num_devices)


@register_plan("sharded")
def _make_sharded(num_devices=None) -> ShardedPlan:
    return ShardedPlan(num_devices=_as_1d("sharded", num_devices))


@register_plan("object_sharded")
def _make_object_sharded(num_devices=None) -> ObjectShardedPlan:
    return ObjectShardedPlan(num_devices=_as_1d("object_sharded", num_devices))


@register_plan("hybrid")
def _make_hybrid(num_devices=None) -> HybridPlan:
    if isinstance(num_devices, (tuple, list)):
        if len(num_devices) != 2:
            raise ValueError(
                f"hybrid mesh_shape must be (query, object), got {num_devices!r}"
            )
        q, o = (int(x) for x in num_devices)
    else:
        q, o = default_hybrid_shape(num_devices)
    return HybridPlan(query_devices=q, object_devices=o)


def resolve_plan(plan, *, num_devices=None) -> ExecutionPlan:
    """Name | ExecutionPlan | None -> ExecutionPlan (default: single).

    ``num_devices`` parameterizes named plans (``EngineConfig.mesh_shape``):
    an int for the 1-D plans (``sharded`` / ``object_sharded``, default every
    visible device) or a ``(query, object)`` pair for ``hybrid`` (default the
    most balanced factorization of the device count).
    """
    if plan is None:
        return SinglePlan()
    if isinstance(plan, ExecutionPlan):
        return plan
    try:
        factory = _PLANS[str(plan)]
    except KeyError:
        raise ValueError(
            f"unknown execution plan {plan!r}; registered: {plan_names()}"
        ) from None
    return factory(num_devices)


# --------------------------------------------------------------------------
# jitted drivers
# --------------------------------------------------------------------------


@partial(
    jax.jit,
    static_argnames=("k", "window", "chunk", "max_nav", "max_iters",
                     "executor", "plan"),
)
def run_plan_device(
    index: QuadtreeIndex,
    qpos: jnp.ndarray,
    qid: jnp.ndarray,
    *,
    k: int,
    window: int,
    chunk: int,
    max_nav: int,
    max_iters: int,
    executor,
    plan: ExecutionPlan,
):
    """Memory-bounded batch k-NN as ONE device program, laid out by ``plan``.

    ``Q`` must already be a whole number of ``plan.pad_multiple(chunk)`` rows:
    callers pad on the host (:func:`pad_queries`) so the compiled program is
    keyed by chunk count per shard, not by the raw query count — variable
    per-tick batch sizes reuse the same executable.

    Returns (nn_idx (Q,k) i32, nn_dist (Q,k) f32 euclidean, stats) in the
    caller's query order (padding rows come back in their input positions).
    """
    nq = qpos.shape[0]
    assert nq % plan.pad_multiple(chunk) == 0, (nq, chunk, plan)
    return plan.run(
        index,
        qpos.astype(jnp.float32),
        qid.astype(jnp.int32),
        k=k,
        window=window,
        chunk=chunk,
        max_nav=max_nav,
        max_iters=max_iters,
        executor=executor,
    )


def knn_chunked_device(index, qpos, qid, *, k, window, chunk, max_nav,
                       max_iters, executor):
    """The single plan's sweep (kept as the PR-1 name; serving now goes
    through :func:`run_plan_device` with an explicit plan)."""
    return run_plan_device(
        index, qpos, qid, k=k, window=window, chunk=chunk, max_nav=max_nav,
        max_iters=max_iters, executor=executor, plan=SinglePlan(),
    )


def knn_sharded_device(index, qpos, qid, *, k, window, chunk, max_nav,
                       max_iters, executor, num_devices):
    """The sharded plan's sweep over ``num_devices`` mesh devices."""
    return run_plan_device(
        index, qpos, qid, k=k, window=window, chunk=chunk, max_nav=max_nav,
        max_iters=max_iters, executor=executor,
        plan=ShardedPlan(num_devices=num_devices),
    )


def knn_query_batch_chunked(
    index: QuadtreeIndex,
    qpos,
    qid=None,
    *,
    k: int = 32,
    window: int = 128,
    chunk: int = 8192,
    max_nav: int | None = None,
    max_iters: int = 100_000,
    backend=None,
    plan=None,
    num_devices: int | None = None,
):
    """Host-friendly wrapper over :func:`run_plan_device` (numpy in/out).

    ``plan``/``num_devices`` select the execution plan by name (default
    ``single``); padding and stripping are handled here, once, host-side.
    """
    import numpy as np

    from .executor import resolve_executor

    nq = qpos.shape[0]
    if qid is None:
        qid = np.full((nq,), -2, np.int32)
    plan = resolve_plan(plan, num_devices=num_devices)
    qpos_p, qid_p = pad_queries(
        np.asarray(qpos), np.asarray(qid), plan.pad_multiple(chunk)
    )
    ii, dd, stats = run_plan_device(
        index,
        jnp.asarray(qpos_p, jnp.float32),
        jnp.asarray(qid_p, jnp.int32),
        k=k,
        window=window,
        chunk=chunk,
        max_nav=_resolve_max_nav(index, max_nav),
        max_iters=max_iters,
        executor=resolve_executor(backend),
        plan=plan,
    )
    return (
        np.asarray(ii[:nq]),
        np.asarray(dd[:nq]),
        KnnStats(
            iterations=int(stats.iterations),
            candidates=float(stats.candidates),
            leaves_visited=int(stats.leaves_visited),
        ),
    )
