"""ExecutionPlan — how a tick's query batch is laid onto devices (DESIGN.md §10).

The pipeline (``core/pipeline.py``) knows how to answer *sorted* queries
against an index; the serving layer (``core/ticks.py``) knows *when* to run a
tick.  The plan is the seam between them: it owns device layout — how the
Morton-sorted batch is chunked, split across a mesh, and gathered back.  Two
plans ship:

``single``
    Today's path: global Morton sort, ``lax.map`` over fixed-shape chunks on
    one device (the chunked sweep formerly inlined in
    ``pipeline.knn_chunked_device``, rehomed here behind the seam).

``sharded``
    A 1-D ``("query",)`` mesh (``launch.mesh.make_query_mesh``) laid out by
    the spatial logical-axis rules (``repro.dist.SPATIAL_RULES``): the
    quadtree index — positions, ids, starts, count pyramid — is *replicated*
    across devices, the Morton-sorted query batch is split into per-device
    contiguous shards with ``shard_map``, each device runs the identical
    masked dense iteration locally over its shard, and the per-shard
    ``(k, dist, id)`` lists are gathered by concatenation (query shards are
    disjoint, so the gather needs no merge; the merge primitive
    ``kernels/merge_topk.py`` is the reduction step reserved for the future
    object-sharded plan).  The drift statistic is ``psum``-reduced over the
    mesh so the serving layer's rebuild trigger sees the whole tick's volume.

Because every shard boundary coincides with a chunk boundary (the host pads
the batch to ``num_devices * chunk``), the per-chunk programs are identical to
the single-device plan's — sharded results are **bit-identical** to ``single``
(pinned by tests/test_plan.py across all three workload families).

Plans are frozen (hence hashable) dataclasses, carried through ``jax.jit`` as
*static* arguments exactly like :class:`repro.core.executor.QueryExecutor`:
the jitted tick step specializes per (plan, backend) pair.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import ClassVar

import jax
import jax.numpy as jnp

from repro.dist import SPATIAL_RULES, shard_map_compat, use_rules
from repro.launch.mesh import make_query_mesh

from .pipeline import (
    KnnStats,
    _knn_sorted_impl,
    _resolve_max_nav,
    _sort_unsort,
)
from .quadtree import QuadtreeIndex

__all__ = [
    "ExecutionPlan",
    "SinglePlan",
    "ShardedPlan",
    "register_plan",
    "resolve_plan",
    "plan_names",
    "pad_capacity",
    "pad_queries",
    "knn_chunked_device",
    "knn_sharded_device",
    "knn_query_batch_chunked",
    "run_plan_device",
]


def pad_capacity(nq: int, multiple: int) -> int:
    """Padded row count for ``nq`` queries at the plan's granularity.

    This is the capacity of the persistent padded query registry
    (``repro.api``): the registry restages its device batch only when the
    live set changes, and the compiled tick step is keyed by this capacity
    (chunk count per shard), never by the raw query count.
    """
    return max(1, -(-nq // multiple)) * multiple


def pad_queries(qpos, qid, multiple: int):
    """Host-side pad of (Q,2)/(Q,) to :func:`pad_capacity` rows.

    ``multiple`` is the plan's padding granularity (:meth:`ExecutionPlan.
    pad_multiple`): ``chunk`` for the single plan, ``num_devices * chunk`` for
    the sharded plan — one pad, host-side, so every device shard is a whole
    number of identical fixed-shape chunks.  Padding rows clone the last
    query with qid=-2; callers strip them after the gather via ``[:Q]`` (the
    global unsort returns them to the tail).  Both the snapshot path
    (``TickEngine``/``knn_query_batch_chunked``) and the session registry pad
    through HERE, which is what makes their padded batches — and hence their
    results and stats — bit-identical.
    """
    import numpy as np

    nq = qpos.shape[0]
    padded = pad_capacity(nq, multiple)
    if padded == nq:
        return qpos, qid
    pad = padded - nq
    qpos = np.concatenate([qpos, np.tile(np.asarray(qpos[-1:]), (pad, 1))])
    qid = np.concatenate([np.asarray(qid), np.full((pad,), -2, np.int32)])
    return qpos, qid


def _chunked_sweep(index, qpos_s, qid_s, *, k, window, chunk, max_nav,
                   max_iters, executor):
    """``lax.map`` of the sorted-query program over fixed-shape chunks.

    Trace-level body shared by both plans: on the single plan it covers the
    whole batch, on the sharded plan it is the device-local program inside
    ``shard_map``.  Inputs must already be Morton-sorted and a whole number of
    chunks.
    """
    nq = qpos_s.shape[0]
    n_chunks = nq // chunk

    def one_chunk(args):
        qp, qi = args
        return _knn_sorted_impl(
            index, qp, qi, k, window, max_nav, max_iters, executor
        )

    idx_c, d2_c, stats_c = jax.lax.map(
        one_chunk,
        (qpos_s.reshape(n_chunks, chunk, 2), qid_s.reshape(n_chunks, chunk)),
    )
    stats = KnnStats(
        iterations=stats_c.iterations.sum(),
        candidates=stats_c.candidates.sum(),
        leaves_visited=stats_c.leaves_visited.sum(),
    )
    return idx_c.reshape(nq, k), d2_c.reshape(nq, k), stats


class ExecutionPlan:
    """Interface: device layout of one tick's query sweep (see module doc)."""

    name: ClassVar[str]

    def pad_multiple(self, chunk: int) -> int:
        """Host-side padding granularity for :func:`pad_queries`."""
        raise NotImplementedError

    def run(self, index: QuadtreeIndex, qpos, qid, *, k, window, chunk,
            max_nav, max_iters, executor):
        """Trace-level tick sweep: (index, padded Q) -> (idx, dist, stats).

        ``qpos.shape[0]`` must be a whole multiple of ``pad_multiple(chunk)``;
        results come back in the caller's query order, distances euclidean.
        """
        raise NotImplementedError

    def describe(self) -> str:
        """Human-readable mesh/layout summary (the example service prints it)."""
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class SinglePlan(ExecutionPlan):
    """One device, the refactor-invariant path: sort -> chunked sweep -> unsort."""

    name: ClassVar[str] = "single"

    def pad_multiple(self, chunk: int) -> int:
        return chunk

    def run(self, index, qpos, qid, *, k, window, chunk, max_nav, max_iters,
            executor):
        order, inv = _sort_unsort(index, qpos)
        idx_s, d2_s, stats = _chunked_sweep(
            index, qpos[order], qid[order], k=k, window=window, chunk=chunk,
            max_nav=max_nav, max_iters=max_iters, executor=executor,
        )
        return idx_s[inv], jnp.sqrt(d2_s[inv]), stats

    def describe(self) -> str:
        return "plan=single mesh=() devices=1"


@dataclasses.dataclass(frozen=True)
class ShardedPlan(ExecutionPlan):
    """Replicated index, query-sharded sweep over a 1-D ``("query",)`` mesh."""

    num_devices: int
    name: ClassVar[str] = "sharded"

    def __post_init__(self):
        if self.num_devices < 1:
            raise ValueError(f"num_devices must be >= 1, got {self.num_devices}")

    def pad_multiple(self, chunk: int) -> int:
        # every device shard must be a whole number of chunks
        return self.num_devices * chunk

    def run(self, index, qpos, qid, *, k, window, chunk, max_nav, max_iters,
            executor):
        from jax.sharding import PartitionSpec as P

        mesh = make_query_mesh(self.num_devices)
        with use_rules(mesh, SPATIAL_RULES) as rules:
            qpos_spec = rules.spec(("query", None))   # (Q, 2) split on axis 0
            qvec_spec = rules.spec(("query",))        # (Q,) split
        repl_spec = P()  # index pytree + psum'd stats: replicated

        # global Morton sort: shards stay spatially coherent AND chunk
        # boundaries coincide with the single plan's (bit-identity argument)
        order, inv = _sort_unsort(index, qpos)
        qpos_s, qid_s = qpos[order], qid[order]

        def device_local(index, qp, qi):
            idx_l, d2_l, st = _chunked_sweep(
                index, qp, qi, k=k, window=window, chunk=chunk,
                max_nav=max_nav, max_iters=max_iters, executor=executor,
            )
            # rebuild trigger must see the WHOLE tick's computation volume
            st = KnnStats(*(jax.lax.psum(x, "query") for x in st))
            return idx_l, d2_l, st

        sharded = shard_map_compat(
            device_local,
            mesh=mesh,
            in_specs=(repl_spec, qpos_spec, qvec_spec),
            out_specs=(qpos_spec, qpos_spec, repl_spec),
            axis_names={"query"},
            check_vma=False,
        )
        idx_s, d2_s, stats = sharded(index, qpos_s, qid_s)
        return idx_s[inv], jnp.sqrt(d2_s[inv]), stats

    def describe(self) -> str:
        return (
            f"plan=sharded mesh=({self.num_devices},) axes=('query',) "
            f"devices={self.num_devices}"
        )


# --------------------------------------------------------------------------
# plan registry — serving/benchmarks/examples select a plan by name
# --------------------------------------------------------------------------

# name -> factory(num_devices | None) -> ExecutionPlan
_PLANS: dict = {}


def register_plan(name: str):
    """Decorator: register an ExecutionPlan factory under ``name``."""

    def deco(factory):
        _PLANS[name] = factory
        return factory

    return deco


def plan_names() -> tuple[str, ...]:
    """Names accepted by ``resolve_plan`` / ``EngineConfig.plan``."""
    return tuple(sorted(_PLANS))


@register_plan("single")
def _make_single(num_devices=None) -> SinglePlan:
    return SinglePlan()


@register_plan("sharded")
def _make_sharded(num_devices=None) -> ShardedPlan:
    n = jax.device_count() if num_devices is None else int(num_devices)
    return ShardedPlan(num_devices=n)


def resolve_plan(plan, *, num_devices=None) -> ExecutionPlan:
    """Name | ExecutionPlan | None -> ExecutionPlan (default: single).

    ``num_devices`` parameterizes named plans (``EngineConfig.mesh_shape``);
    for ``sharded`` it defaults to every visible device.
    """
    if plan is None:
        return SinglePlan()
    if isinstance(plan, ExecutionPlan):
        return plan
    try:
        factory = _PLANS[str(plan)]
    except KeyError:
        raise ValueError(
            f"unknown execution plan {plan!r}; registered: {plan_names()}"
        ) from None
    return factory(num_devices)


# --------------------------------------------------------------------------
# jitted drivers
# --------------------------------------------------------------------------


@partial(
    jax.jit,
    static_argnames=("k", "window", "chunk", "max_nav", "max_iters",
                     "executor", "plan"),
)
def run_plan_device(
    index: QuadtreeIndex,
    qpos: jnp.ndarray,
    qid: jnp.ndarray,
    *,
    k: int,
    window: int,
    chunk: int,
    max_nav: int,
    max_iters: int,
    executor,
    plan: ExecutionPlan,
):
    """Memory-bounded batch k-NN as ONE device program, laid out by ``plan``.

    ``Q`` must already be a whole number of ``plan.pad_multiple(chunk)`` rows:
    callers pad on the host (:func:`pad_queries`) so the compiled program is
    keyed by chunk count per shard, not by the raw query count — variable
    per-tick batch sizes reuse the same executable.

    Returns (nn_idx (Q,k) i32, nn_dist (Q,k) f32 euclidean, stats) in the
    caller's query order (padding rows come back in their input positions).
    """
    nq = qpos.shape[0]
    assert nq % plan.pad_multiple(chunk) == 0, (nq, chunk, plan)
    return plan.run(
        index,
        qpos.astype(jnp.float32),
        qid.astype(jnp.int32),
        k=k,
        window=window,
        chunk=chunk,
        max_nav=max_nav,
        max_iters=max_iters,
        executor=executor,
    )


def knn_chunked_device(index, qpos, qid, *, k, window, chunk, max_nav,
                       max_iters, executor):
    """The single plan's sweep (kept as the PR-1 name; serving now goes
    through :func:`run_plan_device` with an explicit plan)."""
    return run_plan_device(
        index, qpos, qid, k=k, window=window, chunk=chunk, max_nav=max_nav,
        max_iters=max_iters, executor=executor, plan=SinglePlan(),
    )


def knn_sharded_device(index, qpos, qid, *, k, window, chunk, max_nav,
                       max_iters, executor, num_devices):
    """The sharded plan's sweep over ``num_devices`` mesh devices."""
    return run_plan_device(
        index, qpos, qid, k=k, window=window, chunk=chunk, max_nav=max_nav,
        max_iters=max_iters, executor=executor,
        plan=ShardedPlan(num_devices=num_devices),
    )


def knn_query_batch_chunked(
    index: QuadtreeIndex,
    qpos,
    qid=None,
    *,
    k: int = 32,
    window: int = 128,
    chunk: int = 8192,
    max_nav: int | None = None,
    max_iters: int = 100_000,
    backend=None,
    plan=None,
    num_devices: int | None = None,
):
    """Host-friendly wrapper over :func:`run_plan_device` (numpy in/out).

    ``plan``/``num_devices`` select the execution plan by name (default
    ``single``); padding and stripping are handled here, once, host-side.
    """
    import numpy as np

    from .executor import resolve_executor

    nq = qpos.shape[0]
    if qid is None:
        qid = np.full((nq,), -2, np.int32)
    plan = resolve_plan(plan, num_devices=num_devices)
    qpos_p, qid_p = pad_queries(
        np.asarray(qpos), np.asarray(qid), plan.pad_multiple(chunk)
    )
    ii, dd, stats = run_plan_device(
        index,
        jnp.asarray(qpos_p, jnp.float32),
        jnp.asarray(qid_p, jnp.int32),
        k=k,
        window=window,
        chunk=chunk,
        max_nav=_resolve_max_nav(index, max_nav),
        max_iters=max_iters,
        executor=resolve_executor(backend),
        plan=plan,
    )
    return (
        np.asarray(ii[:nq]),
        np.asarray(dd[:nq]),
        KnnStats(
            iterations=int(stats.iterations),
            candidates=float(stats.candidates),
            leaves_visited=int(stats.leaves_visited),
        ),
    )
