"""K-NN_BASELINE — the brute-force GPU k-NN of Garcia et al. (paper ref [4]).

The paper compares against this in study S2: compute the full (Q x N) distance
matrix and k-select each row.  On TPU the distance matrix maps naturally onto
(query-tile x object-tile) VPU work; we chunk over queries to bound memory.
This module doubles as the *test oracle* for the indexed pipeline.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["knn_bruteforce", "knn_bruteforce_chunked"]


@partial(jax.jit, static_argnames=("k",))
def knn_bruteforce(points: jnp.ndarray, qpos: jnp.ndarray, qid: jnp.ndarray, k: int):
    """(N,2) objects, (Q,2) queries, (Q,) issuer ids -> ((Q,k) ids, (Q,k) dists)."""
    points = points.astype(jnp.float32)
    qpos = qpos.astype(jnp.float32)
    d2 = jnp.sum((qpos[:, None, :] - points[None, :, :]) ** 2, axis=-1)  # (Q, N)
    ids = jnp.arange(points.shape[0], dtype=jnp.int32)
    d2 = jnp.where(ids[None, :] == qid[:, None], jnp.inf, d2)
    kk = min(k, points.shape[0])
    neg, idx = jax.lax.top_k(-d2, kk)
    dist = jnp.sqrt(-neg)
    idx = jnp.where(jnp.isinf(dist), -1, idx.astype(jnp.int32))
    if kk < k:  # fewer objects than requested neighbours: pad (-1, inf)
        pad = k - kk
        idx = jnp.concatenate([idx, jnp.full((idx.shape[0], pad), -1, jnp.int32)], 1)
        dist = jnp.concatenate([dist, jnp.full((dist.shape[0], pad), jnp.inf)], 1)
    return idx, dist


def knn_bruteforce_chunked(points, qpos, qid=None, *, k: int = 32, chunk: int = 2048):
    """Memory-bounded brute force (the S2 baseline at scale)."""
    nq = qpos.shape[0]
    if qid is None:
        qid = np.full((nq,), -2, np.int32)
    out_i, out_d = [], []
    pts = jnp.asarray(points)
    for lo in range(0, nq, chunk):
        hi = min(lo + chunk, nq)
        qp = jnp.asarray(qpos[lo:hi])
        qi = jnp.asarray(qid[lo:hi], dtype=jnp.int32)
        if hi - lo < chunk:
            pad = chunk - (hi - lo)
            qp = jnp.concatenate([qp, jnp.tile(qp[-1:], (pad, 1))])
            qi = jnp.concatenate([qi, jnp.full((pad,), -2, jnp.int32)])
        ii, dd = knn_bruteforce(pts, qp, qi, k)
        out_i.append(np.asarray(ii[: hi - lo]))
        out_d.append(np.asarray(dd[: hi - lo]))
    return np.concatenate(out_i), np.concatenate(out_d)
