"""The paper's contribution: iterated batched k-NN over moving objects, in JAX."""
from .balance import (
    CostBalancedPartitioner,
    EqualPartitioner,
    Partitioner,
    partitioner_names,
    resolve_partitioner,
    straggler_gap,
)
from .baseline import knn_bruteforce, knn_bruteforce_chunked
from .cpu_ref import KDTree
from .executor import (
    QueryExecutor,
    available_backends,
    available_partitioners,
    available_plans,
    resolve_executor,
    resolve_plan,
)
from .kselect import find_kdist
from .pipeline import KnnStats, knn_query_batch
from .plan import (
    ExecutionPlan,
    HybridPlan,
    ObjectShardedPlan,
    PlanAux,
    ShardedPlan,
    SinglePlan,
    knn_chunked_device,
    knn_query_batch_chunked,
    knn_sharded_device,
    object_shard_capacity,
    pad_capacity,
    pad_queries,
    run_plan_device,
)
from .quadtree import QuadtreeIndex, build_index, leaf_of_points, reindex_objects
from .ticks import (
    EngineConfig,
    TickEngine,
    TickResult,
    object_shard_of,
    scatter_positions,
    validate_engine_params,
)

__all__ = [
    "knn_bruteforce",
    "knn_bruteforce_chunked",
    "KDTree",
    "QueryExecutor",
    "Partitioner",
    "EqualPartitioner",
    "CostBalancedPartitioner",
    "PlanAux",
    "available_backends",
    "available_partitioners",
    "available_plans",
    "partitioner_names",
    "resolve_partitioner",
    "straggler_gap",
    "resolve_executor",
    "resolve_plan",
    "find_kdist",
    "KnnStats",
    "knn_chunked_device",
    "knn_query_batch",
    "knn_query_batch_chunked",
    "knn_sharded_device",
    "object_shard_capacity",
    "object_shard_of",
    "pad_capacity",
    "pad_queries",
    "run_plan_device",
    "scatter_positions",
    "validate_engine_params",
    "ExecutionPlan",
    "SinglePlan",
    "ShardedPlan",
    "ObjectShardedPlan",
    "HybridPlan",
    "QuadtreeIndex",
    "build_index",
    "leaf_of_points",
    "reindex_objects",
    "EngineConfig",
    "TickEngine",
    "TickResult",
]
