"""The paper's contribution: iterated batched k-NN over moving objects, in JAX."""
from .baseline import knn_bruteforce, knn_bruteforce_chunked
from .cpu_ref import KDTree
from .executor import QueryExecutor, available_backends, resolve_executor
from .kselect import find_kdist
from .pipeline import (
    KnnStats,
    knn_chunked_device,
    knn_query_batch,
    knn_query_batch_chunked,
)
from .quadtree import QuadtreeIndex, build_index, leaf_of_points, reindex_objects
from .ticks import EngineConfig, TickEngine, TickResult

__all__ = [
    "knn_bruteforce",
    "knn_bruteforce_chunked",
    "KDTree",
    "QueryExecutor",
    "available_backends",
    "resolve_executor",
    "find_kdist",
    "KnnStats",
    "knn_chunked_device",
    "knn_query_batch",
    "knn_query_batch_chunked",
    "QuadtreeIndex",
    "build_index",
    "leaf_of_points",
    "reindex_objects",
    "EngineConfig",
    "TickEngine",
    "TickResult",
]
