"""QueryExecutor — the seam between the kernel, core and serving layers.

The pipeline (core layer) never selects neighbours itself: every SCAN
iteration hands its gathered candidate window to an executor, which dispatches
to a registered kernel-layer backend (DESIGN.md §6).  The serving layer
(:class:`repro.core.ticks.TickEngine`) and the benchmarks pick the backend by
name (``EngineConfig.backend`` / ``--backend``), so swapping the selection
strategy — XLA top-k, the fused Pallas kernel, full-sort brute force, or any
future sharded/approximate variant — touches no pipeline code.

Since the ExecutionPlan refactor (DESIGN.md §10) the seam has TWO orthogonal
axes, both selected by name at this layer boundary:

  * **backend** (*what* merges a candidate window) — this module;
  * **plan** (*where* the sweep runs: one device or a ``("query",)`` mesh) —
    ``core/plan.py``; re-exposed here (:func:`available_plans`, plus
    ``resolve_plan`` as a lazy module-level alias of the canonical
    ``repro.core.plan.resolve_plan``) so callers configure both axes at one
    seam without a second resolution code path.

``QueryExecutor`` is a frozen (hence hashable) dataclass so it can ride
through ``jax.jit`` as a *static* argument: a jitted pipeline specializes per
backend, exactly like it specializes per ``k``/``window`` — and per plan.
"""
from __future__ import annotations

import dataclasses

from repro.kernels import get_scan_backend, scan_backend_names

__all__ = [
    "PRECISIONS",
    "QueryExecutor",
    "resolve_executor",
    "available_backends",
    "available_plans",
    "available_partitioners",
    "available_precisions",
    "resolve_plan",
]


def available_backends() -> tuple[str, ...]:
    """Names accepted by ``resolve_executor`` / ``EngineConfig.backend``."""
    return scan_backend_names()


def available_plans() -> tuple[str, ...]:
    """Names accepted by ``resolve_plan`` / ``EngineConfig.plan``."""
    from .plan import plan_names  # lazy: plan.py imports pipeline -> executor

    return plan_names()


def available_partitioners() -> tuple[str, ...]:
    """Names accepted by ``EngineConfig.partitioner`` — the third seam axis
    (work splitting), configured at the same boundary as backend and plan."""
    from .balance import partitioner_names

    return partitioner_names()


def available_precisions() -> tuple[str, ...]:
    """Names accepted by ``EngineConfig.precision`` — the sweep's numeric
    mode (DESIGN.md §14), configured at the same boundary as the backend."""
    return PRECISIONS


def __getattr__(name):
    # ``resolve_plan`` is a documented ALIAS of the canonical entry point
    # ``repro.core.plan.resolve_plan`` — resolved lazily (plan.py imports the
    # pipeline, which imports this module) and re-exported as the *same*
    # function object, so there is exactly one resolution code path
    # (tests/test_plan.py pins the identity).
    if name == "resolve_plan":
        from .plan import resolve_plan

        return resolve_plan
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


PRECISIONS = ("fp32", "mixed")


@dataclasses.dataclass(frozen=True)
class QueryExecutor:
    """A named SCAN-merge strategy (+ the sweep's numeric precision mode).

    ``precision`` selects the sweep arithmetic (DESIGN.md §14): ``fp32`` is
    the exact path; ``mixed`` prepends a bf16 distance pass with a
    conservatively widened k-th-distance radius and re-ranks only the
    survivors in exact fp32 — bitwise-identical results for every backend
    (fuzzed across the plan x partitioner matrix by the property harness).
    """

    backend: str = "dense_topk"
    precision: str = "fp32"

    def __post_init__(self):
        get_scan_backend(self.backend)  # fail fast on unknown names
        if self.precision not in PRECISIONS:
            raise ValueError(
                f"unknown precision {self.precision!r}; one of {PRECISIONS}"
            )

    def scan_merge(self, qpos, cpos, cids, valid, best_d, best_i, *, k: int):
        """Merge one candidate window into the ascending result lists.

        qpos (Q,2); cpos (Q,W,2); cids/valid (Q,W); best_d/best_i (Q,k) ->
        (best_d, best_i), semantics identical across backends up to k-th-
        distance ties.
        """
        return get_scan_backend(self.backend)(
            qpos, cpos, cids, valid, best_d, best_i, k,
            precision=self.precision,
        )


def resolve_executor(backend, precision=None) -> QueryExecutor:
    """Name | QueryExecutor | None [+ precision] -> QueryExecutor.

    Defaults: ``dense_topk`` / ``fp32``.  An explicit ``precision`` overrides
    the one a passed-in ``QueryExecutor`` instance carries.
    """
    if isinstance(backend, QueryExecutor):
        if precision is not None and precision != backend.precision:
            return dataclasses.replace(backend, precision=str(precision))
        return backend
    kw = {}
    if backend is not None:
        kw["backend"] = str(backend)
    if precision is not None:
        kw["precision"] = str(precision)
    return QueryExecutor(**kw)
