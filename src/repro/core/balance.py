"""Partitioner — cost-balanced work splitting for the execution plans (DESIGN.md §13).

The paper's speedups hold "even [for workloads] characterized by highly
skewed spatial distributions", and its repeated-queries setting means tick
τ's measured work is the best predictor of tick τ+1's.  The ExecutionPlan
seam (``core/plan.py``) used to ignore both: every plan split the
Morton-sorted query batch into equal-count contiguous chunks and the
Morton-sorted object array into equal-count slices, so under Zipf skew every
``shard_map`` barrier waited on the device that drew the dense hotspot.

This module is the seam that fixes it: plans no longer hard-code equal
splits — they ask a registered :class:`Partitioner` for **contiguous split
boundaries** along the query axis (in whole-chunk units, so shard boundaries
keep coinciding with chunk boundaries — the bit-identity argument of
DESIGN.md §10) and/or the object axis (in Morton-sorted row units).  Two
partitioners ship:

``equal``
    Today's behavior, bit-for-bit: equal-count contiguous splits, a pure
    function of the unit count.  The ``sharded`` plan keeps its static
    equal-split fast path (split ``in_specs``, no masking) when this
    partitioner is selected; the object-axis plans (``object_sharded`` /
    ``hybrid``) run ONE boundary-driven body for both partitioners — equal
    boundaries are constant-folded values, the replication they add is
    bounded by the object arrays those plans already replicate, and under
    equal boundaries no chunk is ever masked (the per-chunk ``cond`` always
    takes the live branch).  Results are bit-identical either way.

``cost_balanced``
    Boundaries chosen so every shard's *estimated cost* is as equal as the
    contiguity constraint allows (:func:`balanced_boundaries` — a prefix-sum
    + ``searchsorted`` split, clamped to a static per-shard capacity).
    Query-axis costs are seeded from statistics the index already computes
    — the count pyramid gives each query's leaf population (its candidate-
    volume estimate) — and refined each tick by an EMA of the *measured*
    per-query candidate volume fed back through the session (the
    repeated-query feedback loop; ``repro.api.KnnSession`` persists the EMA
    across ticks and rebuilds).  The object axis stays count-balanced
    ("objects per slice" — the memory budget; see
    ``core.plan._object_row_costs`` for the measured rationale), its
    boundaries flowing through the same seam.

Because boundaries move at runtime, shards become uneven — but shapes must
stay static under ``jit``/``shard_map``.  The plans therefore give every
shard a static *capacity* (:meth:`Partitioner.query_capacity` /
:meth:`Partitioner.object_capacity`, ``ceil(units / shards) * slack``) and
mask the unused tail: boundaries are data, capacities are compiled.

Partitioners are frozen (hence hashable) dataclasses carried inside the
ExecutionPlan — itself a static ``jit`` argument — so the tick step
specializes per (plan, backend, partitioner) triple while the *boundaries*
stay dynamic: re-balancing every tick never recompiles.
"""
from __future__ import annotations

import dataclasses
from typing import ClassVar

import jax.numpy as jnp

__all__ = [
    "Partitioner",
    "EqualPartitioner",
    "CostBalancedPartitioner",
    "balanced_boundaries",
    "equal_boundaries",
    "register_partitioner",
    "resolve_partitioner",
    "partitioner_names",
    "straggler_gap",
    "tenant_fair_weights",
]


def equal_boundaries(n_units: int, num_shards: int) -> jnp.ndarray:
    """(R+1,) i32 equal-count contiguous boundaries — today's split rule.

    ``b[r] = r * ceil(n / R)`` clipped to ``n``: exactly the slices the
    equal-split plans carve (query chunks per device, object rows per shard
    — ``core.plan.object_shard_capacity``'s rule expressed as boundaries).
    """
    cap = -(-max(1, n_units) // num_shards)
    return jnp.asarray(
        [min(r * cap, n_units) for r in range(num_shards + 1)], jnp.int32
    )


def balanced_boundaries(costs, num_shards: int, capacity: int) -> jnp.ndarray:
    """Contiguous boundaries with (approximately) equal cost per shard.

    ``costs`` is a (n_units,) f32 array of per-unit cost estimates (traced —
    this runs inside the jitted tick step).  Shard ``r`` receives units
    ``[b[r], b[r+1])``; the ideal boundary for shard prefix ``r`` is where
    the cost prefix sum crosses ``r/R`` of the total (``searchsorted``), then
    clamped so that

      * boundaries are monotone (contiguity),
      * no shard exceeds ``capacity`` units (the static shape the plans
        compiled for), and
      * every unit is covered (``b[R] = n`` stays reachable given
        ``R * capacity >= n`` — guaranteed by the capacity formulas below).

    The clamp recursion is unrolled over ``R`` (static, small): each step is
    O(1) on scalars, the single ``searchsorted`` is O(R log n).
    """
    n = costs.shape[0]
    if num_shards * capacity < n:
        raise ValueError(
            f"infeasible partition: {num_shards} shards x capacity "
            f"{capacity} < {n} units"
        )
    cum = jnp.cumsum(costs.astype(jnp.float32))
    total = cum[-1]
    targets = total * (
        jnp.arange(1, num_shards, dtype=jnp.float32) / num_shards
    )
    # side="right": a target landing exactly on a prefix sum cuts AFTER that
    # unit, so uniform costs reproduce the equal split exactly
    want = jnp.searchsorted(cum, targets, side="right").astype(jnp.int32)
    bs = [jnp.int32(0)]
    for r in range(1, num_shards):
        lo = jnp.maximum(bs[-1], n - (num_shards - r) * capacity)
        hi = jnp.minimum(bs[-1] + capacity, r * capacity)
        bs.append(jnp.clip(want[r - 1], lo, hi).astype(jnp.int32))
    bs.append(jnp.int32(n))
    return jnp.stack(bs)


def straggler_gap(shard_work) -> float:
    """max/mean per-shard work — THE skew metric benchmarks report (s7).

    1.0 = perfectly balanced; R = one shard does everything.  Computed on
    host from the per-shard candidate counters a tick returns
    (``TickResult.shard_candidates``).
    """
    import numpy as np

    w = np.asarray(shard_work, np.float64)
    mean = w.mean()
    return float(w.max() / mean) if mean > 0 else 1.0


def tenant_fair_weights(tenant_ids) -> "jnp.ndarray":
    """(R,) f32 per-row fairness weights from per-row tenant ids.

    The serving layer (``repro.serve``) coalesces many tenants' queries into
    one registry; under the ``cost_balanced`` partitioner the boundary seed
    is a per-query *cost*, so a tenant registering 10x more queries would
    command 10x the boundary-seeding influence.  This helper computes the
    fair-share correction: every row of tenant *t* gets weight
    ``1 / count(t)``, so each tenant's total influence on the boundary seed
    is identical regardless of how many rows it registered.  The weights
    multiply the cost seed (``core.plan`` threads them through as
    ``qweight``); only their *ratios* matter to ``balanced_boundaries``,
    and — because boundaries only move shard ownership, never results
    (DESIGN.md §13) — they can never change bits.

    Host-side numpy (runs at registration time, not in the tick step).
    """
    import numpy as np

    tid = np.asarray(tenant_ids, np.int64).reshape(-1)
    if tid.size == 0:
        return np.zeros((0,), np.float32)
    _, inv, counts = np.unique(tid, return_inverse=True, return_counts=True)
    return (1.0 / counts[inv]).astype(np.float32)


class Partitioner:
    """Interface: contiguous split boundaries for one mesh axis (module doc)."""

    name: ClassVar[str]

    @property
    def is_equal(self) -> bool:
        """True if boundaries are always the equal-count split (a pure
        function of the unit count).  Every plan now runs ONE
        boundary-driven body for both partitioners (the ``sharded`` plan's
        split-``in_specs`` fast path was retired with DESIGN.md §14); the
        flag survives as a cheap query for tests and benchmarks that want
        to know whether boundaries can move between ticks."""
        return False

    def query_capacity(self, n_chunks: int, num_shards: int) -> int:
        """Static max CHUNKS per query shard (compiled shape)."""
        raise NotImplementedError

    def object_capacity(self, n_rows: int, num_shards: int) -> int:
        """Static max Morton-sorted object ROWS per object shard."""
        raise NotImplementedError

    def query_boundaries(self, chunk_costs, num_shards: int) -> jnp.ndarray:
        """(R+1,) i32 chunk-unit boundaries from per-chunk cost estimates."""
        raise NotImplementedError

    def object_boundaries(self, row_costs, num_shards: int) -> jnp.ndarray:
        """(R+1,) i32 row-unit boundaries from per-object cost estimates."""
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class EqualPartitioner(Partitioner):
    """Equal-count contiguous splits — the pre-seam behavior, bit-for-bit."""

    name: ClassVar[str] = "equal"

    @property
    def is_equal(self) -> bool:
        return True

    def query_capacity(self, n_chunks: int, num_shards: int) -> int:
        return -(-n_chunks // num_shards)

    def object_capacity(self, n_rows: int, num_shards: int) -> int:
        return -(-max(1, n_rows) // num_shards)

    def query_boundaries(self, chunk_costs, num_shards: int) -> jnp.ndarray:
        return equal_boundaries(chunk_costs.shape[0], num_shards)

    def object_boundaries(self, row_costs, num_shards: int) -> jnp.ndarray:
        return equal_boundaries(row_costs.shape[0], num_shards)


@dataclasses.dataclass(frozen=True)
class CostBalancedPartitioner(Partitioner):
    """Boundaries balance estimated cost; shard capacity = equal * ``slack``.

    ``slack`` bounds how uneven shards may get (a shard can hold at most
    ``slack`` times its equal share) — it is a STATIC knob: larger values
    admit better balance under extreme skew at the price of a bigger
    compiled per-shard shape (masked, so mostly-idle).  ``ema_alpha`` is the
    per-query cost EMA weight the plans apply to the measured candidate
    volume each tick (0 < alpha <= 1; higher = faster adaptation).
    """

    slack: float = 2.0
    ema_alpha: float = 0.25
    name: ClassVar[str] = "cost_balanced"

    def __post_init__(self):
        if self.slack < 1.0:
            raise ValueError(f"slack must be >= 1.0, got {self.slack}")
        if not 0.0 < self.ema_alpha <= 1.0:
            raise ValueError(
                f"ema_alpha must be in (0, 1], got {self.ema_alpha}"
            )

    def _cap(self, n_units: int, num_shards: int) -> int:
        import math

        equal = -(-max(1, n_units) // num_shards)
        return min(max(1, n_units), math.ceil(equal * self.slack))

    def query_capacity(self, n_chunks: int, num_shards: int) -> int:
        return self._cap(n_chunks, num_shards)

    def object_capacity(self, n_rows: int, num_shards: int) -> int:
        # the object axis is count-balanced (core.plan._object_row_costs):
        # uniform row costs never produce a slice beyond the equal share, so
        # no slack — capacity IS the memory budget per device
        return -(-max(1, n_rows) // num_shards)

    def query_boundaries(self, chunk_costs, num_shards: int) -> jnp.ndarray:
        return balanced_boundaries(
            chunk_costs, num_shards,
            self.query_capacity(chunk_costs.shape[0], num_shards),
        )

    def object_boundaries(self, row_costs, num_shards: int) -> jnp.ndarray:
        return balanced_boundaries(
            row_costs, num_shards,
            self.object_capacity(row_costs.shape[0], num_shards),
        )


# --------------------------------------------------------------------------
# partitioner registry — spec/config/benchmarks select one by name
# --------------------------------------------------------------------------

_PARTITIONERS: dict = {}


def register_partitioner(name: str):
    """Decorator: register a Partitioner factory under ``name``."""

    def deco(factory):
        _PARTITIONERS[name] = factory
        return factory

    return deco


def partitioner_names() -> tuple[str, ...]:
    """Names accepted by ``resolve_partitioner`` / ``ServiceSpec.partitioner``."""
    return tuple(sorted(_PARTITIONERS))


@register_partitioner("equal")
def _make_equal() -> EqualPartitioner:
    return EqualPartitioner()


@register_partitioner("cost_balanced")
def _make_cost_balanced() -> CostBalancedPartitioner:
    return CostBalancedPartitioner()


def resolve_partitioner(partitioner) -> Partitioner:
    """Name | Partitioner | None -> Partitioner (default: equal)."""
    if partitioner is None:
        return EqualPartitioner()
    if isinstance(partitioner, Partitioner):
        return partitioner
    try:
        factory = _PARTITIONERS[str(partitioner)]
    except KeyError:
        raise ValueError(
            f"unknown partitioner {partitioner!r}; registered: "
            f"{partitioner_names()}"
        ) from None
    return factory()
