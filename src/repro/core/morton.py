"""Morton (Z-order) coding for 2-D points — the structural backbone of the paper.

The paper (Sec. 4.1) fixes a maximum quadtree depth ``l_max`` and identifies every
quadrant at any level ``l <= l_max`` by the pair ``(l, z)`` where ``z`` is the Morton
code of the quadrant at that level.  Key properties used throughout:

* ``z' = z >> 2*(l_max - l)`` maps a fine-level code to its ancestor at level ``l``.
* Sorting points once by their ``l_max`` Morton code keeps every quadrant at every
  level a *contiguous interval* of the sorted array.
* Quadrant geometry is pure arithmetic on the code (no memory lookups) — this is what
  makes the "virtual full quadtree" navigation of Sec. 4.2.2 accelerator friendly.

Everything here is vectorized jnp; dtypes are int32 (codes for ``l_max <= 15`` fit).
"""
from __future__ import annotations

import jax.numpy as jnp

__all__ = [
    "part1by1",
    "compact1by1",
    "encode_cells",
    "decode_code",
    "points_to_cells",
    "morton_encode_points",
    "block_box",
    "point_to_block_dist2",
]


def part1by1(v: jnp.ndarray) -> jnp.ndarray:
    """Insert a zero bit between each of the low 16 bits of ``v`` (int32)."""
    v = v.astype(jnp.uint32)
    v = (v | (v << 8)) & jnp.uint32(0x00FF00FF)
    v = (v | (v << 4)) & jnp.uint32(0x0F0F0F0F)
    v = (v | (v << 2)) & jnp.uint32(0x33333333)
    v = (v | (v << 1)) & jnp.uint32(0x55555555)
    return v


def compact1by1(v: jnp.ndarray) -> jnp.ndarray:
    """Inverse of :func:`part1by1`: extract even-position bits."""
    v = v.astype(jnp.uint32) & jnp.uint32(0x55555555)
    v = (v | (v >> 1)) & jnp.uint32(0x33333333)
    v = (v | (v >> 2)) & jnp.uint32(0x0F0F0F0F)
    v = (v | (v >> 4)) & jnp.uint32(0x00FF00FF)
    v = (v | (v >> 8)) & jnp.uint32(0x0000FFFF)
    return v


def encode_cells(cx: jnp.ndarray, cy: jnp.ndarray) -> jnp.ndarray:
    """Morton-interleave integer cell coordinates -> int32 code."""
    return (part1by1(cx) | (part1by1(cy) << 1)).astype(jnp.int32)


def decode_code(z: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Morton code -> (cx, cy) integer cell coordinates (int32)."""
    z = z.astype(jnp.uint32)
    return compact1by1(z).astype(jnp.int32), compact1by1(z >> 1).astype(jnp.int32)


def points_to_cells(
    points: jnp.ndarray, origin: jnp.ndarray, side, level: int
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Map (N, 2) points to integer cell coords of the 2^level x 2^level grid."""
    n_cells = 1 << level
    rel = (points - origin[None, :]) / side  # in [0, 1)
    c = jnp.floor(rel * n_cells).astype(jnp.int32)
    c = jnp.clip(c, 0, n_cells - 1)
    return c[:, 0], c[:, 1]


def morton_encode_points(
    points: jnp.ndarray, origin: jnp.ndarray, side, level: int
) -> jnp.ndarray:
    """(N, 2) float points -> (N,) int32 Morton codes at ``level``."""
    cx, cy = points_to_cells(points, origin, side, level)
    return encode_cells(cx, cy)


def block_box(code, a: jnp.ndarray, origin, side, l_max: int):
    """Geometry of the aligned block ``[code, code + 4**a)`` of fine cells.

    ``code`` is a fine (level ``l_max``) Morton code aligned to ``4**a``; the block is
    the quadrant at level ``l_max - a``.  Returns (x0, y0, x1, y1) — pure arithmetic,
    no memory lookups (the paper's "virtual full quadtree" property).
    """
    cellw = side / (1 << l_max)
    cx, cy = decode_code(code)
    # ``a`` may be a traced per-query array; 2**a fine cells per block side.
    span = jnp.left_shift(jnp.asarray(1, jnp.int32), jnp.asarray(a, jnp.int32))
    x0 = origin[0] + cx * cellw
    y0 = origin[1] + cy * cellw
    x1 = x0 + span * cellw
    y1 = y0 + span * cellw
    return x0, y0, x1, y1


def point_to_block_dist2(px, py, code, a, origin, side, l_max: int):
    """Squared min distance from point(s) to the aligned block ``[code, code+4**a)``.

    Used for pruning (Sec. 4.2.2): a block whose min distance exceeds the current
    k-th distance cannot contribute nearest neighbours.
    """
    x0, y0, x1, y1 = block_box(code, a, origin, side, l_max)
    dx = jnp.maximum(jnp.maximum(x0 - px, px - x1), 0.0)
    dy = jnp.maximum(jnp.maximum(y0 - py, py - y1), 0.0)
    return dx * dx + dy * dy
