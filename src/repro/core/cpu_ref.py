"""K-NN_CPU — sequential CPU kd-tree competitor (paper study S3).

The paper uses FLANN's single-core kd-tree with an optimized L2 functor and leaf
size 32.  FLANN is not available offline, so we implement the same algorithmic
class: a median-split kd-tree (widest-spread dimension), array-based nodes, and a
best-first branch-and-bound k-NN search with a bounded max-heap.  Pure
numpy/python, single core — this is the *sequential* yardstick of study S3, not a
component of the accelerated pipeline.
"""
from __future__ import annotations

import heapq

import numpy as np

__all__ = ["KDTree"]


class KDTree:
    def __init__(self, points: np.ndarray, leaf_size: int = 32):
        self.points = np.asarray(points, np.float32)
        self.leaf_size = int(leaf_size)
        n = self.points.shape[0]
        self.idx = np.arange(n, dtype=np.int32)
        # node arrays (preallocated worst case ~ 2 * ceil(n/leaf) * 2)
        cap = max(4 * (n // leaf_size + 2), 16)
        self.split_dim = np.full(cap, -1, np.int32)
        self.split_val = np.zeros(cap, np.float32)
        self.left = np.full(cap, -1, np.int32)
        self.right = np.full(cap, -1, np.int32)
        self.lo = np.zeros(cap, np.int32)  # leaf: slice into idx
        self.hi = np.zeros(cap, np.int32)
        self.bb_min = np.zeros((cap, 2), np.float32)
        self.bb_max = np.zeros((cap, 2), np.float32)
        self._n_nodes = 0
        self.root = self._build(0, n)

    def _new_node(self) -> int:
        i = self._n_nodes
        self._n_nodes += 1
        return i

    def _build(self, lo: int, hi: int) -> int:
        node = self._new_node()
        pts = self.points[self.idx[lo:hi]]
        self.bb_min[node] = pts.min(axis=0)
        self.bb_max[node] = pts.max(axis=0)
        if hi - lo <= self.leaf_size:
            self.lo[node], self.hi[node] = lo, hi
            return node
        spread = self.bb_max[node] - self.bb_min[node]
        dim = int(np.argmax(spread))
        sub = self.idx[lo:hi]
        order = np.argsort(pts[:, dim], kind="stable")
        self.idx[lo:hi] = sub[order]
        mid = (lo + hi) // 2
        self.split_dim[node] = dim
        self.split_val[node] = self.points[self.idx[mid], dim]
        self.left[node] = self._build(lo, mid)
        self.right[node] = self._build(mid, hi)
        return node

    def _box_dist2(self, node: int, q: np.ndarray) -> float:
        d = np.maximum(np.maximum(self.bb_min[node] - q, q - self.bb_max[node]), 0.0)
        return float(d @ d)

    def query(self, q: np.ndarray, k: int, exclude: int = -2):
        """Best-first k-NN for a single query point. Returns (ids, dists) ascending."""
        q = np.asarray(q, np.float32)
        heap: list[tuple[float, int]] = []  # max-heap via negated dist
        pq: list[tuple[float, int]] = [(0.0, self.root)]
        kth = np.inf
        while pq:
            bd, node = heapq.heappop(pq)
            if bd >= kth and len(heap) >= k:
                break
            if self.split_dim[node] < 0:  # leaf
                ids = self.idx[self.lo[node] : self.hi[node]]
                pts = self.points[ids]
                d2 = ((pts - q) ** 2).sum(axis=1)
                for j in range(len(ids)):
                    oid = int(ids[j])
                    if oid == exclude:
                        continue
                    dj = float(d2[j])
                    if len(heap) < k:
                        heapq.heappush(heap, (-dj, oid))
                    elif dj < -heap[0][0]:
                        heapq.heapreplace(heap, (-dj, oid))
                if len(heap) >= k:
                    kth = -heap[0][0]
            else:
                l, r = int(self.left[node]), int(self.right[node])
                for ch in (l, r):
                    d = self._box_dist2(ch, q)
                    if d < kth or len(heap) < k:
                        heapq.heappush(pq, (d, ch))
        out = sorted((-nd, oid) for nd, oid in heap)
        ids = np.full(k, -1, np.int32)
        dist = np.full(k, np.inf, np.float32)
        for j, (d2, oid) in enumerate(out):
            ids[j] = oid
            dist[j] = np.sqrt(d2)
        return ids, dist

    def query_batch(self, qpos: np.ndarray, k: int, qid=None):
        nq = qpos.shape[0]
        ids = np.empty((nq, k), np.int32)
        dist = np.empty((nq, k), np.float32)
        for i in range(nq):
            ex = -2 if qid is None else int(qid[i])
            ids[i], dist[i] = self.query(qpos[i], k, exclude=ex)
        return ids, dist
