"""Iterated batch processing of k-NN queries over ticks (paper Sec. 2.2/2.3).

``TickEngine`` is the deployable serving artifact: per tick it ingests the
up-to-date positions ``P`` and the query batch ``Q``, maintains the spatial
index, runs the iterative pipeline and emits the result batch ``R`` — i.e. the
repeated spatial join of the problem statement, with timeslice semantics.

Index maintenance follows the paper (Sec. 4.1.1): stage (ii) (object re-sort +
interval refresh) runs every tick; stage (i) (the space partition / z_map) is
rebuilt **only** when the measured computation volume of the last tick exceeds
the volume observed when the partition was built by ``rebuild_factor`` — the
paper's trigger "the overall amount of computations yielded during the last tick
exceeds by a given factor the amount yielded during past, recent ticks".
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax.numpy as jnp
import numpy as np

from .pipeline import knn_query_batch_chunked
from .quadtree import build_index, reindex_objects

__all__ = ["TickEngine", "TickResult", "EngineConfig"]


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    k: int = 32
    th_quad: int = 192
    l_max: int = 8
    window: int = 256
    chunk: int = 8192
    rebuild_factor: float = 2.0  # rebuild partition when work grows by this factor
    region_pad: float = 1e-3


@dataclasses.dataclass
class TickResult:
    tick: int
    nn_idx: np.ndarray  # (Q, k)
    nn_dist: np.ndarray  # (Q, k)
    rebuilt: bool
    wall_s: float
    candidates: float
    iterations: int


class TickEngine:
    def __init__(self, cfg: EngineConfig, origin=(0.0, 0.0), side: float = 22_500.0):
        self.cfg = cfg
        self.origin = np.asarray(origin, np.float32)
        self.side = float(side)
        self.index = None
        self._work_at_build: float | None = None
        self.tick = 0
        self.history: list[TickResult] = []

    def _build(self, positions: np.ndarray):
        self.index = build_index(
            jnp.asarray(positions),
            jnp.asarray(self.origin),
            self.side,
            l_max=self.cfg.l_max,
            th_quad=self.cfg.th_quad,
        )
        self._work_at_build = None  # set after first processed tick

    def process_tick(
        self, positions: np.ndarray, qpos: np.ndarray, qid: np.ndarray | None
    ) -> TickResult:
        """One iteration of the repeated spatial join: (P_tau, Q_tau) -> R_tau."""
        t0 = time.perf_counter()
        rebuilt = False
        if self.index is None:
            self._build(positions)
            rebuilt = True
        else:
            self.index = reindex_objects(self.index, jnp.asarray(positions))
        nn_idx, nn_dist, stats = knn_query_batch_chunked(
            self.index,
            qpos,
            qid,
            k=self.cfg.k,
            window=self.cfg.window,
            chunk=self.cfg.chunk,
        )
        work = float(stats.candidates)
        if self._work_at_build is None:
            self._work_at_build = work
        elif work > self.cfg.rebuild_factor * self._work_at_build:
            # distribution drifted: rebuild partition next tick's index state now
            self._build(positions)
            rebuilt = True
        res = TickResult(
            tick=self.tick,
            nn_idx=nn_idx,
            nn_dist=nn_dist,
            rebuilt=rebuilt,
            wall_s=time.perf_counter() - t0,
            candidates=work,
            iterations=int(stats.iterations),
        )
        self.tick += 1
        self.history.append(res)
        return res

    def run(
        self,
        workload,
        ticks: int,
        query_rate: float = 1.0,
        on_tick: Callable[[TickResult], None] | None = None,
    ):
        """Drive a MovingObjectWorkload for ``ticks`` ticks (paper: 30)."""
        out = []
        for _ in range(ticks):
            qpos, qid = workload.query_batch(query_rate)
            res = self.process_tick(workload.positions(), qpos, qid)
            out.append(res)
            if on_tick:
                on_tick(res)
            workload.advance()
        return out
