"""Iterated batch processing of k-NN queries over ticks (paper Sec. 2.2/2.3).

``TickEngine`` is the deployable serving artifact: per tick it ingests the
up-to-date positions ``P`` and the query batch ``Q``, maintains the spatial
index, runs the iterative pipeline and emits the result batch ``R`` — i.e. the
repeated spatial join of the problem statement, with timeslice semantics.

The whole steady-state tick is ONE donated-buffer jitted device program
(:func:`_tick_step`, DESIGN.md §8): stage (ii) index refresh (object re-sort +
interval/pyramid rebuild), the chunked query sweep (``lax.map`` over fixed-
shape chunks — no per-chunk host loop), and the drift statistic all run
device-side; the host reads back results plus one boolean.  Donation lets XLA
reuse the previous tick's index buffers for the refreshed index in place.

Index maintenance follows the paper (Sec. 4.1.1): stage (ii) runs every tick;
stage (i) (the space partition / z_map) is rebuilt **only** when the measured
computation volume of the last tick exceeds the volume observed when the
partition was built by ``rebuild_factor`` — the paper's trigger "the overall
amount of computations yielded during the last tick exceeds by a given factor
the amount yielded during past, recent ticks".  The trigger is *computed on
device* from the tick's candidate counter and crosses to the host as a single
scalar together with the results.

The SCAN backend is configurable per engine (``EngineConfig.backend``; see
``repro.core.executor.available_backends``), and so is the device layout of
the query sweep (``EngineConfig.plan`` / ``mesh_shape``; DESIGN.md §10): the
``sharded`` plan replicates the index across a 1-D ``("query",)`` mesh and
splits the Morton-sorted batch with ``shard_map``, its drift statistic coming
back ``psum``-reduced so the rebuild trigger sees the whole tick's volume.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from .executor import QueryExecutor, resolve_executor
from .pipeline import default_max_nav
from .plan import ExecutionPlan, pad_queries, resolve_plan
from .quadtree import build_index, reindex_objects

__all__ = ["TickEngine", "TickResult", "EngineConfig"]


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    k: int = 32
    th_quad: int = 192
    l_max: int = 8
    window: int = 256
    chunk: int = 8192
    rebuild_factor: float = 2.0  # rebuild partition when work grows by this factor
    region_pad: float = 1e-3
    backend: str = "dense_topk"  # SCAN backend (executor.available_backends())
    plan: str = "single"  # execution plan (executor.available_plans())
    mesh_shape: int | None = None  # devices on the ("query",) axis; None = all
    max_iters: int = 100_000


@dataclasses.dataclass
class TickResult:
    tick: int
    nn_idx: np.ndarray  # (Q, k)
    nn_dist: np.ndarray  # (Q, k)
    rebuilt: bool
    wall_s: float
    candidates: float
    iterations: int


@partial(
    jax.jit,
    static_argnames=("k", "window", "chunk", "max_nav", "max_iters",
                     "executor", "plan"),
    donate_argnums=(0,),
)
def _tick_step(
    index,
    positions,
    qpos,
    qid,
    work_at_build,
    rebuild_factor,
    *,
    k: int,
    window: int,
    chunk: int,
    max_nav: int,
    max_iters: int,
    executor: QueryExecutor,
    plan: ExecutionPlan,
):
    """(index, P_tau, Q_tau) -> (index', R_tau, stats, should_rebuild).

    One fused device program per tick: reindex + the plan's query sweep +
    drift check.  The step is built *per plan* (a static argument, like the
    executor): under the ``single`` plan the sweep is the chunked one-device
    ``lax.map``; under ``sharded`` it is the ``shard_map`` fan-out over the
    ``("query",)`` mesh with the refreshed index replicated and the stats
    ``psum``-reduced, so the drift comparison below sees whole-tick volume.
    The incoming index is donated — XLA refreshes it in place.  On ticks whose
    index was just built from these exact positions the reindex is a semantic
    no-op; running it anyway keeps ONE compiled program (a static skip flag
    would double the compile for a microseconds-scale saving).
    """
    index = reindex_objects(index, positions)
    nn_idx, nn_dist, stats = plan.run(
        index,
        qpos,
        qid,
        k=k,
        window=window,
        chunk=chunk,
        max_nav=max_nav,
        max_iters=max_iters,
        executor=executor,
    )
    should_rebuild = stats.candidates > rebuild_factor * work_at_build
    return index, nn_idx, nn_dist, stats, should_rebuild


class TickEngine:
    def __init__(self, cfg: EngineConfig, origin=(0.0, 0.0), side: float = 22_500.0):
        self.cfg = cfg
        self.origin = np.asarray(origin, np.float32)
        self.side = float(side)
        self.index = None
        self.executor = resolve_executor(cfg.backend)
        self.plan = resolve_plan(cfg.plan, num_devices=cfg.mesh_shape)
        self._work_at_build: float | None = None
        self.tick = 0
        self.history: list[TickResult] = []

    def _build(self, positions: np.ndarray):
        self.index = build_index(
            jnp.asarray(positions),
            jnp.asarray(self.origin),
            self.side,
            l_max=self.cfg.l_max,
            th_quad=self.cfg.th_quad,
        )
        self._work_at_build = None  # set after first processed tick

    def process_tick(
        self, positions: np.ndarray, qpos: np.ndarray, qid: np.ndarray | None
    ) -> TickResult:
        """One iteration of the repeated spatial join: (P_tau, Q_tau) -> R_tau."""
        t0 = time.perf_counter()
        rebuilt = False
        if self.index is None:
            self._build(positions)
            rebuilt = True
        nq = qpos.shape[0]
        if qid is None:
            qid = np.full((nq,), -2, np.int32)
        # host-side pad, once, to the plan's granularity (num_devices * chunk
        # for the sharded plan): the compiled step is keyed by chunk count per
        # shard, not nq; padding rows are stripped after the gather via [:nq]
        qpos_p, qid_p = pad_queries(
            np.asarray(qpos), np.asarray(qid),
            self.plan.pad_multiple(self.cfg.chunk),
        )
        # the whole tick is one jitted call; host reads results + one bool back
        self.index, nn_idx, nn_dist, stats, should_rebuild = _tick_step(
            self.index,
            jnp.asarray(positions, jnp.float32),
            jnp.asarray(qpos_p, jnp.float32),
            jnp.asarray(qid_p, jnp.int32),
            jnp.float32(np.inf if self._work_at_build is None else self._work_at_build),
            jnp.float32(self.cfg.rebuild_factor),
            k=self.cfg.k,
            window=self.cfg.window,
            chunk=self.cfg.chunk,
            max_nav=default_max_nav(self.cfg.l_max),
            max_iters=self.cfg.max_iters,
            executor=self.executor,
            plan=self.plan,
        )
        work = float(stats.candidates)
        if self._work_at_build is None:
            self._work_at_build = work
        elif bool(should_rebuild):
            # distribution drifted: rebuild partition for next tick's index now
            self._build(positions)
            rebuilt = True
        res = TickResult(
            tick=self.tick,
            nn_idx=np.asarray(nn_idx[:nq]),
            nn_dist=np.asarray(nn_dist[:nq]),
            rebuilt=rebuilt,
            wall_s=time.perf_counter() - t0,
            candidates=work,
            iterations=int(stats.iterations),
        )
        self.tick += 1
        self.history.append(res)
        return res

    def run(
        self,
        workload,
        ticks: int,
        query_rate: float = 1.0,
        on_tick: Callable[[TickResult], None] | None = None,
    ):
        """Drive a MovingObjectWorkload for ``ticks`` ticks (paper: 30)."""
        out = []
        for _ in range(ticks):
            qpos, qid = workload.query_batch(query_rate)
            res = self.process_tick(workload.positions(), qpos, qid)
            out.append(res)
            if on_tick:
                on_tick(res)
            workload.advance()
        return out
