"""Iterated batch processing of k-NN queries over ticks (paper Sec. 2.2/2.3).

Since the session-API redesign (DESIGN.md §11) this module is the **execution
core** under the public serving facade :mod:`repro.api`: it owns the jitted
per-tick device program (:func:`_tick_step`), the device-side delta-ingest
primitive (:func:`scatter_positions`), the engine configuration
(:class:`EngineConfig`, eagerly validated) and the per-tick result record
(:class:`TickResult`).  The stateful serving loop — persistent query
registry, delta object updates, overlapped submit — lives in
:class:`repro.api.KnnSession`; :class:`TickEngine` remains here as a **thin
deprecation shim** over a session so PR-1/PR-2 call sites keep working
unchanged (``TickEngine.run`` ≡ a blocking ``KnnSession`` loop, pinned by
tests/test_api.py).

The whole steady-state tick is ONE jitted device program (:func:`_tick_step`,
DESIGN.md §8/§11): stage (ii) index refresh (object re-sort + interval/
pyramid rebuild), the chunked query sweep (``lax.map`` over fixed-shape
chunks — no per-chunk host loop), and the drift statistic all run device-
side; the host reads back results plus one boolean.  The step dispatches
*asynchronously* — deliberately no buffer donation, which would force a
synchronous dispatch (see the docstring) — so the session can overlap next-
tick staging with this tick's device compute.  State *ingest* is split out
of the step: positions cross the host boundary either as a full snapshot
(``jnp.asarray``) or as a delta scatter of just the moved rows
(:func:`scatter_positions`); the step itself only ever sees device arrays.

Index maintenance follows the paper (Sec. 4.1.1): stage (ii) runs every tick;
stage (i) (the space partition / z_map) is rebuilt **only** when the measured
computation volume of the last tick exceeds the volume observed when the
partition was built by ``rebuild_factor`` — the paper's trigger "the overall
amount of computations yielded during the last tick exceeds by a given factor
the amount yielded during past, recent ticks".  The trigger is *computed on
device* from the tick's candidate counter and crosses to the host as a single
scalar together with the results.

The SCAN backend is configurable per engine (``EngineConfig.backend``; see
``repro.core.executor.available_backends``), and so is the device layout of
the query sweep (``EngineConfig.plan`` / ``mesh_shape``; DESIGN.md §10/§12):
``sharded`` replicates the index across a 1-D ``("query",)`` mesh and splits
the Morton-sorted batch with ``shard_map``; ``object_sharded`` splits the
*object* set into Morton-contiguous slices with a local quadtree per device
and merge-reduces per-query lists; ``hybrid`` composes both on a 2-D
``("query", "object")`` mesh (``mesh_shape`` becomes a pair).  How each
split axis is CUT is the partitioner's job (``EngineConfig.partitioner``;
DESIGN.md §13): ``equal`` keeps the static equal-count splits,
``cost_balanced`` re-balances boundaries every tick from the count-pyramid
seed plus the per-query cost EMA threaded through the step.  Per-shard
candidate/iteration counters come back gathered over every mesh axis
(``TickResult.shard_candidates`` — the straggler-gap metric); their sum is
the whole-tick volume the rebuild trigger reads; :func:`object_shard_of`
evaluates the object-shard ownership rule (capacity or boundary form) for
the session's delta routing.
"""
from __future__ import annotations

import dataclasses
import warnings
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from .balance import partitioner_names
from .executor import QueryExecutor, available_backends, available_plans
from .plan import ExecutionPlan
from .quadtree import reindex_objects, reindex_objects_delta

__all__ = [
    "TickEngine",
    "TickResult",
    "EngineConfig",
    "MAINTENANCE_MODES",
    "validate_engine_params",
    "scatter_positions",
    "object_shard_of",
    "route_delta",
    "delta_shard_counts",
    "shard_churn_over_budget",
]

# Index-maintenance policies (DESIGN.md §15).  "rebuild" = the paper's
# stage-(ii) full refresh every tick; "incremental" = delta recode + splice
# with work proportional to churn, deferring to a full refresh when the
# accumulated delta crosses ``churn_budget`` x N.  (The per-tick device step
# additionally knows an internal "skip" mode — the dirty-flag fast path for
# ticks with no position change — which is a session scheduling decision,
# not a user-facing policy.)
MAINTENANCE_MODES = ("rebuild", "incremental")


def validate_engine_params(*, k, window, chunk, backend, plan, mesh_shape=None,
                           partitioner=None, precision=None, merge=None,
                           maintenance=None, churn_budget=None):
    """Eager validation shared by ``EngineConfig`` and ``repro.api.ServiceSpec``.

    Raises ``ValueError`` with the full registry listing for unknown
    ``backend``/``plan``/``partitioner``/``precision``/``merge`` names
    (instead of the deep registry ``KeyError`` that used to surface on first
    use), and rejects geometry that the chunked sweep cannot serve
    (``chunk`` not a multiple of ``window``, ``k > chunk``).  Instances
    (``QueryExecutor`` / ``ExecutionPlan`` / ``Partitioner``) pass through
    unchecked — they validated themselves on construction.
    """
    from .executor import available_precisions

    if isinstance(backend, str) and backend not in available_backends():
        raise ValueError(
            f"unknown backend {backend!r}; registered SCAN backends: "
            f"{available_backends()}"
        )
    if isinstance(plan, str) and plan not in available_plans():
        raise ValueError(
            f"unknown execution plan {plan!r}; registered plans: "
            f"{available_plans()}"
        )
    if isinstance(partitioner, str) and partitioner not in partitioner_names():
        raise ValueError(
            f"unknown partitioner {partitioner!r}; registered partitioners: "
            f"{partitioner_names()}"
        )
    if precision is not None and precision not in available_precisions():
        raise ValueError(
            f"unknown precision {precision!r}; one of {available_precisions()}"
        )
    if merge is not None:
        from repro.kernels import merge_backend_names

        if isinstance(merge, str) and merge not in merge_backend_names():
            raise ValueError(
                f"unknown merge backend {merge!r}; registered MERGE "
                f"backends: {merge_backend_names()}"
            )
    if maintenance is not None and maintenance not in MAINTENANCE_MODES:
        raise ValueError(
            f"unknown maintenance mode {maintenance!r}; one of "
            f"{MAINTENANCE_MODES}"
        )
    if churn_budget is not None and not (0.0 < churn_budget <= 1.0):
        raise ValueError(
            f"churn_budget must be in (0, 1], got {churn_budget!r} "
            "(fraction of N moved since the last full refresh at which the "
            "incremental path defers to a full reindex)"
        )
    if k < 1:
        raise ValueError(f"k must be >= 1, got {k}")
    if window < 1:
        raise ValueError(f"window must be >= 1, got {window}")
    if chunk < 1 or chunk % window != 0:
        raise ValueError(
            f"chunk ({chunk}) must be a positive multiple of window ({window})"
        )
    if k > chunk:
        raise ValueError(f"k ({k}) must be <= chunk ({chunk})")
    if mesh_shape is not None:
        if isinstance(mesh_shape, (tuple, list)):
            if len(mesh_shape) != 2 or any(
                not isinstance(d, int) or d < 1 for d in mesh_shape
            ):
                raise ValueError(
                    "mesh_shape tuples must be a (query, object) pair of "
                    f"positive ints, got {mesh_shape!r}"
                )
            if isinstance(plan, str) and plan != "hybrid":
                raise ValueError(
                    f"plan {plan!r} lays a 1-D mesh; mesh_shape must be an "
                    f"int, got {tuple(mesh_shape)!r} (2-D shapes are for "
                    "plan='hybrid')"
                )
        elif mesh_shape < 1:
            raise ValueError(f"mesh_shape must be >= 1, got {mesh_shape}")


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    k: int = 32
    th_quad: int = 192
    l_max: int = 8
    window: int = 256
    chunk: int = 8192
    rebuild_factor: float = 2.0  # rebuild partition when work grows by this factor
    region_pad: float = 1e-3
    backend: str = "dense_topk"  # SCAN backend (executor.available_backends())
    plan: str = "single"  # execution plan (executor.available_plans())
    # devices on the plan's mesh: an int for the 1-D plans (sharded /
    # object_sharded), a (query, object) pair for hybrid; None = all devices
    # (hybrid: the most balanced factorization of the device count)
    mesh_shape: int | tuple[int, int] | None = None
    # work partitioner for the plan's split axes (balance.partitioner_names():
    # "equal" = the static equal-count splits, "cost_balanced" = skew-adaptive
    # boundaries from the count-pyramid seed + measured-work EMA)
    partitioner: str = "equal"
    # sweep numeric mode (executor.available_precisions(); DESIGN.md §14):
    # "fp32" = exact; "mixed" = bf16 widened-radius prefilter + fp32 refine,
    # bitwise-identical results
    precision: str = "fp32"
    # MERGE backend for the object-axis reduce (kernels.merge_backend_names();
    # "dense_merge" = binary tree of pairwise kernels, "fused_multi" = one
    # multi-way kernel per query row — no HBM round-trip between rounds)
    merge: str = "dense_merge"
    # index-maintenance policy (MAINTENANCE_MODES; DESIGN.md §15):
    # "rebuild" = full stage-(ii) refresh every dirty tick; "incremental" =
    # delta Morton recode + sorted-run splice + pyramid scatter-add for the
    # moved rows only, bitwise-identical to "rebuild" at every tick
    maintenance: str = "rebuild"
    # incremental only: fraction of N moved since the last full refresh at
    # which the session defers to a full reindex (generalizes the spirit of
    # rebuild_factor to stage (ii); the crossover where a full O(N log N)
    # sort beats delta accounting)
    churn_budget: float = 0.25
    max_iters: int = 100_000

    def __post_init__(self):
        validate_engine_params(
            k=self.k, window=self.window, chunk=self.chunk,
            backend=self.backend, plan=self.plan, mesh_shape=self.mesh_shape,
            partitioner=self.partitioner, precision=self.precision,
            merge=self.merge, maintenance=self.maintenance,
            churn_budget=self.churn_budget,
        )


@dataclasses.dataclass
class TickResult:
    tick: int
    nn_idx: np.ndarray  # (Q, k); device arrays under result(materialize=False)
    nn_dist: np.ndarray  # (Q, k)
    rebuilt: bool
    wall_s: float  # submit -> results materialized, EXCLUDING compile_s
    candidates: float
    iterations: int
    compile_s: float = 0.0  # trace+compile time, nonzero on first-shape ticks
    qids: np.ndarray | None = None  # (Q,) registry qids, row-aligned with nn_*
    # per-shard measured work, one entry per mesh device (1 for the single
    # plan); candidates sums to `candidates` bitwise (PlanAux contract) and
    # max/mean of it is the straggler gap (repro.core.balance.straggler_gap)
    shard_candidates: np.ndarray | None = None  # (R_total,) f32
    shard_iterations: np.ndarray | None = None  # (R_total,) i32
    # host-transfer time actually spent materializing THIS tick's results,
    # attributed to the tick that materializes (not the tick that submits);
    # a subset of wall_s (satellite: overlapped-mode accounting, DESIGN.md §14)
    collect_s: float = 0.0
    # on-device aggregates (repro.api.sink.TickAggregates) under
    # collect="stats"; None under "full"/"none"
    aggregates: object | None = None
    # how THIS tick's step maintained the index: "rebuild" (full stage-(ii)
    # refresh), "incremental" (delta splice), or "skip" (dirty-flag fast
    # path: nothing moved since the last refresh, reindex elided)
    maintenance: str = "rebuild"

    @property
    def kth_dist(self):
        """(Q,) Euclidean k-th distance per query row, or None.

        The radius of each row's result ball — what the serving layer's
        spatial cache invalidation stores per entry.  Derived from
        ``nn_dist[:, k-1]`` under ``collect="full"`` (host or device array,
        matching the result's residency); under ``collect="stats"`` it is
        the sink's already-reduced ``aggregates.kth_dist`` sliced to the
        live rows.  None when neither carrier is available.
        """
        if self.nn_dist is not None:
            return self.nn_dist[:, -1]
        agg = self.aggregates
        if agg is not None and getattr(agg, "kth_dist", None) is not None:
            kd = agg.kth_dist
            if self.qids is not None:
                kd = kd[: self.qids.shape[0]]
            return kd
        return None


@partial(
    jax.jit,
    static_argnames=("k", "window", "chunk", "max_nav", "max_iters",
                     "executor", "plan", "maintenance"),
)
def _tick_step(
    index,
    positions,
    qpos,
    qid,
    qcost,
    work_at_build,
    rebuild_factor,
    delta_ids,
    delta_old_pos,
    qweight=None,
    *,
    k: int,
    window: int,
    chunk: int,
    max_nav: int,
    max_iters: int,
    executor: QueryExecutor,
    plan: ExecutionPlan,
    maintenance: str = "rebuild",
):
    """(index, P_tau, Q_tau) -> (index', R_tau, aux, should_rebuild).

    One fused device program per tick: index maintenance + the plan's query
    sweep + drift check.  The step is built *per plan* (a static argument,
    like the executor): under the ``single`` plan the sweep is the chunked
    one-device ``lax.map``; under ``sharded`` it is the ``shard_map``
    fan-out over the ``("query",)`` mesh with the refreshed index
    replicated; the gathered per-shard counters (``aux.shard_candidates``)
    sum to whole-tick volume, which is what the drift comparison below
    reads.  ``qcost`` is the per-query cost EMA the session threads across
    ticks (zeros = cold); the cost-balanced partitioner turns it into next
    tick's shard boundaries.  ``qweight`` is the optional (Q,) tenant-fair
    multiplier on that boundary seed (None = unweighted — and None being a
    valid pytree, sessions that never set weights hit the same compiled
    programs as before the seam existed).

    ``maintenance`` selects the stage-(ii) refresh, statically — one
    compiled program per (shape, mode) pair (DESIGN.md §15):

    * ``"rebuild"``: full ``reindex_objects`` — recode + argsort + recount
      over all N rows; ``delta_ids``/``delta_old_pos`` must be None (not
      baked into a program that ignores them).
    * ``"incremental"``: ``reindex_objects_delta`` — recode/sort/splice only
      the ``delta_ids`` rows (sentinel-N padded, deduped by the session;
      ``delta_old_pos`` carries their positions as of the last refresh so
      the old keys can be located by search), bitwise-equal to "rebuild" by
      the splice stability argument.
    * ``"skip"``: the dirty-flag fast path — positions are unchanged since
      the index was refreshed from this very buffer, so the reindex (a
      semantic no-op, since ``reindex_objects`` is a pure function of the
      positions buffer) is elided entirely; ``delta_ids`` must be None.
      Before the seam existed the no-op reindex ran anyway to keep one
      compiled program; now the session tracks dirtiness and each mode is
      its own cached executable, so clean ticks pay zero reindex.

    The step deliberately does NOT donate the incoming index: donated
    arguments make the host-side dispatch *synchronous* on this runtime (the
    call blocks for the whole device step instead of returning a future,
    measured while building benchmarks/s6_serving.py), which would serialize
    host staging against device compute and defeat the session API's
    submit/result overlap.  The in-place refresh saved one index-sized
    allocation per tick; the overlap is worth far more, and XLA's allocator
    still recycles the freed buffers.

    ``positions`` and ``qpos``/``qid`` are *already device-resident* (staged
    by the session via snapshot upload, delta scatter, or the persistent
    padded query registry); this step never touches the host boundary.
    """
    if maintenance == "rebuild":
        index = reindex_objects(index, positions)
    elif maintenance == "incremental":
        index = reindex_objects_delta(index, positions, delta_ids, delta_old_pos)
    elif maintenance != "skip":
        raise ValueError(f"unknown step maintenance mode {maintenance!r}")
    # the mode rides into the plan (still static): under "incremental" and
    # "skip" the index's sorted order/pyramid are current for the buffer, so
    # the object-axis plans DERIVE their device-local trees from it instead
    # of re-building one per device from the replicated slice — the sharded
    # half of the maintenance seam (DESIGN.md §15)
    nn_idx, nn_dist, aux = plan.run(
        index,
        qpos,
        qid,
        qcost,
        k=k,
        window=window,
        chunk=chunk,
        max_nav=max_nav,
        max_iters=max_iters,
        executor=executor,
        qweight=qweight,
        maintenance=maintenance,
    )
    should_rebuild = aux.stats.candidates > rebuild_factor * work_at_build
    return index, nn_idx, nn_dist, aux, should_rebuild


@partial(jax.jit, static_argnames=("num_shards",))
def object_shard_of(index, ids, num_shards: int, bounds=None):
    """Owning object shard of each object id under the live index.

    Evaluates the shard-ownership rule of DESIGN.md §12/§13 device-side: an
    object's owner is determined by its Morton *rank* in the current index —
    rank divided by the shard capacity ``ceil(N / num_shards)`` under the
    equal partition, or the boundary interval containing the rank
    (``searchsorted``) when ``bounds`` carries the (R+1,) Morton-row
    boundaries a cost-balanced tick actually used
    (``PlanAux.object_bounds``).  Ownership must be re-derived from the
    index each tick because objects change rank as they move.  Returns (m,)
    int32 shard indices in ``[0, num_shards)``.

    ``ids`` must be in ``[0, index.n_objects)`` — jnp's clamping gather
    would otherwise return confidently wrong owners for stale ids, so the
    host-facing caller (``KnnSession.object_shards``) validates the range
    eagerly.
    """
    from .plan import object_shard_capacity

    n = index.n_objects
    rank = (
        jnp.zeros((n,), jnp.int32)
        .at[index.ids]
        .set(jnp.arange(n, dtype=jnp.int32))
    )
    r = rank[jnp.asarray(ids, jnp.int32)]
    if bounds is None:
        cap = object_shard_capacity(n, num_shards)
        return r // cap
    return (jnp.searchsorted(bounds, r, side="right") - 1).astype(jnp.int32)


@partial(jax.jit, static_argnames=("num_shards",))
def route_delta(index, ids, new_pos, num_shards: int, bounds=None):
    """Group a (sentinel-padded) delta batch by owning shard, device-side.

    Stable-sorts the batch rows by :func:`object_shard_of` ownership
    (sentinel rows — ``id >= N``, dropped by the scatter — sort last as a
    virtual shard ``num_shards``) and returns the reordered ``(ids,
    new_pos)``.  ``bounds`` forwards the cost-balanced boundary rule when
    the session has a completed tick's partition on hand.  Runs entirely on
    device: no host readback, so delta staging keeps the async-dispatch
    property the session's overlap contract relies on.  Today the positions
    buffer is replicated and the grouping is a pure reorder of unique ids
    (bit-identical results, pinned by the routing-edge regressions in
    tests/test_api.py); it stages the memory layout a per-shard-resident
    positions buffer will scatter as contiguous runs.
    """
    n = index.n_objects
    ids = jnp.asarray(ids, jnp.int32)
    shard = jnp.where(
        ids < n,
        object_shard_of(
            index, jnp.clip(ids, 0, max(n - 1, 0)), num_shards, bounds
        ),
        num_shards,
    )
    order = jnp.argsort(shard)  # jnp.argsort is stable by default
    return ids[order], new_pos[order]


@partial(jax.jit, static_argnames=("num_shards",))
def delta_shard_counts(index, ids, num_shards: int, bounds=None):
    """Pending delta rows per owning object shard, device-side.

    The per-shard half of the churn accounting (DESIGN.md §15): counts each
    valid id of a (sentinel-padded) pending delta batch against the shard
    that owns it under the LIVE index — the same ownership rule
    :func:`route_delta` sorts by, so a row is charged to its *source* shard
    (the shard whose local order it vacates; a cross-shard migrant perturbs
    its destination too, but the source count is the one the splice's
    delete-side work tracks, and charging one side keeps the counts a
    partition of the batch).  Sentinel rows (``id >= N``) fall into a
    virtual shard ``num_shards`` and are sliced off.  Returns (num_shards,)
    int32.
    """
    n = index.n_objects
    ids = jnp.asarray(ids, jnp.int32)
    shard = jnp.where(
        ids < n,
        object_shard_of(
            index, jnp.clip(ids, 0, max(n - 1, 0)), num_shards, bounds
        ),
        num_shards,
    )
    return jnp.bincount(
        shard, length=num_shards + 1
    )[:num_shards].astype(jnp.int32)


@partial(jax.jit, static_argnames=("num_shards",))
def shard_churn_over_budget(index, ids, num_shards: int, budget, bounds=None):
    """Does any object shard's pending churn exceed its per-shard budget?

    The sharded generalization of the session's global ``churn_budget`` rule
    (DESIGN.md §15): the incremental path's per-shard benefit — deriving each
    local tree from the spliced global order instead of re-sorting N/R rows —
    assumes churn stays a small fraction of every shard's OWNED rows; a
    single shard absorbing more than ``budget`` × its owned count is the
    local re-sort crossover, so the tick defers to a full rebuild.  Owned
    counts come from ``bounds`` (the cost-balanced boundaries the last tick
    used) or the equal-capacity rule clipped to N.  The comparison is strict
    (``>``): churn exactly AT the budget stays incremental, mirroring the
    global rule's ``<=`` boundary.  At ``num_shards == 1`` this degenerates
    to exactly the global rule (and callers skip it).  Returns a () bool.
    """
    from .plan import object_shard_capacity

    n = index.n_objects
    counts = delta_shard_counts(index, ids, num_shards, bounds)
    if bounds is None:
        cap = object_shard_capacity(n, num_shards)
        edges = jnp.minimum(
            jnp.arange(num_shards + 1, dtype=jnp.int32) * cap, n
        )
    else:
        edges = jnp.asarray(bounds, jnp.int32)
    owned = edges[1:] - edges[:-1]
    return jnp.any(
        counts.astype(jnp.float32)
        > jnp.float32(budget) * owned.astype(jnp.float32)
    )


@jax.jit
def scatter_positions(positions, ids, new_pos):
    """Delta object ingest: scatter ``new_pos`` rows at ``ids`` device-side.

    This is the session API's ``update_objects`` path (DESIGN.md §11): only
    the moved rows cross the host boundary; the (N, 2) buffer never does.
    Rows whose id is out of range are dropped (``mode="drop"``): callers pad
    variable-size update batches to a fixed multiple with the sentinel id
    ``N`` so every delta size reuses one compiled scatter.  Functional (no
    donation) on purpose — twofold: donated dispatch is synchronous on this
    runtime (see ``_tick_step``), and an in-flight tick may still be reading
    the previous buffer while the session scatters the next tick's motion
    into a fresh one (double-buffering).
    """
    return positions.at[ids].set(new_pos, mode="drop")


class TickEngine:
    """Deprecation shim: the PR-1/PR-2 snapshot-per-tick API over a session.

    ``process_tick`` stages a full position snapshot + a full query batch and
    blocks for results, exactly as before — but it now routes through
    :class:`repro.api.KnnSession` (snapshot ingest + bulk ``set_queries`` +
    ``submit().result()``), so there is a single serving implementation.
    New code should construct a ``KnnSession`` from a ``ServiceSpec`` and use
    persistent query handles + delta object updates instead.
    """

    def __init__(self, cfg: EngineConfig, origin=(0.0, 0.0), side: float = 22_500.0):
        warnings.warn(
            "TickEngine is a deprecation shim over repro.api.KnnSession; "
            "migrate to the session API (ServiceSpec + KnnSession)",
            DeprecationWarning,
            stacklevel=2,
        )
        from repro.api import KnnSession, ServiceSpec  # lazy: api sits above core

        self.cfg = cfg
        self.origin = np.asarray(origin, np.float32)
        self.side = float(side)
        self.session = KnnSession(
            ServiceSpec.from_engine(
                cfg, origin=(float(self.origin[0]), float(self.origin[1])),
                side=self.side,
            )
        )
        self.tick = 0
        self.history: list[TickResult] = []

    # legacy attribute surface (benchmarks/examples read these)
    @property
    def executor(self) -> QueryExecutor:
        return self.session.executor

    @property
    def plan(self) -> ExecutionPlan:
        return self.session.plan

    @property
    def index(self):
        return self.session.index

    def process_tick(
        self, positions: np.ndarray, qpos: np.ndarray, qid: np.ndarray | None
    ) -> TickResult:
        """One iteration of the repeated spatial join: (P_tau, Q_tau) -> R_tau."""
        res = self.session.process_tick(positions, qpos, qid)
        self.tick += 1
        self.history.append(res)
        return res

    def run(
        self,
        workload,
        ticks: int,
        query_rate: float = 1.0,
        on_tick: Callable[[TickResult], None] | None = None,
    ):
        """Drive a MovingObjectWorkload for ``ticks`` ticks (paper: 30)."""
        out = []
        for _ in range(ticks):
            qpos, qid = workload.query_batch(query_rate)
            res = self.process_tick(workload.positions(), qpos, qid)
            out.append(res)
            if on_tick:
                on_tick(res)
            workload.advance()
        return out
