"""Bucket-based k-selection (Alabi et al.), the paper's first pillar (Sec. 4.2.1).

Given per-query candidate distances, find a per-query radius ``dist_k`` enclosing
(at least) the k nearest candidates *without sorting*: iteratively histogram the
distances into ``num_bins`` buckets over a shrinking [lo, hi) range and descend into
the bucket containing the k-th element.

This module is the pure-jnp reference; ``repro.kernels.bucket_kselect`` is the fused
Pallas version that never materializes the distance matrix in HBM.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

__all__ = ["find_kdist"]


@partial(jax.jit, static_argnames=("k", "num_bins", "iters"))
def find_kdist(
    dist2: jnp.ndarray,
    valid: jnp.ndarray,
    *,
    k: int,
    num_bins: int = 32,
    iters: int = 4,
) -> jnp.ndarray:
    """Per-row k-selection radius.

    Parameters
    ----------
    dist2: (Q, C) squared distances (rows = queries, cols = candidates).
    valid: (Q, C) bool mask of real candidates.
    k: number of neighbours wanted.
    num_bins / iters: bucket refinement parameters — after ``iters`` rounds the
        returned radius is the upper edge of the bucket containing the k-th element,
        i.e. ``count(d < radius) >= k`` and the excess is < (range / num_bins**iters)
        wide in distance.

    Returns
    -------
    (Q,) radius r with ``count(valid & (dist2 < r)) >= min(k, count(valid))``.
    Rows with fewer than k valid candidates return +inf (paper: findKDist returns
    +inf when |c| < k, no computation needed).
    """
    q = dist2.shape[0]
    big = jnp.asarray(jnp.inf, dist2.dtype)
    d = jnp.where(valid, dist2, big)
    n_valid = valid.sum(axis=1)

    lo = jnp.min(jnp.where(valid, dist2, big), axis=1)  # (Q,)
    hi = jnp.max(jnp.where(valid, dist2, -big), axis=1)
    hi = jnp.maximum(hi, lo) * (1 + 1e-6) + 1e-30  # half-open upper edge
    kth = jnp.full((q,), k, jnp.int32)

    def body(_, state):
        lo, hi, kth = state
        width = (hi - lo) / num_bins
        width = jnp.maximum(width, 1e-30)
        b = jnp.floor((d - lo[:, None]) / width[:, None])
        b = jnp.clip(b, 0, num_bins - 1).astype(jnp.int32)
        in_range = valid & (d >= lo[:, None]) & (d < hi[:, None])
        onehot = jax.nn.one_hot(b, num_bins, dtype=jnp.int32) * in_range[..., None]
        hist = onehot.sum(axis=1)  # (Q, num_bins)
        cum = jnp.cumsum(hist, axis=1)
        # bucket containing the k-th in-range element
        sel = (cum >= kth[:, None]).argmax(axis=1)
        below = jnp.where(sel > 0, jnp.take_along_axis(cum, jnp.maximum(sel - 1, 0)[:, None], 1)[:, 0], 0)
        new_lo = lo + sel * width
        new_hi = new_lo + width
        new_kth = kth - below
        # float guard: edge rounding can push the k-th element out of [lo, hi);
        # keep the previous (still-valid) interval in that case.
        ok = cum[:, -1] >= kth
        return (
            jnp.where(ok, new_lo, lo),
            jnp.where(ok, new_hi, hi),
            jnp.where(ok, new_kth, kth),
        )

    lo, hi, kth = jax.lax.fori_loop(0, iters, body, (lo, hi, kth))
    r = hi
    return jnp.where(n_valid < k, big, r)
