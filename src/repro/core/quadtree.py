"""PR-quadtree spatial index (paper Sec. 4.1), built as a single device program.

The paper builds the tree level-by-level with a GPU/CPU ping-pong (Morton codes +
radix sort on GPU, split decisions on CPU).  On TPU/XLA we improve on this with a
**count pyramid**: one ``bincount`` at the finest level ``l_max`` plus ``l_max``
reshape-sums give the population of *every* quadrant at *every* level in O(|P|).
The PR-quadtree leaf predicate — "deepest ancestor chain whose counts exceed
``th_quad``" — is then evaluated vectorized for all ``4**l_max`` fine cells at once,
which directly materializes the paper's ``z_map`` lookup table (fine cell -> leaf).

Leaf identity convention (matches the paper's total order, Fig. 2): a leaf at level
``l`` is identified by its *first fine cell code* ``key = z << 2*(l_max - l)``; leaves
are totally ordered by ``key`` and tile ``[0, 4**l_max)`` into consecutive intervals.
Because of the Morton sort invariance, the objects of a leaf occupy the contiguous
slice ``[starts[key], starts[key + 4**(l_max-l)])`` of the sorted object array.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from . import morton
from ..kernels.delta_splice import (
    gather_splice,
    searchsorted_pairs,
    sparse_splice_plan,
)

__all__ = [
    "QuadtreeIndex",
    "build_index",
    "rebuild_zmap",
    "reindex_objects",
    "reindex_objects_delta",
    "leaf_of_points",
    "starts_from_pyramid",
    "local_pyramid_from_starts",
    "pyramid_delta",
    "ball_stab_mask",
]


@partial(
    jax.tree_util.register_dataclass,
    data_fields=[
        "origin",
        "side",
        "pos",
        "ids",
        "codes",
        "starts",
        "leaf_level",
        "pyramid",
    ],
    meta_fields=["l_max", "th_quad"],
)
@dataclasses.dataclass(frozen=True)
class QuadtreeIndex:
    """The spatial index + Morton-sorted object store (a pytree).

    Attributes
    ----------
    origin: (2,) f32 — lower-left corner of the MBR ``G``.
    side:   ()  f32 — side length of ``G`` (squared region, as in the paper).
    pos:    (N, 2) f32 — object positions, sorted by fine Morton code (SoV layout).
    ids:    (N,) i32 — original object ids, same order.
    codes:  (N,) i32 — fine Morton codes, sorted.
    starts: (4**l_max + 1,) i32 — prefix offsets: fine cell c holds objects
            ``pos[starts[c]:starts[c+1]]``.
    leaf_level: (4**l_max,) i32 — level of the quadtree leaf covering each fine cell
            (this *is* the paper's z_map: leaf key = (c >> 2d) << 2d,
            d = l_max - leaf_level[c]).
    pyramid: flattened i32 array of quadrant populations at every level
            (``pyr[pyramid_offset(l) + z]``); used for empty-block skipping during
            navigation.
    l_max:   static int — maximum quadtree depth.
    th_quad: static int — max objects per leaf (split threshold).
    """

    origin: jnp.ndarray
    side: jnp.ndarray
    pos: jnp.ndarray
    ids: jnp.ndarray
    codes: jnp.ndarray
    starts: jnp.ndarray
    leaf_level: jnp.ndarray
    pyramid: jnp.ndarray
    l_max: int
    th_quad: int

    def level_counts(self, level: int) -> jnp.ndarray:
        """Populations of the 4**level quadrants at ``level`` (view of pyramid)."""
        off = pyramid_offset(level)
        return self.pyramid[off : off + 4**level]

    @property
    def n_objects(self) -> int:
        return self.pos.shape[0]

    @property
    def n_fine(self) -> int:
        return 4**self.l_max


def pyramid_offset(level):
    """Start of level ``level`` inside the flattened pyramid: (4**l - 1) / 3.

    Works for both static ints and traced int arrays — this is what lets the
    navigation loop index the pyramid at a *dynamic* level (rolled loops keep the
    compiled program small).
    """
    return ((1 << (2 * level)) - 1) // 3 if isinstance(level, int) else (
        (jnp.left_shift(jnp.int32(1), 2 * level) - 1) // 3
    )


def _count_pyramid(codes: jnp.ndarray, l_max: int) -> jnp.ndarray:
    """Quadrant populations at every level, flattened level-major.

    ``pyr[pyramid_offset(l) + z]`` = population of quadrant ``(l, z)``.
    Total size (4**(l_max+1) - 1) / 3.
    """
    counts = jnp.bincount(codes, length=4**l_max).astype(jnp.int32)
    levels = [counts]
    cur = counts
    for _ in range(l_max):
        cur = cur.reshape(-1, 4).sum(axis=1)
        levels.append(cur)
    return jnp.concatenate(list(reversed(levels)))


def starts_from_pyramid(pyramid: jnp.ndarray, l_max: int) -> jnp.ndarray:
    """Prefix offsets from the pyramid's fine level: ``starts[c] = # codes < c``.

    Shared by every index-maintenance path (build / full reindex / delta
    reindex) so that ``starts`` is always the same op over the same int32
    counts — equal pyramids therefore give bitwise-equal offsets.
    """
    fine_counts = pyramid[pyramid_offset(l_max) :]
    return jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(fine_counts).astype(jnp.int32)]
    )


def local_pyramid_from_starts(starts, lo, own, clone_code, capo: int, l_max: int):
    """Count pyramid of one Morton-contiguous slice, derived from GLOBAL offsets.

    A shard owning global sorted ranks ``[lo, lo + own)`` (padded to a static
    ``capo``-row capacity whose surplus rows all carry ``clone_code``) does
    not need to re-``bincount`` its slice: the global ``starts`` array already
    counts every fine cell, so the slice's population of cell ``c`` is the
    overlap of the cell's global rank interval ``[starts[c], starts[c+1])``
    with the owned window —

        ``max(0, min(starts[c+1], lo + own) - max(starts[c], lo))``

    — an O(4**l_max) gather + arithmetic with no scatter and no sort.  The
    ``capo - own`` clone rows are added at ``clone_code`` in one scalar
    update.  All int32 arithmetic, so the fine level is integer-exact equal
    to ``bincount`` over the slice's codes, and the reshape-sum rollup is the
    same op chain as :func:`_count_pyramid` — bitwise-equal pyramids (the
    per-shard derived-index identity of DESIGN.md §15).
    """
    s = starts[:-1]
    e = starts[1:]
    hi = lo + own
    fine = jnp.maximum(
        jnp.minimum(e, hi) - jnp.maximum(s, lo), 0
    ).astype(jnp.int32)
    fine = fine.at[clone_code].add(jnp.int32(capo) - own)
    levels = [fine]
    cur = fine
    for _ in range(l_max):
        cur = cur.reshape(-1, 4).sum(axis=1)
        levels.append(cur)
    return jnp.concatenate(list(reversed(levels)))


def pyramid_delta(
    pyramid: jnp.ndarray,
    old_codes: jnp.ndarray,
    new_codes: jnp.ndarray,
    weight: jnp.ndarray,
    l_max: int,
) -> jnp.ndarray:
    """Update the count pyramid for rows whose fine code changed.

    Scatter-subtract ``weight`` at the old fine-level quadrant and
    scatter-add it at the new one — Δ-sized scatters at the *fine level
    only* — then rebuild the ``l_max`` coarser levels by 4-way reshape-sums
    (the same derivation :func:`_count_pyramid` uses, O(4**l_max) adds
    total).  ``weight`` is 1 for real delta rows, 0 for padding; codes at or
    above ``4**l_max`` (the sentinel convention) fall outside the fine level
    and are dropped.  Integer adds are exact and commute, so the result is
    bitwise-equal to a from-scratch recount of the updated code set — the
    incremental path's pyramid identity in DESIGN.md §15.  O(Δ + 4**l_max)
    work versus the recount's O(N + 4**l_max), and no per-level scatter
    chain (XLA scatters cost ~per-element; the reshape-sums vectorize).
    """
    fine = pyramid[pyramid_offset(l_max) :]
    fine = fine.at[old_codes].add(-weight, mode="drop").at[new_codes].add(
        weight, mode="drop"
    )
    levels = [fine]
    cur = fine
    for _ in range(l_max):
        cur = cur.reshape(-1, 4).sum(axis=1)
        levels.append(cur)
    return jnp.concatenate(list(reversed(levels)))


def _part1by1_np(v: np.ndarray) -> np.ndarray:
    """numpy replica of :func:`repro.core.morton.part1by1` (host-side stab)."""
    v = np.asarray(v, np.uint32)
    v = (v | (v << 8)) & np.uint32(0x00FF00FF)
    v = (v | (v << 4)) & np.uint32(0x0F0F0F0F)
    v = (v | (v << 2)) & np.uint32(0x33333333)
    v = (v | (v << 1)) & np.uint32(0x55555555)
    return v


def _encode_cells_np(cx: np.ndarray, cy: np.ndarray) -> np.ndarray:
    return (_part1by1_np(cx) | (_part1by1_np(cy) << 1)).astype(np.int64)


# conservative widening on the stored squared k-th distance: the kernel
# measures the Euclidean k-th distance in f32 (f32 squared distance,
# possibly FMA-fused, then f32 sqrt), and the cache squares that back in
# f64 on insert — so the stored r^2 can sit a handful of ulps below the
# exact value (a few 2**-23 relative from the kernel's d^2 plus half an
# ulp from the sqrt, doubled by the squaring); 2**-17 gives ~an order of
# magnitude of headroom over that ~5*2**-23 worst case while staying
# geometrically negligible, and at r^2 == 0 no margin is needed (f32
# subtraction yields exactly 0 iff the coordinates are bitwise equal).
_STAB_MARGIN = 1.0 + 2.0**-17


def ball_stab_mask(
    centers: np.ndarray,
    kth2: np.ndarray,
    moved: np.ndarray,
    *,
    origin,
    side,
    l_max: int,
    exact_rows: int = 64,
) -> np.ndarray:
    """Which closed k-th-distance balls does a set of moved points stab?

    Host-side (pure numpy) primitive of the serving layer's spatial cache
    invalidation (DESIGN.md §16): cached entry *e* — query center
    ``centers[e]``, squared k-th distance ``kth2[e]`` — can only have
    changed if some moved row's old or new position lies inside its
    **closed** ball (inclusive boundary: an object tied at exactly the k-th
    distance can flip the canonical id tie-break).  Returns an ``(E,)`` bool
    mask, True = must evict.  The mask is *conservative*: widened by
    ``_STAB_MARGIN`` against f32 kernel rounding, coarsened to cell
    granularity on the pyramid path, and clipped positions only merge cells
    at the region boundary — every approximation adds stabs, never drops
    one.

    Two regimes, same contract:

    * ``moved`` small (``<= exact_rows``): exact vectorized pairwise check.
      f64 squared distance of f32 inputs is *exact* (products of f32 are
      exact in f64 and their sum carries <= 49 significand bits), so only
      the stored radius needs the margin.
    * ``moved`` large: a Morton occupancy pyramid over the moved rows'
      fine cells (the same level-major layout as :func:`_count_pyramid`,
      booleans instead of counts) and, per ball, the coarsest level whose
      cell side covers the ball diameter — there the ball's bbox spans at
      most 2x2 cells, so four occupancy probes decide the stab.

    Non-finite geometry is handled per entry: NaN/inf centers or NaN radius
    always stab (a NaN-payload geometry key is a legitimate cache key whose
    ball is undefined — evicting is the only safe answer), and an infinite
    radius (fewer than k live candidates) stabs on any motion.
    """
    centers = np.asarray(centers, np.float64).reshape(-1, 2)
    kth2 = np.asarray(kth2, np.float64).reshape(-1)
    moved = np.asarray(moved, np.float64).reshape(-1, 2)
    E = centers.shape[0]
    M = moved.shape[0]
    bad = ~(np.isfinite(centers).all(axis=1) & ~np.isnan(kth2))
    if E == 0 or M == 0:
        # no movement to localize, but non-finite geometry (NaN *or* inf
        # radius) still reports as a stab — the always-evict contract does
        # not depend on the delta
        return bad | np.isinf(kth2)
    r2 = kth2 * _STAB_MARGIN
    if M <= exact_rows:
        d2 = (
            (centers[:, None, 0] - moved[None, :, 0]) ** 2
            + (centers[:, None, 1] - moved[None, :, 1]) ** 2
        )
        return bad | (d2 <= r2[:, None]).any(axis=1)
    ox, oy = float(np.asarray(origin).reshape(-1)[0]), float(
        np.asarray(origin).reshape(-1)[1]
    )
    side = float(side)
    n_fine = 1 << l_max
    # occupancy pyramid over the moved rows' fine cells (clip = boundary
    # cells, conservative for out-of-region motion)
    mx = np.clip(np.floor((moved[:, 0] - ox) / side * n_fine), 0, n_fine - 1)
    my = np.clip(np.floor((moved[:, 1] - oy) / side * n_fine), 0, n_fine - 1)
    occ_fine = np.zeros((n_fine * n_fine,), bool)
    occ_fine[_encode_cells_np(mx.astype(np.int64), my.astype(np.int64))] = True
    levels = [occ_fine]
    cur = occ_fine
    for _ in range(l_max):
        cur = cur.reshape(-1, 4).any(axis=1)
        levels.append(cur)
    occ = np.concatenate(list(reversed(levels)))
    # per ball: coarsest level with cell side >= ball diameter (r == 0 ->
    # finest; inf radius or any non-finite geometry -> unconditional stab)
    r = np.sqrt(np.maximum(r2, 0.0))
    always = bad | np.isinf(r)
    ok = ~always
    lvl = np.full((E,), l_max, np.int64)
    pos_r = ok & (r > 0.0)
    with np.errstate(divide="ignore", invalid="ignore"):
        want = np.floor(np.log2(side / (2.0 * np.where(pos_r, r, 1.0))))
    lvl[pos_r] = np.clip(want[pos_r], 0, l_max).astype(np.int64)
    n_cells = np.int64(1) << lvl
    off = ((np.int64(1) << (2 * lvl)) - 1) // 3

    def cell(coord, o):
        c = np.floor((coord - o) / side * n_cells)
        return np.clip(c, 0, n_cells - 1).astype(np.int64)

    # sanitize the always-stab rows so the int casts below see finite values
    cx = np.where(ok, centers[:, 0], ox)
    cy = np.where(ok, centers[:, 1], oy)
    r = np.where(ok & np.isfinite(r), r, 0.0)
    xs = (cell(cx - r, ox), cell(cx + r, ox))
    ys = (cell(cy - r, oy), cell(cy + r, oy))
    hit = np.zeros((E,), bool)
    for ix in xs:
        for iy in ys:
            hit |= occ[off + _encode_cells_np(ix, iy)]
    return always | (ok & hit)


def _leaf_levels(pyramid: jnp.ndarray, l_max: int, th_quad: int) -> jnp.ndarray:
    """Leaf level per fine cell = number of split ancestors along its path.

    A node splits iff its population exceeds ``th_quad`` (and l < l_max).  Path
    populations are non-increasing with depth, so the split predicate holds on a
    prefix of levels and the *count of splitting ancestors* equals the leaf level.
    """
    fine = jnp.arange(4**l_max, dtype=jnp.int32)
    ll = jnp.zeros(4**l_max, dtype=jnp.int32)
    for l in range(l_max):  # levels 0 .. l_max-1 may split
        anc = fine >> jnp.int32(2 * (l_max - l))
        lvl_counts = pyramid[pyramid_offset(l) : pyramid_offset(l) + 4**l]
        ll = ll + (lvl_counts[anc] > th_quad).astype(jnp.int32)
    return ll


@partial(jax.jit, static_argnames=("l_max", "th_quad"))
def build_index(
    points: jnp.ndarray,
    origin: jnp.ndarray,
    side,
    *,
    l_max: int = 8,
    th_quad: int = 192,
) -> QuadtreeIndex:
    """Stage (i) + (ii) of the pipeline: build the PR-quadtree and index objects.

    Equivalent to the paper's *index creation* (Sec. 4.1.1) + *moving objects
    indexing* (Sec. 4.1.2), fused into one device program:
      1. fine Morton codes for all points                      (paper: GPU)
      2. sort by code (XLA sort ~ radix sort role)             (paper: GPU radix)
      3. count pyramid + leaf levels -> z_map                  (paper: GPU+CPU loop)
      4. prefix offsets -> per-cell object intervals           (paper: GPU)
    """
    points = points.astype(jnp.float32)
    origin = jnp.asarray(origin, jnp.float32)
    side = jnp.asarray(side, jnp.float32)
    codes = morton.morton_encode_points(points, origin, side, l_max)
    order = jnp.argsort(codes)
    codes_s = codes[order]
    pos_s = points[order]
    ids_s = order.astype(jnp.int32)
    pyramid = _count_pyramid(codes, l_max)
    leaf_level = _leaf_levels(pyramid, l_max, th_quad)
    starts = starts_from_pyramid(pyramid, l_max)
    return QuadtreeIndex(
        origin=origin,
        side=side,
        pos=pos_s,
        ids=ids_s,
        codes=codes_s,
        starts=starts,
        leaf_level=leaf_level,
        pyramid=pyramid,
        l_max=l_max,
        th_quad=th_quad,
    )


@jax.jit
def rebuild_zmap(index: QuadtreeIndex) -> QuadtreeIndex:
    """Stage (i) only: re-derive the leaf partition (z_map) from the live pyramid.

    The drift policy's rebuild re-decides where the quadtree splits — but when
    the index's sorted order and pyramid are already current for the positions
    buffer (a clean buffer, or right after a splice/reindex), a full
    ``build_index`` would recompute the encode + argsort + recount only to
    arrive at the very same arrays: ``build_index``'s stable argsort of the
    id-indexed codes IS the order the maintenance paths keep, and its pyramid
    is the recount the splice's integer deltas already equal.  The only field
    a rebuild actually changes is ``leaf_level``, a pure function of the
    pyramid — so the stage-(i) reuse rule (DESIGN.md §15) replaces the
    O(N log N) re-sort with one O(4**l_max) ``_leaf_levels`` pass, bitwise
    equal to ``build_index`` over the same positions.
    """
    return dataclasses.replace(
        index,
        leaf_level=_leaf_levels(index.pyramid, index.l_max, index.th_quad),
    )


@partial(jax.jit, static_argnames=())
def reindex_objects(index: QuadtreeIndex, points: jnp.ndarray) -> QuadtreeIndex:
    """Stage (ii) only: re-sort fresh object positions into the *existing* partition.

    Per the paper, stage (i) (the space partition / z_map) is reused across ticks
    while the distribution is stable; every tick only re-sorts the new positions and
    recomputes the per-cell intervals (+ the pyramid, which is O(|C|) and needed for
    empty-block pruning).
    """
    l_max = index.l_max
    points = points.astype(jnp.float32)
    codes = morton.morton_encode_points(points, index.origin, index.side, l_max)
    order = jnp.argsort(codes)
    pyramid = _count_pyramid(codes, l_max)
    starts = starts_from_pyramid(pyramid, l_max)
    return dataclasses.replace(
        index,
        pos=points[order],
        ids=order.astype(jnp.int32),
        codes=codes[order],
        starts=starts,
        pyramid=pyramid,
    )


@jax.jit
def reindex_objects_delta(
    index: QuadtreeIndex,
    points: jnp.ndarray,
    delta_ids: jnp.ndarray,
    delta_old_pos: jnp.ndarray,
) -> QuadtreeIndex:
    """Stage (ii) with work proportional to the delta, not to N.

    Produces bitwise the same index as ``reindex_objects(index, points)``
    when ``points`` differs from the indexed positions only at ``delta_ids``
    (DESIGN.md §15 has the full argument):

    * the canonical order is lexicographic ``(code, id)`` — a stable argsort
      of id-indexed codes — so it can be reproduced by splicing the Δ moved
      rows (the only sort, O(Δ log Δ) via a 2-key ``lax.sort``) into the
      surviving rows of the old order.  The splice is the *sparse* plan of
      the delta-splice kernel: moved slots are located by a
      ``(old code, id)`` pair binary search against the existing sorted
      keys (no O(N) inverse-rank scatter), and the merged order comes back
      as gather sources, so no step issues an N-sized scatter —
      kernels/delta_splice.py documents why that distinction carries the
      whole speedup on XLA backends;
    * the pyramid is int32 counts, so ±1 fine-level scatter-adds at the
      old/new cells + reshape-sum rollup are exactly a recount
      (:func:`pyramid_delta`);
    * ``starts`` is the same :func:`starts_from_pyramid` op over that
      pyramid; ``leaf_level`` (stage i) is untouched, exactly as in
      ``reindex_objects``.

    ``delta_ids`` must contain each object id at most once (the session
    dedups keep-first before padding); out-of-range ids (the sentinel-N
    padding convention of ``scatter_positions``) are ignored.
    ``delta_old_pos`` row ``r`` must hold the position object
    ``delta_ids[r]`` had when ``index`` was built — bitwise, as float32 —
    so its old ``(code, id)`` key can be recomputed and found by search;
    padding rows are arbitrary.  Cost: O(Δ log Δ) sort + O(Δ log N) search
    + O(Δ) scatters + two O(N) cumsums and the O(N) output gathers.  When
    ``(code, id)`` fits a packed int32 (the common case: it needs
    ``4**l_max * (n+1) + n < 2**31``) the sort and search run over packed
    single keys; otherwise the explicit pair formulation of
    kernels/delta_splice.py takes over (x64 is disabled, so there is no
    64-bit packed fallback).
    """
    n = index.n_objects
    l_max = index.l_max
    points = points.astype(jnp.float32)
    ids = delta_ids.astype(jnp.int32)
    p = ids.shape[0]
    valid = ids < n
    safe = jnp.where(valid, ids, 0)
    sent_code = jnp.int32(4**l_max)  # > every real fine code
    q_ids = jnp.where(valid, ids, n)
    old_codes = jnp.where(
        valid,
        morton.morton_encode_points(
            delta_old_pos.astype(jnp.float32), index.origin, index.side, l_max
        ),
        sent_code,
    )
    # run B: the moved rows, (code, id)-lexsorted — the only sort in the path
    new_pos = points[safe]
    new_codes = morton.morton_encode_points(new_pos, index.origin, index.side, l_max)
    new_codes_m = jnp.where(valid, new_codes, sent_code)
    arange_p = jnp.arange(p, dtype=jnp.int32)
    if 4**l_max * (n + 1) + n < 2**31:
        # (code, id) packs into one int32 (id < n+1 makes numeric order equal
        # lexicographic order): a 1-key sort + plain searchsorted beat the
        # pair formulation's 2-key sort + gather-per-iteration binary search.
        mult = jnp.int32(n + 1)
        pk_b, perm = jax.lax.sort(
            (new_codes_m * mult + q_ids, arange_p), num_keys=1
        )
        codes_b = new_codes_m[perm]
        ids_b = q_ids[perm]
        # ONE fused search, side="right": the first half hits existing keys
        # exactly (rank = slot + 1); the second ranks new keys for insertion.
        res = jnp.searchsorted(
            index.codes * mult + index.ids,
            jnp.concatenate([old_codes * mult + q_ids, pk_b]),
            side="right",
        ).astype(jnp.int32)
    else:
        codes_b, ids_b, perm = jax.lax.sort(
            (new_codes_m, q_ids, arange_p), num_keys=2
        )
        res = searchsorted_pairs(
            index.codes,
            index.ids,
            jnp.concatenate([old_codes, codes_b]),
            jnp.concatenate([q_ids, ids_b]),
            side="right",
        )
    pos_b = new_pos[perm]
    slots = jnp.where(valid, res[:p] - 1, n)
    src_a, b_src = sparse_splice_plan(slots, res[p:], n)
    codes_n = gather_splice(src_a, b_src, index.codes, codes_b)
    ids_n = gather_splice(src_a, b_src, index.ids, ids_b)
    pos_n = gather_splice(src_a, b_src, index.pos, pos_b)
    pyramid = pyramid_delta(
        index.pyramid,
        old_codes,
        new_codes_m,
        valid.astype(jnp.int32),
        l_max,
    )
    starts = starts_from_pyramid(pyramid, l_max)
    return dataclasses.replace(
        index,
        pos=pos_n,
        ids=ids_n,
        codes=codes_n,
        starts=starts,
        pyramid=pyramid,
    )


def leaf_of_points(index: QuadtreeIndex, points: jnp.ndarray):
    """z_map lookup (paper Sec. 4.1.1): points -> (leaf_key, leaf_level).

    Constant-time arithmetic + one table read per point; no tree descent.
    """
    fine = morton.morton_encode_points(points, index.origin, index.side, index.l_max)
    lvl = index.leaf_level[fine]
    shift = 2 * (index.l_max - lvl)
    key = (fine >> shift) << shift
    return key, lvl
