"""PR-quadtree spatial index (paper Sec. 4.1), built as a single device program.

The paper builds the tree level-by-level with a GPU/CPU ping-pong (Morton codes +
radix sort on GPU, split decisions on CPU).  On TPU/XLA we improve on this with a
**count pyramid**: one ``bincount`` at the finest level ``l_max`` plus ``l_max``
reshape-sums give the population of *every* quadrant at *every* level in O(|P|).
The PR-quadtree leaf predicate — "deepest ancestor chain whose counts exceed
``th_quad``" — is then evaluated vectorized for all ``4**l_max`` fine cells at once,
which directly materializes the paper's ``z_map`` lookup table (fine cell -> leaf).

Leaf identity convention (matches the paper's total order, Fig. 2): a leaf at level
``l`` is identified by its *first fine cell code* ``key = z << 2*(l_max - l)``; leaves
are totally ordered by ``key`` and tile ``[0, 4**l_max)`` into consecutive intervals.
Because of the Morton sort invariance, the objects of a leaf occupy the contiguous
slice ``[starts[key], starts[key + 4**(l_max-l)])`` of the sorted object array.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from . import morton

__all__ = ["QuadtreeIndex", "build_index", "reindex_objects", "leaf_of_points"]


@partial(
    jax.tree_util.register_dataclass,
    data_fields=[
        "origin",
        "side",
        "pos",
        "ids",
        "codes",
        "starts",
        "leaf_level",
        "pyramid",
    ],
    meta_fields=["l_max", "th_quad"],
)
@dataclasses.dataclass(frozen=True)
class QuadtreeIndex:
    """The spatial index + Morton-sorted object store (a pytree).

    Attributes
    ----------
    origin: (2,) f32 — lower-left corner of the MBR ``G``.
    side:   ()  f32 — side length of ``G`` (squared region, as in the paper).
    pos:    (N, 2) f32 — object positions, sorted by fine Morton code (SoV layout).
    ids:    (N,) i32 — original object ids, same order.
    codes:  (N,) i32 — fine Morton codes, sorted.
    starts: (4**l_max + 1,) i32 — prefix offsets: fine cell c holds objects
            ``pos[starts[c]:starts[c+1]]``.
    leaf_level: (4**l_max,) i32 — level of the quadtree leaf covering each fine cell
            (this *is* the paper's z_map: leaf key = (c >> 2d) << 2d,
            d = l_max - leaf_level[c]).
    pyramid: flattened i32 array of quadrant populations at every level
            (``pyr[pyramid_offset(l) + z]``); used for empty-block skipping during
            navigation.
    l_max:   static int — maximum quadtree depth.
    th_quad: static int — max objects per leaf (split threshold).
    """

    origin: jnp.ndarray
    side: jnp.ndarray
    pos: jnp.ndarray
    ids: jnp.ndarray
    codes: jnp.ndarray
    starts: jnp.ndarray
    leaf_level: jnp.ndarray
    pyramid: jnp.ndarray
    l_max: int
    th_quad: int

    def level_counts(self, level: int) -> jnp.ndarray:
        """Populations of the 4**level quadrants at ``level`` (view of pyramid)."""
        off = pyramid_offset(level)
        return self.pyramid[off : off + 4**level]

    @property
    def n_objects(self) -> int:
        return self.pos.shape[0]

    @property
    def n_fine(self) -> int:
        return 4**self.l_max


def pyramid_offset(level):
    """Start of level ``level`` inside the flattened pyramid: (4**l - 1) / 3.

    Works for both static ints and traced int arrays — this is what lets the
    navigation loop index the pyramid at a *dynamic* level (rolled loops keep the
    compiled program small).
    """
    return ((1 << (2 * level)) - 1) // 3 if isinstance(level, int) else (
        (jnp.left_shift(jnp.int32(1), 2 * level) - 1) // 3
    )


def _count_pyramid(codes: jnp.ndarray, l_max: int) -> jnp.ndarray:
    """Quadrant populations at every level, flattened level-major.

    ``pyr[pyramid_offset(l) + z]`` = population of quadrant ``(l, z)``.
    Total size (4**(l_max+1) - 1) / 3.
    """
    counts = jnp.bincount(codes, length=4**l_max).astype(jnp.int32)
    levels = [counts]
    cur = counts
    for _ in range(l_max):
        cur = cur.reshape(-1, 4).sum(axis=1)
        levels.append(cur)
    return jnp.concatenate(list(reversed(levels)))


def _leaf_levels(pyramid: jnp.ndarray, l_max: int, th_quad: int) -> jnp.ndarray:
    """Leaf level per fine cell = number of split ancestors along its path.

    A node splits iff its population exceeds ``th_quad`` (and l < l_max).  Path
    populations are non-increasing with depth, so the split predicate holds on a
    prefix of levels and the *count of splitting ancestors* equals the leaf level.
    """
    fine = jnp.arange(4**l_max, dtype=jnp.int32)
    ll = jnp.zeros(4**l_max, dtype=jnp.int32)
    for l in range(l_max):  # levels 0 .. l_max-1 may split
        anc = fine >> jnp.int32(2 * (l_max - l))
        lvl_counts = pyramid[pyramid_offset(l) : pyramid_offset(l) + 4**l]
        ll = ll + (lvl_counts[anc] > th_quad).astype(jnp.int32)
    return ll


@partial(jax.jit, static_argnames=("l_max", "th_quad"))
def build_index(
    points: jnp.ndarray,
    origin: jnp.ndarray,
    side,
    *,
    l_max: int = 8,
    th_quad: int = 192,
) -> QuadtreeIndex:
    """Stage (i) + (ii) of the pipeline: build the PR-quadtree and index objects.

    Equivalent to the paper's *index creation* (Sec. 4.1.1) + *moving objects
    indexing* (Sec. 4.1.2), fused into one device program:
      1. fine Morton codes for all points                      (paper: GPU)
      2. sort by code (XLA sort ~ radix sort role)             (paper: GPU radix)
      3. count pyramid + leaf levels -> z_map                  (paper: GPU+CPU loop)
      4. prefix offsets -> per-cell object intervals           (paper: GPU)
    """
    points = points.astype(jnp.float32)
    origin = jnp.asarray(origin, jnp.float32)
    side = jnp.asarray(side, jnp.float32)
    codes = morton.morton_encode_points(points, origin, side, l_max)
    order = jnp.argsort(codes)
    codes_s = codes[order]
    pos_s = points[order]
    ids_s = order.astype(jnp.int32)
    pyramid = _count_pyramid(codes, l_max)
    leaf_level = _leaf_levels(pyramid, l_max, th_quad)
    fine_counts = pyramid[pyramid_offset(l_max) :]
    starts = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(fine_counts).astype(jnp.int32)]
    )
    return QuadtreeIndex(
        origin=origin,
        side=side,
        pos=pos_s,
        ids=ids_s,
        codes=codes_s,
        starts=starts,
        leaf_level=leaf_level,
        pyramid=pyramid,
        l_max=l_max,
        th_quad=th_quad,
    )


@partial(jax.jit, static_argnames=())
def reindex_objects(index: QuadtreeIndex, points: jnp.ndarray) -> QuadtreeIndex:
    """Stage (ii) only: re-sort fresh object positions into the *existing* partition.

    Per the paper, stage (i) (the space partition / z_map) is reused across ticks
    while the distribution is stable; every tick only re-sorts the new positions and
    recomputes the per-cell intervals (+ the pyramid, which is O(|C|) and needed for
    empty-block pruning).
    """
    l_max = index.l_max
    points = points.astype(jnp.float32)
    codes = morton.morton_encode_points(points, index.origin, index.side, l_max)
    order = jnp.argsort(codes)
    pyramid = _count_pyramid(codes, l_max)
    fine_counts = pyramid[pyramid_offset(l_max) :]
    starts = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(fine_counts).astype(jnp.int32)]
    )
    return dataclasses.replace(
        index,
        pos=points[order],
        ids=order.astype(jnp.int32),
        codes=codes[order],
        starts=starts,
        pyramid=pyramid,
    )


def leaf_of_points(index: QuadtreeIndex, points: jnp.ndarray):
    """z_map lookup (paper Sec. 4.1.1): points -> (leaf_key, leaf_level).

    Constant-time arithmetic + one table read per point; no tree descent.
    """
    fine = morton.morton_encode_points(points, index.origin, index.side, index.l_max)
    lvl = index.leaf_level[fine]
    shift = 2 * (index.l_max - lvl)
    key = (fine >> shift) << shift
    return key, lvl
