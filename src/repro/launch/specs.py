"""Abstract input/param/state specs for the dry-run (no allocation, ever).

``input_specs(cfg, shape)`` returns ShapeDtypeStruct stand-ins (weak-type
correct, shardable) for every model input of the given (arch x shape) cell;
``abstract_train_state`` / ``abstract_decode_state`` build the matching param /
optimizer / cache avals via ``jax.eval_shape``.  All carry NamedShardings built
from the active logical rules, so ``jit(...).lower(*avals)`` fully determines
the SPMD partitioning without materializing a single array.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeCell
from repro.dist import logical_to_spec
from repro.dist.sharding import current_rules
from repro.models import init_decode_state, init_params, param_logical
from repro.train.optimizer import init_opt

__all__ = [
    "input_specs",
    "abstract_params",
    "abstract_train_state",
    "abstract_decode_state",
    "shard_struct",
]


def _named(spec: P):
    lr = current_rules()
    assert lr is not None, "input_specs must run inside dist.use_rules(mesh)"
    return NamedSharding(lr.mesh, spec)


def shard_struct(shape, dtype, logical_axes):
    spec = logical_to_spec(logical_axes, shape)
    return jax.ShapeDtypeStruct(shape, dtype, sharding=_named(spec))


def input_specs(cfg: ModelConfig, shape: ShapeCell) -> dict:
    """Model inputs for one cell.  train/prefill: full sequences; decode: one
    new token (the KV cache / recurrent state lives in the decode state)."""
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "decode":
        out = {"tokens": shard_struct((b, 1), jnp.int32, ("batch", None))}
        return out
    out = {"tokens": shard_struct((b, s), jnp.int32, ("batch", "seq"))}
    if cfg.family == "encdec":
        # stub frontend: precomputed speech-frame embeddings
        out["frames"] = shard_struct(
            (b, s, cfg.d_model), jnp.bfloat16, ("batch", "kv_seq", None)
        )
    if cfg.family == "vlm":
        # stub frontend: precomputed patch embeddings
        out["img"] = shard_struct(
            (b, cfg.n_img_tokens, cfg.d_model), jnp.bfloat16, ("batch", "img", None)
        )
    return out


def _with_sharding(avals, logical_tree):
    def leaf(a, ax):
        spec = logical_to_spec(ax, a.shape)
        return jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=_named(spec))

    return jax.tree.map(
        leaf,
        avals,
        logical_tree,
        is_leaf=lambda t: isinstance(t, tuple)
        and all(isinstance(e, (str, type(None))) for e in t),
    )


def abstract_params(cfg: ModelConfig):
    avals = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    return _with_sharding(avals, param_logical(cfg))


def abstract_train_state(cfg: ModelConfig):
    params = abstract_params(cfg)
    opt_avals = jax.eval_shape(init_opt, params)

    def opt_leaf(a):
        # moments inherit the param sharding (same shapes); step is replicated
        return jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=_named(P()))

    logical = param_logical(cfg)
    opt = {
        "m": _with_sharding(opt_avals["m"], logical),
        "v": _with_sharding(opt_avals["v"], logical),
        "step": jax.ShapeDtypeStruct((), jnp.int32, sharding=_named(P())),
    }
    return params, opt


_DECODE_LOGICAL = {
    # kv caches: (layers, batch, kv_seq, kv_heads, head_dim)
    "kv": (None, "cache_batch", "kv_seq", "kv", None),
    "shared_kv": (None, "cache_batch", "kv_seq", "kv", None),
    "self_kv": (None, "cache_batch", "kv_seq", "kv", None),
    "cross_self_kv": (None, "cache_batch", "kv_seq", "kv", None),
    "cross_kv": (None, "cache_batch", "kv_seq", "kv", None),
}


def abstract_decode_state(cfg: ModelConfig, shape: ShapeCell):
    b, s = shape.global_batch, shape.seq_len
    avals = jax.eval_shape(
        lambda: init_decode_state(cfg, b, s, mem_len=min(s, 4096))
    )

    def leaf_with_path(path, a):
        names = [str(getattr(p, "key", "")) for p in path]
        kv_name = next((n for n in names if n in _DECODE_LOGICAL), None)
        if kv_name is not None:
            ax = _DECODE_LOGICAL[kv_name][: a.ndim]
            if a.ndim == 5:
                ax = _DECODE_LOGICAL[kv_name]
            else:  # stacked differently (e.g. vlm grouped kv) — batch then seq
                ax = tuple([None] * (a.ndim - 4) + ["cache_batch", "kv_seq", "kv", None])
        elif "img" in names or "mem" in names:
            ax = ("batch", "kv_seq", None)
        elif a.ndim >= 2:
            # recurrent states: (layers..., batch, ...) -> batch on the DP axes
            lead = a.ndim - _state_tail(names, a)
            ax = tuple(
                [None] * (lead - 1) + ["cache_batch"] + [None] * (a.ndim - lead)
            )
        else:
            ax = tuple([None] * a.ndim)
        spec = logical_to_spec(ax, a.shape)
        return jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=_named(spec))

    return jax.tree_util.tree_map_with_path(leaf_with_path, avals)


def _state_tail(names, a) -> int:
    """How many trailing dims follow the batch dim for recurrent state leaves."""
    # groups: (G, every, B, ...) -> 2 leading; trailing/blocks: (L, B, ...) -> 1
    if "groups" in names:
        return a.ndim - 3
    return a.ndim - 2
