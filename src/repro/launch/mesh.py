"""Production mesh construction (single-pod 16x16 and multi-pod 2x16x16).

A FUNCTION, not a module-level constant — importing this module never touches
jax device state (the dry-run sets XLA_FLAGS *before* any jax init).
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh", "make_query_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1, pod: int = 1):
    """Tiny mesh over however many (possibly fake) local devices exist — tests."""
    if pod > 1:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))


def make_query_mesh(num_devices: int | None = None):
    """The 1-D ``("query",)`` tick-serving mesh (DESIGN.md §10).

    The sharded ExecutionPlan splits the Morton-sorted query batch along this
    single axis; ``num_devices=None`` takes every visible device.  On CPU run
    under ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` to get a
    multi-device mesh without accelerators.
    """
    import numpy as np

    devs = jax.devices()
    n = len(devs) if num_devices is None else int(num_devices)
    if not 1 <= n <= len(devs):
        raise ValueError(f"requested {n} devices, have {len(devs)}")
    return jax.sharding.Mesh(np.asarray(devs[:n]), ("query",))
