"""Production mesh construction (single-pod 16x16 and multi-pod 2x16x16).

A FUNCTION, not a module-level constant — importing this module never touches
jax device state (the dry-run sets XLA_FLAGS *before* any jax init).
"""
from __future__ import annotations

import jax

__all__ = [
    "make_production_mesh",
    "make_local_mesh",
    "make_query_mesh",
    "make_object_mesh",
    "make_spatial_mesh",
    "default_hybrid_shape",
]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1, pod: int = 1):
    """Tiny mesh over however many (possibly fake) local devices exist — tests."""
    if pod > 1:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))


def _take_devices(n: int | None):
    devs = jax.devices()
    n = len(devs) if n is None else int(n)
    if not 1 <= n <= len(devs):
        raise ValueError(f"requested {n} devices, have {len(devs)}")
    return devs[:n]


def make_query_mesh(num_devices: int | None = None):
    """The 1-D ``("query",)`` tick-serving mesh (DESIGN.md §10).

    The sharded ExecutionPlan splits the Morton-sorted query batch along this
    single axis; ``num_devices=None`` takes every visible device.  On CPU run
    under ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` to get a
    multi-device mesh without accelerators.
    """
    import numpy as np

    return jax.sharding.Mesh(np.asarray(_take_devices(num_devices)), ("query",))


def make_object_mesh(num_devices: int | None = None):
    """The 1-D ``("object",)`` mesh of the object-sharded plan (DESIGN.md §12).

    Each device holds one Morton-contiguous slice of the object set (plus its
    own quadtree over that slice); per-query partial result lists reduce
    across this axis with the MERGE backends.
    """
    import numpy as np

    return jax.sharding.Mesh(np.asarray(_take_devices(num_devices)), ("object",))


def make_spatial_mesh(query: int, objects: int):
    """The 2-D ``("query", "object")`` mesh of the hybrid plan (DESIGN.md §12).

    ``query * objects`` devices arranged row-major: the query axis splits the
    Morton-sorted batch (disjoint shards, concatenating gather), the object
    axis splits the object set (overlapping partial lists, merge-reduced).
    """
    import numpy as np

    devs = _take_devices(query * objects)
    return jax.sharding.Mesh(
        np.asarray(devs).reshape(query, objects), ("query", "object")
    )


def default_hybrid_shape(num_devices: int | None = None) -> tuple[int, int]:
    """Most-balanced ``(query, object)`` factorization of the device count.

    The largest divisor pair with ``query <= object`` — 8 devices -> (2, 4),
    6 -> (2, 3), primes degrade to (1, n) (= pure object sharding along a
    2-D mesh).  Used when ``mesh_shape`` is not given for the hybrid plan.
    """
    n = len(jax.devices()) if num_devices is None else int(num_devices)
    if n < 1:
        raise ValueError(f"need at least one device, got {n}")
    q = max(d for d in range(1, int(n**0.5) + 1) if n % d == 0)
    return (q, n // q)
