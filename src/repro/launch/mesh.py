"""Production mesh construction (single-pod 16x16 and multi-pod 2x16x16).

A FUNCTION, not a module-level constant — importing this module never touches
jax device state (the dry-run sets XLA_FLAGS *before* any jax init).
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1, pod: int = 1):
    """Tiny mesh over however many (possibly fake) local devices exist — tests."""
    if pod > 1:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))
