"""Serving drivers.

Two modes, matching the paper's kind (query serving) and the LM stack:

  knn   — the paper's end-to-end service: repeated k-NN query batches over
          moving objects, one batch per tick, served through the session
          facade (repro.api.KnnSession: persistent queries, delta object
          ingest, optional overlapped submit; DESIGN.md §11).
  lm    — batched LM token serving: prefill a batch of prompts, then decode
          tokens with the per-layer KV cache / recurrent state.

Usage:
  PYTHONPATH=src python -m repro.launch.serve knn --objects 50000 --ticks 10 --k 32
  PYTHONPATH=src python -m repro.launch.serve lm --arch rwkv6_3b --smoke --tokens 16
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import KnnSession, ServiceSpec
from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.data import make_workload
from repro.dist import use_rules
from repro.launch.mesh import make_local_mesh
from repro.models import (
    decode_step,
    encode_memory,
    forward,
    init_decode_state,
    init_params,
    seed_decode_state,
)


def serve_knn(args) -> int:
    spec = ServiceSpec(k=args.k, th_quad=args.th_quad, l_max=args.l_max,
                       chunk=args.chunk, plan=args.plan,
                       partitioner=args.partitioner, collect=args.collect,
                       maintenance=args.maintenance)
    if args.tenants > 1:
        return serve_knn_tenants(args, spec)
    session = KnnSession(spec)
    w = make_workload(args.objects, args.distribution, seed=args.seed)
    tput = []

    def on_tick(res, tick_s):
        # tick_s spans staging + submit + result (the pre-session boundary),
        # so throughput stays comparable with PR-2 serve output
        qps = args.objects / max(tick_s, 1e-9)
        tput.append(qps)
        extra = f" compile={res.compile_s:.2f}s" if res.compile_s else ""
        print(
            f"[knn] tick {res.tick}: {tick_s * 1e3:.1f} ms, {qps / 1e3:.1f}K queries/s, "
            f"iters={res.iterations} rebuilt={res.rebuilt} "
            f"maint={res.maintenance}{extra}",
            flush=True,
        )

    # session loop: queries registered once.  With --churn 1.0 the whole
    # population moves every tick and full-snapshot ingest is the cheaper
    # path; a fractional --churn feeds only the moved rows through the
    # device-side delta scatter (update_objects) — the regime where
    # --maintenance incremental splices instead of rebuilding (DESIGN.md §15)
    session.ingest_objects(w.positions())
    cur = np.asarray(w.positions(), np.float32).copy()
    churn_rng = np.random.default_rng(args.seed + 1)
    hq = session.register_queries(*w.query_batch(1.0))
    for t in range(args.ticks):
        t0 = time.time()
        if t > 0:
            w.advance()
            new = np.asarray(w.positions(), np.float32)
            if args.churn < 1.0:
                d = max(1, int(round(args.objects * args.churn)))
                ids = churn_rng.choice(args.objects, d,
                                       replace=False).astype(np.int32)
                cur[ids] = new[ids]
                session.update_objects(ids, cur[ids])
            else:
                cur = new.copy()
                session.ingest_objects(cur)
            session.update_queries(hq, w.query_batch(1.0)[0])
        res = session.submit().result()
        on_tick(res, time.time() - t0 - res.compile_s)
    print(f"[knn] steady-state throughput: {np.median(tput[1:]):.0f} queries/s")
    return 0


def serve_knn_tenants(args, spec) -> int:
    """The server entrypoint: N tenants coalesced into one shared tick program.

    Queries split round-robin across tenants; the whole-population delta of
    each tick is fed by the next tenant in turn (round-robin ingest), so
    every tenant exercises the shared-world path (DESIGN.md §16).
    """
    from repro.serve import KnnServer

    server = KnnServer(spec)
    w = make_workload(args.objects, args.distribution, seed=args.seed)
    T = args.tenants
    server.ingest_objects(w.positions())
    qpos, qid = w.query_batch(1.0)
    tenants = [server.admit(f"tenant-{i}") for i in range(T)]
    groups = [t.register_queries(qpos[i::T], qid[i::T])
              for i, t in enumerate(tenants)]
    all_ids = np.arange(args.objects, dtype=np.int32)
    print(f"[knn] {server.describe()}")
    walls = []
    for t in range(args.ticks):
        t0 = time.time()
        if t > 0:
            w.advance()
            cur = np.asarray(w.positions(), np.float32)
            tenants[t % T].update_objects(all_ids, cur)
            newq = w.query_batch(1.0)[0]
            for i, tn in enumerate(tenants):
                tn.update_queries(groups[i], newq[i::T])
        res = server.submit().result()
        wall = time.time() - t0 - res.compile_s
        walls.append(wall)
        print(f"[knn] tick {res.tick}: {wall * 1e3:.1f} ms, "
              f"rows={res.rows_total} computed={res.rows_computed} "
              f"hit={res.hit_rate:.2f} epoch={res.epoch} "
              f"rebuilt={res.rebuilt}", flush=True)
    lifetime = 1 - server.rows_computed / max(server.rows_served, 1)
    print(f"[knn] {T} tenants steady-state: "
          f"{np.median(walls[1:]) * 1e3:.1f} ms/tick, lifetime hit rate "
          f"{lifetime:.2f}")
    return 0


def serve_lm(args) -> int:
    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mesh = make_local_mesh(data=args.data, model=args.model)
    with use_rules(mesh):
        params = init_params(cfg, jax.random.PRNGKey(args.seed))
        rng = np.random.default_rng(args.seed)
        prompts = jnp.asarray(
            rng.integers(0, cfg.vocab, size=(args.batch, args.prompt_len)), jnp.int32
        )
        batch = {"tokens": prompts}
        if cfg.family == "encdec":
            batch["frames"] = jnp.asarray(
                rng.normal(0, 0.02, (args.batch, args.prompt_len, cfg.d_model)), jnp.float32
            )
        if cfg.family == "vlm":
            batch["img"] = jnp.asarray(
                rng.normal(0, 0.02, (args.batch, cfg.n_img_tokens, cfg.d_model)), jnp.float32
            )
        # prefill: full forward for last-token logits (cache seeding for the
        # attention families happens token-by-token below for simplicity)
        t0 = time.time()
        logits, _ = jax.jit(
            lambda p, b: forward(p, cfg, b, logits_last_only=True)
        )(params, batch)
        tok = jnp.argmax(logits[:, -1, :], -1)[:, None].astype(jnp.int32)
        print(f"[lm] prefill {args.batch}x{args.prompt_len}: {time.time() - t0:.2f}s")

        state = init_decode_state(cfg, args.batch, args.prompt_len + args.tokens,
                                  mem_len=args.prompt_len)
        if cfg.family == "encdec":
            state = seed_decode_state(cfg=cfg, params=params, state=state,
                                      memory=encode_memory(params, cfg, batch["frames"]))
        if cfg.family == "vlm":
            state = seed_decode_state(cfg=cfg, params=params, state=state,
                                      memory=batch["img"])
        step = jax.jit(lambda p, st, t, q: decode_step(p, cfg, st, t, q))
        out = []
        t0 = time.time()
        for i in range(args.tokens):
            logits, state = step(params, state, tok, jnp.int32(args.prompt_len + i))
            tok = jnp.argmax(logits[:, -1, :], -1)[:, None].astype(jnp.int32)
            out.append(np.asarray(tok[:, 0]))
        dt = time.time() - t0
        print(
            f"[lm] decoded {args.tokens} tokens x batch {args.batch}: "
            f"{dt / args.tokens * 1e3:.1f} ms/token, "
            f"{args.batch * args.tokens / dt:.1f} tok/s"
        )
        print("[lm] sample:", np.stack(out, 1)[0][:16])
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    sub = ap.add_subparsers(dest="mode", required=True)
    k = sub.add_parser("knn")
    k.add_argument("--objects", type=int, default=50_000)
    k.add_argument("--ticks", type=int, default=10)
    k.add_argument("--k", type=int, default=32)
    k.add_argument("--th-quad", type=int, default=192)
    k.add_argument("--l-max", type=int, default=8)
    k.add_argument("--chunk", type=int, default=8192)
    k.add_argument("--distribution", default="uniform")
    k.add_argument("--plan", default="single")
    k.add_argument("--partitioner", default="equal")
    k.add_argument("--collect", default="full")
    k.add_argument("--maintenance", default="rebuild",
                   choices=["rebuild", "incremental"],
                   help="index maintenance: rebuild from scratch each tick, "
                        "or splice deltas into the live order (DESIGN.md §15)")
    k.add_argument("--churn", type=float, default=1.0, metavar="F",
                   help="fraction of objects moved per tick; <1.0 feeds only "
                        "the moved rows as a delta, the regime where "
                        "--maintenance incremental pays per shard for churn")
    k.add_argument("--tenants", type=int, default=1,
                   help="serve N tenants through one shared KnnServer tick "
                        "program (repro.serve); 1 = solo KnnSession")
    k.add_argument("--seed", type=int, default=0)
    m = sub.add_parser("lm")
    m.add_argument("--arch", default="rwkv6_3b", choices=list(ARCH_IDS))
    m.add_argument("--smoke", action="store_true")
    m.add_argument("--batch", type=int, default=4)
    m.add_argument("--prompt-len", type=int, default=32)
    m.add_argument("--tokens", type=int, default=16)
    m.add_argument("--data", type=int, default=1)
    m.add_argument("--model", type=int, default=1)
    m.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    return serve_knn(args) if args.mode == "knn" else serve_lm(args)


if __name__ == "__main__":
    sys.exit(main())
