"""Roofline-term extraction from compiled SPMD artifacts.

``cost_analysis`` gives HLO FLOPs + bytes accessed; collective bytes are NOT in
cost_analysis, so we parse the post-partitioning HLO text and sum the *result*
shapes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute (async ``-start`` forms counted once, ``-done`` skipped).
Result-shape bytes are the per-device traffic approximation used consistently
across all cells (methodology note in EXPERIMENTS.md §Roofline).

Hardware constants: TPU v5e-class — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s
per ICI link (values given by the assignment).
"""
from __future__ import annotations

import re

__all__ = ["collective_stats", "roofline_terms", "HW"]

HW = {
    "peak_flops": 197e12,  # bf16 / chip
    "hbm_bw": 819e9,  # B/s / chip
    "ici_bw": 50e9,  # B/s / link
}

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLL = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
# e.g.:  %ag = bf16[2,128]{1,0} all-gather(...)   or  (f32[4], f32[4]) all-to-all(
_OP_RE = re.compile(
    r"=\s*(\(?[^=]*?\)?)\s*"
    r"(all-reduce-start|all-gather-start|all-reduce|all-gather|reduce-scatter|"
    r"all-to-all|collective-permute-start|collective-permute)\("
)


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_stats(hlo_text: str) -> dict:
    """Sum result bytes + counts per collective kind over an HLO module text."""
    bytes_by_kind: dict[str, int] = {}
    count_by_kind: dict[str, int] = {}
    for m in _OP_RE.finditer(hlo_text):
        shapes, op = m.group(1), m.group(2)
        kind = op.replace("-start", "")
        b = _shape_bytes(shapes)
        bytes_by_kind[kind] = bytes_by_kind.get(kind, 0) + b
        count_by_kind[kind] = count_by_kind.get(kind, 0) + 1
    return {
        "bytes_by_kind": bytes_by_kind,
        "count_by_kind": count_by_kind,
        "total_bytes": sum(bytes_by_kind.values()),
        "total_count": sum(count_by_kind.values()),
    }


def roofline_terms(
    flops: float,
    bytes_accessed: float,
    collective_bytes: float,
    n_chips: int,
    *,
    model_flops: float | None = None,
) -> dict:
    """The three roofline terms, in seconds (per assignment formulae).

    flops / bytes_accessed are whole-program HLO numbers (cost_analysis of the
    per-device module already reports per-device work under SPMD —
    collective_bytes likewise comes from the per-device module).
    """
    compute_s = flops / HW["peak_flops"]
    memory_s = bytes_accessed / HW["hbm_bw"]
    collective_s = collective_bytes / HW["ici_bw"]
    terms = {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "n_chips": n_chips,
    }
    dom = max(terms["compute_s"], terms["memory_s"], terms["collective_s"])
    terms["dominant"] = (
        "compute"
        if dom == compute_s
        else ("memory" if dom == memory_s else "collective")
    )
    terms["bound_s"] = dom
    if model_flops is not None:
        terms["model_flops"] = model_flops
        terms["useful_flops_ratio"] = model_flops / max(flops * n_chips, 1.0)
    return terms
