import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import: jax locks the device count on first init.

_DOC = """Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: for each cell we
``jit(step).lower(*abstract_avals).compile()`` against the production mesh
(16x16 single-pod / 2x16x16 multi-pod of host placeholder devices), then record
``memory_analysis()`` (fits-per-device evidence), ``cost_analysis()`` (FLOPs /
bytes for §Roofline) and the collective schedule parsed from the compiled HLO.

Usage:
  python -m repro.launch.dryrun --arch yi_34b --shape train_4k [--multi-pod]
  python -m repro.launch.dryrun --all [--multi-pod] --out results/dryrun.jsonl
  ... --override kv_seq=model --override seq=model     # hillclimb experiments
"""

import argparse
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, SHAPES, get_config
from repro.configs.base import ModelConfig, ShapeCell
from repro.dist import use_rules
from repro.launch.hlo_stats import collective_stats, roofline_terms
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import (
    abstract_decode_state,
    abstract_train_state,
    input_specs,
    shard_struct,
)
from repro.models import decode_step, forward
from repro.train import OptConfig, make_train_step

# long_500k requires sub-quadratic attention; pure full-attention archs skip it
# (DESIGN.md §5).  SWA / SSM / hybrid run it.
LONG_OK = {"h2o_danube_3_4b", "zamba2_7b", "rwkv6_3b"}


def cell_skip_reason(cfg: ModelConfig, shape: ShapeCell) -> str | None:
    if shape.name == "long_500k" and cfg.arch_id not in LONG_OK:
        return "long_500k skipped: pure full (quadratic) attention arch"
    return None


def default_overrides(cfg: ModelConfig, shape: ShapeCell, model_axis: int = 16) -> dict:
    """Arch-adaptive logical bindings.

    When the head count does not divide the model axis (granite 24H, deepseek/
    yi 56H), attention scores cannot shard on heads — fall back to sequence
    parallelism (q-sequence -> 'model') for full-sequence kinds so the (S x S)
    score tile shards instead of replicating.
    """
    ov = {}
    if (
        shape.kind != "decode"
        and cfg.family != "ssm"
        and cfg.n_heads % model_axis != 0
    ):
        # heads can't shard -> shard the q-sequence inside attention instead
        # (scores tile shards on q rows) and the residual stream alongside
        ov["seq"] = "model"
        ov["act_seq"] = "model"
    if shape.kind != "decode" and cfg.sp_residual:
        # Megatron-SP: residual seq-sharded; blocks gather once at entry and
        # reduce-scatter at exit (act_seq stays unsharded -> heads/ff TP inside)
        ov["seq"] = "model"
    if shape.kind == "decode":
        # weights-stationary decode: per-token activations are tiny — replicate
        # them instead of re-gathering FSDP-sharded weights every token
        # (EXPERIMENTS.md §Perf, iteration Q1); caches stay on 'cache_batch'
        ov["batch"] = None
    return ov


def depth_units(cfg: ModelConfig):
    """(layers-per-unit, n_units) for linear cost extrapolation over depth.

    XLA's cost analysis counts a while-loop body ONCE, so costs inside the
    layer scan are underreported by the trip count.  We compile the cell at
    1-unit and 2-unit depth and extrapolate linearly — exact for anything that
    is per-layer (block compute, in-scan collectives, optimizer update on
    stacked params) or depth-independent (embedding, loss, grad all-reduce of
    non-stacked params).
    """
    if cfg.family == "hybrid":
        u = cfg.shared_attn_every + 1
        return u, cfg.n_layers / u
    if cfg.family == "vlm":
        return cfg.cross_attn_every, cfg.n_layers / cfg.cross_attn_every
    if cfg.family == "encdec":
        return 1, cfg.n_enc_layers  # one unit = 1 enc + 1 dec layer
    return 1, cfg.n_layers


def with_depth(cfg: ModelConfig, units: int) -> ModelConfig:
    """Reduced-depth config with UNROLLED layer scans (exact cost counting)."""
    import dataclasses

    u, _ = depth_units(cfg)
    if cfg.family == "encdec":
        return dataclasses.replace(
            cfg, n_enc_layers=units, n_dec_layers=units, n_layers=2 * units,
            scan_unroll=True,
        )
    return dataclasses.replace(cfg, n_layers=u * units, scan_unroll=True)


def _lower_cell(cfg: ModelConfig, shape: ShapeCell, accum: int):
    if shape.kind == "train":
        params, opt = abstract_train_state(cfg)
        batch = input_specs(cfg, shape)
        step = make_train_step(cfg, OptConfig(), accum=accum)
        return jax.jit(step).lower(params, opt, batch)
    if shape.kind == "prefill":
        params, _ = abstract_train_state(cfg)
        batch = input_specs(cfg, shape)
        fn = lambda p, b: forward(p, cfg, b, logits_last_only=True)[0]
        return jax.jit(fn).lower(params, batch)
    params, _ = abstract_train_state(cfg)
    state = abstract_decode_state(cfg, shape)
    tok = input_specs(cfg, shape)["tokens"]
    pos = shard_struct((), jnp.int32, ())
    fn = lambda p, st, t, q: decode_step(p, cfg, st, t, q)[0:2]
    return jax.jit(fn).lower(params, state, tok, pos)


def _cost_of(compiled):
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    coll = collective_stats(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        "collective_bytes": float(coll["total_bytes"]),
        "collectives": coll,
    }


def run_cell(
    arch: str,
    shape_name: str,
    *,
    multi_pod: bool = False,
    overrides: dict | None = None,
    accum: int = 1,
) -> dict:
    cfg = get_config(arch)
    shape = next(s for s in SHAPES if s.name == shape_name)
    rec: dict = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "kind": shape.kind,
        "overrides": overrides or {},
    }
    skip = cell_skip_reason(cfg, shape)
    if skip:
        rec["status"] = "skip"
        rec["reason"] = skip
        return rec

    merged = default_overrides(cfg, shape)
    merged.update(overrides or {})
    overrides = merged
    rec["overrides"] = overrides

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    with use_rules(mesh, overrides):
        # --- full-depth compile: proves lowering + sharding + memory
        t0 = time.time()
        lowered = _lower_cell(cfg, shape, accum)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

        # --- depth-1/2 compiles: scan-trip-count-exact cost extrapolation
        u, n_units = depth_units(cfg)
        c1 = _cost_of(_lower_cell(with_depth(cfg, 1), shape, accum).compile())
        c2 = _cost_of(_lower_cell(with_depth(cfg, 2), shape, accum).compile())

    rec["status"] = "ok"
    rec["lower_s"] = round(t_lower, 1)
    rec["compile_s"] = round(t_compile, 1)

    try:
        mem = compiled.memory_analysis()
        rec["memory"] = {
            k: int(getattr(mem, k))
            for k in (
                "argument_size_in_bytes",
                "output_size_in_bytes",
                "temp_size_in_bytes",
                "generated_code_size_in_bytes",
            )
            if hasattr(mem, k)
        }
        if rec["memory"]:
            total = (
                rec["memory"].get("argument_size_in_bytes", 0)
                + rec["memory"].get("temp_size_in_bytes", 0)
            )
            rec["memory"]["bytes_per_device"] = total
    except Exception as e:  # pragma: no cover - backend specific
        rec["memory"] = {"error": str(e)}

    def extrap(key):
        return c1[key] + (n_units - 1.0) * (c2[key] - c1[key])

    flops = extrap("flops")
    bytes_accessed = extrap("bytes_accessed")
    coll_bytes = extrap("collective_bytes")
    rec["cost"] = {
        "flops": flops,
        "bytes_accessed": bytes_accessed,
        "collective_bytes": coll_bytes,
        "raw_full_depth": _cost_of(compiled),
        "depth1": {k: c1[k] for k in ("flops", "bytes_accessed", "collective_bytes")},
        "depth2": {k: c2[k] for k in ("flops", "bytes_accessed", "collective_bytes")},
        "n_units": n_units,
    }
    rec["collectives"] = c2["collectives"]  # schedule shape (kinds/counts) at 2 units

    # MODEL_FLOPS: 6·N·D train, 2·N·D forward-only (D = tokens this step)
    n_active = cfg.n_active_params()
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mf = (6 if shape.kind == "train" else 2) * n_active * tokens
    rec["model_flops"] = float(mf)
    rec["n_params"] = cfg.n_params()
    rec["n_active_params"] = n_active
    rec["roofline"] = roofline_terms(
        flops, bytes_accessed, coll_bytes, n_chips, model_flops=mf
    )
    return rec


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=_DOC)
    ap.add_argument("--arch", choices=list(ARCH_IDS))
    ap.add_argument("--shape", choices=[s.name for s in SHAPES])
    ap.add_argument("--all", action="store_true", help="run every (arch x shape)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--out", default=None, help="append JSONL here")
    ap.add_argument(
        "--override",
        action="append",
        default=[],
        help="logical=mesh_axis rebinding, e.g. --override kv_seq=model",
    )
    args = ap.parse_args(argv)

    overrides = {}
    for ov in args.override:
        k, _, v = ov.partition("=")
        overrides[k] = None if v in ("", "none", "None") else (
            tuple(v.split("+")) if "+" in v else v
        )

    cells = []
    if args.all:
        for arch in ARCH_IDS:
            for s in SHAPES:
                cells.append((arch, s.name))
    else:
        if not (args.arch and args.shape):
            ap.error("--arch/--shape or --all required")
        cells.append((args.arch, args.shape))

    meshes = [args.multi_pod] if not args.both_meshes else [False, True]
    ok = True
    for arch, shape in cells:
        for mp in meshes:
            try:
                rec = run_cell(
                    arch, shape, multi_pod=mp, overrides=overrides or None,
                    accum=args.accum,
                )
            except Exception as e:
                rec = {
                    "arch": arch,
                    "shape": shape,
                    "mesh": "2x16x16" if mp else "16x16",
                    "status": "error",
                    "error": f"{type(e).__name__}: {e}",
                    "trace": traceback.format_exc()[-2000:],
                }
                ok = False
            line = json.dumps(rec)
            print(line, flush=True)
            if args.out:
                with open(args.out, "a") as f:
                    f.write(line + "\n")
            if rec["status"] == "ok":
                r = rec["roofline"]
                print(
                    f"# {arch} {shape} {rec['mesh']}: compute={r['compute_s']:.4f}s "
                    f"memory={r['memory_s']:.4f}s collective={r['collective_s']:.4f}s "
                    f"dominant={r['dominant']} useful={r.get('useful_flops_ratio', 0):.3f} "
                    f"(compile {rec['compile_s']}s)",
                    file=sys.stderr,
                    flush=True,
                )
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
