from .mesh import make_local_mesh, make_production_mesh, make_query_mesh

__all__ = ["make_local_mesh", "make_production_mesh", "make_query_mesh"]
