"""Distributed training driver (works on 1 CPU device or a real mesh).

Fault tolerance: checkpoints every ``--ckpt-every`` steps (atomic commit);
``--resume`` restores the latest checkpoint and replays the step-indexed data
pipeline from there — restart-deterministic.  ``--simulate-failure N`` exits
hard at step N to exercise the restart path (used by the integration test and
the fault-tolerance example).

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch rwkv6_3b --smoke \
      --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt --resume
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.data.lm import LMDataConfig, SyntheticLMData
from repro.dist import use_rules
from repro.launch.mesh import make_local_mesh
from repro.models import init_params
from repro.train import (
    OptConfig,
    init_opt,
    make_train_step,
    restore_latest,
    save_checkpoint,
)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="rwkv6_3b", choices=list(ARCH_IDS))
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--data", type=int, default=1, help="data-parallel axis size")
    ap.add_argument("--model", type=int, default=1, help="model-parallel axis size")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--simulate-failure", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=1)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mesh = make_local_mesh(data=args.data, model=args.model)
    data = SyntheticLMData(
        LMDataConfig(vocab=cfg.vocab, batch=args.batch, seq_len=args.seq, seed=args.seed)
    )
    extras = {}
    if cfg.family == "encdec":
        extras["frames"] = (args.seq, cfg.d_model)
    if cfg.family == "vlm":
        extras["img"] = (cfg.n_img_tokens, cfg.d_model)

    opt_cfg = OptConfig(lr=args.lr, warmup_steps=5)
    with use_rules(mesh):
        params = init_params(cfg, jax.random.PRNGKey(args.seed))
        opt = init_opt(params)
        start = 0
        if args.resume and args.ckpt_dir:
            restored, step = restore_latest(args.ckpt_dir, {"params": params, "opt": opt})
            if restored is not None:
                params, opt = restored["params"], restored["opt"]
                start = step
                print(f"[train] resumed from step {start}", flush=True)

        step_fn = jax.jit(make_train_step(cfg, opt_cfg, accum=args.accum))
        t0 = time.time()
        for step in range(start, args.steps):
            batch = {
                k: jnp.asarray(v)
                for k, v in data.batch_for_step(step, extras).items()
            }
            params, opt, metrics = step_fn(params, opt, batch)
            if args.simulate_failure is not None and step + 1 == args.simulate_failure:
                # hard crash AFTER the step, BEFORE its checkpoint
                print(f"[train] simulated failure at step {step + 1}", flush=True)
                sys.exit(42)
            if (step + 1) % args.ckpt_every == 0 and args.ckpt_dir:
                save_checkpoint(
                    args.ckpt_dir, step + 1, {"params": params, "opt": opt},
                    extra={"arch": cfg.arch_id},
                )
            if (step + 1) % args.log_every == 0:
                print(
                    f"[train] step {step + 1} loss={float(metrics['loss']):.4f} "
                    f"gnorm={float(metrics['grad_norm']):.3f} "
                    f"({(time.time() - t0) / max(step + 1 - start, 1):.2f}s/step)",
                    flush=True,
                )
        if args.ckpt_dir:
            save_checkpoint(
                args.ckpt_dir, args.steps, {"params": params, "opt": opt},
                extra={"arch": cfg.arch_id},
            )
    print("[train] done", flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
