"""Delta-splice: merge a sorted delta run into an existing sorted order.

The maintenance-seam primitive (DESIGN.md §15): instead of re-running a full
``argsort`` over all N objects every tick, the incremental index refresh
extracts the Δ moved rows, sorts **just the delta** (O(Δ log Δ)) and splices
the two ascending runs back together.  The splice itself is a *rank merge*:
each element's output position is its own run offset plus the count of
smaller elements in the other run — a vectorized binary search
(O((N + Δ) log)) followed by one scatter per payload array.  That replaces
the O(N log N) comparison sort that dominates the rebuild path's reindex
stage (benchmarks/roofline.py models both).

Keys are *pairs*: the quadtree's canonical object order is lexicographic
``(morton code, object id)`` — what a stable ``argsort`` over the
id-indexed positions buffer produces — and ids are the tie-break whenever
two objects share a fine cell.  A packed 64-bit key (``code << 32 | id``)
would be the obvious encoding, but this repo runs with JAX's default
``jax_enable_x64=False`` where ``int64`` silently aliases ``int32``, so the
merge compares the two int32 components explicitly instead:
:func:`searchsorted_pairs` is ``jnp.searchsorted`` generalized to
lexicographic pair keys via an unrolled-bound ``fori_loop`` binary search
(each of the ``ceil(log2 n)`` steps is one vectorized gather + compare).

Stability contract: :func:`merge_ranks` implements the classic stable
two-run merge — on fully-equal keys, run-A elements precede run-B elements
(``side="left"`` for A against B, ``side="right"`` for B against A).  Real
``(code, id)`` keys are unique across runs (an id lives in exactly one
run), so the A/B tie side only ever decides *sentinel* rows — and those
carry keys strictly greater than every real key, landing at merged
positions ``>= n_real`` where :func:`splice_payload`'s scatter bound drops
them.  No masks needed.

Two formulations of the same merge live here:

* **dense** (:func:`merge_ranks` + :func:`splice_payload`): run A is the
  full compacted survivor array, positions are found by an N-query binary
  search and payloads land via N-element scatters.  Simple, and the
  executable specification the tests pin the sparse path against — but on
  an XLA CPU/TPU backend an N-element *scatter* costs ~40x an N-element
  gather (scatters serialize; gathers vectorize), so O(N) scatters swallow
  the whole win over a fresh sort;
* **sparse** (:func:`sparse_splice_plan` + :func:`gather_splice`): the
  production path.  Run A is never materialized — the plan works directly
  on the *moved-slot set*: every scatter it issues is Δ-sized (bump arrays
  of ±1 at run-B insertion points and at the output positions where a
  vacated slot starts shifting its successors), every O(N) step is a
  cumsum or a gather.  The merged order comes back as *gather sources*
  (``src_a``/``b_src``), so payloads are produced by ``jnp.where`` over two
  gathers.  Total: O(Δ log N) search + O(Δ) scatters + two O(N) cumsums —
  this is what makes the incremental reindex pay for churn, not for N.

Why this is a jnp op and not a ``pl.pallas_call`` like its siblings: a
two-run merge is pure data movement — ~zero FLOPs over O(N) bytes, no tile
reuse — and a hand-rolled sequential-merge kernel would serialize what the
rank formulation keeps embarrassingly parallel; there is no arithmetic
intensity for VMEM residency to win back (the same reasoning that keeps the
Morton encode out of Pallas).  It lives in ``kernels/`` because it is a
backend-agnostic reduction primitive of the same family as ``merge_topk`` —
the PR-2/PR-6 merge machinery applied to the index axis instead of the
per-query result lists.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "searchsorted_pairs",
    "merge_ranks",
    "splice_payload",
    "sparse_splice_plan",
    "gather_splice",
]


def searchsorted_pairs(keys_c, keys_i, q_c, q_i, *, side: str):
    """``jnp.searchsorted`` over lexicographic ``(c, i)`` pair keys.

    ``(keys_c, keys_i)`` must be ascending by ``(c, i)``; returns, for every
    query pair, the count of keys strictly less than it (``side="left"``) or
    less-or-equal (``side="right"``) — all int32, no packed wide key.  The
    binary search runs a static ``bit_length + 1`` iterations (enough for
    the half-open search range to collapse from ``[0, n]``), each one
    gather + pair-compare over the whole query batch.
    """
    if side not in ("left", "right"):
        raise ValueError(f"side must be 'left' or 'right', got {side!r}")
    n = keys_c.shape[0]
    if n == 0:
        return jnp.zeros(q_c.shape, jnp.int32)

    def pair_less(ac, ai, bc, bi):
        return (ac < bc) | ((ac == bc) & (ai < bi))

    lo = jnp.zeros(q_c.shape, jnp.int32)
    hi = jnp.full(q_c.shape, n, jnp.int32)

    def body(_, lohi):
        lo, hi = lohi
        active = lo < hi
        mid = (lo + hi) >> 1
        kc = keys_c[jnp.minimum(mid, n - 1)]
        ki = keys_i[jnp.minimum(mid, n - 1)]
        if side == "left":
            go_right = pair_less(kc, ki, q_c, q_i)  # key[mid] < q
        else:
            go_right = ~pair_less(q_c, q_i, kc, ki)  # key[mid] <= q
        lo = jnp.where(active & go_right, mid + 1, lo)
        hi = jnp.where(active & ~go_right, mid, hi)
        return lo, hi

    lo, hi = jax.lax.fori_loop(0, n.bit_length() + 1, body, (lo, hi))
    return lo


@jax.jit
def merge_ranks(codes_a, ids_a, codes_b, ids_b):
    """Output positions of a stable two-run merge of two ``(code, id)``-sorted runs.

    Returns ``(pos_a, pos_b)`` int32 arrays: element ``i`` of run A lands at
    ``pos_a[i]`` of the merged sequence, element ``j`` of run B at
    ``pos_b[j]``.  With real keys unique across runs the real positions are
    a permutation of ``[0, n_real)``; sentinel rows (keys above every real
    key) land at positions ``>= n_real``.
    """
    pos_a = jnp.arange(codes_a.shape[0], dtype=jnp.int32) + searchsorted_pairs(
        codes_b, ids_b, codes_a, ids_a, side="left"
    )
    pos_b = jnp.arange(codes_b.shape[0], dtype=jnp.int32) + searchsorted_pairs(
        codes_a, ids_a, codes_b, ids_b, side="right"
    )
    return pos_a, pos_b


def splice_payload(pos_a, pos_b, val_a, val_b, n_out: int, fill=0):
    """Scatter two runs' payload rows to their merged positions.

    ``pos_a``/``pos_b`` come from :func:`merge_ranks`; rows whose merged
    position falls outside ``[0, n_out)`` — the sentinel tails — are dropped
    by the scatter, so the output holds exactly the real rows of both runs.
    Trace-level (callers jit the enclosing program); one fused scatter pair
    per payload array.
    """
    shape = (n_out,) + val_a.shape[1:]
    out = jnp.full(shape, fill, val_a.dtype)
    return out.at[pos_a].set(val_a, mode="drop").at[pos_b].set(val_b, mode="drop")


def sparse_splice_plan(slots, ins_full, n: int):
    """Gather plan for splicing a sorted Δ-run into an N-row sorted order.

    Inputs describe the delta against the *original* (pre-compaction) sorted
    order of ``n`` rows:

    * ``slots`` (Δp,) i32 — original slot of each moved row (``n`` for
      sentinel/padding rows, which then influence nothing);
    * ``ins_full`` (Δp,) i32 — for each run-B row (ascending ``(code, id)``),
      ``searchsorted_pairs(orig_keys, b_keys, side="right")``: its rank among
      the original rows.  Searching the original order (not the compacted
      survivors) is deliberate — the compacted rank is recovered here by
      subtracting the moved-slot prefix, so run A never needs materializing.

    Returns ``(src_a, b_src)``:

    * ``src_a`` (n,) i32 — for every merged output position, the original
      slot whose row lands there (meaningful where ``b_src < 0``);
    * ``b_src`` (n,) i32 — index into the sorted B run for output positions
      taken by a moved row, ``-1`` elsewhere.

    The construction inverts the forward merge map without any N-sized
    scatter: the output-position shift ``src_a[j] - j`` is piecewise
    constant with only O(Δ) breakpoints — each B insertion stalls the
    survivor stream by one (bump ``-1`` just past its output position) and
    each vacated slot advances it by one (bump ``+1`` at the output position
    of the first surviving successor) — so it is a cumsum over a Δ-sparse
    bump array.  Sentinel rows carry keys above every real key: their
    ``ins_full`` is ``n``, their computed positions land at ``>= n`` and
    every scatter drops them.  Bitwise-equivalent to the dense
    ``merge_ranks``/``splice_payload`` pair (pinned in
    tests/test_maintenance.py).
    """
    slots = slots.astype(jnp.int32)
    ins_full = ins_full.astype(jnp.int32)
    p = slots.shape[0]
    arange_p = jnp.arange(p, dtype=jnp.int32)
    moved = jnp.zeros((n,), bool).at[slots].set(True, mode="drop")
    # pref[j] = number of moved slots < j, for j in [0, n]
    pref = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(moved.astype(jnp.int32))]
    )
    # rank of each B row among the *survivors*; + own B rank = output position
    ins_c = ins_full - pref[ins_full]
    pos_b = ins_c + arange_p
    # a vacated slot shifts all outputs from its first surviving successor's
    # final position onward; sentinels overflow past n and drop.  The count of
    # B rows inserted at survivor rank <= d is a Δ-sized binary search rather
    # than an O(N) counting cumsum: ins_c is nondecreasing (ins_full is, and
    # pref grows at most one per unit step).
    d_m = slots - pref[jnp.clip(slots, 0, n)]
    e_m = d_m + jnp.searchsorted(ins_c, d_m, side="right").astype(jnp.int32)
    bump = (
        jnp.zeros((n + 1,), jnp.int32)
        .at[pos_b + 1]
        .add(-1, mode="drop")
        .at[e_m]
        .add(1, mode="drop")
    )
    shift = jnp.cumsum(bump)[:n]
    src_a = jnp.clip(jnp.arange(n, dtype=jnp.int32) + shift, 0, n - 1)
    b_src = jnp.full((n,), -1, jnp.int32).at[pos_b].set(arange_p, mode="drop")
    return src_a, b_src


def gather_splice(src_a, b_src, val_a, val_b):
    """Materialize one payload array of a :func:`sparse_splice_plan` merge.

    Two gathers and a select — no scatter.  ``val_a`` is indexed by original
    slot, ``val_b`` by sorted-B rank; trailing payload dimensions broadcast.
    """
    take_b = b_src >= 0
    bs = jnp.clip(b_src, 0, val_b.shape[0] - 1)
    if val_a.ndim > 1:
        take_b = take_b.reshape((-1,) + (1,) * (val_a.ndim - 1))
    return jnp.where(take_b, val_b[bs], val_a[src_a])
