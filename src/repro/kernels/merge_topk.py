"""Pallas TPU kernel: merge two ascending (dist, id) result lists per row.

The reduction operator of the object-sharded execution plans (DESIGN.md
§10/§12): given two partial k-NN result lists per query — each ascending,
``+inf``/``-1`` padded, produced against *disjoint* candidate subsets — emit
the k smallest of the union, ascending, under the same canonical
lexicographic ``(d2, id)`` tie contract as the SCAN backends (distance ties
resolve to the lowest id).  This is what makes per-partition k-NN composable
*bit-exactly*: ``knn(P_a ∪ P_b) = merge(knn(P_a), knn(P_b))`` — the
per-partition merge of Gowanlock's hybrid KNN-join, wired into the
``object_sharded``/``hybrid`` plans' cross-device tree reduction
(``kernels.ops.tree_merge_lists``).

Implementation mirrors ``topk_select``: the concatenated (T, ka+kb) row lives
in VMEM and is materialized by k masked argmin rounds — for list-sized inputs
(ka, kb ~ k) this is a tiny tile, and the ascending property lets the wrapper
pre-slice each input to its first k columns before dispatch.

Two entry points:

* :func:`merge_topk_lists` — the binary operator (one pair per call), the
  reduction step of ``tree_merge_lists``'s pairwise tree;
* :func:`merge_topk_multi` — the R-way fusion (DESIGN.md §14): ALL R partial
  lists of a query concatenate into one (T, R*k) VMEM row and materialize in
  a single ``pallas_call``.  The binary tree dispatches ``R - 1`` kernels
  whose (Q, k) intermediates round-trip HBM between rounds; the multi-way
  form reads R*Q*k list entries once and writes Q*k once — same bits (the
  canonical (d2, id) selection over the union is associative), ~log2(R)x
  less list traffic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .refine import masked_argmin_rounds
from .runtime import default_interpret

__all__ = ["merge_topk_lists", "merge_topk_multi", "Q_TILE"]

Q_TILE = 8


def _make_multi_kernel(k: int, c: int):
    def kernel(d_ref, i_ref, out_d_ref, out_i_ref):
        out_d, out_i = masked_argmin_rounds(
            d_ref[:, :].astype(jnp.float32), i_ref[:, :], k
        )
        out_d_ref[:, :] = out_d
        out_i_ref[:, :] = out_i

    return kernel


@functools.partial(jax.jit, static_argnames=("k", "interpret"))
def merge_topk_multi(d_cat, i_cat, *, k: int, interpret: bool | None = None):
    """(Q, R*k) concatenated ascending lists -> (Q, k) merged, ONE kernel.

    The caller lays the R per-shard lists of each query side by side
    (``ops.multi_merge_lists_op`` does the transpose/reshape); the kernel is
    the ``topk_select`` body over that row — k masked argmin rounds with the
    canonical lowest-id tie-break, so the output is bit-identical to folding
    the same lists through the binary ``merge_topk_lists`` tree.
    Q must be a multiple of Q_TILE (the wrapper pads).
    """
    if interpret is None:
        interpret = default_interpret()
    q, c = d_cat.shape
    assert q % Q_TILE == 0, q
    grid = (q // Q_TILE,)
    row = lambda i: (i, 0)
    out_d, out_i = pl.pallas_call(
        _make_multi_kernel(k, c),
        grid=grid,
        in_specs=[
            pl.BlockSpec((Q_TILE, c), row),
            pl.BlockSpec((Q_TILE, c), row),
        ],
        out_specs=[
            pl.BlockSpec((Q_TILE, k), row),
            pl.BlockSpec((Q_TILE, k), row),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((q, k), jnp.float32),
            jax.ShapeDtypeStruct((q, k), jnp.int32),
        ],
        interpret=interpret,
    )(d_cat, i_cat)
    return out_d, out_i


def _make_kernel(k: int, ca: int, cb: int):
    def kernel(da_ref, ia_ref, db_ref, ib_ref, out_d_ref, out_i_ref):
        d = jnp.concatenate([da_ref[:, :], db_ref[:, :]], axis=1)  # (T, ca+cb)
        ids = jnp.concatenate([ia_ref[:, :], ib_ref[:, :]], axis=1)
        out_d, out_i = masked_argmin_rounds(d.astype(jnp.float32), ids, k)
        out_d_ref[:, :] = out_d
        out_i_ref[:, :] = out_i

    return kernel


@functools.partial(jax.jit, static_argnames=("k", "interpret"))
def merge_topk_lists(d_a, i_a, d_b, i_b, *, k: int, interpret: bool | None = None):
    """(Q, ka)+(Q, kb) ascending lists -> (Q, k) merged ascending list.

    Q must be a multiple of Q_TILE (``ops.merge_topk_lists_op`` pads).
    """
    if interpret is None:
        interpret = default_interpret()
    q, ca = d_a.shape
    cb = d_b.shape[1]
    assert q % Q_TILE == 0, q
    grid = (q // Q_TILE,)
    row = lambda i: (i, 0)
    out_d, out_i = pl.pallas_call(
        _make_kernel(k, ca, cb),
        grid=grid,
        in_specs=[
            pl.BlockSpec((Q_TILE, ca), row),
            pl.BlockSpec((Q_TILE, ca), row),
            pl.BlockSpec((Q_TILE, cb), row),
            pl.BlockSpec((Q_TILE, cb), row),
        ],
        out_specs=[
            pl.BlockSpec((Q_TILE, k), row),
            pl.BlockSpec((Q_TILE, k), row),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((q, k), jnp.float32),
            jax.ShapeDtypeStruct((q, k), jnp.int32),
        ],
        interpret=interpret,
    )(d_a, i_a, d_b, i_b)
    return out_d, out_i
