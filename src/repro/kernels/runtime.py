"""Interpret-mode auto-detection shared by every Pallas wrapper/kernel.

Policy: compile to Mosaic when a TPU backend is actually present, fall back to
``interpret=True`` (kernel body evaluated with jnp on the host) anywhere else,
so the identical program runs in CI containers and on accelerators with no
caller opt-in.  ``REPRO_PALLAS_INTERPRET=0/1`` force-overrides both ways (e.g.
to debug a kernel body on TPU, or to exercise the compile path in a unit test).
"""
from __future__ import annotations

import os

import jax

__all__ = ["default_interpret"]


def default_interpret() -> bool:
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env != "0"
    return jax.default_backend() != "tpu"
