"""Pallas TPU kernel: fused distance + bucket k-selection (paper Sec. 4.2.1).

The paper's second pillar is the bucket k-selection of Alabi et al.: find a
radius enclosing the k nearest candidates by iterative histogram refinement,
*without* sorting and without materializing distances.  The GPU version runs one
query per thread with a private refinement loop; the TPU version processes a
Q_TILE of queries per grid step with the whole candidate window resident in
VMEM: distances are (re)computed on the VPU, the per-query histogram is built by
bin-broadcast compares, and the refinement loop is a ``lax.fori_loop`` — the
distance matrix never touches HBM (the fusion is the win; see DESIGN.md §7).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .refine import bucket_refine_step
from .runtime import default_interpret

__all__ = ["bucket_kselect", "Q_TILE"]

Q_TILE = 8


def _make_kernel(k: int, num_bins: int, iters: int, c: int):
    def kernel(qx_ref, qy_ref, px_ref, py_ref, valid_ref, out_ref):
        qx = qx_ref[:]
        qy = qy_ref[:]
        px = px_ref[:]
        py = py_ref[:]
        valid = valid_ref[:]
        dx = qx[:, None] - px[None, :]
        dy = qy[:, None] - py[None, :]
        d2 = dx * dx + dy * dy
        big = jnp.asarray(jnp.inf, d2.dtype)
        d2 = jnp.where(valid[None, :], d2, big)
        n_valid = valid.astype(jnp.int32).sum()

        lo = jnp.min(d2, axis=1)
        hi0 = jnp.max(jnp.where(valid[None, :], d2, -big), axis=1)
        hi = jnp.maximum(hi0, lo) * (1 + 1e-6) + 1e-30
        kth = jnp.full((Q_TILE,), k, jnp.int32)

        def body(_, state):
            lo, hi, kth = state
            return bucket_refine_step(d2, lo, hi, kth, num_bins)

        lo, hi, kth = jax.lax.fori_loop(0, iters, body, (lo, hi, kth))
        out_ref[:] = jnp.where(n_valid < k, big, hi).astype(out_ref.dtype)

    return kernel


@functools.partial(
    jax.jit, static_argnames=("k", "num_bins", "iters", "interpret")
)
def bucket_kselect(
    qx,
    qy,
    px,
    py,
    valid,
    *,
    k: int,
    num_bins: int = 32,
    iters: int = 4,
    interpret: bool | None = None,
):
    """(Q,) queries x (C,) shared candidate window -> (Q,) k-selection radius.

    Guarantee: ``count(valid & d2 < r) >= min(k, n_valid)`` per query, with the
    excess bounded by one bucket width after ``iters`` refinements; rows with
    fewer than k valid candidates return +inf.  ``interpret=None`` auto-detects
    (compiled on TPU, interpreted elsewhere — see runtime.default_interpret).
    """
    if interpret is None:
        interpret = default_interpret()
    q, c = qx.shape[0], px.shape[0]
    assert q % Q_TILE == 0, q
    grid = (q // Q_TILE,)
    return pl.pallas_call(
        _make_kernel(k, num_bins, iters, c),
        grid=grid,
        in_specs=[
            pl.BlockSpec((Q_TILE,), lambda i: (i,)),
            pl.BlockSpec((Q_TILE,), lambda i: (i,)),
            pl.BlockSpec((c,), lambda i: (0,)),
            pl.BlockSpec((c,), lambda i: (0,)),
            pl.BlockSpec((c,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((Q_TILE,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((q,), jnp.float32),
        interpret=interpret,
    )(qx, qy, px, py, valid)
