"""Pallas TPU kernel: masked pairwise squared-L2 distance tile (paper Alg. 1).

The paper's distance scans stream a cell's objects past each query thread.  On
TPU we instead compute a (Q_TILE x C_TILE) distance tile per grid step with the
operands resident in VMEM: queries and candidates arrive as *structure-of-vectors*
planes (x‖y — the paper's SoV layout, Sec. 3.4.1), the tile is pure VPU
elementwise work, and results stream back to HBM one aligned tile at a time.

For 2-D points arithmetic intensity is ~0.25 flop/byte — the kernel is memory
bound; its value is feeding the fused consumers (``bucket_kselect``) without a
round-trip through HBM, and providing the BlockSpec tiling pattern they inherit.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .runtime import default_interpret

__all__ = ["pairwise_dist", "Q_TILE", "C_TILE"]

Q_TILE = 8
C_TILE = 128


def _kernel(qx_ref, qy_ref, px_ref, py_ref, valid_ref, out_ref):
    qx = qx_ref[:]  # (Q_TILE,)
    qy = qy_ref[:]
    px = px_ref[:]  # (C_TILE,)
    py = py_ref[:]
    valid = valid_ref[:]
    dx = qx[:, None] - px[None, :]
    dy = qy[:, None] - py[None, :]
    d2 = dx * dx + dy * dy
    out_ref[:, :] = jnp.where(valid[None, :], d2, jnp.inf).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def pairwise_dist(qx, qy, px, py, valid, *, interpret: bool | None = None):
    """(Q,),(Q,),(C,),(C,),(C,)bool -> (Q, C) f32 masked squared distances.

    Q must be a multiple of Q_TILE and C of C_TILE (wrappers pad); ``interpret``
    runs the kernel body on CPU for validation (None = auto-detect).
    """
    if interpret is None:
        interpret = default_interpret()
    q, c = qx.shape[0], px.shape[0]
    assert q % Q_TILE == 0 and c % C_TILE == 0, (q, c)
    grid = (q // Q_TILE, c // C_TILE)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((Q_TILE,), lambda i, j: (i,)),
            pl.BlockSpec((Q_TILE,), lambda i, j: (i,)),
            pl.BlockSpec((C_TILE,), lambda i, j: (j,)),
            pl.BlockSpec((C_TILE,), lambda i, j: (j,)),
            pl.BlockSpec((C_TILE,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((Q_TILE, C_TILE), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((q, c), jnp.float32),
        interpret=interpret,
    )(qx, qy, px, py, valid)
