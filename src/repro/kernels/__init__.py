"""Pallas TPU kernels for the paper's compute hot-spots (+ jnp oracles).

Layout per the repo convention: ``<name>.py`` holds the ``pl.pallas_call`` +
BlockSpec kernel, ``ops.py`` the jit'd wrappers + the SCAN/MERGE backend
registries, ``ref.py`` the pure-jnp oracles used by the allclose sweeps in
tests/.
"""
from .delta_splice import (
    gather_splice,
    merge_ranks,
    searchsorted_pairs,
    sparse_splice_plan,
    splice_payload,
)
from .ops import (
    bucket_kselect_op,
    fused_scan_merge_op,
    get_merge_backend,
    get_scan_backend,
    merge_backend_names,
    merge_topk_lists_op,
    multi_merge_lists_op,
    pairwise_dist_op,
    register_merge_backend,
    register_scan_backend,
    scan_backend_names,
    topk_select_op,
    tree_merge_lists,
)
from .ref import (
    bucket_kselect_ref,
    merge_topk_lists_ref,
    pairwise_dist_ref,
    topk_select_ref,
)
from .refine import MIXED_WIDEN, mixed_prune_keep
from .runtime import default_interpret

__all__ = [
    "bucket_kselect_op",
    "fused_scan_merge_op",
    "merge_topk_lists_op",
    "multi_merge_lists_op",
    "pairwise_dist_op",
    "topk_select_op",
    "MIXED_WIDEN",
    "mixed_prune_keep",
    "bucket_kselect_ref",
    "merge_topk_lists_ref",
    "pairwise_dist_ref",
    "topk_select_ref",
    "default_interpret",
    "get_scan_backend",
    "register_scan_backend",
    "scan_backend_names",
    "get_merge_backend",
    "register_merge_backend",
    "merge_backend_names",
    "tree_merge_lists",
    "merge_ranks",
    "searchsorted_pairs",
    "splice_payload",
    "sparse_splice_plan",
    "gather_splice",
]
