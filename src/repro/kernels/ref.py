"""Pure-jnp oracles for every Pallas kernel (the correctness contracts).

Each ``*_ref`` function has exactly the same signature/semantics as the jit'd
wrapper in :mod:`repro.kernels.ops`; kernel tests sweep shapes/dtypes and
``assert_allclose`` kernel-vs-oracle.
"""
from __future__ import annotations

import jax.numpy as jnp

__all__ = [
    "pairwise_dist_ref",
    "bucket_kselect_ref",
    "topk_select_ref",
    "merge_topk_lists_ref",
]


def pairwise_dist_ref(qx, qy, px, py, valid):
    """Masked squared L2 distances: (Q,),(Q,),(C,),(C,),(C,) -> (Q, C).

    Invalid candidates map to +inf (paper Alg. 1 distance scans; SoV layout).
    """
    dx = qx[:, None] - px[None, :]
    dy = qy[:, None] - py[None, :]
    d2 = dx * dx + dy * dy
    return jnp.where(valid[None, :], d2, jnp.inf)


def bucket_kselect_ref(qx, qy, px, py, valid, *, k: int, num_bins: int, iters: int):
    """Fused distance + bucket k-selection radius (paper's findKDist pillar).

    Returns (Q,) radius r with count(valid & d2 < r) >= min(k, n_valid); rows
    with fewer than k valid candidates return +inf (paper Sec. 4.2.1).
    """
    d2 = pairwise_dist_ref(qx, qy, px, py, valid)
    n_valid = valid.sum()
    big = jnp.asarray(jnp.inf, d2.dtype)
    lo = jnp.min(d2, axis=1)
    hi0 = jnp.max(jnp.where(jnp.isinf(d2), -big, d2), axis=1)
    hi = jnp.maximum(hi0, lo) * (1 + 1e-6) + 1e-30
    kth = jnp.full((d2.shape[0],), k, jnp.int32)
    for _ in range(iters):
        width = jnp.maximum((hi - lo) / num_bins, 1e-30)
        b = jnp.clip(
            jnp.floor((d2 - lo[:, None]) / width[:, None]), 0, num_bins - 1
        ).astype(jnp.int32)
        in_range = (d2 >= lo[:, None]) & (d2 < hi[:, None])
        hist = jnp.sum(
            (b[:, :, None] == jnp.arange(num_bins)[None, None, :]) & in_range[:, :, None],
            axis=1,
        ).astype(jnp.int32)
        cum = jnp.cumsum(hist, axis=1)
        sel = (cum >= kth[:, None]).argmax(axis=1)
        below = jnp.where(
            sel > 0,
            jnp.take_along_axis(cum, jnp.maximum(sel - 1, 0)[:, None], 1)[:, 0],
            0,
        )
        # float guard: edge rounding can push the k-th element out of [lo, hi);
        # keep the previous (still-valid) interval in that case (kernel mirror).
        ok = cum[:, -1] >= kth
        lo = jnp.where(ok, lo + sel * width, lo)
        hi = jnp.where(ok, lo + width, hi)
        kth = jnp.where(ok, kth - below, kth)
    return jnp.where(n_valid < k, big, hi)


def topk_select_ref(d2, ids, *, k: int):
    """Per-row k smallest: (Q, C) dists + (Q, C) ids -> ((Q, k) d2, (Q, k) ids).

    Ascending; +inf / -1 padded.  This is the result-list materialization of the
    paper (Fig. 1 linear layout) and doubles as MoE top-k routing (on -logits).
    Distance ties resolve to the lowest id — the canonical lexicographic
    ``(d2, id)`` selection order (DESIGN.md §12) shared by every SCAN/MERGE
    backend, which makes selection a pure function of the candidate *set*:
    composable across arbitrary object partitions, hence across plans.
    """
    import jax

    sd, si = jax.lax.sort((d2, ids), num_keys=2)
    out_d = sd[:, :k]
    out_i = jnp.where(jnp.isinf(out_d), -1, si[:, :k])
    return out_d, out_i


def merge_topk_lists_ref(d_a, i_a, d_b, i_b, *, k: int):
    """Merge two ascending per-row (dist, id) lists -> k smallest of the union.

    The reduction operator of the sharded plans (DESIGN.md §10/§12): both
    inputs ascending and +inf/-1 padded, output likewise; distance ties
    resolve to the lowest id — identical contract to the SCAN backends, so
    per-partition partial results compose *bit-exactly*:
    ``knn(A ∪ B) = merge(knn(A), knn(B))``.
    """
    all_d = jnp.concatenate([d_a, d_b], axis=1)
    all_i = jnp.concatenate([i_a, i_b], axis=1)
    return topk_select_ref(all_d, all_i, k=k)
