"""Jit'd public wrappers around the Pallas kernels (padding + dispatch).

On this CPU container kernels run in ``interpret=True`` mode (the kernel body is
executed on CPU for correctness); on TPU the same calls compile to Mosaic.  Set
``REPRO_PALLAS_INTERPRET=0`` to request compiled mode.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np

from . import bucket_kselect as _bk
from . import pairwise_dist as _pd
from . import topk_select as _tk

__all__ = ["pairwise_dist_op", "bucket_kselect_op", "topk_select_op", "INTERPRET"]

INTERPRET = os.environ.get("REPRO_PALLAS_INTERPRET", "1") != "0" or (
    jax.default_backend() != "tpu"
)


def _pad_to(x, n, fill):
    if x.shape[0] == n:
        return x
    pad = n - x.shape[0]
    return jnp.concatenate([x, jnp.full((pad,) + x.shape[1:], fill, x.dtype)])


def pairwise_dist_op(qpos, ppos, valid=None, *, interpret: bool | None = None):
    """(Q,2) x (C,2) [+ (C,) mask] -> (Q, C) masked squared distances."""
    interpret = INTERPRET if interpret is None else interpret
    q, c = qpos.shape[0], ppos.shape[0]
    qp = int(np.ceil(q / _pd.Q_TILE)) * _pd.Q_TILE
    cp = int(np.ceil(c / _pd.C_TILE)) * _pd.C_TILE
    if valid is None:
        valid = jnp.ones((c,), bool)
    qx = _pad_to(qpos[:, 0].astype(jnp.float32), qp, 0)
    qy = _pad_to(qpos[:, 1].astype(jnp.float32), qp, 0)
    px = _pad_to(ppos[:, 0].astype(jnp.float32), cp, 0)
    py = _pad_to(ppos[:, 1].astype(jnp.float32), cp, 0)
    v = _pad_to(valid, cp, False)
    out = _pd.pairwise_dist(qx, qy, px, py, v, interpret=interpret)
    return out[:q, :c]


def bucket_kselect_op(
    qpos,
    ppos,
    valid=None,
    *,
    k: int,
    num_bins: int = 32,
    iters: int = 4,
    interpret: bool | None = None,
):
    """(Q,2) queries x (C,2) shared candidates -> (Q,) k-selection radius."""
    interpret = INTERPRET if interpret is None else interpret
    q, c = qpos.shape[0], ppos.shape[0]
    qp = int(np.ceil(q / _bk.Q_TILE)) * _bk.Q_TILE
    if valid is None:
        valid = jnp.ones((c,), bool)
    qx = _pad_to(qpos[:, 0].astype(jnp.float32), qp, 0)
    qy = _pad_to(qpos[:, 1].astype(jnp.float32), qp, 0)
    out = _bk.bucket_kselect(
        qx,
        qy,
        ppos[:, 0].astype(jnp.float32),
        ppos[:, 1].astype(jnp.float32),
        valid,
        k=k,
        num_bins=num_bins,
        iters=iters,
        interpret=interpret,
    )
    return out[:q]


def topk_select_op(d2, ids, *, k: int, interpret: bool | None = None):
    """(Q, C) distances + ids -> ((Q, k), (Q, k)) ascending top-k smallest."""
    interpret = INTERPRET if interpret is None else interpret
    q = d2.shape[0]
    qp = int(np.ceil(q / _tk.Q_TILE)) * _tk.Q_TILE
    d2p = _pad_to(d2.astype(jnp.float32), qp, jnp.inf)
    idsp = _pad_to(ids.astype(jnp.int32), qp, -1)
    out_d, out_i = _tk.topk_select(d2p, idsp, k=k, interpret=interpret)
    return out_d[:q], out_i[:q]
