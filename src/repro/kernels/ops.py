"""Jit'd public wrappers around the Pallas kernels + the SCAN backend registry.

Two things live here:

1. **Padding wrappers** (``*_op``): pad ragged shapes to kernel tile multiples,
   dispatch, slice back.  On non-TPU backends kernels run in ``interpret=True``
   mode (the body executes as jnp on the host); on TPU the same calls compile
   to Mosaic — see :func:`repro.kernels.runtime.default_interpret`.  Set
   ``REPRO_PALLAS_INTERPRET=0/1`` to force either mode.

2. **The scan-backend registry** (DESIGN.md §6): the pipeline's SCAN step —
   "merge one window of gathered candidates into each query's ascending result
   list" — is a pluggable strategy selected by name.  All backends implement
   ``merge(qpos, cpos, cids, valid, best_d, best_i, k, precision="fp32")``
   with identical semantics (k smallest of the union, ascending, (-1, inf)
   padded; distance ties resolved to the lowest id — the canonical
   lexicographic ``(d2, id)`` selection order of DESIGN.md §12) so they are
   interchangeable under the executor *bit-for-bit*:

   - ``dense_topk``   XLA ``lax.top_k`` over the concatenated row (seed path);
   - ``fused_bucket`` one Pallas kernel: distance tile + Alabi bucket radius +
                      masked argmin rounds, all VMEM-resident (DESIGN.md §7);
   - ``brute``        full per-row sort (Garcia-baseline flavour: selection
                      cost independent of k, the S2 yardstick).

   ``precision="mixed"`` (DESIGN.md §14) prepends the bf16 widened-radius
   prefilter (``refine.mixed_prune_keep``) to any backend's exact fp32
   selection — results stay bitwise-identical to fp32 (the property harness
   fuzzes the parity across the whole backend x plan x partitioner matrix).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from . import bucket_kselect as _bk
from . import fused_scan as _fs
from . import merge_topk as _mt
from . import pairwise_dist as _pd
from . import topk_select as _tk
from .ref import merge_topk_lists_ref
from .refine import mixed_prune_keep

__all__ = [
    "pairwise_dist_op",
    "bucket_kselect_op",
    "topk_select_op",
    "fused_scan_merge_op",
    "merge_topk_lists_op",
    "multi_merge_lists_op",
    "tree_merge_lists",
    "register_scan_backend",
    "get_scan_backend",
    "scan_backend_names",
    "register_merge_backend",
    "get_merge_backend",
    "merge_backend_names",
]


def _pad_to(x, n, fill):
    if x.shape[0] == n:
        return x
    pad = n - x.shape[0]
    return jnp.concatenate([x, jnp.full((pad,) + x.shape[1:], fill, x.dtype)])


def pairwise_dist_op(qpos, ppos, valid=None, *, interpret: bool | None = None):
    """(Q,2) x (C,2) [+ (C,) mask] -> (Q, C) masked squared distances."""
    q, c = qpos.shape[0], ppos.shape[0]
    qp = int(np.ceil(q / _pd.Q_TILE)) * _pd.Q_TILE
    cp = int(np.ceil(c / _pd.C_TILE)) * _pd.C_TILE
    if valid is None:
        valid = jnp.ones((c,), bool)
    qx = _pad_to(qpos[:, 0].astype(jnp.float32), qp, 0)
    qy = _pad_to(qpos[:, 1].astype(jnp.float32), qp, 0)
    px = _pad_to(ppos[:, 0].astype(jnp.float32), cp, 0)
    py = _pad_to(ppos[:, 1].astype(jnp.float32), cp, 0)
    v = _pad_to(valid, cp, False)
    out = _pd.pairwise_dist(qx, qy, px, py, v, interpret=interpret)
    return out[:q, :c]


def bucket_kselect_op(
    qpos,
    ppos,
    valid=None,
    *,
    k: int,
    num_bins: int = 32,
    iters: int = 4,
    interpret: bool | None = None,
):
    """(Q,2) queries x (C,2) shared candidates -> (Q,) k-selection radius."""
    q, c = qpos.shape[0], ppos.shape[0]
    qp = int(np.ceil(q / _bk.Q_TILE)) * _bk.Q_TILE
    if valid is None:
        valid = jnp.ones((c,), bool)
    qx = _pad_to(qpos[:, 0].astype(jnp.float32), qp, 0)
    qy = _pad_to(qpos[:, 1].astype(jnp.float32), qp, 0)
    out = _bk.bucket_kselect(
        qx,
        qy,
        ppos[:, 0].astype(jnp.float32),
        ppos[:, 1].astype(jnp.float32),
        valid,
        k=k,
        num_bins=num_bins,
        iters=iters,
        interpret=interpret,
    )
    return out[:q]


def topk_select_op(d2, ids, *, k: int, interpret: bool | None = None):
    """(Q, C) distances + ids -> ((Q, k), (Q, k)) ascending top-k smallest."""
    q = d2.shape[0]
    qp = int(np.ceil(q / _tk.Q_TILE)) * _tk.Q_TILE
    d2p = _pad_to(d2.astype(jnp.float32), qp, jnp.inf)
    idsp = _pad_to(ids.astype(jnp.int32), qp, -1)
    out_d, out_i = _tk.topk_select(d2p, idsp, k=k, interpret=interpret)
    return out_d[:q], out_i[:q]


def fused_scan_merge_op(
    qpos, cpos, cids, valid, best_d, best_i, *, k: int,
    precision: str = "fp32",
    interpret: bool | None = None,
):
    """Pad-and-dispatch wrapper for :func:`repro.kernels.fused_scan.fused_scan_merge`.

    qpos (Q,2) x per-query windows cpos (Q,W,2) / cids / valid (Q,W) x current
    lists best_d/best_i (Q,k) -> merged (Q,k) lists.
    """
    q = qpos.shape[0]
    qp = int(np.ceil(q / _fs.Q_TILE)) * _fs.Q_TILE
    qx = _pad_to(qpos[:, 0].astype(jnp.float32), qp, 0)
    qy = _pad_to(qpos[:, 1].astype(jnp.float32), qp, 0)
    cx = _pad_to(cpos[:, :, 0].astype(jnp.float32), qp, 0)
    cy = _pad_to(cpos[:, :, 1].astype(jnp.float32), qp, 0)
    ci = _pad_to(cids.astype(jnp.int32), qp, -1)
    v = _pad_to(valid, qp, False)
    bd = _pad_to(best_d.astype(jnp.float32), qp, jnp.inf)
    bi = _pad_to(best_i.astype(jnp.int32), qp, -1)
    out_d, out_i = _fs.fused_scan_merge(
        qx, qy, cx, cy, ci, v, bd, bi, k=k, precision=precision,
        interpret=interpret,
    )
    return out_d[:q], out_i[:q]


def merge_topk_lists_op(
    d_a, i_a, d_b, i_b, *, k: int, interpret: bool | None = None
):
    """Pad-and-dispatch wrapper for :func:`repro.kernels.merge_topk.merge_topk_lists`.

    Two ascending +inf/-1-padded lists per row, (Q, ka) and (Q, kb), -> the k
    smallest of the union, ascending (DESIGN.md §10 merge contract).  Because
    the inputs are ascending, only the first k columns of each can reach the
    output — they are sliced off before dispatch so the kernel tile is at most
    (Q_TILE, 2k).
    """
    q = d_a.shape[0]
    d_a, i_a = d_a[:, :k], i_a[:, :k]
    d_b, i_b = d_b[:, :k], i_b[:, :k]
    qp = int(np.ceil(max(q, 1) / _mt.Q_TILE)) * _mt.Q_TILE
    da = _pad_to(d_a.astype(jnp.float32), qp, jnp.inf)
    ia = _pad_to(i_a.astype(jnp.int32), qp, -1)
    db = _pad_to(d_b.astype(jnp.float32), qp, jnp.inf)
    ib = _pad_to(i_b.astype(jnp.int32), qp, -1)
    out_d, out_i = _mt.merge_topk_lists(da, ia, db, ib, k=k, interpret=interpret)
    return out_d[:q], out_i[:q]


# --------------------------------------------------------------------------
# SCAN backend registry
# --------------------------------------------------------------------------

# merge(qpos, cpos, cids, valid, best_d, best_i, k, precision="fp32")
#   -> (best_d, best_i)
ScanMergeFn = Callable[..., tuple]

_SCAN_BACKENDS: dict[str, ScanMergeFn] = {}


def register_scan_backend(name: str):
    """Decorator: register a SCAN merge strategy under ``name``."""

    def deco(fn: ScanMergeFn) -> ScanMergeFn:
        _SCAN_BACKENDS[name] = fn
        return fn

    return deco


def get_scan_backend(name: str) -> ScanMergeFn:
    try:
        return _SCAN_BACKENDS[name]
    except KeyError:
        raise ValueError(
            f"unknown scan backend {name!r}; registered: {scan_backend_names()}"
        ) from None


def scan_backend_names() -> tuple[str, ...]:
    return tuple(sorted(_SCAN_BACKENDS))


def _lex_sort_merge(qpos, cpos, cids, valid, best_d, best_i, k: int,
                    precision: str = "fp32"):
    """Concatenated row -> XLA two-key ``lax.sort``, lexicographic (d2, id).

    One body for both the ``dense_topk`` and ``brute`` names: the canonical
    lowest-id tie order (DESIGN.md §12) cannot be expressed by
    ``lax.top_k`` (its tie-break is positional), so the seed top_k path and
    the full-row-sort Garcia flavour collapse into the same program — a
    k-independent full sort.  Both names stay registered for the serving/
    benchmark surface; s4 rows for them now measure the same executable.

    Under ``precision="mixed"`` the bf16 widened-radius prefilter narrows the
    validity mask first; the exact fp32 sort below then re-ranks only the
    survivors — same bits (DESIGN.md §14).
    """
    dx = cpos[:, :, 0] - qpos[:, None, 0]
    dy = cpos[:, :, 1] - qpos[:, None, 1]
    if precision == "mixed":
        valid = valid & mixed_prune_keep(dx, dy, best_d[:, k - 1])
    d2 = jnp.where(valid, dx * dx + dy * dy, jnp.inf)
    all_d = jnp.concatenate([best_d, d2], axis=1)
    all_i = jnp.concatenate([best_i, cids.astype(jnp.int32)], axis=1)
    sd, si = jax.lax.sort((all_d, all_i), num_keys=2)
    out_d = sd[:, :k]
    return out_d, jnp.where(jnp.isinf(out_d), -1, si[:, :k])


register_scan_backend("dense_topk")(_lex_sort_merge)


@register_scan_backend("fused_bucket")
def _fused_bucket_merge(qpos, cpos, cids, valid, best_d, best_i, k: int,
                        precision: str = "fp32"):
    """Fused Pallas kernel; auto-interprets off-TPU (runtime.default_interpret).

    ``precision`` rides into the kernel as a static: the mixed-mode prefilter
    runs on the VMEM-resident distance deltas, not as a separate pass.
    """
    return fused_scan_merge_op(
        qpos, cpos, cids, valid, best_d, best_i, k=k, precision=precision
    )


register_scan_backend("brute")(_lex_sort_merge)


# --------------------------------------------------------------------------
# MERGE backend registry — the reduction step of sharded plans (DESIGN.md §10)
# --------------------------------------------------------------------------

# merge(d_a, i_a, d_b, i_b, k) -> (d, i): k smallest of the union of two
# ascending +inf/-1-padded lists, ascending, same tie contract as SCAN.
MergeListsFn = Callable[..., tuple]

_MERGE_BACKENDS: dict[str, MergeListsFn] = {}


def register_merge_backend(name: str):
    """Decorator: register a result-list merge strategy under ``name``."""

    def deco(fn: MergeListsFn) -> MergeListsFn:
        _MERGE_BACKENDS[name] = fn
        return fn

    return deco


def get_merge_backend(name: str) -> MergeListsFn:
    try:
        return _MERGE_BACKENDS[name]
    except KeyError:
        raise ValueError(
            f"unknown merge backend {name!r}; registered: {merge_backend_names()}"
        ) from None


def merge_backend_names() -> tuple[str, ...]:
    return tuple(sorted(_MERGE_BACKENDS))


@register_merge_backend("dense_merge")
def _dense_merge_lists(d_a, i_a, d_b, i_b, k: int):
    """XLA ``lax.top_k`` over the concatenated row (jnp mirror of the kernel)."""
    return merge_topk_lists_ref(d_a, i_a, d_b, i_b, k=k)


@register_merge_backend("fused_merge")
def _fused_merge_lists(d_a, i_a, d_b, i_b, k: int):
    """Pallas kernel; auto-interprets off-TPU (runtime.default_interpret)."""
    return merge_topk_lists_op(d_a, i_a, d_b, i_b, k=k)


def multi_merge_lists_op(d_all, i_all, *, k: int, interpret: bool | None = None):
    """(R, Q, ≥k) per-shard lists -> (Q, k), ONE fused Pallas program.

    The R-way fusion of the merge epilogue (DESIGN.md §14): each query's R
    partial lists are laid side by side into one (Q, R*k) row — a pure
    transpose/reshape, fused into the gather by XLA — and materialized by a
    single ``merge_topk_multi`` dispatch.  Bit-identical to folding the same
    lists through the binary tree (the canonical selection over a union is
    associative; pinned in tests/test_kernels.py), but the (Q, k)
    intermediates of the ``R - 1`` pairwise merges never exist, so partial
    lists cross HBM exactly once.
    """
    r, q = d_all.shape[0], d_all.shape[1]
    d_cat = jnp.swapaxes(d_all[:, :, :k], 0, 1).reshape(q, r * k)
    i_cat = jnp.swapaxes(i_all[:, :, :k], 0, 1).reshape(q, r * k)
    qp = int(np.ceil(max(q, 1) / _mt.Q_TILE)) * _mt.Q_TILE
    d_cat = _pad_to(d_cat.astype(jnp.float32), qp, jnp.inf)
    i_cat = _pad_to(i_cat.astype(jnp.int32), qp, -1)
    out_d, out_i = _mt.merge_topk_multi(d_cat, i_cat, k=k, interpret=interpret)
    return out_d[:q], out_i[:q]


@register_merge_backend("fused_multi")
def _fused_multi_lists(d_a, i_a, d_b, i_b, k: int):
    """Binary form of the R-way fused merge (registry signature adapter).

    Selecting ``merge="fused_multi"`` on a plan makes ``tree_merge_lists``
    collapse the whole reduction into one ``multi_merge_lists_op`` dispatch;
    this pairwise form exists so the name also satisfies the binary MERGE
    contract (and its validation) on its own.  The contract admits lists of
    different widths (narrower than k on under-full shards), so each side is
    (inf, -1)-padded to a common k-column block before stacking.
    """

    def _block(d, i):
        d = d[:, :k].astype(jnp.float32)
        i = i[:, :k].astype(jnp.int32)
        pad = k - d.shape[1]
        if pad > 0:
            q = d.shape[0]
            d = jnp.concatenate(
                [d, jnp.full((q, pad), jnp.inf, jnp.float32)], axis=1)
            i = jnp.concatenate([i, jnp.full((q, pad), -1, jnp.int32)], axis=1)
        return d, i

    da, ia = _block(d_a, i_a)
    db, ib = _block(d_b, i_b)
    return multi_merge_lists_op(jnp.stack([da, db]), jnp.stack([ia, ib]), k=k)


def tree_merge_lists(d_all, i_all, *, k: int, merge="dense_merge"):
    """(R, Q, ≥k) per-shard lists -> (Q, k) merged list.

    The reduction of the object-sharded plans (DESIGN.md §12): ``R`` partial
    result lists — one per object shard, each ascending and +inf/-1 padded —
    are pairwise-merged in ``ceil(log2 R)`` rounds with the selected MERGE
    backend.  Because the merge operator is the canonical lexicographic
    ``(d2, id)`` k-selection, the reduction is associative and commutative on
    id-disjoint inputs: any tree shape yields the same bits, and the result
    equals ``knn`` over the union of the partitions (the composition law,
    pinned R-way in tests/test_kernels.py).

    ``merge="fused_multi"`` short-circuits the tree entirely: the whole
    reduction runs as ONE Pallas program over the (Q, R*k) concatenated row
    (:func:`multi_merge_lists_op`) — same bits, no per-round HBM round-trips
    (DESIGN.md §14).

    ``R`` need not be a power of two: odd tails pass through a round unmerged.
    Shapes are static (R is a Python int), so under ``jit`` the tree unrolls
    into a fixed ``log2 R``-deep program.
    """
    if isinstance(merge, str) and merge == "fused_multi":
        if d_all.shape[0] < 1:
            raise ValueError("tree_merge_lists needs at least one shard list")
        return multi_merge_lists_op(d_all, i_all, k=k)
    fn = get_merge_backend(merge) if isinstance(merge, str) else merge
    lists = [(d_all[r], i_all[r]) for r in range(d_all.shape[0])]
    if not lists:
        raise ValueError("tree_merge_lists needs at least one shard list")
    while len(lists) > 1:
        nxt = []
        for a in range(0, len(lists) - 1, 2):
            (da, ia), (db, ib) = lists[a], lists[a + 1]
            nxt.append(fn(da, ia, db, ib, k))
        if len(lists) % 2:
            nxt.append(lists[-1])
        lists = nxt
    d, i = lists[0]
    return d[:, :k], i[:, :k]
