"""Pallas TPU kernel: per-row top-k smallest (result-list materialization).

Materializes the paper's nearest-neighbour lists (Fig. 1 linear layout): given a
(Q, C) tile of candidate distances + ids, emit the k smallest per row, ascending.
Implementation is k rounds of masked row-argmin on the VPU — for the moderate k
of the paper's sweet spot (and for MoE router top-k, which reuses this kernel
with ``-logits`` as distances) this beats a full sort; for very large k the
bucket radius + threshold path is preferred (see DESIGN.md §7).

Also the TPU answer to the paper's cached-vs-coalesced write study: the result
tile lives in VMEM and flushes as one contiguous aligned store — there is a
single sensible write pattern on TPU (DESIGN.md §3).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .refine import masked_argmin_rounds
from .runtime import default_interpret

__all__ = ["topk_select", "Q_TILE"]

Q_TILE = 8


def _make_kernel(k: int, c: int):
    def kernel(d2_ref, ids_ref, out_d_ref, out_i_ref):
        out_d, out_i = masked_argmin_rounds(
            d2_ref[:, :].astype(jnp.float32), ids_ref[:, :], k
        )
        out_d_ref[:, :] = out_d
        out_i_ref[:, :] = out_i

    return kernel


@functools.partial(jax.jit, static_argnames=("k", "interpret"))
def topk_select(d2, ids, *, k: int, interpret: bool | None = None):
    """(Q, C) distances + (Q, C) ids -> ((Q, k) dists, (Q, k) ids), ascending."""
    if interpret is None:
        interpret = default_interpret()
    q, c = d2.shape
    assert q % Q_TILE == 0, q
    grid = (q // Q_TILE,)
    out_d, out_i = pl.pallas_call(
        _make_kernel(k, c),
        grid=grid,
        in_specs=[
            pl.BlockSpec((Q_TILE, c), lambda i: (i, 0)),
            pl.BlockSpec((Q_TILE, c), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((Q_TILE, k), lambda i: (i, 0)),
            pl.BlockSpec((Q_TILE, k), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((q, k), jnp.float32),
            jax.ShapeDtypeStruct((q, k), jnp.int32),
        ],
        interpret=interpret,
    )(d2, ids)
    return out_d, out_i
