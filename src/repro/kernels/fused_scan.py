"""Pallas TPU kernel: fused SCAN-step merge — distance + bucket prune + top-k.

This is the per-iteration inner join of the pipeline (paper Sec. 4.2) as ONE
kernel: each grid step takes a Q_TILE of queries, their gathered candidate
window (per-query rows, unlike ``bucket_kselect``'s shared window), and the
current ascending result lists, and emits the merged lists.  Everything between
the coordinate planes (in) and the (Q, k) lists (out) — the distance tile, the
histogram refinement, the merge working set — lives in VMEM for the whole step
(DESIGN.md §7): HBM traffic is O(Q·W) coordinates in + O(Q·k) lists out, never
the O(Q·(W+k)) distance matrix that the unfused path materializes between the
distance op and the selection op.

Selection is two-phase, both pillars of the paper fused back-to-back:
  1. **bucket k-selection** (Alabi et al., Sec. 4.2.1): refine a per-query
     radius r over the combined [current list ‖ window] population with
     ``count(d < r) >= min(k, n_valid)`` — so every true top-k member is < r;
  2. **masked argmin rounds** on the r-pruned row materialize the ascending
     (dist, id) lists, exactly like ``topk_select`` but on VMEM-resident
     distances.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .refine import bucket_refine_step, masked_argmin_rounds, mixed_prune_keep
from .runtime import default_interpret

__all__ = ["fused_scan_merge", "Q_TILE"]

Q_TILE = 8


def _make_kernel(k: int, w: int, num_bins: int, iters: int, precision: str):
    def kernel(
        qx_ref, qy_ref, cx_ref, cy_ref, cids_ref, valid_ref,
        best_d_ref, best_i_ref, out_d_ref, out_i_ref,
    ):
        qx = qx_ref[:]  # (T,)
        qy = qy_ref[:]
        cx = cx_ref[:, :]  # (T, W)
        cy = cy_ref[:, :]
        cids = cids_ref[:, :]
        valid = valid_ref[:, :]
        big = jnp.asarray(jnp.inf, jnp.float32)

        dx = cx - qx[:, None]
        dy = cy - qy[:, None]
        if precision == "mixed":
            # bf16 prefilter against the widened exact k-th boundary
            # (DESIGN.md §14): candidates strictly beyond the current k-th
            # distance drop out of the fp32 distance tile AND the bucket
            # refinement population below — entirely in VMEM, so the win is
            # VPU work, not an extra HBM pass.  Bitwise-neutral: the argmin
            # rounds still pick the exact k smallest of the survivors, and
            # no true top-k member (ties included) can be pruned.
            valid = valid & mixed_prune_keep(dx, dy, best_d_ref[:, k - 1])
        d2 = jnp.where(valid, dx * dx + dy * dy, big)  # (T, W) — stays in VMEM

        all_d = jnp.concatenate([best_d_ref[:, :], d2], axis=1)  # (T, k+W)
        all_i = jnp.concatenate([best_i_ref[:, :], cids], axis=1)
        finite = ~jnp.isinf(all_d)
        n_valid = finite.astype(jnp.int32).sum(axis=1)  # (T,)

        # --- pillar 1: bucket refinement of the k-th-distance radius.
        lo = jnp.min(all_d, axis=1)
        hi0 = jnp.max(jnp.where(finite, all_d, -big), axis=1)
        hi = jnp.maximum(hi0, lo) * (1 + 1e-6) + 1e-30
        kth = jnp.full((Q_TILE,), k, jnp.int32)

        def refine(_, state):
            lo, hi, kth = state
            return bucket_refine_step(all_d, lo, hi, kth, num_bins)

        flo, fhi, _ = jax.lax.fori_loop(0, iters, refine, (lo, hi, kth))
        # The k-th element lies in [flo, fhi) up to float rounding of the bucket
        # edges; one extra bucket width of slop makes the prune safely
        # conservative (excess survivors cost nothing — the argmin rounds below
        # still pick the exact k smallest).  The slop is floored at a relative
        # epsilon: once the interval narrows below one ulp of its magnitude,
        # ``lo + width`` rounds back onto ``lo`` and ``fhi - flo`` collapses to
        # 0 — with massed duplicate ties at the k-th distance the collapsed
        # ``fhi`` can land EXACTLY on the k-th value and a ``< radius`` prune
        # would drop every tied member (caught by tests/test_properties.py).
        slop = jnp.maximum(fhi - flo, fhi * 1e-6 + 1e-30)
        radius = jnp.where(n_valid < k, big, fhi + slop)
        d_sel = jnp.where(all_d < radius[:, None], all_d, big)

        # --- pillar 2: ascending materialization by masked argmin rounds.
        out_d, out_i = masked_argmin_rounds(d_sel, all_i, k)
        out_d_ref[:, :] = out_d
        out_i_ref[:, :] = out_i

    return kernel


@functools.partial(
    jax.jit, static_argnames=("k", "num_bins", "iters", "precision", "interpret")
)
def fused_scan_merge(
    qx, qy, cx, cy, cids, valid, best_d, best_i,
    *,
    k: int,
    num_bins: int = 32,
    iters: int = 4,
    precision: str = "fp32",
    interpret: bool | None = None,
):
    """(Q,) queries x (Q, W) per-query windows x (Q, k) lists -> merged lists.

    Semantics match the unfused dense path exactly (up to k-th-distance ties):
    ``merge(best, window)`` = k smallest of the union, ascending, (-1, inf)
    padded.  Q must be a multiple of Q_TILE (wrappers pad).
    ``precision="mixed"`` adds the in-VMEM bf16 widened-radius prefilter —
    bitwise-identical output (tests/test_properties.py fuzzes the parity).
    """
    if interpret is None:
        interpret = default_interpret()
    q, w = cx.shape
    assert q % Q_TILE == 0, q
    grid = (q // Q_TILE,)
    row = lambda i: (i, 0)
    out_d, out_i = pl.pallas_call(
        _make_kernel(k, w, num_bins, iters, precision),
        grid=grid,
        in_specs=[
            pl.BlockSpec((Q_TILE,), lambda i: (i,)),
            pl.BlockSpec((Q_TILE,), lambda i: (i,)),
            pl.BlockSpec((Q_TILE, w), row),
            pl.BlockSpec((Q_TILE, w), row),
            pl.BlockSpec((Q_TILE, w), row),
            pl.BlockSpec((Q_TILE, w), row),
            pl.BlockSpec((Q_TILE, k), row),
            pl.BlockSpec((Q_TILE, k), row),
        ],
        out_specs=[
            pl.BlockSpec((Q_TILE, k), row),
            pl.BlockSpec((Q_TILE, k), row),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((q, k), jnp.float32),
            jax.ShapeDtypeStruct((q, k), jnp.int32),
        ],
        interpret=interpret,
    )(qx, qy, cx, cy, cids, valid, best_d, best_i)
    return out_d, out_i
