"""Selection-round helpers shared by the Pallas kernel bodies.

Factored out of the individual kernels so each contract has a single
kernel-side spelling: ``bucket_refine_step`` (the Alabi refinement round with
its float-edge guard, DESIGN.md §4 — from ``bucket_kselect``/``fused_scan``),
``masked_argmin_rounds`` (the ascending top-k materialization with the
inf→-1 id padding rule — from ``topk_select``/``fused_scan``/``merge_topk``)
and ``mixed_prune_keep`` (the bf16 widened-radius prefilter of the
``precision="mixed"`` sweep mode, DESIGN.md §14 — from the SCAN backends).
The jnp oracles (``kernels/ref.py``, ``core/kselect.py``) keep independent
mirrors on purpose — they are the correctness contracts the allclose sweeps
compare the kernels against.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "MIXED_WIDEN",
    "bucket_refine_step",
    "masked_argmin_rounds",
    "mixed_prune_keep",
]

# Widening factor of the mixed-precision prefilter (DESIGN.md §14).  The bf16
# pass computes d2_b from fp32 deltas rounded to bf16 (two casts, two squares,
# one add — five roundings at machine epsilon 2^-8), so
# ``d2_b <= d2_f32 * (1 + 2^-8)^5 < d2_f32 * (1 + 6 * 2^-8)``.  Widening the
# k-th-distance threshold by 16 * 2^-8 = 2^-4 (>2.5x the bound) guarantees no
# candidate with ``d2_f32 <= kth`` is ever pruned — the exact-refine pass then
# returns bitwise-identical lists to fp32 (the pruned candidates are provably
# strictly beyond the current k-th distance, so they cannot enter the merged
# list even via the lowest-id tie-break).
MIXED_WIDEN = 1.0 + 2.0 ** -4


def mixed_prune_keep(dx, dy, kth):
    """bf16 widened-radius prefilter: keep-mask over a candidate window.

    ``dx``/``dy`` are the (T, W) **fp32 coordinate deltas** (candidate minus
    query — cast AFTER the subtraction: casting raw coordinates first would
    lose the cancellation that makes the error bound *relative*), ``kth`` the
    (T,) current exact k-th distance per query (``best_d[:, k-1]``; ``inf``
    while the list is under-filled, which keeps everything).  Returns the
    (T, W) bool mask of candidates inside the conservatively widened k-th
    boundary.  The comparison is inclusive so exact k-th-distance ties (which
    can enter the list via the lowest-id rule) always survive.
    """
    dxb = dx.astype(jnp.bfloat16)
    dyb = dy.astype(jnp.bfloat16)
    d2b = (dxb * dxb + dyb * dyb).astype(jnp.float32)
    return d2b <= kth[:, None] * jnp.float32(MIXED_WIDEN)


def masked_argmin_rounds(d, ids, k: int):
    """k rounds of masked row-argmin: (T, C) dists + ids -> ascending (T, k).

    The kernel-side top-k materialization (paper Fig. 1 linear layout): each
    round extracts the row minimum, records (dist, id) — +inf slots pad with
    id -1 — and masks the hit.  ``d`` must have invalid entries pre-masked to
    +inf.  Distance ties resolve to the **lowest id** (the canonical
    lexicographic ``(dist, id)`` selection contract of DESIGN.md §12): every
    backend/kernel/plan produces the same list bit-for-bit, which is what
    makes per-partition results composable under the object-sharded plans.
    Exact ``(dist, id)`` duplicates (only the +inf/-1 padding in valid use)
    resolve to the lowest column, one per round.
    """
    t, c = d.shape
    col = jax.lax.broadcasted_iota(jnp.int32, (t, c), 1)
    big = jnp.asarray(jnp.inf, jnp.float32)
    id_big = jnp.asarray(jnp.iinfo(jnp.int32).max, jnp.int32)

    def body(j, state):
        dd, out_d, out_i = state
        mval = jnp.min(dd, axis=1)  # (T,)
        tied = dd == mval[:, None]
        mid = jnp.min(jnp.where(tied, ids, id_big), axis=1)  # (T,) lowest id
        win = tied & (ids == mid[:, None])
        hit = col == jnp.argmax(win, axis=1)[:, None]  # exactly one column
        out_d = out_d.at[:, j].set(mval)
        out_i = out_i.at[:, j].set(jnp.where(jnp.isinf(mval), -1, mid))
        return jnp.where(hit, big, dd), out_d, out_i

    out_d = jnp.zeros((t, k), jnp.float32)
    out_i = jnp.zeros((t, k), jnp.int32)
    _, out_d, out_i = jax.lax.fori_loop(0, k, body, (d, out_d, out_i))
    return out_d, out_i


def bucket_refine_step(d2, lo, hi, kth, num_bins: int):
    """Descend one histogram level toward the k-th element.

    d2: (T, C) population, invalid entries pre-masked to +inf; lo/hi: (T,)
    current half-open interval; kth: (T,) elements still wanted inside it.
    Returns the refined (lo, hi, kth).  Float-edge guard: if bucket-edge
    rounding pushed the k-th element out of [lo, hi) (no bucket reaches kth),
    the interval is kept — it still satisfies ``count(d < hi) >= kth``.
    """
    bins = jnp.arange(num_bins, dtype=jnp.int32)
    width = jnp.maximum((hi - lo) / num_bins, 1e-30)
    b = jnp.clip(
        jnp.floor((d2 - lo[:, None]) / width[:, None]), 0, num_bins - 1
    ).astype(jnp.int32)
    in_range = (d2 >= lo[:, None]) & (d2 < hi[:, None])
    # (T, C, NB) bin-broadcast compare -> per-row histogram (VPU-friendly)
    onehot = (b[:, :, None] == bins[None, None, :]) & in_range[:, :, None]
    hist = onehot.astype(jnp.int32).sum(axis=1)
    cum = jnp.cumsum(hist, axis=1)
    sel = jnp.argmax(cum >= kth[:, None], axis=1)
    below = jnp.where(
        sel > 0,
        jnp.take_along_axis(cum, jnp.maximum(sel - 1, 0)[:, None], 1)[:, 0],
        0,
    )
    new_lo = lo + sel.astype(lo.dtype) * width
    ok = cum[:, num_bins - 1] >= kth
    return (
        jnp.where(ok, new_lo, lo),
        jnp.where(ok, new_lo + width, hi),
        jnp.where(ok, kth - below, kth),
    )
