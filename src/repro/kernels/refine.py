"""One guarded bucket-refinement round, shared by the Pallas kernel bodies.

Factored out of ``bucket_kselect`` and ``fused_scan`` so the Alabi refinement
(including the float-edge guard, DESIGN.md §4) has a single kernel-side
spelling.  The jnp oracles (``kernels/ref.py``, ``core/kselect.py``) keep
independent mirrors on purpose — they are the correctness contracts the
allclose sweeps compare the kernels against.
"""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["bucket_refine_step"]


def bucket_refine_step(d2, lo, hi, kth, num_bins: int):
    """Descend one histogram level toward the k-th element.

    d2: (T, C) population, invalid entries pre-masked to +inf; lo/hi: (T,)
    current half-open interval; kth: (T,) elements still wanted inside it.
    Returns the refined (lo, hi, kth).  Float-edge guard: if bucket-edge
    rounding pushed the k-th element out of [lo, hi) (no bucket reaches kth),
    the interval is kept — it still satisfies ``count(d < hi) >= kth``.
    """
    bins = jnp.arange(num_bins, dtype=jnp.int32)
    width = jnp.maximum((hi - lo) / num_bins, 1e-30)
    b = jnp.clip(
        jnp.floor((d2 - lo[:, None]) / width[:, None]), 0, num_bins - 1
    ).astype(jnp.int32)
    in_range = (d2 >= lo[:, None]) & (d2 < hi[:, None])
    # (T, C, NB) bin-broadcast compare -> per-row histogram (VPU-friendly)
    onehot = (b[:, :, None] == bins[None, None, :]) & in_range[:, :, None]
    hist = onehot.astype(jnp.int32).sum(axis=1)
    cum = jnp.cumsum(hist, axis=1)
    sel = jnp.argmax(cum >= kth[:, None], axis=1)
    below = jnp.where(
        sel > 0,
        jnp.take_along_axis(cum, jnp.maximum(sel - 1, 0)[:, None], 1)[:, 0],
        0,
    )
    new_lo = lo + sel.astype(lo.dtype) * width
    ok = cum[:, num_bins - 1] >= kth
    return (
        jnp.where(ok, new_lo, lo),
        jnp.where(ok, new_lo + width, hi),
        jnp.where(ok, kth - below, kth),
    )
