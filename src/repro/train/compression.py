"""Cross-pod gradient compression (int8 + error feedback) over the slow DCN hop.

Within a pod, gradient reduction rides the fast ICI via GSPMD's automatic
psums.  *Across* pods the link is DCN — an order of magnitude slower — so the
cross-pod mean is the place to compress.  We run the whole train step inside
``jax.shard_map`` with only the ``pod`` axis manual (``axis_names={'pod'}``;
``data``/``model`` stay auto/GSPMD), quantize each gradient leaf to int8 with a
per-leaf amax scale, exchange the int8 payload + f32 scale with
``lax.all_gather`` over ``pod``, and dequantize+mean locally.

Collective-bytes accounting (what the dry-run measures): a bf16 psum over 2
pods moves ~2x the gradient bytes; the int8 all-gather moves ~1x — a ~2x cut
of the DCN term, at the cost of <=0.4% quantization error per step (bounded by
error feedback, which carries the residual to the next step).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["crosspod_mean_int8", "crosspod_mean", "init_error_feedback"]


def init_error_feedback(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _quantize(g):
    amax = jnp.max(jnp.abs(g)) + 1e-12
    scale = amax / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def crosspod_mean_int8(grads, err, axis: str = "pod"):
    """Per-leaf int8 all-gather mean over ``axis`` with error feedback.

    Must run inside shard_map with ``axis`` manual.  Returns (mean_grads, new_err).
    """
    # jax >= 0.6 has lax.axis_size; 0.4.x spells it psum(1, axis)
    npod = (
        jax.lax.axis_size(axis)
        if hasattr(jax.lax, "axis_size")
        else jax.lax.psum(1, axis)
    )

    def leaf(g, e):
        g = g.astype(jnp.float32) + e
        q, scale = _quantize(g)
        new_e = g - q.astype(jnp.float32) * scale  # residual carried forward
        qs = jax.lax.all_gather(q, axis)  # (npod, ...) int8 on the wire
        ss = jax.lax.all_gather(scale, axis)  # (npod,) f32
        deq = (qs.astype(jnp.float32) * ss.reshape((npod,) + (1,) * g.ndim)).mean(0)
        return deq, new_e

    out = jax.tree.map(leaf, grads, err)
    mean = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple) and len(t) == 2)
    new_err = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple) and len(t) == 2)
    return mean, new_err


def crosspod_mean(grads, axis: str = "pod"):
    """Uncompressed baseline: f32 psum-mean over the pod axis."""
    return jax.tree.map(lambda g: jax.lax.pmean(g.astype(jnp.float32), axis), grads)
