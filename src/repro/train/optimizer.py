"""AdamW on raw pytrees (no optax offline) with f32 moments and global-norm clip."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

__all__ = ["OptConfig", "init_opt", "adamw_update", "global_norm", "clip_by_global_norm"]


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100


def init_opt(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree):
    sq = jax.tree.map(lambda g: jnp.sum(jnp.square(g.astype(jnp.float32))), tree)
    return jnp.sqrt(jax.tree.reduce(jnp.add, sq))


def clip_by_global_norm(grads, max_norm: float):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), gn


def _schedule(cfg: OptConfig, step):
    warm = jnp.minimum(1.0, (step + 1) / max(cfg.warmup_steps, 1))
    return cfg.lr * warm


def adamw_update(params, grads, opt, cfg: OptConfig):
    """One AdamW step; grads may be any float dtype (accumulated in f32)."""
    step = opt["step"] + 1
    lr = _schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m2 = b1 * m + (1 - b1) * g
        v2 = b2 * v + (1 - b2) * g * g
        mhat = m2 / bc1
        vhat = v2 / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m2, v2

    flat = jax.tree.map(upd, params, grads, opt["m"], opt["v"])
    new_params = jax.tree.map(lambda t: t[0], flat, is_leaf=lambda t: isinstance(t, tuple) and len(t) == 3)
    new_m = jax.tree.map(lambda t: t[1], flat, is_leaf=lambda t: isinstance(t, tuple) and len(t) == 3)
    new_v = jax.tree.map(lambda t: t[2], flat, is_leaf=lambda t: isinstance(t, tuple) and len(t) == 3)
    return new_params, {"m": new_m, "v": new_v, "step": step}
