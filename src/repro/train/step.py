"""Train-step builders: plain GSPMD step and the shard_map cross-pod variant.

``make_train_step``  — jit + GSPMD everywhere (baseline; gradient reduction over
                       batch axes is inserted automatically by SPMD autodiff).
``make_train_step_crosspod`` — the whole step under ``jax.shard_map`` with only
                       the ``pod`` axis manual, so the cross-pod (DCN) gradient
                       exchange is explicit and optionally int8-compressed
                       (train/compression.py).  data/model stay auto (GSPMD).

Both support microbatch gradient accumulation (``accum`` sequential microsteps
via lax.scan — overlap-friendly and memory-bounded).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist import shard_map_compat
from repro.models import loss_fn
from repro.train.compression import crosspod_mean, crosspod_mean_int8
from repro.train.optimizer import OptConfig, adamw_update, clip_by_global_norm

__all__ = [
    "make_train_step",
    "make_train_step_crosspod",
    "grads_and_loss",
    "shard_map_compat",  # rehomed to repro.dist.sharding (serving uses it too)
]


def grads_and_loss(params, cfg: ModelConfig, batch, accum: int = 1):
    """(loss, grads) with optional sequential microbatch accumulation."""
    if accum <= 1:
        loss, grads = jax.value_and_grad(loss_fn)(params, cfg, batch)
        return loss, grads

    def micro(i, batch):
        return jax.tree.map(lambda x: x.reshape(accum, -1, *x.shape[1:])[i], batch)

    def body(carry, i):
        loss_acc, g_acc = carry
        loss, grads = jax.value_and_grad(loss_fn)(params, cfg, micro(i, batch))
        g_acc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32), g_acc, grads)
        return (loss_acc + loss, g_acc), None

    g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    (loss, grads), _ = jax.lax.scan(body, (jnp.float32(0.0), g0), jnp.arange(accum))
    scale = 1.0 / accum
    return loss * scale, jax.tree.map(lambda g: g * scale, grads)


def make_train_step(cfg: ModelConfig, opt_cfg: OptConfig, accum: int = 1):
    """Plain GSPMD step: (params, opt, batch) -> (params, opt, metrics)."""

    def step(params, opt, batch):
        loss, grads = grads_and_loss(params, cfg, batch, accum)
        grads, gnorm = clip_by_global_norm(grads, opt_cfg.clip_norm)
        params, opt = adamw_update(params, grads, opt, opt_cfg)
        return params, opt, {"loss": loss, "grad_norm": gnorm}

    return step


def make_train_step_crosspod(
    cfg: ModelConfig,
    opt_cfg: OptConfig,
    mesh,
    *,
    compress: bool = True,
    accum: int = 1,
):
    """shard_map(pod-manual) step with explicit (optionally int8) DCN exchange.

    State gains an ``err`` leaf-tree (error feedback) when compressing.
    Batch enters pod-sharded on axis 0; params/opt are replicated across pods
    (FSDP over 'data' continues inside via GSPMD auto mode).
    """
    from jax.sharding import PartitionSpec as P

    from repro.dist import use_rules

    def inner(params, opt, err, batch):
        # inside the pod-manual region, activation specs must not mention the
        # manual axis: rebind batch -> 'data' only (pod sharding is implicit)
        with use_rules(mesh, {"batch": "data"}):
            loss, grads = grads_and_loss(params, cfg, batch, accum)
        if compress:
            grads, err = crosspod_mean_int8(grads, err, "pod")
        else:
            grads = crosspod_mean(grads, "pod")
        loss = jax.lax.pmean(loss, "pod")
        grads, gnorm = clip_by_global_norm(grads, opt_cfg.clip_norm)
        params, opt = adamw_update(params, grads, opt, opt_cfg)
        return params, opt, err, {"loss": loss, "grad_norm": gnorm}

    rep = P()  # replicated w.r.t. pod (manual axis); inner axes stay auto

    def batch_spec(batch):
        return jax.tree.map(lambda _: P("pod"), batch)

    def step(params, opt, err, batch):
        f = shard_map_compat(
            inner,
            mesh=mesh,
            in_specs=(
                jax.tree.map(lambda _: rep, params),
                jax.tree.map(lambda _: rep, opt),
                jax.tree.map(lambda _: rep, err),
                batch_spec(batch),
            ),
            out_specs=(
                jax.tree.map(lambda _: rep, params),
                jax.tree.map(lambda _: rep, opt),
                jax.tree.map(lambda _: rep, err),
                {"loss": rep, "grad_norm": rep},
            ),
            axis_names={"pod"},
            check_vma=False,
        )
        return f(params, opt, err, batch)

    return step
