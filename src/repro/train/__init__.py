from .checkpoint import latest_step, restore_checkpoint, restore_latest, save_checkpoint
from .compression import crosspod_mean, crosspod_mean_int8, init_error_feedback
from .optimizer import OptConfig, adamw_update, clip_by_global_norm, global_norm, init_opt
from .step import (
    grads_and_loss,
    make_train_step,
    make_train_step_crosspod,
    shard_map_compat,
)

__all__ = [
    "latest_step",
    "restore_checkpoint",
    "restore_latest",
    "save_checkpoint",
    "crosspod_mean",
    "crosspod_mean_int8",
    "init_error_feedback",
    "OptConfig",
    "adamw_update",
    "clip_by_global_norm",
    "global_norm",
    "init_opt",
    "grads_and_loss",
    "make_train_step",
    "make_train_step_crosspod",
    "shard_map_compat",
]
