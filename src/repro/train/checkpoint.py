"""Fault-tolerant checkpointing: atomic, step-indexed, reshard-on-restore.

Layout:  <dir>/step_<N>/arrays.npz + manifest.json, committed by atomic rename
of a ``.tmp`` directory — a torn write can never be mistaken for a checkpoint.
``restore_latest`` picks the newest complete step, so a crash mid-save falls
back to the previous one (checkpoint/restart fault tolerance).

Elastic scaling: arrays are saved device-agnostic (host numpy); on restore the
caller passes target shardings built from the *current* mesh — restarting on a
different pod/data/model geometry re-shards transparently (pure-pytree params).

On a real multi-host cluster each host writes only its addressable shards
(process-sliced npz per host) — the single-host container writes everything.
"""
from __future__ import annotations

import json
import os
import shutil

import jax
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "restore_latest", "latest_step"]

_SEP = "||"


def _flatten(tree):
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in leaves:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = np.asarray(leaf)
    return out


def save_checkpoint(directory: str, step: int, tree, extra: dict | None = None):
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    arrays = _flatten(tree)
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    manifest = {"step": step, "n_arrays": len(arrays), **(extra or {})}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic commit
    return final


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(directory, name, "manifest.json")):
                steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def restore_checkpoint(directory: str, step: int, like_tree, shardings=None):
    """Restore into the structure of ``like_tree``; optionally device_put with
    ``shardings`` (same treedef) for elastic re-sharding onto the current mesh."""
    path = os.path.join(directory, f"step_{step:08d}", "arrays.npz")
    data = np.load(path)
    paths, treedef = jax.tree_util.tree_flatten_with_path(like_tree)
    leaves = []
    for p, leaf in paths:
        key = _SEP.join(str(getattr(q, "key", getattr(q, "idx", q))) for q in p)
        arr = data[key]
        leaves.append(arr.astype(leaf.dtype) if hasattr(leaf, "dtype") else arr)
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.tree.map(lambda x, s: jax.device_put(x, s), tree, shardings)
    return tree


def restore_latest(directory: str, like_tree, shardings=None):
    step = latest_step(directory)
    if step is None:
        return None, None
    return restore_checkpoint(directory, step, like_tree, shardings), step
