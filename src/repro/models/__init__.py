from .model import (
    decode_step,
    encode_memory,
    seed_decode_state,
    forward,
    init_decode_state,
    init_params,
    loss_fn,
    param_logical,
)

__all__ = [
    "decode_step",
    "encode_memory",
    "seed_decode_state",
    "forward",
    "init_decode_state",
    "init_params",
    "loss_fn",
    "param_logical",
]
