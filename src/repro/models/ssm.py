"""State-space sequence mixers: Mamba2 (SSD, zamba2-7b) and RWKV6 (rwkv6-3b).

Both are implemented in the *chunked* form: quadratic attention-like einsums
within a chunk (vectorized over all chunks) + a short ``lax.scan`` over chunk
states.  This keeps the compiled program small (rolled scan), the FLOPs count
faithful, and gives O(chunk) not O(L^2) cost — which is what makes these archs
eligible for the ``long_500k`` cell (DESIGN.md §5).

Decode paths carry recurrent state explicitly:
  mamba2: (h (B,H,P,N), conv window (B,K-1,Cdim))
  rwkv6:  (S (B,H,P,P), token-shift (B,d) x2)

Simplifications vs the full releases (noted per instructions): RWKV6 keeps the
*data-dependent decay* (the Finch contribution) via its LoRA, but uses static
token-shift mix coefficients for r/k/v/g; Mamba2 uses G=1 B/C groups.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist import constrain

from .layers import init_linear, rms_norm

__all__ = [
    "init_mamba2",
    "mamba2_logical",
    "mamba2",
    "mamba2_decode",
    "init_mamba2_state",
    "init_rwkv6",
    "rwkv6_logical",
    "rwkv6_timemix",
    "rwkv6_channelmix",
    "rwkv6_timemix_decode",
    "rwkv6_channelmix_decode",
    "init_rwkv6_state",
]


# ===================================================================== Mamba2
def _mamba_dims(d_model: int, expand: int, n_heads: int, state: int):
    d_in = expand * d_model
    h = n_heads
    p = d_in // h
    conv_dim = d_in + 2 * state  # x, B, C share the causal conv
    return d_in, h, p, conv_dim


def init_mamba2(key, d_model: int, expand: int, n_heads: int, state: int, conv: int, dtype):
    d_in, h, p, conv_dim = _mamba_dims(d_model, expand, n_heads, state)
    ks = jax.random.split(key, 5)
    return {
        "in_proj": init_linear(ks[0], d_model, 2 * d_in + 2 * state + h, dtype),
        "conv_w": (jax.random.normal(ks[1], (conv, conv_dim), jnp.float32) * 0.2).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.zeros((h,), jnp.float32),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "norm": jnp.ones((d_in,), dtype),
        "out_proj": init_linear(ks[2], d_in, d_model, dtype),
    }


def mamba2_logical():
    return {
        "in_proj": ("embed", "ff"),
        "conv_w": ("conv", None),
        "conv_b": (None,),
        "A_log": (None,),
        "D": (None,),
        "dt_bias": (None,),
        "norm": (None,),
        "out_proj": ("ff", "embed"),
    }


def _mamba_split(params, x, d_in: int, state: int, h: int):
    zxbcdt = x @ params["in_proj"].astype(x.dtype)
    z, xc, B, C, dt = jnp.split(
        zxbcdt, [d_in, 2 * d_in, 2 * d_in + state, 2 * d_in + 2 * state], axis=-1
    )
    return z, xc, B, C, dt


def _causal_conv(xbc, w, b, window=None):
    """Depthwise causal conv over (B, L, Cdim); kernel (K, Cdim)."""
    k = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(
        pad[:, i : i + xbc.shape[1], :] * w[i][None, None, :] for i in range(k)
    )
    return jax.nn.silu(out + b[None, None, :])


def mamba2(params, x, *, expand: int, n_heads: int, state: int, chunk: int):
    """x (B, L, d) -> (B, L, d); L must be a multiple of ``chunk``."""
    bsz, L, d_model = x.shape
    d_in, h, p, conv_dim = _mamba_dims(d_model, expand, n_heads, state)
    z, xc, B, C, dt = _mamba_split(params, x, d_in, state, h)
    xbc = _causal_conv(
        jnp.concatenate([xc, B, C], -1), params["conv_w"], params["conv_b"]
    )
    xc, B, C = jnp.split(xbc, [d_in, d_in + state], axis=-1)
    f32 = jnp.float32
    xh = xc.reshape(bsz, L, h, p).astype(f32)
    Bh = B.astype(f32)  # (B, L, N)  (G=1 group, shared across heads)
    Ch = C.astype(f32)
    dt = jax.nn.softplus(dt.astype(f32) + params["dt_bias"][None, None, :])  # (B,L,H)
    a = -jnp.exp(params["A_log"])  # (H,)

    nc = L // chunk
    c = chunk
    xh = xh.reshape(bsz, nc, c, h, p)
    Bh = Bh.reshape(bsz, nc, c, state)
    Ch = Ch.reshape(bsz, nc, c, state)
    dt = dt.reshape(bsz, nc, c, h)
    lam = dt * a[None, None, None, :]  # per-step log decay (B,nc,c,H)
    ell = jnp.cumsum(lam, axis=2)  # inclusive cumulative (B,nc,c,H)

    # intra-chunk (attention-like): M[t,s] = C_t.B_s * exp(ell_t - ell_s) * [s<=t]
    cb = jnp.einsum("bnts,bnus->bntu", Ch, Bh)  # (B,nc,c,c) (t,u)=(t,s)
    dec = jnp.exp(
        jnp.clip(ell[:, :, :, None, :] - ell[:, :, None, :, :], -60.0, 0.0)
    )  # (B,nc,t,s,H)
    tri = jnp.tril(jnp.ones((c, c), bool))
    m = cb[..., None] * dec * tri[None, None, :, :, None]  # (B,nc,t,s,H)
    xdt = xh * dt[..., None]  # (B,nc,c,H,P)
    y_intra = jnp.einsum("bntsh,bnshp->bnthp", m, xdt)

    # chunk summary states: S_n = sum_s exp(ell_c - ell_s) dt_s B_s (x) x_s
    dec_end = jnp.exp(jnp.clip(ell[:, :, -1:, :] - ell, -60.0, 0.0))  # (B,nc,c,H)
    s_chunk = jnp.einsum("bnsh,bnsv,bnshp->bnhvp", dec_end, Bh, xdt)  # (B,nc,H,N,P)
    lam_chunk = jnp.exp(jnp.clip(ell[:, :, -1, :], -60.0, 0.0))  # (B,nc,H)

    def scan_body(hprev, inp):
        s_n, lam_n = inp  # (B,H,N,P), (B,H)
        return hprev * lam_n[:, :, None, None] + s_n, hprev

    hs = jnp.zeros((bsz, h, state, p), f32)
    _, h_starts = jax.lax.scan(
        scan_body,
        hs,
        (s_chunk.transpose(1, 0, 2, 3, 4), lam_chunk.transpose(1, 0, 2)),
    )
    h_starts = h_starts.transpose(1, 0, 2, 3, 4)  # (B,nc,H,N,P) state at chunk start

    # inter-chunk: y_t += C_t . (exp(ell_t) * H_start) — INCLUSIVE decay, because
    # y_t reads h_t *after* this step's decay+update (h_t = e^{l_t} h_0 + ...),
    # unlike RWKV where y_t reads the pre-update state S_{t-1}.
    dec_in = jnp.exp(jnp.clip(ell, -60.0, 0.0))  # (B,nc,c,H)
    y_inter = jnp.einsum("bntv,bnhvp,bnth->bnthp", Ch, h_starts, dec_in)

    y = y_intra + y_inter + xh * params["D"][None, None, None, :, None]
    y = y.reshape(bsz, L, d_in).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z.astype(f32)).astype(x.dtype), params["norm"])
    y = constrain(y, ("batch", "act_seq", "ff"))
    return y @ params["out_proj"].astype(x.dtype)


def init_mamba2_state(batch: int, d_model: int, expand: int, n_heads: int, state: int, conv: int, dtype):
    d_in, h, p, conv_dim = _mamba_dims(d_model, expand, n_heads, state)
    return (
        jnp.zeros((batch, h, state, p), jnp.float32),
        jnp.zeros((batch, conv - 1, conv_dim), dtype),
    )


def mamba2_decode(params, x, st, *, expand: int, n_heads: int, state: int):
    """One-token step: x (B, 1, d), st = (h, conv_window)."""
    bsz, _, d_model = x.shape
    d_in, h, p, conv_dim = _mamba_dims(d_model, expand, n_heads, state)
    hstate, convw = st
    z, xc, B, C, dt = _mamba_split(params, x, d_in, state, h)
    xbc_new = jnp.concatenate([xc, B, C], -1)  # (B,1,Cdim)
    win = jnp.concatenate([convw, xbc_new], axis=1)  # (B,K,Cdim)
    w = params["conv_w"].astype(x.dtype)
    conv_out = jax.nn.silu(
        (win * w[None, :, :]).sum(axis=1) + params["conv_b"][None, :].astype(x.dtype)
    )  # (B,Cdim)
    xc1, B1, C1 = jnp.split(conv_out, [d_in, d_in + state], axis=-1)
    f32 = jnp.float32
    xh = xc1.reshape(bsz, h, p).astype(f32)
    dt1 = jax.nn.softplus(dt[:, 0].astype(f32) + params["dt_bias"][None, :])  # (B,H)
    a = -jnp.exp(params["A_log"])
    lam = jnp.exp(dt1 * a[None, :])  # (B,H)
    outer = jnp.einsum("bv,bhp->bhvp", B1.astype(f32), xh * dt1[..., None])
    hnew = hstate * lam[:, :, None, None] + outer
    y = jnp.einsum("bv,bhvp->bhp", C1.astype(f32), hnew) + xh * params["D"][None, :, None]
    y = y.reshape(bsz, 1, d_in).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z.astype(f32)).astype(x.dtype), params["norm"])
    out = y @ params["out_proj"].astype(x.dtype)
    return out, (hnew, win[:, 1:])


# ===================================================================== RWKV6
def init_rwkv6(key, d: int, ff: int, n_heads: int, dtype, lora_rank: int = 64):
    p = d // n_heads
    ks = jax.random.split(key, 12)
    return {
        "mix": (jax.random.uniform(ks[0], (5, d)) * 0.5 + 0.25).astype(jnp.float32),
        "wr": init_linear(ks[1], d, d, dtype),
        "wk": init_linear(ks[2], d, d, dtype),
        "wv": init_linear(ks[3], d, d, dtype),
        "wg": init_linear(ks[4], d, d, dtype),
        "wo": init_linear(ks[5], d, d, dtype),
        "w0": (jax.random.normal(ks[6], (d,), jnp.float32) * 0.1 - 6.0),
        "w_lora_a": init_linear(ks[7], d, lora_rank, jnp.float32),
        "w_lora_b": init_linear(ks[8], lora_rank, d, jnp.float32, scale=0.01),
        "u": (jax.random.normal(ks[9], (n_heads, p), jnp.float32) * 0.1),
        "ln_x": jnp.ones((d,), jnp.float32),
        # channel mix
        "mix_c": (jax.random.uniform(ks[10], (2, d)) * 0.5 + 0.25).astype(jnp.float32),
        "ck": init_linear(ks[11], d, ff, dtype),
        "cv": init_linear(jax.random.fold_in(key, 99), ff, d, dtype),
        "cr": init_linear(jax.random.fold_in(key, 98), d, d, dtype),
    }


def rwkv6_logical():
    return {
        "mix": (None, "embed"),
        "wr": ("embed", "heads"),
        "wk": ("embed", "heads"),
        "wv": ("embed", "heads"),
        "wg": ("embed", "heads"),
        "wo": ("heads", "embed"),
        "w0": ("embed",),
        "w_lora_a": ("embed", None),
        "w_lora_b": (None, "embed"),
        "u": ("heads", None),
        "ln_x": ("embed",),
        "mix_c": (None, "embed"),
        "ck": ("embed", "ff"),
        "cv": ("ff", "embed"),
        "cr": ("embed", None),
    }


def _shift(x):
    """Token shift: x_{t-1} (zeros at t=0)."""
    return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1, :]


def _rwkv_proj(params, x, xx):
    mix = params["mix"]  # (5, d): r, k, v, g, w

    def mixed(i):
        m = mix[i][None, None, :].astype(x.dtype)
        return x + (xx - x) * m

    r = mixed(0) @ params["wr"].astype(x.dtype)
    k = mixed(1) @ params["wk"].astype(x.dtype)
    v = mixed(2) @ params["wv"].astype(x.dtype)
    g = jax.nn.silu(mixed(3) @ params["wg"].astype(x.dtype))
    # data-dependent decay (the Finch contribution): w = exp(-exp(w0 + lora))
    xw = mixed(4).astype(jnp.float32)
    lora = jnp.tanh(xw @ params["w_lora_a"]) @ params["w_lora_b"]
    logw = -jnp.exp(jnp.clip(params["w0"][None, None, :] + lora, -20.0, 8.0))
    return r, k, v, g, logw  # logw = log(decay) in (-inf, 0)


def rwkv6_timemix(params, x, *, n_heads: int, chunk: int, norm_eps: float = 1e-5):
    """RWKV6 time mixing, chunked: x (B, L, d) -> (B, L, d)."""
    bsz, L, d = x.shape
    hp = d // n_heads
    r, k, v, g, logw = _rwkv_proj(params, x, _shift(x))
    f32 = jnp.float32
    nc = L // chunk
    c = chunk

    def heads(t):
        return t.reshape(bsz, nc, c, n_heads, hp).astype(f32)

    r, k, v = heads(r), heads(k), heads(v)
    logw = logw.reshape(bsz, nc, c, n_heads, hp)
    ell = jnp.cumsum(logw, axis=2)  # inclusive (B,nc,c,H,P)

    # intra-chunk: y_t = sum_{s<t} [r_t * exp(ell_{t-1}-ell_s)] . k_s  v_s  + bonus
    ell_prev = ell - logw  # ell_{t-1}
    # factorized decay: exp(ell_prev_t - ell_s) = exp(ell_prev_t) * exp(-ell_s);
    # cumulative logs are clipped to [-60, 0] so both factors stay finite in f32.
    att = jnp.einsum(
        "bnthp,bnshp->bnhts",
        r * jnp.exp(jnp.clip(ell_prev, -60.0, 0.0)),
        k * jnp.exp(jnp.clip(-ell, 0.0, 60.0)),
    )
    tri = jnp.tril(jnp.ones((c, c), bool), k=-1)
    att = att * tri[None, None, None, :, :]
    y = jnp.einsum("bnhts,bnshp->bnthp", att, v)
    bonus = jnp.einsum("bnthp,bnthp->bnth", r, k * params["u"][None, None, None, :, :])
    y = y + bonus[..., None] * v

    # inter-chunk state: S (B,H,P,P) [key-dim, value-dim]
    dec_end = jnp.exp(jnp.clip(ell[:, :, -1:, :, :] - ell, -60.0, 0.0))  # (B,nc,c,H,P)
    s_chunk = jnp.einsum("bnshp,bnshv->bnhpv", k * dec_end, v)
    lam_chunk = jnp.exp(jnp.clip(ell[:, :, -1, :, :], -60.0, 0.0))  # (B,nc,H,P)

    def scan_body(sprev, inp):
        s_n, lam_n = inp
        return sprev * lam_n[..., None] + s_n, sprev

    s0 = jnp.zeros((bsz, n_heads, hp, hp), f32)
    _, s_starts = jax.lax.scan(
        scan_body,
        s0,
        (s_chunk.transpose(1, 0, 2, 3, 4), lam_chunk.transpose(1, 0, 2, 3)),
    )
    s_starts = s_starts.transpose(1, 0, 2, 3, 4)  # (B,nc,H,P,P)
    y_inter = jnp.einsum(
        "bnthp,bnhpv->bnthv", r * jnp.exp(jnp.clip(ell_prev, -60.0, 0.0)), s_starts
    )
    y = (y + y_inter).reshape(bsz, L, d)
    # group-norm per head (ln_x), gate, output proj
    y = y.reshape(bsz, L, n_heads, hp)
    mu = y.mean(-1, keepdims=True)
    var = y.var(-1, keepdims=True)
    y = (y - mu) * jax.lax.rsqrt(var + norm_eps)
    y = y.reshape(bsz, L, d) * params["ln_x"][None, None, :]
    y = (y.astype(x.dtype) * g)
    return y @ params["wo"].astype(x.dtype)


def rwkv6_channelmix(params, x):
    xx = _shift(x)
    mix = params["mix_c"]
    xk = x + (xx - x) * mix[0][None, None, :].astype(x.dtype)
    xr = x + (xx - x) * mix[1][None, None, :].astype(x.dtype)
    kk = jnp.square(jax.nn.relu(xk @ params["ck"].astype(x.dtype)))
    kk = constrain(kk, ("batch", "act_seq", "ff"))
    return jax.nn.sigmoid(xr @ params["cr"].astype(x.dtype)) * (
        kk @ params["cv"].astype(x.dtype)
    )


def init_rwkv6_state(batch: int, d: int, n_heads: int, dtype):
    hp = d // n_heads
    return (
        jnp.zeros((batch, d), dtype),  # time-mix token shift
        jnp.zeros((batch, n_heads, hp, hp), jnp.float32),  # wkv state
        jnp.zeros((batch, d), dtype),  # channel-mix token shift
    )


def rwkv6_timemix_decode(params, x, st, *, n_heads: int, norm_eps: float = 1e-5):
    """One-token step: x (B, 1, d); st = (shift, S, cshift) -> (y, new_st)."""
    bsz, _, d = x.shape
    hp = d // n_heads
    shift, S, cshift = st
    r, k, v, g, logw = _rwkv_proj(params, x, shift[:, None, :])
    f32 = jnp.float32
    r1 = r[:, 0].reshape(bsz, n_heads, hp).astype(f32)
    k1 = k[:, 0].reshape(bsz, n_heads, hp).astype(f32)
    v1 = v[:, 0].reshape(bsz, n_heads, hp).astype(f32)
    w1 = jnp.exp(logw[:, 0].reshape(bsz, n_heads, hp))  # decay in (0,1)
    kv = jnp.einsum("bhp,bhv->bhpv", k1, v1)
    y = jnp.einsum("bhp,bhpv->bhv", r1, S + params["u"][None, :, :, None] * kv)
    S_new = S * w1[..., None] + kv
    mu = y.mean(-1, keepdims=True)
    var = y.var(-1, keepdims=True)
    y = (y - mu) * jax.lax.rsqrt(var + norm_eps)
    y = y.reshape(bsz, 1, d) * params["ln_x"][None, None, :]
    y = y.astype(x.dtype) * g
    out = y @ params["wo"].astype(x.dtype)
    return out, (x[:, 0, :], S_new, cshift)


def rwkv6_channelmix_decode(params, x, cshift):
    xx = cshift[:, None, :]
    mix = params["mix_c"]
    xk = x + (xx - x) * mix[0][None, None, :].astype(x.dtype)
    xr = x + (xx - x) * mix[1][None, None, :].astype(x.dtype)
    kk = jnp.square(jax.nn.relu(xk @ params["ck"].astype(x.dtype)))
    out = jax.nn.sigmoid(xr @ params["cr"].astype(x.dtype)) * (
        kk @ params["cv"].astype(x.dtype)
    )
    return out, x[:, 0, :]
