"""GQA / sliding-window / cross attention with KV-cache decode paths.

Weights:  wq (d, Hq, dh) · wk/wv (d, Hkv, dh) · wo (Hq, dh, d).
Sharding: heads -> 'model' (TP); batch -> ('pod','data'); the KV cache carries
(B, Hkv, S, dh) with kv-heads on 'model' when divisible, else replicated.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist import constrain

from .layers import apply_rope, init_linear, rope

__all__ = [
    "init_attn",
    "attn_logical",
    "attention",
    "attention_decode",
    "init_cache",
]

NEG_INF = -1e30


def init_attn(key, d: int, n_heads: int, n_kv: int, d_head: int, dtype):
    ks = jax.random.split(key, 4)
    return {
        "wq": init_linear(ks[0], d, (n_heads, d_head), dtype),
        "wk": init_linear(ks[1], d, (n_kv, d_head), dtype),
        "wv": init_linear(ks[2], d, (n_kv, d_head), dtype),
        "wo": (
            jax.random.normal(ks[3], (n_heads, d_head, d), jnp.float32)
            * (n_heads * d_head) ** -0.5
        ).astype(dtype),
    }


def attn_logical():
    return {
        "wq": ("embed", "heads", None),
        "wk": ("embed", "kv", None),
        "wv": ("embed", "kv", None),
        "wo": ("heads", None, "embed"),
    }


def _proj_qkv(params, x, xk):
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", xk, params["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", xk, params["wv"].astype(x.dtype))
    return q, k, v


def _scores_to_out(params, q, k, v, mask):
    """q (B,Sq,Hq,dh), k/v (B,Skv,Hkv,dh); GQA by head-group reshape."""
    b, sq, hq, dh = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    q = q.reshape(b, sq, hkv, g, dh)
    scores = jnp.einsum(
        "bqhgk,bshk->bhgqs", q, k, preferred_element_type=jnp.float32
    ) * (dh**-0.5)
    if mask is not None:
        scores = jnp.where(mask, scores, NEG_INF)
    p = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(v.dtype)
    out = jnp.einsum("bhgqs,bshk->bqhgk", p, v)
    out = out.reshape(b, sq, hq, dh)
    out = constrain(out, ("batch", "act_seq", "heads", None))
    return jnp.einsum(
        "bqhk,hkd->bqd", out, params["wo"].astype(out.dtype)
    )


def _causal_mask(sq: int, skv: int, window: int | None):
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(skv)[None, :]
    m = kpos <= qpos
    if window is not None:
        m = m & (kpos > qpos - window)
    return m[None, None, None, :, :]  # (1,1,1,Sq,Skv)


def attention(
    params,
    x,
    *,
    n_heads: int,
    n_kv: int,
    d_head: int,
    rope_theta: float,
    causal: bool = True,
    window: int | None = None,
    memory=None,
):
    """Full-sequence attention (train / prefill / encoder / cross).

    ``memory``: if given, cross-attention over it (no mask, no rope on memory).
    Returns (out, (k, v)) — the kv pair for cache seeding at prefill.
    """
    b, s, _ = x.shape
    xk = memory if memory is not None else x
    q, k, v = _proj_qkv(params, x, xk)
    if memory is None:
        cos, sin = rope(jnp.arange(s), d_head, rope_theta, x.dtype)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
        mask = _causal_mask(s, s, window) if causal else None
    else:
        mask = None
    q = constrain(q, ("batch", "act_seq", "heads", None))
    k = constrain(k, ("batch", "act_kv_seq", "kv", None))
    v = constrain(v, ("batch", "act_kv_seq", "kv", None))
    out = _scores_to_out(params, q, k, v, mask)
    return out, (k, v)


def attention_with_kv(params, x, k, v, *, n_heads: int, n_kv: int, d_head: int):
    """Cross-attention against PRECOMPUTED memory k/v (decode fast path).

    Encoder/image memory is static during decode, so its k/v are projected once
    at prefill and cached — re-projecting (B, S_mem, d) every token was the
    dominant decode cost for encdec/vlm (EXPERIMENTS.md §Perf next-levers).
    """
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(x.dtype))
    q = constrain(q, ("batch", "act_seq", "heads", None))
    return _scores_to_out(params, q, k.astype(x.dtype), v.astype(x.dtype), None)


def project_memory_kv(params, mem):
    """Project cross-attention memory k/v once (prefill-time seeding)."""
    k = jnp.einsum("bsd,dhk->bshk", mem, params["wk"].astype(mem.dtype))
    v = jnp.einsum("bsd,dhk->bshk", mem, params["wv"].astype(mem.dtype))
    return k, v


def init_cache(batch: int, n_kv: int, max_len: int, d_head: int, dtype):
    """Ring/linear KV cache for one layer: (k, v) of (B, S, Hkv, dh)."""
    shape = (batch, max_len, n_kv, d_head)
    return jnp.zeros(shape, dtype), jnp.zeros(shape, dtype)


def attention_decode(
    params,
    x,
    cache,
    pos,
    *,
    n_heads: int,
    n_kv: int,
    d_head: int,
    rope_theta: float,
    window: int | None = None,
):
    """One-token decode: x (B, 1, d); cache (k, v) (B, Smax, Hkv, dh); pos ().

    With ``window`` the cache is a ring buffer of size window (SWA decode keeps
    only the last W keys — how h2o-danube runs the 500k cell with O(W) memory).
    Returns (out (B,1,d), new_cache).
    """
    ck, cv = cache
    smax = ck.shape[1]
    q, k, v = _proj_qkv(params, x, x)
    cos, sin = rope(pos[None], d_head, rope_theta, x.dtype)  # (1, dh/2)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    slot = pos % smax if window is not None else pos
    ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype), (0, slot, 0, 0))
    cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype), (0, slot, 0, 0))
    kpos = jnp.arange(smax)
    if window is None:
        valid = kpos <= pos
    else:
        # ring buffer: slots hold positions (pos - smax, pos]; all written slots valid
        valid = kpos <= pos  # after wrap every slot is valid; pre-wrap only <= pos
        valid = valid | (pos >= smax)
    mask = valid[None, None, None, None, :]
    out = _scores_to_out(params, q, ck, cv, mask)
    return out, (ck, cv)
