"""Model assembly: init / forward / decode for every assigned architecture family.

Families: dense (deepseek/yi/nemotron/h2o-danube), moe (granite/qwen3),
ssm (rwkv6), hybrid (zamba2: mamba2 + shared attention block), encdec
(seamless-m4t: stubbed frame embeddings -> encoder, token decoder), vlm
(llama-3.2-vision: stubbed patch embeddings, cross-attn every 5th layer).

Structure: homogeneous blocks are *stacked* (leading n_layers dim) and driven by
``lax.scan`` so the compiled HLO is one block body regardless of depth — this is
what keeps 94-layer dry-run compiles tractable.  ``cfg.remat`` wraps the block
in ``jax.checkpoint`` (activation recomputation policy for training).

Params are plain nested dicts; ``param_logical(cfg)`` mirrors the tree with
logical sharding axes consumed by repro.dist.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist import constrain

from . import attention as attn
from . import moe as moe_mod
from . import ssm as ssm_mod
from .layers import cross_entropy_loss, init_linear, init_mlp, mlp, mlp_logical, rms_norm

__all__ = [
    "seed_decode_state",
    "encode_memory",
    "init_params",
    "param_logical",
    "forward",
    "init_decode_state",
    "decode_step",
    "loss_fn",
]


def _dt(name):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[name]


def _stack_init(fn, key, n, *args):
    return jax.vmap(lambda k: fn(k, *args))(jax.random.split(key, n))


# ===================================================================== blocks
def _attn_kw(cfg: ModelConfig):
    return dict(
        n_heads=cfg.n_heads,
        n_kv=cfg.n_kv_heads,
        d_head=cfg.head_dim,
        rope_theta=cfg.rope_theta,
    )


def init_dense_block(key, cfg: ModelConfig):
    ks = jax.random.split(key, 2)
    dt = _dt(cfg.param_dtype)
    p = {
        "ln1": jnp.ones((cfg.d_model,), dt),
        "attn": attn.init_attn(ks[0], cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, dt),
        "ln2": jnp.ones((cfg.d_model,), dt),
    }
    if cfg.family == "moe":
        p["mlp"] = moe_mod.init_moe(ks[1], cfg.d_model, cfg.d_ff, cfg.n_experts, dt)
    else:
        p["mlp"] = init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.activation, dt)
    return p


def dense_block_logical(cfg: ModelConfig):
    return {
        "ln1": (None,),
        "attn": attn.attn_logical(),
        "ln2": (None,),
        "mlp": moe_mod.moe_logical() if cfg.family == "moe" else mlp_logical(cfg.activation),
    }


def dense_block(p, x, cfg: ModelConfig, memory=None):
    """Returns (x, aux) where aux is the MoE router logits (or 0.)."""
    h, _ = attn.attention(
        p["attn"],
        constrain(rms_norm(x, p["ln1"], cfg.norm_eps), ("batch", "act_seq", None)),
        causal=True,
        window=cfg.sliding_window,
        **_attn_kw(cfg),
    )
    x = x + h
    x = constrain(x, ("batch", "seq", None))
    hin = rms_norm(x, p["ln2"], cfg.norm_eps)
    hin = constrain(hin, ("batch", "act_seq", None))
    if cfg.family == "moe":
        h, router_logits = moe_mod.moe_ffn(
            p["mlp"], hin, n_experts=cfg.n_experts, top_k=cfg.top_k,
            capacity_factor=cfg.capacity_factor,
        )
        aux = _load_balance_loss(router_logits, cfg)
    else:
        h = mlp(p["mlp"], hin, cfg.activation)
        aux = jnp.float32(0.0)
    x = x + h
    return constrain(x, ("batch", "seq", None)), aux


def _load_balance_loss(router_logits, cfg: ModelConfig):
    """Switch-style aux loss: E * sum_e f_e * p_e."""
    probs = jax.nn.softmax(router_logits, axis=-1)  # (T, E)
    top = jnp.argmax(probs, axis=-1)
    f = jnp.mean(jax.nn.one_hot(top, cfg.n_experts, dtype=jnp.float32), axis=0)
    pbar = probs.mean(axis=0)
    return cfg.n_experts * jnp.sum(f * pbar)


def _scan_blocks(block_fn, stacked, x, remat: bool, unroll: bool = False):
    fn = jax.checkpoint(block_fn) if remat else block_fn

    def body(carry, p):
        y, aux = fn(p, carry)
        return y, aux

    x, auxs = jax.lax.scan(body, x, stacked, unroll=unroll)
    return x, jnp.sum(auxs)


# ===================================================================== top level
def init_params(cfg: ModelConfig, key):
    dt = _dt(cfg.param_dtype)
    ks = jax.random.split(key, 8)
    p = {
        "embed": (jax.random.normal(ks[0], (cfg.vocab, cfg.d_model), jnp.float32) * 0.02).astype(dt),
        "ln_f": jnp.ones((cfg.d_model,), dt),
        "unembed": init_linear(ks[1], cfg.d_model, cfg.vocab, dt),
    }
    fam = cfg.family
    if fam in ("dense", "moe"):
        p["blocks"] = _stack_init(lambda k: init_dense_block(k, cfg), ks[2], cfg.n_layers)
    elif fam == "ssm":
        p["blocks"] = _stack_init(
            lambda k: {
                "ln1": jnp.ones((cfg.d_model,), dt),
                "tm": ssm_mod.init_rwkv6(k, cfg.d_model, cfg.d_ff, cfg.n_heads, dt),
                "ln2": jnp.ones((cfg.d_model,), dt),
            },
            ks[2],
            cfg.n_layers,
        )
    elif fam == "hybrid":
        every = cfg.shared_attn_every
        groups = cfg.n_layers // (every + 1)
        trailing = cfg.n_layers - groups * (every + 1)
        mamba_init = lambda k: {
            "ln": jnp.ones((cfg.d_model,), dt),
            "m": ssm_mod.init_mamba2(
                k, cfg.d_model, cfg.ssm_expand, cfg.n_ssm_heads, cfg.ssm_state, cfg.ssm_conv, dt
            ),
        }
        p["groups"] = jax.vmap(
            lambda k: _stack_init(mamba_init, k, every)
        )(jax.random.split(ks[2], groups))
        p["trailing"] = _stack_init(mamba_init, ks[3], max(trailing, 1))
        p["shared_attn"] = init_dense_block(ks[4], cfg)  # ONE shared block (zamba)
    elif fam == "encdec":
        p["enc_blocks"] = _stack_init(
            lambda k: init_dense_block(k, cfg), ks[2], cfg.n_enc_layers
        )
        p["dec_blocks"] = _stack_init(
            lambda k: {
                **init_dense_block(k, cfg),
                "lnx": jnp.ones((cfg.d_model,), dt),
                "xattn": attn.init_attn(
                    jax.random.fold_in(k, 7), cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, dt
                ),
            },
            ks[3],
            cfg.n_dec_layers,
        )
        p["ln_enc"] = jnp.ones((cfg.d_model,), dt)
    elif fam == "vlm":
        every = cfg.cross_attn_every
        groups = cfg.n_layers // every
        p["groups"] = jax.vmap(
            lambda k: {
                "selfs": _stack_init(
                    lambda kk: init_dense_block(kk, cfg), k, every - 1
                ),
                "cross": {
                    **init_dense_block(jax.random.fold_in(k, 1), cfg),
                    "lnx": jnp.ones((cfg.d_model,), dt),
                    "xattn": attn.init_attn(
                        jax.random.fold_in(k, 2), cfg.d_model, cfg.n_heads,
                        cfg.n_kv_heads, cfg.head_dim, dt,
                    ),
                    "xgate": jnp.zeros((), jnp.float32),
                },
            }
        )(jax.random.split(ks[2], groups))
    else:
        raise ValueError(fam)
    return p


def param_logical(cfg: ModelConfig):
    """Same tree as init_params but with logical-axes tuples at the leaves."""
    fam = cfg.family
    blk = dense_block_logical(cfg)
    p = {"embed": ("vocab", "embed"), "ln_f": (None,), "unembed": ("embed", "vocab")}
    if fam in ("dense", "moe"):
        p["blocks"] = _prefix_layers(blk)
    elif fam == "ssm":
        p["blocks"] = _prefix_layers(
            {"ln1": (None,), "tm": ssm_mod.rwkv6_logical(), "ln2": (None,)}
        )
    elif fam == "hybrid":
        mamba = {"ln": (None,), "m": ssm_mod.mamba2_logical()}
        p["groups"] = _prefix_layers(_prefix_layers(mamba))
        p["trailing"] = _prefix_layers(mamba)
        p["shared_attn"] = blk
    elif fam == "encdec":
        p["enc_blocks"] = _prefix_layers(blk)
        p["dec_blocks"] = _prefix_layers(
            {**blk, "lnx": (None,), "xattn": attn.attn_logical()}
        )
        p["ln_enc"] = (None,)
    elif fam == "vlm":
        p["groups"] = _prefix_layers(
            {
                "selfs": _prefix_layers(blk),
                "cross": {**blk, "lnx": (None,), "xattn": attn.attn_logical(), "xgate": ()},
            }
        )
    return p


def _prefix_layers(tree):
    """Prepend the stacked-layers axis (None) to every logical tuple."""
    return jax.tree.map(
        lambda ax: (None, *ax),
        tree,
        is_leaf=lambda t: isinstance(t, tuple)
        and all(isinstance(e, (str, type(None))) for e in t),
    )


# ===================================================================== forward
def forward(params, cfg: ModelConfig, batch, *, logits_last_only: bool = False):
    """Full-sequence forward.

    batch: {'tokens': (B,S) i32} plus per-family extras:
      encdec: {'frames': (B,S_enc,d)}  (stub frontend: precomputed embeddings)
      vlm:    {'img': (B,n_img,d)}
    ``logits_last_only``: serve-prefill mode — unembed only the final position
    (a 32k x 151936-vocab full-logit tensor would dwarf the prefill itself).
    Returns (logits (B,S,V) or (B,1,V), aux_loss).
    """
    fam = cfg.family
    tokens = batch["tokens"]
    x = params["embed"].astype(_dt(cfg.compute_dtype))[tokens]
    x = constrain(x, ("batch", "seq", None))

    if fam in ("dense", "moe"):
        x, aux = _scan_blocks(lambda p, h: dense_block(p, h, cfg), params["blocks"], x, cfg.remat, cfg.scan_unroll)
    elif fam == "ssm":
        x, aux = _scan_blocks(
            lambda p, h: _rwkv_block(p, h, cfg), params["blocks"], x, cfg.remat,
            cfg.scan_unroll,
        )
    elif fam == "hybrid":
        x, aux = _hybrid_forward(params, x, cfg)
    elif fam == "encdec":
        mem = batch["frames"].astype(x.dtype)
        mem = constrain(mem, ("batch", "kv_seq", None))
        mem, _ = _scan_blocks(
            lambda p, h: _enc_block(p, h, cfg), params["enc_blocks"], mem, cfg.remat,
            cfg.scan_unroll,
        )
        mem = rms_norm(mem, params["ln_enc"], cfg.norm_eps)
        x, aux = _scan_blocks(
            lambda p, h: _dec_block(p, h, mem, cfg), params["dec_blocks"], x, cfg.remat,
            cfg.scan_unroll,
        )
    elif fam == "vlm":
        img = batch["img"].astype(x.dtype)
        img = constrain(img, ("batch", "img", None))
        x, aux = _vlm_forward(params, x, img, cfg)
    else:
        raise ValueError(fam)

    if logits_last_only:
        x = x[:, -1:, :]
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = jnp.einsum(
        "bsd,dv->bsv", x, params["unembed"].astype(x.dtype)
    )
    logits = constrain(logits, ("batch", "seq", "vocab"))
    return logits, aux


def _rwkv_block(p, x, cfg: ModelConfig):
    x = x + ssm_mod.rwkv6_timemix(
        p["tm"], rms_norm(x, p["ln1"], cfg.norm_eps), n_heads=cfg.n_heads, chunk=cfg.ssm_chunk
    )
    x = x + ssm_mod.rwkv6_channelmix(p["tm"], rms_norm(x, p["ln2"], cfg.norm_eps))
    return constrain(x, ("batch", "seq", None)), jnp.float32(0.0)


def _mamba_block(p, x, cfg: ModelConfig):
    h = ssm_mod.mamba2(
        p["m"],
        rms_norm(x, p["ln"], cfg.norm_eps),
        expand=cfg.ssm_expand,
        n_heads=cfg.n_ssm_heads,
        state=cfg.ssm_state,
        chunk=cfg.ssm_chunk,
    )
    return constrain(x + h, ("batch", "seq", None)), jnp.float32(0.0)


def _hybrid_forward(params, x, cfg: ModelConfig):
    shared = params["shared_attn"]

    def group_body(x, gp):
        x, _ = _scan_blocks(
            lambda p, h: _mamba_block(p, h, cfg), gp, x, cfg.remat, cfg.scan_unroll
        )
        x, _ = dense_block(shared, x, cfg)  # the ONE shared attention block
        return x, jnp.float32(0.0)

    x, _ = jax.lax.scan(group_body, x, params["groups"], unroll=cfg.scan_unroll)
    trailing = cfg.n_layers - (cfg.n_layers // (cfg.shared_attn_every + 1)) * (
        cfg.shared_attn_every + 1
    )
    if trailing > 0:
        x, _ = _scan_blocks(
            lambda p, h: _mamba_block(p, h, cfg), params["trailing"], x, cfg.remat,
            cfg.scan_unroll,
        )
    return x, jnp.float32(0.0)


def _enc_block(p, x, cfg: ModelConfig):
    h, _ = attn.attention(
        p["attn"], rms_norm(x, p["ln1"], cfg.norm_eps), causal=False, **_attn_kw(cfg)
    )
    x = x + h
    x = x + mlp(p["mlp"], rms_norm(x, p["ln2"], cfg.norm_eps), cfg.activation)
    return constrain(x, ("batch", "kv_seq", None)), jnp.float32(0.0)


def _dec_block(p, x, mem, cfg: ModelConfig):
    h, _ = attn.attention(
        p["attn"], rms_norm(x, p["ln1"], cfg.norm_eps), causal=True, **_attn_kw(cfg)
    )
    x = x + h
    hx, _ = attn.attention(
        p["xattn"], rms_norm(x, p["lnx"], cfg.norm_eps), memory=mem, **_attn_kw(cfg)
    )
    x = x + hx
    x = x + mlp(p["mlp"], rms_norm(x, p["ln2"], cfg.norm_eps), cfg.activation)
    return constrain(x, ("batch", "seq", None)), jnp.float32(0.0)


def _vlm_forward(params, x, img, cfg: ModelConfig):
    def group_body(x, gp):
        x, _ = _scan_blocks(
            lambda p, h: dense_block(p, h, cfg), gp["selfs"], x, cfg.remat,
            cfg.scan_unroll,
        )
        cp = gp["cross"]
        x, _ = dense_block(cp, x, cfg)
        hx, _ = attn.attention(
            cp["xattn"], rms_norm(x, cp["lnx"], cfg.norm_eps), memory=img, **_attn_kw(cfg)
        )
        x = x + jnp.tanh(cp["xgate"]).astype(x.dtype) * hx
        return constrain(x, ("batch", "seq", None)), jnp.float32(0.0)

    x, _ = jax.lax.scan(group_body, x, params["groups"], unroll=cfg.scan_unroll)
    return x, jnp.float32(0.0)


# ===================================================================== decode
def init_decode_state(cfg: ModelConfig, batch: int, max_len: int, mem_len: int = 0):
    """Per-layer stacked decode state (KV caches / SSM states).

    ``mem_len``: encoder-memory length for encdec (set at prefill time).
    """
    dt = _dt(cfg.compute_dtype)
    fam = cfg.family
    kv_len = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len

    def kv(n):
        return (
            jnp.zeros((n, batch, kv_len, cfg.n_kv_heads, cfg.head_dim), dt),
            jnp.zeros((n, batch, kv_len, cfg.n_kv_heads, cfg.head_dim), dt),
        )

    if fam in ("dense", "moe"):
        return {"kv": kv(cfg.n_layers)}
    if fam == "ssm":
        sh = lambda *s: jnp.zeros((cfg.n_layers, batch, *s))
        hp = cfg.d_model // cfg.n_heads
        return {
            "shift": jnp.zeros((cfg.n_layers, batch, cfg.d_model), dt),
            "S": jnp.zeros((cfg.n_layers, batch, cfg.n_heads, hp, hp), jnp.float32),
            "cshift": jnp.zeros((cfg.n_layers, batch, cfg.d_model), dt),
        }
    if fam == "hybrid":
        every = cfg.shared_attn_every
        groups = cfg.n_layers // (every + 1)
        trailing = cfg.n_layers - groups * (every + 1)
        d_in = cfg.d_inner
        h, pdim = cfg.n_ssm_heads, cfg.d_inner // cfg.n_ssm_heads
        conv_dim = d_in + 2 * cfg.ssm_state

        def mamba_state(*lead):
            return (
                jnp.zeros((*lead, batch, h, cfg.ssm_state, pdim), jnp.float32),
                jnp.zeros((*lead, batch, cfg.ssm_conv - 1, conv_dim), dt),
            )

        return {
            "groups": mamba_state(groups, every),
            "trailing": mamba_state(max(trailing, 1)),
            "shared_kv": kv(groups),
        }
    if fam == "encdec":
        ml = max(mem_len, 1)
        return {
            "kv": kv(cfg.n_dec_layers),
            # cross-attention k/v over the encoder memory, seeded at prefill
            # (seed_decode_state); re-projecting memory per token was the
            # dominant decode cost
            "cross_kv": (
                jnp.zeros((cfg.n_dec_layers, batch, ml, cfg.n_kv_heads, cfg.head_dim), dt),
                jnp.zeros((cfg.n_dec_layers, batch, ml, cfg.n_kv_heads, cfg.head_dim), dt),
            ),
        }
    if fam == "vlm":
        every = cfg.cross_attn_every
        groups = cfg.n_layers // every
        return {
            "self_kv": kv(groups * (every - 1)),
            "cross_self_kv": kv(groups),
            # precomputed patch-embedding cross k/v (seed_decode_state)
            "cross_kv": (
                jnp.zeros((groups, batch, cfg.n_img_tokens, cfg.n_kv_heads, cfg.head_dim), dt),
                jnp.zeros((groups, batch, cfg.n_img_tokens, cfg.n_kv_heads, cfg.head_dim), dt),
            ),
        }
    raise ValueError(fam)


def decode_step(params, cfg: ModelConfig, state, token, pos):
    """One-token decode: token (B, 1) i32, pos () i32 -> (logits (B,1,V), state)."""
    fam = cfg.family
    x = params["embed"].astype(_dt(cfg.compute_dtype))[token]
    x = constrain(x, ("batch", None, None))
    akw = _attn_kw(cfg)

    def attn_block_decode(p, x, cache):
        h, c2 = attn.attention_decode(
            p["attn"], rms_norm(x, p["ln1"], cfg.norm_eps), cache, pos,
            window=cfg.sliding_window, **akw,
        )
        x = x + h
        hin = rms_norm(x, p["ln2"], cfg.norm_eps)
        if cfg.family == "moe":
            h, _ = moe_mod.moe_ffn(
                p["mlp"], hin, n_experts=cfg.n_experts, top_k=cfg.top_k,
                capacity_factor=cfg.capacity_factor,
            )
        else:
            h = mlp(p["mlp"], hin, cfg.activation)
        return x + h, c2

    if fam in ("dense", "moe"):
        def body(x, inp):
            p, ck, cv = inp
            y, (ck2, cv2) = attn_block_decode(p, x, (ck, cv))
            return y, (ck2, cv2)

        ck, cv = state["kv"]
        x, (ck, cv) = jax.lax.scan(body, x, (params["blocks"], ck, cv), unroll=cfg.scan_unroll)
        state = {"kv": (ck, cv)}
    elif fam == "ssm":
        def body(x, inp):
            p, shift, S, cshift = inp
            h, (shift2, S2, _) = ssm_mod.rwkv6_timemix_decode(
                p["tm"], rms_norm(x, p["ln1"], cfg.norm_eps), (shift, S, cshift),
                n_heads=cfg.n_heads,
            )
            x = x + h
            h, cshift2 = ssm_mod.rwkv6_channelmix_decode(
                p["tm"], rms_norm(x, p["ln2"], cfg.norm_eps), cshift
            )
            return x + h, (shift2, S2, cshift2)

        x, (sh, S, csh) = jax.lax.scan(
            body, x, (params["blocks"], state["shift"], state["S"], state["cshift"]),
            unroll=cfg.scan_unroll,
        )
        state = {"shift": sh, "S": S, "cshift": csh}
    elif fam == "hybrid":
        def mamba_decode(p, x, st):
            h, st2 = ssm_mod.mamba2_decode(
                p["m"], rms_norm(x, p["ln"], cfg.norm_eps),
                st, expand=cfg.ssm_expand, n_heads=cfg.n_ssm_heads, state=cfg.ssm_state,
            )
            return x + h, st2

        def group_body(x, inp):
            gp, hS, hconv, ck, cv = inp

            def inner(x, minp):
                p, s1, s2 = minp
                y, (s1b, s2b) = mamba_decode(p, x, (s1, s2))
                return y, (s1b, s2b)

            x, (hS2, hconv2) = jax.lax.scan(
                inner, x, (gp, hS, hconv), unroll=cfg.scan_unroll
            )
            y, (ck2, cv2) = attn_block_decode(params["shared_attn"], x, (ck, cv))
            return y, (hS2, hconv2, ck2, cv2)

        hS, hconv = state["groups"]
        ck, cv = state["shared_kv"]
        x, (hS, hconv, ck, cv) = jax.lax.scan(
            group_body, x, (params["groups"], hS, hconv, ck, cv),
            unroll=cfg.scan_unroll,
        )
        tS, tconv = state["trailing"]
        trailing = cfg.n_layers - (cfg.n_layers // (cfg.shared_attn_every + 1)) * (
            cfg.shared_attn_every + 1
        )
        if trailing > 0:
            def inner(x, minp):
                p, s1, s2 = minp
                y, (s1b, s2b) = mamba_decode(p, x, (s1, s2))
                return y, (s1b, s2b)

            x, (tS, tconv) = jax.lax.scan(
                inner, x, (params["trailing"], tS, tconv), unroll=cfg.scan_unroll
            )
        state = {"groups": (hS, hconv), "trailing": (tS, tconv), "shared_kv": (ck, cv)}
    elif fam == "encdec":
        xk, xv = state["cross_kv"]

        def body(x, inp):
            p, ck, cv, xkl, xvl = inp
            h, (ck2, cv2) = attn.attention_decode(
                p["attn"], rms_norm(x, p["ln1"], cfg.norm_eps), (ck, cv), pos, **akw
            )
            x = x + h
            hx = attn.attention_with_kv(
                p["xattn"], rms_norm(x, p["lnx"], cfg.norm_eps), xkl, xvl,
                n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, d_head=cfg.head_dim,
            )
            x = x + hx
            x = x + mlp(p["mlp"], rms_norm(x, p["ln2"], cfg.norm_eps), cfg.activation)
            return x, (ck2, cv2)

        ck, cv = state["kv"]
        x, (ck, cv) = jax.lax.scan(
            body, x, (params["dec_blocks"], ck, cv, xk, xv), unroll=cfg.scan_unroll
        )
        state = {"kv": (ck, cv), "cross_kv": (xk, xv)}
    elif fam == "vlm":
        every = cfg.cross_attn_every
        groups = cfg.n_layers // every
        sck, scv = state["self_kv"]
        sck = sck.reshape(groups, every - 1, *sck.shape[1:])
        scv = scv.reshape(groups, every - 1, *scv.shape[1:])
        cck, ccv = state["cross_self_kv"]
        xk, xv = state["cross_kv"]

        def group_body(x, inp):
            gp, sck_g, scv_g, cck_g, ccv_g, xk_g, xv_g = inp

            def inner(x, minp):
                p, ck, cv = minp
                y, (ck2, cv2) = attn_block_decode(p, x, (ck, cv))
                return y, (ck2, cv2)

            x, (sck_g, scv_g) = jax.lax.scan(
                inner, x, (gp["selfs"], sck_g, scv_g), unroll=cfg.scan_unroll
            )
            cp = gp["cross"]
            x, (cck_g, ccv_g) = attn_block_decode(cp, x, (cck_g, ccv_g))
            hx = attn.attention_with_kv(
                cp["xattn"], rms_norm(x, cp["lnx"], cfg.norm_eps), xk_g, xv_g,
                n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, d_head=cfg.head_dim,
            )
            x = x + jnp.tanh(cp["xgate"]).astype(x.dtype) * hx
            return x, (sck_g, scv_g, cck_g, ccv_g)

        x, (sck, scv, cck, ccv) = jax.lax.scan(
            group_body, x, (params["groups"], sck, scv, cck, ccv, xk, xv),
            unroll=cfg.scan_unroll,
        )
        state = {
            "self_kv": (sck.reshape(-1, *sck.shape[2:]), scv.reshape(-1, *scv.shape[2:])),
            "cross_self_kv": (cck, ccv),
            "cross_kv": (xk, xv),
        }
    else:
        raise ValueError(fam)

    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["unembed"].astype(x.dtype))
    return logits, state


def seed_decode_state(params, cfg: ModelConfig, state, memory):
    """Fill the precomputed cross-attention k/v from encoder/image memory.

    encdec: ``memory`` is the ENCODED frames (run the encoder + ln_enc first);
    vlm: ``memory`` is the patch-embedding stub input.
    """
    from . import attention as attn_mod

    if cfg.family == "encdec":
        ks, vs = jax.vmap(
            lambda p: attn_mod.project_memory_kv(p, memory)
        )(params["dec_blocks"]["xattn"])
        state = dict(state)
        state["cross_kv"] = (ks, vs)
        return state
    if cfg.family == "vlm":
        ks, vs = jax.vmap(
            lambda p: attn_mod.project_memory_kv(p, memory)
        )(params["groups"]["cross"]["xattn"])
        state = dict(state)
        state["cross_kv"] = (ks, vs)
        return state
    return state


def encode_memory(params, cfg: ModelConfig, frames):
    """Run the encoder stack (encdec prefill side): frames -> memory."""
    from .model import _enc_block, _scan_blocks  # self-import safe at runtime

    mem, _ = _scan_blocks(
        lambda p, h: _enc_block(p, h, cfg), params["enc_blocks"], frames,
        cfg.remat, cfg.scan_unroll,
    )
    return rms_norm(mem, params["ln_enc"], cfg.norm_eps)


def loss_fn(params, cfg: ModelConfig, batch, aux_weight: float = 0.01):
    """Next-token LM loss (+ MoE aux) — the train-step objective."""
    logits, aux = forward(params, cfg, batch)
    tokens = batch["tokens"]
    labels = jnp.concatenate([tokens[:, 1:], tokens[:, :1]], axis=1)
    mask = jnp.ones_like(labels, jnp.float32).at[:, -1].set(0.0)
    return cross_entropy_loss(logits, labels, mask) + aux_weight * aux
