"""Top-k routed mixture-of-experts FFN (granite-moe, qwen3-moe).

Implementation is the *sorted dispatch* scheme (grouped-GEMM style): flatten
(token, choice) pairs, sort by expert, keep up to ``capacity`` pairs per expert,
gather tokens into an (E, C, d) buffer, run the expert FFNs as one batched
einsum, and scatter-add gated outputs back.  FLOPs are the *active* count
(T·top_k·3·d·ff), unlike a dense-all-experts fallback.

Sharding: the (E, C, d) dispatch buffer and expert weights carry the 'expert'
logical axis -> 'model' mesh axis (EP) when E divides it; otherwise expert
weights shard their ff dim (TP fallback — granite's E=40 on a 16-way axis).
GSPMD materializes the dispatch/return traffic as all-to-alls over 'model'.

The router's top-k is exactly the paper's k-selection primitive (DESIGN.md §4):
``repro.kernels.topk_select`` implements it on TPU; here we use lax.top_k so the
roofline of the LM cells reflects the XLA path (the kernel is benchmarked
separately in benchmarks/kernels.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist import constrain

from .layers import init_linear

__all__ = ["init_moe", "moe_logical", "moe_ffn"]


def init_moe(key, d: int, ff: int, n_experts: int, dtype):
    ks = jax.random.split(key, 4)
    return {
        "router": init_linear(ks[0], d, n_experts, jnp.float32),
        "w_in": (
            jax.random.normal(ks[1], (n_experts, d, ff), jnp.float32) * d**-0.5
        ).astype(dtype),
        "w_gate": (
            jax.random.normal(ks[2], (n_experts, d, ff), jnp.float32) * d**-0.5
        ).astype(dtype),
        "w_out": (
            jax.random.normal(ks[3], (n_experts, ff, d), jnp.float32) * ff**-0.5
        ).astype(dtype),
    }


def moe_logical():
    return {
        "router": ("embed", None),
        "w_in": ("expert", "embed", "ff"),
        "w_gate": ("expert", "embed", "ff"),
        "w_out": ("expert", "ff", "embed"),
    }


def moe_ffn(params, x, *, n_experts: int, top_k: int, capacity_factor: float = 1.25):
    """x (B, S, d) -> (B, S, d) via top-k routed experts.

    Routing uses the *inverse-index* formulation (EXPERIMENTS.md §Perf, iteration
    Q2): per batch row we build ``inv_token[e*C+c] -> token`` and a matching
    per-slot gate.  Then

      * dispatch is a GATHER ``xd[e,c] = x[inv_token[e,c]]`` — each expert
        (model) shard gathers only its slots, locally;
      * combine is a SOURCE-DRIVEN scatter-add of gated expert outputs back to
        token positions — each shard contributes partial sums and GSPMD emits
        one small (B,S,d) all-reduce over 'model' instead of all-gathering the
        whole (B,E,C,d) buffer.

    Both directions' transposes (gather<->scatter-add) keep the same property
    in the backward pass.
    """
    b, s, d = x.shape
    logits = (
        x.reshape(-1, d).astype(jnp.float32) @ params["router"]
    ).astype(jnp.float32)  # (B*S, E) — kept flat for the aux loss
    probs = jax.nn.softmax(logits.reshape(b, s, n_experts), axis=-1)
    gate, expert = jax.lax.top_k(probs, top_k)  # (B, S, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    cap = int(max(1, round(s * top_k / n_experts * capacity_factor)))
    n_slots = n_experts * cap

    def route_row(expert_r, gate_r):
        """One batch row -> (inv_token (E*C,), gate_slot (E*C,)).

        Unfilled/dropped slots point at the dummy token index ``s`` (a zero row)
        with gate 0, so they contribute nothing in either direction.
        """
        flat_e = expert_r.reshape(-1).astype(jnp.int32)  # (S*k,)
        order = jnp.argsort(flat_e, stable=True)
        sorted_e = flat_e[order]
        token_of = (order // top_k).astype(jnp.int32)
        counts = jnp.sum(
            jax.nn.one_hot(flat_e, n_experts, dtype=jnp.int32), axis=0
        )  # (E,)
        starts = jnp.cumsum(counts) - counts
        pos_in_e = jnp.arange(s * top_k, dtype=jnp.int32) - starts[sorted_e]
        keep = pos_in_e < cap
        slot = jnp.where(keep, sorted_e * cap + pos_in_e, n_slots)  # drop bin
        gates_sorted = gate_r.reshape(-1)[order]
        inv_token = (
            jnp.full((n_slots + 1,), s, jnp.int32).at[slot].set(token_of)[:n_slots]
        )
        gate_slot = (
            jnp.zeros((n_slots + 1,), jnp.float32).at[slot].set(gates_sorted)[:n_slots]
        )
        return inv_token, gate_slot

    inv_token, gate_slot = jax.vmap(route_row)(expert, gate)  # (B, E*C) each
    x_pad = jnp.concatenate([x, jnp.zeros((b, 1, d), x.dtype)], axis=1)

    # shard the *indices* in the expert layout first: the gather output then
    # follows the index sharding and never materializes the full (E*C, d) buffer
    inv3 = constrain(
        inv_token.reshape(b, n_experts, cap), ("batch", "expert", "expert_cap")
    )
    gate3 = constrain(
        gate_slot.reshape(b, n_experts, cap), ("batch", "expert", "expert_cap")
    )

    # dispatch: expert-shard-local gather
    xd = jax.vmap(lambda xr, iv: xr[iv])(x_pad, inv3)  # (B, E, C, d)
    xd = constrain(xd, ("batch", "expert", "expert_cap", None))

    h = jnp.einsum("becd,edf->becf", xd, params["w_in"].astype(x.dtype))
    g = jnp.einsum("becd,edf->becf", xd, params["w_gate"].astype(x.dtype))
    h = jax.nn.silu(g) * h
    h = constrain(h, ("batch", "expert", "expert_cap", "ff"))
    y_e = jnp.einsum("becf,efd->becd", h, params["w_out"].astype(x.dtype))
    y_e = constrain(y_e, ("batch", "expert", "expert_cap", None))

    # combine: gated source-driven scatter-add (partial sums over 'model')
    def combine_row(y_er, iv, gs):
        contrib = y_er * gs[..., None].astype(y_er.dtype)  # (E, C, d)
        return jnp.zeros((s + 1, d), y_er.dtype).at[iv].add(contrib)[:s]

    y = jax.vmap(combine_row)(y_e, inv3, gate3)  # (B, S, d)
    y = constrain(y, ("batch", "seq", None))
    return y, logits
