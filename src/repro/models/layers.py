"""Shared primitive layers: norms, MLPs, embeddings, RoPE (pure functional).

Params are plain nested dicts of jnp arrays; every ``init_*`` has a matching
``*_logical`` returning the same tree with logical-axes tuples for sharding.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist import constrain

__all__ = [
    "rms_norm",
    "init_linear",
    "dense",
    "init_mlp",
    "mlp",
    "mlp_logical",
    "rope",
    "apply_rope",
    "cross_entropy_loss",
]


def _dtype(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32, "float16": jnp.float16}[
        name
    ]


def rms_norm(x, scale, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * scale.astype(jnp.float32)).astype(dt)


def init_linear(key, d_in: int, d_out, dtype, scale: float | None = None):
    shape = (d_in, d_out) if isinstance(d_out, int) else (d_in, *d_out)
    fan_in = d_in
    s = scale if scale is not None else fan_in**-0.5
    return (jax.random.normal(key, shape, jnp.float32) * s).astype(dtype)


def dense(x, w):
    """x @ w with f32 accumulation, preserving x dtype."""
    return jax.lax.dot_general(
        x,
        w.astype(x.dtype),
        (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).astype(x.dtype)


# ---------------------------------------------------------------- MLP


def init_mlp(key, d: int, ff: int, activation: str, dtype):
    ks = jax.random.split(key, 3)
    p = {
        "w_in": init_linear(ks[0], d, ff, dtype),
        "w_out": init_linear(ks[1], ff, d, dtype),
    }
    if activation == "swiglu":
        p["w_gate"] = init_linear(ks[2], d, ff, dtype)
    return p


def mlp_logical(activation: str):
    p = {"w_in": ("embed", "ff"), "w_out": ("ff", "embed")}
    if activation == "swiglu":
        p["w_gate"] = ("embed", "ff")
    return p


def mlp(params, x, activation: str):
    h = dense(x, params["w_in"])
    if activation == "swiglu":
        g = dense(x, params["w_gate"])
        h = jax.nn.silu(g) * h
    elif activation == "relu2":  # squared ReLU (nemotron / Primer)
        h = jnp.square(jax.nn.relu(h))
    else:
        h = jax.nn.gelu(h)
    h = constrain(h, ("batch", "act_seq", "ff"))
    return dense(h, params["w_out"])


# ---------------------------------------------------------------- RoPE


def rope(positions, d_head: int, theta: float, dtype=jnp.float32):
    """positions (...,) -> (cos, sin) of shape (..., d_head//2)."""
    half = d_head // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.cos(ang).astype(dtype), jnp.sin(ang).astype(dtype)


def apply_rope(x, cos, sin):
    """x: (B, S, H, dh); cos/sin: (B, S, dh//2) or (S, dh//2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if cos.ndim == 2:
        cos = cos[None, :, None, :]
        sin = sin[None, :, None, :]
    else:
        cos = cos[:, :, None, :]
        sin = sin[:, :, None, :]
    xr1 = x1 * cos - x2 * sin
    xr2 = x2 * cos + x1 * sin
    return jnp.concatenate([xr1, xr2], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------- loss


def cross_entropy_loss(logits, labels, mask=None):
    """Mean next-token cross entropy; logits (B, S, V) f32-cast internally."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if mask is not None:
        nll = nll * mask
        return nll.sum() / jnp.maximum(mask.sum(), 1)
    return nll.mean()
