"""ServiceSpec — the declarative description of one k-NN serving session.

A spec subsumes :class:`repro.core.ticks.EngineConfig` (algorithm + device
layout knobs) **and** the workload geometry (the squared region ``G`` the
paper's index partitions: ``origin`` + ``side``) that used to ride as loose
``TickEngine`` constructor arguments, plus the session-only staging knob
``delta_pad``.  It is frozen, hashable and eagerly validated: unknown
``backend``/``plan`` names and inconsistent sweep geometry raise at
construction time with the full registry listing, instead of surfacing as a
deep registry ``KeyError`` on the first tick.
"""
from __future__ import annotations

import dataclasses

from repro.core.ticks import EngineConfig, validate_engine_params

__all__ = ["ServiceSpec", "COLLECT_MODES"]

SIDE_DEFAULT = 22_500.0  # paper Table 1: squared region of side 22500 u

# what crosses the host boundary per tick (DESIGN.md §14):
#   "full"  — the (Q, k) neighbour lists + shard counters (the pre-§14 path);
#   "stats" — O(Q)/O(1) on-device aggregates only (TickAggregates);
#   "none"  — nothing beyond the two drift scalars the session already reads.
COLLECT_MODES = ("full", "stats", "none")


@dataclasses.dataclass(frozen=True)
class ServiceSpec:
    """Everything a :class:`repro.api.KnnSession` needs, declared up front.

    Algorithm / layout fields mirror ``EngineConfig`` one-to-one (same
    defaults); ``origin``/``side`` pin the region ``G`` of the quadtree;
    ``delta_pad`` rounds ``update_objects`` batches up to a fixed multiple
    (sentinel-padded, dropped by the scatter) so every delta size reuses one
    compiled scatter program.
    """

    k: int = 32
    th_quad: int = 192
    l_max: int = 8
    window: int = 256
    chunk: int = 8192
    rebuild_factor: float = 2.0
    region_pad: float = 1e-3
    backend: str = "dense_topk"
    plan: str = "single"
    # int for the 1-D plans (sharded / object_sharded), (query, object) pair
    # for hybrid, None = all devices (hybrid: most balanced factorization)
    mesh_shape: int | tuple[int, int] | None = None
    # work partitioner for the plan's split axes ("equal" | "cost_balanced";
    # repro.core.balance) — cost_balanced re-cuts shard boundaries every tick
    # from the count-pyramid seed + the session's measured-work EMA
    partitioner: str = "equal"
    # sweep numeric mode ("fp32" | "mixed"; repro.core.executor.PRECISIONS)
    # — mixed runs a bf16 widened-radius prefilter + exact fp32 refine,
    # bitwise-identical results (DESIGN.md §14)
    precision: str = "fp32"
    # MERGE backend for the object-axis reduce ("dense_merge" | "fused_multi";
    # repro.kernels.merge_backend_names())
    merge: str = "dense_merge"
    # index-maintenance policy ("rebuild" | "incremental";
    # repro.core.ticks.MAINTENANCE_MODES, DESIGN.md §15): "incremental"
    # refreshes the Morton order / pyramid with work proportional to the
    # delta batch (recode + sort + splice of the moved rows only), bitwise-
    # identical to the full per-tick "rebuild" refresh at every tick
    maintenance: str = "rebuild"
    # incremental only: moved-fraction of N (accumulated since the last full
    # refresh) at which the session defers to one full reindex
    churn_budget: float = 0.25
    max_iters: int = 100_000
    origin: tuple[float, float] = (0.0, 0.0)
    side: float = SIDE_DEFAULT
    delta_pad: int = 1024
    # per-tick result consumption mode (COLLECT_MODES; DESIGN.md §14):
    # "full" ships the (Q, k) lists to the host, "stats" ships only the
    # on-device aggregates, "none" ships nothing beyond the drift scalars
    collect: str = "full"

    def __post_init__(self):
        validate_engine_params(
            k=self.k, window=self.window, chunk=self.chunk,
            backend=self.backend, plan=self.plan, mesh_shape=self.mesh_shape,
            partitioner=self.partitioner, precision=self.precision,
            merge=self.merge, maintenance=self.maintenance,
            churn_budget=self.churn_budget,
        )
        if self.collect not in COLLECT_MODES:
            raise ValueError(
                f"unknown collect mode {self.collect!r}; one of {COLLECT_MODES}"
            )
        if self.side <= 0:
            raise ValueError(f"side must be > 0, got {self.side}")
        if len(self.origin) != 2:
            raise ValueError(f"origin must be an (x, y) pair, got {self.origin!r}")
        if self.delta_pad < 1:
            raise ValueError(f"delta_pad must be >= 1, got {self.delta_pad}")

    def engine_config(self) -> EngineConfig:
        """The EngineConfig subset of this spec (for core-layer consumers)."""
        return EngineConfig(
            k=self.k, th_quad=self.th_quad, l_max=self.l_max,
            window=self.window, chunk=self.chunk,
            rebuild_factor=self.rebuild_factor, region_pad=self.region_pad,
            backend=self.backend, plan=self.plan, mesh_shape=self.mesh_shape,
            partitioner=self.partitioner, precision=self.precision,
            merge=self.merge, maintenance=self.maintenance,
            churn_budget=self.churn_budget, max_iters=self.max_iters,
        )

    @classmethod
    def from_engine(
        cls,
        cfg: EngineConfig,
        *,
        origin: tuple[float, float] = (0.0, 0.0),
        side: float = SIDE_DEFAULT,
        delta_pad: int = 1024,
    ) -> "ServiceSpec":
        """Lift an EngineConfig (+ the old loose geometry args) into a spec."""
        return cls(
            k=cfg.k, th_quad=cfg.th_quad, l_max=cfg.l_max, window=cfg.window,
            chunk=cfg.chunk, rebuild_factor=cfg.rebuild_factor,
            region_pad=cfg.region_pad, backend=cfg.backend, plan=cfg.plan,
            mesh_shape=cfg.mesh_shape, partitioner=cfg.partitioner,
            precision=cfg.precision, merge=cfg.merge,
            maintenance=cfg.maintenance, churn_budget=cfg.churn_budget,
            max_iters=cfg.max_iters,
            origin=(float(origin[0]), float(origin[1])), side=float(side),
            delta_pad=delta_pad,
        )
