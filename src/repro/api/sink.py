"""ResultSink — on-device per-tick result consumers (DESIGN.md §14).

The steady-state serving bottleneck measured in BENCH_serving.json was never
the sweep: it was ``result()`` draining the dispatch queue and shipping the
``(Q, k)`` neighbour lists to the host every tick.  Most monitoring consumers
do not need the lists — they need *aggregates*: how much did each query's
k-th distance drift, how much did the neighbour sets churn, which object
shards served the hits.  A :class:`ResultSink` computes those aggregates in
a jitted device program that consumes ``(nn_idx, nn_dist)`` right where the
tick produced them, so under ``ServiceSpec(collect="stats")`` only O(Q)
scalars — and under ``collect="none"`` nothing beyond the two drift-policy
scalars the session already reads — ever cross the host boundary.

The sink update is dispatched by ``KnnSession.submit()`` immediately after
the tick step, *asynchronously* (no donation, same reasoning as
``_tick_step``): tick τ+1's host staging overlaps τ's aggregation exactly as
it overlaps τ's sweep.  Sink state (previous tick's neighbour ids + k-th
distances) is device-resident and carries the usual sentinel discipline:
``prev_kth = -1`` marks rows with no previous observation (first tick, or a
registry row-set change), for which drift reports 0 and churn reports 1.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["TickAggregates", "SinkState", "ResultSink", "StatsSink"]


class TickAggregates(NamedTuple):
    """O(Q)/O(1) per-tick aggregates, computed on device.

    ``kth_dist`` is padded to the registry batch (rows >= ``n_live`` are
    garbage — slice before use, as ``TickHandle.result`` does); every other
    field is already reduced over live rows only.
    """

    kth_dist: jnp.ndarray  # (Qp,) f32 — Euclidean k-th distance per query
    # (same units as nn_dist; the serve cache squares it at insert time)
    kth_drift_mean: jnp.ndarray  # () f32 — mean |kth - prev_kth|, live+finite
    kth_drift_max: jnp.ndarray  # () f32
    churn_mean: jnp.ndarray  # () f32 — mean fraction of new neighbour ids
    churn_max: jnp.ndarray  # () f32
    shard_hits: jnp.ndarray  # (R_o,) f32 — reported hits per object shard
    n_live: jnp.ndarray  # () i32 — live rows the reductions covered


class SinkState(NamedTuple):
    """Device-resident cross-tick sink memory (previous tick's results)."""

    prev_idx: jnp.ndarray  # (Qp, k) i32; -1 = no entry
    prev_kth: jnp.ndarray  # (Qp,) f32; -1 = row has no previous observation


def init_sink_state(qp: int, k: int) -> SinkState:
    return SinkState(
        prev_idx=jnp.full((qp, k), -1, jnp.int32),
        prev_kth=jnp.full((qp,), -1.0, jnp.float32),
    )


@partial(jax.jit, static_argnames=("num_shards", "use_bounds"))
def _stats_update(
    state: SinkState,
    nn_idx,
    nn_dist,
    index,
    bounds,
    n_live,
    *,
    num_shards: int,
    use_bounds: bool,
):
    """(state, R_tau) -> (state', TickAggregates), entirely on device.

    * **k-th drift** — |kth - prev_kth| over live rows where both are finite
      (under-full queries carry kth = inf; sentinel rows carry prev = -1).
    * **churn** — per live row, the fraction of current neighbour ids absent
      from the row's previous list (padding entries ``-1`` never match); 1.0
      for rows with no previous observation, 0.0 for empty result rows.
      The (Qp, k, k) id comparison is tiny next to the sweep (k² ≪ N).
    * **shard hits** — histogram of reported neighbour ids over their owning
      object shard under the SAME ownership rule delta routing uses
      (Morton rank // capacity, or the boundary intervals the tick actually
      used when ``use_bounds``); scatter-add with ``mode="drop"`` discards
      padding entries.
    """
    qp, k = nn_idx.shape
    live = jnp.arange(qp) < n_live
    valid = nn_idx >= 0

    kth = nn_dist[:, k - 1]
    has_prev = state.prev_kth >= 0.0
    drift_ok = live & has_prev & jnp.isfinite(kth) & jnp.isfinite(state.prev_kth)
    drift = jnp.where(drift_ok, jnp.abs(kth - state.prev_kth), 0.0)
    n_drift = jnp.maximum(drift_ok.sum(), 1)
    drift_mean = drift.sum() / n_drift.astype(jnp.float32)
    drift_max = drift.max(initial=0.0)

    # (Qp, k, k): does current entry j appear anywhere in the previous row?
    match = (nn_idx[:, :, None] == state.prev_idx[:, None, :]) & (
        state.prev_idx[:, None, :] >= 0
    )
    kept = (match.any(axis=2) & valid).sum(axis=1)
    n_valid = valid.sum(axis=1)
    churn_row = 1.0 - kept / jnp.maximum(n_valid, 1).astype(jnp.float32)
    churn_row = jnp.where(n_valid > 0, churn_row, 0.0)
    churn_row = jnp.where(has_prev, churn_row, 1.0)
    churn_live = jnp.where(live, churn_row, 0.0)
    churn_mean = churn_live.sum() / jnp.maximum(n_live, 1).astype(jnp.float32)
    churn_max = churn_live.max(initial=0.0)

    n = index.n_objects
    rank = (
        jnp.zeros((n,), jnp.int32)
        .at[index.ids]
        .set(jnp.arange(n, dtype=jnp.int32))
    )
    flat = nn_idx.reshape(-1)
    ok = (valid & live[:, None]).reshape(-1)
    r = rank[jnp.clip(flat, 0, max(n - 1, 0))]
    if use_bounds:
        owner = (jnp.searchsorted(bounds, r, side="right") - 1).astype(jnp.int32)
    else:
        cap = -(-n // num_shards)
        owner = r // cap
    owner = jnp.where(ok, owner, num_shards)  # out of range -> dropped
    shard_hits = (
        jnp.zeros((num_shards,), jnp.float32)
        .at[owner]
        .add(1.0, mode="drop")
    )

    new_state = SinkState(
        prev_idx=jnp.where(live[:, None], nn_idx, -1).astype(jnp.int32),
        prev_kth=jnp.where(live, kth, -1.0),
    )
    agg = TickAggregates(
        kth_dist=kth,
        kth_drift_mean=drift_mean,
        kth_drift_max=drift_max,
        churn_mean=churn_mean,
        churn_max=churn_max,
        shard_hits=shard_hits,
        n_live=jnp.asarray(n_live, jnp.int32),
    )
    return new_state, agg


class ResultSink:
    """Interface: a jitted per-tick consumer of device-resident results.

    ``init(qp, k)`` returns the device-resident cross-tick state;
    ``update(state, nn_idx, nn_dist, index, bounds, n_live)`` consumes one
    tick's padded ``(Qp, k)`` outputs and returns ``(state', aggregates)``
    — both device-resident, dispatched asynchronously.  Implementations
    must not force a host sync (no ``float()``/``np.asarray`` inside).
    """

    def init(self, qp: int, k: int):
        raise NotImplementedError

    def update(self, state, nn_idx, nn_dist, index, bounds, n_live):
        raise NotImplementedError


class StatsSink(ResultSink):
    """The default ``collect="stats"`` sink: drift + churn + shard hits."""

    def __init__(self, num_obj_shards: int = 1):
        self.num_obj_shards = max(1, int(num_obj_shards))

    def init(self, qp: int, k: int) -> SinkState:
        return init_sink_state(qp, k)

    def update(self, state, nn_idx, nn_dist, index, bounds, n_live):
        use_bounds = bounds is not None
        return _stats_update(
            state, nn_idx, nn_dist, index,
            bounds if use_bounds else jnp.zeros((1,), jnp.int32),
            n_live,
            num_shards=self.num_obj_shards,
            use_bounds=use_bounds,
        )
