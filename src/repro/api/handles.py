"""Handles — the session API's stable references to queries and in-flight ticks.

:class:`QueryHandle` names a group of registered queries; it stays valid
across ticks (and across registry compaction after drops) until the group is
dropped.  :class:`TickHandle` names one submitted tick: ``submit()`` returns
it immediately after dispatch, and ``result()`` materializes the ``(Q, k)``
result batch lazily — so tick τ+1 can be staged and submitted while τ's
results are still computing/transferring (the paper's CPU/GPU pipeline
overlap, DESIGN.md §11).

Host collection is ONE batched transfer: ``result()`` pulls ``nn_idx``,
``nn_dist`` and the per-shard counters through a single ``jax.device_get``
instead of separate blocking ``np.asarray`` syncs (each sync pays the full
dispatch-queue drain; batching them collapsed the dominant steady-tick host
cost measured in BENCH_serving.json).  Pipelines that consume results
on-device skip the transfer entirely with ``result(materialize=False)``.

What ``result()`` fetches is the spec's ``collect`` mode (DESIGN.md §14):
``"full"`` ships the ``(Q, k)`` lists as above; ``"stats"`` ships only the
sink's O(Q)/O(1) :class:`~repro.api.sink.TickAggregates` (``nn_idx``/
``nn_dist`` come back ``None``); ``"none"`` ships nothing at all — the
finalize scalars the session already read are the whole host footprint.
``TickResult.collect_s`` records the transfer time each mode actually paid,
attributed to the tick whose ``result()`` materialized it (NOT the tick
whose ``submit()`` happened to overlap it), so BENCH host-collect columns
stay honest under overlapped submission.  ``result()`` first drains the
device computation (``block_until_ready``) *outside* the timed window, so
``collect_s`` is pure host materialization cost — on a CPU host, where
device compute shares the cores, folding the compute drain into the collect
column is exactly the conflation the column used to suffer from.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import numpy as np

from repro.core.ticks import TickResult

__all__ = ["QueryHandle", "TickHandle"]


@dataclasses.dataclass(frozen=True)
class QueryHandle:
    """Stable reference to a registered query group (``count`` rows)."""

    hid: int
    count: int


class TickHandle:
    """One in-flight tick: dispatched device work + lazy host materialization.

    The handle owns references to the tick's device-side outputs.  The big
    ``(Q, k)`` result arrays stay on device until :meth:`result` is called;
    the tiny bookkeeping scalars (candidate counter, rebuild trigger) are
    read by the session when the tick is *finalized* — at the earlier of
    ``result()`` and the next ``submit()`` — so drift rebuilds apply in tick
    order even when results are collected late or out of order.
    """

    def __init__(
        self,
        session,
        tick: int,
        nn_idx,
        nn_dist,
        aux,
        should_rebuild,
        nq: int,
        qids: np.ndarray,
        owner: np.ndarray,
        t0: float,
        submit_s: float,
        compile_s: float,
        rebuilt_pre: bool,
        collect: str = "full",
        agg=None,
        maintenance: str = "rebuild",
    ):
        self._session = session
        self.tick = tick
        self._nn_idx = nn_idx
        self._nn_dist = nn_dist
        self._aux = aux
        self._should_rebuild = should_rebuild
        self._collect = collect
        self._agg = agg  # device-resident TickAggregates (collect="stats")
        self._nq = nq
        self._qids = qids
        self._owner = owner
        self._t0 = t0
        self.submit_s = submit_s
        self.compile_s = compile_s
        self._rebuilt_pre = rebuilt_pre
        # how the step maintained the index this tick ("rebuild" |
        # "incremental" | "skip") — the session's scheduling decision,
        # recorded for TickResult.maintenance
        self._maintenance = maintenance
        # set by the session at finalize time
        self._finalized = False
        self._rebuilt_post = False
        self._work: float | None = None
        self._iterations: int | None = None
        self._result: TickResult | None = None
        self._result_dev: TickResult | None = None

    @property
    def finalized(self) -> bool:
        """Has this tick's drift bookkeeping landed (finalize or result)?

        Public read-only view for layers above the session (the server's
        epoch/cache observation) — once True, :attr:`rebuilt_post` is
        settled and will not change.
        """
        return self._finalized or self._result is not None

    @property
    def rebuilt_post(self) -> bool:
        """Did the drift check of THIS tick trigger a rebuild after it ran?

        Meaningful once :attr:`finalized` is True (False until then).  A
        post-rebuild re-sorts the same positions the tick already answered
        under — results stay bit-correct; it is scheduling bookkeeping, not
        a world change.
        """
        return self._rebuilt_post

    def done(self) -> bool:
        """Non-blocking: have this tick's result arrays materialized?"""
        if self._result is not None:
            return True
        try:
            return bool(self._nn_idx.is_ready() and self._nn_dist.is_ready())
        except AttributeError:  # older jax without Array.is_ready
            return False

    def block_until_ready(self) -> "TickHandle":
        """Block until this tick's device outputs are computed — NO transfer.

        The wait is device-compute drain, not host collection: callers that
        want the two costs separated (benchmarks, latency-sensitive serving
        loops) call this first, then ``result()``, whose ``collect_s`` then
        times only the materialization.  Idempotent; a no-op once the tick
        has materialized.
        """
        if self._result is None:
            payload = [a for a in (self._nn_idx, self._nn_dist, self._agg)
                       if a is not None]
            if payload:
                jax.block_until_ready(payload)
        return self

    def _tick_result(self, nn_idx, nn_dist, shard_cand, shard_it,
                     collect_s: float = 0.0, aggregates=None) -> TickResult:
        return TickResult(
            tick=self.tick,
            nn_idx=nn_idx,
            nn_dist=nn_dist,
            rebuilt=self._rebuilt_pre or self._rebuilt_post,
            wall_s=time.perf_counter() - self._t0 - self.compile_s,
            candidates=self._work,
            iterations=self._iterations,
            compile_s=self.compile_s,
            qids=self._qids,
            shard_candidates=shard_cand,
            shard_iterations=shard_it,
            collect_s=collect_s,
            aggregates=aggregates,
            maintenance=self._maintenance,
        )

    def result(self, materialize: bool = True) -> TickResult:
        """Block until this tick's results are available (idempotent).

        Finalizes every earlier in-flight tick first (in submit order), so
        rebuild bookkeeping is independent of the order in which callers
        collect results.

        What crosses the host boundary is the spec's ``collect`` mode:
        ``"full"`` materializes the ``(Q, k)`` lists + shard counters in ONE
        batched ``jax.device_get``; ``"stats"`` fetches only the sink
        aggregates + shard counters (``nn_idx``/``nn_dist`` = ``None``);
        ``"none"`` fetches nothing — every host-facing field beyond the
        finalize bookkeeping is ``None``.  ``TickResult.collect_s`` is the
        time THIS call spent in the blocking transfer — the tick that
        materializes pays it, not the tick whose submit it overlapped.

        ``materialize=False`` hands back a :class:`TickResult` whose
        ``nn_idx``/``nn_dist``/``shard_*``/``aggregates`` fields are
        **device arrays** (sliced views of the tick's outputs) — for
        pipelines that consume results on-device, where a host round-trip
        per tick would throw away the submit/result overlap.  The arrays
        stay valid while later ticks submit and even across a drift rebuild
        (nothing donates or overwrites them — pinned by tests/test_api.py).
        It does not release the device buffers; a later ``result()`` still
        materializes and releases them.
        """
        if self._result is not None:
            return self._result
        self._session._finalize_through(self)
        nq = self._nq
        if not materialize:
            if self._result_dev is None:
                self._result_dev = self._tick_result(
                    self._nn_idx[:nq], self._nn_dist[:nq],
                    self._aux.shard_candidates, self._aux.shard_iterations,
                    aggregates=self._agg,
                )
            return self._result_dev
        if self._collect == "none":
            # nothing to transfer: the finalize scalars the session already
            # read are this mode's whole host footprint
            self._result = self._tick_result(None, None, None, None)
        elif self._collect == "stats":
            # drain compute OUTSIDE the timed window: collect_s is the pure
            # materialization cost, not the device queue
            self.block_until_ready()
            tc = time.perf_counter()
            agg, shard_cand, shard_it = jax.device_get(
                (self._agg, self._aux.shard_candidates,
                 self._aux.shard_iterations)
            )
            self._result = self._tick_result(
                None, None, shard_cand, shard_it,
                collect_s=time.perf_counter() - tc, aggregates=agg,
            )
        else:
            # ONE batched host transfer for everything the result carries,
            # timed after the compute drain (same decomposition as "stats")
            self.block_until_ready()
            tc = time.perf_counter()
            nn_idx, nn_dist, shard_cand, shard_it = jax.device_get(
                (self._nn_idx[:nq], self._nn_dist[:nq],
                 self._aux.shard_candidates, self._aux.shard_iterations)
            )
            self._result = self._tick_result(
                nn_idx, nn_dist, shard_cand, shard_it,
                collect_s=time.perf_counter() - tc,
            )
        # release device references so XLA can recycle the buffers
        self._nn_idx = self._nn_dist = self._aux = self._should_rebuild = None
        self._agg = None
        self._result_dev = None
        return self._result

    def result_for(self, handle: QueryHandle):
        """This tick's rows for one query group: (nn_idx, nn_dist, qids).

        Rows are selected by the registry ownership snapshot taken at submit
        time, so the mapping stays correct even if the group is updated or
        dropped after this tick was submitted.  Under ``collect != "full"``
        the host never receives the lists, so the rows come back as sliced
        **device arrays** (via ``result(materialize=False)``).
        """
        if self._collect == "full":
            res = self.result()
        else:
            res = self.result(materialize=False)
        if res.nn_idx is None:
            raise RuntimeError(
                f"result_for after result() under collect={self._collect!r}: "
                "the neighbour lists were never transferred and their device "
                "buffers are released; call result_for (or "
                "result(materialize=False)) before materializing"
            )
        rows = np.nonzero(self._owner == handle.hid)[0]
        return res.nn_idx[rows], res.nn_dist[rows], res.qids[rows]
