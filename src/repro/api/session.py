"""KnnSession — the session-oriented serving facade (DESIGN.md §11).

The paper's workload is *repeated* k-NN queries: queries persist across ticks
while object positions stream in as updates, and throughput comes from
overlapping CPU-side staging with device-side query processing.  A session
speaks exactly that language:

* **Persistent queries** — ``register_queries`` / ``update_queries`` /
  ``drop_queries`` maintain a device-resident *padded query registry* with
  stable :class:`~repro.api.handles.QueryHandle` groups.  The padded device
  batch is (re)staged only when the registry changes; unchanged query sets
  ride across ticks with zero host work (``set_queries`` is the bulk
  snapshot fallback used by the ``TickEngine`` shim).
* **Delta object updates** — ``update_objects(ids, positions)`` scatters
  moved objects into the device-resident positions buffer
  (:func:`repro.core.ticks.scatter_positions`; functional, so an in-flight
  tick keeps reading the previous buffer — double-buffering);
  ``ingest_objects`` keeps the full-snapshot upload as the fallback path.
  Under the object-sharded plans (DESIGN.md §12) the batch is grouped by
  owning shard, device-side — Morton rank // ``ceil(N/R)``, re-derived from
  the live index (``object_shards`` / ``core.ticks.route_delta``) — staging
  the contiguous-run layout a per-shard-resident buffer scatters directly.
* **Overlapped ticks** — ``submit()`` stages + dispatches one tick and
  returns a :class:`~repro.api.handles.TickHandle` immediately; ``result()``
  materializes lazily.  Submitting tick τ+1 while τ's ``(Q, k)`` results are
  still in flight double-buffers host staging against device compute, the
  paper's pipeline.  Drift-rebuild bookkeeping is *finalized* per tick at
  the earlier of ``result(τ)`` and ``submit(τ+1)``, reading back only two
  scalars — so the decision sequence is identical to the blocking loop and
  the session is bit-identical to the snapshot ``TickEngine`` path (pinned
  by tests/test_api.py).

The execution core is unchanged: every tick is still the ONE jitted device
program :func:`repro.core.ticks._tick_step` (reindex + the plan's chunked
sweep + drift statistic), specialized per (backend, plan) and dispatching
asynchronously (no buffer donation — donated dispatch is host-synchronous
on this runtime; see the step's docstring).
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque

import jax.numpy as jnp
import numpy as np

from repro.core.executor import resolve_executor
from repro.core.pipeline import default_max_nav
from repro.core.plan import pad_capacity, pad_queries, resolve_plan
from repro.core.quadtree import build_index, rebuild_zmap, reindex_objects_delta
from repro.core.ticks import (
    _tick_step,
    object_shard_of,
    route_delta,
    scatter_positions,
    shard_churn_over_budget,
)

from .handles import QueryHandle, TickHandle
from .sink import StatsSink
from .spec import ServiceSpec

__all__ = ["KnnSession"]

# compile_s attribution must mirror the PROCESS-global jit cache of
# _tick_step, not per-session state: a second session with identical shapes
# and statics hits the warm cache and must report compile_s = 0.
_COMPILED_KEYS: set = set()


class _QueryRegistry:
    """Host mirror + cached padded device staging of the live query set.

    Rows are kept contiguous (drops compact); padding rows clone the last
    active query with qid = -2 — the exact :func:`repro.core.plan.pad_queries`
    convention of the snapshot path, which is what makes session results
    bit-identical to ``TickEngine``'s.  ``owner`` maps each row to the
    :class:`QueryHandle` that registered it (-1 for bulk ``set_queries``
    rows); handles survive compaction because membership is by owner id,
    not by row position.
    """

    def __init__(self, multiple: int):
        self.multiple = multiple  # plan padding granularity (pad_multiple(chunk))
        self.qpos = np.zeros((0, 2), np.float32)
        self.qid = np.zeros((0,), np.int32)
        self.owner = np.zeros((0,), np.int64)
        self._next_hid = 0
        self._live: set[int] = set()
        self._dirty = True
        self._staged = None
        # True whenever the ROW SET changed (add/drop/replace — not moves):
        # the session's per-query cost EMA is row-aligned and must reset;
        # position-only updates keep it (the repeated-query assumption)
        self.rows_changed = True

    @property
    def nq(self) -> int:
        return int(self.qpos.shape[0])

    def _coerce(self, qpos, qid):
        qpos = np.asarray(qpos, np.float32).reshape(-1, 2)
        m = qpos.shape[0]
        if qid is None:
            qid = np.full((m,), -2, np.int32)
        else:
            qid = np.asarray(qid, np.int32).reshape(-1)
            if qid.shape[0] != m:
                raise ValueError(
                    f"qid has {qid.shape[0]} rows but qpos has {m}"
                )
        return qpos, qid

    def register(self, qpos, qid=None) -> QueryHandle:
        qpos, qid = self._coerce(qpos, qid)
        if qpos.shape[0] == 0:
            raise ValueError("cannot register an empty query group")
        hid = self._next_hid
        self._next_hid += 1
        self.qpos = np.concatenate([self.qpos, qpos])
        self.qid = np.concatenate([self.qid, qid])
        self.owner = np.concatenate(
            [self.owner, np.full((qpos.shape[0],), hid, np.int64)]
        )
        self._live.add(hid)
        self._dirty = True
        self.rows_changed = True
        return QueryHandle(hid=hid, count=qpos.shape[0])

    def _check(self, handle: QueryHandle):
        if handle.hid not in self._live:
            raise KeyError(
                f"{handle} is not live in this registry (already dropped, "
                "or invalidated by set_queries)"
            )

    def rows(self, handle: QueryHandle) -> np.ndarray:
        self._check(handle)
        return np.nonzero(self.owner == handle.hid)[0]

    def update(self, handle: QueryHandle, qpos):
        rows = self.rows(handle)
        qpos = np.asarray(qpos, np.float32).reshape(-1, 2)
        if qpos.shape[0] != rows.shape[0]:
            raise ValueError(
                f"update_queries: {handle} owns {rows.shape[0]} rows, "
                f"got {qpos.shape[0]} positions"
            )
        self.qpos[rows] = qpos
        self._dirty = True

    def drop(self, handle: QueryHandle):
        rows = self.rows(handle)
        keep = np.ones(self.nq, bool)
        keep[rows] = False
        self.qpos = self.qpos[keep]
        self.qid = self.qid[keep]
        self.owner = self.owner[keep]
        self._live.discard(handle.hid)
        self._dirty = True
        self.rows_changed = True

    def replace_all(self, qpos, qid=None):
        """Bulk snapshot staging: replaces every row, invalidates all handles."""
        qpos, qid = self._coerce(qpos, qid)
        self.qpos = qpos.copy()
        self.qid = qid.copy()
        self.owner = np.full((qpos.shape[0],), -1, np.int64)
        self._live = set()
        self._dirty = True
        self.rows_changed = True

    def staged(self):
        """(qpos_dev, qid_dev, nq, qids, owner) — padded, device-resident.

        Cached until the registry changes: steady-state ticks with a stable
        query set re-submit the SAME device arrays, no host pad/upload.
        """
        if self._dirty or self._staged is None:
            qpos_p, qid_p = pad_queries(self.qpos, self.qid, self.multiple)
            self._staged = (
                jnp.asarray(qpos_p, jnp.float32),
                jnp.asarray(qid_p, jnp.int32),
                self.nq,
                self.qid.copy(),
                self.owner.copy(),
            )
            self._dirty = False
        return self._staged


class KnnSession:
    """A live serving session: device-resident object + query state, ticked.

    Construct from a :class:`~repro.api.spec.ServiceSpec`, seed object state
    with ``ingest_objects`` (snapshot) and queries with ``register_queries``,
    then per tick: push motion (``update_objects`` deltas or a fresh
    snapshot), optionally move queries, and ``submit()``.  See the module
    docstring for the overlap contract.
    """

    def __init__(self, spec: ServiceSpec):
        self.spec = spec
        self.executor = resolve_executor(spec.backend, spec.precision)
        self.plan = resolve_plan(
            spec.plan, num_devices=spec.mesh_shape,
            partitioner=spec.partitioner, merge=spec.merge,
        )
        self._registry = _QueryRegistry(self.plan.pad_multiple(spec.chunk))
        self._positions = None  # (N, 2) f32, device-resident, by object id
        self._index = None
        self._work_at_build: float | None = None
        self._tick = 0
        self._pending: deque[TickHandle] = deque()
        # per-query cost EMA, device-resident, row-aligned with the padded
        # registry batch: persists across ticks AND drift rebuilds (queries
        # are the stable entities of the repeated-query workload); reset
        # whenever the registry's row set changes (DESIGN.md §13)
        self._qcost = None
        # object-axis boundaries the LAST submitted tick actually used
        # (PlanAux.object_bounds, device-resident): delta routing and
        # object_shards follow the live partition under cost_balanced;
        # cleared on drift rebuild (the Morton ranks it indexes change)
        self._obj_bounds = None
        # optional per-query fairness weights on the boundary-seeding cost
        # (set_query_cost_weights; the serving layer's tenant fair share,
        # DESIGN.md §16) — host mirror + a cached padded device staging
        self._qweight_host: np.ndarray | None = None
        self._qweight_ver = 0
        self._qweight_staged = None  # (ver, padded_len, device array)
        # on-device result consumer (DESIGN.md §14): under collect="stats"
        # submit() feeds each tick's padded (Qp, k) outputs straight into the
        # jitted sink update — asynchronously, right behind the tick step —
        # and only the O(Q) aggregates ever reach the host
        self._sink = (
            StatsSink(self.plan.object_axis_size)
            if spec.collect == "stats" else None
        )
        self._sink_state = None
        # --- index-maintenance bookkeeping (DESIGN.md §15) ---
        # True iff the positions buffer changed since the index was last
        # refreshed from it; a clean buffer makes the reindex a semantic
        # no-op (reindex is a pure function of the buffer), so the step can
        # statically skip it — the dirty-flag fast path
        self._positions_dirty = True
        # union of object ids moved since the last refresh, sorted unique
        # (delta batches are deduped); None = "unknown delta" — a snapshot
        # ingest replaced the whole buffer, only a full refresh is safe
        self._pending_ids: np.ndarray | None = None
        # device-side batches of pre-update positions (gathered just before
        # each delta scatter) plus, per pending id, the row of its FIRST
        # touch inside their concatenation: the incremental reindex needs
        # each moved object's position as of the last refresh to re-derive
        # (and binary-search) its old sort key — kept on device, assembled
        # by one gather at submit, so update_objects stays fully async
        self._pending_old_batches: list = []
        self._pending_old_rows = 0
        self._pending_src: np.ndarray | None = None

    # ------------------------------------------------------------ state views
    @property
    def tick(self) -> int:
        """Ticks submitted so far (the next submit gets this tick number)."""
        return self._tick

    @property
    def index(self):
        return self._index

    @property
    def num_objects(self) -> int:
        return 0 if self._positions is None else int(self._positions.shape[0])

    @property
    def query_count(self) -> int:
        return self._registry.nq

    # ------------------------------------------------------------ object state
    def ingest_objects(self, positions):
        """Full-snapshot ingest (fallback path): replace all object positions.

        ``positions`` is (N, 2), indexed by object id.  The first ingest (or
        any later one) does NOT rebuild the space partition by itself — the
        partition is built lazily at the first ``submit()`` and thereafter
        only on the drift trigger, exactly like the snapshot engine.
        """
        positions = np.asarray(positions, np.float32)
        if positions.ndim != 2 or positions.shape[1] != 2:
            raise ValueError(f"positions must be (N, 2), got {positions.shape}")
        self._positions = jnp.asarray(positions, jnp.float32)
        # whole buffer replaced, delta unknown: only a full refresh is safe
        self._positions_dirty = True
        self._pending_ids = None
        self._pending_old_batches = []
        self._pending_old_rows = 0
        self._pending_src = None

    def update_objects(self, ids, positions):
        """Delta ingest: scatter ``positions[i]`` to object ``ids[i]`` on device.

        Steady-state motion costs one O(m) staging + device scatter — the
        (N, 2) buffer never re-crosses the host boundary.  Batches are
        padded to ``spec.delta_pad`` rows with the out-of-range sentinel id
        ``N`` (dropped by the scatter) so every delta size shares one
        compiled program; duplicate ids within a batch resolve deterministically
        to the last observation.
        """
        if self._positions is None:
            raise RuntimeError("update_objects before ingest_objects: the "
                               "session has no object state to update")
        ids = np.asarray(ids, np.int32).reshape(-1)
        positions = np.asarray(positions, np.float32).reshape(-1, 2)
        if ids.shape[0] != positions.shape[0]:
            raise ValueError(
                f"update_objects: {ids.shape[0]} ids vs "
                f"{positions.shape[0]} positions"
            )
        m = ids.shape[0]
        if m == 0:
            return
        n = self.num_objects
        if (ids < 0).any() or (ids >= n).any():
            bad = ids[(ids < 0) | (ids >= n)]
            raise ValueError(
                f"update_objects: ids out of range [0, {n}): {bad[:8]}"
            )
        uniq = np.unique(ids)
        if uniq.shape[0] != m:
            # several observations for one object in one batch: keep the LAST
            # (deterministic feed semantics — jnp scatter with repeated
            # indices applies them in unspecified order, which would break
            # the delta ≡ snapshot bit-identity contract)
            _, last_rev = np.unique(ids[::-1], return_index=True)
            keep = np.sort((m - 1) - last_rev)
            ids, positions = ids[keep], positions[keep]
            m = ids.shape[0]
        pad = pad_capacity(m, self.spec.delta_pad) - m
        if pad:
            ids = np.concatenate([ids, np.full((pad,), n, np.int32)])
            positions = np.concatenate(
                [positions, np.zeros((pad, 2), np.float32)]
            )
        ids_dev, pos_dev = jnp.asarray(ids), jnp.asarray(positions)
        tracking = not (self._positions_dirty and self._pending_ids is None)
        if tracking:
            # positions BEFORE this batch's scatter, in the host-known
            # (deduped, padded) id order — an id's first touch since the
            # last refresh reads its as-of-refresh position, which is what
            # the incremental reindex needs to locate its old sort key.
            # Padding rows gather a clamped garbage row, never consumed.
            old_batch = self._positions[ids_dev]
        if self.plan.object_axis_size > 1 and self._index is not None:
            # object-sharded plans: group the batch by owning shard (the
            # Morton-rank rule, DESIGN.md §12; under cost_balanced, the
            # boundary intervals the last tick used — §13) — entirely
            # device-side (core/ticks.py::route_delta), so staging stays
            # async.  A pure reordering of now-unique ids: the scattered
            # buffer, and hence every result, is bit-identical (pinned by
            # the routing-edge regressions in tests/test_api.py).
            ids_dev, pos_dev = route_delta(
                self._index, ids_dev, pos_dev, self.plan.object_axis_size,
                self._obj_bounds,
            )
        self._positions = scatter_positions(self._positions, ids_dev, pos_dev)
        # accumulate the delta set for the maintenance decision at submit:
        # `ids` is unique by now (padding rows are >= n and excluded); union
        # because the SAME object moving twice between submits is one moved
        # row from the index's point of view
        moved = ids[:m]
        if tracking:
            self._pending_old_batches.append(old_batch)
            src_batch = self._pending_old_rows + np.arange(m, dtype=np.int64)
            self._pending_old_rows += int(ids.shape[0])
            if self._pending_ids is None:
                order = np.argsort(moved)
                self._pending_ids = moved[order]
                self._pending_src = src_batch[order]
            else:
                # first touch wins for the old position (it is the one taken
                # against the last refresh); the id set is a union because
                # the same object moving twice is one moved row to the index
                fresh = ~np.isin(moved, self._pending_ids)
                merged = np.union1d(self._pending_ids, moved)
                src = np.empty(merged.size, np.int64)
                src[np.searchsorted(merged, self._pending_ids)] = (
                    self._pending_src
                )
                src[np.searchsorted(merged, moved[fresh])] = src_batch[fresh]
                self._pending_ids, self._pending_src = merged, src
        # else: unknown delta (snapshot since last refresh) stays unknown
        self._positions_dirty = True

    def object_shards(self, ids) -> np.ndarray:
        """Owning object shard per object id under the live plan + index.

        Evaluates the shard-ownership rule (DESIGN.md §12: Morton rank //
        ``ceil(N / R)``; under ``cost_balanced``, §13: the boundary interval
        containing the rank) against the *current* index — objects change
        owner as they move through the Morton order, so the answer is only
        valid until the next tick's reindex.  Plans without an object axis
        own everything on shard 0.  Requires a built index (the rule is
        defined by the index's Morton order): before the first submit the
        partition does not exist yet.

        Any still-pending tick is **finalized first** (blocking on its two
        bookkeeping scalars): a pending tick may carry a drift-rebuild
        decision, and answering from the pre-rebuild Morton order would
        silently route the caller's next updates to shards the rebuilt
        partition no longer owns (the rebuild-then-route regression,
        tests/test_api.py).
        """
        ids = np.asarray(ids, np.int32).reshape(-1)
        r = self.plan.object_axis_size
        if r == 1:
            return np.zeros(ids.shape, np.int32)
        if self._index is None:
            raise RuntimeError(
                "object_shards before the first submit: the index (and with "
                "it the Morton shard ownership) is built lazily at submit()"
            )
        # apply any pending drift-rebuild decision BEFORE reading ownership,
        # then recompute from whatever index is live afterwards
        self._finalize_through()
        n = self._index.n_objects
        if ids.size and ((ids < 0).any() or (ids >= n).any()):
            # jnp's clamping gather would return confidently wrong owners
            # for ids the (possibly stale) index has never seen
            bad = ids[(ids < 0) | (ids >= n)]
            raise ValueError(
                f"object_shards: ids outside the live index's [0, {n}): "
                f"{bad[:8]}"
            )
        return np.asarray(
            object_shard_of(self._index, ids, r, self._obj_bounds)
        )

    # ------------------------------------------------------------ query state
    def register_queries(self, qpos, qid=None) -> QueryHandle:
        """Add a persistent query group; returns its stable handle.

        ``qid`` is the issuing object id per query (excluded from its own
        result list); default -2 = no exclusion, matching
        ``knn_query_batch_chunked``.
        """
        return self._registry.register(qpos, qid)

    def update_queries(self, handle: QueryHandle, qpos):
        """Move a registered group: same row count, new positions.

        Any registry change currently restages the whole padded batch on the
        next submit (host pad + upload, O(total registry rows)); the zero-
        host-work steady state holds for query sets that don't move.  A
        device-side qpos scatter (mirroring ``update_objects``) is the
        prepared next step — it must also maintain the padding rows, which
        clone the last active query for snapshot-path bit-identity.
        """
        self._registry.update(handle, qpos)

    def drop_queries(self, handle: QueryHandle):
        """Remove a group; its rows stop being served from the next submit."""
        self._registry.drop(handle)

    def set_queries(self, qpos, qid=None):
        """Bulk snapshot staging of the whole query set (the shim's path).

        Replaces the registry contents and invalidates all handles; prefer
        ``register_queries`` + ``update_queries`` for persistent sets.
        """
        self._registry.replace_all(qpos, qid)

    def set_query_cost_weights(self, weights):
        """Per-query multipliers on the boundary-seeding cost (or None).

        ``weights`` is (query_count,) f32, aligned with the registry's
        current row order; the serving layer sets the tenant-fair weights
        here (``core.balance.tenant_fair_weights``) so no tenant's query
        volume buys it outsized influence on the cost-balanced shard
        boundaries.  Weights scale the boundary seed ONLY — boundaries move
        shard ownership, never results (DESIGN.md §13), so this cannot
        change bits on any plan.  Pass None to clear.  Weights must be
        re-set after any registry row-set change (validated at submit).
        """
        if weights is None:
            self._qweight_host = None
        else:
            w = np.asarray(weights, np.float32).reshape(-1)
            if w.shape[0] != self._registry.nq:
                raise ValueError(
                    f"set_query_cost_weights: {w.shape[0]} weights for a "
                    f"{self._registry.nq}-row registry"
                )
            if w.size and not (np.isfinite(w).all() and (w > 0).all()):
                raise ValueError(
                    "set_query_cost_weights: weights must be finite and > 0"
                )
            self._qweight_host = w.copy()
        self._qweight_ver += 1
        self._qweight_staged = None

    # ------------------------------------------------------------ serving
    def _assemble_delta(self):
        """Padded (delta_ids, delta_old_pos) device arrays for the pending set.

        ``delta_ids`` is the sorted-unique pending union padded to the
        ``delta_pad`` granularity with the sentinel id N; ``delta_old_pos``
        gathers each id's as-of-refresh position (first touch wins) out of
        the captured pre-scatter batches — one device-side gather, async.
        Requires ``self._pending_ids`` to be a known (non-None) delta.
        """
        n = self.num_objects
        m = self._pending_ids.size
        pad = pad_capacity(max(m, 1), self.spec.delta_pad) - m
        delta_ids_dev = jnp.asarray(np.concatenate(
            [self._pending_ids, np.full((pad,), n, np.int32)]
        ))
        sel = np.concatenate(
            [self._pending_src, np.zeros((pad,), np.int64)]
        ).astype(np.int32)
        batches = self._pending_old_batches
        cat = batches[0] if len(batches) == 1 else jnp.concatenate(batches)
        return delta_ids_dev, cat[jnp.asarray(sel)]

    def _build(self):
        """(Re)build the space partition from the current device positions.

        Three routes to the same bits (the stage-(i) reuse rule, DESIGN.md
        §15).  The drift policy only needs the leaf partition (z_map)
        re-decided; the sorted order, pyramid and offsets are pure functions
        of the positions buffer that the maintenance paths may already hold:

        * buffer CLEAN (index refreshed from this very buffer): everything
          but ``leaf_level`` is already what ``build_index`` would produce —
          ``rebuild_zmap`` replaces the O(N log N) re-sort with one
          O(4**l_max) leaf-level pass;
        * buffer dirty with a known in-budget delta under an incremental
          spec: splice the pending rows into the order
          (``reindex_objects_delta``), then re-derive the leaf partition
          from the spliced pyramid — still no fresh argsort;
        * anything else (first build, snapshot ingest, over-budget churn,
          rebuild spec): the full ``build_index``.

        All three produce bitwise-identical indexes (build ≡ reindex on
        pos/ids/codes/starts/pyramid; ``leaf_level`` is the same
        ``_leaf_levels`` op over equal pyramids), pinned by
        tests/test_maintenance.py.
        """
        spec = self.spec
        if self._index is not None and not self._positions_dirty:
            self._index = rebuild_zmap(self._index)
        elif (
            self._index is not None
            and spec.maintenance == "incremental"
            and self._pending_ids is not None
            and self._pending_ids.size <= spec.churn_budget * self.num_objects
        ):
            ids_dev, old_dev = self._assemble_delta()
            self._index = rebuild_zmap(
                reindex_objects_delta(
                    self._index, self._positions, ids_dev, old_dev
                )
            )
        else:
            self._index = build_index(
                self._positions,
                jnp.asarray(self.spec.origin, jnp.float32),
                self.spec.side,
                l_max=self.spec.l_max,
                th_quad=self.spec.th_quad,
            )
        self._work_at_build = None  # set at the next tick's finalize
        # the stored object boundaries index Morton ranks of the PREVIOUS
        # partition — stale after a rebuild; ownership answers fall back to
        # the capacity rule until the next tick returns fresh boundaries
        self._obj_bounds = None
        # the index was just refreshed from the live buffer: clean slate for
        # the maintenance decision (build_index ≡ reindex_objects on pos/
        # ids/codes/starts/pyramid, so the next clean tick may skip)
        self._positions_dirty = False
        self._pending_ids = None
        self._pending_old_batches = []
        self._pending_old_rows = 0
        self._pending_src = None

    def _finalize_one(self, h: TickHandle):
        """Read back the tick's bookkeeping scalars and apply the drift policy.

        Blocks only on the two scalars (the step must have finished computing,
        but the big result arrays stay un-materialized on device).  Mirrors
        the snapshot engine exactly: the first finalized tick after a build
        becomes the work baseline; later ticks whose candidate volume exceeds
        ``rebuild_factor`` × baseline rebuild the partition — from the newest
        object state — before the next dispatch.
        """
        h._work = float(h._aux.stats.candidates)
        h._iterations = int(h._aux.stats.iterations)
        if self._work_at_build is None:
            self._work_at_build = h._work
        elif bool(h._should_rebuild):
            self._build()
            h._rebuilt_post = True
        h._finalized = True

    def _finalize_through(self, target: TickHandle | None = None):
        """Finalize pending ticks in submit order, up to ``target`` (or all)."""
        if target is not None and target._finalized:
            return  # don't touch (and block on) target's successors
        while self._pending:
            h = self._pending.popleft()
            self._finalize_one(h)
            if h is target:
                break

    def finalize_pending(self):
        """Apply the drift policy of every still-pending tick, now.

        Blocks only on each pending tick's two bookkeeping scalars (the big
        result arrays stay on device).  ``submit()`` does this implicitly;
        the serving layer (``repro.serve``) calls it explicitly so a
        drift-rebuild decision is *observable* (``TickHandle`` bookkeeping)
        before it consults its epoch-keyed result cache.
        """
        self._finalize_through()

    def submit(self) -> TickHandle:
        """Dispatch one tick against the current object + query state.

        Returns immediately after host staging + device dispatch; call
        ``TickHandle.result()`` to materialize.  Any still-pending earlier
        tick is finalized first (scalar readback + drift policy), which is
        the synchronization point that keeps overlapped submission
        bit-identical to the blocking loop.
        """
        if self._positions is None:
            raise RuntimeError("submit before ingest_objects: no object state")
        if self._registry.nq == 0:
            raise RuntimeError("submit with an empty query registry: "
                               "register_queries (or set_queries) first")
        self._finalize_through()
        t0 = time.perf_counter()
        rebuilt_pre = False
        if self._index is None:
            self._build()
            rebuilt_pre = True
        if self._registry.rows_changed:
            # the cost EMA is row-aligned with the padded registry batch; a
            # changed row set invalidates the alignment — re-seed from the
            # count-pyramid estimate (moves via update_queries keep it);
            # likewise the sink's cross-tick memory (prev neighbour lists)
            self._qcost = None
            self._sink_state = None
            self._registry.rows_changed = False
        qpos_dev, qid_dev, nq, qids, owner = self._registry.staged()
        qcost_dev = self._qcost
        if qcost_dev is None or qcost_dev.shape[0] != qpos_dev.shape[0]:
            qcost_dev = jnp.zeros((qpos_dev.shape[0],), jnp.float32)
        qweight_dev = None
        if self._qweight_host is not None:
            if self._qweight_host.shape[0] != nq:
                raise RuntimeError(
                    "query cost weights are stale: the registry row set "
                    "changed since set_query_cost_weights (re-set or clear)"
                )
            cap = int(qpos_dev.shape[0])
            st = self._qweight_staged
            if st is None or st[0] != self._qweight_ver or st[1] != cap:
                # padding rows clone the last active query (pad_queries), so
                # they clone its weight too — pure consistency; padding can
                # only shift boundaries, never results
                w = self._qweight_host
                w_p = np.concatenate(
                    [w, np.full((cap - nq,), w[-1], np.float32)]
                )
                self._qweight_staged = (
                    self._qweight_ver, cap, jnp.asarray(w_p, jnp.float32)
                )
            qweight_dev = self._qweight_staged[2]
        spec = self.spec
        # --- maintenance decision (DESIGN.md §15), made per tick, host-side:
        # clean buffer -> "skip" (reindex would be a bitwise no-op);
        # known small delta under an incremental spec -> "incremental";
        # anything else (rebuild spec, snapshot ingest, churn over budget)
        # -> full "rebuild" refresh.  Each mode is a static of the step, so
        # every (shape, mode) pair is its own cached executable.
        n = self.num_objects
        delta_ids_dev = None
        delta_old_pos_dev = None
        if not self._positions_dirty:
            mode = "skip"
        elif (
            spec.maintenance == "incremental"
            and self._pending_ids is not None
            and self._pending_ids.size <= spec.churn_budget * n
        ):
            mode = "incremental"
            # as-of-refresh positions of the pending ids: one gather over
            # the captured pre-scatter batches (device-side, async)
            delta_ids_dev, delta_old_pos_dev = self._assemble_delta()
            if self.plan.object_axis_size > 1:
                # per-shard budget (DESIGN.md §15): the global fraction can
                # hide one shard absorbing most of the churn — past
                # churn_budget × its OWNED rows, that shard's local re-sort
                # is the cheaper refresh, so the whole tick defers.  One ()
                # bool readback against the last tick's index/boundaries; the
                # pending ticks were already finalized above, so this is not
                # a new synchronization point.
                if bool(shard_churn_over_budget(
                    self._index, delta_ids_dev, self.plan.object_axis_size,
                    spec.churn_budget, self._obj_bounds,
                )):
                    mode = "rebuild"
                    delta_ids_dev = delta_old_pos_dev = None
        else:
            # over-budget churn defers to the FULL stage-(ii) refresh (not
            # build_index: the z_map stays put so the drift trigger fires
            # identically under both maintenance policies)
            mode = "rebuild"
        self._index, nn_idx, nn_dist, aux, should_rebuild = _tick_step(
            self._index,
            self._positions,
            qpos_dev,
            qid_dev,
            qcost_dev,
            jnp.float32(np.inf if self._work_at_build is None
                        else self._work_at_build),
            jnp.float32(spec.rebuild_factor),
            delta_ids_dev,
            delta_old_pos_dev,
            qweight_dev,
            k=spec.k,
            window=spec.window,
            chunk=spec.chunk,
            max_nav=default_max_nav(spec.l_max),
            max_iters=spec.max_iters,
            executor=self.executor,
            plan=self.plan,
            maintenance=mode,
        )
        # the index is now refreshed from this very buffer: clean until the
        # next position change (the dispatched step reads the buffer as of
        # dispatch; later update_objects scatter into a NEW buffer)
        self._positions_dirty = False
        self._pending_ids = None
        self._pending_old_batches = []
        self._pending_old_rows = 0
        self._pending_src = None
        # thread the repeated-query feedback loop: next tick's boundaries
        # see this tick's measured per-query work (device arrays, async)
        self._qcost = aux.qcost_next
        self._obj_bounds = (
            aux.object_bounds if self.plan.object_axis_size > 1 else None
        )
        agg = None
        if self._sink is not None:
            # consume the padded results ON DEVICE, behind the tick step in
            # the same async dispatch stream: tick τ+1's staging overlaps
            # τ's aggregation exactly as it overlaps τ's sweep
            if (
                self._sink_state is None
                or self._sink_state.prev_idx.shape != nn_idx.shape
            ):
                self._sink_state = self._sink.init(
                    int(nn_idx.shape[0]), spec.k
                )
            self._sink_state, agg = self._sink.update(
                self._sink_state, nn_idx, nn_dist, self._index,
                self._obj_bounds, jnp.int32(nq),
            )
        submit_s = time.perf_counter() - t0
        # submit_s covers the whole dispatch window, INCLUDING any first-
        # compile that ran synchronously inside it — compile_s below is the
        # submit-side attribution consumers subtract to get pure staging
        # time (the serve layer's wall_s decomposition relies on this)
        # key must mirror everything the jit cache keys on: shapes AND the
        # statics (th_quad/l_max ride in the index pytree's meta fields)
        key = (int(qpos_dev.shape[0]), self.num_objects, spec.k, spec.window,
               spec.chunk, spec.l_max, spec.th_quad, spec.max_iters,
               self.executor, self.plan, spec.collect, mode,
               None if delta_ids_dev is None else int(delta_ids_dev.shape[0]),
               qweight_dev is not None)
        compile_s = submit_s if key not in _COMPILED_KEYS else 0.0
        _COMPILED_KEYS.add(key)
        h = TickHandle(
            session=self,
            tick=self._tick,
            nn_idx=nn_idx,
            nn_dist=nn_dist,
            aux=aux,
            should_rebuild=should_rebuild,
            nq=nq,
            qids=qids,
            owner=owner,
            t0=t0,
            submit_s=submit_s,
            compile_s=compile_s,
            rebuilt_pre=rebuilt_pre,
            collect=spec.collect,
            agg=agg,
            maintenance=mode,
        )
        self._tick += 1
        self._pending.append(h)
        return h

    def process_tick(self, positions, qpos, qid=None):
        """Blocking snapshot convenience: ingest + set_queries + submit + result.

        ``wall_s`` here is measured from the top of the call — staging
        included — matching the pre-session ``TickEngine.process_tick``
        boundary, so BENCH rows built on it stay comparable across PRs.
        """
        t0 = time.perf_counter()
        self.ingest_objects(positions)
        self.set_queries(qpos, qid)
        res = self.submit().result()
        return dataclasses.replace(
            res, wall_s=time.perf_counter() - t0 - res.compile_s
        )
