"""repro.api — the public session-oriented serving facade (DESIGN.md §11).

Speak in sessions, deltas and in-flight ticks, not snapshots:

    from repro.api import KnnSession, ServiceSpec

    session = KnnSession(ServiceSpec(k=32, side=22_500.0))
    session.ingest_objects(P0)                      # snapshot seed
    hq = session.register_queries(qpos, qid)        # persistent query group
    for tick in range(30):
        session.update_objects(moved_ids, moved_pos)   # delta ingest
        handle = session.submit()                      # non-blocking
        ...                                            # stage the next tick
        res = handle.result()                          # (Q, k) lazily

The execution core underneath is :mod:`repro.core` (`_tick_step`, the
ExecutionPlan/QueryExecutor seams); ``repro.core.TickEngine`` remains as a
deprecation shim over a session.
"""
from .handles import QueryHandle, TickHandle
from .session import KnnSession
from .sink import ResultSink, SinkState, StatsSink, TickAggregates
from .spec import COLLECT_MODES, ServiceSpec

__all__ = [
    "KnnSession",
    "ServiceSpec",
    "COLLECT_MODES",
    "QueryHandle",
    "TickHandle",
    "ResultSink",
    "StatsSink",
    "SinkState",
    "TickAggregates",
]
