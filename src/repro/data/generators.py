"""Synthetic moving-object workload generators (paper Sec. 5, Table 1).

Reproduces the three dataset families of the paper's evaluation framework
(Sowell et al. [2]): *uniform*, *gaussian* (objects gathered around hotspots —
skewness controlled by the hotspot count) and *road network* (objects moving
along the edges of a network; we synthesize a jittered-grid network since the
San Francisco edge file is not available offline — noted in DESIGN.md §9).

Two further presets stress the skew axis the paper's headline claim covers
("highly skewed spatial distributions") — shared by the partitioner
benchmarks (benchmarks/s7_skew.py) and the property harness
(tests/test_properties.py) instead of each hand-rolling skewed clouds:

* *zipf* — ``clusters`` hotspot centers whose populations follow a Zipf law
  with exponent ``zipf_a`` (most mass in one tiny region: deep trees, long
  scan intervals, maximally uneven equal-count shards);
* *hotspot_cluster* — a ``cluster_frac`` share of objects packed into
  ``clusters`` tight gaussian hotspots over a uniform background (dense
  islands in sparse seas — the straggler scenario for query sharding).

Defaults match Table 1: squared region of side 22500 u, max speed 200 u/tick,
one query per object per tick (query rate 100 %).
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["WorkloadConfig", "MovingObjectWorkload", "make_workload"]

SIDE_DEFAULT = 22_500.0
MAX_SPEED_DEFAULT = 200.0


@dataclasses.dataclass(frozen=True)
class WorkloadConfig:
    n_objects: int = 100_000
    # uniform | gaussian | network | zipf | hotspot_cluster
    distribution: str = "uniform"
    side: float = SIDE_DEFAULT
    max_speed: float = MAX_SPEED_DEFAULT
    hotspots: int = 25  # gaussian: more hotspots -> closer to uniform
    hotspot_sigma_frac: float = 1.0 / 64.0  # sigma = side * frac
    network_grid: int = 24  # network: grid nodes per side
    zipf_a: float = 1.6  # zipf: cluster-population exponent (higher = denser)
    clusters: int = 12  # zipf / hotspot_cluster: number of cluster centers
    cluster_frac: float = 0.75  # hotspot_cluster: share of objects clustered
    seed: int = 0


class MovingObjectWorkload:
    """Stateful generator: ``positions()`` then ``advance()`` once per tick."""

    def __init__(self, cfg: WorkloadConfig):
        self.cfg = cfg
        self.rng = np.random.default_rng(cfg.seed)
        n, side = cfg.n_objects, cfg.side
        if cfg.distribution == "uniform":
            self.pos = self.rng.uniform(0, side, size=(n, 2)).astype(np.float32)
            self.vel = self._rand_vel(n)
        elif cfg.distribution == "gaussian":
            centers = self.rng.uniform(0, side, size=(cfg.hotspots, 2))
            which = self.rng.integers(0, cfg.hotspots, size=n)
            sigma = side * cfg.hotspot_sigma_frac
            self.pos = (
                centers[which] + self.rng.normal(0, sigma, size=(n, 2))
            ).astype(np.float32)
            self.pos = np.clip(self.pos, 0, side - 1e-3)
            self.vel = self._rand_vel(n)
        elif cfg.distribution == "zipf":
            # cluster populations ~ Zipf(zipf_a): rank-r cluster draws a
            # 1/r^a share of the objects — the partitioner stress preset
            centers = self.rng.uniform(0, side, size=(cfg.clusters, 2))
            weights = 1.0 / np.arange(1, cfg.clusters + 1) ** cfg.zipf_a
            which = self.rng.choice(
                cfg.clusters, size=n, p=weights / weights.sum()
            )
            sigma = side * cfg.hotspot_sigma_frac
            self.pos = (
                centers[which] + self.rng.normal(0, sigma, size=(n, 2))
            ).astype(np.float32)
            self.pos = np.clip(self.pos, 0, side - 1e-3)
            self.vel = self._rand_vel(n)
        elif cfg.distribution == "hotspot_cluster":
            # cluster_frac of the mass in `clusters` tight equal hotspots,
            # the rest a uniform background (dense islands in sparse seas)
            centers = self.rng.uniform(0, side, size=(cfg.clusters, 2))
            n_cl = int(round(n * cfg.cluster_frac))
            which = self.rng.integers(0, cfg.clusters, size=n_cl)
            sigma = side * cfg.hotspot_sigma_frac / 4.0
            clustered = centers[which] + self.rng.normal(0, sigma, (n_cl, 2))
            background = self.rng.uniform(0, side, size=(n - n_cl, 2))
            self.pos = np.concatenate([clustered, background]).astype(np.float32)
            self.pos = np.clip(self.pos, 0, side - 1e-3)
            self.vel = self._rand_vel(n)
        elif cfg.distribution == "network":
            self._init_network()
        else:
            raise ValueError(f"unknown distribution {cfg.distribution!r}")

    # ------------------------------------------------------------ helpers
    def _rand_vel(self, n: int) -> np.ndarray:
        ang = self.rng.uniform(0, 2 * np.pi, size=n)
        speed = self.rng.uniform(0, self.cfg.max_speed, size=n)
        return (speed[:, None] * np.stack([np.cos(ang), np.sin(ang)], 1)).astype(
            np.float32
        )

    def _init_network(self):
        cfg = self.cfg
        g = cfg.network_grid
        step = cfg.side / (g - 1)
        xs, ys = np.meshgrid(np.arange(g) * step, np.arange(g) * step)
        nodes = np.stack([xs.ravel(), ys.ravel()], 1)
        nodes += self.rng.uniform(-0.25 * step, 0.25 * step, nodes.shape)
        nodes = np.clip(nodes, 0, cfg.side - 1e-3).astype(np.float32)
        edges = []
        for r in range(g):
            for c in range(g):
                i = r * g + c
                if c + 1 < g:
                    edges.append((i, i + 1))
                if r + 1 < g:
                    edges.append((i, i + g))
        self.net_nodes = nodes
        self.net_edges = np.asarray(edges, np.int32)
        # incident edge list per node (for random turns)
        ne = len(edges)
        inc: list[list[int]] = [[] for _ in range(g * g)]
        for e, (a, b) in enumerate(edges):
            inc[a].append(e)
            inc[b].append(e)
        maxdeg = max(len(x) for x in inc)
        self.net_inc = np.full((g * g, maxdeg), -1, np.int32)
        self.net_deg = np.zeros(g * g, np.int32)
        for v, lst in enumerate(inc):
            self.net_deg[v] = len(lst)
            self.net_inc[v, : len(lst)] = lst
        n = cfg.n_objects
        self.obj_edge = self.rng.integers(0, ne, size=n).astype(np.int32)
        self.obj_t = self.rng.uniform(0, 1, size=n).astype(np.float32)
        self.obj_dir = self.rng.choice([-1.0, 1.0], size=n).astype(np.float32)
        self.obj_speed = self.rng.uniform(
            0.3 * cfg.max_speed, cfg.max_speed, size=n
        ).astype(np.float32)
        self.pos = self._network_positions()

    def _edge_len(self, e):
        a, b = self.net_edges[e, 0], self.net_edges[e, 1]
        return np.linalg.norm(self.net_nodes[a] - self.net_nodes[b], axis=-1)

    def _network_positions(self) -> np.ndarray:
        a = self.net_edges[self.obj_edge, 0]
        b = self.net_edges[self.obj_edge, 1]
        pa, pb = self.net_nodes[a], self.net_nodes[b]
        return (pa + self.obj_t[:, None] * (pb - pa)).astype(np.float32)

    # ------------------------------------------------------------ API
    def positions(self) -> np.ndarray:
        """Last known positions P at the end of the current tick: (N, 2) f32."""
        return self.pos

    def advance(self):
        """Move every object by one tick (<= max_speed displacement)."""
        cfg = self.cfg
        if cfg.distribution in ("uniform", "gaussian", "zipf", "hotspot_cluster"):
            # speed random-walk as in [2]: perturb velocity, clamp magnitude
            self.vel += self.rng.normal(0, 0.1 * cfg.max_speed, self.vel.shape).astype(
                np.float32
            )
            speed = np.linalg.norm(self.vel, axis=1, keepdims=True)
            fac = np.minimum(1.0, cfg.max_speed / np.maximum(speed, 1e-6))
            self.vel *= fac
            self.pos = self.pos + self.vel
            # reflect at region borders
            for d in (0, 1):
                below = self.pos[:, d] < 0
                above = self.pos[:, d] > cfg.side - 1e-3
                self.pos[below, d] = -self.pos[below, d]
                self.vel[below, d] = -self.vel[below, d]
                self.pos[above, d] = 2 * (cfg.side - 1e-3) - self.pos[above, d]
                self.vel[above, d] = -self.vel[above, d]
            self.pos = np.clip(self.pos, 0, cfg.side - 1e-3)
        else:  # network
            elen = np.maximum(self._edge_len(self.obj_edge), 1e-6)
            self.obj_t += self.obj_dir * self.obj_speed / elen
            done_hi = self.obj_t >= 1.0
            done_lo = self.obj_t <= 0.0
            for mask, node_col in ((done_hi, 1), (done_lo, 0)):
                idx = np.nonzero(mask)[0]
                if idx.size == 0:
                    continue
                node = self.net_edges[self.obj_edge[idx], node_col]
                deg = self.net_deg[node]
                pick = (self.rng.random(idx.size) * deg).astype(np.int32)
                new_e = self.net_inc[node, pick]
                self.obj_edge[idx] = new_e
                # orient: start from `node`
                starts_at_node = self.net_edges[new_e, 0] == node
                self.obj_t[idx] = np.where(starts_at_node, 0.0, 1.0)
                self.obj_dir[idx] = np.where(starts_at_node, 1.0, -1.0)
            self.obj_t = np.clip(self.obj_t, 0.0, 1.0)
            self.pos = self._network_positions()

    def query_batch(self, rate: float = 1.0):
        """Queries for the tick: one per object (Table 1), centered at the issuer."""
        n = self.cfg.n_objects
        if rate >= 1.0:
            qid = np.arange(n, dtype=np.int32)
        else:
            m = max(1, int(n * rate))
            qid = self.rng.choice(n, size=m, replace=False).astype(np.int32)
        return self.pos[qid], qid


def make_workload(
    n_objects: int,
    distribution: str = "uniform",
    seed: int = 0,
    **kw,
) -> MovingObjectWorkload:
    return MovingObjectWorkload(
        WorkloadConfig(n_objects=n_objects, distribution=distribution, seed=seed, **kw)
    )
