from .generators import MovingObjectWorkload, WorkloadConfig, make_workload

__all__ = ["MovingObjectWorkload", "WorkloadConfig", "make_workload"]
