"""Deterministic synthetic LM data pipeline (step-indexed => restart-safe).

``batch_for_step(step)`` is a pure function of (seed, step): after a crash and
restore-from-checkpoint, training replays exactly the same remaining batches —
the property the fault-tolerance integration test asserts.  The token stream is
a Zipf-ish unigram mix with short-range repetition so tiny models have
something learnable.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["LMDataConfig", "SyntheticLMData"]


@dataclasses.dataclass(frozen=True)
class LMDataConfig:
    vocab: int
    batch: int
    seq_len: int
    seed: int = 0


class SyntheticLMData:
    def __init__(self, cfg: LMDataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        ranks = np.arange(1, cfg.vocab + 1, dtype=np.float64)
        probs = 1.0 / ranks**1.1
        self.probs = probs / probs.sum()
        self.base_seed = int(rng.integers(0, 2**31 - 1))

    def batch_for_step(self, step: int, extras: dict | None = None) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng((self.base_seed, step))
        toks = rng.choice(cfg.vocab, size=(cfg.batch, cfg.seq_len), p=self.probs)
        # short-range repetition: copy spans back by 3 with prob .3 (learnable)
        rep = rng.random((cfg.batch, cfg.seq_len)) < 0.3
        toks[:, 3:] = np.where(rep[:, 3:], toks[:, :-3], toks[:, 3:])
        out = {"tokens": toks.astype(np.int32)}
        if extras:
            for name, shape in extras.items():
                out[name] = rng.normal(0, 0.02, size=(cfg.batch, *shape)).astype(
                    np.float32
                )
        return out
