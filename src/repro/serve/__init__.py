"""repro.serve — the multi-tenant serving layer above :class:`repro.api.KnnSession`.

One :class:`KnnServer` admits many tenant sessions and coalesces their
repeated k-NN queries into ONE shared tick program on one device mesh:
tenant-tagged rows in a unified registry, deduplicated by exact query
geometry, quota-checked at registration, fairness-weighted under the
cost-balanced partitioner, and replayed from an LRU result cache whose
invalidation is a knob — ``invalidation="epoch"`` clears the store on any
world movement, ``"spatial"`` evicts only the entries whose k-th-distance
ball a moved row stabs.  Per-tenant results are bitwise identical to what
a solo session would have produced (DESIGN.md §16).

    spec = ServiceSpec(k=8, side=1000.0, plan="sharded", mesh_shape=8)
    server = KnnServer(spec)
    server.ingest_objects(positions)          # ONE shared world
    alice = server.admit("alice", quota=512)
    bob = server.admit("bob")
    qa = alice.register_queries(alice_qpos)
    qb = bob.register_queries(bob_qpos)
    bob.update_objects(ids, moved)            # invalidates affected cache
    tickres = server.submit()                 # one device tick for everyone
    ii, dd, qids = tickres.result_for(qa)
"""
from .cache import CacheStats, ResultCache
from .registry import ComputeView, TenantRegistry
from .server import KnnServer, ServerTick, ServerTickResult
from .tenant import (
    AdmissionError,
    QuotaExceededError,
    TenantHandle,
    TenantQueryHandle,
)

__all__ = [
    "KnnServer",
    "ServerTick",
    "ServerTickResult",
    "TenantHandle",
    "TenantQueryHandle",
    "AdmissionError",
    "QuotaExceededError",
    "ResultCache",
    "CacheStats",
    "TenantRegistry",
    "ComputeView",
]
