"""Epoch-keyed result cache for overlapping hotspot queries (DESIGN.md §16).

Tenants of one :class:`~repro.serve.server.KnnServer` share one moving-object
world, and under hotspot workloads they ask about the SAME places: the cache
turns the second tenant's identical query into a host-side array copy instead
of device work.  The contract (the AppLovin caching pattern in SNIPPETS.md —
results keyed on the index epoch, invalidated by ingest):

* **Key** = the tenant-agnostic query geometry — the exact float bit patterns
  of the query position plus the exclusion qid (qid is part of the result's
  definition: it removes the issuing object from its own list).  Tenants
  never appear in the key; a cached list is correct for ANY tenant asking
  the bitwise-same question, which is what makes sharing sound.
* **Epoch** = a monotone counter over the object world.  Any delta ingest,
  snapshot ingest, or drift rebuild bumps it; a bump atomically invalidates
  every entry (the store only ever holds entries of the CURRENT epoch, so
  "key = (geometry, epoch)" degenerates to "clear on bump" — no stale entry
  can survive to be looked up).  Results computed under epoch *e* are only
  inserted if the epoch is still *e* when they materialize: an ingest racing
  an in-flight tick can only lose cached work, never poison the store.
* **Values** are read-only ``(k,)`` numpy arrays; lookups hand back the
  stored arrays and assembly into per-tenant results always copies (fancy
  indexing), so no tenant can mutate what another is served.

Eviction is LRU at a fixed entry capacity.  ``capacity=0`` disables the
cache entirely (every lookup misses, inserts drop) — the server does this
under ``collect != "full"``, where neighbour lists never reach the host and
there is nothing host-side to cache; intra-tick dedup still works there.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict

import numpy as np

__all__ = ["CacheStats", "ResultCache"]


@dataclasses.dataclass
class CacheStats:
    """Counters over the cache's lifetime (monotone; epochs don't reset them)."""

    lookups: int = 0
    hits: int = 0
    misses: int = 0
    insertions: int = 0
    evictions: int = 0
    invalidations: int = 0

    @property
    def hit_rate(self) -> float:
        return self.hits / self.lookups if self.lookups else 0.0

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["hit_rate"] = self.hit_rate
        return d


class ResultCache:
    """LRU store: geometry key bytes -> read-only (nn_idx, nn_dist) pair."""

    def __init__(self, capacity: int = 65536):
        if capacity < 0:
            raise ValueError(f"capacity must be >= 0, got {capacity}")
        self.capacity = int(capacity)
        self.epoch = 0
        self.last_invalidation: str | None = None
        self.stats = CacheStats()
        self._store: OrderedDict[bytes, tuple] = OrderedDict()

    @property
    def enabled(self) -> bool:
        return self.capacity > 0

    def __len__(self) -> int:
        return len(self._store)

    def bump_epoch(self, reason: str = "ingest") -> int:
        """Advance the epoch and drop every entry (see module docstring)."""
        self.epoch += 1
        self.last_invalidation = reason
        if self._store:
            self.stats.invalidations += len(self._store)
            self._store.clear()
        return self.epoch

    def lookup(self, key: bytes):
        """(nn_idx, nn_dist) for ``key`` at the current epoch, else None."""
        self.stats.lookups += 1
        ent = self._store.get(key)
        if ent is None:
            self.stats.misses += 1
            return None
        self._store.move_to_end(key)
        self.stats.hits += 1
        return ent

    def insert(self, key: bytes, nn_idx, nn_dist):
        """Store a result under ``key``; no-op when disabled.

        Callers must have verified the epoch they computed under is still
        current (the server's materialization guard); the cache itself only
        promises that a bump clears everything inserted before it.
        """
        if not self.enabled:
            return
        ii = np.array(nn_idx, np.int32, copy=True)
        dd = np.array(nn_dist, np.float32, copy=True)
        ii.setflags(write=False)
        dd.setflags(write=False)
        self._store[key] = (ii, dd)
        self._store.move_to_end(key)
        self.stats.insertions += 1
        while len(self._store) > self.capacity:
            self._store.popitem(last=False)
            self.stats.evictions += 1
