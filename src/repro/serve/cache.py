"""Result cache for overlapping hotspot queries (DESIGN.md §16).

Tenants of one :class:`~repro.serve.server.KnnServer` share one moving-object
world, and under hotspot workloads they ask about the SAME places: the cache
turns the second tenant's identical query into a host-side array copy instead
of device work.  The contract (grown from the AppLovin caching pattern in
SNIPPETS.md — results keyed on an index epoch, invalidated by ingest):

* **Key** = the tenant-agnostic query geometry — the exact float bit patterns
  of the query position plus the exclusion qid (qid is part of the result's
  definition: it removes the issuing object from its own list).  Tenants
  never appear in the key; a cached list is correct for ANY tenant asking
  the bitwise-same question, which is what makes sharing sound.
* **Epoch** = a monotone counter over *global* invalidations.  A bump
  atomically drops every entry (snapshot ingest always bumps; delta ingest
  bumps under ``invalidation="epoch"``, and under ``"spatial"`` only as the
  over-budget fallback).  No stale entry can survive a bump to be looked up.
* **Mutation** = a monotone counter over *world mutations* — bumped by any
  snapshot or delta ingest, and by nothing else.  Drift rebuilds re-sort the
  SAME positions, so they do not touch it.  Results computed while the
  mutation counter read *m* are only inserted if it still reads *m* when
  they materialize (the server's guard): an ingest racing an in-flight tick
  can only lose cached work, never poison the store — while a drift rebuild
  no longer discards the rebuilt tick's own fresh inserts.
* **Spatial eviction** (``invalidation="spatial"``): each entry additionally
  stores its query center and squared k-th distance; a delta ingest evicts
  exactly the entries whose closed k-th ball a moved row's old or new
  position stabs (:func:`repro.core.quadtree.ball_stab_mask`) instead of
  clearing the store.
* **Values** are read-only ``(k,)`` numpy arrays; lookups hand back the
  stored arrays and assembly into per-tenant results always copies (fancy
  indexing), so no tenant can mutate what another is served.

Eviction is LRU at a fixed entry capacity.  ``capacity=0`` disables the
cache entirely (every lookup misses, inserts drop) — the server does this
under ``collect != "full"``, where neighbour lists never reach the host and
there is nothing host-side to cache; intra-tick dedup still works there.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict

import numpy as np

__all__ = ["CacheStats", "ResultCache"]


@dataclasses.dataclass
class CacheStats:
    """Counters over the cache's lifetime (monotone; epochs don't reset them).

    ``invalidations`` counts entries dropped by epoch bumps AND by spatial
    stab evictions (both are "a world change killed this entry"); plain LRU
    capacity pressure counts into ``evictions`` instead.
    """

    lookups: int = 0
    hits: int = 0
    misses: int = 0
    insertions: int = 0
    evictions: int = 0
    invalidations: int = 0

    @property
    def hit_rate(self) -> float:
        return self.hits / self.lookups if self.lookups else 0.0

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["hit_rate"] = self.hit_rate
        return d


class ResultCache:
    """LRU store: geometry key bytes -> read-only (nn_idx, nn_dist, ball)."""

    def __init__(self, capacity: int = 65536):
        if capacity < 0:
            raise ValueError(f"capacity must be >= 0, got {capacity}")
        self.capacity = int(capacity)
        self.epoch = 0
        self.mutation = 0
        self.last_invalidation: str | None = None
        self.stats = CacheStats()
        # key -> (nn_idx, nn_dist, center | None, kth2 | None)
        self._store: OrderedDict[bytes, tuple] = OrderedDict()

    @property
    def enabled(self) -> bool:
        return self.capacity > 0

    def __len__(self) -> int:
        return len(self._store)

    def bump_mutation(self) -> int:
        """Record a world mutation (ingest).  Does NOT drop entries — the
        caller pairs it with :meth:`bump_epoch` or :meth:`evict_keys` as its
        invalidation mode dictates; the counter's sole consumer is the
        server's insert guard (results staged under an older world are
        dropped on materialization)."""
        self.mutation += 1
        return self.mutation

    def bump_epoch(self, reason: str = "ingest") -> int:
        """Advance the epoch and drop every entry (see module docstring)."""
        self.epoch += 1
        self.last_invalidation = reason
        if self._store:
            self.stats.invalidations += len(self._store)
            self._store.clear()
        return self.epoch

    def evict_keys(self, keys, reason: str) -> int:
        """Spatially targeted invalidation: drop exactly ``keys``.

        Counts into ``stats.invalidations`` (these are world-change kills,
        not capacity pressure) and records ``reason`` like an epoch bump —
        but does NOT advance the epoch: surviving entries stay valid.
        """
        n = 0
        for key in keys:
            if self._store.pop(key, None) is not None:
                n += 1
        self.stats.invalidations += n
        self.last_invalidation = reason
        return n

    def geometry(self):
        """(keys, centers, kth2) over the live store, insertion-LRU order.

        ``centers`` is ``(E, 2)`` f32 and ``kth2`` ``(E,)`` f64 (squared
        ball radii, squared at insert time from the kernel's Euclidean
        k-th distance); entries
        inserted without ball geometry come back NaN, which
        :func:`~repro.core.quadtree.ball_stab_mask` treats as always-stab —
        an entry the stab can't reason about is evicted, never kept.
        """
        keys = list(self._store.keys())
        centers = np.full((len(keys), 2), np.nan, np.float32)
        kth2 = np.full((len(keys),), np.nan, np.float64)
        for i, key in enumerate(keys):
            ent = self._store[key]
            if ent[2] is not None:
                centers[i] = ent[2]
                kth2[i] = ent[3]
        return keys, centers, kth2

    def lookup(self, key: bytes):
        """(nn_idx, nn_dist) for ``key`` if live, else None."""
        self.stats.lookups += 1
        ent = self._store.get(key)
        if ent is None:
            self.stats.misses += 1
            return None
        self._store.move_to_end(key)
        self.stats.hits += 1
        return ent[0], ent[1]

    def insert(self, key: bytes, nn_idx, nn_dist, center=None, kth_dist=None):
        """Store a result under ``key``; no-op when disabled.

        ``center`` (query position, f32 ``(2,)``) and ``kth_dist`` (the
        kernel's EUCLIDEAN k-th distance, its f32 value) are the entry's
        stab ball for spatial invalidation; the radius is squared here in
        f64 (exact for any f32 input) so the stab compares squared
        distances without a second rounding.  Omitting them is allowed and
        merely makes the entry always-evict under spatial mode.  Callers must have verified
        the mutation counter they computed under is still current (the
        server's materialization guard); the cache itself only promises that
        an epoch bump clears everything inserted before it.
        """
        if not self.enabled:
            return
        ii = np.array(nn_idx, np.int32, copy=True)
        dd = np.array(nn_dist, np.float32, copy=True)
        ii.setflags(write=False)
        dd.setflags(write=False)
        c = None
        r2 = None
        if center is not None and kth_dist is not None:
            c = np.array(center, np.float32, copy=True).reshape(2)
            c.setflags(write=False)
            r2 = np.float64(kth_dist) ** 2
        self._store[key] = (ii, dd, c, r2)
        self._store.move_to_end(key)
        self.stats.insertions += 1
        while len(self._store) > self.capacity:
            self._store.popitem(last=False)
            self.stats.evictions += 1
