"""TenantRegistry — the server's tenant-tagged logical query rows (DESIGN.md §16).

The server keeps its OWN host-side registry above the session's: every
registered query row carries (geometry, exclusion qid, tenant, group handle).
Per tick the registry derives the **compute view** — the deduplicated set of
distinct (geometry, qid) keys across all tenants — and it is that unique set
(minus cache hits) that gets staged into the inner :class:`~repro.api.KnnSession`
via ``set_queries``, padded by the same :func:`repro.core.plan.pad_queries`
convention as any solo session.  Deduplication is sound for the same reason
the cache is: a result is a pure function of (object positions, query
geometry, qid) — the repo-wide exactness contract (canonical selection,
DESIGN.md §12) — so two tenants asking the bitwise-same question own the
bitwise-same answer.

Keys are the raw bit patterns (f32 position words + i32 qid), not float
comparisons: distinct NaN payloads or signed zeros never alias, and the
12-byte key doubles as the result-cache key.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["ComputeView", "TenantRegistry"]

_KEY_DTYPE = np.dtype([("x", "<u4"), ("y", "<u4"), ("q", "<i4")])


def _geometry_keys(qpos: np.ndarray, qid: np.ndarray) -> np.ndarray:
    """(R,) structured key records from (R, 2) f32 positions + (R,) i32 qids."""
    rec = np.empty(qpos.shape[0], _KEY_DTYPE)
    rec["x"] = np.ascontiguousarray(qpos[:, 0], "<f4").view("<u4")
    rec["y"] = np.ascontiguousarray(qpos[:, 1], "<f4").view("<u4")
    rec["q"] = qid.astype("<i4")
    return rec


@dataclasses.dataclass(frozen=True)
class ComputeView:
    """One tick's dedup of the logical rows into distinct compute keys.

    ``qpos``/``qid`` are the (U,) unique rows in key-sorted order (rows of
    the ORIGINAL arrays, bit-exact); ``row_to_unique`` maps each logical
    registry row to its unique index; ``keys[u]`` is unique row *u*'s
    12-byte geometry key (the cache key).  ``qpos[u]`` doubles as cache
    entry *u*'s stab-ball center under spatial invalidation — it must stay
    the original f32 bits (NOT a re-rounded copy) so the zero-radius stab's
    bitwise-equality semantics hold.
    """

    qpos: np.ndarray
    qid: np.ndarray
    row_to_unique: np.ndarray
    keys: list

    @property
    def n_unique(self) -> int:
        return int(self.qpos.shape[0])


class TenantRegistry:
    """Contiguous tenant-tagged rows; groups drop by handle, tenants wholesale."""

    def __init__(self):
        self.qpos = np.zeros((0, 2), np.float32)
        self.qid = np.zeros((0,), np.int32)
        self.tenant = np.zeros((0,), np.int64)  # tenant id per row
        self.owner = np.zeros((0,), np.int64)   # group hid per row
        self._next_hid = 0
        self._live: set[int] = set()

    @property
    def nrows(self) -> int:
        return int(self.qpos.shape[0])

    def tenant_count(self, tid: int) -> int:
        return int((self.tenant == tid).sum())

    def _coerce(self, qpos, qid):
        qpos = np.asarray(qpos, np.float32).reshape(-1, 2)
        m = qpos.shape[0]
        if qid is None:
            qid = np.full((m,), -2, np.int32)
        else:
            qid = np.asarray(qid, np.int32).reshape(-1)
            if qid.shape[0] != m:
                raise ValueError(
                    f"qid has {qid.shape[0]} rows but qpos has {m}"
                )
        return qpos, qid

    def register(self, tid: int, qpos, qid=None) -> int:
        qpos, qid = self._coerce(qpos, qid)
        if qpos.shape[0] == 0:
            raise ValueError("cannot register an empty query group")
        hid = self._next_hid
        self._next_hid += 1
        m = qpos.shape[0]
        self.qpos = np.concatenate([self.qpos, qpos])
        self.qid = np.concatenate([self.qid, qid])
        self.tenant = np.concatenate([self.tenant, np.full((m,), tid, np.int64)])
        self.owner = np.concatenate([self.owner, np.full((m,), hid, np.int64)])
        self._live.add(hid)
        return hid

    def _check(self, hid: int):
        if hid not in self._live:
            raise KeyError(
                f"query group {hid} is not live (already dropped, or its "
                "tenant was evicted)"
            )

    def group_rows(self, hid: int) -> np.ndarray:
        self._check(hid)
        return np.nonzero(self.owner == hid)[0]

    def tenant_rows(self, tid: int) -> np.ndarray:
        return np.nonzero(self.tenant == tid)[0]

    def update(self, hid: int, qpos):
        rows = self.group_rows(hid)
        qpos = np.asarray(qpos, np.float32).reshape(-1, 2)
        if qpos.shape[0] != rows.shape[0]:
            raise ValueError(
                f"update: group {hid} owns {rows.shape[0]} rows, got "
                f"{qpos.shape[0]} positions"
            )
        self.qpos[rows] = qpos

    def _drop_rows(self, rows: np.ndarray):
        keep = np.ones(self.nrows, bool)
        keep[rows] = False
        self.qpos = self.qpos[keep]
        self.qid = self.qid[keep]
        self.tenant = self.tenant[keep]
        self.owner = self.owner[keep]

    def drop(self, hid: int):
        rows = self.group_rows(hid)
        self._drop_rows(rows)
        self._live.discard(hid)

    def drop_tenant(self, tid: int):
        rows = self.tenant_rows(tid)
        if rows.size:
            for hid in np.unique(self.owner[rows]):
                self._live.discard(int(hid))
            self._drop_rows(rows)

    def compute_view(self) -> ComputeView:
        """Dedup the logical rows into the distinct compute keys (docstring).

        ``np.unique`` on the structured keys sorts lexicographically on the
        bit patterns — a deterministic order, so an unchanged key SET stages
        an unchanged compute batch regardless of registration order, and the
        session's staged device arrays (and compiled programs) are reused.
        """
        keys = _geometry_keys(self.qpos, self.qid)
        uniq, first, inverse = np.unique(
            keys, return_index=True, return_inverse=True
        )
        return ComputeView(
            qpos=self.qpos[first].copy(),
            qid=self.qid[first].copy(),
            row_to_unique=inverse.reshape(-1).astype(np.int64),
            keys=[uniq[u].tobytes() for u in range(uniq.shape[0])],
        )
