"""Tenant-facing handles: admission, quotas, and per-tenant ingest routing.

A :class:`TenantHandle` is what :meth:`~repro.serve.server.KnnServer.admit`
returns — the ONLY object a tenant's client code needs.  It scopes query
registration (quota-checked), query movement, and delta object ingest to one
tenant while delegating every device interaction to the shared server.

Quota rule (DESIGN.md §16): a tenant may hold at most ``quota`` live query
rows.  Over-quota registration raises :class:`QuotaExceededError` by
default; ``clip=True`` degrades gracefully by registering only the first
``quota_remaining`` rows (the handle's ``count`` says how many survived).
Quotas bound *admission*, not fairness — fair share under the cost-balanced
partitioner is the per-row weighting (``core.balance.tenant_fair_weights``)
the server threads into boundary seeding, so even a tenant at a 10x larger
quota moves shard boundaries no more than any other.
"""
from __future__ import annotations

import dataclasses

__all__ = [
    "AdmissionError",
    "QuotaExceededError",
    "TenantQueryHandle",
    "TenantHandle",
]


class AdmissionError(RuntimeError):
    """The server refused admission (capacity, duplicate name, evicted)."""


class QuotaExceededError(AdmissionError):
    """Registration would exceed the tenant's live query-row quota."""


@dataclasses.dataclass(frozen=True)
class TenantQueryHandle:
    """Stable reference to one tenant's registered query group."""

    tenant: str
    hid: int
    count: int


class TenantHandle:
    """One admitted tenant's scoped view of the shared server."""

    def __init__(self, server, name: str, tid: int, quota: int | None):
        self._server = server
        self.name = name
        self.tid = tid
        self.quota = quota
        self.live = True
        self.deltas_fed = 0  # moved-object rows this tenant has ingested

    def __repr__(self):
        return (
            f"TenantHandle(name={self.name!r}, quota={self.quota}, "
            f"queries={self.query_count}, live={self.live})"
        )

    def _check_live(self):
        if not self.live:
            raise AdmissionError(f"tenant {self.name!r} was evicted")

    @property
    def query_count(self) -> int:
        """Live query rows this tenant currently holds."""
        return self._server._registry.tenant_count(self.tid)

    @property
    def quota_remaining(self) -> int | None:
        if self.quota is None:
            return None
        return max(0, self.quota - self.query_count)

    # ------------------------------------------------------------ queries
    def register_queries(self, qpos, qid=None, *, clip=False) -> TenantQueryHandle:
        """Add a persistent query group for this tenant (quota-checked).

        ``qid`` is the issuing object id per query (excluded from its own
        list; default -2 = none) — same convention as
        :meth:`repro.api.KnnSession.register_queries`.  Raises
        :class:`QuotaExceededError` when the group would push the tenant
        over quota; ``clip=True`` registers the first ``quota_remaining``
        rows instead (still raising if none remain).
        """
        self._check_live()
        return self._server._register_queries(self, qpos, qid, clip=clip)

    def update_queries(self, handle: TenantQueryHandle, qpos):
        """Move a registered group: same row count, new positions."""
        self._check_live()
        self._server._update_queries(self, handle, qpos)

    def drop_queries(self, handle: TenantQueryHandle):
        """Remove a group; its rows stop being served from the next submit."""
        self._check_live()
        self._server._drop_queries(self, handle)

    # ------------------------------------------------------------ objects
    def update_objects(self, ids, positions):
        """Delta-ingest this tenant's observations into the SHARED world.

        All tenants observe one moving-object population; the delta rides
        the session's device-side scatter
        (:meth:`repro.api.KnnSession.update_objects`) and — because the
        world changed — invalidates the result cache: the whole store
        under ``invalidation="epoch"``, only the stabbed entries under
        ``"spatial"`` (DESIGN.md §16).
        """
        self._check_live()
        self._server._ingest_delta(self, ids, positions)
