"""KnnServer — many tenants, ONE shared tick program on one mesh (DESIGN.md §16).

The dataflow per tick:

1. **Admission** put tenants' query groups into the host-side
   :class:`~repro.serve.registry.TenantRegistry` (tenant-tagged logical
   rows, quota-checked at registration).
2. ``submit()`` first *observes* any earlier in-flight tick's drift
   bookkeeping (``KnnSession.finalize_pending``) so drift decisions land
   BEFORE the cache is consulted (under ``invalidation="epoch"`` a rebuild
   bumps the epoch; under ``"spatial"`` it is a no-op — a rebuild re-sorts
   the SAME positions, so cached entries stay bit-correct).
3. The registry dedups the logical rows into distinct (geometry, qid) keys
   (:meth:`~repro.serve.registry.TenantRegistry.compute_view`); each unique
   key is looked up in the :class:`~repro.serve.cache.ResultCache`, whose
   invalidation mode is the server's ``invalidation`` knob: ``"epoch"``
   clears the store on every delta ingest; ``"spatial"`` evicts only the
   entries whose closed k-th-distance ball a moved row's old or new
   position stabs (:func:`repro.core.quadtree.ball_stab_mask`), falling
   back to the epoch clear above ``stab_budget`` moved rows.
4. The **miss set** becomes the inner :class:`~repro.api.KnnSession`'s query
   registry (``set_queries`` — only restaged when the miss set actually
   changed), with tenant-fair cost weights
   (``core.balance.tenant_fair_weights`` summed onto unique rows) threaded
   into the cost-balanced partitioner's boundary seeding, and ONE session
   tick is dispatched for all tenants together.  A tick whose unique rows
   are ALL cached skips the device entirely.
5. ``ServerTick.result_for(...)`` assembles each tenant's rows from the
   computed batch + cached entries by the row→unique mapping snapshotted at
   submit (always a copy — no tenant can mutate another's lists).

**Bit-identity argument** (the acceptance bar): a k-NN result here is a pure
function of (object positions, query geometry, exclusion qid) — canonical
selection makes every plan × partitioner × backend bitwise-equal to the
single-device sweep (DESIGN.md §12/§13), so neither batch composition, nor
dedup, nor fairness-weighted boundaries, nor cache replay can change a
row's bits.  The inner session pads with the same
:func:`repro.core.plan.pad_queries` the solo path uses; a cached entry is
the bits a solo session produced for that geometry under object positions
that are — by the invalidation contract (epoch clear, or the conservative
closed-ball stab) — still current for that entry.  Hence N tenants through
one server ≡ N solo sessions, row for row (pinned by tests/test_serve.py
and the property harness).
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque

import jax.numpy as jnp
import numpy as np

from repro.api.session import KnnSession
from repro.api.spec import ServiceSpec
from repro.core.balance import tenant_fair_weights
from repro.core.quadtree import ball_stab_mask

from .cache import ResultCache
from .registry import TenantRegistry
from .tenant import (
    AdmissionError,
    QuotaExceededError,
    TenantHandle,
    TenantQueryHandle,
)

__all__ = ["KnnServer", "ServerTick", "ServerTickResult"]


@dataclasses.dataclass(frozen=True)
class ServerTickResult:
    """One shared tick's host-facing record (per-tenant rows come from
    ``ServerTick.result_for``; this is the accounting view).

    ``rows_total`` counts logical tenant rows served; ``rows_computed`` the
    unique keys that actually ran on device.  ``hit_rate`` is the fraction
    of logical rows served WITHOUT fresh device work —
    ``dedup_hit_rows`` (duplicates folded into a computed unique row, any
    collect mode) plus ``cache_hit_rows`` (rows replayed from a previous
    tick's still-valid entry, ``collect="full"`` only).  ``inner`` is the
    underlying session :class:`~repro.core.ticks.TickResult` (None for a
    pure-cache tick that never touched the device).

    ``wall_s`` is the tick's attributable latency, decomposed so that host
    idle time between ``submit()`` and a lazy ``result()`` (or an
    overlapped τ+1 submit) never inflates it::

        wall_s = submit_s + drain_s + assemble_s

    * ``submit_s`` — host-side staging inside ``submit()`` (observe +
      dedup + cache probe + query restage + dispatch), compile excluded;
    * ``drain_s``  — blocking wait for the device computation
      (``TickHandle.block_until_ready``) paid by THIS ``result()`` call;
    * ``assemble_s`` — host materialization + row/cache bookkeeping.

    All three are clamped >= 0; ``compile_s`` (trace+compile, first-shape
    ticks only) is reported separately, as in the inner session result.
    """

    tick: int
    epoch: int
    rows_total: int
    rows_unique: int
    rows_computed: int
    dedup_hit_rows: int
    cache_hit_rows: int
    rebuilt: bool
    wall_s: float
    compile_s: float
    inner: object
    submit_s: float = 0.0
    drain_s: float = 0.0
    assemble_s: float = 0.0

    @property
    def hit_rate(self) -> float:
        if self.rows_total == 0:
            return 0.0
        return (self.dedup_hit_rows + self.cache_hit_rows) / self.rows_total


class ServerTick:
    """One submitted shared tick: the session handle + the row assembly maps."""

    def __init__(self, server, tick, handle, view, compute_idx, u_src,
                 cached_i, cached_d, owner, tenant, qid, epoch, mutation,
                 submit_s):
        self._server = server
        self.tick = tick
        self._handle = handle          # session TickHandle | None (pure cache)
        self._view = view              # ComputeView snapshot
        self._compute_idx = compute_idx  # (Uc,) unique indices sent to device
        self._u_src = u_src            # (U,) >=0: computed row j; <0: cached -(c+1)
        self._cached_i = cached_i      # (C, k) stacked cache hits (host)
        self._cached_d = cached_d
        self._owner = owner            # registry snapshots at submit
        self._tenant = tenant
        self._qid = qid
        self._epoch = epoch            # cache epoch at submit
        self._mutation = mutation      # world-mutation counter at submit
        self._submit_s = submit_s      # staging wall inside submit(), incl compile
        self._observed = False         # drift bookkeeping folded into the cache
        self._inserted = False
        self._res: ServerTickResult | None = None
        self._inner = None

    def done(self) -> bool:
        return self._handle is None or self._handle.done()

    def result(self) -> ServerTickResult:
        """Materialize the shared tick (idempotent; see ServerTickResult)."""
        if self._res is not None:
            return self._res
        srv = self._server
        rebuilt = False
        compile_s = 0.0
        drain_s = 0.0
        if self._handle is not None:
            # drain the device computation in its own timed window: host
            # idle between submit() and this call is nobody's latency, and
            # the drain is the only part that scales with device work
            td = time.perf_counter()
            self._handle.block_until_ready()
            drain_s = max(0.0, time.perf_counter() - td)
        ta = time.perf_counter()
        if self._handle is not None:
            if srv.spec.collect == "full":
                self._inner = self._handle.result()
            else:
                self._inner = self._handle.result(materialize=False)
            rebuilt = self._inner.rebuilt
            compile_s = self._inner.compile_s
        srv._observe(self)
        # insert fresh results only if the world has not MUTATED since
        # submit (ingest bumps the mutation counter; a drift rebuild — same
        # positions, new sort order — deliberately does not): an ingest
        # racing this tick loses cached work, never poisons the store
        if (
            not self._inserted
            and self._inner is not None
            and self._inner.nn_idx is not None
            and srv.spec.collect == "full"
            and srv.cache.enabled
            and srv.cache.mutation == self._mutation
        ):
            keys = self._view.keys
            qpos = self._view.qpos
            kth = self._inner.kth_dist
            for j, u in enumerate(self._compute_idx):
                srv.cache.insert(
                    keys[u], self._inner.nn_idx[j], self._inner.nn_dist[j],
                    center=qpos[u], kth_dist=kth[j],
                )
            self._inserted = True
        R = int(self._owner.shape[0])
        U = self._view.n_unique
        Uc = int(self._compute_idx.shape[0])
        rows_per_u = np.bincount(
            self._view.row_to_unique, minlength=U
        ) if R else np.zeros((U,), np.int64)
        cache_rows = int(rows_per_u[self._u_src < 0].sum())
        # compile happens synchronously inside submit() (first-shape
        # dispatch), so it comes out of the submit window only
        assemble_s = max(0.0, time.perf_counter() - ta)
        submit_s = max(0.0, self._submit_s - compile_s)
        self._res = ServerTickResult(
            tick=self.tick,
            epoch=self._epoch,
            rows_total=R,
            rows_unique=U,
            rows_computed=Uc,
            dedup_hit_rows=(R - cache_rows) - Uc,
            cache_hit_rows=cache_rows,
            rebuilt=rebuilt,
            wall_s=submit_s + drain_s + assemble_s,
            compile_s=compile_s,
            inner=self._inner,
            submit_s=submit_s,
            drain_s=drain_s,
            assemble_s=assemble_s,
        )
        return self._res

    def _rows_for(self, rows: np.ndarray):
        """Assemble (nn_idx, nn_dist, qids) for a set of snapshot rows.

        Every path copies (fancy indexing / ``jnp.take``): callers own their
        arrays, cached entries stay read-only — no cross-tenant aliasing.
        """
        self.result()
        us = self._view.row_to_unique[rows]
        src = self._u_src[us]
        qids = self._qid[rows].copy()
        inner = self._inner
        if self._server.spec.collect != "full":
            # cache disabled here, so every unique row was computed: pure
            # device-side gather on the (materialize=False) result arrays
            if inner is None or inner.nn_idx is None:
                raise RuntimeError(
                    "result_for after the device buffers were released "
                    f"(collect={self._server.spec.collect!r})"
                )
            sel = jnp.asarray(src, jnp.int32)
            return inner.nn_idx[sel], inner.nn_dist[sel], qids
        k = self._server.spec.k
        out_i = np.empty((rows.shape[0], k), np.int32)
        out_d = np.empty((rows.shape[0], k), np.float32)
        comp = src >= 0
        if comp.any():
            out_i[comp] = inner.nn_idx[src[comp]]
            out_d[comp] = inner.nn_dist[src[comp]]
        if (~comp).any():
            c = -(src[~comp]) - 1
            out_i[~comp] = self._cached_i[c]
            out_d[~comp] = self._cached_d[c]
        return out_i, out_d, qids

    def result_for(self, handle: TenantQueryHandle):
        """This tick's rows for one tenant query group: (nn_idx, nn_dist, qids).

        Row selection uses the registry snapshot taken at submit, so the
        mapping stays correct even if the group moved or dropped afterwards.
        """
        rows = np.nonzero(self._owner == handle.hid)[0]
        if rows.size == 0:
            raise KeyError(
                f"{handle} owned no rows when tick {self.tick} was submitted"
            )
        return self._rows_for(rows)

    def result_for_tenant(self, tenant: TenantHandle):
        """All of one tenant's rows this tick (registration order)."""
        rows = np.nonzero(self._tenant == tenant.tid)[0]
        return self._rows_for(rows)


class KnnServer:
    """Admit tenants, coalesce their queries into one session's shared ticks.

    Construct from the same :class:`~repro.api.spec.ServiceSpec` a solo
    session takes — the spec IS the shared tick program (plan, partitioner,
    backend, collect mode).  ``max_tenants`` bounds admission;
    ``default_quota`` applies to tenants admitted without an explicit one
    (None = unbounded); ``cache_entries`` sizes the result cache (it is
    auto-disabled under ``collect != "full"``, where neighbour lists never
    reach the host — intra-tick dedup still shares device work there).

    ``invalidation`` selects the cache-invalidation mode (DESIGN.md §16):

    * ``"epoch"`` (default) — any delta ingest clears the whole store;
    * ``"spatial"`` — a delta ingest evicts only entries whose closed
      k-th-distance ball a moved row's old or new position stabs
      (:func:`repro.core.quadtree.ball_stab_mask`); deltas larger than
      ``stab_budget`` rows fall back to the epoch clear, and deltas up to
      ``stab_exact_rows`` use the exact pairwise check instead of the
      Morton cell-ball cover.  Requires a host mirror of object positions
      (kept only in this mode, refreshed per ingest) to recover each moved
      row's OLD position without a device round-trip.

    In both modes drift rebuilds leave the cache alone as a *store of
    inserts*: the insert guard is keyed on the world-mutation counter
    (bumped by ingests only), so a rebuilt tick's own fresh results are
    kept — a rebuild re-sorts the same positions and cannot change any
    row's bits.  Under ``"epoch"`` a rebuild still bumps the epoch (the
    historical conservative hygiene, observable in ``cache.epoch``); under
    ``"spatial"`` it is a no-op.
    """

    def __init__(self, spec: ServiceSpec, *, max_tenants: int | None = None,
                 default_quota: int | None = None, cache_entries: int = 65536,
                 fair_share: bool = True, invalidation: str = "epoch",
                 stab_budget: int = 4096, stab_exact_rows: int = 64):
        if invalidation not in ("epoch", "spatial"):
            raise ValueError(
                f"invalidation must be 'epoch' or 'spatial', got "
                f"{invalidation!r}"
            )
        if stab_budget < 0 or stab_exact_rows < 0:
            raise ValueError("stab_budget and stab_exact_rows must be >= 0")
        self.spec = spec
        self.session = KnnSession(spec)
        self.cache = ResultCache(
            capacity=cache_entries if spec.collect == "full" else 0
        )
        self.invalidation = invalidation
        self.stab_budget = int(stab_budget)
        self.stab_exact_rows = int(stab_exact_rows)
        # host mirror of object positions (spatial mode + enabled cache
        # only): the stab needs each moved row's OLD position, and reading
        # it back from the device would serialize ingest on the tick queue
        self._world: np.ndarray | None = None
        self.fair_share = fair_share
        self.max_tenants = max_tenants
        self.default_quota = default_quota
        self._registry = TenantRegistry()
        self._tenants: dict[str, TenantHandle] = {}
        self._next_tid = 0
        self._tick = 0
        self._inflight: deque[ServerTick] = deque()
        self._staged_sig: bytes | None = None
        self._staged_w: np.ndarray | None = None
        self.rows_served = 0
        self.rows_computed = 0

    # ------------------------------------------------------------ state views
    @property
    def tick(self) -> int:
        return self._tick

    @property
    def tenants(self) -> tuple[str, ...]:
        return tuple(self._tenants)

    @property
    def query_count(self) -> int:
        """Logical tenant query rows (>= the deduped device batch)."""
        return self._registry.nrows

    @property
    def num_objects(self) -> int:
        return self.session.num_objects

    def describe(self) -> str:
        return (
            f"server tenants={len(self._tenants)} rows={self.query_count} "
            f"cache={'off' if not self.cache.enabled else self.cache.capacity} "
            f"inval={self.invalidation} epoch={self.cache.epoch} | "
            f"{self.session.plan.describe()}"
        )

    # ------------------------------------------------------------ admission
    def admit(self, name: str, quota: int | None = None) -> TenantHandle:
        """Admit a tenant by unique name; returns its scoped handle."""
        if name in self._tenants:
            raise AdmissionError(f"tenant {name!r} is already admitted")
        if self.max_tenants is not None and len(self._tenants) >= self.max_tenants:
            raise AdmissionError(
                f"server is at max_tenants={self.max_tenants}"
            )
        if quota is None:
            quota = self.default_quota
        if quota is not None and quota < 1:
            raise ValueError(f"quota must be >= 1, got {quota}")
        t = TenantHandle(self, name, self._next_tid, quota)
        self._next_tid += 1
        self._tenants[name] = t
        return t

    def evict(self, tenant: TenantHandle):
        """Drop a tenant and every query row it registered.

        Cached results stay: they are keyed on tenant-agnostic geometry and
        remain bit-correct answers for any tenant at the current epoch.
        """
        if self._tenants.get(tenant.name) is not tenant:
            raise AdmissionError(f"tenant {tenant.name!r} is not admitted here")
        self._registry.drop_tenant(tenant.tid)
        del self._tenants[tenant.name]
        tenant.live = False

    # ------------------------------------------------------------ world state
    @property
    def _mirror_world(self) -> bool:
        return self.invalidation == "spatial" and self.cache.enabled

    def ingest_objects(self, positions):
        """Seed/replace the SHARED object world (snapshot path); bumps epoch.

        A snapshot replaces every position, so both modes clear the store
        (a stab against N moved rows is the epoch clear's work for no
        savings).
        """
        self.session.ingest_objects(positions)
        self.cache.bump_mutation()
        self.cache.bump_epoch("snapshot-ingest")
        if self._mirror_world:
            self._world = np.array(positions, np.float32).reshape(-1, 2)

    def _ingest_delta(self, tenant: TenantHandle, ids, positions):
        ids_a = np.asarray(ids, np.int64).reshape(-1)
        m = ids_a.shape[0]
        # the session validates ids/shapes first — an invalid delta must
        # not invalidate anything
        self.session.update_objects(ids, positions)
        if not m:
            return
        tenant.deltas_fed += m
        self.cache.bump_mutation()
        if self.invalidation == "spatial":
            self._invalidate_delta(
                ids_a, np.asarray(positions, np.float32).reshape(-1, 2),
                tenant.name,
            )
        else:
            self.cache.bump_epoch(f"delta-ingest:{tenant.name}")

    def _invalidate_delta(self, ids: np.ndarray, new_pos: np.ndarray,
                          name: str):
        """Spatial invalidation for one delta batch (already validated).

        Evicts exactly the entries whose closed k-th ball contains a moved
        row's old (host mirror) or new position; a batch over
        ``stab_budget`` rows falls back to the epoch clear (reason
        ``stab-budget:<tenant>``).  The mirror is updated keep-last per id,
        matching the session's scatter semantics, BEFORE the early returns
        so it never goes stale.
        """
        cache = self.cache
        if not cache.enabled:
            return
        # keep-last dedup: only the last occurrence of an id lands, and its
        # old position is the pre-batch mirror value (intermediate
        # positions within one batch never exist on device)
        _, keep_rev = np.unique(ids[::-1], return_index=True)
        sel = ids.shape[0] - 1 - keep_rev
        ids_u = ids[sel]
        new_u = new_pos[sel]
        if self._world is None:
            # no snapshot observed since spatial mode needed it (shouldn't
            # happen: ingest precedes deltas) — conservative full clear
            cache.bump_epoch(f"stab-nomirror:{name}")
            return
        old_u = self._world[ids_u].copy()
        self._world[ids_u] = new_u
        if ids_u.shape[0] > self.stab_budget:
            cache.bump_epoch(f"stab-budget:{name}")
            return
        keys, centers, kth2 = cache.geometry()
        if not keys:
            cache.last_invalidation = f"delta-stab:{name}"
            return
        mask = ball_stab_mask(
            centers, kth2, np.concatenate([old_u, new_u]),
            origin=np.asarray(self.spec.origin, np.float64),
            side=self.spec.side, l_max=self.spec.l_max,
            exact_rows=self.stab_exact_rows,
        )
        cache.evict_keys(
            [k for k, m in zip(keys, mask) if m], f"delta-stab:{name}"
        )

    # ------------------------------------------------------------ queries
    def _register_queries(self, tenant: TenantHandle, qpos, qid, *,
                          clip: bool) -> TenantQueryHandle:
        qpos = np.asarray(qpos, np.float32).reshape(-1, 2)
        m = qpos.shape[0]
        if qid is not None:
            qid = np.asarray(qid, np.int32).reshape(-1)
        remaining = tenant.quota_remaining
        if remaining is not None and m > remaining:
            if not clip or remaining == 0:
                raise QuotaExceededError(
                    f"tenant {tenant.name!r}: registering {m} rows would "
                    f"exceed quota {tenant.quota} "
                    f"({tenant.query_count} live, {remaining} remaining)"
                )
            qpos = qpos[:remaining]
            qid = None if qid is None else qid[:remaining]
            m = remaining
        hid = self._registry.register(tenant.tid, qpos, qid)
        return TenantQueryHandle(tenant=tenant.name, hid=hid, count=m)

    def _check_owner(self, tenant: TenantHandle, handle: TenantQueryHandle):
        if handle.tenant != tenant.name:
            raise KeyError(
                f"{handle} belongs to tenant {handle.tenant!r}, not "
                f"{tenant.name!r}"
            )

    def _update_queries(self, tenant, handle, qpos):
        self._check_owner(tenant, handle)
        self._registry.update(handle.hid, qpos)

    def _drop_queries(self, tenant, handle):
        self._check_owner(tenant, handle)
        self._registry.drop(handle.hid)

    # ------------------------------------------------------------ serving
    def _observe(self, st: ServerTick):
        """Fold one finalized tick's drift decision into the cache.

        A drift rebuild re-sorts the SAME positions, so already-cached
        entries are still bit-correct.  Under ``invalidation="epoch"`` the
        bump is the historical conservative hygiene; under ``"spatial"``
        nothing happens — no position changed, no ball was stabbed.  In
        BOTH modes the rebuild leaves the world-mutation counter alone, so
        the rebuilt tick's own fresh inserts are kept (the insert guard
        keys on mutation, not epoch).  The initial lazy build
        (``rebuilt_pre`` of tick 0) is not a drift decision and does not
        bump.
        """
        if st._observed:
            return
        h = st._handle
        if h is not None and not h.finalized:
            return  # not finalized yet; observed again later
        st._observed = True
        if h is not None and h.rebuilt_post and self.invalidation == "epoch":
            self.cache.bump_epoch("drift-rebuild")

    def submit(self) -> ServerTick:
        """Dispatch ONE shared tick for every admitted tenant's queries.

        Returns immediately after staging + dispatch (or instantly for a
        pure-cache tick); ``ServerTick.result()`` / ``result_for`` block.
        """
        if self._registry.nrows == 0:
            raise RuntimeError(
                "submit with no registered tenant queries: admit tenants and "
                "register_queries first"
            )
        t0 = time.perf_counter()
        # drift decisions of earlier ticks must land before the cache read
        self.session.finalize_pending()
        while self._inflight:
            st = self._inflight[0]
            self._observe(st)
            if not st._observed:
                break
            self._inflight.popleft()
        view = self._registry.compute_view()
        U = view.n_unique
        u_src = np.empty((U,), np.int64)
        compute_idx = []
        cached_entries = []
        for u, key in enumerate(view.keys):
            ent = self.cache.lookup(key) if self.cache.enabled else None
            if ent is None:
                u_src[u] = len(compute_idx)
                compute_idx.append(u)
            else:
                u_src[u] = -(len(cached_entries) + 1)
                cached_entries.append(ent)
        compute_idx = np.asarray(compute_idx, np.int64)
        k = self.spec.k
        if cached_entries:
            cached_i = np.stack([e[0] for e in cached_entries])
            cached_d = np.stack([e[1] for e in cached_entries])
        else:
            cached_i = np.zeros((0, k), np.int32)
            cached_d = np.zeros((0, k), np.float32)
        epoch = self.cache.epoch
        mutation = self.cache.mutation
        handle = None
        if compute_idx.size:
            sig = b"".join(view.keys[u] for u in compute_idx)
            w = None
            if self.fair_share:
                # each tenant's total boundary-seeding influence is equal;
                # duplicate rows SUM their owners' shares onto the one
                # computed unique row (shared work, shared influence)
                w_row = tenant_fair_weights(self._registry.tenant)
                w_u = np.zeros((U,), np.float32)
                np.add.at(w_u, view.row_to_unique, w_row)
                w = w_u[compute_idx]
            if sig != self._staged_sig:
                self.session.set_queries(
                    view.qpos[compute_idx], view.qid[compute_idx]
                )
                self.session.set_query_cost_weights(w)
                self._staged_sig, self._staged_w = sig, w
            elif not (
                w is None and self._staged_w is None
            ) and not np.array_equal(w, self._staged_w):
                self.session.set_query_cost_weights(w)
                self._staged_w = w
            handle = self.session.submit()
        st = ServerTick(
            self, self._tick, handle, view, compute_idx, u_src,
            cached_i, cached_d,
            self._registry.owner.copy(), self._registry.tenant.copy(),
            self._registry.qid.copy(), epoch, mutation,
            time.perf_counter() - t0,
        )
        self._tick += 1
        self._inflight.append(st)
        self.rows_served += self._registry.nrows
        self.rows_computed += int(compute_idx.size)
        return st
